//! Microbenchmarks of the protocol hot paths (hand-rolled harness — the
//! offline image has no criterion). Reports medians over repeated runs;
//! used by the §Perf pass in EXPERIMENTS.md.

use std::time::Instant;

use trident::crypto::Rng;
use trident::ring::{Matrix, Z64};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    println!("{name:<48} {:>12.3} ms (median of {iters})", med * 1e3);
}

fn main() {
    let pjrt = trident::runtime::pjrt::init_default();
    println!("pjrt artifacts: {}", if pjrt { "enabled" } else { "disabled (native only)" });
    let mut rng = Rng::seeded(42);

    // L3-native vs PJRT masked matmul at the NN layer shape
    for (a, b, c) in [(128usize, 784usize, 128usize), (128, 128, 128), (256, 256, 256)] {
        let mk = |rng: &mut Rng, r: usize, co: usize| Matrix::from_fn(r, co, |_, _| rng.gen::<Z64>());
        let lx = mk(&mut rng, a, b);
        let mx = mk(&mut rng, a, b);
        let my = mk(&mut rng, b, c);
        let ly = mk(&mut rng, b, c);
        let g = mk(&mut rng, a, c);
        let lz = mk(&mut rng, a, c);
        bench(&format!("native masked_matmul {a}x{b}x{c}"), 7, || {
            let out = trident::runtime::native::masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
            std::hint::black_box(&out);
        });
        if pjrt {
            bench(&format!("pjrt   masked_matmul {a}x{b}x{c}"), 7, || {
                let out = trident::runtime::pjrt::try_masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
                std::hint::black_box(&out);
            });
        }
        bench(&format!("native gemm          {a}x{b}x{c}"), 7, || {
            let out = trident::runtime::native::gemm(&lx, &my);
            std::hint::black_box(&out);
        });
    }

    // protocol end-to-end
    bench("4pc mult (cluster roundtrip)", 10, || {
        let run = trident::proto::run_4pc(trident::net::NetProfile::zero(), 1, |ctx| {
            let x = trident::proto::share(
                ctx,
                trident::net::P1,
                (ctx.id() == trident::net::P1).then_some(Z64(3)),
            )?;
            let y = trident::proto::share(
                ctx,
                trident::net::P2,
                (ctx.id() == trident::net::P2).then_some(Z64(5)),
            )?;
            let z = trident::proto::mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        std::hint::black_box(&run.report);
    });

    bench("4pc dotp d=1000 (cluster roundtrip)", 5, || {
        let run = trident::proto::run_4pc(trident::net::NetProfile::zero(), 2, |ctx| {
            let xs = trident::proto::sharing::share_many_n(
                ctx,
                trident::net::P1,
                (ctx.id() == trident::net::P1).then(|| vec![Z64(3); 1000]).as_deref(),
                1000,
            )?;
            let ys = trident::proto::sharing::share_many_n(
                ctx,
                trident::net::P2,
                (ctx.id() == trident::net::P2).then(|| vec![Z64(5); 1000]).as_deref(),
                1000,
            )?;
            let z = trident::proto::dotp(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        std::hint::black_box(&run.report);
    });

    // garbling throughput
    let circuit = trident::gc::circuit::aes_shaped();
    let r = rng.gen_key();
    let k0: Vec<[u8; 16]> = (0..circuit.n_inputs).map(|_| rng.gen_key()).collect();
    bench("garble AES-shaped circuit (6.4k ANDs)", 5, || {
        let g = trident::gc::garble::garble(&circuit, r, &k0);
        std::hint::black_box(&g.gc);
    });

    // one secure linreg iteration (d=100, B=128)
    bench("secure linreg iteration d=100 B=128", 3, || {
        let m = trident::bench::measure_linreg_iter(trident::net::NetProfile::lan(), 100, 128);
        std::hint::black_box(&m.report);
    });
}
