//! `cargo bench --bench serving`
//!
//! Serving-engine benchmark: amortized per-query online cost of the
//! circuit-keyed pool + cross-request-batching engine (`trident::serve`)
//! against the scalar-pool and seed-style inline paths, plus a coalescing
//! sweep over LAN and WAN. Hand-rolled harness (the offline image has no
//! criterion).

use trident::net::NetProfile;
use trident::serve::{serve, PoolMode, ServeConfig};

fn main() {
    trident::runtime::pjrt::init_default();

    // run the mode sweep + multi-tenant workload once; the text tables
    // and the JSON artifact below render the same measurements
    let bench = trident::bench::run_serving_bench();
    print!("{}", trident::bench::serve_table_from(&bench.modes));
    print!("{}", trident::bench::fill_throughput_line(&bench.fill));
    println!();

    println!("== coalescing sweep: 32 one-row queries, d=128, keyed pool + background refill ==");
    println!("net | coalesce | batches | online rounds | ms/query | B/query | off msgs in waves");
    for profile in [NetProfile::lan(), NetProfile::wan()] {
        for coalesce in [1usize, 2, 4, 8, 16, 32] {
            let cfg = ServeConfig {
                d: 128,
                rows_per_query: 1,
                queries: 32,
                coalesce,
                mode: PoolMode::Keyed,
                low_water: 1,
                high_water: 2,
                relu: false,
                seed: 77,
            };
            let s = serve(profile.clone(), cfg);
            println!(
                "{:<3} | {coalesce:>8} | {:>7} | {:>13} | {:>8.3} | {:>7.0} | {:>17}",
                profile.name,
                s.batches,
                s.online_rounds,
                s.per_query_latency() * 1e3,
                s.per_query_online_bytes(),
                s.offline_msgs_in_waves,
            );
        }
    }

    println!();
    println!("== Multi-tenant serving: 3 resident models (1 deep NN-3), WRR 2:1:1, LAN ==");
    print!("{}", trident::bench::tenant_table(&bench.tenants));

    println!();
    println!("== ReLU layer serving (keyed mode drains paired MatCorr+ReluCorr bundles) ==");
    for (mode, label) in [
        (PoolMode::Inline, "inline"),
        (PoolMode::Scalar, "scalar"),
        (PoolMode::Keyed, "keyed "),
    ] {
        let cfg = ServeConfig {
            d: 64,
            rows_per_query: 4,
            queries: 8,
            coalesce: 8,
            mode,
            low_water: 1,
            high_water: 2,
            relu: true,
            seed: 78,
        };
        let s = serve(NetProfile::lan(), cfg);
        println!(
            "{label}: {:.3} ms/query online, offline {:.1} KiB, rounds {}, off msgs in waves {} (mat {} | relu {})",
            s.per_query_latency() * 1e3,
            s.offline_value_bits as f64 / 8.0 / 1024.0,
            s.online_rounds,
            s.offline_msgs_in_waves,
            s.offline_msgs_matmul,
            s.offline_msgs_relu,
        );
    }

    // machine-readable perf trajectory, tracked across PRs at the repo
    // root — same measurements as the tables above, rendered once
    println!();
    match trident::bench::write_serving_bench_json_from(&bench, "BENCH_serving.json") {
        Ok(_) => println!("wrote BENCH_serving.json"),
        Err(e) => {
            // fail the bench run loudly: CI uploads this file as the perf
            // trajectory, and a swallowed write error would publish the
            // committed placeholder as if it were measured numbers
            eprintln!("could not write BENCH_serving.json: {e}");
            std::process::exit(1);
        }
    }
}
