//! `cargo bench --bench paper_tables [-- table4 fig20 ...]`
//!
//! Regenerates every table and figure of the paper's evaluation
//! (DESIGN.md §5 maps each to its module). Trident rows are measured runs
//! of the real protocols; baseline rows use the paper's own cost
//! accounting. Absolute numbers differ from the authors' testbed; the
//! *shape* (who wins, by what factor, where crossovers fall) is the
//! reproduction target — see EXPERIMENTS.md.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    trident::runtime::pjrt::init_default();
    let out = trident::bench::run_tables(&args);
    println!("{out}");
}
