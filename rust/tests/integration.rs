//! Cross-module integration + property tests: whole-pipeline flows,
//! coordinator invariants under the mini property harness, and
//! failure-injection beyond the per-module malicious tests.

use trident::convert::{a2b, b2a, bitext};
use trident::crypto::Rng;
use trident::ml::share_fixed_mat;
use trident::net::{Abort, NetProfile, Phase, P0, P1, P2, P3};
use trident::proto::sharing::share_many_n;
use trident::proto::{
    matmul_tr, mult, mult_tr, reconstruct, run_4pc, run_4pc_timeout, share,
};
use trident::ring::{Bit, FixedPoint, Matrix, Ring, Z64};
use trident::sharing::{mat::open_mat, open, MShare};
use trident::testutil::{forall, shrink_vec};

#[test]
fn arithmetic_circuit_end_to_end() {
    // (x + y)·z − 5, mixed dealers, opened by everyone
    let run = run_4pc(NetProfile::lan(), 500, |ctx| {
        let x = share(ctx, P0, (ctx.id() == P0).then_some(Z64(100)))?;
        let y = share(ctx, P1, (ctx.id() == P1).then_some(Z64(23)))?;
        let z = share(ctx, P2, (ctx.id() == P2).then_some(Z64(7)))?;
        let s = x + y;
        let p = mult(ctx, &s, &z)?;
        let out = p.add_const(Z64(0) - Z64(5));
        reconstruct(ctx, &out)
    });
    let (outs, _) = run.expect_ok();
    assert!(outs.iter().all(|&v| v == Z64((100 + 23) * 7 - 5)));
}

#[test]
fn property_linearity_of_shared_circuits() {
    // ∀ random (a, b, c): open(a·[[x]] + b·[[y]] + c) == a·x + b·y + c
    forall(
        501,
        25,
        |rng| {
            (
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
            )
        },
        |_| Vec::new(),
        |&(x, y, a, b, c)| {
            let run = run_4pc(NetProfile::zero(), x ^ y, move |ctx| {
                let sx = share(ctx, P1, (ctx.id() == P1).then_some(Z64(x)))?;
                let sy = share(ctx, P3, (ctx.id() == P3).then_some(Z64(y)))?;
                let lin = sx.scale(Z64(a)) + sy.scale(Z64(b));
                reconstruct(ctx, &lin.add_const(Z64(c)))
            });
            let (outs, _) = run.expect_ok();
            let want = Z64(x.wrapping_mul(a).wrapping_add(y.wrapping_mul(b)).wrapping_add(c));
            if outs[1] == want {
                Ok(())
            } else {
                Err(format!("got {:?} want {want:?}", outs[1]))
            }
        },
    );
}

#[test]
fn property_mult_agrees_with_ring() {
    forall(
        502,
        15,
        |rng| (rng.next_u64(), rng.next_u64()),
        |_| Vec::new(),
        |&(x, y)| {
            let run = run_4pc(NetProfile::zero(), x.wrapping_add(y), move |ctx| {
                let sx = share(ctx, P1, (ctx.id() == P1).then_some(Z64(x)))?;
                let sy = share(ctx, P2, (ctx.id() == P2).then_some(Z64(y)))?;
                let z = mult(ctx, &sx, &sy)?;
                ctx.flush_verify()?;
                Ok(z)
            });
            let (outs, _) = run.expect_ok();
            if open(&outs) == Z64(x.wrapping_mul(y)) {
                Ok(())
            } else {
                Err(format!("{x}·{y} mismatch"))
            }
        },
    );
}

#[test]
fn property_a2b_b2a_identity_random() {
    forall(
        503,
        8,
        |rng| rng.next_u64(),
        |&v| trident::testutil::shrink_u64(v).into_iter().collect(),
        |&v| {
            let run = run_4pc(NetProfile::zero(), v | 1, move |ctx| {
                let a = share(ctx, P2, (ctx.id() == P2).then_some(Z64(v)))?;
                let bits = a2b(ctx, &a)?;
                let back = b2a(ctx, &bits)?;
                ctx.flush_verify()?;
                Ok(back)
            });
            let (outs, _) = run.expect_ok();
            if open(&outs) == Z64(v) {
                Ok(())
            } else {
                Err(format!("roundtrip broke for {v}"))
            }
        },
    );
}

#[test]
fn property_batched_reconstruction_order_preserving() {
    forall(
        504,
        10,
        |rng| (0..rng.below(20) + 1).map(|_| rng.next_u64()).collect::<Vec<u64>>(),
        |v| shrink_vec(v),
        |vals| {
            let v2 = vals.clone();
            let n = vals.len();
            let run = run_4pc(NetProfile::zero(), 504, move |ctx| {
                let vs: Option<Vec<Z64>> =
                    (ctx.id() == P1).then(|| v2.iter().map(|&x| Z64(x)).collect());
                let shs = share_many_n(ctx, P1, vs.as_deref(), n)?;
                ctx.flush_verify()?;
                trident::proto::reconstruct::reconstruct_many(ctx, &shs)
            });
            let (outs, _) = run.expect_ok();
            let want: Vec<Z64> = vals.iter().map(|&x| Z64(x)).collect();
            if outs.iter().all(|o| *o == want) {
                Ok(())
            } else {
                Err("order or value mismatch".into())
            }
        },
    );
}

#[test]
fn secure_matmul_pipeline_matches_cleartext() {
    let mut rng = Rng::seeded(505);
    let a = Matrix::from_fn(5, 7, |_, _| rng.gen::<Z64>());
    let b = Matrix::from_fn(7, 3, |_, _| rng.gen::<Z64>());
    let (a2, b2) = (a.clone(), b.clone());
    let run = run_4pc(NetProfile::zero(), 505, move |ctx| {
        let sa = trident::testutil::share_mat(ctx, P1, &a2)?;
        let sb = trident::testutil::share_mat(ctx, P2, &b2)?;
        let sc = trident::proto::matmul(ctx, &sa, &sb)?;
        ctx.flush_verify()?;
        Ok(sc)
    });
    let (outs, _) = run.expect_ok();
    assert_eq!(open_mat(&outs), a.matmul(&b));
}

#[test]
fn relu_pipeline_fixed_point() {
    // x shared → matmul_tr with weights → relu → open: matches cleartext
    let run = run_4pc(NetProfile::zero(), 506, |ctx| {
        let x = trident::ml::F64Mat {
            rows: 2,
            cols: 2,
            data: vec![1.0, -2.0, 0.5, 3.0],
        };
        let w = trident::ml::F64Mat {
            rows: 2,
            cols: 1,
            data: vec![1.5, 1.0],
        };
        let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&x), 2, 2)?;
        let ws = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&w), 2, 1)?;
        let u = matmul_tr(ctx, &xs, &ws)?;
        let (r, _) = trident::ml::relu_many(ctx, &u.to_shares())?;
        ctx.flush_verify()?;
        trident::proto::reconstruct::reconstruct_many(ctx, &r)
    });
    let (outs, _) = run.expect_ok();
    let got: Vec<f64> = outs[1].iter().map(|&v| FixedPoint::decode(v)).collect();
    // cleartext: [1·1.5 + (−2)·1, 0.5·1.5 + 3·1] = [−0.5, 3.75] → relu
    assert!((got[0] - 0.0).abs() < 0.01, "{got:?}");
    assert!((got[1] - 3.75).abs() < 0.01, "{got:?}");
}

#[test]
fn comparison_chain_bitext_bit2a() {
    // sign(x) lifted back to arithmetic equals (x<0)
    for v in [-5i64, 5] {
        let run = run_4pc(NetProfile::zero(), 507, move |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(v)))?;
            let b = bitext(ctx, &x)?;
            let a = trident::convert::bit2a(ctx, &b)?;
            ctx.flush_verify()?;
            Ok(a)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open(&outs), Z64((v < 0) as u64));
    }
}

#[test]
fn cheater_cannot_flip_reconstruction() {
    // P2 lies about λ1 during Π_Rec towards P1 → P0's vouched digest busts it
    let run = run_4pc_timeout(
        NetProfile::zero(),
        508,
        std::time::Duration::from_millis(500),
        |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(77)))?;
            ctx.flush_verify()?;
            if ctx.id() == P2 {
                // emulate Π_Rec but send a corrupted λ1 to P1
                return ctx.online(|ctx| {
                    let bad = x.lam(P2, 1).unwrap() + Z64(1);
                    ctx.send_ring(P1, &[bad]);
                    let ms = [x.m()];
                    ctx.vouch_ring(P0, &ms);
                    let lam2: Vec<Z64> = ctx.recv_ring(P3, 1)?;
                    ctx.expect_ring(P0, &lam2);
                    let _ = ctx.flush_verify();
                    Ok(Z64(0))
                });
            }
            reconstruct(ctx, &x)
        },
    );
    // P1 must abort (digest mismatch), honest P3/P0 still fine or aborted —
    // but no honest party accepts a wrong value.
    match &run.outputs[1] {
        Err(_) => {}
        Ok(v) => assert_eq!(*v, Z64(77), "P1 must never accept a flipped value"),
    }
    assert!(run.outputs[1].is_err(), "P1 should abort on digest mismatch");
}

#[test]
fn dropout_party_aborts_cleanly() {
    // P3 goes silent mid-protocol: everyone else times out / aborts, no hang
    let run = run_4pc_timeout(
        NetProfile::zero(),
        509,
        std::time::Duration::from_millis(300),
        |ctx| {
            if ctx.id() == P3 {
                return Ok(Z64(0)); // drops out before the mult
            }
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(3)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(4)))?;
            let z = mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            reconstruct(ctx, &z)
        },
    );
    assert!(
        run.outputs.iter().skip(1).take(2).all(|o| o.is_err()),
        "evaluators must abort when P3 vanishes"
    );
}

#[test]
fn boolean_and_arithmetic_worlds_consistent() {
    // msb via Π_BitExt == msb via A2B's top bit, for the same share
    let run = run_4pc(NetProfile::zero(), 510, |ctx| {
        let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(-42i64)))?;
        let fast = bitext(ctx, &x)?;
        let bits = a2b(ctx, &x)?;
        let slow = bits[63];
        ctx.flush_verify()?;
        Ok((fast, slow))
    });
    let (outs, _) = run.expect_ok();
    let fast = open(&[outs[0].0, outs[1].0, outs[2].0, outs[3].0]);
    let slow = open(&[outs[0].1, outs[1].1, outs[2].1, outs[3].1]);
    assert_eq!(fast, Bit(true));
    assert_eq!(slow, Bit(true));
}

#[test]
fn trunc_pair_stream_stays_verified_under_load() {
    // hundreds of truncated multiplications in one run: all checks pass,
    // all results within tolerance
    let run = run_4pc(NetProfile::zero(), 511, |ctx| {
        let mut rng = Rng::seeded(99);
        let raw: Vec<(f64, f64)> = (0..200).map(|_| (rng.normal(), rng.normal())).collect();
        let r2 = raw.clone();
        let xs: Option<Vec<Z64>> = (ctx.id() == P1)
            .then(|| r2.iter().map(|c| FixedPoint::encode(c.0)).collect());
        let ys: Option<Vec<Z64>> = (ctx.id() == P2)
            .then(|| r2.iter().map(|c| FixedPoint::encode(c.1)).collect());
        let sx = share_many_n(ctx, P1, xs.as_deref(), 200)?;
        let sy = share_many_n(ctx, P2, ys.as_deref(), 200)?;
        let zs = trident::proto::trunc::mult_tr_many(ctx, &sx, &sy)?;
        ctx.flush_verify()?;
        Ok((raw, zs))
    });
    let (outs, _) = run.expect_ok();
    let raw = &outs[1].0;
    for i in 0..200 {
        let got = FixedPoint::decode(open(&[
            outs[0].1[i],
            outs[1].1[i],
            outs[2].1[i],
            outs[3].1[i],
        ]));
        let want = raw[i].0 * raw[i].1;
        assert!((got - want).abs() < 0.01, "case {i}: {got} vs {want}");
    }
}

#[test]
fn report_phases_never_mix() {
    let run = run_4pc(NetProfile::wan(), 512, |ctx| {
        let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(2.0)))?;
        let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(3.0)))?;
        let z = mult_tr(ctx, &x, &y)?;
        ctx.flush_verify()?;
        Ok(z)
    });
    let (_, report) = run.expect_ok();
    // offline and online both nonzero, P0 idle online
    assert!(report.value_bits[Phase::Offline as usize] > 0);
    assert!(report.value_bits[Phase::Online as usize] > 0);
    assert_eq!(report.party_time[Phase::Online as usize][0], 0.0);
    assert!(report.party_time[Phase::Offline as usize][0] > 0.0);
}

#[test]
fn mshare_share_vector_roundtrip_property() {
    forall(
        513,
        20,
        |rng| {
            let n = (rng.below(8) + 1) as usize;
            (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |v| shrink_vec(v),
        |vals| {
            // local (no network): deal/open roundtrip with random masks
            let mut rng = Rng::seeded(vals.iter().fold(0u64, |a, &b| a.wrapping_add(b)) | 1);
            for &v in vals {
                let lam = [rng.gen(), rng.gen(), rng.gen()];
                let shares = trident::sharing::deal(Z64(v), lam);
                if trident::sharing::open(&shares) != Z64(v) {
                    return Err(format!("deal/open broke for {v}"));
                }
                // linearity against a second sharing
                let lam2 = [rng.gen(), rng.gen(), rng.gen()];
                let shares2 = trident::sharing::deal(Z64(v).scale_id(), lam2);
                let sum: Vec<MShare<Z64>> =
                    (0..4).map(|i| shares[i] + shares2[i]).collect();
                if trident::sharing::open(&[sum[0], sum[1], sum[2], sum[3]])
                    != Z64(v.wrapping_add(v))
                {
                    return Err("linearity broke".into());
                }
            }
            Ok(())
        },
    );
}

/// helper for the property above
trait ScaleId {
    fn scale_id(self) -> Self;
}
impl ScaleId for Z64 {
    fn scale_id(self) -> Self {
        self
    }
}
