//! Protocol-equivalence suite backing the offline pool + serving engine:
//!
//! * **batched == scalar**: `mult_many`/`mult_tr_many`/`bit2a_many`/
//!   `bitext_many` open to the same values as their per-element scalar
//!   counterparts (property-tested via `testutil::forall`);
//! * **pool-backed == inline**: every protocol the pool feeds produces
//!   the same opened outputs whether its correlated randomness was
//!   pre-generated (`pool::fill_*`) or generated inline;
//! * **failure injection**: a tampered or replayed pooled truncation pair
//!   aborts in the online phase — never a wrong opened value at an honest
//!   party — and pool exhaustion falls back deterministically;
//! * **circuit-keyed matrix pooling**: `matmul`/`matmul_tr` through the
//!   keyed wire-mask pool open to the inline/cleartext values over a shape
//!   grid (1×k, k×1, non-square); a keyed gate and a whole warm-pool
//!   serving wave send **zero offline-phase messages** (asserted via the
//!   per-party sent-traffic counters); tampered wire masks, replayed
//!   `MatGamma` bundles and cross-key material all end in `Abort`;
//! * **meter regressions**: pool attachment leaves `Π_MultTr`'s online
//!   rounds/bits untouched (the paper-shaped cost), and a coalesced wave
//!   of N queries costs the rounds of a single query;
//! * **multi-tenant scheduling** (`sched` + `serve::multi`): per-tenant
//!   keyed waves open to the same values as the inline path (both vs the
//!   cleartext oracle), a cross-tenant pool pop **fails closed** (tenant
//!   A's correlation is never served to tenant B), a two-tenant warm run
//!   keeps **every** wave offline-silent per tenant (trailing partial
//!   waves included), and the weighted round-robin planner's share split
//!   holds within one wave over a saturated window;
//! * **abort blast-radius containment**: a keyed bundle tampered mid-run
//!   quarantines only the owning tenant — the quarantine tick is
//!   lockstep-identical at all four parties, every surviving answer
//!   (including the poisoned wave's re-queued queries) matches the
//!   cleartext oracle — while party-scoped aborts, and any abort with
//!   containment off, still fail the whole run closed;
//! * **GOD failover** (`FailoverPolicy::God`): a quarantined tenant's
//!   re-queued queries serve on the Tetrad-style guaranteed-output-
//!   delivery backend — zero lost queries, lockstep failover/rehab
//!   transitions at all four parties, post-rehab keyed waves offline-
//!   silent again — while party-scoped aborts still fail the run closed
//!   and the whole backend family (Trident / Tetrad-fair / Tetrad-GOD)
//!   opens identical values against the cleartext oracle;
//! * **scheduled training**: a training job driven through the same
//!   registry/queue/planner lands on a cleartext fixed-point GD oracle
//!   (logreg with the 3-segment sigmoid head and a deep NN, keyed ==
//!   inline), warm keyed epochs stay offline-silent, and restoring a
//!   mid-job checkpoint replays only the remaining epochs onto the full
//!   run's final model.

use trident::convert::{bit2a, bit2a_many, bitext, bitext_many};
use trident::crypto::Rng;
use trident::net::{NetProfile, Phase, P1, P2, P3};
use trident::pool::{
    fill_bitext, fill_lam, fill_mat, fill_mat_relu, fill_trunc, relu_key_for, CircuitKey, OpKind,
    Pool,
};
use trident::proto::sharing::share_many_n;
use trident::proto::{
    dotp, matmul, matmul_keyed, matmul_tr_keyed, mult, mult_many, mult_tr, mult_tr_many,
    run_4pc, run_4pc_timeout, share,
};
use trident::ring::fixed::{FixedPoint, FRAC_BITS, SCALE};
use trident::ring::{Bit, Matrix, Z64};
use trident::sharing::mat::open_mat;
use trident::sharing::{open, MShare};
use trident::testutil::{forall, share_mat, shrink_vec};

// ---------------------------------------------------------- batched == scalar

#[test]
fn property_mult_many_equals_scalar_mult() {
    forall(
        601,
        6,
        |rng| {
            let n = (rng.below(6) + 1) as usize;
            (0..2 * n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| v.len() % 2 == 0 && !v.is_empty()).collect(),
        |vals| {
            let n = vals.len() / 2;
            let (xs, ys) = (vals[..n].to_vec(), vals[n..].to_vec());
            let (x2, y2) = (xs.clone(), ys.clone());
            let run = run_4pc(NetProfile::zero(), 601, move |ctx| {
                let sx = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1).then(|| x2.iter().map(|&v| Z64(v)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let sy = share_many_n(
                    ctx,
                    P2,
                    (ctx.id() == P2).then(|| y2.iter().map(|&v| Z64(v)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let batched = mult_many(ctx, &sx, &sy)?;
                let mut scalar = Vec::with_capacity(n);
                for i in 0..n {
                    scalar.push(mult(ctx, &sx[i], &sy[i])?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for i in 0..n {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Z64(xs[i].wrapping_mul(ys[i]));
                if b != want || s != want {
                    return Err(format!(
                        "gate {i}: batched {b:?}, scalar {s:?}, want {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_mult_tr_many_equals_scalar_mult_tr() {
    forall(
        602,
        5,
        |rng| {
            let n = (rng.below(4) + 1) as usize;
            (0..2 * n).map(|_| rng.normal() * 8.0).collect::<Vec<f64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| v.len() % 2 == 0 && !v.is_empty()).collect(),
        |vals| {
            let n = vals.len() / 2;
            let (xs, ys) = (vals[..n].to_vec(), vals[n..].to_vec());
            let (x2, y2) = (xs.clone(), ys.clone());
            let run = run_4pc(NetProfile::zero(), 602, move |ctx| {
                let sx = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1)
                        .then(|| x2.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let sy = share_many_n(
                    ctx,
                    P2,
                    (ctx.id() == P2)
                        .then(|| y2.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let batched = mult_tr_many(ctx, &sx, &sy)?;
                let mut scalar = Vec::with_capacity(n);
                for i in 0..n {
                    scalar.push(mult_tr(ctx, &sx[i], &sy[i])?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for i in 0..n {
                let b = FixedPoint::decode(open(&[
                    outs[0].0[i],
                    outs[1].0[i],
                    outs[2].0[i],
                    outs[3].0[i],
                ]));
                let s = FixedPoint::decode(open(&[
                    outs[0].1[i],
                    outs[1].1[i],
                    outs[2].1[i],
                    outs[3].1[i],
                ]));
                let want = xs[i] * ys[i];
                let tol = (xs[i].abs() + ys[i].abs() + 4.0) / SCALE;
                if (b - want).abs() > tol || (s - want).abs() > tol {
                    return Err(format!(
                        "gate {i}: batched {b}, scalar {s}, want {want} (tol {tol})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_bit2a_many_equals_scalar_bit2a() {
    forall(
        603,
        5,
        |rng| {
            let n = (rng.below(6) + 1) as usize;
            (0..n).map(|_| rng.next_u64() & 1 == 1).collect::<Vec<bool>>()
        },
        |v| shrink_vec(v),
        |bits| {
            let n = bits.len();
            let b2 = bits.clone();
            let run = run_4pc(NetProfile::zero(), 603, move |ctx| {
                let bs = share_many_n(
                    ctx,
                    P3,
                    (ctx.id() == P3).then(|| b2.iter().map(|&b| Bit(b)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let batched = bit2a_many(ctx, &bs)?;
                let mut scalar = Vec::with_capacity(n);
                for b in &bs {
                    scalar.push(bit2a(ctx, b)?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for (i, &bit) in bits.iter().enumerate() {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Z64(bit as u64);
                if b != want || s != want {
                    return Err(format!("bit {i}: batched {b:?}, scalar {s:?}, want {want:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_bitext_many_equals_scalar_bitext() {
    forall(
        604,
        5,
        |rng| {
            let n = (rng.below(5) + 1) as usize;
            (0..n)
                .map(|_| {
                    let v = rng.next_u64() as i64 / 4;
                    if v == 0 {
                        1
                    } else {
                        v
                    }
                })
                .collect::<Vec<i64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| !v.is_empty()).collect(),
        |vals| {
            let n = vals.len();
            let v2 = vals.clone();
            let run = run_4pc(NetProfile::zero(), 604, move |ctx| {
                let vs = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1)
                        .then(|| v2.iter().map(|&v| Z64::from(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let batched = bitext_many(ctx, &vs)?;
                let mut scalar = Vec::with_capacity(n);
                for v in &vs {
                    scalar.push(bitext(ctx, v)?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for (i, &v) in vals.iter().enumerate() {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Bit(v < 0);
                if b != want || s != want {
                    return Err(format!("msb({v}): batched {b:?}, scalar {s:?}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------ pool-backed == inline

/// Run `body` twice — once with a pre-stocked pool, once inline — and
/// require identical opened outputs.
fn assert_pool_inline_equal<F>(seed: u64, n: usize, body: F)
where
    F: Fn(&mut trident::proto::Ctx, bool) -> Result<Vec<MShare<Z64>>, trident::net::Abort>
        + Send
        + Sync
        + Copy
        + 'static,
{
    let pooled = run_4pc(NetProfile::zero(), seed, move |ctx| body(ctx, true));
    let inline = run_4pc(NetProfile::zero(), seed, move |ctx| body(ctx, false));
    let (po, _) = pooled.expect_ok();
    let (io, _) = inline.expect_ok();
    for i in 0..n {
        let p = open(&[po[0][i], po[1][i], po[2][i], po[3][i]]);
        let q = open(&[io[0][i], io[1][i], io[2][i], io[3][i]]);
        assert_eq!(p, q, "pool-backed vs inline diverged at output {i}");
    }
}

#[test]
fn pool_inline_equivalence_mult_many() {
    let n = 5;
    assert_pool_inline_equal(611, n, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, n);
        }
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| (1..=n as u64).map(Z64).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| (11..=10 + n as u64).map(Z64).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let zs = mult_many(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        if pool {
            let stats = ctx.detach_pool().unwrap().stats();
            assert!(stats.lam_hits >= 1, "pooled run must hit the λ pool: {stats:?}");
        }
        Ok(zs)
    });
}

#[test]
fn pool_inline_equivalence_dotp() {
    assert_pool_inline_equal(612, 1, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, 1);
        }
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| vec![Z64(3); 20]).as_deref(),
            20,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| vec![Z64(7); 20]).as_deref(),
            20,
        )?;
        let z = dotp(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        Ok(vec![z])
    });
}

#[test]
fn pool_inline_equivalence_bit2a_many() {
    let bits = [true, false, true, true];
    assert_pool_inline_equal(613, bits.len(), move |ctx, pool| {
        let n = bits.len();
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, n);
        }
        let bs = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| bits.iter().map(|&b| Bit(b)).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let out = bit2a_many(ctx, &bs)?;
        ctx.flush_verify()?;
        Ok(out)
    });
}

#[test]
fn pool_inline_equivalence_mult_tr_many() {
    // truncation pairs differ between the two runs (they are fresh
    // randomness), so equivalence is against the cleartext oracle within
    // the probabilistic-truncation tolerance — for both runs.
    let vals = [(1.5f64, 2.5f64), (-3.25, 1.5), (0.75, -4.0)];
    let n = vals.len();
    let runner = |pool: bool| {
        run_4pc(NetProfile::zero(), 614, move |ctx| {
            if pool {
                ctx.attach_pool(Pool::new());
                fill_trunc(ctx, n, FRAC_BITS)?;
            }
            let xs = share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| vals.iter().map(|c| FixedPoint::encode(c.0)).collect::<Vec<_>>())
                    .as_deref(),
                n,
            )?;
            let ys = share_many_n(
                ctx,
                P2,
                (ctx.id() == P2)
                    .then(|| vals.iter().map(|c| FixedPoint::encode(c.1)).collect::<Vec<_>>())
                    .as_deref(),
                n,
            )?;
            let zs = mult_tr_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            let hits = ctx.detach_pool().map(|p| p.stats().trunc_hits).unwrap_or(0);
            Ok((zs, hits))
        })
    };
    for pool in [true, false] {
        let (outs, _) = runner(pool).expect_ok();
        if pool {
            assert!(outs[1].1 >= 1, "pooled run must consume pooled pairs");
        }
        for (i, &(a, b)) in vals.iter().enumerate() {
            let got = FixedPoint::decode(open(&[
                outs[0].0[i],
                outs[1].0[i],
                outs[2].0[i],
                outs[3].0[i],
            ]));
            let tol = (a.abs() + b.abs() + 4.0) / SCALE;
            assert!(
                (got - a * b).abs() <= tol,
                "pool={pool} gate {i}: {a}·{b} → {got}"
            );
        }
    }
}

#[test]
fn pool_inline_equivalence_bitext_and_relu() {
    let vals = [-3.5f64, 2.25, -0.125, 7.0];
    let n = vals.len();
    assert_pool_inline_equal(615, n, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_bitext(ctx, n)?;
            fill_lam::<Z64>(ctx, 1); // the Π_Mult inside Π_BitExt
        }
        let vs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1)
                .then(|| vals.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                .as_deref(),
            n,
        )?;
        let (relu, _drelu) = trident::ml::relu_many(ctx, &vs)?;
        ctx.flush_verify()?;
        if pool {
            let stats = ctx.detach_pool().unwrap().stats();
            assert!(stats.bitext_hits >= 1, "relu must pop bitext masks: {stats:?}");
        }
        Ok(relu)
    });
}

// ---------------------------------------------------------- failure injection

#[test]
fn tampered_pool_trunc_pair_aborts_online() {
    let run = run_4pc_timeout(
        NetProfile::zero(),
        621,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 1, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                // a malicious P2 corrupts its stored r1 component
                let pair = ctx.pool_mut().unwrap().trunc_front_mut(FRAC_BITS).unwrap();
                pair.r[0] = pair.r[0].map(|v| v + Z64(1));
            }
            let x = share(ctx, P1, (me == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (me == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled pair must abort, got ok");
}

#[test]
fn replayed_pool_trunc_pair_aborts_online() {
    let run = run_4pc_timeout(
        NetProfile::zero(),
        622,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 2, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                // P2 re-serves its first pair while the peers advance
                assert!(ctx.pool_mut().unwrap().replay_front_trunc(FRAC_BITS));
            }
            let xs = share_many_n(
                ctx,
                P1,
                (me == P1)
                    .then(|| vec![FixedPoint::encode(1.5), FixedPoint::encode(-2.0)])
                    .as_deref(),
                2,
            )?;
            let ys = share_many_n(
                ctx,
                P2,
                (me == P2)
                    .then(|| vec![FixedPoint::encode(3.0), FixedPoint::encode(0.5)])
                    .as_deref(),
                2,
            )?;
            let zs = mult_tr_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(zs)
        },
    );
    assert!(run.any_verify_abort(), "replayed pooled pair must abort");
}

#[test]
fn tampered_pool_rt_never_yields_wrong_opened_value() {
    // Corrupting the [[rᵗ]] mask component only skews the cheater's output
    // share; the damage must surface as an abort during reconstruction,
    // never as a wrong value accepted by an honest party.
    let run = run_4pc_timeout(
        NetProfile::zero(),
        623,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 1, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                let pair = ctx.pool_mut().unwrap().trunc_front_mut(FRAC_BITS).unwrap();
                if let MShare::Eval { lam_prev, .. } = &mut pair.rt {
                    *lam_prev += Z64(1); // P2's copy of λ1
                }
            }
            let x = share(ctx, P1, (me == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (me == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            trident::proto::reconstruct(ctx, &z)
        },
    );
    // P1 receives the corrupted λ1 from P2; P0's vouched digest busts it
    assert!(run.outputs[1].is_err(), "P1 must abort on the corrupted λ1");
    // no honest party accepts a wrong value
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 2 {
            continue; // the cheater's own view is unconstrained
        }
        if let Ok(v) = out {
            let got = FixedPoint::decode(*v);
            assert!(
                (got - 6.0).abs() < 0.01,
                "P{i} accepted a wrong opened value: {got}"
            );
        }
    }
}

#[test]
fn pool_exhaustion_falls_back_deterministically() {
    let run = run_4pc(NetProfile::zero(), 624, |ctx| {
        ctx.attach_pool(Pool::new());
        fill_trunc(ctx, 2, FRAC_BITS)?;
        // request MORE than stocked: every party falls back to inline
        // generation, leaving the stock untouched
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| vec![FixedPoint::encode(1.0); 4]).as_deref(),
            4,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| vec![FixedPoint::encode(2.0); 4]).as_deref(),
            4,
        )?;
        let zs = mult_tr_many(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        let pool = ctx.detach_pool().unwrap();
        Ok((zs, pool.len_trunc(FRAC_BITS), pool.stats()))
    });
    let (outs, _) = run.expect_ok();
    for i in 0..4 {
        let got = FixedPoint::decode(open(&[
            outs[0].0[i],
            outs[1].0[i],
            outs[2].0[i],
            outs[3].0[i],
        ]));
        assert!((got - 2.0).abs() < 0.01, "fallback result {i}: {got}");
    }
    // stock untouched, exactly one recorded miss, at every party
    for o in &outs {
        assert_eq!(o.1, 2, "all-or-nothing: stock must be untouched");
        assert_eq!(o.2.trunc_misses, 1);
        assert_eq!(o.2.trunc_hits, 0);
    }
}

// --------------------------------------------------------- meter regressions

#[test]
fn meter_pool_leaves_mult_tr_online_cost_unchanged() {
    let runner = |pool: bool| {
        run_4pc(NetProfile::zero(), 631, move |ctx| {
            if pool {
                ctx.attach_pool(Pool::new());
                fill_trunc(ctx, 1, FRAC_BITS)?;
            }
            let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        })
    };
    let (_, with_pool) = runner(true).expect_ok();
    let (_, without) = runner(false).expect_ok();
    // Table II shape: online rounds and value bits identical either way
    assert_eq!(
        with_pool.rounds[1], without.rounds[1],
        "pool attachment must not change online rounds"
    );
    assert_eq!(
        with_pool.value_bits[1], without.value_bits[1],
        "pool attachment must not change online bits"
    );
    // offline work is moved (into the fill), not grown: same total bits
    assert_eq!(
        with_pool.value_bits[0], without.value_bits[0],
        "pool moves offline cost, it must not grow it"
    );
    // online stays 3ℓ beyond the two input sharings (Lemma D.2)
    assert_eq!(with_pool.value_bits[1] - 4 * 64, 3 * 64);
}

#[test]
fn meter_coalesced_wave_costs_single_query_rounds() {
    use trident::serve::{serve, PoolMode, ServeConfig};
    let cfg = |queries: usize, coalesce: usize| ServeConfig {
        d: 8,
        rows_per_query: 1,
        queries,
        coalesce,
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 1,
        relu: false,
        seed: 632,
    };
    let one = serve(NetProfile::zero(), cfg(1, 1));
    let wave = serve(NetProfile::zero(), cfg(8, 8));
    assert_eq!(wave.batches, 1);
    assert_eq!(
        wave.online_rounds, one.online_rounds,
        "8 coalesced queries must cost ~1× (not 8×) the rounds of one query"
    );
    let inline = serve(
        NetProfile::zero(),
        ServeConfig { mode: PoolMode::Inline, ..cfg(8, 1) },
    );
    assert_eq!(inline.online_rounds, 8 * one.online_rounds);
}

// ------------------------------------------ circuit-keyed pool == inline

/// Run one `OpKind::MatMul` gate through the circuit-keyed pool and through
/// the inline path; require both to open to the exact ring product, the
/// keyed gate to be served from the pool, and the keyed gate window to send
/// **zero offline-phase messages** at every party.
fn check_keyed_matmul_matches_inline(
    a: usize,
    b: usize,
    c: usize,
    vals: Vec<u64>,
) -> Result<(), String> {
    assert_eq!(vals.len(), a * b + b * c);
    let x = Matrix::from_vec(a, b, vals[..a * b].iter().map(|&v| Z64(v)).collect());
    let y = Matrix::from_vec(b, c, vals[a * b..].iter().map(|&v| Z64(v)).collect());
    let key = CircuitKey {
        model: 41,
        layer: 7,
        op: OpKind::MatMul,
        rows: a,
        inner: b,
        cols: c,
        dealer: P2,
    };
    let (x2, y2) = (x.clone(), y.clone());
    let run = run_4pc(NetProfile::zero(), 641, move |ctx| {
        // resident Y from the model owner; live X arrives from P2 per gate
        let ysh = share_mat(ctx, P1, &y2)?;
        ctx.attach_pool(Pool::new());
        fill_mat(ctx, key, &ysh, 1)?;
        let off0 = ctx.net.sent_msgs(Phase::Offline);
        let (_xsh, z_keyed) =
            matmul_keyed(ctx, &key, (ctx.id() == P2).then_some(&x2), &ysh)?;
        let off_sent = ctx.net.sent_msgs(Phase::Offline) - off0;
        let xsh = share_mat(ctx, P2, &x2)?;
        let z_inline = matmul(ctx, &xsh, &ysh)?;
        ctx.flush_verify()?;
        let hits = ctx.detach_pool().unwrap().stats().mat_hits;
        Ok((z_keyed, z_inline, off_sent, hits))
    });
    let (outs, _) = run.expect_ok();
    let keyed = open_mat(&[
        outs[0].0.clone(),
        outs[1].0.clone(),
        outs[2].0.clone(),
        outs[3].0.clone(),
    ]);
    let inline = open_mat(&[
        outs[0].1.clone(),
        outs[1].1.clone(),
        outs[2].1.clone(),
        outs[3].1.clone(),
    ]);
    let want = x.matmul(&y);
    if keyed != want {
        return Err(format!("{a}×{b}×{c}: keyed product diverged from cleartext"));
    }
    if inline != want {
        return Err(format!("{a}×{b}×{c}: inline product diverged from cleartext"));
    }
    for (i, o) in outs.iter().enumerate() {
        if o.2 != 0 {
            return Err(format!(
                "P{i} sent {} offline-phase messages inside the keyed gate",
                o.2
            ));
        }
        if o.3 != 1 {
            return Err(format!("P{i}: keyed gate must be served from the pool"));
        }
    }
    Ok(())
}

#[test]
fn property_matmul_keyed_equals_inline_for_random_shapes() {
    forall(
        641,
        5,
        |rng| {
            let a = (rng.below(3) + 1) as usize;
            let b = (rng.below(4) + 1) as usize;
            let c = (rng.below(3) + 1) as usize;
            let vals: Vec<u64> = (0..(a * b + b * c)).map(|_| rng.next_u64()).collect();
            (a, b, c, vals)
        },
        |_| Vec::new(), // shapes don't shrink meaningfully
        |case| {
            let (a, b, c, vals) = case.clone();
            check_keyed_matmul_matches_inline(a, b, c, vals)
        },
    );
}

#[test]
fn keyed_matmul_shape_grid_including_vectors() {
    // the explicit grid the suite promises: 1×k, k×1, non-square, scalar
    let mut rng = Rng::seeded(642);
    for (a, b, c) in [(1, 5, 1), (5, 1, 3), (1, 1, 1), (2, 3, 4), (3, 4, 1)] {
        let vals: Vec<u64> = (0..(a * b + b * c)).map(|_| rng.next_u64()).collect();
        check_keyed_matmul_matches_inline(a, b, c, vals)
            .unwrap_or_else(|e| panic!("shape {a}×{b}×{c}: {e}"));
    }
}

#[test]
fn keyed_matmul_tr_matches_cleartext_over_shape_grid() {
    let mut rng = Rng::seeded(645);
    for (a, b, c) in [(1usize, 4usize, 1usize), (4, 1, 2), (2, 3, 2)] {
        let xf: Vec<f64> = (0..a * b).map(|_| rng.normal()).collect();
        let yf: Vec<f64> = (0..b * c).map(|_| rng.normal()).collect();
        let x = Matrix::from_vec(a, b, xf.iter().map(|&v| FixedPoint::encode(v)).collect());
        let y = Matrix::from_vec(b, c, yf.iter().map(|&v| FixedPoint::encode(v)).collect());
        let key = CircuitKey {
            model: 5,
            layer: 1,
            op: OpKind::MatMulTr { shift: FRAC_BITS },
            rows: a,
            inner: b,
            cols: c,
            dealer: P2,
        };
        let (x2, y2) = (x.clone(), y.clone());
        let run = run_4pc(NetProfile::zero(), 646, move |ctx| {
            let ysh = share_mat(ctx, P1, &y2)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key, &ysh, 1)?;
            let off0 = ctx.net.sent_msgs(Phase::Offline);
            let (_xsh, z) =
                matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x2), &ysh)?;
            let off_sent = ctx.net.sent_msgs(Phase::Offline) - off0;
            ctx.flush_verify()?;
            Ok((z, off_sent))
        });
        let (outs, _) = run.expect_ok();
        let got = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        // oracle: the same fixed-point ring product, truncated — isolates
        // the protocol's ≤2-ulp probabilistic-truncation error from the
        // f64→fixed encoding error of the inputs
        let clear = x.matmul(&y);
        for i in 0..a {
            for j in 0..c {
                let want = FixedPoint::decode(clear[(i, j)].truncate(FRAC_BITS));
                let gotv = FixedPoint::decode(got[(i, j)]);
                assert!(
                    (gotv - want).abs() <= 4.0 / SCALE,
                    "{a}×{b}×{c} ({i},{j}): keyed {gotv}, fixed-point oracle {want}"
                );
            }
        }
        for (p, o) in outs.iter().enumerate() {
            assert_eq!(o.1, 0, "P{p} sent offline messages inside the keyed Π_MatMulTr");
        }
    }
}

#[test]
fn keyed_matmul_tr_online_cost_matches_inline_3l() {
    // A 1×1×1 keyed gate ≡ scalar Π_MultTr: online = input delivery (2ℓ:
    // the dealer sends m to the two other evaluators) + the 3ℓ exchange,
    // in 2 data rounds — identical to the inline path, which additionally
    // pays its offline phase live. Pooling must move offline cost, not
    // grow it, and must leave the Table-II online shape untouched.
    let key = CircuitKey {
        model: 6,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows: 1,
        inner: 1,
        cols: 1,
        dealer: P2,
    };
    let x = Matrix::from_vec(1, 1, vec![FixedPoint::encode(2.0)]);
    let y = Matrix::from_vec(1, 1, vec![FixedPoint::encode(3.0)]);
    let (x2, y2) = (x.clone(), y.clone());
    let keyed = run_4pc(NetProfile::zero(), 647, move |ctx| {
        let ysh = share_mat(ctx, P1, &y2)?;
        ctx.attach_pool(Pool::new());
        fill_mat(ctx, key, &ysh, 1)?;
        let (_xsh, z) = matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x2), &ysh)?;
        ctx.flush_verify()?;
        Ok(z)
    });
    let (x3, y3) = (x.clone(), y.clone());
    let inline = run_4pc(NetProfile::zero(), 647, move |ctx| {
        let ysh = share_mat(ctx, P1, &y3)?;
        let xsh = share_mat(ctx, P2, &x3)?;
        let z = trident::proto::matmul_tr(ctx, &xsh, &ysh)?;
        ctx.flush_verify()?;
        Ok(z)
    });
    let (kouts, krep) = keyed.expect_ok();
    let (iouts, irep) = inline.expect_ok();
    let kv = FixedPoint::decode(
        open_mat(&[kouts[0].clone(), kouts[1].clone(), kouts[2].clone(), kouts[3].clone()])
            [(0, 0)],
    );
    let iv = FixedPoint::decode(
        open_mat(&[iouts[0].clone(), iouts[1].clone(), iouts[2].clone(), iouts[3].clone()])
            [(0, 0)],
    );
    assert!((kv - 6.0).abs() < 0.01 && (iv - 6.0).abs() < 0.01);
    // Π_MultTr online shape: y-share (2ℓ) + x-delivery (2ℓ) + 3ℓ exchange
    assert_eq!(krep.value_bits[1], (2 + 2 + 3) * 64, "keyed online = inputs + 3ℓ");
    assert_eq!(krep.value_bits[1], irep.value_bits[1], "online bits identical");
    assert_eq!(krep.rounds[1], irep.rounds[1], "online rounds identical");
    // offline cost is moved into the fill, not grown (value bits equal)
    assert_eq!(krep.value_bits[0], irep.value_bits[0], "offline bits moved, not grown");
}

#[test]
fn keyed_exhaustion_falls_back_inline_deterministically() {
    let key = CircuitKey {
        model: 8,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows: 2,
        inner: 2,
        cols: 1,
        dealer: P2,
    };
    let x = Matrix::from_vec(
        2,
        2,
        vec![
            FixedPoint::encode(1.0),
            FixedPoint::encode(2.0),
            FixedPoint::encode(-1.5),
            FixedPoint::encode(0.5),
        ],
    );
    let y = Matrix::from_vec(2, 1, vec![FixedPoint::encode(3.0), FixedPoint::encode(-2.0)]);
    let want = [1.0 * 3.0 + 2.0 * -2.0, -1.5 * 3.0 + 0.5 * -2.0];
    let (x2, y2) = (x.clone(), y.clone());
    let run = run_4pc(NetProfile::zero(), 648, move |ctx| {
        let ysh = share_mat(ctx, P1, &y2)?;
        ctx.attach_pool(Pool::new());
        fill_mat(ctx, key, &ysh, 1)?;
        // first gate drains the only bundle; the second falls back inline —
        // at every party, in lockstep
        let (_x1, z1) = matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x2), &ysh)?;
        let (_x2, z2) = matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x2), &ysh)?;
        ctx.flush_verify()?;
        let stats = ctx.detach_pool().unwrap().stats();
        Ok((z1, z2, stats))
    });
    let (outs, _) = run.expect_ok();
    for pick in [0usize, 1] {
        let z = |i: usize| match pick {
            0 => outs[i].0.clone(),
            _ => outs[i].1.clone(),
        };
        let opened = open_mat(&[z(0), z(1), z(2), z(3)]);
        for (r, want) in want.iter().enumerate() {
            let got = FixedPoint::decode(opened[(r, 0)]);
            assert!(
                (got - want).abs() < 0.01,
                "gate {pick}, row {r}: got {got}, want {want}"
            );
        }
    }
    for o in &outs {
        assert_eq!(o.2.mat_hits, 1, "first gate pooled");
        assert_eq!(o.2.mat_misses, 1, "second gate fell back");
    }
}

// -------------------------------------------- keyed-pool failure injection

/// Shared fixture for the keyed adversarial tests: resident 3×1 model,
/// 2×3 live input, `Π_MatMulTr` key dealt by P2.
fn adversarial_fixture() -> (CircuitKey, Matrix<Z64>, Matrix<Z64>, [f64; 2]) {
    let key = CircuitKey {
        model: 3,
        layer: 2,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows: 2,
        inner: 3,
        cols: 1,
        dealer: P2,
    };
    let xf = [1.5, -2.0, 0.5, 3.0, 0.25, -1.0];
    let yf = [2.0, 1.0, -4.0];
    let x = Matrix::from_vec(2, 3, xf.iter().map(|&v| FixedPoint::encode(v)).collect());
    let y = Matrix::from_vec(3, 1, yf.iter().map(|&v| FixedPoint::encode(v)).collect());
    let want = [
        xf[0] * yf[0] + xf[1] * yf[1] + xf[2] * yf[2],
        xf[3] * yf[0] + xf[4] * yf[1] + xf[5] * yf[2],
    ];
    (key, x, y, want)
}

#[test]
fn tampered_keyed_wire_mask_aborts_never_wrong_value() {
    let (key, x, y, want) = adversarial_fixture();
    let run = run_4pc_timeout(
        NetProfile::zero(),
        661,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key, &ysh, 1)?;
            if ctx.id() == P3 {
                // malicious P3 corrupts a held component of the pooled Λ_X
                ctx.pool_mut().unwrap().mat_front_mut(&key).unwrap().tamper_lam_x();
            }
            let (_xsh, z) =
                matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x), &ysh)?;
            ctx.flush_verify()?;
            trident::proto::reconstruct::reconstruct_many(ctx, &z.to_shares())
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled wire mask must abort");
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 3 {
            continue; // the cheater's own view is unconstrained
        }
        if let Ok(vals) = out {
            for (r, want) in want.iter().enumerate() {
                let got = FixedPoint::decode(vals[r]);
                assert!(
                    (got - want).abs() < 0.01,
                    "P{i} accepted a wrong opened value: {got} (want {want})"
                );
            }
        }
    }
}

#[test]
fn tampered_keyed_trunc_pair_aborts() {
    let (key, x, y, _) = adversarial_fixture();
    let run = run_4pc_timeout(
        NetProfile::zero(),
        662,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key, &ysh, 1)?;
            if ctx.id() == P1 {
                // corrupt a held r component of the bundle's first pair
                assert!(ctx
                    .pool_mut()
                    .unwrap()
                    .mat_front_mut(&key)
                    .unwrap()
                    .tamper_pair_r());
            }
            let (_xsh, z) =
                matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x), &ysh)?;
            ctx.flush_verify()?;
            let _ = z;
            Ok(())
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled truncation pair must abort");
}

#[test]
fn replayed_keyed_gamma_bundle_aborts() {
    let (key, x, y, _) = adversarial_fixture();
    let run = run_4pc_timeout(
        NetProfile::zero(),
        663,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key, &ysh, 2)?;
            if ctx.id() == P1 {
                // P1 re-serves its first ⟨Γ⟩/wire-mask bundle while the
                // peers advance to the second
                assert!(ctx.pool_mut().unwrap().replay_front_mat(&key));
            }
            let (_x1, z1) =
                matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (_x2, z2) =
                matmul_tr_keyed(ctx, &key, (ctx.id() == P2).then_some(&x), &ysh)?;
            ctx.flush_verify()?;
            let _ = (z1, z2);
            Ok(())
        },
    );
    assert!(run.any_verify_abort(), "replayed keyed bundle must abort");
}

#[test]
fn cross_keyed_material_fails_closed() {
    let (key_a, x, y, _) = adversarial_fixture();
    let key_b = CircuitKey { layer: key_a.layer + 1, ..key_a };
    let run = run_4pc_timeout(
        NetProfile::zero(),
        664,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key_a, &ysh, 1)?;
            fill_mat(ctx, key_b, &ysh, 1)?;
            if ctx.id() == P1 {
                // P1 files layer-a material at layer b's position (same
                // shape — only the embedded key differs)
                assert!(ctx.pool_mut().unwrap().cross_file_front_mat(&key_a, &key_b));
            }
            // the wave for layer b: P1's pop must fail closed, aborting
            // before any online message is computed from wrong material
            let (_xsh, z) =
                matmul_tr_keyed(ctx, &key_b, (ctx.id() == P2).then_some(&x), &ysh)?;
            ctx.flush_verify()?;
            let _ = z;
            Ok(())
        },
    );
    assert!(
        matches!(run.outputs[1], Err(trident::net::Abort::Verify(_))),
        "P1 must fail closed on cross-keyed material: {:?}",
        run.outputs[1].as_ref().err()
    );
    assert!(run.any_verify_abort());
}

// ------------------------------------------- offline-silence serving waves

#[test]
fn warm_keyed_pool_serving_wave_is_offline_message_free() {
    use trident::serve::{cleartext_predictions, serve, PoolMode, ServeConfig};
    let cfg = ServeConfig {
        d: 16,
        rows_per_query: 2,
        queries: 6,
        coalesce: 3,
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        relu: false,
        seed: 650,
    };
    let s = serve(NetProfile::zero(), cfg.clone());
    // THE tentpole property: with a warm circuit-keyed pool, no party sends
    // a single offline-phase message inside any serving wave — the
    // per-request offline phase is truly message-free.
    assert_eq!(
        s.offline_msgs_in_waves, 0,
        "warm keyed pool must leave every serving wave offline-silent"
    );
    assert_eq!(s.offline_bytes_in_waves, 0);
    // background refill ran, and its traffic is Phase::Offline only
    assert!(s.refill_mat_items >= 2, "refill must have produced bundles");
    assert_eq!(s.refill_online_msgs, 0, "refill traffic must be offline-phase only");
    // every response still verified-correct
    let want = cleartext_predictions(&cfg);
    assert_eq!(s.answers.len(), want.len());
    for (got, want) in s.answers.iter().zip(&want) {
        assert!((got - want).abs() < 0.01, "silent wave answer: {got} vs {want}");
    }
    // the scalar pool still runs the γ-exchange live inside waves …
    let scalar = serve(
        NetProfile::zero(),
        ServeConfig { mode: PoolMode::Scalar, ..cfg.clone() },
    );
    assert!(
        scalar.offline_msgs_in_waves > 0,
        "scalar pools still γ-exchange inside waves"
    );
    // … while Π_MultTr's online shape (3ℓ / 1 round per gate) is identical
    // either way: same online rounds and value bits for the same workload
    assert_eq!(s.online_rounds, scalar.online_rounds);
    assert_eq!(s.online_value_bits, scalar.online_value_bits);
}

// --------------------------------------------------------- misc sanity: P0

#[test]
fn pool_backed_serving_keeps_p0_offline_only() {
    use trident::serve::{serve, PoolMode, ServeConfig};
    let s = serve(
        NetProfile::wan(),
        ServeConfig {
            d: 8,
            rows_per_query: 2,
            queries: 4,
            coalesce: 4,
            mode: PoolMode::Keyed,
            low_water: 1,
            high_water: 1,
            relu: false,
            seed: 640,
        },
    );
    // P0 does no online work in the serving loop (reconstruction towards
    // the data owner has P0 vouching only — hash traffic, zero rounds for
    // value data from P0)
    let p0_online = s.report.party_time[1][0];
    let others: f64 = s.report.party_time[1][1..].iter().cloned().fold(0.0, f64::max);
    assert!(
        p0_online <= others,
        "P0 online time {p0_online} must not exceed the evaluators' {others}"
    );
}

// ---------------------------------------- circuit-keyed nonlinear (ReLU) pool

/// Fixture for the keyed ReLU pipeline: resident `inner×cols` model dealt
/// by P1, live `rows×inner` input dealt by P2, `Π_MatMulTr` + ReLU.
fn relu_fixture(
    rows: usize,
    inner: usize,
    cols: usize,
    seed: u64,
) -> (CircuitKey, CircuitKey, Matrix<Z64>, Matrix<Z64>, Vec<f64>) {
    let mat_key = CircuitKey {
        model: 50 + seed,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows,
        inner,
        cols,
        dealer: P2,
    };
    let relu_key = relu_key_for(&mat_key);
    let mut rng = Rng::seeded(seed);
    let xf: Vec<f64> = (0..rows * inner).map(|_| rng.normal()).collect();
    let yf: Vec<f64> = (0..inner * cols).map(|_| rng.normal()).collect();
    let x = Matrix::from_vec(rows, inner, xf.iter().map(|&v| FixedPoint::encode(v)).collect());
    let y = Matrix::from_vec(inner, cols, yf.iter().map(|&v| FixedPoint::encode(v)).collect());
    // oracle on the fixed-point ring product (isolates the ≤2-ulp
    // probabilistic-truncation error from the f64→fixed encoding error)
    let clear = x.matmul(&y);
    let want: Vec<f64> = clear
        .data()
        .iter()
        .map(|&v| FixedPoint::decode(v.truncate(FRAC_BITS)).max(0.0))
        .collect();
    (mat_key, relu_key, x, y, want)
}

#[test]
fn relu_pool_keyed_pipeline_matches_inline_and_cleartext_over_shape_grid() {
    for (rows, inner, cols) in [(1usize, 3usize, 1usize), (3, 1, 1), (2, 3, 1), (2, 2, 2)] {
        let (mat_key, relu_key, x, y, want) =
            relu_fixture(rows, inner, cols, 7 + rows as u64 * 10 + inner as u64);
        let (x2, y2) = (x.clone(), y.clone());
        let run = run_4pc(NetProfile::zero(), 681, move |ctx| {
            let ysh = share_mat(ctx, P1, &y2)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_key, relu_key, &ysh, 1)?;
            // --- keyed pipeline, windowed: zero offline-phase sends ---
            let off0 = ctx.net.sent_msgs(Phase::Offline);
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x2), &ysh)?;
            let (keyed, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
            let off_sent = ctx.net.sent_msgs(Phase::Offline) - off0;
            // --- inline pipeline over the same inputs ---
            let xsh = share_mat(ctx, P2, &x2)?;
            let u2 = trident::proto::matmul_tr(ctx, &xsh, &ysh)?;
            let (inline, _) = trident::ml::relu_many(ctx, &u2.to_shares())?;
            ctx.flush_verify()?;
            let stats = ctx.detach_pool().unwrap().stats();
            Ok((keyed, inline, off_sent, stats))
        });
        let (outs, _) = run.expect_ok();
        for (i, want) in want.iter().enumerate() {
            let k = FixedPoint::decode(open(&[
                outs[0].0[i],
                outs[1].0[i],
                outs[2].0[i],
                outs[3].0[i],
            ]));
            let il = FixedPoint::decode(open(&[
                outs[0].1[i],
                outs[1].1[i],
                outs[2].1[i],
                outs[3].1[i],
            ]));
            let tol = 4.0 / SCALE;
            assert!(
                (k - want).abs() <= tol,
                "{rows}×{inner}×{cols} out {i}: keyed relu {k}, oracle {want}"
            );
            assert!(
                (il - want).abs() <= tol,
                "{rows}×{inner}×{cols} out {i}: inline relu {il}, oracle {want}"
            );
        }
        for (p, o) in outs.iter().enumerate() {
            assert_eq!(
                o.2, 0,
                "P{p} sent offline messages inside the keyed matmul_tr→relu pipeline"
            );
            assert_eq!(o.3.mat_hits, 1, "P{p}: matrix bundle drained");
            assert_eq!(o.3.relu_hits, 1, "P{p}: nonlinear bundle drained");
        }
    }
}

#[test]
fn relu_pool_mult_online_cost_unchanged_and_offline_moved_not_grown() {
    // 1×1×1 gate + width-1 ReLU: the keyed pipeline must keep the exact
    // online shape of the inline path — Π_Mult's 3ℓ/1-round exchange
    // included — and move its offline bits into the fill without growing
    // them.
    let (mat_key, relu_key, x, y, _) = relu_fixture(1, 1, 1, 99);
    let (x2, y2) = (x.clone(), y.clone());
    let keyed = run_4pc(NetProfile::zero(), 682, move |ctx| {
        let ysh = share_mat(ctx, P1, &y2)?;
        ctx.attach_pool(Pool::new());
        fill_mat_relu(ctx, mat_key, relu_key, &ysh, 1)?;
        let (_xsh, u) = matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x2), &ysh)?;
        let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
        ctx.flush_verify()?;
        Ok(r)
    });
    let (x3, y3) = (x.clone(), y.clone());
    let inline = run_4pc(NetProfile::zero(), 682, move |ctx| {
        let ysh = share_mat(ctx, P1, &y3)?;
        let xsh = share_mat(ctx, P2, &x3)?;
        let u = trident::proto::matmul_tr(ctx, &xsh, &ysh)?;
        let (r, _) = trident::ml::relu_many(ctx, &u.to_shares())?;
        ctx.flush_verify()?;
        Ok(r)
    });
    let (kouts, krep) = keyed.expect_ok();
    let (iouts, irep) = inline.expect_ok();
    let kv = FixedPoint::decode(open(&[kouts[0][0], kouts[1][0], kouts[2][0], kouts[3][0]]));
    let iv = FixedPoint::decode(open(&[iouts[0][0], iouts[1][0], iouts[2][0], iouts[3][0]]));
    assert!((kv - iv).abs() <= 4.0 / SCALE, "keyed {kv} vs inline {iv}");
    assert_eq!(
        krep.value_bits[1], irep.value_bits[1],
        "online bits identical (Π_Mult stays 3ℓ)"
    );
    assert_eq!(krep.rounds[1], irep.rounds[1], "online rounds identical");
    assert_eq!(
        krep.value_bits[0], irep.value_bits[0],
        "offline bits moved into the fill, not grown"
    );
}

#[test]
fn relu_pool_warm_keyed_relu_waves_offline_silent_single_tenant() {
    use trident::serve::{cleartext_predictions, serve, PoolMode, ServeConfig};
    let cfg = ServeConfig {
        d: 12,
        rows_per_query: 2,
        queries: 6,
        coalesce: 3,
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        relu: true,
        seed: 683,
    };
    let s = serve(NetProfile::zero(), cfg.clone());
    // THE tentpole property, now through the nonlinear layer too: no party
    // sends a single offline-phase message inside any serving wave
    assert_eq!(s.offline_msgs_in_waves, 0, "keyed relu waves must be offline-silent");
    assert_eq!(s.offline_msgs_matmul, 0, "matrix sub-window silent");
    assert_eq!(s.offline_msgs_relu, 0, "relu sub-window silent");
    assert_eq!(s.refill_online_msgs, 0, "refill traffic is offline-phase only");
    let want = cleartext_predictions(&cfg);
    assert_eq!(s.answers.len(), want.len());
    for (got, want) in s.answers.iter().zip(&want) {
        assert!((got - want).abs() < 0.01, "silent relu wave answer: {got} vs {want}");
    }
    // the scalar pool still runs the bitext γ-exchange and the Π_BitInj
    // offline sharings live inside the wave — and the per-op split shows
    // exactly where
    let scalar = serve(NetProfile::zero(), ServeConfig { mode: PoolMode::Scalar, ..cfg });
    assert!(scalar.offline_msgs_relu > 0, "scalar relu still works offline in-wave");
    // … while the online shape is identical either way
    assert_eq!(s.online_rounds, scalar.online_rounds);
    assert_eq!(s.online_value_bits, scalar.online_value_bits);
}

#[test]
fn relu_pool_two_tenant_warm_relu_run_every_wave_silent() {
    use trident::serve::{serve_multi, PoolMode};
    // the acceptance-criteria run: two --relu tenants, tightest refill
    // cadence (low == high == 1), warmth maintained by interleaved refill
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 1);
    for t in &mut cfg.tenants {
        t.relu = true;
    }
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.waves, 6, "3 full waves per tenant");
    for (i, m) in s.wave_offline_msgs.iter().enumerate() {
        assert_eq!(
            *m, 0,
            "wave {i} (tenant {}) sent offline-phase messages inside the wave window",
            s.wave_tenants[i]
        );
    }
    assert_eq!(s.offline_msgs_matmul, 0);
    assert_eq!(s.offline_msgs_relu, 0, "the nonlinear leg is silent in every wave");
    for ts in &s.tenants {
        assert_eq!(ts.offline_msgs_in_waves, 0, "per-tenant offline silence: {ts:?}");
        assert_eq!(ts.keyed_waves, ts.waves, "every wave drained keyed bundles");
        assert_eq!(ts.pool_left_mat, 0, "no matrix bundle stranded");
        assert_eq!(ts.pool_left_relu, 0, "no nonlinear bundle stranded");
    }
    assert_eq!(s.refill_online_msgs, 0, "refill traffic is offline-phase only");
    let ps = s.pool_stats.expect("pool attached");
    assert_eq!(ps.relu_hits, 6, "one nonlinear bundle per wave: {ps:?}");
    assert_eq!(ps.bitext_hits, 0, "the shared typed bitext queue is never touched");
    assert_tenant_answers_match_cleartext(&s, &cfg, "warm two-tenant relu");
}

#[test]
fn relu_pool_tampered_gamma_aborts_never_wrong_value() {
    let (mat_key, relu_key, x, y, want) = relu_fixture(2, 3, 1, 55);
    let run = run_4pc_timeout(
        NetProfile::zero(),
        684,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_key, relu_key, &ysh, 1)?;
            if ctx.id() == P1 {
                // malicious P1 corrupts its held ⟨γ_{r·v}⟩ component
                ctx.pool_mut().unwrap().relu_front_mut(&relu_key).unwrap().tamper_gamma();
            }
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
            ctx.flush_verify()?;
            trident::proto::reconstruct::reconstruct_many(ctx, &r)
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled γ must abort");
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 1 {
            continue; // the cheater's own view is unconstrained
        }
        if let Ok(vals) = out {
            for (r, want) in want.iter().enumerate() {
                let got = FixedPoint::decode(vals[r]);
                assert!(
                    (got - want).abs() < 0.01,
                    "P{i} accepted a wrong opened value: {got} (want {want})"
                );
            }
        }
    }
}

#[test]
fn relu_pool_tampered_bitext_mask_aborts() {
    let (mat_key, relu_key, x, y, _) = relu_fixture(2, 3, 1, 56);
    let run = run_4pc_timeout(
        NetProfile::zero(),
        685,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_key, relu_key, &ysh, 1)?;
            if ctx.id() == P3 {
                // malicious P3 corrupts a held λ component of [[r]]
                ctx.pool_mut().unwrap().relu_front_mut(&relu_key).unwrap().tamper_mask_r();
            }
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
            ctx.flush_verify()?;
            let _ = r;
            Ok(())
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled BitExtMask must abort");
}

#[test]
fn relu_pool_replayed_bundle_aborts() {
    let (mat_key, relu_key, x, y, _) = relu_fixture(2, 3, 1, 57);
    let run = run_4pc_timeout(
        NetProfile::zero(),
        686,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_key, relu_key, &ysh, 2)?;
            if ctx.id() == P1 {
                // P1 re-serves its first nonlinear bundle while the peers
                // advance to the second
                assert!(ctx.pool_mut().unwrap().replay_front_relu(&relu_key));
            }
            for _ in 0..2 {
                let (_xsh, u) =
                    matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x), &ysh)?;
                let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
                let _ = r;
            }
            ctx.flush_verify()?;
            Ok(())
        },
    );
    assert!(run.any_verify_abort(), "replayed nonlinear bundle must abort");
}

#[test]
fn relu_pool_cross_key_pop_fails_closed() {
    let (mat_a, relu_a, x, y, _) = relu_fixture(2, 3, 1, 58);
    let mat_b = CircuitKey { layer: mat_a.layer + 1, ..mat_a };
    let relu_b = relu_key_for(&mat_b);
    let run = run_4pc_timeout(
        NetProfile::zero(),
        687,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_a, relu_a, &ysh, 1)?;
            fill_mat_relu(ctx, mat_b, relu_b, &ysh, 1)?;
            if ctx.id() == P1 {
                // P1 files layer-a nonlinear material at layer b's position
                assert!(ctx.pool_mut().unwrap().cross_file_front_relu(&relu_a, &relu_b));
            }
            // layer b's wave: P1's relu pop must fail closed before any
            // online message is computed from wrong-position masks
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_b, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_b, &u.to_shares())?;
            ctx.flush_verify()?;
            let _ = r;
            Ok(())
        },
    );
    assert!(
        matches!(run.outputs[1], Err(trident::net::Abort::Verify(_))),
        "P1 must fail closed on cross-keyed nonlinear material: {:?}",
        run.outputs[1].as_ref().err()
    );
    assert!(run.any_verify_abort());
}

#[test]
fn relu_pool_cross_tenant_pop_fails_closed() {
    use trident::sched::{tenant_relu_key, tenant_wave_key, TenantSpec};
    // two relu tenants with byte-identical wave shapes — only the tenant
    // id in the circuit key differs
    let mk = |name: &str, model: u64| {
        let mut s = TenantSpec::new(name, model, 3, 4, 2);
        s.relu = true;
        s
    };
    let (spec_a, spec_b) = (mk("tenant-a", 301), mk("tenant-b", 302));
    let rows = spec_a.wave_rows();
    let (mat_a, relu_a) = (tenant_wave_key(&spec_a, rows), tenant_relu_key(&spec_a, rows));
    let (mat_b, relu_b) = (tenant_wave_key(&spec_b, rows), tenant_relu_key(&spec_b, rows));
    assert_ne!(relu_a, relu_b, "tenant id shards the nonlinear key space");
    let (_, _, x, y, want) = relu_fixture(rows, spec_a.d, 1, 59);
    let run = run_4pc_timeout(
        NetProfile::zero(),
        688,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_a, relu_a, &ysh, 1)?;
            fill_mat_relu(ctx, mat_b, relu_b, &ysh, 1)?;
            if ctx.id() == P1 {
                // malicious P1 files tenant A's nonlinear correlation at
                // tenant B's position (shape-compatible, so only the
                // embedded key can catch it)
                assert!(ctx.pool_mut().unwrap().cross_file_front_relu(&relu_a, &relu_b));
            }
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_b, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_b, &u.to_shares())?;
            ctx.flush_verify()?;
            trident::proto::reconstruct::reconstruct_many(ctx, &r)
        },
    );
    assert!(
        matches!(run.outputs[1], Err(trident::net::Abort::Verify(_))),
        "P1 must fail closed on cross-tenant nonlinear material: {:?}",
        run.outputs[1].as_ref().err()
    );
    assert!(run.any_verify_abort());
    // an honest party that did complete never accepted a wrong value
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 1 {
            continue;
        }
        if let Ok(vals) = out {
            for (r, want) in want.iter().enumerate() {
                let got = FixedPoint::decode(vals[r]);
                assert!(
                    (got - want).abs() < 0.01,
                    "P{i} accepted a wrong opened value: {got} (want {want})"
                );
            }
        }
    }
}

#[test]
fn relu_pool_exhaustion_falls_back_inline_deterministically() {
    let (mat_key, relu_key, x, y, want) = relu_fixture(2, 3, 1, 60);
    let run = run_4pc(NetProfile::zero(), 689, move |ctx| {
        let ysh = share_mat(ctx, P1, &y)?;
        ctx.attach_pool(Pool::new());
        fill_mat_relu(ctx, mat_key, relu_key, &ysh, 1)?;
        // first pipeline drains the only bundle pair; the second falls
        // back inline — at every party, in lockstep
        let mut outs = Vec::new();
        for _ in 0..2 {
            let (_xsh, u) =
                matmul_tr_keyed(ctx, &mat_key, (ctx.id() == P2).then_some(&x), &ysh)?;
            let (r, _) = trident::ml::relu_many_keyed(ctx, &relu_key, &u.to_shares())?;
            outs.push(r);
        }
        ctx.flush_verify()?;
        let stats = ctx.detach_pool().unwrap().stats();
        Ok((outs, stats))
    });
    let (outs, _) = run.expect_ok();
    for pipeline in 0..2 {
        for (r, want) in want.iter().enumerate() {
            let got = FixedPoint::decode(open(&[
                outs[0].0[pipeline][r],
                outs[1].0[pipeline][r],
                outs[2].0[pipeline][r],
                outs[3].0[pipeline][r],
            ]));
            assert!(
                (got - want).abs() < 0.01,
                "pipeline {pipeline} out {r}: got {got}, want {want}"
            );
        }
    }
    for o in &outs {
        assert_eq!(o.1.relu_hits, 1, "first pipeline drained the bundle");
        assert_eq!(o.1.relu_misses, 1, "second pipeline fell back inline");
        assert_eq!(o.1.mat_hits, 1);
        assert_eq!(o.1.mat_misses, 1);
    }
}

// -------------------------------------------------- multi-tenant scheduling

/// Two resident models (same shapes, different tenant ids) with enough
/// demand for three full waves each.
fn two_tenant_cfg(
    mode: trident::serve::PoolMode,
    low: usize,
    high: usize,
) -> trident::serve::MultiServeConfig {
    use trident::sched::TenantSpec;
    let mk = |name: &str, model: u64| {
        let mut s = TenantSpec::new(name, model, 16, 9, 3);
        s.rows_per_query = 2;
        s
    };
    trident::serve::MultiServeConfig {
        tenants: vec![mk("m1", 1), mk("m2", 2)],
        mode,
        low_water: low,
        high_water: high,
        age_every: 0,
        seed: 1660,
        ..trident::serve::MultiServeConfig::default()
    }
}

fn assert_tenant_answers_match_cleartext(
    stats: &trident::serve::MultiServeStats,
    cfg: &trident::serve::MultiServeConfig,
    label: &str,
) {
    use trident::serve::cleartext_tenant_predictions;
    for (t, ts) in stats.tenants.iter().enumerate() {
        let want = cleartext_tenant_predictions(&cfg.tenants[t]);
        assert_eq!(ts.answers.len(), ts.served, "{label}: one answer per served query");
        for (qid, rows) in &ts.answers {
            for (r, got) in rows.iter().enumerate() {
                let w = want[*qid][r];
                assert!(
                    (got - w).abs() < 0.01,
                    "{label}: tenant {t} query {qid} row {r}: got {got}, want {w}"
                );
            }
        }
    }
}

#[test]
fn multi_tenant_keyed_waves_open_identical_values_to_inline() {
    use trident::serve::{serve_multi, PoolMode};
    // the same two-tenant workload through the per-tenant keyed pools and
    // through the seed-style inline path: both must reproduce the
    // cleartext oracle per tenant, query for query
    let kcfg = two_tenant_cfg(PoolMode::Keyed, 1, 2);
    let keyed = serve_multi(NetProfile::zero(), kcfg.clone());
    let icfg = two_tenant_cfg(PoolMode::Inline, 1, 2);
    let inline = serve_multi(NetProfile::zero(), icfg.clone());
    for s in [&keyed, &inline] {
        for ts in &s.tenants {
            assert_eq!(ts.served, 9, "all queries answered");
            assert_eq!(ts.expired, 0);
            assert_eq!(ts.rejected, 0);
        }
    }
    assert_tenant_answers_match_cleartext(&keyed, &kcfg, "keyed");
    assert_tenant_answers_match_cleartext(&inline, &icfg, "inline");
    // same schedule either way (the planner is mode-independent) …
    assert_eq!(keyed.wave_tenants, inline.wave_tenants);
    // … but only the keyed run drains per-tenant pools
    for ts in &keyed.tenants {
        assert_eq!(ts.keyed_waves, ts.waves, "keyed: every full wave hits its pool");
    }
    for ts in &inline.tenants {
        assert_eq!(ts.inline_waves, ts.waves, "inline: no pool exists to hit");
    }
}

#[test]
fn cross_tenant_pool_pop_fails_closed() {
    use trident::sched::TenantSpec;
    // two tenants with byte-identical gate shapes — only the tenant/model
    // id in the circuit key differs
    let spec_a = TenantSpec::new("tenant-a", 101, 3, 4, 2);
    let spec_b = TenantSpec::new("tenant-b", 202, 3, 4, 2);
    let (key_a, key_b) = (spec_a.key(), spec_b.key());
    assert_eq!((key_a.rows, key_a.inner, key_a.cols), (key_b.rows, key_b.inner, key_b.cols));
    assert_ne!(key_a, key_b, "tenant id shards the key space");
    let xf = [1.5, -2.0, 0.5, 3.0, 0.25, -1.0];
    let yf = [2.0, 1.0, -4.0];
    let want = [
        xf[0] * yf[0] + xf[1] * yf[1] + xf[2] * yf[2],
        xf[3] * yf[0] + xf[4] * yf[1] + xf[5] * yf[2],
    ];
    let x = Matrix::from_vec(2, 3, xf.iter().map(|&v| FixedPoint::encode(v)).collect());
    let y = Matrix::from_vec(3, 1, yf.iter().map(|&v| FixedPoint::encode(v)).collect());
    let run = run_4pc_timeout(
        NetProfile::zero(),
        665,
        std::time::Duration::from_millis(500),
        move |ctx| {
            let ysh = share_mat(ctx, P1, &y)?;
            ctx.attach_pool(Pool::new());
            fill_mat(ctx, key_a, &ysh, 1)?;
            fill_mat(ctx, key_b, &ysh, 1)?;
            if ctx.id() == P1 {
                // malicious P1 files tenant A's correlation at tenant B's
                // position (shape-compatible, so only the embedded key
                // can catch it)
                assert!(ctx.pool_mut().unwrap().cross_file_front_mat(&key_a, &key_b));
            }
            // tenant B's wave: P1's pop must fail closed before any online
            // message is computed from tenant A's material
            let (_xsh, z) =
                matmul_tr_keyed(ctx, &key_b, (ctx.id() == P2).then_some(&x), &ysh)?;
            ctx.flush_verify()?;
            trident::proto::reconstruct::reconstruct_many(ctx, &z.to_shares())
        },
    );
    assert!(
        matches!(run.outputs[1], Err(trident::net::Abort::Verify(_))),
        "P1 must fail closed on cross-tenant material: {:?}",
        run.outputs[1].as_ref().err()
    );
    assert!(run.any_verify_abort());
    // an honest party that did complete never accepted a wrong value
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 1 {
            continue; // the cheater's own view is unconstrained
        }
        if let Ok(vals) = out {
            for (r, want) in want.iter().enumerate() {
                let got = FixedPoint::decode(vals[r]);
                assert!(
                    (got - want).abs() < 0.01,
                    "P{i} accepted a wrong opened value: {got} (want {want})"
                );
            }
        }
    }
}

#[test]
fn two_tenant_warm_run_keeps_every_wave_offline_silent() {
    use trident::serve::{serve_multi, PoolMode};
    // low == high == 1: the tightest refill cadence — every wave pops the
    // single stocked bundle and the between-waves tick restocks the
    // most-depleted tenant, so warmth is maintained by interleaved refill,
    // not by over-provisioning
    let cfg = two_tenant_cfg(PoolMode::Keyed, 1, 1);
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.waves, 6, "3 full waves per tenant");
    for (i, m) in s.wave_offline_msgs.iter().enumerate() {
        assert_eq!(
            *m, 0,
            "wave {i} (tenant {}) sent offline-phase messages inside the wave window",
            s.wave_tenants[i]
        );
    }
    for ts in &s.tenants {
        assert_eq!(ts.offline_msgs_in_waves, 0, "per-tenant offline silence: {ts:?}");
        assert_eq!(ts.keyed_waves, ts.waves, "every wave drained a keyed bundle");
        assert!(
            ts.refill_ticks >= 2,
            "warm-up + interleaved between-wave refills: {ts:?}"
        );
        assert_eq!(ts.pool_left_mat, 0, "no bundle stranded at shutdown");
    }
    assert_eq!(s.refill_online_msgs, 0, "refill traffic is offline-phase only");
    assert_tenant_answers_match_cleartext(&s, &cfg, "warm two-tenant");
}

#[test]
fn wrr_share_split_asserted_within_tolerance() {
    use trident::sched::TenantSpec;
    use trident::serve::{serve_multi, MultiServeConfig, PoolMode};
    let mk = |name: &str, model: u64, weight: u64| {
        let mut s = TenantSpec::new(name, model, 8, 12, 2);
        s.weight = weight;
        s
    };
    let cfg = MultiServeConfig {
        tenants: vec![mk("heavy", 1, 2), mk("light", 2, 1)],
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        age_every: 0,
        seed: 1661,
        ..MultiServeConfig::default()
    };
    let s = serve_multi(NetProfile::zero(), cfg);
    // heavy needs 6 waves, light 6; both are backlogged for the first 9
    // waves, where the 2:1 share must hold to within one wave
    let heavy = s.wave_tenants[..9].iter().filter(|&&t| t == 0).count() as f64;
    assert!(
        (heavy - 6.0).abs() <= 1.0,
        "2:1 split over a saturated 9-wave window: got {heavy} heavy waves ({:?})",
        s.wave_tenants
    );
    assert_eq!(s.tenants[0].served, 12);
    assert_eq!(s.tenants[1].served, 12);
}

#[test]
fn two_tenant_partial_waves_stay_offline_silent() {
    use trident::serve::{serve_multi, PoolMode};
    // 10 queries / coalesce 3 → three full waves + a trailing partial per
    // tenant, at the tightest refill cadence: the registered partial-wave
    // key (warmed once at load) must keep the LAST wave offline-silent too
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 1);
    for t in &mut cfg.tenants {
        t.queries = 10;
    }
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.waves, 8, "3 full + 1 partial per tenant");
    for (i, m) in s.wave_offline_msgs.iter().enumerate() {
        assert_eq!(
            *m, 0,
            "wave {i} (tenant {}) sent offline-phase messages inside the wave window",
            s.wave_tenants[i]
        );
    }
    for ts in &s.tenants {
        assert_eq!(ts.partial_waves, 1, "{ts:?}");
        assert_eq!(ts.partial_keyed_waves, 1, "the partial wave hit its own key");
        assert_eq!(ts.keyed_waves, ts.waves, "full AND partial waves drain keyed bundles");
        assert_eq!(ts.offline_msgs_in_waves, 0);
    }
    assert_tenant_answers_match_cleartext(&s, &cfg, "warm partial");
}

// ------------------------------------------- abort blast-radius containment

/// The tentpole acceptance scenario: a keyed bundle is tampered with
/// mid-run (P1 corrupts tenant 0's second wave). With containment on, the
/// abort must stay scoped to the owning tenant's wave — the quarantine is
/// decided at the same tick at all four parties (asserted internally at
/// aggregation), the other tenant's queries and the poisoned wave's
/// re-queued innocents all match the cleartext oracle, and no wrong opened
/// value ever surfaces as an answer.
#[test]
fn containment_tampered_wave_quarantines_only_its_tenant() {
    use trident::serve::{serve_multi, FaultKind, FaultPlan, PoolMode};
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 2);
    cfg.containment = true;
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 0,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.quarantines.len(), 1, "exactly one contained abort: {:?}", s.quarantines);
    let q = &s.quarantines[0];
    assert_eq!(q.tenant, 0, "the quarantine names the poisoned tenant");
    assert_eq!(q.requeued, 3, "the aborted wave's whole batch is re-admitted");
    assert_eq!(q.lost, 0);
    assert!(q.drained_mat > 0, "the poisoned shard is drained: {q:?}");
    let (poisoned, innocent) = (&s.tenants[0], &s.tenants[1]);
    assert_eq!(poisoned.quarantined_at, Some(q.at_tick));
    assert_eq!(
        poisoned.served, 9,
        "re-queued queries finish over the secure inline path: {poisoned:?}"
    );
    assert!(poisoned.inline_waves >= 1, "quarantined pops miss deterministically");
    assert_eq!(innocent.quarantined_at, None);
    assert_eq!(innocent.served, 9, "the innocent tenant never notices");
    // every answer that surfaced — both tenants, including the re-queued
    // innocents of the poisoned wave — equals the cleartext oracle
    assert_tenant_answers_match_cleartext(&s, &cfg, "containment");
}

#[test]
fn containment_relu_tamper_is_contained_too() {
    use trident::serve::{serve_multi, FaultKind, FaultPlan, PoolMode};
    // same scenario through the nonlinear leg: the paired ReluCorr bundle
    // is corrupted instead of the matrix bundle
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 2);
    cfg.tenants[0].relu = true;
    cfg.containment = true;
    cfg.fault = Some(FaultPlan {
        party: P3,
        tenant: 0,
        wave: 0,
        layer: 0,
        kind: FaultKind::TamperReluGamma,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.quarantines.len(), 1, "{:?}", s.quarantines);
    assert_eq!(s.quarantines[0].tenant, 0);
    assert!(
        s.quarantines[0].drained_relu > 0,
        "quarantine drains the paired nonlinear shard: {:?}",
        s.quarantines[0]
    );
    assert_eq!(s.tenants[0].served, 9);
    assert_eq!(s.tenants[1].served, 9);
    assert_tenant_answers_match_cleartext(&s, &cfg, "relu containment");
}

#[test]
fn containment_off_keeps_the_fail_closed_contract() {
    use trident::serve::{serve_multi_checked, FaultKind, FaultPlan, PoolMode};
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 2);
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 0,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let err = serve_multi_checked(NetProfile::zero(), cfg)
        .expect_err("containment off: any tamper is run-fatal");
    assert!(
        matches!(err, trident::net::Abort::Verify(_)),
        "the verification abort is the surfaced cause: {err}"
    );
}

#[test]
fn containment_party_scoped_abort_fails_the_run_closed() {
    use trident::serve::{serve_multi_checked, FaultKind, FaultPlan, PoolMode};
    // a party aborting OUTSIDE a wave body implicates the party, not a
    // tenant's material — containment must not quarantine anybody
    let mut cfg = two_tenant_cfg(PoolMode::Keyed, 1, 2);
    cfg.containment = true;
    cfg.fault = Some(FaultPlan {
        party: P3,
        tenant: 1,
        wave: 1,
        layer: 0,
        kind: FaultKind::AbortOffWave,
        every: None,
    });
    let err = serve_multi_checked(NetProfile::zero(), cfg)
        .expect_err("party-scoped aborts fail closed even with containment on");
    assert!(
        matches!(err, trident::net::Abort::Verify(_)),
        "the aborting party's own cause is surfaced: {err}"
    );
}

// ------------------------------------------------- deep resident networks

/// Two deep resident 3-layer networks (4-8-8-2): hidden ReLU at gates 0
/// and 1, linear head at gate 2. Each tenant's registry entry carries one
/// keyed bundle pair per gate, popped as a whole vector per wave.
fn deep_two_tenant_cfg(low: usize, high: usize) -> trident::serve::MultiServeConfig {
    use trident::sched::TenantSpec;
    let mk = |name: &str, model: u64| {
        let mut s = TenantSpec::new(name, model, 4, 4, 2);
        s.rows_per_query = 2;
        s.layers = vec![8, 8, 2];
        s
    };
    trident::serve::MultiServeConfig {
        tenants: vec![mk("nn-a", 11), mk("nn-b", 12)],
        mode: trident::serve::PoolMode::Keyed,
        low_water: low,
        high_water: high,
        age_every: 0,
        seed: 1662,
        ..trident::serve::MultiServeConfig::default()
    }
}

/// The deep-circuit acceptance scenario: a warm two-tenant 3-layer run
/// where EVERY wave runs share → 3×(matmul → hidden ReLU) → reconstruct
/// with zero offline-phase messages at every gate, and every opened
/// answer equals the cleartext forward pass.
#[test]
fn deep_keyed_waves_are_offline_silent_and_match_cleartext() {
    use trident::serve::serve_multi;
    let cfg = deep_two_tenant_cfg(1, 2);
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.waves, 4, "2 full waves per tenant");
    for (i, m) in s.wave_offline_msgs.iter().enumerate() {
        assert_eq!(
            *m, 0,
            "wave {i} (tenant {}) sent offline-phase messages inside the wave window",
            s.wave_tenants[i]
        );
    }
    for ts in &s.tenants {
        assert_eq!(ts.served, 4);
        assert_eq!(ts.keyed_waves, ts.waves, "every deep wave pops its whole layer vector");
        assert_eq!(ts.inline_waves, 0);
        assert_eq!(ts.offline_msgs_in_waves, 0, "{ts:?}");
        assert_eq!(
            ts.offline_msgs_matmul_layers,
            vec![0, 0, 0],
            "offline-silent at every matrix gate: {ts:?}"
        );
        assert_eq!(
            ts.offline_msgs_relu_layers,
            vec![0, 0, 0],
            "offline-silent at every nonlinear gate: {ts:?}"
        );
    }
    assert_tenant_answers_match_cleartext(&s, &cfg, "deep keyed");
}

#[test]
fn deep_tamper_at_any_gate_fails_closed_without_containment() {
    use trident::serve::{serve_multi_checked, FaultKind, FaultPlan};
    // a tampered matrix bundle at ANY gate position of the layer vector —
    // first, middle, head — must surface as a verification abort, never a
    // wrong opened value
    for layer in 0..3u32 {
        let mut cfg = deep_two_tenant_cfg(1, 2);
        cfg.fault = Some(FaultPlan {
            party: P1,
            tenant: 0,
            wave: 1,
            layer,
            kind: FaultKind::TamperMatLamX,
            every: None,
        });
        let err = serve_multi_checked(NetProfile::zero(), cfg)
            .expect_err("a tampered bundle at any gate is run-fatal without containment");
        assert!(
            matches!(err, trident::net::Abort::Verify(_)),
            "gate {layer}: the verification abort is the surfaced cause: {err}"
        );
    }
    // the nonlinear leg: gate 1's paired hidden-ReLU bundle
    let mut cfg = deep_two_tenant_cfg(1, 2);
    cfg.fault = Some(FaultPlan {
        party: P3,
        tenant: 1,
        wave: 0,
        layer: 1,
        kind: FaultKind::TamperReluGamma,
        every: None,
    });
    let err = serve_multi_checked(NetProfile::zero(), cfg)
        .expect_err("a tampered hidden-gate ReLU bundle is run-fatal without containment");
    assert!(matches!(err, trident::net::Abort::Verify(_)), "{err}");
}

#[test]
fn deep_containment_quarantines_only_the_tampered_tenant() {
    use trident::serve::{serve_multi, FaultKind, FaultPlan};
    // tamper a MIDDLE gate's matrix bundle mid-run with containment on:
    // the quarantine must stay scoped to the owning tenant, land at the
    // same tick at all four parties (asserted internally at aggregation),
    // and drain the tenant's shards in whole per-layer vector units
    let mut cfg = deep_two_tenant_cfg(1, 2);
    cfg.containment = true;
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 1,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.quarantines.len(), 1, "exactly one contained abort: {:?}", s.quarantines);
    let q = &s.quarantines[0];
    assert_eq!(q.tenant, 0, "the quarantine names the tampered tenant");
    assert_eq!(q.requeued, 2, "the aborted wave's whole batch is re-admitted");
    assert_eq!(q.lost, 0);
    // 3 matrix shards and 2 hidden-ReLU shards per remaining vector: the
    // drain never splits a layer vector
    assert_eq!(q.drained_mat % 3, 0, "mat shards drain in whole layer-vector units: {q:?}");
    assert_eq!(
        q.drained_relu * 3,
        q.drained_mat * 2,
        "2 hidden ReLU shards drain per 3 matrix shards: {q:?}"
    );
    let (poisoned, innocent) = (&s.tenants[0], &s.tenants[1]);
    assert_eq!(poisoned.quarantined_at, Some(q.at_tick), "lockstep quarantine tick");
    assert_eq!(poisoned.served, 4, "re-queued queries finish over the secure inline path");
    assert!(poisoned.inline_waves >= 1, "quarantined pops miss deterministically");
    assert_eq!(innocent.quarantined_at, None);
    assert_eq!(innocent.served, 4, "the innocent tenant never notices");
    assert_tenant_answers_match_cleartext(&s, &cfg, "deep containment");
}

// ------------------------------------------------------------- GOD failover

/// Two-tenant keyed cluster with containment and `--failover god` armed,
/// sized so the tampered tenant walks the whole degrade ladder: keyed →
/// quarantine → GOD failover → rehabilitation → keyed again.
fn failover_two_tenant_cfg(queries: usize, seed: u64) -> trident::serve::MultiServeConfig {
    use trident::sched::TenantSpec;
    let mk = |name: &str, model: u64| {
        let mut s = TenantSpec::new(name, model, 16, queries, 3);
        s.rows_per_query = 2;
        s
    };
    trident::serve::MultiServeConfig {
        tenants: vec![mk("m1", 1), mk("m2", 2)],
        mode: trident::serve::PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        age_every: 0,
        seed,
        containment: true,
        failover: trident::serve::FailoverPolicy::God,
        ..trident::serve::MultiServeConfig::default()
    }
}

/// The failover acceptance scenario: with `--failover god` the tampered
/// tenant loses ZERO queries — the poisoned wave's batch is re-queued and
/// re-served on the Tetrad GOD backend, the tenant rehabilitates back to
/// keyed Trident after [`REHAB_AFTER`] clean failover waves, and every
/// surfaced answer (both tenants, all three serving regimes) equals the
/// cleartext oracle.
#[test]
fn god_failover_loses_no_query_and_rehabilitates_to_keyed_serving() {
    use trident::serve::{serve_multi, FaultKind, FaultPlan, TransitionKind, REHAB_AFTER};
    let mut cfg = failover_two_tenant_cfg(15, 2301);
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 0,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.quarantines.len(), 1, "one contained abort: {:?}", s.quarantines);
    assert_eq!(s.quarantines[0].tenant, 0);
    assert_eq!(s.quarantines[0].lost, 0, "god failover loses nothing");
    let kinds: Vec<_> = s.transitions.iter().map(|tr| (tr.tenant, tr.kind)).collect();
    assert_eq!(
        kinds,
        vec![(0, TransitionKind::Failover), (0, TransitionKind::Rehab)],
        "one failover → rehab cycle, scoped to the tampered tenant: {:?}",
        s.transitions
    );
    let (degraded, innocent) = (&s.tenants[0], &s.tenants[1]);
    assert_eq!(degraded.served, 15, "every admitted query is answered: {degraded:?}");
    assert_eq!(degraded.expired, 0);
    assert_eq!(degraded.lost, 0);
    assert_eq!(
        degraded.failover_waves,
        REHAB_AFTER as usize,
        "exactly the clean waves the rehab rule demands ran on GOD: {degraded:?}"
    );
    assert_eq!(degraded.rehabilitated_at, Some(s.transitions[1].at_tick));
    assert!(
        degraded.keyed_waves >= 2,
        "keyed before the fault and again after rehab: {degraded:?}"
    );
    // the tenant's LAST wave runs post-rehab: keyed Trident, offline-silent
    let last = s.wave_tenants.iter().rposition(|&t| t == 0).unwrap();
    assert_eq!(
        s.wave_offline_msgs[last], 0,
        "post-rehab waves are offline-silent keyed waves again"
    );
    assert_eq!(innocent.served, 15);
    assert_eq!(innocent.failover_waves, 0, "failover never leaks to the innocent tenant");
    assert_eq!(innocent.rehabilitated_at, None);
    assert_tenant_answers_match_cleartext(&s, &cfg, "god failover");
}

/// The deep-circuit variant: a tamper at an INNER gate of a 3-layer
/// resident network degrades and rehabilitates the same way — the
/// quarantine drain still moves whole layer-vector units and every answer
/// survives the backend switches.
#[test]
fn god_failover_recovers_deep_tenant_tampered_at_inner_gate() {
    use trident::serve::{serve_multi, FailoverPolicy, FaultKind, FaultPlan, TransitionKind};
    let mut cfg = deep_two_tenant_cfg(1, 2);
    for t in cfg.tenants.iter_mut() {
        t.queries = 8;
    }
    cfg.seed = 2302;
    cfg.containment = true;
    cfg.failover = FailoverPolicy::God;
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 1,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg.clone());
    assert_eq!(s.quarantines.len(), 1, "{:?}", s.quarantines);
    let q = &s.quarantines[0];
    assert_eq!(q.tenant, 0);
    assert_eq!(q.lost, 0);
    assert_eq!(q.drained_mat % 3, 0, "the drain never splits a layer vector: {q:?}");
    let kinds: Vec<_> = s.transitions.iter().map(|tr| (tr.tenant, tr.kind)).collect();
    assert_eq!(kinds, vec![(0, TransitionKind::Failover), (0, TransitionKind::Rehab)]);
    assert_eq!(s.tenants[0].served, 8, "{:?}", s.tenants[0]);
    assert_eq!(s.tenants[1].served, 8);
    assert_eq!(s.tenants[1].failover_waves, 0);
    let last = s.wave_tenants.iter().rposition(|&t| t == 0).unwrap();
    assert_eq!(s.wave_offline_msgs[last], 0, "post-rehab deep waves pop keyed vectors");
    assert_tenant_answers_match_cleartext(&s, &cfg, "deep god failover");
}

/// Failover does not weaken the party-scoped contract: an abort OUTSIDE a
/// wave body still fails the whole run closed even with `--failover god`
/// armed — no backend switch, no quarantine, no transitions.
#[test]
fn god_failover_keeps_party_scoped_aborts_fail_closed() {
    use trident::serve::{serve_multi_checked, FaultKind, FaultPlan};
    let mut cfg = failover_two_tenant_cfg(9, 2304);
    cfg.fault = Some(FaultPlan {
        party: P3,
        tenant: 1,
        wave: 1,
        layer: 0,
        kind: FaultKind::AbortOffWave,
        every: None,
    });
    let err = serve_multi_checked(NetProfile::zero(), cfg)
        .expect_err("party-scoped aborts fail closed under any failover policy");
    assert!(
        matches!(err, trident::net::Abort::Verify(_)),
        "the aborting party's own cause is surfaced: {err}"
    );
}

/// The backend family shares one evaluation phase: the same two-tenant
/// workload served natively on each backend — keyed Trident, Tetrad-fair,
/// Tetrad-GOD — opens identical values (all equal to the cleartext
/// oracle), with the planner schedule and the pool path untouched by the
/// delivery mode.
#[test]
fn tetrad_backends_open_identical_values_to_trident() {
    use trident::sched::Backend;
    use trident::serve::serve_multi;
    let mut schedules = Vec::new();
    for b in [Backend::Trident, Backend::TetradFair, Backend::TetradGod] {
        let mut cfg = two_tenant_cfg(trident::serve::PoolMode::Keyed, 1, 2);
        cfg.seed = 2305;
        for t in cfg.tenants.iter_mut() {
            t.backend = b;
        }
        let s = serve_multi(NetProfile::zero(), cfg.clone());
        for ts in &s.tenants {
            assert_eq!(ts.served, 9, "{}: all queries answered", b.label());
            assert_eq!(
                ts.keyed_waves, ts.waves,
                "{}: delivery mode never touches the pool path",
                b.label()
            );
        }
        assert_tenant_answers_match_cleartext(&s, &cfg, b.label());
        schedules.push(s.wave_tenants.clone());
    }
    assert!(schedules.windows(2).all(|w| w[0] == w[1]), "the planner is backend-independent");
}

// ------------------------------------------------------------ observability

/// The observer-effect contract: enabling the trace recorder must not
/// change a single metered value or opened answer. Trace hooks sit
/// strictly after the metering arithmetic and never send, sample, or
/// touch the virtual clocks — so two otherwise-identical runs, one
/// traced and one not, must agree on every deterministic meter.
/// (Latencies and compute times are wall-clock-derived and legitimately
/// differ between any two runs; they are deliberately not compared.)
#[test]
fn tracing_is_observer_effect_free() {
    use trident::serve::serve_multi;
    let off_cfg = deep_two_tenant_cfg(1, 2);
    let mut on_cfg = deep_two_tenant_cfg(1, 2);
    on_cfg.trace = true;
    let off = serve_multi(NetProfile::zero(), off_cfg.clone());
    let on = serve_multi(NetProfile::zero(), on_cfg);
    assert!(off.trace.is_empty() && !on.trace.is_empty());
    assert_eq!(off.online_rounds, on.online_rounds);
    assert_eq!(off.offline_msgs_in_waves, on.offline_msgs_in_waves);
    assert_eq!(off.offline_msgs_matmul, on.offline_msgs_matmul);
    assert_eq!(off.offline_msgs_relu, on.offline_msgs_relu);
    assert_eq!(off.refill_online_msgs, on.refill_online_msgs);
    assert_eq!(off.waves, on.waves);
    assert_eq!(off.ticks, on.ticks);
    assert_eq!(off.wave_tenants, on.wave_tenants);
    assert_eq!(off.wave_offline_msgs, on.wave_offline_msgs);
    assert_eq!(off.report.rounds, on.report.rounds, "metered rounds unchanged");
    assert_eq!(off.report.value_bits, on.report.value_bits, "analytic bits unchanged");
    assert_eq!(off.report.value_bytes, on.report.value_bytes, "value bytes unchanged");
    assert_eq!(off.report.total_bytes, on.report.total_bytes, "all byte classes unchanged");
    assert_eq!(off.report.msgs, on.report.msgs, "message counts unchanged");
    for (a, b) in off.tenants.iter().zip(&on.tenants) {
        assert_eq!(a.answers, b.answers, "opened answers byte-identical with tracing on");
        assert_eq!(a.offline_msgs_matmul_layers, b.offline_msgs_matmul_layers);
        assert_eq!(a.offline_msgs_relu_layers, b.offline_msgs_relu_layers);
    }
    assert_tenant_answers_match_cleartext(&on, &off_cfg, "traced deep keyed");
}

/// Trace identity fields are pure functions of public lockstep metadata,
/// so all four parties must emit the same skeleton — and the per-gate
/// spans must be present with the wave/gate coordinates filled in.
#[test]
fn four_party_trace_skeletons_are_identical() {
    use trident::serve::serve_multi;
    let mut cfg = deep_two_tenant_cfg(1, 2);
    cfg.trace = true;
    let s = serve_multi(NetProfile::zero(), cfg);
    assert_eq!(s.party_traces.len(), 4);
    trident::obs::check_skeletons(&s.party_traces).expect("lockstep skeletons must agree");
    let gates: Vec<_> = s.trace.iter().filter(|e| e.op == "gate.matmul").collect();
    assert!(!gates.is_empty(), "per-gate matmul spans recorded");
    for e in &gates {
        assert!(e.tenant.is_some() && e.wave.is_some() && e.gate.is_some(), "{e:?}");
    }
    assert!(s.trace.iter().any(|e| e.op == "gate.relu"), "hidden-ReLU spans recorded");
    assert_eq!(s.trace.first().map(|e| e.op), Some("run.open"));
    assert_eq!(s.trace.last().map(|e| e.op), Some("run.close"));
    // a lockstep event's payload is the four-party merge: the wave.commit
    // offline-message sums must match the run-level meter
    let committed: u64 = s
        .trace
        .iter()
        .filter(|e| e.op == "wave.commit")
        .map(|e| e.payload.msgs)
        .sum();
    assert_eq!(committed, s.offline_msgs_in_waves, "merged wave payloads == meters");
}

/// A party whose identity fields drift — here P2 recording under a
/// different logical tick — must be caught by the skeleton check, not
/// silently merged.
#[test]
fn skeleton_check_catches_injected_divergence() {
    use trident::obs::Payload;
    let run = run_4pc(NetProfile::zero(), 991, |ctx| {
        ctx.net.trace().enable();
        let tick = if ctx.id() == P2 { 7 } else { 3 };
        ctx.net.trace().set_tick(tick);
        ctx.net.trace_event("test.step", true, Payload::gauge(1));
        Ok(ctx.net.trace().take())
    });
    let (outs, _) = run.expect_ok();
    let err = trident::obs::check_skeletons(&outs).expect_err("P2's tick drift must be caught");
    assert!(err.contains("test.step"), "the diverging event is named: {err}");
}

/// The trace-derived per-op rollup reconciles exactly with the offline
/// message meters in both pool modes (keyed: all zero on warm waves;
/// inline: the full per-gate correlation traffic).
#[test]
fn op_rollup_reconciles_with_offline_meters_in_both_modes() {
    use trident::serve::{serve_multi, PoolMode};
    for mode in [PoolMode::Keyed, PoolMode::Inline] {
        let mut cfg = two_tenant_cfg(mode, 1, 2);
        cfg.trace = true;
        let s = serve_multi(NetProfile::zero(), cfg);
        let rollup = s.op_rollup();
        assert!(!rollup.is_empty(), "{mode:?}: rollup populated");
        let mat: u64 =
            rollup.iter().filter(|r| r.op == "matmul").map(|r| r.offline_msgs).sum();
        let relu: u64 = rollup.iter().filter(|r| r.op == "relu").map(|r| r.offline_msgs).sum();
        assert_eq!(mat, s.offline_msgs_matmul, "{mode:?}: matmul rollup == meter");
        assert_eq!(relu, s.offline_msgs_relu, "{mode:?}: relu rollup == meter");
        if mode == PoolMode::Inline {
            assert!(mat > 0, "inline waves pay per-gate correlation traffic");
        } else {
            assert_eq!(mat + relu, 0, "warm keyed waves are offline-silent");
        }
    }
}

// -------------------------------------------------- scheduled training

/// Cleartext gradient-descent oracle mirroring `ml::nn::train_step` in
/// f64 over the job's deterministic batch and seed-derived initial
/// weights: per epoch a forward pass (hidden ReLU, head linear or the
/// 3-segment sigmoid), `E = A − T`, then per layer in reverse the update
/// `W ← W − AᵀE · 2^−lr_pow / B` and the back-propagated error
/// `E ← (E ∘ Wᵀ) ⊗ drelu(U)`, both against the epoch-start weights.
fn cleartext_gd_model(
    spec: &trident::sched::TenantSpec,
    epochs: usize,
) -> Vec<trident::ml::F64Mat> {
    use trident::sched::{tenant_layer_weights, TrainKind};
    use trident::serve::tenant_train_batch;
    let (kind, _, batch, _, lr_pow) = spec.workload.training().expect("training tenant");
    let (x, y) = tenant_train_batch(spec);
    let mut ws = tenant_layer_weights(spec);
    let depth = ws.len();
    let lr = 2f64.powi(-(lr_pow as i32)) / batch as f64;
    for _ in 0..epochs {
        // forward, keeping pre-activations for the drelu gates
        let mut acts = vec![x.clone()];
        let mut pre = Vec::with_capacity(depth);
        for i in 0..depth {
            let u = acts[i].matmul(&ws[i]);
            let mut a = u.clone();
            if i + 1 < depth {
                for v in a.data.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            } else if kind == TrainKind::LogReg {
                for v in a.data.iter_mut() {
                    *v = if *v < -0.5 {
                        0.0
                    } else if *v < 0.5 {
                        *v + 0.5
                    } else {
                        1.0
                    };
                }
            }
            pre.push(u);
            acts.push(a);
        }
        let mut e = acts[depth].clone();
        for (v, t) in e.data.iter_mut().zip(y.data.iter()) {
            *v -= t;
        }
        let old = ws.clone();
        for i in (0..depth).rev() {
            let grad = acts[i].transpose().matmul(&e);
            for (w, g) in ws[i].data.iter_mut().zip(grad.data.iter()) {
                *w -= g * lr;
            }
            if i > 0 {
                let mut back = e.matmul(&old[i].transpose());
                for (v, u) in back.data.iter_mut().zip(pre[i - 1].data.iter()) {
                    if *u < 0.0 {
                        *v = 0.0;
                    }
                }
                e = back;
            }
        }
    }
    ws
}

fn assert_model_close(
    got: &[Vec<f64>],
    want: &[trident::ml::F64Mat],
    tol: f64,
    label: &str,
) {
    assert_eq!(got.len(), want.len(), "{label}: layer count");
    for (l, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.len(), w.data.len(), "{label}: layer {l} element count");
        for (i, (a, b)) in g.iter().zip(w.data.iter()).enumerate() {
            assert!(
                (a - b).abs() < tol,
                "{label}: layer {l} elem {i}: got {a}, want {b}"
            );
        }
    }
}

/// Single training job as the only tenant — the minimal scheduled-
/// workload harness (one epoch-granular wave per epoch).
fn one_job_cfg(
    spec: trident::sched::TenantSpec,
    mode: trident::serve::PoolMode,
    seed: u64,
) -> trident::serve::MultiServeConfig {
    trident::serve::MultiServeConfig {
        tenants: vec![spec],
        mode,
        low_water: 1,
        high_water: 2,
        age_every: 0,
        seed,
        ..trident::serve::MultiServeConfig::default()
    }
}

/// A scheduled logistic-regression job (sigmoid head, inline nonlinear
/// machinery) lands on the cleartext fixed-point GD oracle — through the
/// per-epoch keyed pools and through the inline path alike.
#[test]
fn train_scheduled_logreg_job_matches_cleartext_gd_oracle() {
    use trident::sched::{TenantSpec, TrainKind};
    use trident::serve::{serve_multi, PoolMode};
    let spec =
        || TenantSpec::training("job", 1, 6, Vec::new(), TrainKind::LogReg, 4, 8, 0, 4);
    let want = cleartext_gd_model(&spec(), 4);
    for mode in [PoolMode::Keyed, PoolMode::Inline] {
        let s = serve_multi(NetProfile::zero(), one_job_cfg(spec(), mode, 1705));
        let ts = &s.tenants[0];
        assert_eq!(ts.epochs_committed, 4, "{mode:?}: all epochs commit: {ts:?}");
        let got = ts.final_model.as_ref().expect("finished job reconstructs its model");
        assert_model_close(got, &want, 0.02, &format!("logreg {mode:?}"));
    }
}

/// A scheduled NN job (hidden ReLU, linear head, full forward/grad/back
/// gate taxonomy) lands on the cleartext GD oracle in both pool modes,
/// and its warm keyed epochs stay offline-silent.
#[test]
fn train_scheduled_nn_job_matches_cleartext_gd_oracle() {
    use trident::sched::{TenantSpec, TrainKind};
    use trident::serve::{serve_multi, PoolMode};
    let spec =
        || TenantSpec::training("job", 1, 9, vec![6, 2], TrainKind::Nn, 3, 8, 0, 5);
    let want = cleartext_gd_model(&spec(), 3);
    for mode in [PoolMode::Keyed, PoolMode::Inline] {
        let s = serve_multi(NetProfile::zero(), one_job_cfg(spec(), mode, 1715));
        let ts = &s.tenants[0];
        assert_eq!(ts.epochs_committed, 3, "{mode:?}: all epochs commit: {ts:?}");
        if mode == PoolMode::Keyed {
            assert_eq!(ts.keyed_waves, 3, "warm epochs draw from the per-epoch pools");
            assert_eq!(
                ts.offline_msgs_in_waves, 0,
                "warm keyed training epochs are offline-silent: {ts:?}"
            );
        }
        let got = ts.final_model.as_ref().expect("finished job reconstructs its model");
        assert_model_close(got, &want, 0.02, &format!("nn {mode:?}"));
    }
}

/// Restoring a mid-job checkpoint replays only the remaining epochs and
/// lands on the full run's final model (per-party blobs, deterministic
/// restore) — which itself sits on the cleartext GD oracle. Within-run
/// four-party identity of the reconstructed model is asserted by the
/// engine's aggregation; across runs the probabilistic truncation leaves
/// sub-tolerance drift, hence the closeness bound rather than equality.
#[test]
fn checkpoint_restore_resumes_onto_the_full_runs_model() {
    use trident::sched::{TenantSpec, TrainKind};
    use trident::serve::{serve_multi, PoolMode};
    let spec =
        || TenantSpec::training("job", 1, 9, vec![6, 2], TrainKind::Nn, 4, 8, 2, 5);
    let full = serve_multi(NetProfile::zero(), one_job_cfg(spec(), PoolMode::Keyed, 1725));
    let ts = &full.tenants[0];
    assert_eq!(ts.epochs_committed, 4);
    let epochs: Vec<u64> = ts.checkpoints.iter().map(|(e, _)| *e).collect();
    assert_eq!(epochs, vec![2, 4], "checkpoint_every = 2 over 4 epochs");
    let full_model = ts.final_model.as_ref().expect("full run finishes its model");
    assert_model_close(
        full_model,
        &cleartext_gd_model(&spec(), 4),
        0.02,
        "full run vs oracle",
    );

    // resume from the mid-job checkpoint: only epochs 2..4 run again
    let (ck_epoch, blobs) = ts.checkpoints[0].clone();
    assert_eq!(ck_epoch, 2);
    let mut cfg = one_job_cfg(spec(), PoolMode::Keyed, 1725);
    cfg.resume = vec![Some(blobs)];
    let resumed = serve_multi(NetProfile::zero(), cfg);
    let rs = &resumed.tenants[0];
    assert_eq!(rs.epochs_committed, 2, "only the remaining epochs run: {rs:?}");
    let got = rs.final_model.as_ref().expect("resumed job finishes its model");
    assert_eq!(got.len(), full_model.len());
    for (l, (g, f)) in got.iter().zip(full_model.iter()).enumerate() {
        for (i, (a, b)) in g.iter().zip(f.iter()).enumerate() {
            assert!(
                (a - b).abs() < 0.01,
                "resumed vs full layer {l} elem {i}: {a} vs {b}"
            );
        }
    }
}

/// A scheduled training job quarantined mid-run under `--failover god`:
/// the tampered epoch is re-queued and re-run on the GOD backend (the
/// mid-job checkpoint lands while the tenant serves on failover), the job
/// rehabilitates, every epoch commits, and the final model still sits on
/// the uninterrupted cleartext GD oracle. The checkpoint taken during
/// failover then restores onto plain keyed serving, replaying only the
/// remaining epochs onto a model that also matches the oracle.
#[test]
fn training_job_quarantined_mid_run_finishes_on_failover_and_matches_oracle() {
    use trident::sched::{TenantSpec, TrainKind};
    use trident::serve::{
        serve_multi, FailoverPolicy, FaultKind, FaultPlan, PoolMode, TransitionKind, REHAB_AFTER,
    };
    let spec = || TenantSpec::training("job", 1, 9, vec![6, 2], TrainKind::Nn, 4, 8, 2, 5);
    let want = cleartext_gd_model(&spec(), 4);
    let mut cfg = one_job_cfg(spec(), PoolMode::Keyed, 2303);
    cfg.containment = true;
    cfg.failover = FailoverPolicy::God;
    cfg.fault = Some(FaultPlan {
        party: P1,
        tenant: 0,
        wave: 1,
        layer: 0,
        kind: FaultKind::TamperMatLamX,
        every: None,
    });
    let s = serve_multi(NetProfile::zero(), cfg);
    assert_eq!(s.quarantines.len(), 1, "{:?}", s.quarantines);
    assert_eq!(s.quarantines[0].tenant, 0);
    assert_eq!(s.quarantines[0].lost, 0, "the tampered epoch is re-queued, not lost");
    let ts = &s.tenants[0];
    assert_eq!(ts.epochs_committed, 4, "every epoch commits despite the quarantine: {ts:?}");
    assert_eq!(
        ts.failover_waves,
        REHAB_AFTER as usize,
        "the re-run epochs served on the GOD backend: {ts:?}"
    );
    let kinds: Vec<_> = s.transitions.iter().map(|tr| tr.kind).collect();
    assert_eq!(kinds, vec![TransitionKind::Failover, TransitionKind::Rehab]);
    assert!(ts.rehabilitated_at.is_some(), "{ts:?}");
    let got = ts.final_model.as_ref().expect("the degraded job still finishes its model");
    assert_model_close(got, &want, 0.02, "god-failover training vs oracle");

    // restore the checkpoint taken during failover onto a clean keyed run
    let (ck_epoch, blobs) = ts.checkpoints[0].clone();
    assert_eq!(ck_epoch, 2, "checkpoint_every = 2: the mid-job checkpoint is epoch 2");
    let mut rcfg = one_job_cfg(spec(), PoolMode::Keyed, 2303);
    rcfg.resume = vec![Some(blobs)];
    let resumed = serve_multi(NetProfile::zero(), rcfg);
    let rs = &resumed.tenants[0];
    assert_eq!(rs.epochs_committed, 2, "only epochs 2..4 replay: {rs:?}");
    assert_eq!(rs.failover_waves, 0, "the honest resumed run never degrades");
    let rgot = rs.final_model.as_ref().expect("resumed job finishes its model");
    assert_model_close(rgot, &want, 0.02, "resumed-from-failover-checkpoint vs oracle");
}
