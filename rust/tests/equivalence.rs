//! Protocol-equivalence suite backing the offline pool + serving engine:
//!
//! * **batched == scalar**: `mult_many`/`mult_tr_many`/`bit2a_many`/
//!   `bitext_many` open to the same values as their per-element scalar
//!   counterparts (property-tested via `testutil::forall`);
//! * **pool-backed == inline**: every protocol the pool feeds produces
//!   the same opened outputs whether its correlated randomness was
//!   pre-generated (`pool::fill_*`) or generated inline;
//! * **failure injection**: a tampered or replayed pooled truncation pair
//!   aborts in the online phase — never a wrong opened value at an honest
//!   party — and pool exhaustion falls back deterministically;
//! * **meter regressions**: pool attachment leaves `Π_MultTr`'s online
//!   rounds/bits untouched (the paper-shaped cost), and a coalesced wave
//!   of N queries costs the rounds of a single query.

use trident::convert::{bit2a, bit2a_many, bitext, bitext_many};
use trident::net::{NetProfile, P1, P2, P3};
use trident::pool::{fill_bitext, fill_lam, fill_trunc, Pool};
use trident::proto::sharing::share_many_n;
use trident::proto::{
    dotp, mult, mult_many, mult_tr, mult_tr_many, run_4pc, run_4pc_timeout, share,
};
use trident::ring::fixed::{FixedPoint, FRAC_BITS, SCALE};
use trident::ring::{Bit, Z64};
use trident::sharing::{open, MShare};
use trident::testutil::{forall, shrink_vec};

// ---------------------------------------------------------- batched == scalar

#[test]
fn property_mult_many_equals_scalar_mult() {
    forall(
        601,
        6,
        |rng| {
            let n = (rng.below(6) + 1) as usize;
            (0..2 * n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| v.len() % 2 == 0 && !v.is_empty()).collect(),
        |vals| {
            let n = vals.len() / 2;
            let (xs, ys) = (vals[..n].to_vec(), vals[n..].to_vec());
            let (x2, y2) = (xs.clone(), ys.clone());
            let run = run_4pc(NetProfile::zero(), 601, move |ctx| {
                let sx = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1).then(|| x2.iter().map(|&v| Z64(v)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let sy = share_many_n(
                    ctx,
                    P2,
                    (ctx.id() == P2).then(|| y2.iter().map(|&v| Z64(v)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let batched = mult_many(ctx, &sx, &sy)?;
                let mut scalar = Vec::with_capacity(n);
                for i in 0..n {
                    scalar.push(mult(ctx, &sx[i], &sy[i])?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for i in 0..n {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Z64(xs[i].wrapping_mul(ys[i]));
                if b != want || s != want {
                    return Err(format!(
                        "gate {i}: batched {b:?}, scalar {s:?}, want {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_mult_tr_many_equals_scalar_mult_tr() {
    forall(
        602,
        5,
        |rng| {
            let n = (rng.below(4) + 1) as usize;
            (0..2 * n).map(|_| rng.normal() * 8.0).collect::<Vec<f64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| v.len() % 2 == 0 && !v.is_empty()).collect(),
        |vals| {
            let n = vals.len() / 2;
            let (xs, ys) = (vals[..n].to_vec(), vals[n..].to_vec());
            let (x2, y2) = (xs.clone(), ys.clone());
            let run = run_4pc(NetProfile::zero(), 602, move |ctx| {
                let sx = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1)
                        .then(|| x2.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let sy = share_many_n(
                    ctx,
                    P2,
                    (ctx.id() == P2)
                        .then(|| y2.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let batched = mult_tr_many(ctx, &sx, &sy)?;
                let mut scalar = Vec::with_capacity(n);
                for i in 0..n {
                    scalar.push(mult_tr(ctx, &sx[i], &sy[i])?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for i in 0..n {
                let b = FixedPoint::decode(open(&[
                    outs[0].0[i],
                    outs[1].0[i],
                    outs[2].0[i],
                    outs[3].0[i],
                ]));
                let s = FixedPoint::decode(open(&[
                    outs[0].1[i],
                    outs[1].1[i],
                    outs[2].1[i],
                    outs[3].1[i],
                ]));
                let want = xs[i] * ys[i];
                let tol = (xs[i].abs() + ys[i].abs() + 4.0) / SCALE;
                if (b - want).abs() > tol || (s - want).abs() > tol {
                    return Err(format!(
                        "gate {i}: batched {b}, scalar {s}, want {want} (tol {tol})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_bit2a_many_equals_scalar_bit2a() {
    forall(
        603,
        5,
        |rng| {
            let n = (rng.below(6) + 1) as usize;
            (0..n).map(|_| rng.next_u64() & 1 == 1).collect::<Vec<bool>>()
        },
        |v| shrink_vec(v),
        |bits| {
            let n = bits.len();
            let b2 = bits.clone();
            let run = run_4pc(NetProfile::zero(), 603, move |ctx| {
                let bs = share_many_n(
                    ctx,
                    P3,
                    (ctx.id() == P3).then(|| b2.iter().map(|&b| Bit(b)).collect::<Vec<_>>()).as_deref(),
                    n,
                )?;
                let batched = bit2a_many(ctx, &bs)?;
                let mut scalar = Vec::with_capacity(n);
                for b in &bs {
                    scalar.push(bit2a(ctx, b)?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for (i, &bit) in bits.iter().enumerate() {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Z64(bit as u64);
                if b != want || s != want {
                    return Err(format!("bit {i}: batched {b:?}, scalar {s:?}, want {want:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_bitext_many_equals_scalar_bitext() {
    forall(
        604,
        5,
        |rng| {
            let n = (rng.below(5) + 1) as usize;
            (0..n)
                .map(|_| {
                    let v = rng.next_u64() as i64 / 4;
                    if v == 0 {
                        1
                    } else {
                        v
                    }
                })
                .collect::<Vec<i64>>()
        },
        |v| shrink_vec(v).into_iter().filter(|v| !v.is_empty()).collect(),
        |vals| {
            let n = vals.len();
            let v2 = vals.clone();
            let run = run_4pc(NetProfile::zero(), 604, move |ctx| {
                let vs = share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1)
                        .then(|| v2.iter().map(|&v| Z64::from(v)).collect::<Vec<_>>())
                        .as_deref(),
                    n,
                )?;
                let batched = bitext_many(ctx, &vs)?;
                let mut scalar = Vec::with_capacity(n);
                for v in &vs {
                    scalar.push(bitext(ctx, v)?);
                }
                ctx.flush_verify()?;
                Ok((batched, scalar))
            });
            let (outs, _) = run.expect_ok();
            for (i, &v) in vals.iter().enumerate() {
                let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
                let s = open(&[outs[0].1[i], outs[1].1[i], outs[2].1[i], outs[3].1[i]]);
                let want = Bit(v < 0);
                if b != want || s != want {
                    return Err(format!("msb({v}): batched {b:?}, scalar {s:?}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------ pool-backed == inline

/// Run `body` twice — once with a pre-stocked pool, once inline — and
/// require identical opened outputs.
fn assert_pool_inline_equal<F>(seed: u64, n: usize, body: F)
where
    F: Fn(&mut trident::proto::Ctx, bool) -> Result<Vec<MShare<Z64>>, trident::net::Abort>
        + Send
        + Sync
        + Copy
        + 'static,
{
    let pooled = run_4pc(NetProfile::zero(), seed, move |ctx| body(ctx, true));
    let inline = run_4pc(NetProfile::zero(), seed, move |ctx| body(ctx, false));
    let (po, _) = pooled.expect_ok();
    let (io, _) = inline.expect_ok();
    for i in 0..n {
        let p = open(&[po[0][i], po[1][i], po[2][i], po[3][i]]);
        let q = open(&[io[0][i], io[1][i], io[2][i], io[3][i]]);
        assert_eq!(p, q, "pool-backed vs inline diverged at output {i}");
    }
}

#[test]
fn pool_inline_equivalence_mult_many() {
    let n = 5;
    assert_pool_inline_equal(611, n, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, n);
        }
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| (1..=n as u64).map(Z64).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| (11..=10 + n as u64).map(Z64).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let zs = mult_many(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        if pool {
            let stats = ctx.detach_pool().unwrap().stats();
            assert!(stats.lam_hits >= 1, "pooled run must hit the λ pool: {stats:?}");
        }
        Ok(zs)
    });
}

#[test]
fn pool_inline_equivalence_dotp() {
    assert_pool_inline_equal(612, 1, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, 1);
        }
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| vec![Z64(3); 20]).as_deref(),
            20,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| vec![Z64(7); 20]).as_deref(),
            20,
        )?;
        let z = dotp(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        Ok(vec![z])
    });
}

#[test]
fn pool_inline_equivalence_bit2a_many() {
    let bits = [true, false, true, true];
    assert_pool_inline_equal(613, bits.len(), move |ctx, pool| {
        let n = bits.len();
        if pool {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, n);
        }
        let bs = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| bits.iter().map(|&b| Bit(b)).collect::<Vec<_>>()).as_deref(),
            n,
        )?;
        let out = bit2a_many(ctx, &bs)?;
        ctx.flush_verify()?;
        Ok(out)
    });
}

#[test]
fn pool_inline_equivalence_mult_tr_many() {
    // truncation pairs differ between the two runs (they are fresh
    // randomness), so equivalence is against the cleartext oracle within
    // the probabilistic-truncation tolerance — for both runs.
    let vals = [(1.5f64, 2.5f64), (-3.25, 1.5), (0.75, -4.0)];
    let n = vals.len();
    let runner = |pool: bool| {
        run_4pc(NetProfile::zero(), 614, move |ctx| {
            if pool {
                ctx.attach_pool(Pool::new());
                fill_trunc(ctx, n, FRAC_BITS)?;
            }
            let xs = share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| vals.iter().map(|c| FixedPoint::encode(c.0)).collect::<Vec<_>>())
                    .as_deref(),
                n,
            )?;
            let ys = share_many_n(
                ctx,
                P2,
                (ctx.id() == P2)
                    .then(|| vals.iter().map(|c| FixedPoint::encode(c.1)).collect::<Vec<_>>())
                    .as_deref(),
                n,
            )?;
            let zs = mult_tr_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            let hits = ctx.detach_pool().map(|p| p.stats().trunc_hits).unwrap_or(0);
            Ok((zs, hits))
        })
    };
    for pool in [true, false] {
        let (outs, _) = runner(pool).expect_ok();
        if pool {
            assert!(outs[1].1 >= 1, "pooled run must consume pooled pairs");
        }
        for (i, &(a, b)) in vals.iter().enumerate() {
            let got = FixedPoint::decode(open(&[
                outs[0].0[i],
                outs[1].0[i],
                outs[2].0[i],
                outs[3].0[i],
            ]));
            let tol = (a.abs() + b.abs() + 4.0) / SCALE;
            assert!(
                (got - a * b).abs() <= tol,
                "pool={pool} gate {i}: {a}·{b} → {got}"
            );
        }
    }
}

#[test]
fn pool_inline_equivalence_bitext_and_relu() {
    let vals = [-3.5f64, 2.25, -0.125, 7.0];
    let n = vals.len();
    assert_pool_inline_equal(615, n, move |ctx, pool| {
        if pool {
            ctx.attach_pool(Pool::new());
            fill_bitext(ctx, n)?;
            fill_lam::<Z64>(ctx, 1); // the Π_Mult inside Π_BitExt
        }
        let vs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1)
                .then(|| vals.iter().map(|&v| FixedPoint::encode(v)).collect::<Vec<_>>())
                .as_deref(),
            n,
        )?;
        let (relu, _drelu) = trident::ml::relu_many(ctx, &vs)?;
        ctx.flush_verify()?;
        if pool {
            let stats = ctx.detach_pool().unwrap().stats();
            assert!(stats.bitext_hits >= 1, "relu must pop bitext masks: {stats:?}");
        }
        Ok(relu)
    });
}

// ---------------------------------------------------------- failure injection

#[test]
fn tampered_pool_trunc_pair_aborts_online() {
    let run = run_4pc_timeout(
        NetProfile::zero(),
        621,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 1, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                // a malicious P2 corrupts its stored r1 component
                let pair = ctx.pool_mut().unwrap().trunc_front_mut(FRAC_BITS).unwrap();
                pair.r[0] = pair.r[0].map(|v| v + Z64(1));
            }
            let x = share(ctx, P1, (me == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (me == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        },
    );
    assert!(run.any_verify_abort(), "tampered pooled pair must abort, got ok");
}

#[test]
fn replayed_pool_trunc_pair_aborts_online() {
    let run = run_4pc_timeout(
        NetProfile::zero(),
        622,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 2, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                // P2 re-serves its first pair while the peers advance
                assert!(ctx.pool_mut().unwrap().replay_front_trunc(FRAC_BITS));
            }
            let xs = share_many_n(
                ctx,
                P1,
                (me == P1)
                    .then(|| vec![FixedPoint::encode(1.5), FixedPoint::encode(-2.0)])
                    .as_deref(),
                2,
            )?;
            let ys = share_many_n(
                ctx,
                P2,
                (me == P2)
                    .then(|| vec![FixedPoint::encode(3.0), FixedPoint::encode(0.5)])
                    .as_deref(),
                2,
            )?;
            let zs = mult_tr_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(zs)
        },
    );
    assert!(run.any_verify_abort(), "replayed pooled pair must abort");
}

#[test]
fn tampered_pool_rt_never_yields_wrong_opened_value() {
    // Corrupting the [[rᵗ]] mask component only skews the cheater's output
    // share; the damage must surface as an abort during reconstruction,
    // never as a wrong value accepted by an honest party.
    let run = run_4pc_timeout(
        NetProfile::zero(),
        623,
        std::time::Duration::from_millis(500),
        |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 1, FRAC_BITS)?;
            let me = ctx.id();
            if me == P2 {
                let pair = ctx.pool_mut().unwrap().trunc_front_mut(FRAC_BITS).unwrap();
                if let MShare::Eval { lam_prev, .. } = &mut pair.rt {
                    *lam_prev += Z64(1); // P2's copy of λ1
                }
            }
            let x = share(ctx, P1, (me == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (me == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            trident::proto::reconstruct(ctx, &z)
        },
    );
    // P1 receives the corrupted λ1 from P2; P0's vouched digest busts it
    assert!(run.outputs[1].is_err(), "P1 must abort on the corrupted λ1");
    // no honest party accepts a wrong value
    for (i, out) in run.outputs.iter().enumerate() {
        if i == 2 {
            continue; // the cheater's own view is unconstrained
        }
        if let Ok(v) = out {
            let got = FixedPoint::decode(*v);
            assert!(
                (got - 6.0).abs() < 0.01,
                "P{i} accepted a wrong opened value: {got}"
            );
        }
    }
}

#[test]
fn pool_exhaustion_falls_back_deterministically() {
    let run = run_4pc(NetProfile::zero(), 624, |ctx| {
        ctx.attach_pool(Pool::new());
        fill_trunc(ctx, 2, FRAC_BITS)?;
        // request MORE than stocked: every party falls back to inline
        // generation, leaving the stock untouched
        let xs = share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then(|| vec![FixedPoint::encode(1.0); 4]).as_deref(),
            4,
        )?;
        let ys = share_many_n(
            ctx,
            P2,
            (ctx.id() == P2).then(|| vec![FixedPoint::encode(2.0); 4]).as_deref(),
            4,
        )?;
        let zs = mult_tr_many(ctx, &xs, &ys)?;
        ctx.flush_verify()?;
        let pool = ctx.detach_pool().unwrap();
        Ok((zs, pool.len_trunc(FRAC_BITS), pool.stats()))
    });
    let (outs, _) = run.expect_ok();
    for i in 0..4 {
        let got = FixedPoint::decode(open(&[
            outs[0].0[i],
            outs[1].0[i],
            outs[2].0[i],
            outs[3].0[i],
        ]));
        assert!((got - 2.0).abs() < 0.01, "fallback result {i}: {got}");
    }
    // stock untouched, exactly one recorded miss, at every party
    for o in &outs {
        assert_eq!(o.1, 2, "all-or-nothing: stock must be untouched");
        assert_eq!(o.2.trunc_misses, 1);
        assert_eq!(o.2.trunc_hits, 0);
    }
}

// --------------------------------------------------------- meter regressions

#[test]
fn meter_pool_leaves_mult_tr_online_cost_unchanged() {
    let runner = |pool: bool| {
        run_4pc(NetProfile::zero(), 631, move |ctx| {
            if pool {
                ctx.attach_pool(Pool::new());
                fill_trunc(ctx, 1, FRAC_BITS)?;
            }
            let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        })
    };
    let (_, with_pool) = runner(true).expect_ok();
    let (_, without) = runner(false).expect_ok();
    // Table II shape: online rounds and value bits identical either way
    assert_eq!(
        with_pool.rounds[1], without.rounds[1],
        "pool attachment must not change online rounds"
    );
    assert_eq!(
        with_pool.value_bits[1], without.value_bits[1],
        "pool attachment must not change online bits"
    );
    // offline work is moved (into the fill), not grown: same total bits
    assert_eq!(
        with_pool.value_bits[0], without.value_bits[0],
        "pool moves offline cost, it must not grow it"
    );
    // online stays 3ℓ beyond the two input sharings (Lemma D.2)
    assert_eq!(with_pool.value_bits[1] - 4 * 64, 3 * 64);
}

#[test]
fn meter_coalesced_wave_costs_single_query_rounds() {
    use trident::serve::{serve, ServeConfig};
    let cfg = |queries: usize, coalesce: usize| ServeConfig {
        d: 8,
        rows_per_query: 1,
        queries,
        coalesce,
        pool: true,
        relu: false,
        seed: 632,
    };
    let one = serve(NetProfile::zero(), cfg(1, 1));
    let wave = serve(NetProfile::zero(), cfg(8, 8));
    assert_eq!(wave.batches, 1);
    assert_eq!(
        wave.online_rounds, one.online_rounds,
        "8 coalesced queries must cost ~1× (not 8×) the rounds of one query"
    );
    let inline = serve(NetProfile::zero(), cfg(8, 1));
    assert_eq!(inline.online_rounds, 8 * one.online_rounds);
}

// --------------------------------------------------------- misc sanity: P0

#[test]
fn pool_backed_serving_keeps_p0_offline_only() {
    use trident::serve::{serve, ServeConfig};
    let s = serve(
        NetProfile::wan(),
        ServeConfig {
            d: 8,
            rows_per_query: 2,
            queries: 4,
            coalesce: 4,
            pool: true,
            relu: false,
            seed: 640,
        },
    );
    // P0 does no online work in the serving loop (reconstruction towards
    // the data owner has P0 vouching only — hash traffic, zero rounds for
    // value data from P0)
    let p0_online = s.report.party_time[1][0];
    let others: f64 = s.report.party_time[1][1..].iter().cloned().fold(0.0, f64::max);
    assert!(
        p0_online <= others,
        "P0 online time {p0_online} must not exceed the evaluators' {others}"
    );
}
