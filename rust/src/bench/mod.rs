//! Benchmark harness: regenerates **every table and figure** of the paper's
//! evaluation (§VI + Appendix E) — Trident numbers are *measured* (the real
//! protocols over the metered network with virtual LAN/WAN clocks); baseline
//! numbers come from the paper's own cost accounting
//! (`baseline::aby3::Aby3Cost`, `baseline::gordon`). See DESIGN.md §5 for
//! the experiment index and EXPERIMENTS.md for a recorded snapshot.
//!
//! Run via `cargo bench --bench paper_tables -- [table...]` or
//! `trident tables [table...]`.

use crate::baseline::aby3::{Aby3Cost, Security};
use crate::baseline::{gordon, PhaseCost};
use crate::crypto::Rng;
use crate::gc::circuit::aes_shaped;
use crate::ml::data::{class_batch, linreg_batch, logreg_batch, Shape};
use crate::ml::{share_fixed_mat, LinReg, LogReg, Network, NetworkKind};
use crate::net::{NetProfile, NetReport, Phase, P1, P2};
use crate::proto::{run_4pc, Ctx};

/// Measured result of one secure workload run.
#[derive(Clone, Debug)]
pub struct Measured {
    pub report: NetReport,
}

impl Measured {
    pub fn online_latency(&self) -> f64 {
        self.report.online_latency()
    }

    pub fn online_bits(&self) -> u64 {
        self.report.value_bits[Phase::Online as usize]
    }

    pub fn offline_bits(&self) -> u64 {
        self.report.value_bits[Phase::Offline as usize]
    }

    pub fn online_rounds(&self) -> u64 {
        self.report.rounds[Phase::Online as usize]
    }
}

/// Run one measured linear-regression training iteration.
pub fn measure_linreg_iter(profile: NetProfile, d: usize, batch: usize) -> Measured {
    let run = run_4pc(profile, 1000 + d as u64, move |ctx| {
        let mut rng = Rng::seeded(5);
        let data = linreg_batch(&mut rng, batch, d);
        let model = LinReg::new(d, batch);
        let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
        let ys = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.y), batch, 1)?;
        let w0 = crate::ml::F64Mat::zeros(d, 1);
        let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&w0), d, 1)?;
        // measure one steady-state iteration: reset clocks after setup
        ctx.net.reset_clocks();
        let w2 = model.train_iteration(ctx, &w, &xs, &ys)?;
        ctx.flush_verify()?;
        let _ = w2;
        Ok(())
    });
    let (_, report) = run.expect_ok();
    Measured { report }
}

/// Run one measured logistic-regression training iteration.
pub fn measure_logreg_iter(profile: NetProfile, d: usize, batch: usize) -> Measured {
    let run = run_4pc(profile, 2000 + d as u64, move |ctx| {
        let mut rng = Rng::seeded(6);
        let data = logreg_batch(&mut rng, batch, d);
        let model = LogReg::new(d, batch);
        let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
        let ys = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.y), batch, 1)?;
        let w0 = crate::ml::F64Mat::zeros(d, 1);
        let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&w0), d, 1)?;
        ctx.net.reset_clocks();
        let w2 = model.train_iteration(ctx, &w, &xs, &ys)?;
        ctx.flush_verify()?;
        let _ = w2;
        Ok(())
    });
    let (_, report) = run.expect_ok();
    Measured { report }
}

/// Run one measured NN/CNN training iteration.
pub fn measure_nn_iter(profile: NetProfile, kind: NetworkKind, batch: usize) -> Measured {
    let run = run_4pc(profile, 3000 + batch as u64, move |ctx| {
        let mut rng = Rng::seeded(7);
        let net = Network::new(kind, batch);
        let d = net.layers[0];
        let classes = *net.layers.last().unwrap();
        let data = class_batch(&mut rng, batch, d, classes);
        let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
        let ts =
            share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.t), batch, classes)?;
        let init = net.init_weights_clear(&mut Rng::seeded(8));
        let ws = net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
        ctx.net.reset_clocks();
        let ws2 = net.train_iteration(ctx, &ws, &xs, &ts)?;
        ctx.flush_verify()?;
        let _ = ws2;
        Ok(())
    });
    let (_, report) = run.expect_ok();
    Measured { report }
}

/// Measured prediction (forward pass) for a model kind.
pub fn measure_predict(
    profile: NetProfile,
    model: &str,
    d: usize,
    batch: usize,
) -> Measured {
    let model = model.to_string();
    let run = run_4pc(profile, 4000 + batch as u64, move |ctx| {
        let mut rng = Rng::seeded(9);
        match model.as_str() {
            "linreg" => {
                let data = linreg_batch(&mut rng, batch, d);
                let m = LinReg::new(d, batch);
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
                let w0 = crate::ml::F64Mat::zeros(d, 1);
                let w = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&w0), d, 1)?;
                ctx.net.reset_clocks();
                let _ = m.predict(ctx, &xs, &w)?;
            }
            "logreg" => {
                let data = logreg_batch(&mut rng, batch, d);
                let m = LogReg::new(d, batch);
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
                let w0 = crate::ml::F64Mat::zeros(d, 1);
                let w = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&w0), d, 1)?;
                ctx.net.reset_clocks();
                let _ = m.predict(ctx, &xs, &w)?;
            }
            "nn" | "cnn" => {
                let kind = if model == "nn" { NetworkKind::Nn } else { NetworkKind::Cnn };
                let net = Network::new(kind, batch);
                let classes = *net.layers.last().unwrap();
                let data = class_batch(&mut rng, batch, net.layers[0], classes);
                let xs = share_fixed_mat(
                    ctx,
                    P1,
                    (ctx.id() == P1).then_some(&data.x),
                    batch,
                    net.layers[0],
                )?;
                let init = net.init_weights_clear(&mut Rng::seeded(8));
                let ws = net.share_weights(ctx, P2, (ctx.id() == P2).then_some(&init[..]))?;
                ctx.net.reset_clocks();
                let _ = net.predict(ctx, &ws, &xs)?;
            }
            _ => unreachable!(),
        }
        ctx.flush_verify()?;
        Ok(())
    });
    let (_, report) = run.expect_ok();
    Measured { report }
}

fn fmt_rate(lat: f64, lan: bool) -> String {
    if lan {
        format!("{:.2}", 1.0 / lat)
    } else {
        format!("{:.2}", 60.0 / lat)
    }
}

// ---------------------------------------------------------------- tables --

/// Table I / IX: online (and total) cost of sharing conversions.
pub fn table1_9() -> String {
    let mut out = String::new();
    out.push_str("== Table I/IX: share conversions, online rounds & bits (ours measured vs ABY3 per-paper) ==\n");
    out.push_str("conv   | ABY3 rounds | ABY3 bits | ours rounds | ours bits (measured)\n");
    let l = 64u64;
    let k = 128u64;
    // measured: run each conversion once, subtracting input-sharing cost
    let mut add = |name: &str, aby3_r: String, aby3_b: u64, meas: (u64, u64)| {
        out.push_str(&format!(
            "{name:<6} | {aby3_r:>11} | {aby3_b:>9} | {:>11} | {:>9}\n",
            meas.0, meas.1
        ));
    };

    // G2B
    let m = measure_conversion("g2b");
    add("G2B", "1".into(), k, m);
    let m = measure_conversion("g2a");
    add("G2A", "1".into(), 2 * l * k, m);
    let m = measure_conversion("b2g");
    add("B2G", "1".into(), 2 * k, m);
    let m = measure_conversion("a2g");
    add("A2G", "1".into(), 2 * l * k, m);
    let m = measure_conversion("a2b");
    add("A2B", "1+logl".into(), 9 * l * 6 + 9 * l, m);
    let m = measure_conversion("bit2a");
    add("Bit2A", "2".into(), 18 * l, m);
    let m = measure_conversion("b2a");
    add("B2A", "1+logl".into(), 9 * l * 6 + 9 * l, m);
    let m = measure_conversion("bitinj");
    add("BitInj", "3".into(), 27 * l, m);
    out
}

/// Measure one conversion's online (rounds, bits), inputs excluded: runs
/// the workload twice (inputs only / inputs + conversion) and differences
/// the metered bits — the meter is cluster-global, unlike the per-party
/// clock reset.
fn measure_conversion(which: &str) -> (u64, u64) {
    let base = measure_conversion_inner("none");
    let full = measure_conversion_inner(which);
    (full.0, full.1 - base.1)
}

fn measure_conversion_inner(which: &str) -> (u64, u64) {
    use crate::ring::{Bit, Z64};
    let which = which.to_string();
    let run = run_4pc(NetProfile::zero(), 777, move |ctx| {
        // shared inputs (cost subtracted via pre-measurement reset)
        let a = crate::proto::share(ctx, P1, (ctx.id() == P1).then_some(Z64(12345)))?;
        let b = crate::proto::share(ctx, P1, (ctx.id() == P1).then_some(Bit(true)))?;
        let bits64 = crate::gc::circuit::u64_bits(777, 64);
        let bs = crate::proto::sharing::share_many_n(
            ctx,
            P1,
            (ctx.id() == P1).then_some(&bits64[..]),
            64,
        )?;
        let gb = crate::gc::g_share(ctx, P1, (ctx.id() == P1).then_some(&bits64[..]), 64)?;
        ctx.net.reset_clocks();
        match which.as_str() {
            "g2b" => {
                let _ = crate::convert::g2b(ctx, &gb[0])?;
            }
            "g2a" => {
                let _ = crate::convert::g2a(ctx, &gb)?;
            }
            "b2g" => {
                let _ = crate::convert::b2g(ctx, &b)?;
            }
            "a2g" => {
                let _ = crate::convert::a2g(ctx, &a)?;
            }
            "a2b" => {
                let _ = crate::convert::a2b(ctx, &a)?;
            }
            "bit2a" => {
                let _ = crate::convert::bit2a(ctx, &b)?;
            }
            "b2a" => {
                let _ = crate::convert::b2a(ctx, &bs)?;
            }
            "bitinj" => {
                let _ = crate::convert::bitinj(ctx, &b, &a)?;
            }
            "none" => {}
            _ => unreachable!(),
        }
        ctx.flush_verify()?;
        Ok(())
    });
    let (_, report) = run.expect_ok();
    (report.rounds[1], report.value_bits[1])
}

/// Table II / X: ML building blocks.
pub fn table2_10() -> String {
    use crate::ring::Z64;
    let mut out = String::new();
    out.push_str("== Table II/X: ML conversions, online (ours measured vs ABY3 per-paper, l=64) ==\n");
    out.push_str("op      | ABY3 rounds/bits | ours rounds/bits (measured)\n");
    let cases: Vec<(&str, String)> = vec![
        ("MultTr", "1 / 768".into()),
        ("BitExt", "6 / 6912".into()),
        ("ReLU", "9 / 2880".into()),
        ("Sigmoid", "10 / 5193".into()),
    ];
    // baseline: inputs only
    let base = {
        let run = run_4pc(NetProfile::zero(), 778, move |ctx| {
            let _x = crate::proto::share(
                ctx,
                P1,
                (ctx.id() == P1).then_some(crate::ring::FixedPoint::encode(1.5)),
            )?;
            let _y = crate::proto::share(
                ctx,
                P1,
                (ctx.id() == P1).then_some(crate::ring::FixedPoint::encode(-2.5)),
            )?;
            ctx.flush_verify()?;
            Ok(())
        });
        let (_, report) = run.expect_ok();
        report.value_bits[1]
    };
    for (name, aby3) in cases {
        let which = name.to_string();
        let run = run_4pc(NetProfile::zero(), 778, move |ctx| {
            let x = crate::proto::share(
                ctx,
                P1,
                (ctx.id() == P1).then_some(crate::ring::FixedPoint::encode(1.5)),
            )?;
            let y = crate::proto::share(
                ctx,
                P1,
                (ctx.id() == P1).then_some(crate::ring::FixedPoint::encode(-2.5)),
            )?;
            ctx.net.reset_clocks();
            match which.as_str() {
                "MultTr" => {
                    let _ = crate::proto::mult_tr(ctx, &x, &y)?;
                }
                "BitExt" => {
                    let _ = crate::convert::bitext(ctx, &x)?;
                }
                "ReLU" => {
                    let _: (Vec<crate::sharing::MShare<Z64>>, _) =
                        crate::ml::relu_many(ctx, &[x])?;
                }
                "Sigmoid" => {
                    let _ = crate::ml::sigmoid_many(ctx, &[x])?;
                }
                _ => unreachable!(),
            }
            ctx.flush_verify()?;
            Ok(())
        });
        let (_, report) = run.expect_ok();
        out.push_str(&format!(
            "{name:<7} | {aby3:>16} | {} / {}\n",
            report.rounds[1],
            report.value_bits[1] - base
        ));
    }
    out
}

/// Tables IV & V: regression training throughput.
pub fn table4_5(logistic: bool) -> String {
    let mut out = String::new();
    let name = if logistic { "V (Logistic" } else { "IV (Linear" };
    out.push_str(&format!(
        "== Table {name} Regression): #it/s LAN, #it/min WAN — ours measured vs ABY3 model ==\n"
    ));
    out.push_str("net  | d    | B   | ABY3      | Trident\n");
    let aby3 = Aby3Cost::new(Security::Malicious);
    for lan in [true, false] {
        let profile = if lan { NetProfile::lan() } else { NetProfile::wan() };
        for d in [10usize, 100, 1000] {
            for batch in [128usize, 256, 512] {
                let m = if logistic {
                    measure_logreg_iter(profile.clone(), d, batch)
                } else {
                    measure_linreg_iter(profile.clone(), d, batch)
                };
                let ours = m.online_latency();
                let a = if logistic {
                    aby3.logreg_iter_online(d as u64, batch as u64)
                } else {
                    aby3.linreg_iter_online(d as u64, batch as u64)
                };
                let aby3_lat = a.latency(&profile);
                out.push_str(&format!(
                    "{:<4} | {d:<4} | {batch:<3} | {:>9} | {:>9}\n",
                    profile.name,
                    fmt_rate(aby3_lat, lan),
                    fmt_rate(ours, lan),
                ));
            }
        }
    }
    out
}

/// Table VI: NN and CNN training.
pub fn table6() -> String {
    let mut out = String::new();
    out.push_str("== Table VI: NN/CNN training — ours measured vs ABY3 model ==\n");
    out.push_str("model | net | B   | ABY3      | Trident\n");
    let aby3 = Aby3Cost::new(Security::Malicious);
    for (kind, label, layers) in [
        (NetworkKind::Nn, "NN", vec![784u64, 128, 128, 10]),
        (NetworkKind::Cnn, "CNN", vec![784u64, 2880, 100, 10]),
    ] {
        for lan in [true, false] {
            let profile = if lan { NetProfile::lan() } else { NetProfile::wan() };
            for batch in [128usize, 256, 512] {
                let m = measure_nn_iter(profile.clone(), kind, batch);
                let a = aby3.nn_iter_online(&layers, batch as u64);
                out.push_str(&format!(
                    "{label:<5} | {:<3} | {batch:<3} | {:>9} | {:>9}\n",
                    profile.name,
                    fmt_rate(a.latency(&profile), lan),
                    fmt_rate(m.online_latency(), lan),
                ));
            }
        }
    }
    out
}

/// Table III: training gain at d=784, B=128 (derived from IV/V/VI runs).
pub fn table3() -> String {
    let mut out = String::new();
    out.push_str("== Table III: online training throughput gain over ABY3 (d=784, B=128) ==\n");
    out.push_str("net | LinReg | LogReg | NN | CNN\n");
    let aby3 = Aby3Cost::new(Security::Malicious);
    for lan in [true, false] {
        let profile = if lan { NetProfile::lan() } else { NetProfile::wan() };
        let lin = measure_linreg_iter(profile.clone(), 784, 128).online_latency();
        let log = measure_logreg_iter(profile.clone(), 784, 128).online_latency();
        let nn = measure_nn_iter(profile.clone(), NetworkKind::Nn, 128).online_latency();
        let cnn = measure_nn_iter(profile.clone(), NetworkKind::Cnn, 128).online_latency();
        let g = |ours: f64, theirs: PhaseCost| theirs.latency(&profile) / ours;
        out.push_str(&format!(
            "{:<3} | {:>6.2}x | {:>6.2}x | {:>5.2}x | {:>5.2}x\n",
            profile.name,
            g(lin, aby3.linreg_iter_online(784, 128)),
            g(log, aby3.logreg_iter_online(784, 128)),
            g(nn, aby3.nn_iter_online(&[784, 128, 128, 10], 128)),
            g(cnn, aby3.nn_iter_online(&[784, 2880, 100, 10], 128)),
        ));
    }
    out
}

/// Table VII: prediction latency (LAN ms / WAN s), d = 784, B ∈ {1, 100}.
pub fn table7() -> String {
    let mut out = String::new();
    out.push_str("== Table VII: secure prediction online latency (ours measured vs ABY3 model) ==\n");
    out.push_str("net | B   | model  | ABY3        | Trident\n");
    let aby3 = Aby3Cost::new(Security::Malicious);
    for lan in [true, false] {
        let profile = if lan { NetProfile::lan() } else { NetProfile::wan() };
        for batch in [1usize, 100] {
            for model in ["linreg", "logreg", "nn", "cnn"] {
                let m = measure_predict(profile.clone(), model, 784, batch);
                let a = match model {
                    "linreg" => aby3.predict_online(&[784, 1], batch as u64, false),
                    "logreg" => {
                        let mut c = aby3.predict_online(&[784, 1], batch as u64, false);
                        c.add(aby3.sigmoid_online(batch as u64));
                        c
                    }
                    "nn" => aby3.predict_online(&[784, 128, 128, 10], batch as u64, true),
                    _ => aby3.predict_online(&[784, 2880, 100, 10], batch as u64, true),
                };
                let (scale, unit) = if lan { (1e3, "ms") } else { (1.0, "s") };
                out.push_str(&format!(
                    "{:<3} | {batch:<3} | {model:<6} | {:>9.2}{unit} | {:>9.2}{unit}\n",
                    profile.name,
                    a.latency(&profile) * scale,
                    m.online_latency() * scale,
                ));
            }
        }
    }
    out
}

/// Table VIII / XV: prediction throughput over real-dataset shapes.
pub fn table8_15() -> String {
    let mut out = String::new();
    out.push_str("== Table VIII/XV: prediction throughput (queries/s over LAN, 32 threads x 100-query batches) ==\n");
    out.push_str("dataset | d   | model  | Trident q/s | ABY3-mal q/s | ABY3-semi q/s\n");
    let lan = NetProfile::lan();
    let mal = Aby3Cost::new(Security::Malicious);
    let semi = Aby3Cost::new(Security::SemiHonest);
    let sets = [
        (Shape::Boston, "linreg"),
        (Shape::Weather, "linreg"),
        (Shape::CalCofi, "linreg"),
        (Shape::Candy, "logreg"),
        (Shape::Epileptic, "logreg"),
        (Shape::Recipes, "logreg"),
        (Shape::Mnist, "nn"),
        (Shape::Mnist, "cnn"),
    ];
    for (shape, model) in sets {
        let d = shape.features();
        let m = measure_predict(lan.clone(), model, d, 100);
        let threads = 32.0;
        let tput = threads * 100.0 / m.online_latency();
        let a_cost = |c: &Aby3Cost| match model {
            "linreg" => c.predict_online(&[d as u64, 1], 100, false),
            "logreg" => {
                let mut x = c.predict_online(&[d as u64, 1], 100, false);
                x.add(c.sigmoid_online(100));
                x
            }
            "nn" => c.predict_online(&[784, 128, 128, 10], 100, true),
            _ => c.predict_online(&[784, 2880, 100, 10], 100, true),
        };
        out.push_str(&format!(
            "{:<7} | {d:<3} | {model:<6} | {:>11.1} | {:>12.1} | {:>13.1}\n",
            shape.name(),
            tput,
            threads * 100.0 / a_cost(&mal).latency(&lan),
            threads * 100.0 / a_cost(&semi).latency(&lan),
        ));
    }
    out
}

/// Table XI: per-party online runtime on the AES-128-shaped circuit (WAN).
pub fn table11() -> String {
    let mut out = String::new();
    out.push_str("== Table XI: AES-128 circuit, per-party online runtime over WAN (s) ==\n");
    let c = aes_shaped();
    let wan = NetProfile::wan();
    let g = gordon::circuit_party_times(&c, &wan);
    let t = gordon::trident_circuit_party_times(&c, &wan);
    out.push_str(&format!(
        "Gordon  | P0 {:.2} | P1 {:.2} | P2 {:.2} | P3 {:.2} | total {:.2}\n",
        g[0],
        g[1],
        g[2],
        g[3],
        g.iter().sum::<f64>()
    ));
    out.push_str(&format!(
        "Trident | P0 {:.2} | P1 {:.2} | P2 {:.2} | P3 {:.2} | total {:.2}\n",
        t[0],
        t[1],
        t[2],
        t[3],
        t.iter().sum::<f64>()
    ));
    out
}

/// Table XII: monetary-cost argument (total online runtime, WAN, d=784, B=128).
pub fn table12() -> String {
    let mut out = String::new();
    out.push_str("== Table XII: total online party-time (s), WAN, d=784 (monetary cost) ==\n");
    out.push_str("phase      | model  | ABY3 model | Trident measured\n");
    let wan = NetProfile::wan();
    let aby3 = Aby3Cost::new(Security::Malicious);
    let rows: Vec<(&str, f64, f64)> = vec![
        (
            "train",
            aby3.linreg_iter_online(784, 128).latency(&wan) * 3.0,
            measure_linreg_iter(wan.clone(), 784, 128).report.total_party_time(Phase::Online),
        ),
        (
            "predict",
            aby3.predict_online(&[784, 1], 100, false).latency(&wan) * 3.0,
            measure_predict(wan.clone(), "linreg", 784, 100)
                .report
                .total_party_time(Phase::Online),
        ),
    ];
    for (phase, a, ours) in rows {
        out.push_str(&format!("{phase:<10} | linreg | {a:>10.3} | {ours:>10.3}\n"));
    }
    out
}

/// Tables XIII/XIV: semi-honest-ABY3 comparison.
pub fn table13_14() -> String {
    let mut out = String::new();
    out.push_str("== Table XIII/XIV: vs ABY3 semi-honest (training #it/s LAN; prediction ms LAN, d=784) ==\n");
    let lan = NetProfile::lan();
    let semi = Aby3Cost::new(Security::SemiHonest);
    let lin = measure_linreg_iter(lan.clone(), 1000, 128);
    let log = measure_logreg_iter(lan.clone(), 1000, 128);
    let nn = measure_nn_iter(lan.clone(), NetworkKind::Nn, 128);
    out.push_str(&format!(
        "train linreg d=1000: ABY3S {:.1} it/s | ours {:.1} it/s\n",
        1.0 / semi.linreg_iter_online(1000, 128).latency(&lan),
        1.0 / lin.online_latency()
    ));
    out.push_str(&format!(
        "train logreg d=1000: ABY3S {:.1} it/s | ours {:.1} it/s\n",
        1.0 / semi.logreg_iter_online(1000, 128).latency(&lan),
        1.0 / log.online_latency()
    ));
    out.push_str(&format!(
        "train NN:            ABY3S {:.2} it/s | ours {:.2} it/s\n",
        1.0 / semi.nn_iter_online(&[784, 128, 128, 10], 128).latency(&lan),
        1.0 / nn.online_latency()
    ));
    let pred = measure_predict(lan.clone(), "nn", 784, 100);
    out.push_str(&format!(
        "predict NN B=100:    ABY3S {:.1} ms    | ours {:.1} ms\n",
        semi.predict_online(&[784, 128, 128, 10], 100, true).latency(&lan) * 1e3,
        pred.online_latency() * 1e3
    ));
    out
}

/// Figure 20: throughput gain vs bandwidth cap.
pub fn fig20() -> String {
    let mut out = String::new();
    out.push_str("== Fig. 20: prediction throughput gain vs bandwidth (WAN rtt, capped bw) ==\n");
    out.push_str("bw Mbps | linreg gain | logreg gain | nn gain\n");
    let mal = Aby3Cost::new(Security::Malicious);
    for mbps in [0.5f64, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0] {
        let profile = NetProfile::wan_with_bandwidth(mbps * 1e6);
        let mut cells = Vec::new();
        for model in ["linreg", "logreg", "nn"] {
            let m = measure_predict(profile.clone(), model, 784, 100);
            let a = match model {
                "linreg" => mal.predict_online(&[784, 1], 100, false),
                "logreg" => {
                    let mut c = mal.predict_online(&[784, 1], 100, false);
                    c.add(mal.sigmoid_online(100));
                    c
                }
                _ => mal.predict_online(&[784, 128, 128, 10], 100, true),
            };
            cells.push(format!("{:>10.2}x", a.latency(&profile) / m.online_latency()));
        }
        out.push_str(&format!("{mbps:>7} | {} | {} | {}\n", cells[0], cells[1], cells[2]));
    }
    out
}

/// Serving benchmark (beyond the paper): per-query amortized online cost
/// of the serving engine across its three offline-material modes — the
/// seed's inline per-query path, PR 1's scalar pools (γ-exchange still
/// live), and the circuit-keyed matrix wire-mask pool (message-free
/// per-request offline, `off msg/wave` = 0). Offline cost (pool fill /
/// refill + any live γ exchanges) stays under `Phase::Offline` — the
/// offline column shows it is *moved*, not hidden.
fn serve_mode_rows() -> Vec<(&'static str, crate::serve::ServeStats)> {
    use crate::serve::{serve, PoolMode, ServeConfig};
    let base = ServeConfig {
        d: 128,
        rows_per_query: 1,
        queries: 32,
        coalesce: 1,
        mode: PoolMode::Inline,
        low_water: 1,
        high_water: 2,
        relu: false,
        seed: 321,
    };
    vec![
        ("inline per-query", serve(NetProfile::lan(), base.clone())),
        (
            "scalar, coalesce 8",
            serve(
                NetProfile::lan(),
                ServeConfig { mode: PoolMode::Scalar, coalesce: 8, ..base.clone() },
            ),
        ),
        (
            "keyed,  coalesce 8",
            serve(
                NetProfile::lan(),
                ServeConfig { mode: PoolMode::Keyed, coalesce: 8, ..base.clone() },
            ),
        ),
        (
            "keyed,  coalesce 32",
            serve(
                NetProfile::lan(),
                ServeConfig { mode: PoolMode::Keyed, coalesce: 32, ..base.clone() },
            ),
        ),
        // the relu pair makes the off-msg split meaningful: the scalar pool
        // still works offline in-wave for the nonlinear leg, the keyed
        // nonlinear pool is silent through the whole pipeline
        (
            "scalar+relu, coal 8",
            serve(
                NetProfile::lan(),
                ServeConfig { mode: PoolMode::Scalar, coalesce: 8, relu: true, ..base.clone() },
            ),
        ),
        (
            "keyed+relu,  coal 8",
            serve(
                NetProfile::lan(),
                ServeConfig { mode: PoolMode::Keyed, coalesce: 8, relu: true, ..base },
            ),
        ),
    ]
}

/// One canonical single-tenant keyed serving run per 4PC backend —
/// Trident secure-with-abort vs Tetrad-style fair vs Tetrad-style GOD
/// ([`crate::proto::tetrad`]). The masked evaluation is identical across
/// the family (same offline material, same per-gate protocols); the
/// variants diverge only at output delivery, so the round/latency deltas
/// are the measured price of fairness and of guaranteed output delivery —
/// the Tetrad paper's protocol-comparison tables projected onto the
/// serving path.
fn backend_rows() -> Vec<(&'static str, crate::serve::MultiServeStats)> {
    use crate::proto::Backend;
    use crate::sched::TenantSpec;
    use crate::serve::{serve_multi, MultiServeConfig, PoolMode};
    [Backend::Trident, Backend::TetradFair, Backend::TetradGod]
        .into_iter()
        .map(|b| {
            let mut s = TenantSpec::new("bk", 77, 64, 16, 4);
            s.relu = true;
            s.backend = b;
            let cfg = MultiServeConfig {
                tenants: vec![s],
                mode: PoolMode::Keyed,
                low_water: 1,
                high_water: 2,
                age_every: 0,
                seed: 9010,
                ..MultiServeConfig::default()
            };
            (b.label(), serve_multi(NetProfile::lan(), cfg))
        })
        .collect()
}

/// Render the backend-comparison serving table from precomputed rows.
pub fn backend_table_from(rows: &[(&'static str, crate::serve::MultiServeStats)]) -> String {
    let mut out = String::new();
    out.push_str(
        "== Serving backends: Trident vs Tetrad-fair vs Tetrad-GOD (d=64+ReLU, keyed, coalesce 4, LAN) ==\n",
    );
    out.push_str(
        "backend     | served | waves | online rnds | rnds/wave | p50 ms | p99 ms | online s total | off msg/wave\n",
    );
    for (name, s) in rows {
        let ts = &s.tenants[0];
        out.push_str(&format!(
            "{name:<11} | {:>6} | {:>5} | {:>11} | {:>9.1} | {:>6.3} | {:>6.3} | {:>14.6} | {:>12.2}\n",
            ts.served,
            s.waves,
            s.online_rounds,
            s.online_rounds as f64 / s.waves.max(1) as f64,
            ts.p50_latency * 1e3,
            ts.p99_latency * 1e3,
            s.online_latency,
            ts.offline_msgs_in_waves as f64 / ts.waves.max(1) as f64,
        ));
    }
    out
}

/// Offline fill throughput: items generated per wall-clock second by the
/// real 4-party fill protocols (the keystream-batched PRF is the hot path
/// here — every mask/pair element used to burn one AES block per element,
/// a `Π_BitExt` position ~64 blocks per party; see EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug)]
pub struct FillThroughput {
    /// `fill_bitext` masks per second (each = one `[[r]]`, `[[msb r]]^B`).
    pub bitext_masks_per_s: f64,
    /// `fill_trunc` verified truncation pairs per second.
    pub trunc_pairs_per_s: f64,
    /// `fill_lam` λ-skeletons per second (PRF-only, no messages).
    pub lam_per_s: f64,
}

/// Measure the offline fill throughput over the zero-cost network (pure
/// generation speed, no simulated latency).
pub fn measure_fill_throughput() -> FillThroughput {
    use crate::pool::{fill_bitext, fill_lam, fill_trunc, Pool};
    use crate::ring::fixed::FRAC_BITS;
    use crate::ring::Z64;
    let per_s = |items: usize, wall: std::time::Duration| {
        items as f64 / wall.as_secs_f64().max(1e-9)
    };
    let nb = 1024usize;
    let run = run_4pc(NetProfile::zero(), 9001, move |ctx| {
        ctx.attach_pool(Pool::new());
        fill_bitext(ctx, nb)?;
        ctx.flush_verify()
    });
    let (_, rb) = run.expect_ok();
    let nt = 4096usize;
    let run = run_4pc(NetProfile::zero(), 9002, move |ctx| {
        ctx.attach_pool(Pool::new());
        fill_trunc(ctx, nt, FRAC_BITS)?;
        ctx.flush_verify()
    });
    let (_, rt) = run.expect_ok();
    let nl = 16384usize;
    let run = run_4pc(NetProfile::zero(), 9003, move |ctx| {
        ctx.attach_pool(Pool::new());
        fill_lam::<Z64>(ctx, nl);
        Ok(())
    });
    let (_, rl) = run.expect_ok();
    FillThroughput {
        bitext_masks_per_s: per_s(nb, rb.wall),
        trunc_pairs_per_s: per_s(nt, rt.wall),
        lam_per_s: per_s(nl, rl.wall),
    }
}

/// Render the fill-throughput line appended to the serving table.
pub fn fill_throughput_line(f: &FillThroughput) -> String {
    format!(
        "offline fill throughput: {:.0} bitext masks/s | {:.0} trunc pairs/s | {:.0} λ-skeletons/s\n",
        f.bitext_masks_per_s, f.trunc_pairs_per_s, f.lam_per_s,
    )
}

/// One full serving-benchmark run: the single-model mode sweep, the
/// canonical two-tenant workload, the mixed training+serving pair (the
/// schema-6 isolation section) and the offline fill throughput. Compute
/// it once and feed both the text tables and the JSON writer — every row
/// is a real 4PC cluster run, so re-running for a second output format
/// doubles bench wall time.
pub struct ServingBench {
    pub modes: Vec<(&'static str, crate::serve::ServeStats)>,
    /// The same keyed workload served once per 4PC backend (Trident /
    /// Tetrad-fair / Tetrad-GOD) — the schema-7 comparison rows.
    pub backends: Vec<(&'static str, crate::serve::MultiServeStats)>,
    pub tenants_cfg: crate::serve::MultiServeConfig,
    pub tenants: crate::serve::MultiServeStats,
    /// The inference pair served alone — the baseline the mixed run's
    /// inference-p99-under-training column is compared against.
    pub train_alone: crate::serve::MultiServeStats,
    pub train_mixed_cfg: crate::serve::MultiServeConfig,
    /// The same inference pair sharing the cluster with a saturating
    /// class-1 training job.
    pub train_mixed: crate::serve::MultiServeStats,
    pub fill: FillThroughput,
}

/// Mixed training+serving workload for the schema-6 bench section: the
/// same inference pair (weight 2:1, both class 0) served alone and next
/// to a saturating scheduled LinReg training job (class 1, unaged, one
/// epoch wave per grant, mid-job checkpoints every 2 epochs). Returns
/// `(alone, mixed)` configs; priority-class isolation means the inference
/// latency columns of both runs must line up exactly.
pub fn mixed_train_tenants(
    queries: usize,
) -> (crate::serve::MultiServeConfig, crate::serve::MultiServeConfig) {
    use crate::sched::{TenantSpec, TrainKind};
    use crate::serve::{MultiServeConfig, PoolMode};
    let mut prio = TenantSpec::new("prio", 1, 64, queries, 4);
    prio.weight = 2;
    let batch = TenantSpec::new("batch", 2, 64, queries, 4);
    let alone = MultiServeConfig {
        tenants: vec![prio, batch],
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        age_every: 2,
        seed: 444,
        trace: true,
        ..MultiServeConfig::default()
    };
    let mut mixed = alone.clone();
    mixed.tenants.push(TenantSpec::training(
        "train",
        3,
        8,
        Vec::new(),
        TrainKind::LinReg,
        6,
        8,
        2,
        4,
    ));
    (alone, mixed)
}

pub fn run_serving_bench() -> ServingBench {
    let cfg = demo_tenants(12);
    let (alone_cfg, mixed_cfg) = mixed_train_tenants(8);
    ServingBench {
        modes: serve_mode_rows(),
        backends: backend_rows(),
        tenants: crate::serve::serve_multi(NetProfile::lan(), cfg.clone()),
        tenants_cfg: cfg,
        train_alone: crate::serve::serve_multi(NetProfile::lan(), alone_cfg),
        train_mixed: crate::serve::serve_multi(NetProfile::lan(), mixed_cfg.clone()),
        train_mixed_cfg: mixed_cfg,
        fill: measure_fill_throughput(),
    }
}

pub fn serve_table() -> String {
    let mut out = serve_table_from(&serve_mode_rows());
    out.push_str(&backend_table_from(&backend_rows()));
    out.push_str(&fill_throughput_line(&measure_fill_throughput()));
    out
}

/// Render the single-model serving table from precomputed rows.
pub fn serve_table_from(rows: &[(&'static str, crate::serve::ServeStats)]) -> String {
    let mut out = String::new();
    out.push_str(
        "== Serving: pooled-matrix vs scalar-pool vs inline (linreg d=128, 1-row queries, LAN) ==\n",
    );
    out.push_str(
        "mode                 | q  | batches | online rnds | ms/query | online B/query | comp ms/wave | val B/wave | offline KiB | off msg/wave (mat|relu)\n",
    );
    let mut inline_lat = None;
    for (name, s) in rows {
        if inline_lat.is_none() {
            inline_lat = Some(s.per_query_latency());
        }
        let per_wave = |m: u64| m as f64 / s.batches.max(1) as f64;
        out.push_str(&format!(
            "{name:<20} | {:<2} | {:>7} | {:>11} | {:>8.4} | {:>14.0} | {:>12.4} | {:>10.0} | {:>11.1} | {:>8.1} ({:.1}|{:.1})\n",
            s.queries,
            s.batches,
            s.online_rounds,
            s.per_query_latency() * 1e3,
            s.per_query_online_bytes(),
            s.compute_ms_per_wave(),
            s.value_bytes_per_wave(),
            s.offline_value_bits as f64 / 8.0 / 1024.0,
            per_wave(s.offline_msgs_in_waves),
            per_wave(s.offline_msgs_matmul),
            per_wave(s.offline_msgs_relu),
        ));
        if s.batches == 1 {
            out.push_str(&format!(
                "{:<20} |    |         |             | gain {:>5.1}x vs inline per-query\n",
                "",
                inline_lat.unwrap() / s.per_query_latency().max(1e-12),
            ));
        }
    }
    out
}

/// Canonical multi-tenant demo workload for the per-tenant table/JSON:
/// three resident models behind one cluster — a weight-2 class-0 tenant, a
/// weight-1 class-1 tenant with a 6-tick deadline (aging every 2 ticks
/// keeps the low-priority tenant from starving; the deadline column shows
/// expiry accounting in action), and a **deep resident NN-3** (12-8-8-4,
/// hidden ReLU) whose warm waves pop a whole per-layer bundle vector and
/// report per-gate offline-message counts.
pub fn demo_tenants(queries: usize) -> crate::serve::MultiServeConfig {
    use crate::sched::TenantSpec;
    use crate::serve::{MultiServeConfig, PoolMode};
    let mut prio = TenantSpec::new("prio", 1, 64, queries, 4);
    prio.weight = 2;
    prio.class = 0;
    let mut batch = TenantSpec::new("batch", 2, 64, queries, 4);
    batch.weight = 1;
    batch.class = 1;
    batch.deadline_ticks = Some(6);
    // a ReLU pipeline on the batch tenant: its waves drain paired
    // MatCorr+ReluCorr bundles, so the off-msg (mat|relu) columns show the
    // nonlinear leg silent too
    batch.relu = true;
    let mut nn3 = TenantSpec::new("nn3", 3, 12, queries, 4);
    nn3.weight = 1;
    nn3.class = 0;
    nn3.layers = vec![8, 8, 4];
    MultiServeConfig {
        tenants: vec![prio, batch, nn3],
        mode: PoolMode::Keyed,
        low_water: 1,
        high_water: 2,
        age_every: 2,
        seed: 333,
        // trace on: the benchmark rollup is trace-derived and every bench
        // run exercises the cross-party skeleton + reconciliation asserts
        trace: true,
        ..MultiServeConfig::default()
    }
}

/// Per-tenant serving table: one row per resident model of a
/// [`crate::serve::MultiServeStats`] run.
pub fn tenant_table(stats: &crate::serve::MultiServeStats) -> String {
    let mut out = String::new();
    out.push_str(
        "tenant   | sub | adm | rej | served | expired | waves (keyed/inl) | part | p50 ms | p99 ms | sojourn t | off msg/wave (mat|relu) | share | quarantine\n",
    );
    for ts in &stats.tenants {
        let per_wave = |m: u64| m as f64 / ts.waves.max(1) as f64;
        let quarantine = match ts.quarantined_at {
            Some(tick) => format!("t{tick} ({}r/{}l)", ts.requeued, ts.lost),
            None => "-".into(),
        };
        out.push_str(&format!(
            "{:<8} | {:>3} | {:>3} | {:>3} | {:>6} | {:>7} | {:>5} ({:>2}/{:>2})      | {:>4} | {:>6.3} | {:>6.3} | {:>9.1} | {:>9.2} ({:.1}|{:.1})   | {:>4.0}% | {quarantine}\n",
            ts.name,
            ts.submitted,
            ts.admitted,
            ts.rejected,
            ts.served,
            ts.expired,
            ts.waves,
            ts.keyed_waves,
            ts.inline_waves,
            ts.partial_waves,
            ts.p50_latency * 1e3,
            ts.p99_latency * 1e3,
            ts.mean_sojourn_ticks,
            per_wave(ts.offline_msgs_in_waves),
            per_wave(ts.offline_msgs_matmul),
            per_wave(ts.offline_msgs_relu),
            100.0 * ts.waves as f64 / stats.waves.max(1) as f64,
        ));
    }
    out.push_str(&format!(
        "total    : {} waves over {} ticks | {} online rounds | refill online msgs {} | aged promotions {} | quarantines {}\n",
        stats.waves, stats.ticks, stats.online_rounds, stats.refill_online_msgs, stats.aged_promotions,
        stats.quarantines.len(),
    ));
    out
}

/// Multi-tenant serving table (beyond the paper): the scheduler subsystem
/// — per-model keyed pools, deadline/priority queue, weighted-round-robin
/// wave planner — serving two resident models behind one cluster.
pub fn serve_tenants_table() -> String {
    use crate::serve::serve_multi;
    let mut out = String::new();
    out.push_str("== Multi-tenant serving: 3 resident models (1 deep NN-3), WRR 2:1:1, LAN ==\n");
    let stats = serve_multi(NetProfile::lan(), demo_tenants(12));
    out.push_str(&tenant_table(&stats));
    out.push_str(&flame_table(&stats));
    out
}

/// Flame-style per-protocol breakdown derived from the merged four-party
/// trace (falls back to the per-layer meter counters when tracing was
/// off): one row per `(tenant, gate, op)` with the offline-message vs
/// online-compute split at gate granularity — the paper's Table-6 shape
/// projected onto the serving path. The per-op totals reconcile exactly
/// with the `offline_msgs_matmul` / `offline_msgs_relu` meters (asserted
/// at aggregation time whenever the trace is live).
pub fn flame_table(stats: &crate::serve::MultiServeStats) -> String {
    let rollup = stats.op_rollup();
    let mut out = String::new();
    out.push_str(
        "flame: tenant   | gate | op     | waves | off msgs | off msg/wave | online compute ms\n",
    );
    for r in &rollup {
        out.push_str(&format!(
            "flame: {:<8} | {:>4} | {:<6} | {:>5} | {:>8} | {:>12.2} | {:>17.3}\n",
            stats.tenants[r.tenant].name,
            r.gate,
            r.op,
            r.waves,
            r.offline_msgs,
            r.offline_msgs as f64 / r.waves.max(1) as f64,
            r.compute_ns as f64 / 1e6,
        ));
    }
    let tm: u64 = rollup.iter().filter(|r| r.op == "matmul").map(|r| r.offline_msgs).sum();
    let tr: u64 = rollup.iter().filter(|r| r.op == "relu").map(|r| r.offline_msgs).sum();
    out.push_str(&format!(
        "flame: totals = matmul {tm} + relu {tr} = {} offline msgs across {} committed waves\n",
        tm + tr,
        stats.waves,
    ));
    out
}

/// Mixed training+serving table (the schema-6 isolation section in text
/// form): each inference tenant's latency columns alone vs under a
/// saturating scheduled training job, plus the job's epoch throughput.
pub fn train_serve_table() -> String {
    use crate::serve::serve_multi;
    let (alone_cfg, mixed_cfg) = mixed_train_tenants(8);
    let alone = serve_multi(NetProfile::lan(), alone_cfg);
    let mixed = serve_multi(NetProfile::lan(), mixed_cfg.clone());
    let mut out = String::new();
    out.push_str(
        "== Scheduled training as a workload: inference latency under a saturating job (LAN) ==\n",
    );
    out.push_str(
        "tenant   | p50 ms alone | p50 ms mixed | p99 ms alone | p99 ms mixed | p99 delta ms\n",
    );
    for (t, spec) in mixed_cfg.tenants.iter().enumerate() {
        if spec.is_training() {
            continue;
        }
        let (a, m) = (&alone.tenants[t], &mixed.tenants[t]);
        out.push_str(&format!(
            "{:<8} | {:>12.3} | {:>12.3} | {:>12.3} | {:>12.3} | {:>12.3}\n",
            a.name,
            a.p50_latency * 1e3,
            m.p50_latency * 1e3,
            a.p99_latency * 1e3,
            m.p99_latency * 1e3,
            (m.p99_latency - a.p99_latency) * 1e3,
        ));
    }
    for (t, spec) in mixed_cfg.tenants.iter().enumerate() {
        if !spec.is_training() {
            continue;
        }
        let ts = &mixed.tenants[t];
        out.push_str(&format!(
            "job {:<4} : {} epochs committed ({} keyed waves) | {:.2} epochs/s online | {} checkpoints | {} offline msgs in wave windows\n",
            ts.name,
            ts.epochs_committed,
            ts.keyed_waves,
            ts.epochs_committed as f64 / mixed.online_latency.max(1e-9),
            ts.checkpoints.len(),
            ts.offline_msgs_in_waves,
        ));
    }
    out
}

fn json_num_array<T: std::fmt::Display>(v: &[T]) -> String {
    let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable serving benchmark: the mode table and the per-tenant
/// table as one JSON document, so the perf trajectory is trackable across
/// PRs (`BENCH_serving.json` at the repo root; see
/// [`write_serving_bench_json`]). Runs the full benchmark — callers that
/// already hold a [`ServingBench`] should use [`serving_bench_json_from`].
pub fn serving_bench_json() -> String {
    serving_bench_json_from(&run_serving_bench())
}

/// Render the JSON document from a precomputed [`ServingBench`].
///
/// Schema 2 extended schema 1 with the per-wave `compute_ms` /
/// `value_bytes` columns on every mode row and a top-level
/// `offline_fill_throughput` object — the regression-gated numbers for the
/// keystream-batched PRF and the packed/flat hot path. Schema 3 added the
/// containment fields: per-tenant `partial_waves` / `partial_keyed_waves`
/// (the trailing-partial-batch keyed-pool fix) and `quarantined_at` /
/// `requeued` / `lost`, plus a top-level `quarantines` array (empty for
/// the honest benchmark run). Schema 4 (this PR) adds the deep-circuit
/// columns: per-tenant gate-order arrays `off_msgs_matmul_layers` /
/// `off_msgs_relu_layers` (one entry per resident layer, all zero on a
/// warm run) and `pool_left_mat_layers` / `pool_left_relu_layers`
/// (unconsumed keyed bundles per layer shard at shutdown), driven by the
/// resident NN-3 tenant in the canonical workload. Schema 5 (this PR)
/// replaces the hand-maintained `off_msgs_matmul_layers` /
/// `off_msgs_relu_layers` arrays with a trace-derived per-tenant `"ops"`
/// rollup — one object per `(op, gate)` with `waves` / `off_msgs` /
/// `compute_ns`, produced from the merged four-party trace and asserted
/// at aggregation time to reconcile exactly with the offline-message
/// meters (the `pool_left_*` arrays stay). Schema 6 (this PR) adds the
/// scheduled-training section: per-tenant `epochs_committed`, and a
/// top-level `"training"` object with per-job epoch throughput
/// (`epochs_per_s`, `checkpoints`, the job's own offline-silence counter)
/// and the `inference_under_training` isolation columns — each inference
/// tenant's p50/p99 alone vs next to a saturating training job. Schema 7
/// (this PR) adds the 4PC backend family: a top-level `"backends"` array
/// with one measured row per protocol variant (Trident secure-with-abort
/// vs `tetrad-fair` vs `tetrad-god` — the guaranteed-output-delivery
/// failover backend) over the same keyed workload, per-tenant
/// `failover_waves` / `rehabilitated_at` columns, and a top-level
/// `"transitions"` array mirroring `"quarantines"` (both empty for the
/// honest benchmark run).
pub fn serving_bench_json_from(bench: &ServingBench) -> String {
    let mut out = String::from("{\n  \"schema\": \"trident-serving-bench/7\",\n");
    out.push_str(&format!(
        "  \"offline_fill_throughput\": {{\"bitext_masks_per_s\": {:.1}, \"trunc_pairs_per_s\": {:.1}, \"lam_skeletons_per_s\": {:.1}}},\n",
        bench.fill.bitext_masks_per_s, bench.fill.trunc_pairs_per_s, bench.fill.lam_per_s,
    ));
    out.push_str("  \"modes\": [\n");
    let rows = &bench.modes;
    for (i, (name, s)) in rows.iter().enumerate() {
        // the per-op split uses the same per-wave unit as off_msgs_per_wave
        // so mat + relu ≈ total holds row-internally
        let per_wave = |m: u64| m as f64 / s.batches.max(1) as f64;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"queries\": {}, \"batches\": {}, \"online_rounds\": {}, \"ms_per_query\": {:.6}, \"online_bytes_per_query\": {:.1}, \"compute_ms_per_wave\": {:.6}, \"value_bytes_per_wave\": {:.1}, \"offline_kib\": {:.3}, \"off_msgs_per_wave\": {:.3}, \"off_msgs_matmul_per_wave\": {:.3}, \"off_msgs_relu_per_wave\": {:.3}}}{}\n",
            json_escape(name),
            s.queries,
            s.batches,
            s.online_rounds,
            s.per_query_latency() * 1e3,
            s.per_query_online_bytes(),
            s.compute_ms_per_wave(),
            s.value_bytes_per_wave(),
            s.offline_value_bits as f64 / 8.0 / 1024.0,
            per_wave(s.offline_msgs_in_waves),
            per_wave(s.offline_msgs_matmul),
            per_wave(s.offline_msgs_relu),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"backends\": [\n");
    for (i, (name, s)) in bench.backends.iter().enumerate() {
        let ts = &s.tenants[0];
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"served\": {}, \"waves\": {}, \"online_rounds\": {}, \"rounds_per_wave\": {:.3}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \"online_s\": {:.6}, \"off_msgs_in_waves\": {}}}{}\n",
            json_escape(name),
            ts.served,
            s.waves,
            s.online_rounds,
            s.online_rounds as f64 / s.waves.max(1) as f64,
            ts.p50_latency * 1e3,
            ts.p99_latency * 1e3,
            s.online_latency,
            ts.offline_msgs_in_waves,
            if i + 1 < bench.backends.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    let (cfg, stats) = (&bench.tenants_cfg, &bench.tenants);
    let rollup = stats.op_rollup();
    out.push_str("  \"tenants\": [\n");
    for (t, ts) in stats.tenants.iter().enumerate() {
        let spec = &cfg.tenants[t];
        let ops: Vec<String> = rollup
            .iter()
            .filter(|r| r.tenant == t)
            .map(|r| {
                format!(
                    "{{\"op\": \"{}\", \"gate\": {}, \"waves\": {}, \"off_msgs\": {}, \"compute_ns\": {}}}",
                    r.op, r.gate, r.waves, r.offline_msgs, r.compute_ns,
                )
            })
            .collect();
        let ops_json = format!("[{}]", ops.join(", "));
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"weight\": {}, \"class\": {}, \"depth\": {}, \"submitted\": {}, \"admitted\": {}, \"rejected\": {}, \"served\": {}, \"expired\": {}, \"waves\": {}, \"keyed_waves\": {}, \"inline_waves\": {}, \"partial_waves\": {}, \"partial_keyed_waves\": {}, \"quarantined_at\": {}, \"requeued\": {}, \"lost\": {}, \"failover_waves\": {}, \"rehabilitated_at\": {}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \"mean_sojourn_ticks\": {:.3}, \"off_msgs_in_waves\": {}, \"off_msgs_matmul\": {}, \"off_msgs_relu\": {}, \"epochs_committed\": {}, \"ops\": {}, \"pool_left_mat_layers\": {}, \"pool_left_relu_layers\": {}, \"wave_share\": {:.4}}}{}\n",
            json_escape(&ts.name),
            spec.weight,
            spec.class,
            spec.depth(),
            ts.submitted,
            ts.admitted,
            ts.rejected,
            ts.served,
            ts.expired,
            ts.waves,
            ts.keyed_waves,
            ts.inline_waves,
            ts.partial_waves,
            ts.partial_keyed_waves,
            ts.quarantined_at.map_or("null".into(), |t| t.to_string()),
            ts.requeued,
            ts.lost,
            ts.failover_waves,
            ts.rehabilitated_at.map_or("null".into(), |t| t.to_string()),
            ts.p50_latency * 1e3,
            ts.p99_latency * 1e3,
            ts.mean_sojourn_ticks,
            ts.offline_msgs_in_waves,
            ts.offline_msgs_matmul,
            ts.offline_msgs_relu,
            ts.epochs_committed,
            ops_json,
            json_num_array(&ts.pool_left_mat_layers),
            json_num_array(&ts.pool_left_relu_layers),
            ts.waves as f64 / stats.waves.max(1) as f64,
            if t + 1 < stats.tenants.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    // schema 6: the mixed training+serving section — per-job epoch
    // throughput and the inference-p99-under-training isolation columns
    let (mcfg, mixed, alone) = (&bench.train_mixed_cfg, &bench.train_mixed, &bench.train_alone);
    out.push_str("  \"training\": {\n    \"jobs\": [\n");
    let jobs: Vec<usize> =
        (0..mcfg.tenants.len()).filter(|&t| mcfg.tenants[t].is_training()).collect();
    for (i, &t) in jobs.iter().enumerate() {
        let ts = &mixed.tenants[t];
        let (kind, epochs, _, _, _) =
            mcfg.tenants[t].workload.training().expect("training tenant");
        let kind_s = match kind {
            crate::sched::TrainKind::LinReg => "linreg",
            crate::sched::TrainKind::LogReg => "logreg",
            crate::sched::TrainKind::Nn => "nn",
        };
        out.push_str(&format!(
            "      {{\"name\": \"{}\", \"kind\": \"{kind_s}\", \"epochs\": {epochs}, \"epochs_committed\": {}, \"epochs_per_s\": {:.3}, \"checkpoints\": {}, \"keyed_waves\": {}, \"inline_waves\": {}, \"off_msgs_in_waves\": {}}}{}\n",
            json_escape(&ts.name),
            ts.epochs_committed,
            ts.epochs_committed as f64 / mixed.online_latency.max(1e-9),
            ts.checkpoints.len(),
            ts.keyed_waves,
            ts.inline_waves,
            ts.offline_msgs_in_waves,
            if i + 1 < jobs.len() { "," } else { "" },
        ));
    }
    out.push_str("    ],\n    \"inference_under_training\": [\n");
    let inf: Vec<usize> =
        (0..alone.tenants.len()).filter(|&t| !mcfg.tenants[t].is_training()).collect();
    for (i, &t) in inf.iter().enumerate() {
        let (a, m) = (&alone.tenants[t], &mixed.tenants[t]);
        out.push_str(&format!(
            "      {{\"name\": \"{}\", \"p50_ms_alone\": {:.6}, \"p50_ms_under_training\": {:.6}, \"p99_ms_alone\": {:.6}, \"p99_ms_under_training\": {:.6}, \"p99_delta_ms\": {:.6}}}{}\n",
            json_escape(&a.name),
            a.p50_latency * 1e3,
            m.p50_latency * 1e3,
            a.p99_latency * 1e3,
            m.p99_latency * 1e3,
            (m.p99_latency - a.p99_latency) * 1e3,
            if i + 1 < inf.len() { "," } else { "" },
        ));
    }
    out.push_str("    ]\n  },\n");
    out.push_str("  \"quarantines\": [\n");
    for (i, q) in stats.quarantines.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"tenant\": {}, \"at_tick\": {}, \"requeued\": {}, \"lost\": {}, \"drained_mat\": {}, \"drained_relu\": {}, \"why\": \"{}\"}}{}\n",
            q.tenant,
            q.at_tick,
            q.requeued,
            q.lost,
            q.drained_mat,
            q.drained_relu,
            json_escape(&q.why),
            if i + 1 < stats.quarantines.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"transitions\": [\n");
    for (i, tr) in stats.transitions.iter().enumerate() {
        let kind = match tr.kind {
            crate::serve::TransitionKind::Failover => "failover",
            crate::serve::TransitionKind::Rehab => "rehab",
        };
        out.push_str(&format!(
            "    {{\"tenant\": {}, \"at_tick\": {}, \"wave\": {}, \"kind\": \"{kind}\"}}{}\n",
            tr.tenant,
            tr.at_tick,
            tr.wave,
            if i + 1 < stats.transitions.len() { "," } else { "" },
        ));
    }
    out.push_str("  ],\n");
    out.push_str(&format!(
        "  \"totals\": {{\"waves\": {}, \"ticks\": {}, \"online_rounds\": {}, \"offline_msgs_in_waves\": {}, \"offline_msgs_matmul\": {}, \"offline_msgs_relu\": {}, \"refill_online_msgs\": {}, \"aged_promotions\": {}}}\n",
        stats.waves,
        stats.ticks,
        stats.online_rounds,
        stats.offline_msgs_in_waves,
        stats.offline_msgs_matmul,
        stats.offline_msgs_relu,
        stats.refill_online_msgs,
        stats.aged_promotions,
    ));
    out.push_str("}\n");
    out
}

/// Run the serving benchmarks and write the JSON document to `path`
/// (`BENCH_serving.json` at the repo root by convention). Returns the JSON.
pub fn write_serving_bench_json(path: &str) -> std::io::Result<String> {
    write_serving_bench_json_from(&run_serving_bench(), path)
}

/// Write the JSON document for a precomputed [`ServingBench`] to `path`.
pub fn write_serving_bench_json_from(
    bench: &ServingBench,
    path: &str,
) -> std::io::Result<String> {
    let json = serving_bench_json_from(bench);
    std::fs::write(path, &json)?;
    Ok(json)
}

/// All tables, in paper order. `filter`: empty = all.
pub fn run_tables(filter: &[String]) -> String {
    let all: Vec<(&str, fn() -> String)> = vec![
        ("table1", || table1_9()),
        ("table2", || table2_10()),
        ("table3", table3),
        ("table4", || table4_5(false)),
        ("table5", || table4_5(true)),
        ("table6", table6),
        ("table7", table7),
        ("table8", || table8_15()),
        ("table9", || table1_9()),
        ("table10", || table2_10()),
        ("table11", table11),
        ("table12", table12),
        ("table13", || table13_14()),
        ("table14", || table13_14()),
        ("table15", || table8_15()),
        ("fig20", fig20),
        ("serve", serve_table),
        ("serve-tenants", serve_tenants_table),
        ("serve-train", train_serve_table),
    ];
    let mut out = String::new();
    let mut done = std::collections::HashSet::new();
    for (name, f) in all {
        if !filter.is_empty() && !filter.iter().any(|x| x == name) {
            continue;
        }
        // aliased tables print once
        let key = f as usize;
        if !done.insert(key) {
            continue;
        }
        out.push_str(&f());
        out.push('\n');
    }
    out
}
