//! The Garbled World (paper §IV-A): MRZ-style garbling in the 4PC setting —
//! `P1, P2, P3` are the garblers (sharing all garbling randomness through
//! their triple key `P \ {P0}`), `P0` is the sole evaluator.
//!
//! Submodules: [`circuit`] (boolean circuits + builders), [`garble`]
//! (half-gates/free-XOR/fixed-key-AES), and the 4PC protocols below
//! (`Π_Sh^G`, `Π_vSh^G`, garbled evaluation, reconstruction).

pub mod circuit;
pub mod garble;

use crate::crypto::{Commitment, Key};
use crate::net::{Abort, MsgClass, PartyId, P0, P1, P2, P3};
use crate::ring::Bit;
use crate::setup::Scope;

use crate::proto::Ctx;
use circuit::Circuit;
use garble::{active_label, evaluate, garble, output_k0, GarbledCircuit};

/// A party's `[[·]]^G`-share of one bit: garblers hold the zero-label `K⁰`,
/// the evaluator holds the active label `K^v`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GShare {
    Garbler(Key),
    Evaluator(Key),
}

impl GShare {
    pub fn key(&self) -> Key {
        match self {
            GShare::Garbler(k) | GShare::Evaluator(k) => *k,
        }
    }
}

/// The garblers' shared global offset `R` (lsb 1), drawn eagerly at context
/// creation from the `P\{P0}` triple key (see `Ctx::new`).
pub fn offset(ctx: &mut Ctx) -> Key {
    ctx.gc_offset.expect("P0 never learns R")
}

/// Garblers jointly sample a fresh zero-label.
fn fresh_k0(ctx: &mut Ctx) -> Key {
    ctx.keys.sample_key(Scope::Excl(P0))
}

/// Garblers jointly sample commitment randomness / permutation bits.
fn shared_rand(ctx: &mut Ctx) -> Key {
    ctx.keys.sample_key(Scope::Excl(P0))
}

fn xor_key(a: Key, b: Key) -> Key {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a[i] ^ b[i];
    }
    out
}

/// `Π_Sh^G(P_i, v)` for a garbler dealer (Fig. 6), batched over bits.
/// Offline: garblers agree on `K⁰`; P1, P2 commit to `{K⁰, K¹}` towards P0
/// in a random permuted order. Online: the dealer sends the active key plus
/// the decommitment; P0 verifies. Amortized online cost: κ bits per bit
/// shared (Lemma C.2).
pub fn g_share(
    ctx: &mut Ctx,
    dealer: PartyId,
    bits: Option<&[Bit]>,
    n: usize,
) -> Result<Vec<GShare>, Abort> {
    assert!(dealer.is_evaluator(), "use g_share_p0 for a P0 dealer");
    let me = ctx.id();
    if me == dealer {
        assert_eq!(bits.unwrap().len(), n);
    }

    // offline: labels + commitments
    let offline_state = ctx.offline(|ctx| {
        if me.is_evaluator() {
            let r = offset(ctx);
            let mut k0s = Vec::with_capacity(n);
            let mut material = Vec::new(); // (rand0, rand1, perm)
            for _ in 0..n {
                let k0 = fresh_k0(ctx);
                let k1 = xor_key(k0, r);
                let r0 = shared_rand(ctx);
                let r1 = shared_rand(ctx);
                let perm = shared_rand(ctx)[0] & 1 == 1;
                let c0 = Commitment::commit(&k0, &r0);
                let c1 = Commitment::commit(&k1, &r1);
                let (first, second) = if perm { (c1.clone(), c0.clone()) } else { (c0, c1) };
                if me == P1 || me == P2 {
                    // both send the permuted commitment pair to P0
                    let mut buf = Vec::with_capacity(64);
                    buf.extend_from_slice(&first.0);
                    buf.extend_from_slice(&second.0);
                    ctx.net.send(P0, &buf, MsgClass::Commit);
                }
                k0s.push(k0);
                material.push((r0, r1, perm));
            }
            Ok::<_, Abort>((k0s, material, Vec::new()))
        } else {
            // P0: receive and cross-check the commitment pairs
            let mut pairs = Vec::with_capacity(n);
            for _ in 0..n {
                let a = ctx.net.recv(P1)?;
                let b = ctx.net.recv(P2)?;
                if a != b {
                    return Err(ctx.net.abort("Π_Sh^G: commitment mismatch P1 vs P2".into()));
                }
                pairs.push(a);
            }
            Ok((Vec::new(), Vec::new(), pairs))
        }
    })?;
    let (k0s, material, commit_pairs) = offline_state;

    // online: dealer delivers active keys + decommitments
    ctx.online(|ctx| {
        if me == dealer {
            let r = offset(ctx);
            let bits = bits.unwrap();
            for i in 0..n {
                let kv = active_label(k0s[i], r, bits[i]);
                // key travels as value traffic (κ bits), decommitment as
                // amortized commitment traffic
                ctx.net.send_with_bits(P0, &kv, MsgClass::Value, 128);
                let (r0, r1, _) = material[i];
                let rb = if bits[i].0 { r1 } else { r0 };
                ctx.net.send(P0, &rb, MsgClass::Commit);
            }
        }
        if me == P0 {
            let mut out = Vec::with_capacity(n);
            for pair in commit_pairs.iter().take(n) {
                let kv = ctx.net.recv(dealer)?;
                let rb = ctx.net.recv(dealer)?;
                let mut key = [0u8; 16];
                key.copy_from_slice(&kv);
                let mut rand = [0u8; 16];
                rand.copy_from_slice(&rb);
                let com = Commitment::commit(&key, &rand);
                let c_first: &[u8] = &pair[..32];
                let c_second: &[u8] = &pair[32..];
                if com.0.as_slice() != c_first && com.0.as_slice() != c_second {
                    return Err(ctx
                        .net
                        .abort("Π_Sh^G: decommitment does not open either commitment".into()));
                }
                out.push(GShare::Evaluator(key));
            }
            return Ok(out);
        }
        Ok(k0s.into_iter().map(GShare::Garbler).collect())
    })
}

/// `Π_Sh^G(P0, v)`: P0 splits `v = v1 ⊕ v2`, hands `v1`/`v2` to P1/P2, who
/// then `Π_Sh^G` them; shares combine by free XOR (Fig. 6 text).
pub fn g_share_p0(ctx: &mut Ctx, bits: Option<&[Bit]>, n: usize) -> Result<Vec<GShare>, Abort> {
    let me = ctx.id();
    // P0 → v1 to P1, v2 to P2 (online: these depend on the data)
    let (v1, v2) = ctx.online(|ctx| {
        match me {
            P0 => {
                let bits = bits.expect("P0 supplies bits");
                assert_eq!(bits.len(), n, "dealer must supply exactly n bits");
                let mut b1s: Vec<Bit> = Vec::with_capacity(n);
                let mut b2s: Vec<Bit> = Vec::with_capacity(n);
                for &b in bits {
                    let b1 = Bit(ctx.rng.next_u64() & 1 == 1);
                    b1s.push(b1);
                    b2s.push(b + b1);
                }
                // packed boolean deliveries: ⌈n/8⌉ payload bytes each,
                // still metered as n analytic bits
                ctx.send_bits(P1, &b1s);
                ctx.send_bits(P2, &b2s);
                Ok::<_, Abort>((Some(b1s), Some(b2s)))
            }
            P1 => Ok((Some(ctx.recv_bits(P0, n)?), None)),
            P2 => Ok((None, Some(ctx.recv_bits(P0, n)?))),
            _ => Ok((None, None)),
        }
    })?;
    let s1 = g_share(ctx, P1, v1.as_deref(), n)?;
    let s2 = g_share(ctx, P2, v2.as_deref(), n)?;
    Ok(s1.iter().zip(s2.iter()).map(|(a, b)| g_xor(a, b)).collect())
}

/// `Π_vSh^G(P_i, P_j, v)` (Fig. 8): verifiable garbled sharing by two
/// owners. Amortized online cost κ bits.
pub fn g_vsh(
    ctx: &mut Ctx,
    (pi, pj): (PartyId, PartyId),
    bits: Option<&[Bit]>,
    n: usize,
) -> Result<Vec<GShare>, Abort> {
    assert!(pi.is_evaluator(), "P_i must be a garbler");
    let me = ctx.id();
    let k0s: Vec<Key> = ctx.offline(|ctx| {
        if me.is_evaluator() {
            (0..n).map(|_| fresh_k0(ctx)).collect()
        } else {
            Vec::new()
        }
    });

    (|ctx: &mut Ctx| {
        if pj == P0 {
            // (P_k, P0): P_k and its next garbler send ordered commitments;
            // P_k additionally decommits the actual key.
            let helper = if pi == P3 { P1 } else { PartyId(pi.0 + 1) };
            if me == pi || me == helper {
                let r = offset(ctx);
                for (i, &k0) in k0s.iter().enumerate() {
                    let k1 = xor_key(k0, r);
                    let r0 = shared_rand(ctx);
                    let r1 = shared_rand(ctx);
                    let c0 = Commitment::commit(&k0, &r0);
                    let c1 = Commitment::commit(&k1, &r1);
                    let mut buf = Vec::with_capacity(64);
                    buf.extend_from_slice(&c0.0);
                    buf.extend_from_slice(&c1.0);
                    ctx.net.send(P0, &buf, MsgClass::Commit);
                    if me == pi {
                        let b = bits.unwrap()[i];
                        let kv = active_label(k0, r, b);
                        ctx.net.send_with_bits(P0, &kv, MsgClass::Value, 128);
                        ctx.net.send(P0, if b.0 { &r1 } else { &r0 }, MsgClass::Commit);
                    }
                }
                if me != pi && me != helper {
                    unreachable!();
                }
            } else if me.is_evaluator() {
                // third garbler: still consume the shared randomness so the
                // Excl(P0) streams stay aligned
                let _ = offset(ctx);
                for _ in 0..n {
                    let _ = shared_rand(ctx);
                    let _ = shared_rand(ctx);
                }
            }
            if me == P0 {
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let a = ctx.net.recv(pi)?;
                    let kv = ctx.net.recv(pi)?;
                    let rb = ctx.net.recv(pi)?;
                    let b = ctx.net.recv(helper)?;
                    if a != b {
                        return Err(ctx.net.abort("Π_vSh^G: ordered commitments differ".into()));
                    }
                    let mut key = [0u8; 16];
                    key.copy_from_slice(&kv);
                    let mut rand = [0u8; 16];
                    rand.copy_from_slice(&rb);
                    let com = Commitment::commit(&key, &rand);
                    if com.0.as_slice() != &a[..32] && com.0.as_slice() != &a[32..] {
                        return Err(ctx.net.abort("Π_vSh^G: bad decommitment".into()));
                    }
                    out.push(GShare::Evaluator(key));
                }
                return Ok(out);
            }
        } else {
            // both owners are garblers: P_i sends K^v, P_j vouches H(K^v)
            if me == pi || me == pj {
                let r = offset(ctx);
                let bits = bits.unwrap();
                for (i, &k0) in k0s.iter().enumerate() {
                    let kv = active_label(k0, r, bits[i]);
                    if me == pi {
                        ctx.net.send_with_bits(P0, &kv, MsgClass::Value, 128);
                    } else {
                        ctx.vouch_bytes(P0, &kv);
                    }
                }
            } else if me.is_evaluator() {
                let _ = offset(ctx);
            }
            if me == P0 {
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    let kv = ctx.net.recv(pi)?;
                    ctx.expect_bytes(pj, &kv);
                    let mut key = [0u8; 16];
                    key.copy_from_slice(&kv);
                    out.push(GShare::Evaluator(key));
                }
                return Ok(out);
            }
        }
        Ok(k0s.into_iter().map(GShare::Garbler).collect())
    })(ctx)
}

/// Free XOR of two garbled shares (both roles).
pub fn g_xor(a: &GShare, b: &GShare) -> GShare {
    match (a, b) {
        (GShare::Garbler(x), GShare::Garbler(y)) => GShare::Garbler(xor_key(*x, *y)),
        (GShare::Evaluator(x), GShare::Evaluator(y)) => GShare::Evaluator(xor_key(*x, *y)),
        _ => panic!("mixed garbled share roles"),
    }
}

/// NOT of a garbled share: garblers offset `K⁰` by `R`; P0's active label is
/// unchanged (it now encodes the complement).
pub fn g_not(ctx: &mut Ctx, a: &GShare) -> GShare {
    match a {
        GShare::Garbler(x) => GShare::Garbler(xor_key(*x, offset(ctx))),
        GShare::Evaluator(x) => GShare::Evaluator(*x),
    }
}

/// Garbled evaluation of `circuit` on shared inputs. Garblers derive the
/// (identical) tables; P1 ships them to P0 (offline — they are
/// data-independent), P2 vouches their hash; P0 evaluates online.
pub fn g_eval(ctx: &mut Ctx, circuit: &Circuit, inputs: &[GShare]) -> Result<Vec<GShare>, Abort> {
    assert_eq!(inputs.len(), circuit.n_inputs);
    let me = ctx.id();
    if me.is_evaluator() {
        let r = offset(ctx);
        let input_k0: Vec<Key> = inputs.iter().map(|s| s.key()).collect();
        let g = ctx.net.timed(|| garble(circuit, r, &input_k0));
        ctx.offline(|ctx| {
            let bytes = g.gc.to_bytes();
            match me {
                P1 => ctx.net.send(P0, &bytes, MsgClass::Garbled),
                P2 => ctx.vouch_bytes(P0, &bytes),
                _ => {}
            }
        });
        Ok(output_k0(circuit, &g).into_iter().map(GShare::Garbler).collect())
    } else {
        let gc = ctx.offline(|ctx| -> Result<GarbledCircuit, Abort> {
            let bytes = if circuit.and_count() > 0 { ctx.net.recv(P1)? } else { Vec::new() };
            ctx.expect_bytes(P2, &bytes);
            GarbledCircuit::from_bytes(&bytes)
                .ok_or_else(|| ctx.net.abort("malformed garbled circuit".into()))
        })?;
        ctx.online(|ctx| {
            let active: Vec<Key> = inputs.iter().map(|s| s.key()).collect();
            let out = ctx.net.timed(|| evaluate(circuit, &gc, &active));
            Ok(out.into_iter().map(GShare::Evaluator).collect())
        })
    }
}

/// Reconstruct garbled-shared bits towards `target`.
///
/// * towards P0: P1 and P2 both send the colour bit `lsb(K⁰)`; P0 compares
///   and decodes `v = lsb(K^v) ⊕ lsb(K⁰)`.
/// * towards a garbler: P0 sends its active labels (authenticity of the
///   garbling scheme makes lying infeasible); the garbler matches against
///   `{K⁰, K¹}`.
pub fn g_reconstruct(
    ctx: &mut Ctx,
    shares: &[GShare],
    target: PartyId,
) -> Result<Option<Vec<Bit>>, Abort> {
    let me = ctx.id();
    let n = shares.len();
    ctx.online(|ctx| {
        if target == P0 {
            if me == P1 || me == P2 {
                // colour bits packed 8/byte; metered as n analytic bits
                let colors: Vec<Bit> =
                    shares.iter().map(|s| Bit(s.key()[0] & 1 == 1)).collect();
                ctx.send_bits(P0, &colors);
            }
            if me == P0 {
                let c1 = ctx.recv_bits(P1, n)?;
                let c2 = ctx.recv_bits(P2, n)?;
                if c1 != c2 {
                    return Err(ctx.net.abort("garbled reconstruction: colour bits differ".into()));
                }
                let out = shares
                    .iter()
                    .zip(c1)
                    .map(|(s, c)| Bit((s.key()[0] & 1 == 1) != c.0))
                    .collect();
                return Ok(Some(out));
            }
            Ok(None)
        } else {
            if me == P0 {
                let mut buf = Vec::with_capacity(16 * n);
                for s in shares {
                    buf.extend_from_slice(&s.key());
                }
                ctx.net.send_with_bits(target, &buf, MsgClass::Value, (128 * n) as u64);
            }
            if me == target {
                let buf = ctx.net.recv(P0)?;
                if buf.len() != 16 * n {
                    return Err(ctx.net.abort("garbled reconstruction: short keys".into()));
                }
                let r = offset(ctx);
                let mut out = Vec::with_capacity(n);
                for (i, s) in shares.iter().enumerate() {
                    let mut kv = [0u8; 16];
                    kv.copy_from_slice(&buf[16 * i..16 * (i + 1)]);
                    let k0 = s.key();
                    let k1 = xor_key(k0, r);
                    if kv == k0 {
                        out.push(Bit(false));
                    } else if kv == k1 {
                        out.push(Bit(true));
                    } else {
                        return Err(ctx
                            .net
                            .abort("garbled reconstruction: invalid active label".into()));
                    }
                }
                return Ok(Some(out));
            }
            if me.is_evaluator() {
                let _ = offset(ctx);
            }
            Ok(None)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::circuit::{adder, bits_u64, subtractor, u64_bits};
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, run_4pc_timeout};

    #[test]
    fn g_share_and_reconstruct_roundtrip() {
        for dealer in [P1, P2, P3] {
            let run = run_4pc(NetProfile::zero(), 90, move |ctx| {
                let bits = vec![Bit(true), Bit(false), Bit(true)];
                let shares =
                    g_share(ctx, dealer, (ctx.id() == dealer).then_some(&bits[..]), 3)?;
                let out = g_reconstruct(ctx, &shares, P0)?;
                ctx.flush_verify()?;
                Ok(out)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(outs[0], Some(vec![Bit(true), Bit(false), Bit(true)]), "dealer {dealer}");
        }
    }

    #[test]
    fn g_share_p0_roundtrip() {
        let run = run_4pc(NetProfile::zero(), 91, |ctx| {
            let bits = vec![Bit(true), Bit(true), Bit(false), Bit(true)];
            let shares = g_share_p0(ctx, (ctx.id() == P0).then_some(&bits[..]), 4)?;
            // reconstruct towards a garbler (tests authenticity path)
            let out = g_reconstruct(ctx, &shares, P3)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[3], Some(vec![Bit(true), Bit(true), Bit(false), Bit(true)]));
    }

    #[test]
    fn g_vsh_garbler_pair() {
        let run = run_4pc(NetProfile::zero(), 92, |ctx| {
            let bits = vec![Bit(false), Bit(true)];
            let own = ctx.id() == P1 || ctx.id() == P3;
            let shares = g_vsh(ctx, (P1, P3), own.then_some(&bits[..]), 2)?;
            let out = g_reconstruct(ctx, &shares, P0)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[0], Some(vec![Bit(false), Bit(true)]));
    }

    #[test]
    fn g_vsh_with_p0() {
        let run = run_4pc(NetProfile::zero(), 93, |ctx| {
            let bits = vec![Bit(true)];
            let own = ctx.id() == P2 || ctx.id() == P0;
            let shares = g_vsh(ctx, (P2, P0), own.then_some(&bits[..]), 1)?;
            let out = g_reconstruct(ctx, &shares, P1)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[1], Some(vec![Bit(true)]));
    }

    #[test]
    fn garbled_adder_end_to_end() {
        let run = run_4pc(NetProfile::zero(), 94, |ctx| {
            let x = 123456789u64;
            let y = 987654321u64;
            let xb = u64_bits(x, 64);
            let yb = u64_bits(y, 64);
            let xs = g_share(ctx, P1, (ctx.id() == P1).then_some(&xb[..]), 64)?;
            let ys = g_share(ctx, P2, (ctx.id() == P2).then_some(&yb[..]), 64)?;
            let circuit = adder(64);
            let mut inputs = xs;
            inputs.extend(ys);
            let outs = g_eval(ctx, &circuit, &inputs)?;
            let v = g_reconstruct(ctx, &outs, P0)?;
            ctx.flush_verify()?;
            Ok(v)
        });
        let (outs, report) = run.expect_ok();
        let bits = outs[0].clone().unwrap();
        assert_eq!(bits_u64(&bits), 123456789 + 987654321);
        // garbled tables travel offline: 63 ANDs × 32 bytes
        assert_eq!(report.garbled_bytes[0], 63 * 32);
    }

    #[test]
    fn garbled_subtractor_to_garbler() {
        let run = run_4pc(NetProfile::zero(), 95, |ctx| {
            let x = 1000u64;
            let y = 2024u64;
            let xb = u64_bits(x, 64);
            let yb = u64_bits(y, 64);
            let xs = g_share(ctx, P3, (ctx.id() == P3).then_some(&xb[..]), 64)?;
            let ys = g_share(ctx, P1, (ctx.id() == P1).then_some(&yb[..]), 64)?;
            let circuit = subtractor(64);
            let mut inputs = xs;
            inputs.extend(ys);
            let outs = g_eval(ctx, &circuit, &inputs)?;
            let v = g_reconstruct(ctx, &outs, P2)?;
            ctx.flush_verify()?;
            Ok(v)
        });
        let (outs, _) = run.expect_ok();
        let bits = outs[2].clone().unwrap();
        assert_eq!(bits_u64(&bits), 1000u64.wrapping_sub(2024));
    }

    #[test]
    fn malicious_p1_bad_table_detected() {
        // P1 ships a corrupted garbled table; P2's vouched hash catches it
        let run = run_4pc_timeout(
            NetProfile::zero(),
            96,
            std::time::Duration::from_millis(500),
            |ctx| {
                let xb = vec![Bit(true)];
                let yb = vec![Bit(false)];
                let xs = g_share(ctx, P1, (ctx.id() == P1).then_some(&xb[..]), 1)?;
                let ys = g_share(ctx, P2, (ctx.id() == P2).then_some(&yb[..]), 1)?;
                let mut circuit = crate::gc::circuit::Builder::new(2);
                let o = circuit.and(0, 1);
                let circuit = circuit.finish(vec![o]);
                let inputs = vec![xs[0], ys[0]];
                if ctx.id() == P1 {
                    // garble honestly then corrupt the shipped bytes
                    let r = offset(ctx);
                    let g = garble(&circuit, r, &[inputs[0].key(), inputs[1].key()]);
                    let mut bytes = g.gc.to_bytes();
                    bytes[3] ^= 0xFF;
                    ctx.offline(|ctx| ctx.net.send(P0, &bytes, MsgClass::Garbled));
                    ctx.flush_verify()?;
                    return Ok(());
                }
                let outs = g_eval(ctx, &circuit, &inputs)?;
                ctx.flush_verify()?;
                let _ = outs;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "corrupted garbled table must be caught");
    }

    #[test]
    fn malicious_p0_wrong_label_rejected() {
        // P0 sends a fabricated key during reconstruction to a garbler
        let run = run_4pc_timeout(
            NetProfile::zero(),
            97,
            std::time::Duration::from_millis(500),
            |ctx| {
                let bits = vec![Bit(true)];
                let shares = g_share(ctx, P1, (ctx.id() == P1).then_some(&bits[..]), 1)?;
                if ctx.id() == P0 {
                    // fabricate a label
                    ctx.online(|ctx| {
                        ctx.net.send_with_bits(P2, &[0xABu8; 16], MsgClass::Value, 128);
                    });
                    ctx.flush_verify()?;
                    return Ok(());
                }
                let out = g_reconstruct(ctx, &shares, P2)?;
                ctx.flush_verify()?;
                let _ = out;
                Ok(())
            },
        );
        assert!(
            run.outputs[2].is_err(),
            "P2 must reject an unauthenticated active label"
        );
    }
}
