//! Half-gates garbling with free-XOR and fixed-key-AES hashing
//! (§IV-A: "free XOR, half-gates, fixed-key AES garbling").
//!
//! * Labels are 128-bit; the global offset `R` has lsb 1 (point-and-permute
//!   colour bit).
//! * XOR/NOT are free; each AND emits two ciphertexts (generator +
//!   evaluator half, Zahur–Rosulek–Evans).
//! * `H(K, t) = AES_k0(2K ⊕ t) ⊕ 2K` — the fixed-key construction of
//!   Bellare et al., with doubling in GF(2^128).
//!
//! Garbling is **deterministic** given `(R, input labels, gate tweaks)`:
//! the three garblers derive identical tables from their shared randomness,
//! which is what lets P2 verify P1's tables with a single hash (Fig. 6).

use std::sync::OnceLock;

use crate::crypto::aes128::Aes128;
use crate::crypto::Key;
use crate::ring::Bit;

use super::circuit::{Circuit, Gate};

/// Fixed AES key for the garbling hash (public constant).
static FIXED_AES: OnceLock<Aes128> = OnceLock::new();

#[inline]
fn fixed_aes() -> &'static Aes128 {
    FIXED_AES.get_or_init(|| Aes128::new([0x5Au8; 16]))
}

#[inline]
fn xor(a: Key, b: Key) -> Key {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a[i] ^ b[i];
    }
    out
}

#[inline]
fn lsb(k: Key) -> bool {
    k[0] & 1 == 1
}

/// Doubling in GF(2^128) (little-endian byte order, x^128 + x^7 + x^2 + x + 1).
#[inline]
fn double(k: Key) -> Key {
    let mut v = u128::from_le_bytes(k);
    let carry = v >> 127;
    v <<= 1;
    if carry == 1 {
        v ^= 0x87;
    }
    v.to_le_bytes()
}

/// The garbling hash `H(K, t)`.
#[inline]
pub fn gc_hash(k: Key, tweak: u64) -> Key {
    let dk = double(k);
    let mut block = dk;
    block[8..].iter_mut().zip(tweak.to_le_bytes()).for_each(|(b, t)| *b ^= t);
    let out = fixed_aes().encrypt_block(block);
    xor(out, dk)
}

/// One garbled AND gate: the two half-gate ciphertexts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AndTable {
    pub tg: Key,
    pub te: Key,
}

/// The garbled circuit: AND tables in gate order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GarbledCircuit {
    pub tables: Vec<AndTable>,
}

impl GarbledCircuit {
    /// Serialized size in bytes (what travels P1 → P0).
    pub fn wire_bytes(&self) -> usize {
        self.tables.len() * 32
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        for t in &self.tables {
            out.extend_from_slice(&t.tg);
            out.extend_from_slice(&t.te);
        }
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Option<GarbledCircuit> {
        if buf.len() % 32 != 0 {
            return None;
        }
        let tables = buf
            .chunks_exact(32)
            .map(|c| {
                let mut tg = [0u8; 16];
                let mut te = [0u8; 16];
                tg.copy_from_slice(&c[..16]);
                te.copy_from_slice(&c[16..]);
                AndTable { tg, te }
            })
            .collect();
        Some(GarbledCircuit { tables })
    }
}

/// Garbler output: tables + all zero-labels (K⁰ per wire).
pub struct Garbling {
    pub gc: GarbledCircuit,
    /// K⁰ for every wire (inputs + gate outputs).
    pub k0: Vec<Key>,
}

/// Garble `circuit` with global offset `r` (lsb forced to 1) and the given
/// input zero-labels. Deterministic.
pub fn garble(circuit: &Circuit, r: Key, input_k0: &[Key]) -> Garbling {
    assert_eq!(input_k0.len(), circuit.n_inputs);
    let mut r = r;
    r[0] |= 1;
    let mut k0: Vec<Key> = Vec::with_capacity(circuit.n_wires());
    k0.extend_from_slice(input_k0);
    let mut tables = Vec::with_capacity(circuit.and_count());
    for (g, gate) in circuit.gates.iter().enumerate() {
        let w = match *gate {
            Gate::Xor(a, b) => xor(k0[a as usize], k0[b as usize]),
            Gate::Not(a) => xor(k0[a as usize], r),
            Gate::And(a, b) => {
                let a0 = k0[a as usize];
                let b0 = k0[b as usize];
                let a1 = xor(a0, r);
                let b1 = xor(b0, r);
                let pa = lsb(a0);
                let pb = lsb(b0);
                let t1 = (2 * g) as u64;
                let t2 = (2 * g + 1) as u64;
                // generator half
                let mut tg = xor(gc_hash(a0, t1), gc_hash(a1, t1));
                if pb {
                    tg = xor(tg, r);
                }
                let mut wg = gc_hash(a0, t1);
                if pa {
                    wg = xor(wg, tg);
                }
                // evaluator half
                let te = xor(xor(gc_hash(b0, t2), gc_hash(b1, t2)), a0);
                let mut we = gc_hash(b0, t2);
                if pb {
                    we = xor(we, xor(te, a0));
                }
                tables.push(AndTable { tg, te });
                xor(wg, we)
            }
        };
        k0.push(w);
    }
    Garbling { gc: GarbledCircuit { tables }, k0 }
}

/// Evaluate a garbled circuit on active input labels.
pub fn evaluate(circuit: &Circuit, gc: &GarbledCircuit, active_inputs: &[Key]) -> Vec<Key> {
    assert_eq!(active_inputs.len(), circuit.n_inputs);
    let mut active: Vec<Key> = Vec::with_capacity(circuit.n_wires());
    active.extend_from_slice(active_inputs);
    let mut and_idx = 0usize;
    for (g, gate) in circuit.gates.iter().enumerate() {
        let w = match *gate {
            Gate::Xor(a, b) => xor(active[a as usize], active[b as usize]),
            Gate::Not(a) => active[a as usize], // label moves to the other logical value implicitly
            Gate::And(a, b) => {
                let wa = active[a as usize];
                let wb = active[b as usize];
                let sa = lsb(wa);
                let sb = lsb(wb);
                let t1 = (2 * g) as u64;
                let t2 = (2 * g + 1) as u64;
                let tab = gc.tables[and_idx];
                and_idx += 1;
                let mut wg = gc_hash(wa, t1);
                if sa {
                    wg = xor(wg, tab.tg);
                }
                let mut we = gc_hash(wb, t2);
                if sb {
                    we = xor(we, xor(tab.te, wa));
                }
                xor(wg, we)
            }
        };
        active.push(w);
    }
    circuit.outputs.iter().map(|&o| active[o as usize]).collect()
}

/// Decode an active output label given the zero label: the colour bits
/// (lsb) differ iff the value is 1 (lsb(R) = 1).
pub fn decode(active: Key, k0: Key) -> Bit {
    Bit(lsb(active) != lsb(k0))
}

/// Active label for value `b` given zero-label and offset.
pub fn active_label(k0: Key, r: Key, b: Bit) -> Key {
    let mut r = r;
    r[0] |= 1;
    if b.0 {
        xor(k0, r)
    } else {
        k0
    }
}

/// K⁰ for the circuit outputs of a garbling.
pub fn output_k0(circuit: &Circuit, g: &Garbling) -> Vec<Key> {
    circuit.outputs.iter().map(|&o| g.k0[o as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::gc::circuit::{adder, aes_shaped, bits_u64, subtractor, u64_bits, Builder};

    fn rand_key(rng: &mut Rng) -> Key {
        rng.gen_key()
    }

    fn garble_eval_roundtrip(c: &Circuit, inputs: &[Bit], rng: &mut Rng) -> Vec<Bit> {
        let r = rand_key(rng);
        let input_k0: Vec<Key> = (0..c.n_inputs).map(|_| rand_key(rng)).collect();
        let g = garble(c, r, &input_k0);
        let active: Vec<Key> =
            inputs.iter().zip(&input_k0).map(|(&b, &k0)| active_label(k0, r, b)).collect();
        let out_active = evaluate(c, &g.gc, &active);
        let out_k0 = output_k0(c, &g);
        out_active.iter().zip(out_k0).map(|(&a, k0)| decode(a, k0)).collect()
    }

    use super::super::circuit::Circuit;

    #[test]
    fn and_gate_truth_table() {
        let mut b = Builder::new(2);
        let o = b.and(0, 1);
        let c = b.finish(vec![o]);
        let mut rng = Rng::seeded(80);
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = garble_eval_roundtrip(&c, &[Bit(x), Bit(y)], &mut rng);
            assert_eq!(out[0], Bit(x && y), "{x} AND {y}");
        }
    }

    #[test]
    fn xor_not_free_gates() {
        let mut b = Builder::new(2);
        let x = b.xor(0, 1);
        let n = b.not(x);
        let c = b.finish(vec![x, n]);
        assert_eq!(c.and_count(), 0);
        let mut rng = Rng::seeded(81);
        for (x, y) in [(false, false), (false, true), (true, false), (true, true)] {
            let out = garble_eval_roundtrip(&c, &[Bit(x), Bit(y)], &mut rng);
            assert_eq!(out[0], Bit(x ^ y));
            assert_eq!(out[1], Bit(!(x ^ y)));
        }
    }

    #[test]
    fn garbled_adder_matches_clear() {
        let c = adder(64);
        let mut rng = Rng::seeded(82);
        for _ in 0..10 {
            let x = rng.next_u64();
            let y = rng.next_u64();
            let mut input = u64_bits(x, 64);
            input.extend(u64_bits(y, 64));
            let out = garble_eval_roundtrip(&c, &input, &mut rng);
            assert_eq!(bits_u64(&out), x.wrapping_add(y));
        }
    }

    #[test]
    fn garbled_subtractor_matches_clear() {
        let c = subtractor(64);
        let mut rng = Rng::seeded(83);
        for _ in 0..10 {
            let x = rng.next_u64();
            let y = rng.next_u64();
            let mut input = u64_bits(x, 64);
            input.extend(u64_bits(y, 64));
            let out = garble_eval_roundtrip(&c, &input, &mut rng);
            assert_eq!(bits_u64(&out), x.wrapping_sub(y));
        }
    }

    #[test]
    fn garbling_is_deterministic() {
        let c = adder(16);
        let mut rng = Rng::seeded(84);
        let r = rand_key(&mut rng);
        let k0: Vec<Key> = (0..c.n_inputs).map(|_| rand_key(&mut rng)).collect();
        let g1 = garble(&c, r, &k0);
        let g2 = garble(&c, r, &k0);
        assert_eq!(g1.gc, g2.gc);
        assert_eq!(g1.k0, g2.k0);
    }

    #[test]
    fn table_size_is_2_ciphertexts_per_and() {
        let c = adder(64);
        let mut rng = Rng::seeded(85);
        let r = rand_key(&mut rng);
        let k0: Vec<Key> = (0..c.n_inputs).map(|_| rand_key(&mut rng)).collect();
        let g = garble(&c, r, &k0);
        assert_eq!(g.gc.wire_bytes(), c.and_count() * 32);
        // serialize round-trip
        let back = GarbledCircuit::from_bytes(&g.gc.to_bytes()).unwrap();
        assert_eq!(back, g.gc);
    }

    #[test]
    fn wrong_label_decodes_garbage() {
        // authenticity smoke test: evaluating with a flipped input label
        // yields a non-matching output label (not just a flipped bit you
        // could aim for)
        let c = adder(8);
        let mut rng = Rng::seeded(86);
        let r = rand_key(&mut rng);
        let k0: Vec<Key> = (0..c.n_inputs).map(|_| rand_key(&mut rng)).collect();
        let g = garble(&c, r, &k0);
        let mut active: Vec<Key> =
            (0..c.n_inputs).map(|i| active_label(k0[i], r, Bit(false))).collect();
        active[0][5] ^= 0xFF; // corrupt a label (not a valid label anymore)
        let out = evaluate(&c, &g.gc, &active);
        let out_k0 = output_k0(&c, &g);
        // the corrupted evaluation must not reproduce either valid label on
        // at least one output wire
        let mut r1 = r;
        r1[0] |= 1;
        let some_invalid = out.iter().zip(&out_k0).any(|(&a, &k)| a != k && a != xor(k, r1));
        assert!(some_invalid);
    }

    #[test]
    fn aes_shaped_garbles_and_evaluates() {
        let c = aes_shaped();
        let mut rng = Rng::seeded(87);
        let inputs: Vec<Bit> = (0..c.n_inputs).map(|_| Bit(rng.next_u64() & 1 == 1)).collect();
        let clear = c.eval(&inputs);
        let out = garble_eval_roundtrip(&c, &inputs, &mut rng);
        assert_eq!(out, clear);
    }
}
