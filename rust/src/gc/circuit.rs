//! Boolean circuits for the garbled world.
//!
//! Free-XOR-friendly representation: XOR and NOT are free, AND costs two
//! ciphertexts (half-gates). Builders cover the circuits the conversions
//! need — `ℓ`-bit ripple-carry adder/subtractor (Figs. 10–14) — plus an
//! AES-128-*shaped* benchmark circuit for Table XI (same AND count and
//! depth as the Bristol AES-128 circuit; see DESIGN.md §3 on the
//! substitution).

use crate::ring::Bit;

/// Gate in a boolean circuit. Wire ids: `0..n_inputs` are inputs; gate `g`
/// drives wire `n_inputs + g`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Gate {
    Xor(u32, u32),
    And(u32, u32),
    Not(u32),
}

/// A boolean circuit with explicit output wires.
#[derive(Clone, Debug)]
pub struct Circuit {
    pub n_inputs: usize,
    pub gates: Vec<Gate>,
    pub outputs: Vec<u32>,
}

impl Circuit {
    pub fn n_wires(&self) -> usize {
        self.n_inputs + self.gates.len()
    }

    /// Number of AND gates (the garbling cost driver).
    pub fn and_count(&self) -> usize {
        self.gates.iter().filter(|g| matches!(g, Gate::And(_, _))).count()
    }

    /// Multiplicative depth (longest AND chain).
    pub fn and_depth(&self) -> usize {
        let mut depth = vec![0usize; self.n_wires()];
        for (g, gate) in self.gates.iter().enumerate() {
            let w = self.n_inputs + g;
            depth[w] = match *gate {
                Gate::Xor(a, b) => depth[a as usize].max(depth[b as usize]),
                Gate::And(a, b) => depth[a as usize].max(depth[b as usize]) + 1,
                Gate::Not(a) => depth[a as usize],
            };
        }
        self.outputs.iter().map(|&o| depth[o as usize]).max().unwrap_or(0)
    }

    /// Cleartext evaluation (the correctness oracle for garbling).
    pub fn eval(&self, inputs: &[Bit]) -> Vec<Bit> {
        assert_eq!(inputs.len(), self.n_inputs);
        let mut w: Vec<Bit> = Vec::with_capacity(self.n_wires());
        w.extend_from_slice(inputs);
        for gate in &self.gates {
            let v = match *gate {
                Gate::Xor(a, b) => w[a as usize] + w[b as usize],
                Gate::And(a, b) => w[a as usize] * w[b as usize],
                Gate::Not(a) => w[a as usize].not(),
            };
            w.push(v);
        }
        self.outputs.iter().map(|&o| w[o as usize]).collect()
    }
}

/// Incremental circuit builder.
pub struct Builder {
    n_inputs: usize,
    gates: Vec<Gate>,
}

impl Builder {
    pub fn new(n_inputs: usize) -> Builder {
        Builder { n_inputs, gates: Vec::new() }
    }

    pub(crate) fn push(&mut self, g: Gate) -> u32 {
        self.gates.push(g);
        (self.n_inputs + self.gates.len() - 1) as u32
    }

    pub fn xor(&mut self, a: u32, b: u32) -> u32 {
        self.push(Gate::Xor(a, b))
    }

    pub fn and(&mut self, a: u32, b: u32) -> u32 {
        self.push(Gate::And(a, b))
    }

    pub fn not(&mut self, a: u32) -> u32 {
        self.push(Gate::Not(a))
    }

    pub fn or(&mut self, a: u32, b: u32) -> u32 {
        // a|b = ¬(¬a & ¬b)
        let na = self.not(a);
        let nb = self.not(b);
        let n = self.and(na, nb);
        self.not(n)
    }

    /// Full adder: returns (sum, carry_out). One AND via the
    /// `c' = c ⊕ ((a⊕c)&(b⊕c))` identity.
    pub fn full_adder(&mut self, a: u32, b: u32, c: u32) -> (u32, u32) {
        let axc = self.xor(a, c);
        let bxc = self.xor(b, c);
        let sum = self.xor(axc, b);
        let t = self.and(axc, bxc);
        let cout = self.xor(c, t);
        (sum, cout)
    }

    pub fn finish(self, outputs: Vec<u32>) -> Circuit {
        Circuit { n_inputs: self.n_inputs, gates: self.gates, outputs }
    }
}

/// `ℓ`-bit ripple-carry adder: inputs `x_0..x_{ℓ-1}, y_0..y_{ℓ-1}`
/// (little-endian), outputs the `ℓ`-bit sum (mod 2^ℓ). ℓ−1 AND gates.
pub fn adder(bits: usize) -> Circuit {
    let mut b = Builder::new(2 * bits);
    let mut outs = Vec::with_capacity(bits);
    let mut carry: Option<u32> = None;
    for i in 0..bits {
        let x = i as u32;
        let y = (bits + i) as u32;
        match carry {
            None => {
                outs.push(b.xor(x, y));
                if bits > 1 {
                    carry = Some(b.and(x, y));
                }
            }
            Some(c) => {
                if i + 1 < bits {
                    let (s, c2) = b.full_adder(x, y, c);
                    outs.push(s);
                    carry = Some(c2);
                } else {
                    // last bit: no carry-out needed → sum only, no AND
                    let t = b.xor(x, y);
                    outs.push(b.xor(t, c));
                }
            }
        }
    }
    b.finish(outs)
}

/// `ℓ`-bit subtractor `x − y` (mod 2^ℓ): x + ¬y + 1 via borrow logic.
pub fn subtractor(bits: usize) -> Circuit {
    let mut b = Builder::new(2 * bits);
    let mut outs = Vec::with_capacity(bits);
    // x - y = x + ~y + 1: carry-in 1, ny = ¬y
    let mut carry: Option<u32> = None;
    for i in 0..bits {
        let x = i as u32;
        let ny = {
            let y = (bits + i) as u32;
            b.not(y)
        };
        match carry {
            None => {
                // carry-in = 1: sum = x ⊕ ¬y ⊕ 1, carry = x | ¬y? Using
                // full-adder with constant 1: s = x⊕ny⊕1 = ¬(x⊕ny),
                // c = (x & ny) | (x⊕ny)·1 = x | ny
                let xn = b.xor(x, ny);
                outs.push(b.not(xn));
                if bits > 1 {
                    carry = Some(b.or(x, ny));
                }
            }
            Some(c) => {
                if i + 1 < bits {
                    let (s, c2) = b.full_adder(x, ny, c);
                    outs.push(s);
                    carry = Some(c2);
                } else {
                    let t = b.xor(x, ny);
                    outs.push(b.xor(t, c));
                }
            }
        }
    }
    b.finish(outs)
}

/// The most significant bit of `x − y` — the comparison/msb circuit used by
/// boolean-world bit extraction (`msb(x−y) = sign`, §V-B).
pub fn msb_of_diff(bits: usize) -> Circuit {
    let mut c = subtractor(bits);
    let msb = *c.outputs.last().unwrap();
    c.outputs = vec![msb];
    c
}

/// Constant-false wire (XOR of an input with itself).
impl Builder {
    pub fn const_false(&mut self) -> u32 {
        self.xor(0, 0)
    }

    /// Subtract `y` from `x` (equal-width little-endian wire vectors),
    /// returning `(difference, no_borrow)` — `no_borrow = 1` iff `x ≥ y`
    /// (the carry-out of `x + ¬y + 1`).
    pub fn sub_with_borrow(&mut self, x: &[u32], y: &[u32]) -> (Vec<u32>, u32) {
        assert_eq!(x.len(), y.len());
        let mut out = Vec::with_capacity(x.len());
        let mut carry: Option<u32> = None;
        for i in 0..x.len() {
            let ny = self.not(y[i]);
            match carry {
                None => {
                    // carry-in = 1
                    let xn = self.xor(x[i], ny);
                    out.push(self.not(xn));
                    carry = Some(self.or(x[i], ny));
                }
                Some(c) => {
                    let (s, c2) = self.full_adder(x[i], ny, c);
                    out.push(s);
                    carry = Some(c2);
                }
            }
        }
        (out, carry.unwrap())
    }

    /// Per-bit multiplexer: `sel ? a : b`.
    pub fn mux(&mut self, sel: u32, a: &[u32], b: &[u32]) -> Vec<u32> {
        assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let d = self.xor(x, y);
                let t = self.and(sel, d);
                self.xor(y, t)
            })
            .collect()
    }
}

/// Unsigned restoring divider: `Q = ⌊N / D⌋` for `bits`-wide inputs
/// (inputs `n_0..n_{b-1}, d_0..d_{b-1}` little-endian; undefined for D=0).
/// This is the "division garbled circuit" of the paper's MPC-friendly
/// softmax (§VI-A.c): ~`bits·(2·bits)` AND gates, evaluated by P0 in the
/// garbled world after an `Π_A2G` of numerator and denominator.
pub fn divider(bits: usize) -> Circuit {
    let mut b = Builder::new(2 * bits);
    let q = divider_core(&mut b, bits);
    b.finish(q)
}

/// The restoring-divider loop, shared by [`divider`] and [`safe_divider`]:
/// emits the quotient wires of `⌊N / D⌋` into `b` (inputs are the builder's
/// first `2·bits` wires, numerator then denominator, little-endian).
fn divider_core(b: &mut Builder, bits: usize) -> Vec<u32> {
    let d_wires: Vec<u32> = (bits..2 * bits).map(|i| i as u32).collect();
    let f0 = b.const_false();
    let mut r = vec![f0; bits];
    let mut q = vec![f0; bits];
    for i in (0..bits).rev() {
        let r_top = r[bits - 1];
        // R' = (R << 1) | n_i
        let mut rp = Vec::with_capacity(bits);
        rp.push(i as u32); // n_i
        rp.extend_from_slice(&r[..bits - 1]);
        let (t, no_borrow) = b.sub_with_borrow(&rp, &d_wires);
        // R had a 65th bit (r_top): if set, R' ≥ D regardless
        let ge = b.or(r_top, no_borrow);
        q[i] = ge;
        r = b.mux(ge, &t, &rp);
    }
    q
}

/// [`divider`] with **defined `D = 0` behavior**: an in-circuit comparator
/// OR-folds the denominator wires and a final mux swaps the (garbage)
/// restoring quotient for the constant `fallback` when `D = 0`. The test is
/// taken on the garbled denominator wires, so whether the zero branch fired
/// is never revealed — callers get total-function semantics at a cost of
/// `2·bits − 1` extra AND-equivalent gates on top of [`divider`].
pub fn safe_divider(bits: usize, fallback: u64) -> Circuit {
    assert!(bits <= 64, "fallback constant is u64-wide");
    let mut b = Builder::new(2 * bits);
    let q = divider_core(&mut b, bits);
    // is_zero(D) = ¬(d_0 | d_1 | … | d_{b-1})
    let mut any = bits as u32;
    for i in 1..bits {
        any = b.or(any, (bits + i) as u32);
    }
    let is_zero = b.not(any);
    let f0 = b.const_false();
    let f1 = b.not(f0);
    let fb: Vec<u32> =
        (0..bits).map(|i| if (fallback >> i) & 1 == 1 { f1 } else { f0 }).collect();
    let outs = b.mux(is_zero, &fb, &q);
    b.finish(outs)
}

/// Parallel-prefix (Sklansky) adder with carry-in: `log ℓ` AND-depth,
/// `O(ℓ log ℓ)` AND gates — the "optimized Parallel Prefix Adder" ABY3 uses
/// and Trident's `Π_A2B` evaluates in the boolean world (Lemma C.8).
pub fn ppa_adder(bits: usize, carry_in: bool) -> Circuit {
    let mut b = Builder::new(2 * bits);
    // propagate/generate per bit
    let ps: Vec<u32> = (0..bits).map(|i| b.xor(i as u32, (bits + i) as u32)).collect();
    let gs: Vec<u32> = (0..bits).map(|i| b.and(i as u32, (bits + i) as u32)).collect();
    // Sklansky prefix tree over (G, P); span[i] = combined (G,P) of bits 0..=i
    let mut gg = gs.clone();
    let mut pp = ps.clone();
    let mut step = 1usize;
    while step < bits {
        for i in 0..bits {
            if (i / step) % 2 == 1 {
                let j = (i / step) * step - 1; // rightmost index of the left block
                // (G,P)[i] = (G[i] ⊕ P[i]&G[j], P[i]&P[j])
                let t = b.and(pp[i], gg[j]);
                gg[i] = b.xor(gg[i], t);
                pp[i] = b.and(pp[i], pp[j]);
            }
        }
        step *= 2;
    }
    // carries: c_0 = cin; c_{i} = G[i-1] ⊕ (P[i-1] & cin)
    let mut outs = Vec::with_capacity(bits);
    for i in 0..bits {
        let ci = if i == 0 {
            None // carry-in handled below
        } else {
            Some(if carry_in {
                // G[i-1] ⊕ P[i-1] (cin = 1)
                b.xor(gg[i - 1], pp[i - 1])
            } else {
                gg[i - 1]
            })
        };
        let s = match ci {
            Some(c) => b.xor(ps[i], c),
            None => {
                if carry_in {
                    b.not(ps[i])
                } else {
                    ps[i]
                }
            }
        };
        outs.push(s);
    }
    b.finish(outs)
}

/// Parallel-prefix subtractor `x − y` (`x + ¬y + 1` with the PPA core).
pub fn ppa_subtractor(bits: usize) -> Circuit {
    // wrap ppa_adder(b, cin=1) with ¬y on the second operand
    let inner = ppa_adder(bits, true);
    let mut b = Builder::new(2 * bits);
    // remap: first operand passthrough; second operand negated
    let mut map: Vec<u32> = (0..bits as u32).collect();
    for i in 0..bits {
        map.push(b.not((bits + i) as u32));
    }
    // inline the inner circuit
    for gate in &inner.gates {
        let mp = |w: u32| map[w as usize];
        let ng = match *gate {
            Gate::Xor(x, y) => Gate::Xor(mp(x), mp(y)),
            Gate::And(x, y) => Gate::And(mp(x), mp(y)),
            Gate::Not(x) => Gate::Not(mp(x)),
        };
        let w = b.push(ng);
        map.push(w);
    }
    let outputs = inner.outputs.iter().map(|&o| map[o as usize]).collect();
    b.finish(outputs)
}

/// AES-128-*shaped* benchmark circuit for Table XI: ~6400 AND / ~28000 XOR
/// gates arranged in 40 AND-layers (10 rounds × 4-deep S-box approximation),
/// the published Bristol AES-128 profile. The function computed is not AES —
/// Table XI depends only on gate counts and depth (see DESIGN.md §3).
pub fn aes_shaped() -> Circuit {
    layered_circuit(256, 40, 160, 704)
}

/// Generic layered benchmark circuit: `layers` AND-layers of `ands_per_layer`
/// AND gates each, with `xors_per_layer` XORs mixing between layers.
pub fn layered_circuit(
    n_inputs: usize,
    layers: usize,
    ands_per_layer: usize,
    xors_per_layer: usize,
) -> Circuit {
    let mut b = Builder::new(n_inputs);
    // state wires start as the inputs
    let mut state: Vec<u32> = (0..n_inputs as u32).collect();
    let mut mix = 0usize;
    for _layer in 0..layers {
        let mut next = Vec::with_capacity(state.len());
        for i in 0..ands_per_layer.min(state.len() / 2) {
            let a = state[(2 * i) % state.len()];
            let c = state[(2 * i + 1) % state.len()];
            next.push(b.and(a, c));
        }
        for i in 0..xors_per_layer {
            let a = state[(i + mix) % state.len()];
            let c = next[i % next.len()];
            next.push(b.xor(a, c));
        }
        mix += 1;
        state = next;
    }
    let outputs = state.iter().take(128.min(state.len())).cloned().collect();
    b.finish(outputs)
}

/// Encode a u64 as little-endian bits.
pub fn u64_bits(v: u64, bits: usize) -> Vec<Bit> {
    (0..bits).map(|i| Bit((v >> i) & 1 == 1)).collect()
}

/// Decode little-endian bits to u64.
pub fn bits_u64(bits: &[Bit]) -> u64 {
    bits.iter().enumerate().fold(0u64, |acc, (i, b)| acc | ((b.0 as u64) << i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;

    #[test]
    fn adder_matches_wrapping_add() {
        let mut rng = Rng::seeded(70);
        let c = adder(64);
        for _ in 0..50 {
            let x = rng.next_u64();
            let y = rng.next_u64();
            let mut input = u64_bits(x, 64);
            input.extend(u64_bits(y, 64));
            let out = c.eval(&input);
            assert_eq!(bits_u64(&out), x.wrapping_add(y));
        }
    }

    #[test]
    fn subtractor_matches_wrapping_sub() {
        let mut rng = Rng::seeded(71);
        let c = subtractor(64);
        for _ in 0..50 {
            let x = rng.next_u64();
            let y = rng.next_u64();
            let mut input = u64_bits(x, 64);
            input.extend(u64_bits(y, 64));
            let out = c.eval(&input);
            assert_eq!(bits_u64(&out), x.wrapping_sub(y), "{x} - {y}");
        }
    }

    #[test]
    fn small_width_adders() {
        for bits in [1usize, 2, 8, 16] {
            let c = adder(bits);
            let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
            for x in [0u64, 1, mask, mask / 2] {
                for y in [0u64, 1, mask] {
                    let mut input = u64_bits(x & mask, bits);
                    input.extend(u64_bits(y & mask, bits));
                    let out = c.eval(&input);
                    assert_eq!(bits_u64(&out), x.wrapping_add(y) & mask);
                }
            }
        }
    }

    #[test]
    fn msb_of_diff_is_comparison() {
        let mut rng = Rng::seeded(72);
        let c = msb_of_diff(64);
        for _ in 0..50 {
            let x = rng.next_u64() as i64;
            let y = rng.next_u64() as i64;
            let mut input = u64_bits(x as u64, 64);
            input.extend(u64_bits(y as u64, 64));
            let out = c.eval(&input);
            assert_eq!(out[0].0, x.wrapping_sub(y) < 0);
        }
    }

    #[test]
    fn adder_and_count_is_l_minus_1() {
        assert_eq!(adder(64).and_count(), 63);
        assert_eq!(subtractor(64).and_count(), 63); // OR's AND + 62 full adders
    }

    #[test]
    fn aes_shaped_profile() {
        let c = aes_shaped();
        assert!((6000..7000).contains(&c.and_count()), "ANDs = {}", c.and_count());
        assert_eq!(c.and_depth(), 40);
    }

    #[test]
    fn divider_matches_integer_division() {
        let mut rng = Rng::seeded(75);
        let c = divider(64);
        for _ in 0..25 {
            let n = rng.next_u64();
            let d = rng.next_u64().max(1);
            let mut input = u64_bits(n, 64);
            input.extend(u64_bits(d, 64));
            let out = c.eval(&input);
            assert_eq!(bits_u64(&out), n / d, "{n}/{d}");
        }
        // edges
        for (n, d) in [(0u64, 5u64), (5, 5), (4, 5), (u64::MAX, 1), (u64::MAX, u64::MAX)] {
            let mut input = u64_bits(n, 64);
            input.extend(u64_bits(d, 64));
            assert_eq!(bits_u64(&c.eval(&input)), n / d, "{n}/{d}");
        }
    }

    #[test]
    fn safe_divider_matches_divider_and_defines_zero_denominator() {
        let mut rng = Rng::seeded(76);
        let fb = 0xA5u64;
        let c = safe_divider(8, fb);
        for _ in 0..25 {
            let n = rng.next_u64() & 0xFF;
            let d = (rng.next_u64() & 0xFF).max(1);
            let mut input = u64_bits(n, 8);
            input.extend(u64_bits(d, 8));
            assert_eq!(bits_u64(&c.eval(&input)), n / d, "{n}/{d}");
        }
        // D = 0: the comparator swaps in the fallback instead of garbage
        for n in [0u64, 1, 255] {
            let mut input = u64_bits(n, 8);
            input.extend(u64_bits(0, 8));
            assert_eq!(bits_u64(&c.eval(&input)), fb, "{n}/0 must yield the fallback");
        }
    }

    #[test]
    fn divider_small_widths() {
        let c = divider(8);
        for n in [0u64, 1, 100, 255] {
            for d in [1u64, 3, 16, 255] {
                let mut input = u64_bits(n, 8);
                input.extend(u64_bits(d, 8));
                assert_eq!(bits_u64(&c.eval(&input)), n / d, "{n}/{d}");
            }
        }
    }

    #[test]
    fn ppa_adder_matches_wrapping_add() {
        let mut rng = Rng::seeded(73);
        for cin in [false, true] {
            let c = ppa_adder(64, cin);
            assert!(c.and_depth() <= 8, "depth {}", c.and_depth());
            for _ in 0..20 {
                let x = rng.next_u64();
                let y = rng.next_u64();
                let mut input = u64_bits(x, 64);
                input.extend(u64_bits(y, 64));
                let out = c.eval(&input);
                let want = x.wrapping_add(y).wrapping_add(cin as u64);
                assert_eq!(bits_u64(&out), want, "{x}+{y}+{}", cin as u64);
            }
        }
    }

    #[test]
    fn ppa_subtractor_matches_wrapping_sub() {
        let mut rng = Rng::seeded(74);
        let c = ppa_subtractor(64);
        for _ in 0..20 {
            let x = rng.next_u64();
            let y = rng.next_u64();
            let mut input = u64_bits(x, 64);
            input.extend(u64_bits(y, 64));
            let out = c.eval(&input);
            assert_eq!(bits_u64(&out), x.wrapping_sub(y));
        }
    }

    #[test]
    fn not_gate_and_or() {
        let mut b = Builder::new(2);
        let o = b.or(0, 1);
        let c = b.finish(vec![o]);
        for (x, y, want) in
            [(false, false, false), (true, false, true), (false, true, true), (true, true, true)]
        {
            assert_eq!(c.eval(&[Bit(x), Bit(y)])[0], Bit(want));
        }
    }
}
