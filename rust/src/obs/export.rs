//! Trace exporters: JSONL event stream (chrome-tracing-compatible `ts`),
//! Prometheus-style text snapshot (`trident metrics`), and the CLI gauge
//! render that replaced the printf stats lines in
//! `coordinator::serve_tenants_cli`.

use super::TraceEvent;
use crate::net::Phase;
use crate::serve::multi::MultiServeStats;
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn phase_str(ph: Phase) -> &'static str {
    match ph {
        Phase::Offline => "offline",
        Phase::Online => "online",
    }
}

fn opt_u32(v: Option<u32>) -> String {
    v.map_or_else(|| "null".to_string(), |x| x.to_string())
}

fn opt_u64(v: Option<u64>) -> String {
    v.map_or_else(|| "null".to_string(), |x| x.to_string())
}

/// One JSONL line for one event. `ts` is chrome-tracing-compatible
/// microseconds derived from the deterministic identity plus the measured
/// compute: `tick · 1000 + compute_ns / 1000` — logical ticks are spaced
/// 1 ms apart on the rendered timeline and an event's span nests inside
/// its tick.
pub fn jsonl_event(party: usize, e: &TraceEvent) -> String {
    let ts_us = e.tick as f64 * 1000.0 + e.payload.compute_ns as f64 / 1000.0;
    format!(
        "{{\"op\":\"{}\",\"party\":{},\"phase\":\"{}\",\"lockstep\":{},\
         \"tenant\":{},\"wave\":{},\"gate\":{},\"tick\":{},\"ts\":{:.3},\
         \"msgs\":{},\"bytes\":{},\"rounds\":{},\"compute_ns\":{},\"value\":{}}}",
        e.op,
        party,
        phase_str(e.phase),
        e.lockstep,
        opt_u32(e.tenant),
        opt_u64(e.wave),
        opt_u32(e.gate),
        e.tick,
        ts_us,
        e.payload.msgs,
        e.payload.bytes,
        e.payload.rounds,
        e.payload.compute_ns,
        e.payload.value,
    )
}

/// The whole run as JSONL: every party's full event stream (lockstep AND
/// per-party detail events), party order. Because each party's first
/// recorded event is `run.open` and its last is `run.close`, the file's
/// first line is a `run.open` and its last line a `run.close` — the CI
/// trace smoke step greps for exactly that.
pub fn trace_jsonl(party_traces: &[Vec<TraceEvent>]) -> String {
    let mut out = String::new();
    for (p, t) in party_traces.iter().enumerate() {
        for e in t {
            out.push_str(&jsonl_event(p, e));
            out.push('\n');
        }
    }
    out
}

/// Final wave-boundary gauge samples from the merged trace: for each
/// gauge identity `(op, tenant, gate)`, the value of its last sample.
fn last_gauges(stats: &MultiServeStats) -> BTreeMap<(&'static str, Option<u32>, Option<u32>), i64> {
    let mut g = BTreeMap::new();
    for e in &stats.trace {
        if e.op.starts_with("sched.depth")
            || e.op.starts_with("sched.inflight")
            || e.op.starts_with("pool.stock")
        {
            g.insert((e.op, e.tenant, e.gate), e.payload.value);
        }
    }
    g
}

fn tenant_name(stats: &MultiServeStats, t: Option<u32>) -> String {
    t.and_then(|t| stats.tenants.get(t as usize))
        .map_or_else(|| "?".to_string(), |ts| ts.name.clone())
}

/// Prometheus text-exposition snapshot of a finished run: run counters,
/// per-tenant counters, and the last wave-boundary gauge samples from the
/// trace (absent when the run was not traced).
pub fn prometheus(stats: &MultiServeStats) -> String {
    let mut out = String::new();
    let mut metric = |name: &str, typ: &str, help: &str, lines: &[String]| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {typ}");
        for l in lines {
            let _ = writeln!(out, "{l}");
        }
    };

    metric(
        "trident_waves_total",
        "counter",
        "Serving waves committed.",
        &[format!("trident_waves_total {}", stats.waves)],
    );
    metric(
        "trident_ticks_total",
        "counter",
        "Logical scheduler ticks.",
        &[format!("trident_ticks_total {}", stats.ticks)],
    );
    metric(
        "trident_online_rounds_total",
        "counter",
        "Online-phase protocol rounds.",
        &[format!("trident_online_rounds_total {}", stats.online_rounds)],
    );
    metric(
        "trident_offline_msgs_in_waves_total",
        "counter",
        "Offline-phase messages any party sent inside wave windows (0 when warm keyed).",
        &[
            format!("trident_offline_msgs_in_waves_total {}", stats.offline_msgs_in_waves),
            format!(
                "trident_offline_msgs_in_waves_total{{op=\"matmul\"}} {}",
                stats.offline_msgs_matmul
            ),
            format!(
                "trident_offline_msgs_in_waves_total{{op=\"relu\"}} {}",
                stats.offline_msgs_relu
            ),
        ],
    );
    metric(
        "trident_refill_online_msgs_total",
        "counter",
        "Online messages inside refill ticks (contract: 0).",
        &[format!("trident_refill_online_msgs_total {}", stats.refill_online_msgs)],
    );
    metric(
        "trident_quarantines_total",
        "counter",
        "Contained tenant-scoped aborts.",
        &[format!("trident_quarantines_total {}", stats.quarantines.len())],
    );

    let per_tenant = |field: fn(&crate::serve::multi::TenantServeStats) -> usize| {
        stats
            .tenants
            .iter()
            .map(|ts| (ts.name.clone(), field(ts)))
            .collect::<Vec<_>>()
    };
    for (name, help, rows) in [
        ("trident_tenant_served_total", "Queries answered.", per_tenant(|ts| ts.served)),
        ("trident_tenant_expired_total", "Queries dropped past deadline.", per_tenant(|ts| ts.expired)),
        ("trident_tenant_rejected_total", "Queries shed by admission control.", per_tenant(|ts| ts.rejected)),
        ("trident_tenant_waves_total", "Waves granted.", per_tenant(|ts| ts.waves)),
        ("trident_tenant_keyed_waves_total", "Waves served from the keyed pool.", per_tenant(|ts| ts.keyed_waves)),
    ] {
        let lines: Vec<String> = rows
            .iter()
            .map(|(t, v)| format!("{name}{{tenant=\"{t}\"}} {v}"))
            .collect();
        metric(name, "counter", help, &lines);
    }

    let gauges = last_gauges(stats);
    if !gauges.is_empty() {
        let mut depth = Vec::new();
        let mut inflight = Vec::new();
        let mut stock = Vec::new();
        for (&(op, tenant, gate), &v) in &gauges {
            match op {
                "sched.depth" => depth.push(format!(
                    "trident_sched_queue_depth{{class=\"{}\"}} {v}",
                    gate.unwrap_or(0)
                )),
                "sched.inflight" => inflight.push(format!(
                    "trident_sched_inflight{{tenant=\"{}\"}} {v}",
                    tenant_name(stats, tenant)
                )),
                "pool.stock.mat" | "pool.stock.relu" => stock.push(format!(
                    "trident_pool_stock{{tenant=\"{}\",gate=\"{}\",op=\"{}\"}} {v}",
                    tenant_name(stats, tenant),
                    gate.unwrap_or(0),
                    if op == "pool.stock.mat" { "matmul" } else { "relu" }
                )),
                _ => {}
            }
        }
        metric(
            "trident_sched_queue_depth",
            "gauge",
            "Pending queries per priority class (last wave-boundary sample).",
            &depth,
        );
        metric(
            "trident_sched_inflight",
            "gauge",
            "Admitted-unserved queries per tenant (last wave-boundary sample).",
            &inflight,
        );
        metric(
            "trident_pool_stock",
            "gauge",
            "Keyed bundles in stock per tenant gate (last wave-boundary sample).",
            &stock,
        );
    }
    out
}

/// Human-readable render of the wave-boundary gauges, the offline-silence
/// check and the quarantine log — the same data the old printf-style
/// stats lines in `serve_tenants_cli` showed, now derived from the trace
/// and the aggregated stats instead of ad-hoc counters.
pub fn gauge_table(stats: &MultiServeStats) -> String {
    let mut out = String::new();
    let silent = stats.offline_msgs_in_waves == 0;
    let _ = writeln!(
        out,
        "offline-silent waves: {} ({} offline msgs inside wave windows; matmul {}, relu {})",
        if silent { "yes" } else { "NO" },
        stats.offline_msgs_in_waves,
        stats.offline_msgs_matmul,
        stats.offline_msgs_relu,
    );
    let _ = writeln!(
        out,
        "refill online msgs: {} (contract: 0) | aged promotions: {}",
        stats.refill_online_msgs, stats.aged_promotions
    );
    if stats.quarantines.is_empty() {
        let _ = writeln!(out, "quarantine: none");
    } else {
        for q in &stats.quarantines {
            let _ = writeln!(
                out,
                "quarantine: tenant {} ({}) at tick {} — requeued {}, lost {}, \
                 drained {} mat / {} relu bundles [{}]",
                q.tenant,
                tenant_name(stats, Some(q.tenant as u32)),
                q.at_tick,
                q.requeued,
                q.lost,
                q.drained_mat,
                q.drained_relu,
                q.why
            );
        }
    }
    let gauges = last_gauges(stats);
    if !gauges.is_empty() {
        let mut line = String::from("gauges (last wave boundary):");
        for (&(op, tenant, gate), &v) in &gauges {
            match op {
                "sched.depth" => {
                    let _ = write!(line, " depth[class {}]={v}", gate.unwrap_or(0));
                }
                "sched.inflight" => {
                    let _ = write!(line, " inflight[{}]={v}", tenant_name(stats, tenant));
                }
                _ => {}
            }
        }
        let _ = writeln!(out, "{line}");
        let mut line = String::from("pool stock (last wave boundary):");
        for (&(op, tenant, gate), &v) in &gauges {
            if op == "pool.stock.mat" || op == "pool.stock.relu" {
                let _ = write!(
                    line,
                    " {}[{} g{}]={v}",
                    if op == "pool.stock.mat" { "mat" } else { "relu" },
                    tenant_name(stats, tenant),
                    gate.unwrap_or(0)
                );
            }
        }
        let _ = writeln!(out, "{line}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Payload;

    fn ev(op: &'static str) -> TraceEvent {
        TraceEvent {
            op,
            phase: Phase::Online,
            lockstep: true,
            tenant: Some(1),
            wave: Some(2),
            gate: None,
            tick: 3,
            payload: Payload { msgs: 4, bytes: 5, rounds: 6, compute_ns: 2500, value: -1 },
        }
    }

    #[test]
    fn jsonl_line_shape_is_stable() {
        let line = jsonl_event(2, &ev("wave.commit"));
        assert_eq!(
            line,
            "{\"op\":\"wave.commit\",\"party\":2,\"phase\":\"online\",\"lockstep\":true,\
             \"tenant\":1,\"wave\":2,\"gate\":null,\"tick\":3,\"ts\":3002.500,\
             \"msgs\":4,\"bytes\":5,\"rounds\":6,\"compute_ns\":2500,\"value\":-1}"
        );
    }

    #[test]
    fn trace_jsonl_is_one_event_per_line() {
        let traces = vec![vec![ev("run.open"), ev("run.close")], vec![ev("run.open")]];
        let s = trace_jsonl(&traces);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"op\":\"run.open\"") && lines[0].contains("\"party\":0"));
        assert!(lines[2].contains("\"party\":1"));
    }
}
