//! Observability: deterministic cross-party tracing, unified metering
//! windows and metric export for the 4PC serving stack.
//!
//! ## Identity vs payload
//!
//! Every [`TraceEvent`] splits into two halves:
//!
//! * **Identity** — `(op, phase, tenant, wave, gate, tick)`: pure
//!   functions of public lockstep metadata (the serving schedule, the gate
//!   position in the resident circuit, the logical tick). For events
//!   recorded at lockstep decision points (`lockstep: true`), all four
//!   parties emit the **same identity sequence**; [`check_skeletons`]
//!   asserts it, so the recorder doubles as a desync detector — a party
//!   that admits a different query, runs a different wave or refills a
//!   different tenant diverges in its trace skeleton long before the run
//!   hangs or opens a wrong value.
//! * **Payload** — measured, per-party numbers ([`Payload`]: bytes, msgs,
//!   rounds, compute-ns, gauge values). Payloads are *excluded* from the
//!   cross-party equality: each party reports what it measured.
//!
//! Per-party low-level events (`net.send`, `phase.switch`) are recorded
//! with `lockstep: false` — different parties legitimately send different
//! message counts — and travel in the JSONL export only.
//!
//! ## Observer-effect contract
//!
//! The recorder hangs off [`Trace`], a zero-cost-when-off sink: every hook
//! is a single `Option` check when disabled, every hook sits **after** the
//! metering arithmetic of the site it instruments, and no hook sends a
//! message, samples randomness or touches a virtual clock. Enabling
//! tracing therefore changes no metered byte/msg/round counter and no
//! opened value — tested (like the PR 5 metering contract at
//! `Ctx::send_ring`) by
//! `obs_tracing_is_observer_effect_free_on_deep_two_tenant_run` in the
//! equivalence suite.
//!
//! ## Windows
//!
//! [`Window`]/[`Counters`] replace the ~20 hand-subtracted meter snapshots
//! that used to live in `serve/`, `serve/multi.rs` and `ml/nn.rs`: open a
//! window, run the measured region, `diff` it. Diffs are **saturating**,
//! so a window that straddles a `reset_clocks` (which zeroes the round
//! counters) reads 0 instead of wrapping.

pub mod export;

use crate::net::{PartyCtx, Phase};

// ------------------------------------------------------------- events --

/// Measured (per-party) half of a trace event. Excluded from the
/// cross-party skeleton equality.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Payload {
    /// Messages sent inside the event's window.
    pub msgs: u64,
    /// Payload bytes sent inside the event's window.
    pub bytes: u64,
    /// Protocol rounds inside the event's window.
    pub rounds: u64,
    /// Party-local compute nanoseconds inside the event's window.
    pub compute_ns: u64,
    /// Event-specific gauge value (queue depth, inflight count, pool
    /// stock, refill items, query id — the `op` names the meaning).
    pub value: i64,
}

impl Payload {
    /// A pure gauge sample.
    pub fn gauge(value: i64) -> Payload {
        Payload { value, ..Payload::default() }
    }
}

/// One structured trace event: deterministic identity + measured payload.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// What happened (static vocabulary: `run.open`, `wave.commit`,
    /// `gate.matmul`, `sched.admit`, `pool.stock.mat`, `net.send`, …).
    pub op: &'static str,
    /// Phase the event was recorded in.
    pub phase: Phase,
    /// Recorded at a lockstep decision point: part of the cross-party
    /// skeleton ([`skeleton`] / [`check_skeletons`]).
    pub lockstep: bool,
    /// Tenant index, when the event is tenant-scoped.
    pub tenant: Option<u32>,
    /// Global wave sequence number, when the event is wave-scoped.
    pub wave: Option<u64>,
    /// Gate (layer) position — or, for `sched.depth`, the priority class.
    pub gate: Option<u32>,
    /// Logical scheduler tick.
    pub tick: u64,
    pub payload: Payload,
}

/// The identity tuple compared across parties.
pub type EventKey = (&'static str, u8, Option<u32>, Option<u64>, Option<u32>, u64);

impl TraceEvent {
    pub fn key(&self) -> EventKey {
        (self.op, self.phase as u8, self.tenant, self.wave, self.gate, self.tick)
    }
}

/// The deterministic skeleton of a party's trace: the identity sequence of
/// its lockstep events.
pub fn skeleton(events: &[TraceEvent]) -> Vec<EventKey> {
    events.iter().filter(|e| e.lockstep).map(TraceEvent::key).collect()
}

/// Assert-style check that every party's trace skeleton equals party 0's.
/// `Ok` for fewer than two traces (and for all-empty traces — tracing
/// off). The error names the first diverging event.
pub fn check_skeletons(traces: &[Vec<TraceEvent>]) -> Result<(), String> {
    let Some(first) = traces.first() else { return Ok(()) };
    let want = skeleton(first);
    for (p, t) in traces.iter().enumerate().skip(1) {
        let got = skeleton(t);
        if got != want {
            let at = want.iter().zip(&got).position(|(a, b)| a != b);
            return Err(match at {
                Some(i) => format!(
                    "party {p} trace skeleton diverges at lockstep event {i}: {:?} vs party 0's {:?}",
                    got[i], want[i]
                ),
                None => format!(
                    "party {p} trace skeleton has {} lockstep events, party 0 has {}",
                    got.len(),
                    want.len()
                ),
            });
        }
    }
    Ok(())
}

/// Merge the four parties' lockstep events into one representative trace:
/// identity from party 0 (equal everywhere once [`check_skeletons`]
/// passed), `msgs`/`bytes` summed over parties (matching how the serving
/// aggregates sum offline-message counters), `rounds`/`compute_ns` as the
/// max over parties (matching the per-wave latency convention), gauge
/// `value`s from party 0 (lockstep-deterministic by construction).
pub fn merge_lockstep(traces: &[Vec<TraceEvent>]) -> Vec<TraceEvent> {
    let Some(first) = traces.first() else { return Vec::new() };
    let mut merged: Vec<TraceEvent> = first.iter().filter(|e| e.lockstep).cloned().collect();
    for t in &traces[1..] {
        for (m, e) in merged.iter_mut().zip(t.iter().filter(|e| e.lockstep)) {
            m.payload.msgs += e.payload.msgs;
            m.payload.bytes += e.payload.bytes;
            m.payload.rounds = m.payload.rounds.max(e.payload.rounds);
            m.payload.compute_ns = m.payload.compute_ns.max(e.payload.compute_ns);
        }
    }
    merged
}

// ------------------------------------------------------------ recorder --

/// Per-party identity cursor: the ambient `(tenant, wave, gate, tick)`
/// that low-level events (sends, phase switches) are stamped with. Set by
/// the serving layer at lockstep points.
#[derive(Clone, Copy, Debug, Default)]
struct Cursor {
    tenant: Option<u32>,
    wave: Option<u64>,
    gate: Option<u32>,
    tick: u64,
}

#[derive(Debug, Default)]
struct TraceBuf {
    events: Vec<TraceEvent>,
    cursor: Cursor,
}

/// Zero-cost-when-off trace sink carried by every
/// [`crate::net::PartyCtx`]. Disabled (`buf: None`) by default: every
/// record/cursor call is one branch and no allocation.
#[derive(Debug, Default)]
pub struct Trace {
    buf: Option<Box<TraceBuf>>,
}

impl Trace {
    #[inline]
    pub fn enabled(&self) -> bool {
        self.buf.is_some()
    }

    /// Start recording (idempotent; an existing buffer is kept).
    pub fn enable(&mut self) {
        if self.buf.is_none() {
            self.buf = Some(Box::default());
        }
    }

    /// Stop recording and drain the buffered events.
    pub fn take(&mut self) -> Vec<TraceEvent> {
        self.buf.take().map(|b| b.events).unwrap_or_default()
    }

    #[inline]
    pub fn set_tick(&mut self, tick: u64) {
        if let Some(b) = self.buf.as_deref_mut() {
            b.cursor.tick = tick;
        }
    }

    #[inline]
    pub fn set_wave(&mut self, tenant: u32, wave: u64) {
        if let Some(b) = self.buf.as_deref_mut() {
            b.cursor.tenant = Some(tenant);
            b.cursor.wave = Some(wave);
        }
    }

    #[inline]
    pub fn clear_wave(&mut self) {
        if let Some(b) = self.buf.as_deref_mut() {
            b.cursor.tenant = None;
            b.cursor.wave = None;
            b.cursor.gate = None;
        }
    }

    #[inline]
    pub fn set_gate(&mut self, gate: u32) {
        if let Some(b) = self.buf.as_deref_mut() {
            b.cursor.gate = Some(gate);
        }
    }

    #[inline]
    pub fn clear_gate(&mut self) {
        if let Some(b) = self.buf.as_deref_mut() {
            b.cursor.gate = None;
        }
    }

    /// Record an event stamped with the ambient cursor identity.
    #[inline]
    pub fn record(&mut self, op: &'static str, phase: Phase, lockstep: bool, payload: Payload) {
        if let Some(b) = self.buf.as_deref_mut() {
            let c = b.cursor;
            b.events.push(TraceEvent {
                op,
                phase,
                lockstep,
                tenant: c.tenant,
                wave: c.wave,
                gate: c.gate,
                tick: c.tick,
                payload,
            });
        }
    }

    /// Record an event with explicit identity fields (gauges and scheduler
    /// events whose tenant/gate are not the ambient wave's).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn record_at(
        &mut self,
        op: &'static str,
        phase: Phase,
        lockstep: bool,
        tenant: Option<u32>,
        wave: Option<u64>,
        gate: Option<u32>,
        payload: Payload,
    ) {
        if let Some(b) = self.buf.as_deref_mut() {
            let tick = b.cursor.tick;
            b.events.push(TraceEvent { op, phase, lockstep, tenant, wave, gate, tick, payload });
        }
    }
}

// ------------------------------------------------------------- windows --

/// Snapshot of one party's monotone meter counters, both phases. Taken by
/// [`PartyCtx::counters`]; differenced by [`Window`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    pub msgs: [u64; 2],
    pub bytes: [u64; 2],
    pub value_bytes: [u64; 2],
    pub rounds: [u64; 2],
    pub clock: [f64; 2],
    pub compute: [f64; 2],
}

impl Counters {
    pub fn msgs(&self, ph: Phase) -> u64 {
        self.msgs[ph as usize]
    }

    pub fn bytes(&self, ph: Phase) -> u64 {
        self.bytes[ph as usize]
    }

    pub fn value_bytes(&self, ph: Phase) -> u64 {
        self.value_bytes[ph as usize]
    }

    pub fn rounds(&self, ph: Phase) -> u64 {
        self.rounds[ph as usize]
    }

    pub fn clock(&self, ph: Phase) -> f64 {
        self.clock[ph as usize]
    }

    pub fn compute(&self, ph: Phase) -> f64 {
        self.compute[ph as usize]
    }

    /// Party-local compute nanoseconds, for [`Payload::compute_ns`].
    pub fn compute_ns(&self, ph: Phase) -> u64 {
        (self.compute[ph as usize] * 1e9) as u64
    }

    /// Per-field saturating difference: a counter that went *down* between
    /// the snapshots (only possible across a `reset_clocks`, which zeroes
    /// the round counters and virtual clocks) reads 0 instead of wrapping.
    pub fn saturating_sub(&self, earlier: &Counters) -> Counters {
        let sub = |a: [u64; 2], b: [u64; 2]| [a[0].saturating_sub(b[0]), a[1].saturating_sub(b[1])];
        let fsub = |a: [f64; 2], b: [f64; 2]| [(a[0] - b[0]).max(0.0), (a[1] - b[1]).max(0.0)];
        Counters {
            msgs: sub(self.msgs, earlier.msgs),
            bytes: sub(self.bytes, earlier.bytes),
            value_bytes: sub(self.value_bytes, earlier.value_bytes),
            rounds: sub(self.rounds, earlier.rounds),
            clock: fsub(self.clock, earlier.clock),
            compute: fsub(self.compute, earlier.compute),
        }
    }
}

/// One metering window over a party's counters: the single replacement for
/// the hand-subtracted `let m0 = ctx.net.sent_msgs(..); … - m0` snapshot
/// pairs that used to be re-plumbed at every serving call site.
#[derive(Clone, Copy, Debug)]
pub struct Window {
    start: Counters,
}

impl Window {
    /// Open a window at the party's current counter values.
    pub fn open(net: &PartyCtx) -> Window {
        Window { start: net.counters() }
    }

    /// The (saturating) counter deltas since the window was opened.
    pub fn diff(&self, net: &PartyCtx) -> Counters {
        net.counters().saturating_sub(&self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(op: &'static str, lockstep: bool, tick: u64) -> TraceEvent {
        TraceEvent {
            op,
            phase: Phase::Online,
            lockstep,
            tenant: None,
            wave: None,
            gate: None,
            tick,
            payload: Payload::default(),
        }
    }

    #[test]
    fn skeleton_ignores_non_lockstep_events_and_payloads() {
        let mut a = vec![ev("run.open", true, 0), ev("wave.commit", true, 3)];
        let mut b = vec![
            ev("run.open", true, 0),
            ev("net.send", false, 1), // per-party detail must not diverge the skeleton
            ev("wave.commit", true, 3),
        ];
        a[1].payload.msgs = 7;
        b[2].payload.msgs = 9; // payloads differ per party by design
        assert_eq!(skeleton(&a), skeleton(&b));
        assert!(check_skeletons(&[a, b]).is_ok());
    }

    #[test]
    fn check_skeletons_catches_identity_divergence() {
        let a = vec![ev("run.open", true, 0), ev("wave.commit", true, 3)];
        let b = vec![ev("run.open", true, 0), ev("wave.commit", true, 4)];
        let err = check_skeletons(&[a.clone(), b]).unwrap_err();
        assert!(err.contains("diverges"), "{err}");
        let short = vec![ev("run.open", true, 0)];
        let err = check_skeletons(&[a, short]).unwrap_err();
        assert!(err.contains("lockstep events"), "{err}");
    }

    #[test]
    fn merge_sums_msgs_and_maxes_rounds() {
        let mut a = vec![ev("wave.commit", true, 1)];
        let mut b = vec![ev("net.send", false, 1), ev("wave.commit", true, 1)];
        a[0].payload = Payload { msgs: 2, bytes: 10, rounds: 4, compute_ns: 5, value: 9 };
        b[1].payload = Payload { msgs: 3, bytes: 1, rounds: 6, compute_ns: 2, value: 1 };
        let m = merge_lockstep(&[a, b]);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].payload.msgs, 5);
        assert_eq!(m[0].payload.bytes, 11);
        assert_eq!(m[0].payload.rounds, 6);
        assert_eq!(m[0].payload.compute_ns, 5);
        assert_eq!(m[0].payload.value, 9, "gauge value comes from party 0");
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::default();
        assert!(!t.enabled());
        t.record("net.send", Phase::Online, false, Payload::default());
        t.set_tick(7);
        assert!(t.take().is_empty());
    }

    #[test]
    fn cursor_stamps_events() {
        let mut t = Trace::default();
        t.enable();
        t.set_tick(5);
        t.set_wave(1, 9);
        t.set_gate(2);
        t.record("gate.matmul", Phase::Online, true, Payload::gauge(3));
        t.clear_wave();
        t.record("refill.tick", Phase::Offline, true, Payload::default());
        let evs = t.take();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].key(), ("gate.matmul", Phase::Online as u8, Some(1), Some(9), Some(2), 5));
        assert_eq!(evs[1].key(), ("refill.tick", Phase::Offline as u8, None, None, None, 5));
        assert!(!t.enabled(), "take() disables the sink");
    }

    #[test]
    fn counters_saturating_sub_never_wraps() {
        let a = Counters { msgs: [3, 1], rounds: [0, 2], clock: [0.5, 0.0], ..Counters::default() };
        let b = Counters { msgs: [1, 4], rounds: [1, 0], clock: [1.0, 0.0], ..Counters::default() };
        let d = a.saturating_sub(&b);
        assert_eq!(d.msgs, [2, 0], "underflow saturates to 0");
        assert_eq!(d.rounds, [0, 2]);
        assert_eq!(d.clock, [0.0, 0.0]);
    }

    #[test]
    fn window_survives_clock_reset_with_saturating_diff() {
        use crate::net::{NetProfile, P1, P2};
        let run = crate::proto::run_4pc(NetProfile::zero(), 71, |ctx| {
            let w = Window::open(ctx.net);
            ctx.online(|ctx| {
                if ctx.id() == P1 {
                    ctx.send_ring1(P2, crate::ring::Z64(5));
                }
                if ctx.id() == P2 {
                    ctx.recv_ring1::<crate::ring::Z64>(P1).map(|_| ())
                } else {
                    Ok(())
                }
            })?;
            let before = w.diff(ctx.net);
            // reset_clocks zeroes the round counters: the same window must
            // now read 0 rounds, not panic on u64 underflow
            ctx.net.reset_clocks();
            let after = w.diff(ctx.net);
            Ok((before, after))
        });
        let (outs, _) = run.expect_ok();
        let (before, after) = outs[1];
        assert_eq!(before.msgs(Phase::Online), 1);
        assert_eq!(before.rounds(Phase::Online), 1);
        assert_eq!(after.msgs(Phase::Online), 1, "sent counters are not reset");
        assert_eq!(after.rounds(Phase::Online), 0, "reset rounds saturate, never wrap");
    }
}
