//! Input-sharing protocols: `Π_Sh` (Fig. 1), `Π_aSh` (Fig. 2),
//! `Π_vSh` (Fig. 7).
//!
//! Mask-sampling scopes follow the paper exactly:
//! * dealer `P0`: each `λ_{v,j}` from the triple key `P\{P_j}` — P0 holds all
//!   triple keys, so it knows the whole mask;
//! * dealer `P_k` (evaluator): `λ_{v,k}` from the all-party key, the others
//!   from `P\{P_j}` — again the dealer knows the whole mask, and each
//!   evaluator `P_j` misses exactly `λ_{v,j}`;
//! * verifiable `Π_vSh(P_i, P_j, ·)`: components indexed by `{i,j}∩{1,2,3}`
//!   come from the all-party key so that **both** owners can compute `m_v`
//!   (P0, when an owner, knows every mask anyway).

use crate::net::{Abort, PartyId, EVALUATORS, P0};
use crate::ring::{Matrix, Ring};
use crate::setup::Scope;
use crate::sharing::{MMat, MShare, RShare};

use super::Ctx;

/// Which scope component `j` of a sharing dealt by `dealer` is drawn from.
fn lam_scope(dealer: PartyId, j: PartyId) -> Scope {
    if dealer.is_evaluator() && dealer == j {
        Scope::All
    } else {
        Scope::Excl(j)
    }
}

/// Draw the λ components for `n` sharings dealt by `dealer` — the single
/// source of truth for the dealer scope pattern of `Π_Sh`. Components are
/// drawn **per scope** (one bulk `sample_vec` per component) instead of
/// `n` interleaved per-element draws; the per-scope PRF streams are
/// independent, so the values are draw-for-draw what the per-element path
/// would have produced while the keystream refills in one pass — the
/// flat-buffer fill path of [`share_many_n`]/[`share_mat_n`] and
/// [`crate::pool::mat`]'s pooled wire masks. Returns the component vectors
/// indexed `j − 1` (`None` where this party's scopes do not cover them).
pub(crate) fn sample_mask_vecs<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    n: usize,
) -> [Option<Vec<R>>; 3] {
    let me = ctx.id();
    let mut lam: [Option<Vec<R>>; 3] = [None, None, None];
    for j in EVALUATORS {
        let scope = lam_scope(dealer, j);
        if scope.holds(me) {
            lam[(j.0 - 1) as usize] = Some(ctx.keys.sample_vec(scope, n));
        }
    }
    lam
}

/// The full mask `Λ = λ1 + λ2 + λ3` per element, where all three component
/// vectors are held (the dealer, and P0). Shared with
/// [`crate::pool::mat::sample_wire_mask`] so the pooled==inline mask
/// invariant lives in one place.
pub(crate) fn full_masks<R: Ring>(lam: &[Option<Vec<R>>; 3], n: usize) -> Option<Vec<R>> {
    match (&lam[0], &lam[1], &lam[2]) {
        (Some(l1), Some(l2), Some(l3)) => {
            Some((0..n).map(|i| l1[i] + l2[i] + l3[i]).collect())
        }
        _ => None,
    }
}

/// Assemble a party's SoA matrix share from per-scope λ component vectors
/// (`m` present at evaluators only). The single source of truth for the
/// Eval/Helper component layout — [`share_mat_n`] and
/// [`crate::pool::mat::sample_wire_mask`] both build through it, so a
/// layout change cannot desync pooled wire masks from inline sharings.
pub(crate) fn assemble_mmat<R: Ring>(
    me: PartyId,
    mut lam: [Option<Vec<R>>; 3],
    m: Option<Matrix<R>>,
    rows: usize,
    cols: usize,
) -> MMat<R> {
    let mut take = |j: u8| {
        Matrix::from_vec(rows, cols, lam[(j - 1) as usize].take().expect("λ held"))
    };
    if me.is_evaluator() {
        MMat::Eval {
            m: m.expect("evaluator holds m"),
            lam_next: take(me.next_evaluator().0),
            lam_prev: take(me.prev_evaluator().0),
        }
    } else {
        MMat::Helper { lam: [take(1), take(2), take(3)] }
    }
}

/// `Π_Sh(P_i, v)` — dealer `dealer` shares `v` (Fig. 1). Pass `Some(v)` at
/// the dealer, `None` elsewhere. Offline: non-interactive mask sampling.
/// Online: one round, ≤ 3ℓ bits; evaluators cross-check `m_v` (batched).
pub fn share<R: Ring>(ctx: &mut Ctx, dealer: PartyId, v: Option<R>) -> Result<MShare<R>, Abort> {
    share_many_n(ctx, dealer, v.map(|x| vec![x]).as_deref(), 1).map(|mut v| v.pop().unwrap())
}

/// Batched [`share`]: one message carries all values (single round). The
/// batch size is taken from the dealer's slice; every party must call with
/// the same implied size, which non-dealers pass via [`share_many_n`] when
/// they cannot infer it. This convenience wrapper requires the dealer's
/// slice at the dealer and infers `n` from it at other parties via the
/// public circuit topology embedded in the call site (both sides pass the
/// same `n`).
pub fn share_many<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    vs: Option<&[R]>,
) -> Result<Vec<MShare<R>>, Abort> {
    // Batch size is public circuit structure. When the caller is not the
    // dealer it must know n anyway; we recover it from the dealer's first
    // message only in the explicit-n variant. Here: all callers in this
    // crate pass vs=Some at the dealer and know n statically — assert and
    // delegate.
    let n = match vs {
        Some(v) => v.len(),
        None => panic!(
            "share_many without values requires the explicit-n variant \
             share_many_n (batch size is public circuit structure)"
        ),
    };
    share_many_n(ctx, dealer, vs, n)
}

/// The online delivery of `Π_Sh`: the dealer sends `m = v + Λ` to the other
/// evaluators; every evaluator cross-checks the common `m`. Returns my
/// `m`-vector (`None` at P0 when it is not the dealer's audience… P0 never
/// holds `m`). Shared by [`share_many_n`] and [`share_mat_n`] and
/// message-for-message the delivery of [`share_mat_with_mask`] — the
/// pooled==inline equivalence suite pins that; change them together.
fn share_deliver<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    vs: Option<&[R]>,
    full: Option<&[R]>,
    n: usize,
) -> Result<Option<Vec<R>>, Abort> {
    let me = ctx.id();
    if me == dealer {
        let vs = vs.expect("dealer must supply values");
        assert_eq!(vs.len(), n);
        let f = full.expect("dealer knows the full mask");
        let ms: Vec<R> = vs.iter().zip(f).map(|(&v, &l)| v + l).collect();
        for p in EVALUATORS {
            if p != me {
                ctx.send_ring(p, &ms);
            }
        }
        if me.is_evaluator() {
            ctx.crosscheck_ring(&ms);
            Ok(Some(ms))
        } else {
            Ok(None)
        }
    } else if me.is_evaluator() {
        let ms: Vec<R> = ctx.recv_ring(dealer, n)?;
        ctx.crosscheck_ring(&ms);
        Ok(Some(ms))
    } else {
        // P0, not dealer: holds only the mask components
        Ok(None)
    }
}

/// [`share_many`] with an explicit public batch size `n`.
pub fn share_many_n<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    vs: Option<&[R]>,
    n: usize,
) -> Result<Vec<MShare<R>>, Abort> {
    let me = ctx.id();
    let lam = ctx.offline(|ctx| sample_mask_vecs::<R>(ctx, dealer, n));
    let full = full_masks(&lam, n);

    ctx.online(|ctx| {
        let my_m = share_deliver(ctx, dealer, vs, full.as_deref(), n)?;
        Ok((0..n)
            .map(|i| {
                if me.is_evaluator() {
                    MShare::Eval {
                        m: my_m.as_ref().expect("evaluator holds m")[i],
                        lam_next: lam[(me.next_evaluator().0 - 1) as usize]
                            .as_ref()
                            .expect("next λ held")[i],
                        lam_prev: lam[(me.prev_evaluator().0 - 1) as usize]
                            .as_ref()
                            .expect("prev λ held")[i],
                    }
                } else {
                    MShare::Helper {
                        lam: [
                            lam[0].as_ref().expect("P0 holds λ1")[i],
                            lam[1].as_ref().expect("P0 holds λ2")[i],
                            lam[2].as_ref().expect("P0 holds λ3")[i],
                        ],
                    }
                }
            })
            .collect())
    })
}

/// Share a whole matrix from `dealer` (batched `Π_Sh`; the shape is public
/// circuit structure). Pass the clear matrix at the dealer, `None`
/// elsewhere. **Flat path**: the mask components are drawn per scope into
/// SoA component matrices and the share is assembled directly — no
/// per-element [`MShare`] materialisation, no `from_shares` pass.
pub fn share_mat_n<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    m: Option<&Matrix<R>>,
    rows: usize,
    cols: usize,
) -> Result<MMat<R>, Abort> {
    let me = ctx.id();
    let n = rows * cols;
    if let Some(m) = m {
        assert_eq!((m.rows(), m.cols()), (rows, cols), "dealer matrix shape");
    }
    let lam = ctx.offline(|ctx| sample_mask_vecs::<R>(ctx, dealer, n));
    let full = full_masks(&lam, n);

    ctx.online(|ctx| {
        let my_m = share_deliver(ctx, dealer, m.map(Matrix::data), full.as_deref(), n)?;
        let m_mat = my_m.map(|v| Matrix::from_vec(rows, cols, v));
        Ok(assemble_mmat(me, lam, m_mat, rows, cols))
    })
}

/// `Π_Sh` against a **pre-drawn pooled wire mask** (see
/// [`crate::pool::mat`]): the mask skeleton `Λ_X` (and, at the dealer, the
/// full mask `Λ_X = Λ_1+Λ_2+Λ_3`) was sampled at pool-fill time with the
/// dealer scope pattern of [`lam_scope`], so the online step is delivery
/// only — the dealer sends `m = X + Λ_X` to the other evaluators, who
/// cross-check it exactly as in the inline protocol. **Zero offline work**:
/// no PRF draws, no messages; this is what makes a pool-backed serving
/// wave's per-request offline phase message-free.
pub fn share_mat_with_mask<R: Ring>(
    ctx: &mut Ctx,
    dealer: PartyId,
    x: Option<&Matrix<R>>,
    skel: MMat<R>,
    full: Option<Matrix<R>>,
) -> Result<MMat<R>, Abort> {
    // NOTE: this is [`share_deliver`] (the online delivery of
    // share_many_n / share_mat_n) transplanted onto a pre-drawn mask
    // (dealer send → evaluator crosscheck → fill m). The two must stay
    // message-for-message identical — the pooled==inline equivalence suite
    // pins that; change them together.
    let me = ctx.id();
    let (rows, cols) = skel.dims();
    let n = rows * cols;
    ctx.online(|ctx| {
        let my_m: Option<Vec<R>> = if me == dealer {
            let x = x.expect("dealer must supply the clear matrix");
            assert_eq!((x.rows(), x.cols()), (rows, cols), "dealer matrix shape");
            let full = full.expect("pooled wire mask must carry the dealer's full mask");
            let ms: Vec<R> =
                x.data().iter().zip(full.data()).map(|(&v, &l)| v + l).collect();
            for p in EVALUATORS {
                if p != me {
                    ctx.send_ring(p, &ms);
                }
            }
            if me.is_evaluator() {
                ctx.crosscheck_ring(&ms);
                Some(ms)
            } else {
                None
            }
        } else if me.is_evaluator() {
            let ms: Vec<R> = ctx.recv_ring(dealer, n)?;
            ctx.crosscheck_ring(&ms);
            Some(ms)
        } else {
            None
        };
        Ok(match skel {
            MMat::Eval { lam_next, lam_prev, .. } => MMat::Eval {
                m: Matrix::from_vec(rows, cols, my_m.expect("evaluator holds m")),
                lam_next,
                lam_prev,
            },
            h @ MMat::Helper { .. } => h,
        })
    })
}

/// Re-mask an **already-shared** matrix under a pre-drawn pooled wire mask
/// (deep-circuit keyed path, layer ≥ 1): the input `[[A]]` carries an
/// online-fresh mask `Λ_A`, but the pooled `⟨Γ⟩` of the next keyed matmul
/// was pre-exchanged against the pooled `Λ_X` — so the evaluators **open
/// the mask delta** `δ = Λ_X − Λ_A` among themselves (uniform: the pooled
/// `Λ_X` is fresh and never revealed, so `δ` leaks nothing about `Λ_A` or
/// the value) and shift the public part: `m' = m + δ`, `λ' = Λ_X`. One
/// online round, `3·n` ring elements over the standard evaluator exchange
/// cycle (receive from next, digest-vouch prev) — a tampered delta from
/// either neighbour fails the digest check at flush, before any opened
/// value releases. P0 swaps its component view for the skeleton's. **Zero
/// offline traffic**, which is what keeps an N-layer warm keyed wave
/// offline-silent past the first layer.
pub(crate) fn remask_mat<R: Ring>(
    ctx: &mut Ctx,
    a: &MMat<R>,
    skel: MMat<R>,
) -> Result<MMat<R>, Abort> {
    let me = ctx.id();
    let (rows, cols) = a.dims();
    assert_eq!(skel.dims(), (rows, cols), "re-mask skeleton shape");
    let n = rows * cols;
    ctx.online(|ctx| {
        match (a, skel) {
            // P0's view IS the mask components: just adopt the skeleton's
            (MMat::Helper { .. }, h @ MMat::Helper { .. }) => Ok(h),
            (
                MMat::Eval { m, lam_next, lam_prev },
                MMat::Eval { lam_next: skel_next, lam_prev: skel_prev, .. },
            ) => {
                // δ_j = Λ_{X,j} − Λ_{A,j} for my two held components
                let d_next = &skel_next - lam_next;
                let d_prev = &skel_prev - lam_prev;
                ctx.send_ring(me.prev_evaluator(), d_prev.data());
                ctx.vouch_ring(me.next_evaluator(), d_next.data());
                let missing: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
                ctx.expect_ring(me.prev_evaluator(), &missing);
                let missing = Matrix::from_vec(rows, cols, missing);
                let m_new = &(&(m + &d_next) + &d_prev) + &missing;
                Ok(MMat::Eval { m: m_new, lam_next: skel_next, lam_prev: skel_prev })
            }
            _ => unreachable!("share roles are fixed per party"),
        }
    })
}

/// `Π_aSh(P0, v)` — P0 deals a ⟨·⟩-sharing in the offline phase (Fig. 2).
/// `v` is `Some` only at P0. Comm: 2ℓ bits, 1 round (offline).
pub fn ash<R: Ring>(ctx: &mut Ctx, v: Option<R>) -> Result<RShare<R>, Abort> {
    ash_many(ctx, v.map(|x| vec![x]).as_deref(), 1).map(|mut v| v.pop().unwrap())
}

/// Batched [`ash`]; `n` must be known to all parties (circuit-static).
pub fn ash_many<R: Ring>(ctx: &mut Ctx, vs: Option<&[R]>, n: usize) -> Result<Vec<RShare<R>>, Abort> {
    let me = ctx.id();
    ctx.offline(|ctx| {
        // P\{P1} samples v1, P\{P2} samples v2
        let v1: Option<Vec<R>> = ctx.sample_lam_vec(crate::net::P1, n);
        let v2: Option<Vec<R>> = ctx.sample_lam_vec(crate::net::P2, n);
        match me {
            P0 => {
                let vs = vs.expect("P0 supplies values");
                assert_eq!(vs.len(), n);
                let v1 = v1.unwrap();
                let v2 = v2.unwrap();
                let v3: Vec<R> = vs
                    .iter()
                    .zip(v1.iter().zip(v2.iter()))
                    .map(|(&v, (&a, &b))| v - a - b)
                    .collect();
                ctx.send_ring(crate::net::P1, &v3);
                ctx.send_ring(crate::net::P2, &v3);
                Ok((0..n)
                    .map(|i| RShare::Helper { v: [v1[i], v2[i], v3[i]] })
                    .collect())
            }
            crate::net::P1 => {
                let v3: Vec<R> = ctx.recv_ring(P0, n)?;
                // P1, P2 exchange H(v3)
                ctx.vouch_ring(crate::net::P2, &v3);
                ctx.expect_ring(crate::net::P2, &v3);
                let v2 = v2.unwrap();
                Ok((0..n).map(|i| RShare::Eval { next: v2[i], prev: v3[i] }).collect())
            }
            crate::net::P2 => {
                let v3: Vec<R> = ctx.recv_ring(P0, n)?;
                ctx.vouch_ring(crate::net::P1, &v3);
                ctx.expect_ring(crate::net::P1, &v3);
                let v1 = v1.unwrap();
                Ok((0..n).map(|i| RShare::Eval { next: v3[i], prev: v1[i] }).collect())
            }
            crate::net::P3 => {
                let v1 = v1.unwrap();
                let v2 = v2.unwrap();
                Ok((0..n).map(|i| RShare::Eval { next: v1[i], prev: v2[i] }).collect())
            }
            _ => unreachable!(),
        }
    })
}

/// `Π_vSh(P_i, P_j, v)` — verifiable sharing by two owners (Fig. 7).
/// `v` is `Some` at both owners. One round; ℓ bits when both owners are
/// evaluators, 2ℓ when P0 is an owner. The delivery runs in the **ambient**
/// phase: conversions invoke Π_vSh both offline (e.g. the `r` of Π_BitExt)
/// and online (e.g. the `x` of Π_A2B), exactly as the figures specify.
pub fn vsh<R: Ring>(
    ctx: &mut Ctx,
    owners: (PartyId, PartyId),
    v: Option<R>,
) -> Result<MShare<R>, Abort> {
    vsh_many(ctx, owners, v.map(|x| vec![x]).as_deref(), 1).map(|mut v| v.pop().unwrap())
}

/// One party's view of a pre-drawn `Π_vSh` mask: λ components indexed
/// `j − 1`, `None` where the party's scope does not cover them. Pooled by
/// [`crate::pool::relu`] so a keyed wave's `y`-sharing is delivery-only.
pub(crate) type VshMask<R> = [Option<R>; 3];

/// The offline half of [`vsh_many`]: draw the λ components for `n`
/// sharings owned by `(pi, pj)` — `λ_k` from `All` if `k` is an
/// (evaluator) owner, else `Excl(k)`. PRF-only, no messages; also the
/// single source of truth for the pooled masks of [`crate::pool::relu`],
/// which must follow the exact scope pattern (and draw order) of `Π_vSh`.
pub(crate) fn sample_vsh_masks<R: Ring>(
    ctx: &mut Ctx,
    (pi, pj): (PartyId, PartyId),
    n: usize,
) -> Vec<VshMask<R>> {
    let me = ctx.id();
    ctx.offline(|ctx| {
        (0..n)
            .map(|_| {
                let mut lam = [None; 3];
                for k in EVALUATORS {
                    let scope = if k == pi || k == pj { Scope::All } else { Scope::Excl(k) };
                    if scope.holds(me) {
                        lam[(k.0 - 1) as usize] = Some(ctx.keys.sample(scope));
                    }
                }
                lam
            })
            .collect()
    })
}

/// The party's `[[·]]`-skeleton (`m = 0`) for a pre-drawn `Π_vSh` mask.
pub(crate) fn vsh_mask_skeleton<R: Ring>(me: PartyId, mask: &VshMask<R>) -> MShare<R> {
    if me.is_evaluator() {
        MShare::Eval {
            m: R::ZERO,
            lam_next: mask[(me.next_evaluator().0 - 1) as usize].expect("next λ held"),
            lam_prev: mask[(me.prev_evaluator().0 - 1) as usize].expect("prev λ held"),
        }
    } else {
        MShare::Helper {
            lam: [mask[0].unwrap(), mask[1].unwrap(), mask[2].unwrap()],
        }
    }
}

/// Batched [`vsh`].
pub fn vsh_many<R: Ring>(
    ctx: &mut Ctx,
    (pi, pj): (PartyId, PartyId),
    vs: Option<&[R]>,
    n: usize,
) -> Result<Vec<MShare<R>>, Abort> {
    let masks = sample_vsh_masks(ctx, (pi, pj), n);
    vsh_deliver(ctx, (pi, pj), vs, &masks)
}

/// The online half of [`vsh_many`]: owners compute `m = v + λ` over the
/// given masks (pre-drawn inline or popped from a pool), the sender
/// delivers, the co-owner vouches, the recipient cross-checks — runs in
/// the **ambient** phase, message-for-message the delivery of `Π_vSh`.
pub(crate) fn vsh_deliver<R: Ring>(
    ctx: &mut Ctx,
    (pi, pj): (PartyId, PartyId),
    vs: Option<&[R]>,
    masks: &[VshMask<R>],
) -> Result<Vec<MShare<R>>, Abort> {
    assert_ne!(pi, pj);
    assert!(pi.is_evaluator(), "sender P_i must be an evaluator");
    let me = ctx.id();
    let n = masks.len();
    let is_owner = me == pi || me == pj;
    if is_owner {
        assert_eq!(vs.expect("owners must supply values").len(), n);
    }

    (|ctx: &mut Ctx| {
        // owners compute m = v + λ (they hold all components)
        let ms_if_owner: Option<Vec<R>> = is_owner.then(|| {
            vs.unwrap()
                .iter()
                .zip(masks.iter())
                .map(|(&v, lam)| v + lam[0].unwrap() + lam[1].unwrap() + lam[2].unwrap())
                .collect()
        });

        // recipients = evaluators that are not owners
        let recipients: Vec<PartyId> =
            EVALUATORS.into_iter().filter(|&p| p != pi && p != pj).collect();

        let my_m: Option<Vec<R>> = if me == pi {
            let ms = ms_if_owner.clone().unwrap();
            for &p in &recipients {
                ctx.send_ring(p, &ms);
            }
            Some(ms)
        } else if me == pj {
            let ms = ms_if_owner.clone().unwrap();
            for &p in &recipients {
                ctx.vouch_ring(p, &ms);
            }
            Some(ms)
        } else if me.is_evaluator() {
            let ms: Vec<R> = ctx.recv_ring(pi, n)?;
            ctx.expect_ring(pj, &ms);
            Some(ms)
        } else {
            None
        };

        Ok((0..n)
            .map(|i| {
                if me.is_evaluator() {
                    let lam = masks[i];
                    MShare::Eval {
                        m: my_m.as_ref().unwrap()[i],
                        lam_next: lam[(me.next_evaluator().0 - 1) as usize].expect("next λ"),
                        lam_prev: lam[(me.prev_evaluator().0 - 1) as usize].expect("prev λ"),
                    }
                } else {
                    let lam = masks[i];
                    MShare::Helper {
                        lam: [lam[0].unwrap(), lam[1].unwrap(), lam[2].unwrap()],
                    }
                }
            })
            .collect())
    })(ctx)
}

/// Three parallel `Π_vSh` instances with the cyclic owner pattern
/// `(P1,P3), (P2,P1), (P3,P2)` used by `Π_B2A` and `Π_BitInj` — every
/// evaluator sends one message, vouches one hash and receives one message,
/// so the whole trio completes in **one** round (3ℓ bits for ℓ-bit
/// batches), matching Lemmas C.10/C.11.
pub fn vsh_cycle<R: Ring>(
    ctx: &mut Ctx,
    vals: [Option<&[R]>; 3],
    n: usize,
) -> Result<[Vec<MShare<R>>; 3], Abort> {
    use crate::net::{P1, P2, P3};
    let owners = [(P1, P3), (P2, P1), (P3, P2)];
    let me = ctx.id();
    // masks for each sharing, in fixed order
    let mut masks: Vec<Vec<[Option<R>; 3]>> = Vec::with_capacity(3);
    for (pi, pj) in owners {
        let m: Vec<[Option<R>; 3]> = ctx.offline(|ctx| {
            (0..n)
                .map(|_| {
                    let mut lam = [None; 3];
                    for k in EVALUATORS {
                        let scope =
                            if k == pi || k == pj { Scope::All } else { Scope::Excl(k) };
                        if scope.holds(me) {
                            lam[(k.0 - 1) as usize] = Some(ctx.keys.sample(scope));
                        }
                    }
                    lam
                })
                .collect()
        });
        masks.push(m);
    }
    // compute my m-vectors where I am an owner
    let mut ms: [Option<Vec<R>>; 3] = [None, None, None];
    for (idx, (pi, pj)) in owners.into_iter().enumerate() {
        if me == pi || me == pj {
            let vs = vals[idx].expect("owner supplies values");
            assert_eq!(vs.len(), n);
            ms[idx] = Some(
                vs.iter()
                    .zip(&masks[idx])
                    .map(|(&v, lam)| v + lam[0].unwrap() + lam[1].unwrap() + lam[2].unwrap())
                    .collect(),
            );
        }
    }
    // sends first (parallel round): sender pi → the non-owner evaluator
    if me.is_evaluator() {
        for (idx, (pi, pj)) in owners.into_iter().enumerate() {
            let recipient = EVALUATORS.into_iter().find(|&p| p != pi && p != pj).unwrap();
            if me == pi {
                ctx.send_ring(recipient, ms[idx].as_ref().unwrap());
            } else if me == pj {
                ctx.vouch_ring(recipient, ms[idx].as_ref().unwrap());
            }
        }
        // receive the one sharing I don't own
        for (idx, (pi, pj)) in owners.into_iter().enumerate() {
            if me != pi && me != pj {
                let got: Vec<R> = ctx.recv_ring(pi, n)?;
                ctx.expect_ring(pj, &got);
                ms[idx] = Some(got);
            }
        }
    }
    // assemble shares
    let build = |idx: usize, ms: &[Option<Vec<R>>; 3], masks: &[Vec<[Option<R>; 3]>]| {
        (0..n)
            .map(|i| {
                let lam = masks[idx][i];
                if me.is_evaluator() {
                    MShare::Eval {
                        m: ms[idx].as_ref().unwrap()[i],
                        lam_next: lam[(me.next_evaluator().0 - 1) as usize].unwrap(),
                        lam_prev: lam[(me.prev_evaluator().0 - 1) as usize].unwrap(),
                    }
                } else {
                    MShare::Helper {
                        lam: [lam[0].unwrap(), lam[1].unwrap(), lam[2].unwrap()],
                    }
                }
            })
            .collect::<Vec<_>>()
    };
    Ok([build(0, &ms, &masks), build(1, &ms, &masks), build(2, &ms, &masks)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2, P3};
    use crate::ring::{Bit, Z64};
    use crate::sharing::{open, open_rss};

    fn open_from_outputs<R: Ring>(outs: [MShare<R>; 4]) -> R {
        open(&outs)
    }

    #[test]
    fn share_by_each_dealer_opens_correctly() {
        for dealer in crate::net::ALL {
            let run = super::super::run_4pc(NetProfile::zero(), 11, move |ctx| {
                let v = (ctx.id() == dealer).then_some(Z64(123456));
                let sh = share(ctx, dealer, v)?;
                ctx.flush_verify()?;
                Ok(sh)
            });
            let (outs, report) = run.expect_ok();
            assert_eq!(open_from_outputs(outs), Z64(123456), "dealer {dealer}");
            // online: exactly one data round (verification is amortized)
            assert_eq!(report.rounds[1], 1, "dealer {dealer}");
            let expected_bits = if dealer == P0 { 3 * 64 } else { 2 * 64 };
            assert_eq!(report.value_bits[1], expected_bits, "dealer {dealer}");
        }
    }

    #[test]
    fn share_boolean_world() {
        let run = super::super::run_4pc(NetProfile::zero(), 12, |ctx| {
            let v = (ctx.id() == P2).then_some(Bit(true));
            let sh = share(ctx, P2, v)?;
            ctx.flush_verify()?;
            Ok(sh)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open_from_outputs(outs), Bit(true));
    }

    #[test]
    fn share_many_batches_one_round() {
        let run = super::super::run_4pc(NetProfile::zero(), 13, |ctx| {
            let vs = (ctx.id() == P1).then(|| (0..50u64).map(Z64).collect::<Vec<_>>());
            let sh = share_many_n(ctx, P1, vs.as_deref(), 50)?;
            ctx.flush_verify()?;
            Ok(sh)
        });
        let (outs, report) = run.expect_ok();
        // one data round for the whole batch
        assert_eq!(report.rounds[1], 1);
        for i in 0..50 {
            assert_eq!(
                open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]),
                Z64(i as u64)
            );
        }
    }

    #[test]
    fn ash_opens_and_costs_2l() {
        let run = super::super::run_4pc(NetProfile::zero(), 14, |ctx| {
            let v = (ctx.id() == P0).then_some(Z64(777));
            let sh = ash(ctx, v)?;
            ctx.flush_verify()?;
            Ok(sh)
        });
        let (outs, report) = run.expect_ok();
        let rss = [
            match outs[1] {
                s @ RShare::Eval { .. } => s,
                _ => panic!(),
            },
            match outs[2] {
                s @ RShare::Eval { .. } => s,
                _ => panic!(),
            },
            match outs[3] {
                s @ RShare::Eval { .. } => s,
                _ => panic!(),
            },
        ];
        assert_eq!(open_rss(&rss), Z64(777));
        // offline comm 2ℓ, nothing online
        assert_eq!(report.value_bits[0], 128);
        assert_eq!(report.value_bits[1], 0);
        // P0's helper view matches
        if let RShare::Helper { v } = outs[0] {
            assert_eq!(v[0] + v[1] + v[2], Z64(777));
        } else {
            panic!("P0 should be helper");
        }
    }

    #[test]
    fn vsh_evaluator_pair_costs_l() {
        let run = super::super::run_4pc(NetProfile::zero(), 15, |ctx| {
            let v = (ctx.id() == P1 || ctx.id() == P3).then_some(Z64(31415));
            let sh = vsh(ctx, (P1, P3), v)?;
            ctx.flush_verify()?;
            Ok(sh)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open_from_outputs(outs), Z64(31415));
        assert_eq!(report.value_bits[1], 64); // ℓ bits: P1→P2 only
    }

    #[test]
    fn vsh_with_p0_costs_2l() {
        let run = super::super::run_4pc(NetProfile::zero(), 16, |ctx| {
            let v = (ctx.id() == P3 || ctx.id() == P0).then_some(Z64(2718));
            let sh = vsh(ctx, (P3, P0), v)?;
            ctx.flush_verify()?;
            Ok(sh)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open_from_outputs(outs), Z64(2718));
        assert_eq!(report.value_bits[1], 128); // 2ℓ: P3→P1, P3→P2
    }

    #[test]
    fn malicious_dealer_inconsistent_m_detected() {
        // dealer P0 sends different m to P1 vs P2/P3 → crosscheck aborts
        let run = super::super::run_4pc_timeout(
            NetProfile::zero(),
            17,
            std::time::Duration::from_millis(500),
            |ctx| {
                if ctx.id() == P0 {
                    // cheat: emulate Π_Sh but with inconsistent m values
                    ctx.offline(|ctx| {
                        let _ = sample_mask_vecs::<Z64>(ctx, P0, 1);
                    });
                    ctx.online(|ctx| {
                        ctx.send_ring1(P1, Z64(1));
                        ctx.send_ring1(P2, Z64(2)); // inconsistent!
                        ctx.send_ring1(P3, Z64(1));
                    });
                    return Ok(());
                }
                let _sh = share::<Z64>(ctx, P0, None)?;
                ctx.flush_verify()?;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "evaluators must detect inconsistent m_v");
    }

    #[test]
    fn malicious_p0_bad_v3_in_ash_detected() {
        let run = super::super::run_4pc_timeout(
            NetProfile::zero(),
            18,
            std::time::Duration::from_millis(500),
            |ctx| {
                if ctx.id() == P0 {
                    ctx.offline(|ctx| {
                        let _v1: Vec<Z64> = ctx.sample_lam_vec(P1, 1).unwrap();
                        let _v2: Vec<Z64> = ctx.sample_lam_vec(P2, 1).unwrap();
                        // send DIFFERENT v3 to P1 and P2
                        ctx.send_ring1(P1, Z64(111));
                        ctx.send_ring1(P2, Z64(222));
                    });
                    return Ok(());
                }
                let _ = ash::<Z64>(ctx, None)?;
                ctx.flush_verify()?;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "P1/P2 must detect inconsistent v3");
    }
}
