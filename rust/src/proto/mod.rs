//! The 4PC protocol suite (paper §III–§V).
//!
//! Every protocol is written as a **party program**: a single function that
//! all four parties execute (over [`crate::net::run_cluster`]) with behaviour
//! branching on `ctx.id()`. Messages really flow; consistency checks really
//! run. Verification hashes are *deferred and batched* exactly as the paper's
//! amortization arguments require ("the exchange of hash values for every
//! multiplication gate can be delayed until the output reconstruction
//! stage", §III-C): [`Ctx::vouch`]/[`Ctx::expect`] accumulate per-peer
//! SHA-256 transcripts and [`Ctx::flush_verify`] exchanges one digest per
//! direction.
//!
//! Protocols switch phases internally ([`Ctx::offline`]/[`Ctx::online`]) so
//! that the metered bytes/rounds/virtual-time land in the right bucket even
//! when a caller interleaves gates.
//!
//! Boolean messages are **byte-packed on the wire** (8 shares per payload
//! byte) while the meter keeps counting lemma-accurate analytic bits —
//! payload bytes and metered bits intentionally diverge for boolean
//! traffic; see the metering contract documented at [`Ctx::send_ring`].

pub mod dotp;
pub mod mult;
pub mod reconstruct;
pub mod sharing;
pub mod tetrad;
pub mod trunc;

pub use dotp::{dotp, matmul, matmul_keyed};
pub use mult::{mult, mult_many};
pub use reconstruct::{
    fair_reconstruct, reconstruct, reconstruct_mat, reconstruct_mat_to, reconstruct_to,
};
pub use tetrad::{
    fair_reconstruct_mat_to, god_reconstruct_mat, god_reconstruct_mat_to,
    reconstruct_mat_backend, reconstruct_mat_to_backend, Backend,
};
pub use sharing::{ash, share, share_mat_n, share_mat_with_mask, vsh};
pub use trunc::{
    matmul_tr, matmul_tr_keyed, matmul_tr_keyed_shared, matmul_tr_shift, mult_tr, mult_tr_many,
    trunc_pairs, TruncPair,
};

use crate::crypto::{HashAcc, Rng};
use crate::net::{
    run_cluster_timeout, Abort, ClusterRun, MsgClass, NetProfile, PartyCtx, PartyId, Phase, ALL,
};
use crate::ring::{Bit, Ring};
use crate::setup::{setup_keys, KeyChain, Scope, ZeroShare};

/// Per-party protocol context: transport + key material + deferred
/// verification transcripts.
pub struct Ctx<'a> {
    pub net: &'a mut PartyCtx,
    pub keys: KeyChain,
    /// Private per-party randomness (e.g. the challenge `c` of Π_MultTr's
    /// offline check, garbled-label sampling).
    pub rng: Rng,
    /// The garbled world's global offset R (garblers only), drawn **eagerly**
    /// at context creation so the `P\{P0}` PRF streams of the three garblers
    /// never desynchronise on lazy first use.
    pub gc_offset: Option<crate::crypto::Key>,
    /// Outgoing verification transcript per peer and phase (digest sent at
    /// flush, in the phase it was deferred from).
    vouch: [[HashAcc; 4]; 2],
    /// Expected verification transcript per peer and phase.
    expect: [[HashAcc; 4]; 2],
    /// Optional offline precomputation pool (see [`crate::pool`]): when
    /// attached and stocked, pool-aware protocols pop pre-generated
    /// correlated randomness instead of generating inline.
    pub(crate) pool: Option<crate::pool::Pool>,
}

impl<'a> Ctx<'a> {
    pub fn new(net: &'a mut PartyCtx, keys: KeyChain) -> Ctx<'a> {
        let rng = Rng::seeded(0x7031_7232 ^ ((net.id.0 as u64) << 56) ^ 0xA5A5_5A5A);
        let mut keys = keys;
        let gc_offset = net.id.is_evaluator().then(|| {
            let mut r = keys.sample_key(Scope::Excl(crate::net::P0));
            r[0] |= 1;
            r
        });
        Ctx {
            net,
            keys,
            rng,
            gc_offset,
            vouch: Default::default(),
            expect: Default::default(),
            pool: None,
        }
    }

    // ---- offline precomputation pool ------------------------------------

    /// Attach an offline precomputation pool. Pool-aware protocols
    /// (`trunc_pairs`, the λ_z draws of `mult`/`dotp`/`bit2a`, the mask
    /// material of `bitext`, and the circuit-keyed matrix correlations of
    /// `matmul_keyed`/`matmul_tr_keyed`) pop from it when stocked and fall
    /// back to inline generation otherwise. **All four parties must attach
    /// (and fill) their pools in lockstep** — pool consumption is part of
    /// the public protocol schedule, exactly like the PRF streams it
    /// caches.
    pub fn attach_pool(&mut self, pool: crate::pool::Pool) {
        self.pool = Some(pool);
    }

    /// Detach and return the pool (e.g. to inspect [`crate::pool::PoolStats`]).
    pub fn detach_pool(&mut self) -> Option<crate::pool::Pool> {
        self.pool.take()
    }

    /// Mutable access to the attached pool, if any.
    pub fn pool_mut(&mut self) -> Option<&mut crate::pool::Pool> {
        self.pool.as_mut()
    }

    pub fn has_pool(&self) -> bool {
        self.pool.is_some()
    }

    #[inline]
    pub fn id(&self) -> PartyId {
        self.net.id
    }

    #[inline]
    pub fn is_evaluator(&self) -> bool {
        self.net.id.is_evaluator()
    }

    /// Run `f` with the context switched to `phase`, restoring after.
    pub fn in_phase<T>(&mut self, phase: Phase, f: impl FnOnce(&mut Self) -> T) -> T {
        let prev = self.net.phase();
        self.net.set_phase(phase);
        let out = f(self);
        self.net.set_phase(prev);
        out
    }

    pub fn offline<T>(&mut self, f: impl FnOnce(&mut Self) -> T) -> T {
        self.in_phase(Phase::Offline, f)
    }

    pub fn online<T>(&mut self, f: impl FnOnce(&mut Self) -> T) -> T {
        self.in_phase(Phase::Online, f)
    }

    // ---- ring-element wire helpers -------------------------------------
    //
    // ## Metering contract: payload bytes vs analytic bits
    //
    // Ring slices travel under the **bulk wire codec**
    // ([`Ring::to_wire_bulk`]): byte-granular rings serialize to
    // `n·WIRE_BYTES` bytes, and boolean slices pack 8 bits per byte —
    // `⌈n/8⌉` payload bytes for an `n`-bit message. The analytic meter
    // ([`crate::net::Meter`], fed through `send_with_bits`) keeps counting
    // `n·BITS` bits regardless, because that is what the paper's
    // communication lemmas (Appendices B–D) and the §VI tables count.
    //
    // These two numbers **intentionally diverge for boolean messages**:
    // `NetReport::value_bits` is the lemma-accurate cost (a boolean share
    // = 1 bit), while `NetReport::value_bytes` / `PartyCtx::sent_bytes`
    // are the physical payload (8 bits/byte plus a zero-padded trailing
    // byte). A future codec change must preserve the `bits` argument of
    // `send_with_bits` as-is or it silently breaks the §VI tables; the
    // payload side is free to get tighter. Rounds are unaffected either
    // way: packing changes message *size*, never message *count*.
    //
    // The trace recorder ([`crate::obs`]) observes the same meters from
    // strictly *after* this arithmetic: `obs::Window` snapshots counters
    // and diffs them, and trace hooks never send, pad, or re-class a
    // message. Enabling tracing therefore cannot move a single number in
    // this contract — the observer-effect test in `tests/equivalence.rs`
    // pins that, and EXPERIMENTS.md §Observability documents how the
    // exported events map back onto these meters.

    /// Send a slice of ring elements (Value class; packed bulk codec on
    /// the wire, lemma-accurate analytic bits in the meter — see the
    /// metering contract above).
    pub fn send_ring<R: Ring>(&mut self, to: PartyId, vals: &[R]) {
        let mut buf = Vec::with_capacity(R::wire_len(vals.len()));
        R::to_wire_bulk(vals, &mut buf);
        self.net
            .send_with_bits(to, &buf, MsgClass::Value, (vals.len() * R::BITS) as u64);
    }

    /// Receive exactly `n` ring elements (inverse of [`Ctx::send_ring`]).
    pub fn recv_ring<R: Ring>(&mut self, from: PartyId, n: usize) -> Result<Vec<R>, Abort> {
        let (buf, class) = self.net.recv_tagged(from)?;
        if class != MsgClass::Value {
            return Err(self
                .net
                .abort(format!("expected value message from {from}, got {class:?}")));
        }
        match R::from_wire_bulk(&buf, n) {
            Some((out, used)) if used == buf.len() => Ok(out),
            Some(_) => Err(self.net.abort(format!("oversized ring message from {from}"))),
            None => Err(self
                .net
                .abort(format!("short or malformed ring message from {from}"))),
        }
    }

    /// Bulk boolean send: `n` bits travel as `⌈n/8⌉` payload bytes while
    /// the meter still counts `n` analytic bits. Alias of
    /// [`Ctx::send_ring`] over [`Bit`] for call sites that are explicitly
    /// boolean (conversions, GC bit deliveries).
    pub fn send_bits(&mut self, to: PartyId, bits: &[Bit]) {
        self.send_ring(to, bits);
    }

    /// Inverse of [`Ctx::send_bits`].
    pub fn recv_bits(&mut self, from: PartyId, n: usize) -> Result<Vec<Bit>, Abort> {
        self.recv_ring(from, n)
    }

    /// Scalar fast path: one element per message (the γ-exchange of
    /// `Π_Mult`/`Π_DotP` on the 1×1 path) encodes into a stack buffer —
    /// no per-message `Vec` allocation.
    pub fn send_ring1<R: Ring>(&mut self, to: PartyId, v: R) {
        let mut buf = [0u8; 16];
        let used = v.to_wire_into(&mut buf);
        self.net
            .send_with_bits(to, &buf[..used], MsgClass::Value, R::BITS as u64);
    }

    /// Scalar fast path: decode one element straight from the payload —
    /// no intermediate `Vec<R>`.
    pub fn recv_ring1<R: Ring>(&mut self, from: PartyId) -> Result<R, Abort> {
        let (buf, class) = self.net.recv_tagged(from)?;
        if class != MsgClass::Value {
            return Err(self
                .net
                .abort(format!("expected value message from {from}, got {class:?}")));
        }
        match R::from_wire(&buf) {
            Some((v, used)) if used == buf.len() => Ok(v),
            _ => Err(self
                .net
                .abort(format!("malformed scalar ring message from {from}"))),
        }
    }

    // ---- deferred batched verification ----------------------------------

    /// Absorb `vals` into the transcript whose digest *we* will send to `to`
    /// ("P_x sends H(v) to P_y", batched).
    pub fn vouch_ring<R: Ring>(&mut self, to: PartyId, vals: &[R]) {
        let ph = self.net.phase() as usize;
        for v in vals {
            self.vouch[ph][to.idx()].absorb_ring(v);
        }
    }

    /// Absorb `vals` into the transcript we expect `from` to vouch for.
    pub fn expect_ring<R: Ring>(&mut self, from: PartyId, vals: &[R]) {
        let ph = self.net.phase() as usize;
        for v in vals {
            self.expect[ph][from.idx()].absorb_ring(v);
        }
    }

    pub fn vouch_bytes(&mut self, to: PartyId, bytes: &[u8]) {
        let ph = self.net.phase() as usize;
        self.vouch[ph][to.idx()].absorb(bytes);
    }

    pub fn expect_bytes(&mut self, from: PartyId, bytes: &[u8]) {
        let ph = self.net.phase() as usize;
        self.expect[ph][from.idx()].absorb(bytes);
    }

    /// Evaluator broadcast-consistency check: absorb my copy of a commonly
    /// held value; at flush, digests travel cyclically (P1→P2→P3→P1) which
    /// detects any disagreement under one corruption.
    pub fn crosscheck_ring<R: Ring>(&mut self, vals: &[R]) {
        debug_assert!(self.is_evaluator());
        let next = self.id().next_evaluator();
        let prev = self.id().prev_evaluator();
        self.vouch_ring(next, vals);
        self.expect_ring(prev, vals);
    }

    /// Exchange and check all pending verification digests: one digest per
    /// non-empty (direction, phase), sent/received in the phase the items
    /// were deferred from; aborts on any mismatch. Sends go out first for
    /// both phases (non-blocking), then receives — deadlock-free.
    pub fn flush_verify(&mut self) -> Result<(), Abort> {
        for ph in [Phase::Offline, Phase::Online] {
            let mut outs: Vec<(PartyId, crate::crypto::Digest32)> = Vec::new();
            for p in ALL {
                if p != self.id() && !self.vouch[ph as usize][p.idx()].is_empty() {
                    let acc = std::mem::take(&mut self.vouch[ph as usize][p.idx()]);
                    outs.push((p, acc.finalize()));
                }
            }
            if !outs.is_empty() {
                self.in_phase(ph, |ctx| {
                    for (p, d) in outs {
                        ctx.net.send_digest(p, &d);
                    }
                });
            }
        }
        for ph in [Phase::Offline, Phase::Online] {
            for p in ALL {
                if p != self.id() && !self.expect[ph as usize][p.idx()].is_empty() {
                    let acc = std::mem::take(&mut self.expect[ph as usize][p.idx()]);
                    let want = acc.finalize();
                    self.in_phase(ph, |ctx| {
                        ctx.net.recv_digest_expect(p, &want, "batched verification")
                    })?;
                }
            }
        }
        Ok(())
    }

    /// Discard every pending vouch/expect transcript without exchanging
    /// digests. **Containment-only**: after the wave barrier has agreed an
    /// aborted wave's blast radius is one tenant, the half-accumulated
    /// transcripts of that dead wave must not poison the next wave's
    /// flush (the erring parties stopped mid-protocol, so the per-peer
    /// accumulators are asymmetric by construction). On the happy path
    /// every wave settles its own digests inside `reconstruct_mat_to`, so
    /// this only ever drops checks whose wave already failed closed.
    pub fn reset_verify(&mut self) {
        self.vouch = Default::default();
        self.expect = Default::default();
    }

    /// True if any deferred checks are pending (test hook).
    pub fn has_pending_verification(&self) -> bool {
        self.vouch
            .iter()
            .flatten()
            .chain(self.expect.iter().flatten())
            .any(|a| !a.is_empty())
    }

    // ---- correlated randomness shortcuts --------------------------------

    /// Draw λ-component `j` (scope `P\{P_j}`) if held; all holders draw.
    pub fn sample_lam<R: Ring>(&mut self, j: PartyId) -> Option<R> {
        if Scope::Excl(j).holds(self.id()) {
            Some(self.keys.sample_excl(j))
        } else {
            None
        }
    }

    pub fn sample_lam_vec<R: Ring>(&mut self, j: PartyId, n: usize) -> Option<Vec<R>> {
        if Scope::Excl(j).holds(self.id()) {
            Some(self.keys.sample_excl_vec(j, n))
        } else {
            None
        }
    }

    /// Fresh ⟨·⟩-sharing of zero (Π_Zero).
    pub fn zero_share<R: Ring>(&mut self) -> ZeroShare<R> {
        crate::setup::zero_share(&mut self.keys)
    }
}

/// Run a 4-party protocol: builds the cluster, gives each thread its
/// [`Ctx`] (keys from a simulated `F_setup` with `seed`), runs `program`.
pub fn run_4pc<T, F>(profile: NetProfile, seed: u64, program: F) -> ClusterRun<T>
where
    T: Send + 'static,
    F: Fn(&mut Ctx) -> Result<T, Abort> + Send + Sync + 'static,
{
    run_4pc_timeout(profile, seed, std::time::Duration::from_secs(30), program)
}

/// [`run_4pc`] with custom recv timeout (malicious tests use short ones).
pub fn run_4pc_timeout<T, F>(
    profile: NetProfile,
    seed: u64,
    timeout: std::time::Duration,
    program: F,
) -> ClusterRun<T>
where
    T: Send + 'static,
    F: Fn(&mut Ctx) -> Result<T, Abort> + Send + Sync + 'static,
{
    run_cluster_timeout(profile, timeout, move |net| {
        let keys = setup_keys(seed)
            .into_iter()
            .nth(net.id.idx())
            .expect("party id in range");
        // Ambient phase is Online: protocols switch to Offline internally
        // for their preprocessing blocks, and everything else a party
        // program does (verification flushes, reconstructions) is online
        // traffic — matching the paper's accounting.
        net.set_phase(Phase::Online);
        let mut ctx = Ctx::new(net, keys);
        program(&mut ctx)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2, P3};
    use crate::ring::Z64;

    #[test]
    fn run_4pc_gives_synced_keys() {
        let run = run_4pc(NetProfile::zero(), 99, |ctx| {
            let v: Z64 = ctx.keys.sample_all();
            Ok(v)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        assert_eq!(outs[2], outs[3]);
    }

    #[test]
    fn flush_verify_matches_on_agreement() {
        let run = run_4pc(NetProfile::zero(), 7, |ctx| {
            ctx.online(|ctx| {
                if ctx.is_evaluator() {
                    ctx.crosscheck_ring(&[Z64(42), Z64(43)]);
                }
                ctx.flush_verify()
            })
        });
        assert!(run.outputs.iter().all(|o| o.is_ok()));
    }

    #[test]
    fn flush_verify_aborts_on_disagreement() {
        let run = run_4pc_timeout(
            NetProfile::zero(),
            7,
            std::time::Duration::from_millis(500),
            |ctx| {
                ctx.online(|ctx| {
                    if ctx.is_evaluator() {
                        // P2 holds a different value for the "common" item
                        let v = if ctx.id() == P2 { Z64(666) } else { Z64(42) };
                        ctx.crosscheck_ring(&[v]);
                    }
                    ctx.flush_verify()
                })
            },
        );
        // at least one of P1/P3 must notice (P2's digest disagrees)
        let evs = [&run.outputs[1], &run.outputs[2], &run.outputs[3]];
        assert!(evs.iter().any(|o| o.is_err()), "someone must abort");
    }

    #[test]
    fn ring_slice_roundtrip() {
        let run = run_4pc(NetProfile::zero(), 7, |ctx| {
            ctx.online(|ctx| match ctx.id() {
                P1 => {
                    ctx.send_ring(P2, &[Z64(1), Z64(2), Z64(3)]);
                    Ok(vec![])
                }
                P2 => ctx.recv_ring::<Z64>(P1, 3),
                _ => Ok(vec![]),
            })
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(outs[2], vec![Z64(1), Z64(2), Z64(3)]);
        assert_eq!(report.value_bits[1], 192);
    }

    #[test]
    fn bit_slice_packs_8_per_byte_on_wire() {
        use crate::ring::Bit;
        let run = run_4pc(NetProfile::zero(), 8, |ctx| {
            ctx.online(|ctx| match ctx.id() {
                P1 => {
                    let bits: Vec<Bit> = (0..100).map(|i| Bit(i % 7 == 0)).collect();
                    let b0 = ctx.net.sent_bytes(crate::net::Phase::Online);
                    ctx.send_bits(P2, &bits);
                    Ok((bits, ctx.net.sent_bytes(crate::net::Phase::Online) - b0))
                }
                P2 => Ok((ctx.recv_bits(P1, 100)?, 0)),
                _ => Ok((vec![], 0)),
            })
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(outs[2].0, outs[1].0, "packed bits decode to the sent values");
        // payload: ⌈100/8⌉ = 13 bytes; meter: 100 analytic bits
        assert_eq!(outs[1].1, 13, "8 bits per payload byte");
        assert_eq!(report.value_bytes[1], 13);
        assert_eq!(report.value_bits[1], 100, "lemma-accurate bit metering unchanged");
    }

    #[test]
    fn scalar_fast_path_roundtrip() {
        use crate::ring::Bit;
        let run = run_4pc(NetProfile::zero(), 9, |ctx| {
            ctx.online(|ctx| match ctx.id() {
                P1 => {
                    ctx.send_ring1(P2, Z64(0xABCD));
                    ctx.send_ring1(P2, Bit(true));
                    Ok((Z64(0), Bit(false)))
                }
                P2 => {
                    let z: Z64 = ctx.recv_ring1(P1)?;
                    let b: Bit = ctx.recv_ring1(P1)?;
                    Ok((z, b))
                }
                _ => Ok((Z64(0), Bit(false))),
            })
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(outs[2], (Z64(0xABCD), Bit(true)));
        assert_eq!(report.value_bits[1], 64 + 1);
        assert_eq!(report.value_bytes[1], 8 + 1);
    }

    #[test]
    fn phases_nest_and_restore() {
        let run = run_4pc(NetProfile::zero(), 7, |ctx| {
            ctx.online(|ctx| {
                ctx.offline(|ctx| {
                    if ctx.id() == P1 {
                        ctx.send_ring1(P3, Z64(5));
                    }
                    if ctx.id() == P3 {
                        ctx.recv_ring1::<Z64>(P1).map(|_| ())
                    } else {
                        Ok(())
                    }
                })?;
                assert_eq!(ctx.net.phase(), crate::net::Phase::Online);
                Ok(())
            })
        });
        let (_, report) = run.expect_ok();
        assert_eq!(report.value_bytes[0], 8); // landed in offline bucket
        assert_eq!(report.value_bytes[1], 0);
    }
}
