//! Output reconstruction: `Π_Rec` (Fig. 3), reconstruction towards a single
//! party, and the fair variant `Π_fRec` (Fig. 5).

use crate::net::{Abort, PartyId, EVALUATORS, P0, P1, P2, P3};
use crate::ring::{Matrix, Ring};
use crate::sharing::{MMat, MShare};

use super::Ctx;

/// `Π_Rec(P, [[v]])` — everyone learns `v`. Each party receives its missing
/// piece from one party and a (batched) hash of it from another. One round,
/// 4ℓ bits amortized (Lemma B.3).
pub fn reconstruct<R: Ring>(ctx: &mut Ctx, sh: &MShare<R>) -> Result<R, Abort> {
    reconstruct_many(ctx, std::slice::from_ref(sh)).map(|mut v| v.pop().unwrap())
}

/// Batched [`reconstruct`]: one message per direction for the whole slice.
pub fn reconstruct_many<R: Ring>(ctx: &mut Ctx, shs: &[MShare<R>]) -> Result<Vec<R>, Abort> {
    let me = ctx.id();
    let n = shs.len();
    ctx.online(|ctx| {
        match me {
            P0 => {
                // P0 vouches H(λ_i) to each evaluator, receives m_v from P1
                // and H(m_v) from P2.
                p0_vouch_lams(ctx, shs);
                let ms: Vec<R> = ctx.recv_ring(P1, n)?;
                ctx.expect_ring(P2, &ms);
                ctx.flush_verify()?;
                Ok(shs
                    .iter()
                    .zip(ms)
                    .map(|(sh, m)| match sh {
                        MShare::Helper { lam } => m - lam[0] - lam[1] - lam[2],
                        _ => panic!("P0 must hold helper share"),
                    })
                    .collect())
            }
            _ => {
                // Evaluator P_i misses λ_i; sender/vouch pattern per Fig. 3:
                //   P1 ← λ1 from P2, H from P0
                //   P2 ← λ2 from P3, H from P0
                //   P3 ← λ3 from P1, H from P0
                // and P1 sends m_v to P0, P2 vouches H(m_v) to P0.
                let (lam_src, _) = rec_sources(me);
                // what I must send: I am `lam_src` for someone, and P1/P2
                // have m-duties toward P0.
                // send duties first (non-blocking):
                for target in EVALUATORS {
                    if target != me && rec_sources(target).0 == me {
                        // I send λ_{target} for each share
                        let vals: Vec<R> = shs
                            .iter()
                            .map(|sh| sh.lam(me, target.0).expect("source holds λ_target"))
                            .collect();
                        ctx.send_ring(target, &vals);
                    }
                }
                if me == P1 {
                    let ms: Vec<R> = shs.iter().map(|sh| sh.m()).collect();
                    ctx.send_ring(P0, &ms);
                }
                if me == P2 {
                    let ms: Vec<R> = shs.iter().map(|sh| sh.m()).collect();
                    ctx.vouch_ring(P0, &ms);
                }
                // P0 vouches H(λ_i) to each evaluator — we absorb what we
                // receive and expect P0's digest over the true values.
                let lam_i: Vec<R> = ctx.recv_ring(lam_src, n)?;
                ctx.expect_ring(P0, &lam_i);
                ctx.flush_verify()?;
                Ok(shs
                    .iter()
                    .zip(lam_i)
                    .map(|(sh, li)| {
                        let ln = sh.lam(me, me.next_evaluator().0).unwrap();
                        let lp = sh.lam(me, me.prev_evaluator().0).unwrap();
                        sh.m() - li - ln - lp
                    })
                    .collect())
            }
        }
    })
}

/// For evaluator `target`, who sends it `λ_target` and who vouches.
/// (Fig. 3: P1←P2, P2←P3, P3←P1; vouch always from P0.)
fn rec_sources(target: PartyId) -> (PartyId, PartyId) {
    match target {
        P1 => (P2, P0),
        P2 => (P3, P0),
        P3 => (P1, P0),
        _ => unreachable!(),
    }
}

/// P0-side vouching for [`reconstruct_many`] must absorb the λ components
/// *before* the evaluators flush. We fold it into the same call: P0 vouches
/// all three λ-component streams. This helper is invoked from
/// `reconstruct_many` via the P0 branch — but P0's branch above only handles
/// its own receive. To keep the protocol single-pass, P0's vouching happens
/// here, called at the *start* of its branch in `reconstruct_many_v2`.
///
/// NOTE: kept as a free function for the fairness variant to reuse.
fn p0_vouch_lams<R: Ring>(ctx: &mut Ctx, shs: &[MShare<R>]) {
    for target in EVALUATORS {
        let vals: Vec<R> = shs
            .iter()
            .map(|sh| sh.lam(P0, target.0).expect("P0 holds all λ"))
            .collect();
        ctx.vouch_ring(target, &vals);
    }
}

/// Reconstruct `[[v]]` towards a subset of parties only (e.g. `Π_BitExt`
/// opens `rv` to P0 and P3). For each target: one value message + one
/// batched hash. Others send/vouch as needed and learn nothing.
pub fn reconstruct_to<R: Ring>(
    ctx: &mut Ctx,
    sh: &MShare<R>,
    targets: &[PartyId],
) -> Result<Option<R>, Abort> {
    reconstruct_to_many(ctx, std::slice::from_ref(sh), targets).map(|o| o.map(|mut v| v.pop().unwrap()))
}

/// Batched [`reconstruct_to`].
pub fn reconstruct_to_many<R: Ring>(
    ctx: &mut Ctx,
    shs: &[MShare<R>],
    targets: &[PartyId],
) -> Result<Option<Vec<R>>, Abort> {
    let me = ctx.id();
    let n = shs.len();
    ctx.online(|ctx| {
        let mut my_value: Option<Vec<R>> = None;
        // send duties
        for &t in targets {
            if t == me {
                continue;
            }
            if t == P0 {
                // P0 needs m_v: P1 sends, P2 vouches
                if me == P1 {
                    let ms: Vec<R> = shs.iter().map(|sh| sh.m()).collect();
                    ctx.send_ring(P0, &ms);
                }
                if me == P2 {
                    let ms: Vec<R> = shs.iter().map(|sh| sh.m()).collect();
                    ctx.vouch_ring(P0, &ms);
                }
            } else {
                // evaluator t needs λ_t: its rec source sends, P0 vouches
                let (src, _) = rec_sources(t);
                if me == src {
                    let vals: Vec<R> =
                        shs.iter().map(|sh| sh.lam(me, t.0).expect("src holds λ_t")).collect();
                    ctx.send_ring(t, &vals);
                }
                if me == P0 {
                    let vals: Vec<R> =
                        shs.iter().map(|sh| sh.lam(P0, t.0).expect("P0 holds λ")).collect();
                    ctx.vouch_ring(t, &vals);
                }
            }
        }
        // receive if I'm a target
        if targets.contains(&me) {
            if me == P0 {
                let ms: Vec<R> = ctx.recv_ring(P1, n)?;
                ctx.expect_ring(P2, &ms);
                my_value = Some(
                    shs.iter()
                        .zip(ms)
                        .map(|(sh, m)| match sh {
                            MShare::Helper { lam } => m - lam[0] - lam[1] - lam[2],
                            _ => panic!("P0 helper share"),
                        })
                        .collect(),
                );
            } else {
                let (src, _) = rec_sources(me);
                let lam_i: Vec<R> = ctx.recv_ring(src, n)?;
                ctx.expect_ring(P0, &lam_i);
                my_value = Some(
                    shs.iter()
                        .zip(lam_i)
                        .map(|(sh, li)| {
                            let ln = sh.lam(me, me.next_evaluator().0).unwrap();
                            let lp = sh.lam(me, me.prev_evaluator().0).unwrap();
                            sh.m() - li - ln - lp
                        })
                        .collect(),
                );
            }
        }
        // every party flushes: vouchers must deliver their digests even when
        // they are not reconstruction targets themselves.
        ctx.flush_verify()?;
        Ok(my_value)
    })
}

/// [`reconstruct_many`] over a whole matrix sharing — the flat serving
/// path: the λ-component and `m` **matrices are the message payloads**
/// (SoA slice views), so no per-element [`MShare`] vector is ever
/// materialised. Message-for-message identical to
/// `reconstruct_many(ctx, &sh.to_shares())`.
pub fn reconstruct_mat<R: Ring>(ctx: &mut Ctx, sh: &MMat<R>) -> Result<Matrix<R>, Abort> {
    let me = ctx.id();
    let (rows, cols) = sh.dims();
    let n = rows * cols;
    ctx.online(|ctx| {
        match sh {
            MMat::Helper { lam } => {
                // P0 vouches H(Λ_t) to each evaluator, receives M from P1
                // and H(M) from P2.
                for t in EVALUATORS {
                    ctx.vouch_ring(t, lam[(t.0 - 1) as usize].data());
                }
                let ms: Vec<R> = ctx.recv_ring(P1, n)?;
                ctx.expect_ring(P2, &ms);
                ctx.flush_verify()?;
                let data = ms
                    .iter()
                    .enumerate()
                    .map(|(i, &m)| m - lam[0].data()[i] - lam[1].data()[i] - lam[2].data()[i])
                    .collect();
                Ok(Matrix::from_vec(rows, cols, data))
            }
            MMat::Eval { m, lam_next, lam_prev } => {
                let (lam_src, _) = rec_sources(me);
                // send duties first (non-blocking), as in reconstruct_many
                for target in EVALUATORS {
                    if target != me && rec_sources(target).0 == me {
                        let vals = sh.lam(me, target.0).expect("source holds λ_target");
                        ctx.send_ring(target, vals.data());
                    }
                }
                if me == P1 {
                    ctx.send_ring(P0, m.data());
                }
                if me == P2 {
                    ctx.vouch_ring(P0, m.data());
                }
                let lam_i: Vec<R> = ctx.recv_ring(lam_src, n)?;
                ctx.expect_ring(P0, &lam_i);
                ctx.flush_verify()?;
                let data = (0..n)
                    .map(|i| m.data()[i] - lam_i[i] - lam_next.data()[i] - lam_prev.data()[i])
                    .collect();
                Ok(Matrix::from_vec(rows, cols, data))
            }
        }
    })
}

/// [`reconstruct_to_many`] over a whole matrix sharing — the flat serving
/// delivery (`serve`'s reconstruct-to-owner stage): SoA payloads, no
/// intermediate share vector. Message-for-message identical to
/// `reconstruct_to_many(ctx, &sh.to_shares(), targets)`.
pub fn reconstruct_mat_to<R: Ring>(
    ctx: &mut Ctx,
    sh: &MMat<R>,
    targets: &[PartyId],
) -> Result<Option<Matrix<R>>, Abort> {
    let me = ctx.id();
    let (rows, cols) = sh.dims();
    let n = rows * cols;
    ctx.online(|ctx| {
        let mut my_value: Option<Matrix<R>> = None;
        // send duties
        for &t in targets {
            if t == me {
                continue;
            }
            if t == P0 {
                if me == P1 {
                    ctx.send_ring(P0, sh.m().data());
                }
                if me == P2 {
                    ctx.vouch_ring(P0, sh.m().data());
                }
            } else {
                let (src, _) = rec_sources(t);
                if me == src {
                    ctx.send_ring(t, sh.lam(me, t.0).expect("src holds λ_t").data());
                }
                if me == P0 {
                    ctx.vouch_ring(t, sh.lam(P0, t.0).expect("P0 holds λ").data());
                }
            }
        }
        // receive if I'm a target
        if targets.contains(&me) {
            match sh {
                MMat::Helper { lam } => {
                    let ms: Vec<R> = ctx.recv_ring(P1, n)?;
                    ctx.expect_ring(P2, &ms);
                    let data = ms
                        .iter()
                        .enumerate()
                        .map(|(i, &m)| {
                            m - lam[0].data()[i] - lam[1].data()[i] - lam[2].data()[i]
                        })
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
                MMat::Eval { m, lam_next, lam_prev } => {
                    let (src, _) = rec_sources(me);
                    let lam_i: Vec<R> = ctx.recv_ring(src, n)?;
                    ctx.expect_ring(P0, &lam_i);
                    let data = (0..n)
                        .map(|i| {
                            m.data()[i] - lam_i[i] - lam_next.data()[i] - lam_prev.data()[i]
                        })
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
            }
        }
        // every party flushes, exactly as in reconstruct_to_many
        ctx.flush_verify()?;
        Ok(my_value)
    })
}

/// `Π_fRec` (Fig. 5) — fair reconstruction: liveness bits through P0,
/// majority agreement on continue/abort, then missing shares delivered with
/// 2-of-3 redundancy so every party picks the majority value.
///
/// `ok` is each party's local verification verdict going in.
pub fn fair_reconstruct<R: Ring>(ctx: &mut Ctx, sh: &MShare<R>, ok: bool) -> Result<R, Abort> {
    let me = ctx.id();
    ctx.online(|ctx| {
        // Round 1: evaluators send b to P0
        if me.is_evaluator() {
            ctx.net
                .send_with_bits(P0, &[ok as u8], crate::net::MsgClass::Value, 1);
        }
        // Round 2: P0 replies continue iff all said continue
        let go = if me == P0 {
            let mut all_ok = true;
            for p in EVALUATORS {
                let b = ctx.net.recv(p)?;
                all_ok &= b == [1u8];
            }
            for p in EVALUATORS {
                ctx.net
                    .send_with_bits(p, &[all_ok as u8], crate::net::MsgClass::Value, 1);
            }
            all_ok
        } else {
            let b = ctx.net.recv(P0)?;
            b == [1u8]
        };
        // Round 3: evaluators exchange P0's reply; honest majority decides
        let proceed = if me.is_evaluator() {
            for p in EVALUATORS {
                if p != me {
                    ctx.net
                        .send_with_bits(p, &[go as u8], crate::net::MsgClass::Value, 1);
                }
            }
            let mut votes = vec![go];
            for p in EVALUATORS {
                if p != me {
                    let b = ctx.net.recv(p)?;
                    votes.push(b == [1u8]);
                }
            }
            let yes = votes.iter().filter(|&&v| v).count();
            yes >= 2
        } else {
            go
        };
        if !proceed {
            return Err(ctx.net.abort("fair reconstruction: majority abort".into()));
        }

        // Round 4: redundant share delivery; receiver takes the majority.
        //   P0 ← m from P1, P2 (+H from P3)
        //   P_i ← λ_i from the two other evaluators (+H from P0)
        match me {
            P0 => {
                // hash side: P0 vouches λ_t to each P_t
                for t in EVALUATORS {
                    let v = sh.lam(P0, t.0).expect("P0 holds all λ");
                    ctx.vouch_ring(t, &[v]);
                }
                let m1: R = ctx.recv_ring::<R>(P1, 1)?[0];
                let m2: R = ctx.recv_ring::<R>(P2, 1)?[0];
                ctx.expect_ring(P3, &[m1]);
                // majority of {m1, m2, H(m3)}: with one corruption, if m1≠m2
                // the hash from P3 breaks the tie.
                let m = if m1 == m2 {
                    ctx.flush_verify().ok(); // best effort: hash may mismatch if P3 corrupt
                    m1
                } else {
                    // tie-break via P3's digest
                    match ctx.flush_verify() {
                        Ok(()) => m1, // H(m1) matched P3's vouch
                        Err(_) => m2,
                    }
                };
                match sh {
                    MShare::Helper { lam } => Ok(m - lam[0] - lam[1] - lam[2]),
                    _ => unreachable!(),
                }
            }
            _ => {
                // send duties: for each other evaluator t, I hold λ_t → send
                for t in EVALUATORS {
                    if t != me {
                        let v = sh.lam(me, t.0).expect("evaluator holds peers' λ");
                        ctx.send_ring(t, &[v]);
                    }
                }
                // P0 receives m from P1 AND P2 (redundant), H(m) from P3
                if me == P1 || me == P2 {
                    ctx.send_ring(P0, &[sh.m()]);
                }
                if me == P3 {
                    // P3 vouches H(m) to P0
                    ctx.vouch_ring(P0, &[sh.m()]);
                }
                let a: R = ctx.recv_ring::<R>(me.next_evaluator(), 1)?[0];
                let b: R = ctx.recv_ring::<R>(me.prev_evaluator(), 1)?[0];
                ctx.expect_ring(P0, &[a]);
                let lam_i = if a == b {
                    ctx.flush_verify().ok();
                    a
                } else {
                    match ctx.flush_verify() {
                        Ok(()) => a,
                        Err(_) => b,
                    }
                };
                let ln = sh.lam(me, me.next_evaluator().0).unwrap();
                let lp = sh.lam(me, me.prev_evaluator().0).unwrap();
                Ok(sh.m() - lam_i - ln - lp)
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, run_4pc_timeout, share};
    use crate::ring::Z64;

    #[test]
    fn reconstruct_all_parties() {
        let run = run_4pc(NetProfile::zero(), 21, |ctx| {
            let v = (ctx.id() == P1).then_some(Z64(9999));
            let sh = share(ctx, P1, v)?;
            ctx.flush_verify()?;
            reconstruct(ctx, &sh)
        });
        let (outs, report) = run.expect_ok();
        assert!(outs.iter().all(|&v| v == Z64(9999)));
        // Π_Rec value traffic: 4ℓ bits
        assert!(report.value_bits[1] >= 4 * 64);
    }

    #[test]
    fn reconstruct_many_batches() {
        let run = run_4pc(NetProfile::zero(), 22, |ctx| {
            let vs = (ctx.id() == P0).then(|| (0..20u64).map(Z64).collect::<Vec<_>>());
            let shs = super::super::sharing::share_many_n(ctx, P0, vs.as_deref(), 20)?;
            ctx.flush_verify()?;
            reconstruct_many(ctx, &shs)
        });
        let (outs, _) = run.expect_ok();
        for o in &outs {
            assert_eq!(*o, (0..20u64).map(Z64).collect::<Vec<_>>());
        }
    }

    #[test]
    fn reconstruct_towards_subset_only() {
        let run = run_4pc(NetProfile::zero(), 23, |ctx| {
            let v = (ctx.id() == P2).then_some(Z64(555));
            let sh = share(ctx, P2, v)?;
            ctx.flush_verify()?;
            reconstruct_to(ctx, &sh, &[P0, P3])
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[0], Some(Z64(555)));
        assert_eq!(outs[3], Some(Z64(555)));
        assert_eq!(outs[1], None);
        assert_eq!(outs[2], None);
    }

    #[test]
    fn reconstruct_mat_flat_matches_elementwise() {
        use crate::ring::Matrix;
        let run = run_4pc(NetProfile::zero(), 27, |ctx| {
            let x = (ctx.id() == P1)
                .then(|| Matrix::from_fn(3, 2, |r, c| Z64((10 * r + c) as u64)));
            let sh = super::super::sharing::share_mat_n(ctx, P1, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            let all = reconstruct_mat(ctx, &sh)?;
            let subset = reconstruct_mat_to(ctx, &sh, &[P0, P2])?;
            Ok((all, subset))
        });
        let (outs, _) = run.expect_ok();
        let want = Matrix::from_fn(3, 2, |r, c| Z64((10 * r + c) as u64));
        for (p, (all, _)) in outs.iter().enumerate() {
            assert_eq!(all, &want, "P{p} full reconstruction");
        }
        assert_eq!(outs[0].1.as_ref(), Some(&want));
        assert_eq!(outs[2].1.as_ref(), Some(&want));
        assert_eq!(outs[1].1, None);
        assert_eq!(outs[3].1, None);
    }

    #[test]
    fn fair_reconstruct_happy_path() {
        let run = run_4pc(NetProfile::zero(), 24, |ctx| {
            let v = (ctx.id() == P1).then_some(Z64(31337));
            let sh = share(ctx, P1, v)?;
            ctx.flush_verify()?;
            fair_reconstruct(ctx, &sh, true)
        });
        let (outs, report) = run.expect_ok();
        assert!(outs.iter().all(|&v| v == Z64(31337)));
        // Fig. 5 / Lemma B.6: 4 online rounds
        assert!(report.rounds[1] >= 4);
    }

    #[test]
    fn fair_reconstruct_majority_abort() {
        // one evaluator claims verification failed → P0 relays abort → all abort
        let run = run_4pc_timeout(
            NetProfile::zero(),
            25,
            std::time::Duration::from_millis(500),
            |ctx| {
                let v = (ctx.id() == P1).then_some(Z64(1));
                let sh = share(ctx, P1, v)?;
                ctx.flush_verify()?;
                let ok = ctx.id() != P2; // P2 raises abort
                fair_reconstruct(ctx, &sh, ok)
            },
        );
        // all parties must abort together (fairness: no partial output)
        for o in &run.outputs {
            assert!(o.is_err(), "fairness: everyone aborts");
        }
    }

    #[test]
    fn fair_reconstruct_tolerates_wrong_share_from_one() {
        // corrupt P3 sends garbage λ1 to P1; P1 takes majority (P2's copy
        // + P0's hash) and still reconstructs correctly.
        let run = run_4pc(NetProfile::zero(), 26, |ctx| {
            let v = (ctx.id() == P1).then_some(Z64(2024));
            let sh = share(ctx, P1, v)?;
            ctx.flush_verify()?;
            if ctx.id() == P3 {
                // cheat inside fair reconstruction: send wrong λ1 to P1
                return ctx.online(|ctx| {
                    ctx.net.send_with_bits(P0, &[1u8], crate::net::MsgClass::Value, 1);
                    let _ = ctx.net.recv(P0)?;
                    for p in [P1, P2] {
                        ctx.net.send_with_bits(p, &[1u8], crate::net::MsgClass::Value, 1);
                    }
                    let _ = ctx.net.recv(P1)?;
                    let _ = ctx.net.recv(P2)?;
                    // round 4 duties, with a corrupted λ1 for P1:
                    let bad = Z64(0xBAD);
                    ctx.send_ring(P1, &[bad]);
                    let good2 = sh.lam(P3, 2).unwrap();
                    ctx.send_ring(P2, &[good2]);
                    ctx.vouch_ring(P0, &[sh.m()]);
                    let _ = ctx.recv_ring::<Z64>(P1, 1)?;
                    let _ = ctx.recv_ring::<Z64>(P2, 1)?;
                    ctx.expect_ring(P0, &[sh.lam(P3, 3).unwrap_or(Z64(0))]);
                    let _ = ctx.flush_verify();
                    Ok(Z64(0))
                });
            }
            fair_reconstruct(ctx, &sh, true)
        });
        // honest parties got the right value
        assert_eq!(run.outputs[1].as_ref().ok(), Some(&Z64(2024)));
        assert_eq!(run.outputs[2].as_ref().ok(), Some(&Z64(2024)));
        assert_eq!(run.outputs[0].as_ref().ok(), Some(&Z64(2024)));
    }
}
