//! `Π_MultTr` (Fig. 18): multiplication with truncation at **no extra online
//! cost** over `Π_Mult` — the paper's flagship ML optimisation. Instead of a
//! boolean ripple-carry circuit (ABY3's 2ℓ−2-round offline), P0 — who knows
//! the full random `r = r1+r2+r3` — locally produces the truncated pair
//! `(r, rᵗ)` and ⟨·⟩-shares `rᵗ`; the evaluators verify it with one masked
//! linear identity (`r = 2ᵈ·rᵗ + r_d`, Lemma D.1).
//!
//! Carry handling: `Σᵢ r_{d,i} = r_d + 2ᵈ·κ` with carry `κ ∈ {0,1,2}`, so
//! the honest P0 sets `rᵗ = (r ≫ₐ d) − κ` — the unique value passing the
//! check. The extra `κ` (≤ 2 ulp) is the probabilistic-truncation error
//! inherited from SecureML; tests bound it empirically.
//!
//! Online: open `z − r` (3ℓ bits, same exchange as `Π_Mult` with `−rᵢ`
//! replacing `+λ_{z,i}`), truncate the clear value locally (arithmetic
//! shift), and add `[[rᵗ]]`.

use crate::net::{Abort, EVALUATORS, P0, P1, P2};
use crate::pool::{CircuitKey, MatCorr, OpKind};
use crate::ring::{fixed::FRAC_BITS, Matrix, Z64};
use crate::sharing::{MMat, MShare, RShare};

use super::dotp::{local_share_mat, matmul_offline, pop_keyed, MatGamma};
use super::mult::{mult_offline, GammaView};
use super::sharing::{ash_many, share_mat_n, share_mat_with_mask};
use super::Ctx;

/// A verified truncation pair: additive `r`-components (those I hold) and
/// the `[[rᵗ]]` share (with `m = 0`, `λ = −rᵗ`).
#[derive(Clone, Debug)]
pub struct TruncPair {
    /// r components I hold, by index 1..=3 (None where not held).
    pub r: [Option<Z64>; 3],
    /// `[[rᵗ]]` share.
    pub rt: MShare<Z64>,
}

/// `n` verified truncation pairs for shift `d` (`FRAC_BITS` unless
/// overridden). Pool-aware: pops pre-generated pairs when an attached
/// [`crate::pool::Pool`] can serve the whole request, else runs the
/// inline Fig. 18 offline protocol ([`gen_trunc_pairs`]). The decision is
/// all-or-nothing, so all four parties take the same branch.
pub fn trunc_pairs(ctx: &mut Ctx, n: usize, d: u32) -> Result<Vec<TruncPair>, Abort> {
    if let Some(pool) = ctx.pool.as_mut() {
        if let Some(pairs) = pool.pop_trunc(d, n) {
            return Ok(pairs);
        }
    }
    gen_trunc_pairs(ctx, n, d)
}

/// Offline generation + verification of `n` truncation pairs (Fig. 18,
/// offline) — the inline path, also used by [`crate::pool::fill_trunc`].
pub(crate) fn gen_trunc_pairs(ctx: &mut Ctx, n: usize, d: u32) -> Result<Vec<TruncPair>, Abort> {
    let me = ctx.id();
    ctx.offline(|ctx| {
        // r_j sampled by P\{P_j}
        let mut r: [Option<Vec<Z64>>; 3] = [None, None, None];
        for j in EVALUATORS {
            r[(j.0 - 1) as usize] = ctx.sample_lam_vec::<Z64>(j, n);
        }
        // P0 computes rᵗ and ⟨·⟩-shares it
        let rts: Option<Vec<Z64>> = (me == P0).then(|| {
            let r1 = r[0].as_ref().unwrap();
            let r2 = r[1].as_ref().unwrap();
            let r3 = r[2].as_ref().unwrap();
            (0..n)
                .map(|i| {
                    let rr = r1[i] + r2[i] + r3[i];
                    let kappa = ((r1[i].low_bits(d).0 as u128
                        + r2[i].low_bits(d).0 as u128
                        + r3[i].low_bits(d).0 as u128)
                        >> d) as u64;
                    rr.truncate(d) - Z64(kappa)
                })
                .collect()
        });
        let rt_shares: Vec<RShare<Z64>> = ash_many(ctx, rts.as_deref(), n)?;

        // Verification (Fig. 18): P1 → (m1, H(c)) → P2; P2 checks
        // H(m1+m2) == H(c). Batched: one message, one combined digest.
        match me {
            P1 => {
                let r2 = r[1].as_ref().unwrap();
                let mut m1s = Vec::with_capacity(n);
                let mut c_acc = crate::crypto::HashAcc::new();
                for i in 0..n {
                    let c: Z64 = ctx.rng.gen();
                    let r2t = rt_shares[i].component(me, 2).expect("P1 holds r2ᵗ");
                    let m1 = r2[i] - Z64::wrapping_pow2(d) * r2t - r2[i].low_bits(d) + c;
                    m1s.push(m1);
                    c_acc.absorb_ring(&c);
                }
                ctx.send_ring(P2, &m1s);
                let digest = c_acc.finalize();
                ctx.net.send_digest(P2, &digest);
            }
            P2 => {
                let m1s: Vec<Z64> = ctx.recv_ring(P1, n)?;
                let r1 = r[0].as_ref().unwrap();
                let r3 = r[2].as_ref().unwrap();
                let mut sum_acc = crate::crypto::HashAcc::new();
                for i in 0..n {
                    let r1t = rt_shares[i].component(me, 1).expect("P2 holds r1ᵗ");
                    let r3t = rt_shares[i].component(me, 3).expect("P2 holds r3ᵗ");
                    let m2 = (r1[i] + r3[i])
                        - Z64::wrapping_pow2(d) * (r1t + r3t)
                        - (r1[i].low_bits(d) + r3[i].low_bits(d));
                    sum_acc.absorb_ring(&(m1s[i] + m2));
                }
                let want = sum_acc.finalize();
                ctx.net.recv_digest_expect(P1, &want, "Π_MultTr r/rᵗ check")?;
            }
            _ => {}
        }

        Ok((0..n)
            .map(|i| TruncPair {
                r: [
                    r[0].as_ref().map(|v| v[i]),
                    r[1].as_ref().map(|v| v[i]),
                    r[2].as_ref().map(|v| v[i]),
                ],
                rt: rt_shares[i].into_mshare(),
            })
            .collect())
    })
}

/// `Π_MultTr(x, y)` — `[[ (x·y) ≫ d ]]` at `Π_Mult`'s online cost
/// (1 round, 3ℓ bits).
pub fn mult_tr(ctx: &mut Ctx, x: &MShare<Z64>, y: &MShare<Z64>) -> Result<MShare<Z64>, Abort> {
    mult_tr_many(ctx, std::slice::from_ref(x), std::slice::from_ref(y))
        .map(|mut v| v.pop().unwrap())
}

/// Batched [`mult_tr`].
pub fn mult_tr_many(
    ctx: &mut Ctx,
    xs: &[MShare<Z64>],
    ys: &[MShare<Z64>],
) -> Result<Vec<MShare<Z64>>, Abort> {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    let me = ctx.id();
    let corr = mult_offline(ctx, xs, ys, false)?;
    let pairs = trunc_pairs(ctx, n, FRAC_BITS)?;

    ctx.online(|ctx| {
        if me == P0 {
            // P0's output share: λ_{zᵗ} = −rᵗ (from the pair)
            return Ok(pairs.iter().map(|p| p.rt).collect());
        }
        let (g_next, g_prev) = match &corr.gamma {
            GammaView::Eval { next, prev } => (next, prev),
            _ => unreachable!(),
        };
        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
        let mut zp_next = Vec::with_capacity(n);
        let mut zp_prev = Vec::with_capacity(n);
        for i in 0..n {
            let (mx, my) = (xs[i].m(), ys[i].m());
            let r_n = pairs[i].r[(jn - 1) as usize].expect("hold r_next");
            let r_p = pairs[i].r[(jp - 1) as usize].expect("hold r_prev");
            zp_next.push(
                -(xs[i].lam(me, jn).unwrap() * my) - ys[i].lam(me, jn).unwrap() * mx + g_next[i]
                    - r_n,
            );
            zp_prev.push(
                -(xs[i].lam(me, jp).unwrap() * my) - ys[i].lam(me, jp).unwrap() * mx + g_prev[i]
                    - r_p,
            );
        }
        ctx.send_ring(me.prev_evaluator(), &zp_prev);
        ctx.vouch_ring(me.next_evaluator(), &zp_next);
        let missing: Vec<Z64> = ctx.recv_ring(me.next_evaluator(), n)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);

        Ok((0..n)
            .map(|i| {
                // all evaluators learn z − r in the clear (it is uniform)
                let z_minus_r = zp_next[i] + zp_prev[i] + missing[i] + xs[i].m() * ys[i].m();
                let zt_pub = z_minus_r.truncate(FRAC_BITS);
                // [[zᵗ]] = [[ (z−r)ᵗ ]]_public + [[rᵗ]]
                pairs[i].rt.add_const(zt_pub)
            })
            .collect())
    })
}

/// Matrix variant used by ML: `[[ (X∘Y) ≫ d ]]` with 3·(a·c) online ring
/// elements (the dot-product trick + free truncation).
pub fn matmul_tr(ctx: &mut Ctx, x: &MMat<Z64>, y: &MMat<Z64>) -> Result<MMat<Z64>, Abort> {
    matmul_tr_shift(ctx, x, y, FRAC_BITS)
}

/// [`matmul_tr`] with an explicit shift: ML weight updates fold the public
/// `α/B = 2^{−k}` factor into the truncation (`shift = f + k`), so the
/// learning-rate multiplication is free.
pub fn matmul_tr_shift(
    ctx: &mut Ctx,
    x: &MMat<Z64>,
    y: &MMat<Z64>,
    shift: u32,
) -> Result<MMat<Z64>, Abort> {
    let corr = matmul_offline(ctx, x, y, false)?;
    let pairs = trunc_pairs(ctx, x.rows() * y.cols(), shift)?;
    matmul_tr_online(ctx, x, y, &corr.gamma, &pairs, shift)
}

/// Online phase of `Π_MatMulTr`, given the offline correlation (`⟨Γ⟩` for
/// the wire-mask pair and one verified truncation pair per output element).
/// Shared by the inline path above and the circuit-keyed pooled path
/// ([`matmul_tr_keyed`]), which differ only in where the correlation comes
/// from.
pub(crate) fn matmul_tr_online(
    ctx: &mut Ctx,
    x: &MMat<Z64>,
    y: &MMat<Z64>,
    gamma: &MatGamma<Z64>,
    pairs: &[TruncPair],
    shift: u32,
) -> Result<MMat<Z64>, Abort> {
    let me = ctx.id();
    let (a, c) = (x.rows(), y.cols());
    let n = a * c;
    assert_eq!(pairs.len(), n, "one truncation pair per output element");

    ctx.online(|ctx| {
        if me == P0 {
            // SoA output: P0's share is the pairs' −rᵗ components, column
            // by column — no per-element MShare round-trip
            let mut l = [
                Vec::with_capacity(n),
                Vec::with_capacity(n),
                Vec::with_capacity(n),
            ];
            for p in pairs {
                match p.rt {
                    MShare::Helper { lam } => {
                        l[0].push(lam[0]);
                        l[1].push(lam[1]);
                        l[2].push(lam[2]);
                    }
                    _ => unreachable!("P0 holds helper rt shares"),
                }
            }
            let [l1, l2, l3] = l;
            return Ok(MMat::Helper {
                lam: [
                    Matrix::from_vec(a, c, l1),
                    Matrix::from_vec(a, c, l2),
                    Matrix::from_vec(a, c, l3),
                ],
            });
        }
        let (g_next, g_prev) = match gamma {
            MatGamma::Eval { next, prev } => (next, prev),
            _ => unreachable!(),
        };
        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
        // r matrices for my two components
        let r_mat = |j: u8| {
            Matrix::from_vec(
                a,
                c,
                pairs.iter().map(|p| p.r[(j - 1) as usize].expect("hold r_j")).collect(),
            )
        };
        let neg_r_n = -&r_mat(jn);
        let neg_r_p = -&r_mat(jp);
        let zp_next = local_share_mat(ctx, x, y, g_next, &neg_r_n, jn);
        let zp_prev = local_share_mat(ctx, x, y, g_prev, &neg_r_p, jp);
        ctx.send_ring(me.prev_evaluator(), zp_prev.data());
        ctx.vouch_ring(me.next_evaluator(), zp_next.data());
        let missing: Vec<Z64> = ctx.recv_ring(me.next_evaluator(), n)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);
        let missing = Matrix::from_vec(a, c, missing);
        let mxmy = ctx.net.timed(|| crate::runtime::gemm(x.m(), y.m()));
        let z_minus_r = &(&(&zp_next + &zp_prev) + &missing) + &mxmy;

        // SoA output: m = (z − r) ≫ shift (the pairs' rt carries m = 0),
        // λ straight from the pairs' components — one pass, no
        // Vec<MShare> + from_shares round-trip
        let mut m = Vec::with_capacity(n);
        let mut l_next = Vec::with_capacity(n);
        let mut l_prev = Vec::with_capacity(n);
        for (i, p) in pairs.iter().enumerate() {
            match p.rt {
                MShare::Eval { lam_next, lam_prev, .. } => {
                    m.push(z_minus_r.data()[i].truncate(shift));
                    l_next.push(lam_next);
                    l_prev.push(lam_prev);
                }
                _ => unreachable!("evaluators hold eval rt shares"),
            }
        }
        Ok(MMat::Eval {
            m: Matrix::from_vec(a, c, m),
            lam_next: Matrix::from_vec(a, c, l_next),
            lam_prev: Matrix::from_vec(a, c, l_prev),
        })
    })
}

/// Pool-aware **circuit-keyed** `Π_MatMulTr` — the pooled serving hot path.
/// Pops the correlation pre-generated for `key` (pre-drawn input wire mask
/// `Λ_X`, pre-exchanged `⟨Γ⟩` against the resident `[[Y]]`, and one verified
/// truncation pair per output element), shares the dealer's `X` under the
/// pooled mask and runs only the online exchange: a hit performs **zero
/// offline-phase messages**, which is what makes a warm-pool serving wave's
/// per-request offline phase message-free. A miss falls back to the inline
/// share + [`matmul_tr_shift`] path; the pop decision is lockstep at all
/// four parties, so the fallback is deterministic. Material filed under a
/// different key fails closed (the popping party aborts — never a wrong
/// honest opened value). Returns the input sharing alongside the product.
pub fn matmul_tr_keyed(
    ctx: &mut Ctx,
    key: &CircuitKey,
    x_clear: Option<&Matrix<Z64>>,
    y: &MMat<Z64>,
) -> Result<(MMat<Z64>, MMat<Z64>), Abort> {
    let shift = match key.op {
        OpKind::MatMulTr { shift } => shift,
        _ => panic!("matmul_tr_keyed requires an OpKind::MatMulTr key"),
    };
    assert_eq!((key.inner, key.cols), y.dims(), "resident Y must match the key shape");
    match pop_keyed(ctx, key)? {
        Some(item) => {
            let MatCorr { lam_x, lam_x_full, gamma, pairs, .. } = item;
            let x = share_mat_with_mask(ctx, key.dealer, x_clear, lam_x, lam_x_full)?;
            let z = matmul_tr_online(ctx, &x, y, &gamma, &pairs, shift)?;
            Ok((x, z))
        }
        None => {
            let x = share_mat_n(ctx, key.dealer, x_clear, key.rows, key.inner)?;
            let z = matmul_tr_shift(ctx, &x, y, shift)?;
            Ok((x, z))
        }
    }
}

/// [`matmul_tr_keyed`] for an **already-shared** input — the deep-circuit
/// serving path (layer ≥ 1 of a resident network, whose input is the
/// previous layer's output rather than a dealer-held clear matrix). A hit
/// re-masks `[[A]]` under the bundle's pooled wire mask
/// ([`super::sharing::remask_mat`]: the evaluators open the uniform mask
/// delta `Λ_X − Λ_A` online) and runs the pre-exchanged `⟨Γ⟩` online
/// protocol — **zero offline-phase messages**, exactly like the first
/// layer. A miss falls back to the inline [`matmul_tr_shift`] directly on
/// `[[A]]` (no re-share needed); the pop decision is lockstep, so the
/// fallback is deterministic. Wrong-keyed front material fails closed.
pub fn matmul_tr_keyed_shared(
    ctx: &mut Ctx,
    key: &CircuitKey,
    a: &MMat<Z64>,
    y: &MMat<Z64>,
) -> Result<MMat<Z64>, Abort> {
    let shift = match key.op {
        OpKind::MatMulTr { shift } => shift,
        _ => panic!("matmul_tr_keyed_shared requires an OpKind::MatMulTr key"),
    };
    assert_eq!((key.inner, key.cols), y.dims(), "resident Y must match the key shape");
    assert_eq!((key.rows, key.inner), a.dims(), "shared input must match the key shape");
    match pop_keyed(ctx, key)? {
        Some(item) => {
            let MatCorr { lam_x, gamma, pairs, .. } = item;
            let x = super::sharing::remask_mat(ctx, a, lam_x)?;
            matmul_tr_online(ctx, &x, y, &gamma, &pairs, shift)
        }
        None => matmul_tr_shift(ctx, a, y, shift),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::net::{NetProfile, P1, P2, P3};
    use crate::proto::{run_4pc, run_4pc_timeout, share};
    use crate::ring::fixed::{FixedPoint, SCALE};
    use crate::sharing::mat::open_mat;
    use crate::sharing::open;

    #[test]
    fn trunc_pair_identity_holds() {
        let run = run_4pc(NetProfile::zero(), 61, |ctx| trunc_pairs(ctx, 16, FRAC_BITS));
        let (outs, _) = run.expect_ok();
        for i in 0..16 {
            // open r from components (each component appears at ≥2 parties)
            let r1 = outs[0][i].r[0].unwrap();
            let r2 = outs[0][i].r[1].unwrap();
            let r3 = outs[0][i].r[2].unwrap();
            let r = r1 + r2 + r3;
            let rt = open(&[outs[0][i].rt, outs[1][i].rt, outs[2][i].rt, outs[3][i].rt]);
            // rᵗ within 2 of the true arithmetic shift
            let diff = (r.truncate(FRAC_BITS) - rt).as_i64();
            assert!((0..=2).contains(&diff), "rᵗ off by {diff}");
        }
    }

    #[test]
    fn mult_tr_fixed_point_accuracy() {
        let cases = [(1.5, 2.5), (-3.25, 1.5), (0.75, -0.5), (-2.0, -2.0), (100.5, 0.125)];
        for (a, b) in cases {
            let run = run_4pc(NetProfile::zero(), 62, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(a)))?;
                let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(b)))?;
                let z = mult_tr(ctx, &x, &y)?;
                ctx.flush_verify()?;
                Ok(z)
            });
            let (outs, _) = run.expect_ok();
            let got = FixedPoint::decode(open(&outs));
            let tol = (a.abs() + b.abs() + 4.0) / SCALE;
            assert!((got - a * b).abs() <= tol, "{a}*{b}: got {got}");
        }
    }

    #[test]
    fn mult_tr_online_cost_equals_mult() {
        // Table II headline: multiplication-with-truncation online cost is
        // 3ℓ — identical to plain multiplication.
        let run = run_4pc(NetProfile::zero(), 63, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(2.0)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(3.0)))?;
            let z = mult_tr(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (_, report) = run.expect_ok();
        assert_eq!(report.value_bits[1] - 4 * 64, 3 * 64, "online = 3ℓ");
        // offline: γ (3ℓ) + aSh (2ℓ) + check (ℓ) = 6ℓ  (Lemma D.2)
        assert_eq!(report.value_bits[0], 6 * 64, "offline = 6ℓ");
        // offline rounds ≤ 2 (Lemma D.2)
        assert!(report.rounds[0] <= 2, "offline rounds = {}", report.rounds[0]);
    }

    #[test]
    fn mult_tr_error_statistics() {
        // avg/max truncation error over many random fixed-point products
        let run = run_4pc(NetProfile::zero(), 64, |ctx| {
            let mut rng = Rng::seeded(999);
            let raw: Vec<(f64, f64)> =
                (0..64).map(|_| (rng.normal() * 10.0, rng.normal() * 10.0)).collect();
            let xs = super::super::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| raw.iter().map(|c| FixedPoint::encode(c.0)).collect::<Vec<_>>())
                    .as_deref(),
                64,
            )?;
            let ys = super::super::sharing::share_many_n(
                ctx,
                P2,
                (ctx.id() == P2)
                    .then(|| raw.iter().map(|c| FixedPoint::encode(c.1)).collect::<Vec<_>>())
                    .as_deref(),
                64,
            )?;
            let zs = mult_tr_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok((raw, zs))
        });
        let (outs, _) = run.expect_ok();
        let raw = &outs[1].0;
        for i in 0..raw.len() {
            let got = FixedPoint::decode(open(&[
                outs[0].1[i],
                outs[1].1[i],
                outs[2].1[i],
                outs[3].1[i],
            ]));
            let (a, b) = raw[i];
            let tol = (a.abs() + b.abs() + 4.0) / SCALE;
            assert!((got - a * b).abs() <= tol, "case {i}: {a}*{b} → {got}");
        }
    }

    #[test]
    fn matmul_tr_matches_plain_fixed_matmul() {
        let mut rng = Rng::seeded(65);
        let a = Matrix::from_fn(3, 4, |_, _| FixedPoint::encode(rng.normal()));
        let b = Matrix::from_fn(4, 2, |_, _| FixedPoint::encode(rng.normal()));
        let (a2, b2) = (a.clone(), b.clone());
        let run = run_4pc(NetProfile::zero(), 66, move |ctx| {
            let xs = crate::testutil::share_mat(ctx, P1, &a2)?;
            let ys = crate::testutil::share_mat(ctx, P3, &b2)?;
            let z = matmul_tr(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        let got = open_mat(&outs);
        let clear = a.matmul(&b);
        for i in 0..3 {
            for j in 0..2 {
                let want = FixedPoint::decode(clear[(i, j)].truncate(FRAC_BITS));
                let gotv = FixedPoint::decode(got[(i, j)]);
                assert!(
                    (gotv - want).abs() <= 4.0 / SCALE,
                    "({i},{j}): got {gotv}, want {want}"
                );
            }
        }
        // online cost: 3·(3·2)·64 + inputs
        assert_eq!(report.value_bits[1] - ((3 * 4 + 4 * 2) as u64) * 2 * 64, 3 * 6 * 64);
    }

    #[test]
    fn malicious_p0_bad_rt_detected() {
        // P0 shares a wrong rᵗ → P2's check aborts
        let run = run_4pc_timeout(
            NetProfile::zero(),
            67,
            std::time::Duration::from_millis(500),
            |ctx| {
                if ctx.id() == crate::net::P0 {
                    return ctx.offline(|ctx| {
                        let n = 1;
                        let d = FRAC_BITS;
                        let r1: Vec<Z64> = ctx.sample_lam_vec(P1, n).unwrap();
                        let r2: Vec<Z64> = ctx.sample_lam_vec(P2, n).unwrap();
                        let r3: Vec<Z64> = ctx.sample_lam_vec(P3, n).unwrap();
                        let rr = r1[0] + r2[0] + r3[0];
                        // CHEAT: off-by-more-than-κ truncation
                        let bad_rt = rr.truncate(d) + Z64(5);
                        let _ = ash_many(ctx, Some(&[bad_rt]), 1)?;
                        Ok(())
                    });
                }
                let pairs = trunc_pairs(ctx, 1, FRAC_BITS)?;
                ctx.flush_verify()?;
                let _ = pairs;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "bad rᵗ must be caught by the P1/P2 check");
    }
}
