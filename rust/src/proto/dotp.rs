//! `Π_DotP` (Fig. 9) and its matrix generalisation — the communication cost
//! is **independent of the vector length**: the evaluators sum their local
//! per-element contributions before the single 3-element exchange. This is
//! the protocol that makes Trident's ML training communication-flat in the
//! feature dimension (§VI-A.a).
//!
//! The matrix form is the ML hot path: every party-local term is a dense
//! u64 matmul (`−Λx_j∘M_y − M_x∘Λy_j + Γ_j + Λz_j`), which is exactly the
//! computation the L1 Pallas kernel implements; `runtime::gemm` dispatches
//! to the AOT PJRT artifact when one exists for the shape.

use crate::net::{Abort, PartyId, EVALUATORS, P0};
use crate::pool::{CircuitKey, MatCorr, OpKind};
use crate::ring::{Matrix, Ring, Z64};
use crate::runtime::gemm;
use crate::sharing::{MMat, MShare};

use super::mult::gamma_component;
use super::sharing::{share_mat_n, share_mat_with_mask};
use super::Ctx;

#[inline]
fn succ(j: u8) -> u8 {
    1 + (j % 3)
}

/// `Π_DotP(x⃗, y⃗)` — `[[z]] = [[x⃗ ⊙ y⃗]]`. One offline round (3ℓ) and one
/// online round (3ℓ), independent of `d = x⃗.len()`.
pub fn dotp<R: Ring>(ctx: &mut Ctx, xs: &[MShare<R>], ys: &[MShare<R>]) -> Result<MShare<R>, Abort> {
    assert_eq!(xs.len(), ys.len());
    let me = ctx.id();
    let d = xs.len();

    // ---- offline: λ_z + ⟨γ_xy⟩ with summed components ----
    // λ_z is pool-aware: a stocked pool serves the pre-drawn skeleton
    let lam_z: MShare<R> = super::mult::lam_shares(ctx, 1).pop().expect("one λ_z");
    let (gam_next, gam_prev, gam_all) = ctx.offline(|ctx| {
        let z = ctx.zero_share::<R>();
        let mut mine = R::ZERO;
        let mut all = [R::ZERO; 3];
        match me {
            P0 => {
                let masks = [z.gamma.unwrap(), z.a.unwrap(), z.b.unwrap()];
                for j in 1..=3u8 {
                    let mut acc = R::ZERO;
                    for i in 0..d {
                        acc = acc
                            + gamma_component(
                                xs[i].lam(me, j).unwrap(),
                                xs[i].lam(me, succ(j)).unwrap(),
                                ys[i].lam(me, j).unwrap(),
                                ys[i].lam(me, succ(j)).unwrap(),
                                R::ZERO,
                            );
                    }
                    all[(j - 1) as usize] = acc + masks[(j - 1) as usize];
                }
            }
            _ => {
                let j = me.next_evaluator().0;
                let mask = match me.0 {
                    1 => z.a.unwrap(),
                    2 => z.b.unwrap(),
                    3 => z.gamma.unwrap(),
                    _ => unreachable!(),
                };
                for i in 0..d {
                    mine = mine
                        + gamma_component(
                            xs[i].lam(me, j).unwrap(),
                            xs[i].lam(me, succ(j)).unwrap(),
                            ys[i].lam(me, j).unwrap(),
                            ys[i].lam(me, succ(j)).unwrap(),
                            R::ZERO,
                        );
                }
                mine += mask;
            }
        }
        // exchange summed γ components (3 ring elements total)
        match me {
            P0 => {
                ctx.vouch_ring(crate::net::P1, &[all[2]]);
                ctx.vouch_ring(crate::net::P2, &[all[0]]);
                ctx.vouch_ring(crate::net::P3, &[all[1]]);
                Ok::<_, Abort>((R::ZERO, R::ZERO, Some(all)))
            }
            _ => {
                ctx.send_ring1(me.prev_evaluator(), mine);
                let got: R = ctx.recv_ring1(me.next_evaluator())?;
                ctx.expect_ring(P0, &[got]);
                Ok((mine, got, None))
            }
        }
    })?;
    let _ = gam_all;

    // ---- online: single 3-element exchange ----
    ctx.online(|ctx| {
        if me == P0 {
            return Ok(lam_z);
        }
        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
        let mut mp_next = gam_next + lam_z.lam(me, jn).unwrap();
        let mut mp_prev = gam_prev + lam_z.lam(me, jp).unwrap();
        for i in 0..d {
            let (mx, my) = (xs[i].m(), ys[i].m());
            mp_next = mp_next - xs[i].lam(me, jn).unwrap() * my - ys[i].lam(me, jn).unwrap() * mx;
            mp_prev = mp_prev - xs[i].lam(me, jp).unwrap() * my - ys[i].lam(me, jp).unwrap() * mx;
        }
        ctx.send_ring1(me.prev_evaluator(), mp_prev);
        ctx.vouch_ring(me.next_evaluator(), &[mp_next]);
        let missing: R = ctx.recv_ring1(me.next_evaluator())?;
        ctx.expect_ring(me.prev_evaluator(), &[missing]);
        let mut m_z = mp_next + mp_prev + missing;
        for i in 0..d {
            m_z += xs[i].m() * ys[i].m();
        }
        match lam_z {
            MShare::Eval { lam_next, lam_prev, .. } => {
                Ok(MShare::Eval { m: m_z, lam_next, lam_prev })
            }
            _ => unreachable!(),
        }
    })
}

/// Offline correlation for a matrix product `[[X]] ∘ [[Y]]` with output
/// shape `a×c`.
pub(crate) struct MatmulCorr<R> {
    /// λ_Z skeleton.
    pub lam_z: MMat<R>,
    /// γ matrices I hold: evaluators `[next, prev]`, P0 all three.
    pub gamma: MatGamma<R>,
}

#[derive(Clone, Debug)]
pub(crate) enum MatGamma<R> {
    Helper([Matrix<R>; 3]),
    Eval { next: Matrix<R>, prev: Matrix<R> },
}

/// Sample a fresh λ mask for an `a×c` matrix wire.
pub(crate) fn sample_lam_mat<R: Ring>(ctx: &mut Ctx, rows: usize, cols: usize) -> MMat<R> {
    let me = ctx.id();
    let n = rows * cols;
    let mut lam: [Option<Matrix<R>>; 3] = [None, None, None];
    for j in EVALUATORS {
        if let Some(v) = ctx.sample_lam_vec::<R>(j, n) {
            lam[(j.0 - 1) as usize] = Some(Matrix::from_vec(rows, cols, v));
        }
    }
    if me.is_evaluator() {
        MMat::Eval {
            m: Matrix::zeros(rows, cols),
            lam_next: lam[(me.next_evaluator().0 - 1) as usize].take().unwrap(),
            lam_prev: lam[(me.prev_evaluator().0 - 1) as usize].take().unwrap(),
        }
    } else {
        MMat::Helper {
            lam: [lam[0].take().unwrap(), lam[1].take().unwrap(), lam[2].take().unwrap()],
        }
    }
}

/// Zero-share matrices (Π_Zero elementwise).
fn zero_mat<R: Ring>(ctx: &mut Ctx, rows: usize, cols: usize) -> [Option<Matrix<R>>; 3] {
    let n = rows * cols;
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    let mut g = Vec::with_capacity(n);
    let mut have = [false; 3];
    for _ in 0..n {
        let z = ctx.zero_share::<R>();
        if let Some(v) = z.a {
            a.push(v);
            have[0] = true;
        }
        if let Some(v) = z.b {
            b.push(v);
            have[1] = true;
        }
        if let Some(v) = z.gamma {
            g.push(v);
            have[2] = true;
        }
    }
    [
        have[0].then(|| Matrix::from_vec(rows, cols, a)),
        have[1].then(|| Matrix::from_vec(rows, cols, b)),
        have[2].then(|| Matrix::from_vec(rows, cols, g)),
    ]
}

/// γ matrix for component `j`:
/// `Γ_j = Λx_j∘(Λy_j + Λy_{j+1}) + Λx_{j+1}∘Λy_j (+ zero-share mask)`.
fn gamma_mat<R: Ring>(
    ctx: &mut Ctx,
    x: &MMat<R>,
    y: &MMat<R>,
    j: u8,
    mask: &Matrix<R>,
) -> Matrix<R> {
    let me = ctx.id();
    let lxj = x.lam(me, j).unwrap().clone();
    let lxj1 = x.lam(me, succ(j)).unwrap().clone();
    let lyj = y.lam(me, j).unwrap().clone();
    let lyj1 = y.lam(me, succ(j)).unwrap().clone();
    let prod = ctx.net.timed(|| {
        let t1 = gemm(&lxj, &(&lyj + &lyj1));
        let t2 = gemm(&lxj1, &lyj);
        &t1 + &t2
    });
    &prod + mask
}

/// Offline phase for `matmul`/`matmul_tr`.
pub(crate) fn matmul_offline<R: Ring>(
    ctx: &mut Ctx,
    x: &MMat<R>,
    y: &MMat<R>,
    with_lam_z: bool,
) -> Result<MatmulCorr<R>, Abort> {
    let me = ctx.id();
    let (a, _b) = x.dims();
    let c = y.cols();
    assert_eq!(x.cols(), y.rows(), "matmul dims");
    ctx.offline(|ctx| {
        let lam_z = if with_lam_z {
            sample_lam_mat(ctx, a, c)
        } else {
            MMat::zero(me, a, c)
        };
        let zs = zero_mat::<R>(ctx, a, c);
        let gamma = match me {
            P0 => {
                // masks: γ1←Γ, γ2←A, γ3←B
                let masks = [zs[2].clone().unwrap(), zs[0].clone().unwrap(), zs[1].clone().unwrap()];
                let g1 = gamma_mat(ctx, x, y, 1, &masks[0]);
                let g2 = gamma_mat(ctx, x, y, 2, &masks[1]);
                let g3 = gamma_mat(ctx, x, y, 3, &masks[2]);
                ctx.vouch_ring(crate::net::P1, g3.data());
                ctx.vouch_ring(crate::net::P2, g1.data());
                ctx.vouch_ring(crate::net::P3, g2.data());
                MatGamma::Helper([g1, g2, g3])
            }
            _ => {
                let j = me.next_evaluator().0;
                let mask = match me.0 {
                    1 => zs[0].clone().unwrap(),
                    2 => zs[1].clone().unwrap(),
                    3 => zs[2].clone().unwrap(),
                    _ => unreachable!(),
                };
                let mine = gamma_mat(ctx, x, y, j, &mask);
                ctx.send_ring(me.prev_evaluator(), mine.data());
                let got: Vec<R> = ctx.recv_ring(me.next_evaluator(), a * c)?;
                ctx.expect_ring(P0, &got);
                MatGamma::Eval { next: mine, prev: Matrix::from_vec(a, c, got) }
            }
        };
        Ok(MatmulCorr { lam_z, gamma })
    })
}

/// The evaluator-local online term
/// `M'_j = −Λx_j∘M_y − M_x∘Λy_j + Γ_j + Λz_j` — the **hot path**; the two
/// matmuls are what `python/compile/kernels/masked_matmul.py` fuses.
pub(crate) fn local_share_mat<R: Ring>(
    ctx: &mut Ctx,
    x: &MMat<R>,
    y: &MMat<R>,
    gamma_j: &Matrix<R>,
    lam_z_j: &Matrix<R>,
    j: u8,
) -> Matrix<R> {
    let me = ctx.id();
    let lxj = x.lam(me, j).unwrap();
    let lyj = y.lam(me, j).unwrap();
    let (mx, my) = (x.m(), y.m());
    ctx.net
        .timed(|| crate::runtime::masked_matmul(lxj, my, mx, lyj, gamma_j, lam_z_j))
}

/// `[[Z]] = [[X]] ∘ [[Y]]` — matrix product with 3·(a·c) online ring
/// elements, independent of the inner dimension (Π_DotP lifted to matrices).
pub fn matmul<R: Ring>(ctx: &mut Ctx, x: &MMat<R>, y: &MMat<R>) -> Result<MMat<R>, Abort> {
    let corr = matmul_offline(ctx, x, y, true)?;
    matmul_online(ctx, x, y, &corr)
}

pub(crate) fn matmul_online<R: Ring>(
    ctx: &mut Ctx,
    x: &MMat<R>,
    y: &MMat<R>,
    corr: &MatmulCorr<R>,
) -> Result<MMat<R>, Abort> {
    let me = ctx.id();
    let (a, c) = (x.rows(), y.cols());
    ctx.online(|ctx| {
        if me == P0 {
            return Ok(corr.lam_z.clone());
        }
        let (g_next, g_prev) = match &corr.gamma {
            MatGamma::Eval { next, prev } => (next, prev),
            _ => unreachable!(),
        };
        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
        let lz_n = corr.lam_z.lam(me, jn).unwrap().clone();
        let lz_p = corr.lam_z.lam(me, jp).unwrap().clone();
        let mp_next = local_share_mat(ctx, x, y, g_next, &lz_n, jn);
        let mp_prev = local_share_mat(ctx, x, y, g_prev, &lz_p, jp);
        ctx.send_ring(me.prev_evaluator(), mp_prev.data());
        ctx.vouch_ring(me.next_evaluator(), mp_next.data());
        let missing: Vec<R> = ctx.recv_ring(me.next_evaluator(), a * c)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);
        let missing = Matrix::from_vec(a, c, missing);
        let mxmy = ctx.net.timed(|| gemm(x.m(), y.m()));
        let m_z = &(&(&mp_next + &mp_prev) + &missing) + &mxmy;
        match &corr.lam_z {
            MMat::Eval { lam_next, lam_prev, .. } => Ok(MMat::Eval {
                m: m_z,
                lam_next: lam_next.clone(),
                lam_prev: lam_prev.clone(),
            }),
            _ => unreachable!(),
        }
    })
}

/// Lockstep pop of a circuit-keyed matrix correlation from the attached
/// pool. `Ok(None)` on a miss or with no pool attached (→ the caller's
/// deterministic inline fallback; all four parties fill and pop in
/// lockstep, so they agree). Material filed under a different [`CircuitKey`]
/// **fails closed**: the popping party aborts rather than running the
/// online phase on wrong-position correlations.
pub(crate) fn pop_keyed(ctx: &mut Ctx, key: &CircuitKey) -> Result<Option<MatCorr>, Abort> {
    match ctx.pool.as_mut().map(|p| p.pop_mat(key)) {
        None => Ok(None),
        Some(Ok(item)) => Ok(item),
        Some(Err(why)) => Err(ctx.net.abort(why)),
    }
}

/// Pool-aware **circuit-keyed** matrix product: pops the pre-generated
/// correlation for `key` (pre-drawn input wire mask `Λ_X`, pre-exchanged
/// `⟨Γ⟩` against the resident `[[Y]]`, pooled `λ_Z`), shares the dealer's
/// `X` under the pooled mask and runs only the online exchange — a hit
/// performs **zero offline-phase messages**. A miss (exhausted or
/// unattached pool, or a shape the key was not registered for) falls back
/// to the inline share + [`matmul`] path; the pop decision is lockstep at
/// all four parties, so the fallback is deterministic. Returns the input
/// sharing alongside the product (multi-layer callers need both).
pub fn matmul_keyed(
    ctx: &mut Ctx,
    key: &CircuitKey,
    x_clear: Option<&Matrix<Z64>>,
    y: &MMat<Z64>,
) -> Result<(MMat<Z64>, MMat<Z64>), Abort> {
    assert!(
        matches!(key.op, OpKind::MatMul),
        "matmul_keyed requires an OpKind::MatMul key"
    );
    assert_eq!((key.inner, key.cols), y.dims(), "resident Y must match the key shape");
    match pop_keyed(ctx, key)? {
        Some(item) => {
            let MatCorr { lam_x, lam_x_full, gamma, lam_z, .. } = item;
            let x = share_mat_with_mask(ctx, key.dealer, x_clear, lam_x, lam_x_full)?;
            let corr = MatmulCorr { lam_z, gamma };
            let z = matmul_online(ctx, &x, y, &corr)?;
            Ok((x, z))
        }
        None => {
            let x = share_mat_n(ctx, key.dealer, x_clear, key.rows, key.inner)?;
            let z = matmul(ctx, &x, y)?;
            Ok((x, z))
        }
    }
}

/// Who computes γ-component j (sanity helper used in tests).
#[allow(dead_code)]
pub(crate) fn gamma_owner(j: u8) -> PartyId {
    match j {
        2 => crate::net::P1,
        3 => crate::net::P2,
        1 => crate::net::P3,
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::{run_4pc, share};
    use crate::ring::Z64;
    use crate::sharing::mat::open_mat;
    use crate::sharing::open;

    #[test]
    fn dotp_opens_to_dot_product() {
        let run = run_4pc(NetProfile::zero(), 41, |ctx| {
            let xs = super::super::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1).then(|| (1..=100u64).map(Z64).collect::<Vec<_>>()).as_deref(),
                100,
            )?;
            let ys = super::super::sharing::share_many_n(
                ctx,
                P2,
                (ctx.id() == P2).then(|| (201..=300u64).map(Z64).collect::<Vec<_>>()).as_deref(),
                100,
            )?;
            let z = dotp(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        let expect: u64 = (1..=100u64).zip(201..=300u64).map(|(a, b)| a * b).sum();
        assert_eq!(open(&outs), Z64(expect));
        // THE headline property: dot-product online cost is 3ℓ bits,
        // independent of d=100 (inputs: 2 dealers × 100 values × 2 receivers).
        assert_eq!(report.value_bits[1] - 400 * 64, 3 * 64);
        assert_eq!(report.value_bits[0], 3 * 64);
    }

    #[test]
    fn dotp_cost_flat_in_dimension() {
        let mut costs = Vec::new();
        for d in [1usize, 10, 1000] {
            let run = run_4pc(NetProfile::zero(), 42, move |ctx| {
                let xs = super::super::sharing::share_many_n(
                    ctx,
                    P1,
                    (ctx.id() == P1).then(|| vec![Z64(3); d]).as_deref(),
                    d,
                )?;
                let ys = super::super::sharing::share_many_n(
                    ctx,
                    P2,
                    (ctx.id() == P2).then(|| vec![Z64(5); d]).as_deref(),
                    d,
                )?;
                let z = dotp(ctx, &xs, &ys)?;
                ctx.flush_verify()?;
                Ok(z)
            });
            let (outs, report) = run.expect_ok();
            assert_eq!(open(&outs), Z64(15 * d as u64));
            costs.push(report.value_bits[1] - (4 * d as u64) * 64);
        }
        assert_eq!(costs[0], costs[1]);
        assert_eq!(costs[1], costs[2]);
    }

    #[test]
    fn matmul_opens_to_product() {
        let mut rng = Rng::seeded(43);
        let xm = Matrix::from_fn(4, 6, |_, _| rng.gen::<Z64>());
        let ym = Matrix::from_fn(6, 3, |_, _| rng.gen::<Z64>());
        let expect = xm.matmul(&ym);
        let xm2 = xm.clone();
        let ym2 = ym.clone();
        let run = run_4pc(NetProfile::zero(), 44, move |ctx| {
            let xsh = crate::testutil::share_mat(ctx, P1, &xm2)?;
            let ysh = crate::testutil::share_mat(ctx, P2, &ym2)?;
            let z = matmul(ctx, &xsh, &ysh)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open_mat(&outs), expect);
        // online: inputs (4·6 + 6·3)·64 + 3·(4·3)·64 — flat in inner dim 6
        let io = (4 * 6 + 6 * 3) as u64 * 64 * 2; // P1 and P2 dealer sends go to 2 peers each? no: dealer evaluator sends to 2 others → 2·n·64
        let _ = io;
        let mat_online = report.value_bits[1] - (4 * 6 + 6 * 3) as u64 * 2 * 64;
        assert_eq!(mat_online, 3 * (4 * 3) as u64 * 64);
    }

    #[test]
    fn matmul_chain_associates() {
        // (X∘Y)∘w == X∘(Y∘w) through the protocol
        let mut rng = Rng::seeded(45);
        let x = Matrix::from_fn(3, 3, |_, _| rng.gen::<Z64>());
        let y = Matrix::from_fn(3, 3, |_, _| rng.gen::<Z64>());
        let w = Matrix::from_fn(3, 1, |_, _| rng.gen::<Z64>());
        let expect = x.matmul(&y).matmul(&w);
        let (x2, y2, w2) = (x.clone(), y.clone(), w.clone());
        let run = run_4pc(NetProfile::zero(), 46, move |ctx| {
            let xs = crate::testutil::share_mat(ctx, P1, &x2)?;
            let ys = crate::testutil::share_mat(ctx, P2, &y2)?;
            let ws = crate::testutil::share_mat(ctx, P1, &w2)?;
            let xy = matmul(ctx, &xs, &ys)?;
            let out = matmul(ctx, &xy, &ws)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open_mat(&outs), expect);
    }
}
