//! `Π_Mult` (Fig. 4): multiplication with a verified masked evaluation.
//!
//! Offline: the evaluators locally compute `[·]`-shares of `γ_xy = λ_x·λ_y`
//! (randomized by a `Π_Zero` share), exchange them to form `⟨γ_xy⟩`, with P0
//! vouching hashes of every component. One round, 3ℓ bits amortized.
//!
//! Online (evaluators only): each `P_i` locally computes the two
//! `m'_z`-components it owns, sends one and vouches the other, so every
//! evaluator verifiably reconstructs `m_z − m_x m_y`. One round, 3ℓ bits.
//!
//! Component ownership is fully cyclic: `P_i` computes `m'_{next(i)}` and
//! `m'_{prev(i)}`, sends `m'_{prev(i)}` to `prev(i)` (whose missing piece it
//! is) and vouches `m'_{next(i)}` towards `next(i)`.

use crate::net::{Abort, EVALUATORS, P0};
use crate::ring::Ring;
use crate::sharing::MShare;

use super::Ctx;

/// The γ-component `γ_{xy,j}` from the λ components visible to its owners.
///
/// `γ_{xy,2} = λx2·λy2 + λx2·λy3 + λx3·λy2 (+A)` and cyclic shifts
/// (Fig. 4) — component `j` pairs index `j` with itself and with `j+1`
/// (x-side) / `j+1` with `j` (y-side).
#[inline]
pub(crate) fn gamma_component<R: Ring>(lx_j: R, lx_j1: R, ly_j: R, ly_j1: R, mask: R) -> R {
    lx_j * ly_j + lx_j * ly_j1 + lx_j1 * ly_j + mask
}

/// Which λ indices feed `γ_j`: `(j, j+1)` cyclically over `{1,2,3}`.
#[inline]
fn succ(j: u8) -> u8 {
    1 + (j % 3)
}

/// Offline state carried into the online step: my ⟨γ⟩ components and the
/// fresh output masks λ_z.
pub(crate) struct MultCorr<R> {
    /// γ components I hold, indexed like λ: for evaluators `[next, prev]`;
    /// for P0 all three `[γ1, γ2, γ3]`.
    pub gamma: GammaView<R>,
    /// λ_z skeleton (an [`MShare`] with `m` still zero).
    pub lam_z: MShare<R>,
}

#[derive(Clone)]
pub(crate) enum GammaView<R> {
    Helper([Vec<R>; 3]),
    Eval { next: Vec<R>, prev: Vec<R> },
}

/// Offline phase of `Π_Mult` for a batch of gates: produces `⟨γ_xy⟩` and
/// λ_z (Fig. 4, offline). The λ components of `xs`/`ys` must already exist
/// (i.e. the inputs are `[[·]]`-shared or their masks pre-sampled).
pub(crate) fn mult_offline<R: Ring>(
    ctx: &mut Ctx,
    xs: &[MShare<R>],
    ys: &[MShare<R>],
    with_lam_z: bool,
) -> Result<MultCorr<R>, Abort> {
    let me = ctx.id();
    // fresh output mask λ_z (pool-aware: pops a pre-drawn skeleton when a
    // stocked pool is attached)
    let lam_z = if with_lam_z {
        lam_shares::<R>(ctx, 1).pop().expect("one λ_z")
    } else {
        MShare::zero(me)
    };
    let gamma = mult_gamma_offline(ctx, xs, ys)?;
    Ok(MultCorr { gamma, lam_z })
}

/// The γ-exchange half of the `Π_Mult` offline phase, split out of
/// [`mult_offline`] so a **pooled** correlation can be produced at fill
/// time and injected at wave time ([`crate::pool::relu`] generates the
/// `⟨γ_{r·v}⟩` of a ReLU gate's internal multiplication against the
/// position's pooled masks this way). Only the λ components of `xs`/`ys`
/// are read — `m` may still be zero skeletons.
pub(crate) fn mult_gamma_offline<R: Ring>(
    ctx: &mut Ctx,
    xs: &[MShare<R>],
    ys: &[MShare<R>],
) -> Result<GammaView<R>, Abort> {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    let me = ctx.id();
    ctx.offline(|ctx| {
        // zero shares and γ components
        let mut gamma_mine: Vec<R> = Vec::with_capacity(n); // the component I compute
        let mut gamma_all: [Vec<R>; 3] = [Vec::new(), Vec::new(), Vec::new()]; // P0 only
        for i in 0..n {
            let z = ctx.zero_share::<R>();
            match me {
                P0 => {
                    // P0 computes all three components
                    let masks = [z.gamma.unwrap(), z.a.unwrap(), z.b.unwrap()];
                    // mask for γ1 is Γ, γ2 is A, γ3 is B (Fig. 4)
                    for j in 1..=3u8 {
                        let lxj = xs[i].lam(me, j).unwrap();
                        let lxj1 = xs[i].lam(me, succ(j)).unwrap();
                        let lyj = ys[i].lam(me, j).unwrap();
                        let lyj1 = ys[i].lam(me, succ(j)).unwrap();
                        gamma_all[(j - 1) as usize]
                            .push(gamma_component(lxj, lxj1, lyj, lyj1, masks[(j - 1) as usize]));
                    }
                }
                _ => {
                    // evaluator P_i computes γ_{next(i)}:
                    //   P1 → γ2 (mask A), P2 → γ3 (mask B), P3 → γ1 (mask Γ)
                    let j = me.next_evaluator().0;
                    let mask = match me.0 {
                        1 => z.a.unwrap(),
                        2 => z.b.unwrap(),
                        3 => z.gamma.unwrap(),
                        _ => unreachable!(),
                    };
                    let lxj = xs[i].lam(me, j).unwrap();
                    let lxj1 = xs[i].lam(me, succ(j)).unwrap();
                    let lyj = ys[i].lam(me, j).unwrap();
                    let lyj1 = ys[i].lam(me, succ(j)).unwrap();
                    gamma_mine.push(gamma_component(lxj, lxj1, lyj, lyj1, mask));
                }
            }
        }

        // exchange: P1 →γ2→ P3, P2 →γ3→ P1, P3 →γ1→ P2; P0 vouches hashes.
        let gamma = match me {
            P0 => {
                // vouch H(γ3) to P1, H(γ1) to P2, H(γ2) to P3
                ctx.vouch_ring(crate::net::P1, &gamma_all[2]);
                ctx.vouch_ring(crate::net::P2, &gamma_all[0]);
                ctx.vouch_ring(crate::net::P3, &gamma_all[1]);
                GammaView::Helper(gamma_all)
            }
            _ => {
                // my computed component is γ_{g(me)} where g: P1→2,P2→3,P3→1,
                // i.e. exactly the "next" slot of my ⟨·⟩ view. I send it to
                // the evaluator for whom it is the "prev" slot: prev(me).
                ctx.send_ring(me.prev_evaluator(), &gamma_mine);
                let got: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
                // verify against P0's vouch
                ctx.expect_ring(P0, &got);
                GammaView::Eval { next: gamma_mine, prev: got }
            }
        };
        Ok(gamma)
    })
}

/// Pool-aware batch of fresh λ_z skeletons: pops pre-generated material
/// when a stocked pool is attached ([`crate::pool`]), otherwise draws
/// inline from the correlated PRF streams under `Phase::Offline`. The
/// decision is all-or-nothing so all parties agree on it.
pub(crate) fn lam_shares<R: Ring>(ctx: &mut Ctx, n: usize) -> Vec<MShare<R>> {
    if n == 0 {
        return Vec::new();
    }
    if let Some(pool) = ctx.pool.as_mut() {
        if let Some(v) = pool.pop_lam::<R>(n) {
            return v;
        }
    }
    ctx.offline(|ctx| (0..n).map(|_| sample_lam_share(ctx)).collect())
}

/// Sample a fresh mask λ_z as an [`MShare`] skeleton (m = 0).
pub(crate) fn sample_lam_share<R: Ring>(ctx: &mut Ctx) -> MShare<R> {
    let me = ctx.id();
    let mut lam = [None::<R>; 3];
    for j in EVALUATORS {
        if let Some(v) = ctx.sample_lam::<R>(j) {
            lam[(j.0 - 1) as usize] = Some(v);
        }
    }
    if me.is_evaluator() {
        MShare::Eval {
            m: R::ZERO,
            lam_next: lam[(me.next_evaluator().0 - 1) as usize].unwrap(),
            lam_prev: lam[(me.prev_evaluator().0 - 1) as usize].unwrap(),
        }
    } else {
        MShare::Helper { lam: [lam[0].unwrap(), lam[1].unwrap(), lam[2].unwrap()] }
    }
}

/// Online phase of `Π_Mult` for one gate, given the offline correlation.
pub(crate) fn mult_online<R: Ring>(
    ctx: &mut Ctx,
    x: &MShare<R>,
    y: &MShare<R>,
    corr: &MultCorr<R>,
) -> Result<MShare<R>, Abort> {
    mult_online_many(ctx, std::slice::from_ref(x), std::slice::from_ref(y), corr)
        .map(|mut v| v.pop().unwrap())
}

pub(crate) fn mult_online_many<R: Ring>(
    ctx: &mut Ctx,
    xs: &[MShare<R>],
    ys: &[MShare<R>],
    corr: &MultCorr<R>,
) -> Result<Vec<MShare<R>>, Abort> {
    let me = ctx.id();
    let n = xs.len();
    ctx.online(|ctx| {
        if me == P0 {
            // P0 idle online; its output share is just λ_z
            return Ok(vec![corr.lam_z; n]);
        }
        let (g_next, g_prev) = match &corr.gamma {
            GammaView::Eval { next, prev } => (next, prev),
            _ => unreachable!(),
        };
        let jn = me.next_evaluator().0;
        let jp = me.prev_evaluator().0;
        // m'_{jn} and m'_{jp}
        let mut mp_next = Vec::with_capacity(n);
        let mut mp_prev = Vec::with_capacity(n);
        for i in 0..n {
            let mx = xs[i].m();
            let my = ys[i].m();
            let lz_n = corr.lam_z.lam(me, jn).unwrap();
            let lz_p = corr.lam_z.lam(me, jp).unwrap();
            mp_next.push(
                -(xs[i].lam(me, jn).unwrap() * my) - ys[i].lam(me, jn).unwrap() * mx
                    + g_next[i]
                    + lz_n,
            );
            mp_prev.push(
                -(xs[i].lam(me, jp).unwrap() * my) - ys[i].lam(me, jp).unwrap() * mx
                    + g_prev[i]
                    + lz_p,
            );
        }
        // send my prev-component to prev (their missing piece), vouch my
        // next-component towards next.
        ctx.send_ring(me.prev_evaluator(), &mp_prev);
        ctx.vouch_ring(me.next_evaluator(), &mp_next);
        let missing: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);

        Ok((0..n)
            .map(|i| {
                let m_z = mp_next[i] + mp_prev[i] + missing[i] + xs[i].m() * ys[i].m();
                match corr.lam_z {
                    MShare::Eval { lam_next, lam_prev, .. } => {
                        MShare::Eval { m: m_z, lam_next, lam_prev }
                    }
                    _ => unreachable!(),
                }
            })
            .collect())
    })
}

/// `Π_Mult(x, y)` — one multiplication gate (offline + online fused; the
/// phase meter still books each half correctly).
pub fn mult<R: Ring>(ctx: &mut Ctx, x: &MShare<R>, y: &MShare<R>) -> Result<MShare<R>, Abort> {
    let corr = mult_offline(ctx, std::slice::from_ref(x), std::slice::from_ref(y), true)?;
    mult_online(ctx, x, y, &corr)
}

/// Batched multiplication of share slices (one offline + one online round
/// for the whole batch). Each gate gets an *independent* λ_z.
pub fn mult_many<R: Ring>(
    ctx: &mut Ctx,
    xs: &[MShare<R>],
    ys: &[MShare<R>],
) -> Result<Vec<MShare<R>>, Abort> {
    assert_eq!(xs.len(), ys.len());
    // Per-gate λ_z: we run the scalar pipeline per gate but share the
    // message rounds by accumulating first. Simplest correct version: one
    // offline per gate (cheap, PRF-only for λ; γ exchange batched by the
    // caller's message coalescing) — instead, do it properly batched here.
    let n = xs.len();
    let me = ctx.id();
    // λ_z for every gate (pool-aware)
    let lam_zs: Vec<MShare<R>> = lam_shares(ctx, n);
    let corr0 = mult_offline(ctx, xs, ys, false)?;
    let mut out = Vec::with_capacity(n);
    // online, batched manually to keep one round for the whole slice
    let res = ctx.online(|ctx| -> Result<Vec<MShare<R>>, Abort> {
        if me == P0 {
            return Ok(lam_zs.clone());
        }
        let (g_next, g_prev) = match &corr0.gamma {
            GammaView::Eval { next, prev } => (next, prev),
            _ => unreachable!(),
        };
        let jn = me.next_evaluator().0;
        let jp = me.prev_evaluator().0;
        let mut mp_next = Vec::with_capacity(n);
        let mut mp_prev = Vec::with_capacity(n);
        for i in 0..n {
            let (mx, my) = (xs[i].m(), ys[i].m());
            mp_next.push(
                -(xs[i].lam(me, jn).unwrap() * my) - ys[i].lam(me, jn).unwrap() * mx
                    + g_next[i]
                    + lam_zs[i].lam(me, jn).unwrap(),
            );
            mp_prev.push(
                -(xs[i].lam(me, jp).unwrap() * my) - ys[i].lam(me, jp).unwrap() * mx
                    + g_prev[i]
                    + lam_zs[i].lam(me, jp).unwrap(),
            );
        }
        ctx.send_ring(me.prev_evaluator(), &mp_prev);
        ctx.vouch_ring(me.next_evaluator(), &mp_next);
        let missing: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);
        Ok((0..n)
            .map(|i| {
                let m_z = mp_next[i] + mp_prev[i] + missing[i] + xs[i].m() * ys[i].m();
                match lam_zs[i] {
                    MShare::Eval { lam_next, lam_prev, .. } => {
                        MShare::Eval { m: m_z, lam_next, lam_prev }
                    }
                    _ => unreachable!(),
                }
            })
            .collect())
    })?;
    out.extend(res);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2, P3};
    use crate::proto::{run_4pc, run_4pc_timeout, share};
    use crate::ring::{Bit, Z64};
    use crate::sharing::open;

    #[test]
    fn mult_opens_to_product() {
        let run = run_4pc(NetProfile::zero(), 31, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(123)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(4567)))?;
            let z = mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(123 * 4567));
        // online value traffic: 2 evaluator-dealt inputs (2ℓ each: the
        // dealer sends m to the two other evaluators) + 3ℓ for the mult
        assert_eq!(report.value_bits[1], (4 + 3) * 64);
        // offline: 3ℓ for γ exchange
        assert_eq!(report.value_bits[0], 3 * 64);
    }

    #[test]
    fn mult_wrapping_values() {
        let a = u64::MAX - 5;
        let b = 123456789u64;
        let run = run_4pc(NetProfile::zero(), 32, move |ctx| {
            let x = share(ctx, P0, (ctx.id() == P0).then_some(Z64(a)))?;
            let y = share(ctx, P3, (ctx.id() == P3).then_some(Z64(b)))?;
            let z = mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open(&outs), Z64(a.wrapping_mul(b)));
    }

    #[test]
    fn mult_boolean_is_and() {
        for (a, b) in [(false, false), (false, true), (true, false), (true, true)] {
            let run = run_4pc(NetProfile::zero(), 33, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(Bit(a)))?;
                let y = share(ctx, P2, (ctx.id() == P2).then_some(Bit(b)))?;
                let z = mult(ctx, &x, &y)?;
                ctx.flush_verify()?;
                Ok(z)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(a && b), "{a} AND {b}");
        }
    }

    #[test]
    fn mult_many_single_round_online() {
        let run = run_4pc(NetProfile::zero(), 34, |ctx| {
            let xs = super::super::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1).then(|| (1..=32u64).map(Z64).collect::<Vec<_>>()).as_deref(),
                32,
            )?;
            let ys = super::super::sharing::share_many_n(
                ctx,
                P2,
                (ctx.id() == P2).then(|| (101..=132u64).map(Z64).collect::<Vec<_>>()).as_deref(),
                32,
            )?;
            let zs = mult_many(ctx, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok(zs)
        });
        let (outs, report) = run.expect_ok();
        for i in 0..32usize {
            let z = open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]);
            assert_eq!(z, Z64((i as u64 + 1) * (i as u64 + 101)));
        }
        // online rounds: 2 sequential input sharings + 1 mult round
        // (independent dealers chain in program order; the mult itself is
        // one round for the whole batch)
        assert_eq!(report.rounds[1], 3);
        // mult online bits: 3·32·64 on top of 2·(2·32)·64 input bits
        assert_eq!(report.value_bits[1], (4 * 32 + 3 * 32) * 64);
    }

    #[test]
    fn depth_chains_rounds() {
        // z = ((x*y)*y)*y → online rounds = 1 input + 3 mult rounds
        let run = run_4pc(NetProfile::zero(), 35, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(3)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(5)))?;
            let mut z = mult(ctx, &x, &y)?;
            z = mult(ctx, &z, &y)?;
            z = mult(ctx, &z, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(3 * 5 * 5 * 5));
        // 2 input rounds (sequential dealers) + 3 chained mult rounds
        assert_eq!(report.rounds[1], 5);
        // offline: 3 γ exchanges — data-independent (a deployment batches
        // them into one round), but the sequential in-process schedule
        // chains them; the measured value is the schedule depth.
        assert_eq!(report.rounds[0], 3);
    }

    #[test]
    fn p0_does_nothing_online_in_mult() {
        let run = run_4pc(NetProfile::wan(), 36, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(7)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(8)))?;
            let z = mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            Ok(z)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(56));
        // P0's online virtual time is zero: it neither sends nor receives
        assert_eq!(report.party_time[1][0], 0.0);
    }

    #[test]
    fn malicious_gamma_detected() {
        // P2 sends a corrupted γ3 to P1 → P0's vouched hash mismatches
        let run = run_4pc_timeout(
            NetProfile::zero(),
            37,
            std::time::Duration::from_millis(500),
            |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(9)))?;
                let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(10)))?;
                if ctx.id() == P2 {
                    // replay mult but corrupt the γ we send to P1
                    let corr = {
                        // run the honest offline computation, then tamper
                        // with the exchange by sending garbage.
                        ctx.offline(|ctx| {
                            let _lam_z: MShare<Z64> = sample_lam_share(ctx);
                            let z = ctx.zero_share::<Z64>();
                            let mask = z.b.unwrap();
                            let me = ctx.id();
                            let lxj = x.lam(me, 3).unwrap();
                            let lxj1 = x.lam(me, 1).unwrap();
                            let lyj = y.lam(me, 3).unwrap();
                            let lyj1 = y.lam(me, 1).unwrap();
                            let g3 = gamma_component(lxj, lxj1, lyj, lyj1, mask);
                            ctx.send_ring1(P1, g3 + Z64(1)); // CORRUPTED
                            let got: Z64 = ctx.recv_ring1(P3)?;
                            ctx.expect_ring(P0, &[got]);
                            Ok::<_, crate::net::Abort>(())
                        })?;
                    };
                    let _ = corr;
                    let _ = ctx.flush_verify();
                    return Ok(());
                }
                let z = mult(ctx, &x, &y)?;
                ctx.flush_verify()?;
                let _ = z;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "corrupted γ must be caught");
    }

    #[test]
    fn malicious_online_share_detected() {
        // P3 sends a corrupted m'-component to P2; P1's vouched hash catches it
        let run = run_4pc_timeout(
            NetProfile::zero(),
            38,
            std::time::Duration::from_millis(500),
            |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(11)))?;
                let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(13)))?;
                if ctx.id() == P3 {
                    let corr = mult_offline(ctx, &[x], &[y], true)?;
                    // run the online step but corrupt what we send to P2
                    return ctx.online(|ctx| {
                        let me = ctx.id();
                        let (g_next, g_prev) = match &corr.gamma {
                            GammaView::Eval { next, prev } => (next, prev),
                            _ => unreachable!(),
                        };
                        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
                        let (mx, my) = (x.m(), y.m());
                        let mp_next = -(x.lam(me, jn).unwrap() * my) - y.lam(me, jn).unwrap() * mx
                            + g_next[0]
                            + corr.lam_z.lam(me, jn).unwrap();
                        let mp_prev = -(x.lam(me, jp).unwrap() * my) - y.lam(me, jp).unwrap() * mx
                            + g_prev[0]
                            + corr.lam_z.lam(me, jp).unwrap();
                        ctx.send_ring1(me.prev_evaluator(), mp_prev + Z64(99)); // CORRUPTED
                        ctx.vouch_ring(me.next_evaluator(), &[mp_next]);
                        let _missing: Z64 = ctx.recv_ring1(me.next_evaluator())?;
                        ctx.expect_ring(me.prev_evaluator(), &[_missing]);
                        let _ = ctx.flush_verify();
                        Ok(())
                    });
                }
                let z = mult(ctx, &x, &y)?;
                ctx.flush_verify()?;
                let _ = z;
                Ok(())
            },
        );
        assert!(run.any_verify_abort(), "corrupted m' must be caught");
    }
}
