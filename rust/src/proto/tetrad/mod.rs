//! Tetrad-style 4PC backend: fairness and guaranteed-output-delivery (GOD)
//! variants behind the same masked-sharing seam as the Trident protocols.
//!
//! Trident (the source paper) is secure-with-abort: one malicious party can
//! deny everyone the output. Its successor **Tetrad** (arXiv:2106.02850) and
//! the **MPCLeague** thesis (arXiv:2112.13338) show the same 4-party,
//! one-corruption setting supports *fairness* (either everyone learns the
//! output or no one does) and *GOD* (every honest party always learns the
//! output) at comparable cost — the input-sharing and masked-evaluation
//! phases are structurally identical; the variants diverge only in how the
//! output is delivered.
//!
//! This module follows that decomposition. Sharing, multiplication and
//! truncation are the Trident primitives re-exported under Tetrad names
//! ([`share_mat`], [`matmul`], [`matmul_tr`], [`mult`]): the `(m, λ)` masked
//! form is exactly Tetrad's ⟨·⟩-sharing over four parties, so the evaluation
//! phase carries over message-for-message and the bench columns compare the
//! variants on the one stage where they really differ — reconstruction:
//!
//! * [`fair_reconstruct_mat_to`] — matrix generalization of the scalar
//!   `Π_fRec` (Trident Fig. 5): an agree-to-open vote relayed through P0,
//!   then 2-of-3 redundant delivery with a digest tie-break. A cheater can
//!   still force a (fair, unanimous) abort in the vote, but can never split
//!   the honest parties between output and no-output.
//! * [`god_reconstruct_mat_to`] / [`god_reconstruct_mat`] — abort-free
//!   delivery: every missing component travels as **three independent value
//!   copies**, with the fourth party (P0, who holds every λ) acting as the
//!   trusted-payload tiebreaker for evaluator targets. The receiver takes an
//!   elementwise majority, so a single equivocating party cannot force an
//!   abort *or* a wrong opened value — the delivery premium (a third full
//!   copy instead of a digest) is the GOD cost visible in
//!   `bench::serve_table`'s backend columns, mirroring Tetrad's Table
//!   comparisons.
//!
//! **Fail-closed precondition:** both variants settle all deferred
//! verification transcripts (`flush_verify`) *before* delivery. A corrupt
//! evaluation transcript therefore still aborts the wave — GOD protects the
//! delivery of a correctly-evaluated output, it never launders a tampered
//! one ("never a wrong honest opened value", the abort-scoping contract in
//! `net/`).

use crate::net::{Abort, PartyId, EVALUATORS, P0, P1, P2, P3};
use crate::ring::{Matrix, Ring};
use crate::sharing::MMat;

use super::Ctx;

/// Which 4PC protocol family serves a tenant's waves.
///
/// Selected per-tenant via `TenantSpec::backend`; the serving engine also
/// switches a quarantined tenant to [`Backend::TetradGod`] at runtime under
/// `--failover god` (the failover state machine in `serve/multi.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Trident secure-with-abort (the paper's protocols; the default).
    Trident,
    /// Tetrad-style fair output delivery: unanimous open-or-abort.
    TetradFair,
    /// Tetrad-style guaranteed output delivery: majority-of-3 copies,
    /// P0 as trusted-payload tiebreaker; reconstruction cannot abort.
    TetradGod,
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Trident
    }
}

impl Backend {
    /// Stable lowercase label (bench rows, JSON, trace payloads).
    pub fn label(&self) -> &'static str {
        match self {
            Backend::Trident => "trident",
            Backend::TetradFair => "tetrad-fair",
            Backend::TetradGod => "tetrad-god",
        }
    }

    /// Parse a CLI/label string (inverse of [`Backend::label`]).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "trident" => Some(Backend::Trident),
            "tetrad-fair" | "fair" => Some(Backend::TetradFair),
            "tetrad-god" | "god" => Some(Backend::TetradGod),
            _ => None,
        }
    }
}

// ---- evaluation phase: Trident primitives under Tetrad names -------------
//
// Tetrad's input sharing and multiplication use the same masked form
// ([m], λ split three ways with P0 holding all of λ), so the evaluation
// phase is byte-identical here and the cost comparison isolates delivery.

/// Tetrad joint input sharing — identical wire schedule to Trident `Π_Sh`.
pub use crate::proto::sharing::share_mat_n as share_mat;

/// Tetrad multiplication (scalar) — identical evaluation-phase schedule.
pub use crate::proto::mult::mult;

/// Tetrad matrix multiplication — identical evaluation-phase schedule.
pub use crate::proto::dotp::matmul;

/// Tetrad truncated matrix multiplication — identical evaluation-phase
/// schedule (probabilistic truncation over the same verified pairs).
pub use crate::proto::trunc::matmul_tr;

// ---- fair reconstruction -------------------------------------------------

/// Matrix `Π_fRec` towards a subset of parties: the scalar fair
/// reconstruction (Fig. 5) generalized to SoA matrix payloads and
/// subset delivery, used by the `TetradFair` serving backend.
///
/// Rounds 1–3 run the agree-to-open vote among **all** parties (liveness
/// bits through P0, evaluator majority); round 4 delivers each target's
/// missing component with 2-of-3 redundancy plus a digest tie-break.
/// `ok` is the caller's local verification verdict going in — serving
/// callers settle the wave's deferred digests first and pass `true`.
pub fn fair_reconstruct_mat_to<R: Ring>(
    ctx: &mut Ctx,
    sh: &MMat<R>,
    targets: &[PartyId],
    ok: bool,
) -> Result<Option<Matrix<R>>, Abort> {
    let me = ctx.id();
    let (rows, cols) = sh.dims();
    let n = rows * cols;
    ctx.online(|ctx| {
        // Rounds 1–3: agree-to-open, exactly as the scalar Π_fRec.
        if me.is_evaluator() {
            ctx.net.send_with_bits(P0, &[ok as u8], crate::net::MsgClass::Value, 1);
        }
        let go = if me == P0 {
            let mut all_ok = ok;
            for p in EVALUATORS {
                let b = ctx.net.recv(p)?;
                all_ok &= b == [1u8];
            }
            for p in EVALUATORS {
                ctx.net.send_with_bits(p, &[all_ok as u8], crate::net::MsgClass::Value, 1);
            }
            all_ok
        } else {
            let b = ctx.net.recv(P0)?;
            b == [1u8]
        };
        let proceed = if me.is_evaluator() {
            for p in EVALUATORS {
                if p != me {
                    ctx.net.send_with_bits(p, &[go as u8], crate::net::MsgClass::Value, 1);
                }
            }
            let mut votes = vec![go];
            for p in EVALUATORS {
                if p != me {
                    let b = ctx.net.recv(p)?;
                    votes.push(b == [1u8]);
                }
            }
            votes.iter().filter(|&&v| v).count() >= 2
        } else {
            go
        };
        if !proceed {
            return Err(ctx.net.abort("fair reconstruction: majority abort".into()));
        }

        // Round 4: redundant delivery toward the targets.
        //   P0 ← M from P1 and P2, H(M) from P3
        //   evaluator t ← λ_t from the two other evaluators, H(λ_t) from P0
        let mut my_value: Option<Matrix<R>> = None;
        for &t in targets {
            if t == me {
                continue;
            }
            if t == P0 {
                if me == P1 || me == P2 {
                    ctx.send_ring(P0, sh.m().data());
                }
                if me == P3 {
                    ctx.vouch_ring(P0, sh.m().data());
                }
            } else {
                if me.is_evaluator() {
                    ctx.send_ring(t, sh.lam(me, t.0).expect("evaluator holds peers' λ").data());
                }
                if me == P0 {
                    ctx.vouch_ring(t, sh.lam(P0, t.0).expect("P0 holds all λ").data());
                }
            }
        }
        let mut flushed = false;
        if targets.contains(&me) {
            match sh {
                MMat::Helper { lam } => {
                    let m1: Vec<R> = ctx.recv_ring(P1, n)?;
                    let m2: Vec<R> = ctx.recv_ring(P2, n)?;
                    ctx.expect_ring(P3, &m1);
                    // majority of {M1, M2, H(M3)}: if the copies disagree,
                    // P3's digest over the true M breaks the tie.
                    let m = if m1 == m2 {
                        ctx.flush_verify().ok();
                        m1
                    } else {
                        match ctx.flush_verify() {
                            Ok(()) => m1,
                            Err(_) => m2,
                        }
                    };
                    flushed = true;
                    let data = m
                        .iter()
                        .enumerate()
                        .map(|(i, &v)| v - lam[0].data()[i] - lam[1].data()[i] - lam[2].data()[i])
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
                MMat::Eval { m, lam_next, lam_prev } => {
                    let a: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
                    let b: Vec<R> = ctx.recv_ring(me.prev_evaluator(), n)?;
                    ctx.expect_ring(P0, &a);
                    let lam_i = if a == b {
                        ctx.flush_verify().ok();
                        a
                    } else {
                        match ctx.flush_verify() {
                            Ok(()) => a,
                            Err(_) => b,
                        }
                    };
                    flushed = true;
                    let data = (0..n)
                        .map(|i| m.data()[i] - lam_i[i] - lam_next.data()[i] - lam_prev.data()[i])
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
            }
        }
        if !flushed {
            // vouchers that are not targets still deliver their digests
            ctx.flush_verify()?;
        }
        Ok(my_value)
    })
}

// ---- GOD reconstruction --------------------------------------------------

/// Elementwise majority of three copies; `trusted` (P0's payload for
/// evaluator targets) wins a three-way split, which under one corruption
/// never actually occurs — it is the documented tie-break, not a guess.
fn maj3<R: Ring>(a: &[R], b: &[R], trusted: &[R]) -> Vec<R> {
    (0..a.len())
        .map(|i| {
            if a[i] == b[i] || a[i] == trusted[i] {
                a[i]
            } else if b[i] == trusted[i] {
                b[i]
            } else {
                trusted[i]
            }
        })
        .collect()
}

/// GOD reconstruction towards a subset: settles all deferred verification
/// first (fail-closed on a corrupt evaluation transcript), then delivers
/// each target's missing component as **three independent value copies** and
/// takes an elementwise majority — no digest dependence, so an equivocating
/// party cannot force an abort during delivery.
///
/// Delivery pattern per target:
///   * evaluator `t` ← λ_t from the two other evaluators **and from P0 as a
///     value payload** (the trusted-payload tiebreaker: P0 holds every λ);
///   * `P0` ← M from all three evaluators.
pub fn god_reconstruct_mat_to<R: Ring>(
    ctx: &mut Ctx,
    sh: &MMat<R>,
    targets: &[PartyId],
) -> Result<Option<Matrix<R>>, Abort> {
    let me = ctx.id();
    let (rows, cols) = sh.dims();
    let n = rows * cols;
    ctx.online(|ctx| {
        // Fail closed before delivering anything: a tampered evaluation
        // phase must never reach an opened value, GOD or not.
        ctx.flush_verify()?;
        let mut my_value: Option<Matrix<R>> = None;
        // send duties (non-blocking)
        for &t in targets {
            if t == me {
                continue;
            }
            if t == P0 {
                if me.is_evaluator() {
                    ctx.send_ring(P0, sh.m().data());
                }
            } else if me.is_evaluator() {
                ctx.send_ring(t, sh.lam(me, t.0).expect("evaluator holds peers' λ").data());
            } else {
                // P0's trusted payload: the λ_t value itself, not a digest
                ctx.send_ring(t, sh.lam(P0, t.0).expect("P0 holds all λ").data());
            }
        }
        // receive if I'm a target
        if targets.contains(&me) {
            match sh {
                MMat::Helper { lam } => {
                    let m1: Vec<R> = ctx.recv_ring(P1, n)?;
                    let m2: Vec<R> = ctx.recv_ring(P2, n)?;
                    let m3: Vec<R> = ctx.recv_ring(P3, n)?;
                    let m = maj3(&m1, &m2, &m3);
                    let data = m
                        .iter()
                        .enumerate()
                        .map(|(i, &v)| v - lam[0].data()[i] - lam[1].data()[i] - lam[2].data()[i])
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
                MMat::Eval { m, lam_next, lam_prev } => {
                    let a: Vec<R> = ctx.recv_ring(me.next_evaluator(), n)?;
                    let b: Vec<R> = ctx.recv_ring(me.prev_evaluator(), n)?;
                    let t: Vec<R> = ctx.recv_ring(P0, n)?;
                    let lam_i = maj3(&a, &b, &t);
                    let data = (0..n)
                        .map(|i| m.data()[i] - lam_i[i] - lam_next.data()[i] - lam_prev.data()[i])
                        .collect();
                    my_value = Some(Matrix::from_vec(rows, cols, data));
                }
            }
        }
        Ok(my_value)
    })
}

/// GOD reconstruction towards **all four parties** (the failover path for a
/// training job's epoch-final model opening).
pub fn god_reconstruct_mat<R: Ring>(ctx: &mut Ctx, sh: &MMat<R>) -> Result<Matrix<R>, Abort> {
    let out = god_reconstruct_mat_to(ctx, sh, &crate::net::ALL)?;
    Ok(out.expect("every party is a target"))
}

/// Backend-dispatched subset reconstruction — the single seam the serving
/// wave path goes through, so a tenant's `Backend` (or the failover state
/// machine's runtime override) selects the delivery protocol without the
/// wave code knowing the difference. The Trident arm keeps the existing
/// schedule byte-for-byte; the Tetrad arms settle the wave's deferred
/// digests first (see the module docs' fail-closed precondition).
pub fn reconstruct_mat_to_backend<R: Ring>(
    ctx: &mut Ctx,
    backend: Backend,
    sh: &MMat<R>,
    targets: &[PartyId],
) -> Result<Option<Matrix<R>>, Abort> {
    match backend {
        Backend::Trident => crate::proto::reconstruct::reconstruct_mat_to(ctx, sh, targets),
        Backend::TetradFair => {
            ctx.flush_verify()?;
            fair_reconstruct_mat_to(ctx, sh, targets, true)
        }
        Backend::TetradGod => god_reconstruct_mat_to(ctx, sh, targets),
    }
}

/// Backend-dispatched all-party reconstruction (training epoch commits).
pub fn reconstruct_mat_backend<R: Ring>(
    ctx: &mut Ctx,
    backend: Backend,
    sh: &MMat<R>,
) -> Result<Matrix<R>, Abort> {
    match backend {
        Backend::Trident => crate::proto::reconstruct::reconstruct_mat(ctx, sh),
        Backend::TetradFair => {
            ctx.flush_verify()?;
            let out = fair_reconstruct_mat_to(ctx, sh, &crate::net::ALL, true)?;
            Ok(out.expect("every party is a target"))
        }
        Backend::TetradGod => god_reconstruct_mat(ctx, sh),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::proto::run_4pc;
    use crate::ring::Z64;

    fn test_mat() -> Matrix<Z64> {
        Matrix::from_fn(3, 2, |r, c| Z64((100 * r + c) as u64))
    }

    #[test]
    fn backend_labels_roundtrip() {
        for b in [Backend::Trident, Backend::TetradFair, Backend::TetradGod] {
            assert_eq!(Backend::parse(b.label()), Some(b));
        }
        assert_eq!(Backend::parse("god"), Some(Backend::TetradGod));
        assert_eq!(Backend::parse("fair"), Some(Backend::TetradFair));
        assert_eq!(Backend::parse("nope"), None);
        assert_eq!(Backend::default(), Backend::Trident);
    }

    #[test]
    fn fair_mat_honest_all_backends_agree() {
        let run = run_4pc(NetProfile::zero(), 1901, |ctx| {
            let x = (ctx.id() == P1).then(test_mat);
            let sh = share_mat(ctx, P1, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            let fair = fair_reconstruct_mat_to(ctx, &sh, &crate::net::ALL, true)?;
            let god = god_reconstruct_mat(ctx, &sh)?;
            Ok((fair, god))
        });
        let (outs, _) = run.expect_ok();
        for (p, (fair, god)) in outs.iter().enumerate() {
            assert_eq!(fair.as_ref(), Some(&test_mat()), "P{p} fair");
            assert_eq!(god, &test_mat(), "P{p} god");
        }
    }

    #[test]
    fn god_subset_delivers_to_targets_only() {
        let run = run_4pc(NetProfile::zero(), 1902, |ctx| {
            let x = (ctx.id() == P2).then(test_mat);
            let sh = share_mat(ctx, P2, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            god_reconstruct_mat_to(ctx, &sh, &[P0, P2])
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(outs[0].as_ref(), Some(&test_mat()));
        assert_eq!(outs[2].as_ref(), Some(&test_mat()));
        assert_eq!(outs[1], None);
        assert_eq!(outs[3], None);
    }

    #[test]
    fn god_tolerates_equivocating_evaluator() {
        // P3 sends a corrupted λ1 to P1 during GOD delivery; P1 still
        // reconstructs from the P2+P0 majority and nobody aborts.
        let run = run_4pc(NetProfile::zero(), 1903, |ctx| {
            let x = (ctx.id() == P1).then(test_mat);
            let sh = share_mat(ctx, P1, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            if ctx.id() == P3 {
                return ctx.online(|ctx| {
                    ctx.flush_verify()?;
                    // duties toward targets [P0, P1, P2], with λ1 garbled
                    ctx.send_ring(P0, sh.m().data());
                    let n = sh.dims().0 * sh.dims().1;
                    let bad = vec![Z64(0xBAD); n];
                    ctx.send_ring(P1, &bad);
                    ctx.send_ring(P2, sh.lam(P3, 2).expect("λ2").data());
                    // own receive leg (P3 is also a target in this test)
                    let a: Vec<Z64> = ctx.recv_ring(P1, n)?;
                    let _b: Vec<Z64> = ctx.recv_ring(P2, n)?;
                    let _t: Vec<Z64> = ctx.recv_ring(P0, n)?;
                    let _ = a;
                    Ok(None)
                });
            }
            god_reconstruct_mat_to(ctx, &sh, &crate::net::ALL)
        });
        assert_eq!(run.outputs[0].as_ref().ok().and_then(|o| o.as_ref()), Some(&test_mat()));
        assert_eq!(run.outputs[1].as_ref().ok().and_then(|o| o.as_ref()), Some(&test_mat()));
        assert_eq!(run.outputs[2].as_ref().ok().and_then(|o| o.as_ref()), Some(&test_mat()));
    }

    #[test]
    fn god_p0_payload_breaks_ties_for_evaluator_target() {
        // Only P1 is a target; its λ1 arrives corrupted from P3, honestly
        // from P2, and as P0's trusted payload — majority(bad, good, good).
        let run = run_4pc(NetProfile::zero(), 1904, |ctx| {
            let x = (ctx.id() == P0).then(test_mat);
            let sh = share_mat(ctx, P0, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            if ctx.id() == P3 {
                return ctx.online(|ctx| {
                    ctx.flush_verify()?;
                    let n = sh.dims().0 * sh.dims().1;
                    ctx.send_ring(P1, &vec![Z64(0xBAD); n]);
                    Ok(None)
                });
            }
            god_reconstruct_mat_to(ctx, &sh, &[P1])
        });
        assert_eq!(run.outputs[1].as_ref().ok().and_then(|o| o.as_ref()), Some(&test_mat()));
    }

    #[test]
    fn fair_mat_majority_abort_is_unanimous() {
        // one evaluator votes abort → P0 relays → everyone aborts together
        let run = crate::proto::run_4pc_timeout(
            NetProfile::zero(),
            1905,
            std::time::Duration::from_millis(500),
            |ctx| {
                let x = (ctx.id() == P1).then(test_mat);
                let sh = share_mat(ctx, P1, x.as_ref(), 3, 2)?;
                ctx.flush_verify()?;
                let ok = ctx.id() != P2;
                fair_reconstruct_mat_to(ctx, &sh, &crate::net::ALL, ok)
            },
        );
        for o in &run.outputs {
            assert!(o.is_err(), "fairness: no partial output");
        }
    }

    #[test]
    fn god_still_fails_closed_on_corrupt_transcript() {
        // a pending digest mismatch (tampered evaluation phase) must abort
        // before GOD delivery opens anything — GOD never launders a bad wave
        let run = crate::proto::run_4pc_timeout(
            NetProfile::zero(),
            1906,
            std::time::Duration::from_millis(500),
            |ctx| {
                let x = (ctx.id() == P1).then(test_mat);
                let sh = share_mat(ctx, P1, x.as_ref(), 3, 2)?;
                ctx.flush_verify()?;
                ctx.online(|ctx| {
                    if ctx.is_evaluator() {
                        let v = if ctx.id() == P2 { Z64(666) } else { Z64(42) };
                        ctx.crosscheck_ring(&[v]);
                    }
                    Ok(())
                })?;
                god_reconstruct_mat(ctx, &sh)
            },
        );
        let evs = [&run.outputs[1], &run.outputs[2], &run.outputs[3]];
        assert!(evs.iter().any(|o| o.is_err()), "corrupt transcript must abort");
    }

    #[test]
    fn backend_dispatch_matches_trident_on_honest_run() {
        let run = run_4pc(NetProfile::zero(), 1907, |ctx| {
            let x = (ctx.id() == P1).then(test_mat);
            let sh = share_mat(ctx, P1, x.as_ref(), 3, 2)?;
            ctx.flush_verify()?;
            let mut outs = Vec::new();
            for b in [Backend::Trident, Backend::TetradFair, Backend::TetradGod] {
                outs.push(reconstruct_mat_to_backend(ctx, b, &sh, &[P2])?);
            }
            Ok(outs)
        });
        let (outs, _) = run.expect_ok();
        for o in &outs[2] {
            assert_eq!(o.as_ref(), Some(&test_mat()), "P2 opened under every backend");
        }
        for p in [0usize, 1, 3] {
            assert!(outs[p].iter().all(|o| o.is_none()), "P{p} learned nothing");
        }
    }
}
