//! # Trident — efficient 4PC framework for privacy-preserving machine learning
//!
//! A full reproduction of *Trident: Efficient 4PC Framework for Privacy
//! Preserving Machine Learning* (Rachuri & Suresh, NDSS 2020) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the 4PC protocol suite: sharing semantics,
//!   multiplication/dot-product/truncation, the garbled world, all share
//!   conversions, the ML building blocks, the ABY3/Gordon baselines, and the
//!   metered four-party network runtime they execute on.
//! * **Layer 2/1 (python/, build time only)** — JAX graphs of the party-local
//!   share computations with a Pallas `masked_matmul` kernel at the hot spot,
//!   AOT-lowered to HLO text artifacts.
//! * **runtime/** bridges the two: the rust hot path executes the AOT
//!   artifacts through the PJRT CPU client (`xla` crate), with a native
//!   fallback for shapes without artifacts.
//!
//! See DESIGN.md for the system inventory and per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod baseline;
pub mod bench;
pub mod convert;
pub mod coordinator;
pub mod crypto;
pub mod gc;
pub mod ml;
pub mod net;
pub mod proto;
pub mod ring;
pub mod runtime;
pub mod setup;
pub mod sharing;
pub mod testutil;

pub use net::{PartyId, P0, P1, P2, P3};
pub use ring::{Bit, Ring, Z64};
