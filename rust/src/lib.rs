//! # Trident — efficient 4PC framework for privacy-preserving machine learning
//!
//! A full reproduction of *Trident: Efficient 4PC Framework for Privacy
//! Preserving Machine Learning* (Rachuri & Suresh, NDSS 2020) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the 4PC protocol suite: sharing semantics,
//!   multiplication/dot-product/truncation, the garbled world, all share
//!   conversions, the ML building blocks, the ABY3/Gordon baselines, and the
//!   metered four-party network runtime they execute on.
//! * **Layer 2/1 (python/, build time only)** — JAX graphs of the party-local
//!   share computations with a Pallas `masked_matmul` kernel at the hot spot,
//!   AOT-lowered to HLO text artifacts.
//! * **runtime/** bridges the two: the rust hot path executes the AOT
//!   artifacts through the PJRT CPU client (`xla` crate; stubbed in
//!   offline builds), with a native fallback for shapes without artifacts.
//!
//! The serving stack on top of the protocol suite (§VI-A.a's
//! offline/online decoupling as a system):
//!
//! * **pool/** — the offline precomputation pool: typed, keyed correlated
//!   randomness (truncation pairs, λ_z skeletons, bit-extraction masks,
//!   circuit-position-keyed matrix wire-mask bundles — pre-drawn input
//!   wire masks + pre-exchanged `⟨Γ⟩` per `CircuitKey` — and
//!   circuit-keyed **nonlinear bundles**: `ReluCorr` = bitext masks +
//!   pre-exchanged `⟨γ_{r·v}⟩` + pre-checked `Π_BitInj` material, paired
//!   with the matrix bundle) generated ahead of time under
//!   `Phase::Offline`, topped up between serving waves by a background
//!   refill producer with low/high water marks; pool-aware protocol entry
//!   points (`trunc_pairs`, `mult`/`dotp` λ draws, `bitext_many`,
//!   `matmul_keyed`/`matmul_tr_keyed`, `bitext_many_keyed`/
//!   `relu_many_keyed`) pop from an attached pool and fall back to inline
//!   generation deterministically on exhaustion.
//! * **serve/** — the batched online serving engine: a request queue that
//!   coalesces concurrent inference queries into cross-request protocol
//!   batches (one round-trip per wave, not per query), registers its
//!   model's circuit keys at load — the matrix gate and its paired ReLU
//!   position — and drains the keyed bundles per wave, making the
//!   **whole** per-request offline phase message-free (ReLU included);
//!   verifies every response before release, and reports per-query
//!   amortized online cost (with a per-op matmul/relu offline-message
//!   split) through the meter.
//! * **sched/** — the multi-tenant scheduler over the serving stack: a
//!   model registry holding N resident models with per-tenant keyed pools
//!   (the `CircuitKey::model` field shards the offline material; a
//!   cross-tenant pop fails closed), a deadline/priority request queue
//!   (priority classes, EDF within a class, aging for starvation freedom,
//!   per-tenant admission caps), and a weighted-round-robin wave planner
//!   that interleaves refill ticks for the most-depleted tenant pool —
//!   all driven by logical ticks, lockstep-deterministic at the four
//!   parties (`serve::multi` is the engine that executes its decisions).
//!
//! See DESIGN.md for the system inventory and per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod baseline;
pub mod bench;
pub mod convert;
pub mod coordinator;
pub mod crypto;
pub mod gc;
pub mod ml;
pub mod net;
pub mod obs;
pub mod pool;
pub mod proto;
pub mod ring;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod setup;
pub mod sharing;
pub mod testutil;

pub use net::{PartyId, P0, P1, P2, P3};
pub use ring::{Bit, Ring, Z64};
