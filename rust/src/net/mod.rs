//! Four-party network runtime + measurement fabric.
//!
//! The paper benchmarks on four physical machines over LAN (1 Gbps,
//! rtt 0.296 ms) and WAN (GCP, 40 Mbps, the §VI rtt matrix). We reproduce the
//! *testbed* as an in-process cluster: each party is an OS thread running its
//! party program; every protocol message really flows through an mpsc channel
//! and is metered. Timing is a discrete-event virtual clock:
//!
//! * each party `i` carries a virtual clock `T_i` (per phase);
//! * `send` charges serialization `bytes·8/bw` to the sender;
//! * `recv` advances the receiver to `max(T_j, T_send + rtt_ij/2)` —
//!   one-way latency is half the measured rtt;
//! * rounds are measured, not asserted: messages carry the sender's round
//!   counter `r`, and a receiver moves to `max(r_own, r_msg + 1)` — i.e. the
//!   communication depth, which is exactly what the paper's round lemmas
//!   count.
//!
//! Local compute enters the clock through [`PartyCtx::timed`], which measures
//! real wall time of a closure and charges it to the party's clock. This is
//! the model the paper itself uses to explain its LAN/WAN gains (§VI-A.a):
//! time ≈ compute + rounds×latency + bytes/bandwidth.
//!
//! DESIGN.md §3 documents why this substitution preserves the benchmark
//! shape; DESIGN.md §7 the exact accounting.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::crypto::Digest32;
use crate::obs;

/// One of the four parties P0..P3. P0 is the "distributor"/helper that is
/// offline-only except for input sharing and output reconstruction.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct PartyId(pub u8);

pub const P0: PartyId = PartyId(0);
pub const P1: PartyId = PartyId(1);
pub const P2: PartyId = PartyId(2);
pub const P3: PartyId = PartyId(3);

/// All four parties, in order.
pub const ALL: [PartyId; 4] = [P0, P1, P2, P3];
/// The three online evaluators (P0 excluded).
pub const EVALUATORS: [PartyId; 3] = [P1, P2, P3];

impl PartyId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }

    pub fn is_evaluator(self) -> bool {
        self.0 != 0
    }

    /// The other two evaluators, for an evaluator id (cyclic order P1→P2→P3).
    pub fn next_evaluator(self) -> PartyId {
        debug_assert!(self.is_evaluator());
        PartyId(1 + (self.0 % 3))
    }

    pub fn prev_evaluator(self) -> PartyId {
        debug_assert!(self.is_evaluator());
        PartyId(1 + ((self.0 + 1) % 3))
    }
}

impl std::fmt::Display for PartyId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{}", self.0)
    }
}

/// Protocol phase, for separate offline/online accounting (the paper reports
/// the two phases separately everywhere).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Phase {
    Offline = 0,
    Online = 1,
}

/// Message class, for the amortized-cost accounting of Appendices B–D:
/// `Value` bytes are what the communication lemmas count; `Hash`/`Commit`
/// are the (batched, amortized-away) verification traffic; `Garbled` is
/// garbled-table + decoding material.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum MsgClass {
    Value = 0,
    Hash = 1,
    Commit = 2,
    Garbled = 3,
    Control = 4,
}

const N_CLASS: usize = 5;

/// Why a party program stopped.
///
/// ## Abort-scoping contract (tenant-scoped vs party-scoped)
///
/// Like the metering contract above, this is an invariant callers build on:
///
/// * **Party-scoped** aborts — [`Abort::Verify`], [`Abort::Signalled`],
///   [`Abort::Channel`] — implicate a *party* (a failed consistency check,
///   a peer's abort signal, a dead channel). They always fail the whole
///   run closed: no containment layer may swallow them, because the
///   paper's one-malicious-corruption security argument is exactly that an
///   honest party stops the world when verification fails.
/// * **Tenant-scoped** aborts — [`Abort::TenantScoped`] — carry the
///   *provenance* of an in-wave failure: which tenant's wave (the pool
///   shard `model`), at which logical `tick`, and why. All three fields
///   are public schedule metadata, identical at the four parties, so a
///   containment decision made on them is lockstep-deterministic. The
///   variant is only ever constructed by the serving engine's wave
///   wrapper *after* the four parties have exchanged wave outcomes over
///   [`PartyCtx::wave_barrier`]; the underlying protocol error stays one
///   of the party-scoped variants until that barrier agrees the blast
///   radius is one tenant's keyed material. A `TenantScoped` abort that
///   escapes to the caller (containment disabled, or escalation —
///   e.g. a party died, or the failing wave ran inline generation whose
///   correlated PRF draws cannot be re-synchronised) fails the run closed
///   exactly like a party-scoped one.
///
/// ### Failover rung (GOD degrade ladder)
///
/// With [`FailoverPolicy::God`](crate::serve::FailoverPolicy), a contained
/// `TenantScoped` abort additionally arms a *failover* for the offending
/// tenant: its re-queued queries are served on the Tetrad-style
/// guaranteed-output-delivery backend ([`crate::proto::tetrad`]) until
/// [`REHAB_AFTER`](crate::serve::REHAB_AFTER) consecutive clean failover
/// waves rehabilitate it back to keyed Trident serving. The ladder is
/// keyed Trident → quarantine (contained `TenantScoped`) → GOD failover →
/// rehabilitation. The failover rung changes *output delivery only* — the
/// evaluation phase, and therefore this abort contract, is unchanged:
/// party-scoped aborts on a failover wave still stop the world, and a
/// GOD delivery first verifies the evaluation transcript and fails closed
/// on corruption before reconstructing from redundant copies.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Abort {
    /// A consistency check failed locally (the honest-party abort of the
    /// paper's protocols).
    Verify(String),
    /// A peer signalled abort.
    Signalled(PartyId),
    /// Channel closed / timed out (peer died).
    Channel(PartyId),
    /// An in-wave failure attributed (by the four-party wave barrier) to
    /// one tenant's wave — see the abort-scoping contract above. `model`
    /// is the tenant's pool-shard id ([`crate::pool::CircuitKey`]'s
    /// `model` field), `tick` the logical tick of the poisoned wave.
    TenantScoped { model: u64, tick: u64, why: String },
}

impl std::fmt::Display for Abort {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Abort::Verify(why) => write!(f, "verification failed: {why}"),
            Abort::Signalled(p) => write!(f, "abort signalled by {p}"),
            Abort::Channel(p) => write!(f, "channel to {p} broken"),
            Abort::TenantScoped { model, tick, why } => {
                write!(f, "tenant-scoped abort (model {model}, tick {tick}): {why}")
            }
        }
    }
}

impl std::error::Error for Abort {}

struct Envelope {
    payload: Vec<u8>,
    /// Sender's virtual send-completion time (after serialization).
    t_send: f64,
    /// Sender's round counter at send time.
    round: u64,
    class: MsgClass,
    abort: bool,
}

/// Network profile: pairwise rtt (seconds) + per-link bandwidth (bits/s).
#[derive(Clone, Debug)]
pub struct NetProfile {
    pub name: &'static str,
    /// rtt[i][j] in seconds (symmetric, diag 0).
    pub rtt: [[f64; 4]; 4],
    /// Link bandwidth, bits per second.
    pub bandwidth_bps: f64,
}

impl NetProfile {
    /// §VI: LAN, 1 Gbps, rtt 0.296 ms between every pair.
    pub fn lan() -> NetProfile {
        let r = 0.296e-3;
        let mut rtt = [[r; 4]; 4];
        for (i, row) in rtt.iter_mut().enumerate() {
            row[i] = 0.0;
        }
        NetProfile { name: "LAN", rtt, bandwidth_bps: 1e9 }
    }

    /// §VI: WAN (GCP: West Europe, East Australia, South Asia, SE Asia),
    /// 40 Mbps, measured rtt matrix.
    pub fn wan() -> NetProfile {
        Self::wan_with_bandwidth(40e6)
    }

    /// WAN rtt matrix with a configurable bandwidth cap — Fig. 20's
    /// "Throughput Gain in Low-end Networks" sweeps this from 0.5–40 Mbps.
    pub fn wan_with_bandwidth(bps: f64) -> NetProfile {
        let ms = 1e-3;
        let mut rtt = [[0.0; 4]; 4];
        let pairs = [
            (0, 1, 274.83),
            (0, 2, 174.13),
            (0, 3, 219.45),
            (1, 2, 152.3),
            (1, 3, 60.19),
            (2, 3, 92.63),
        ];
        for (i, j, v) in pairs {
            rtt[i][j] = v * ms;
            rtt[j][i] = v * ms;
        }
        NetProfile { name: "WAN", rtt, bandwidth_bps: bps }
    }

    /// Zero-cost network for pure-logic tests.
    pub fn zero() -> NetProfile {
        NetProfile { name: "zero", rtt: [[0.0; 4]; 4], bandwidth_bps: f64::INFINITY }
    }
}

#[derive(Default, Clone, Debug)]
struct MeterInner {
    /// bytes[phase][class]
    bytes: [[u64; N_CLASS]; 2],
    /// analytic bits of `Value`-class traffic per phase (bit-granular: a
    /// boolean share counts 1, a Z64 share 64) — what Tables I/II/IX/X count.
    value_bits: [u64; 2],
    /// bytes per directed pair (both phases)
    pair_bytes: [[u64; 4]; 4],
    /// messages per phase
    msgs: [u64; 2],
}

/// Shared measurement fabric (wrapped in `Arc<Mutex<…>>`).
#[derive(Clone, Default)]
pub struct Meter {
    inner: Arc<Mutex<MeterInner>>,
}

impl Meter {
    fn record(&self, phase: Phase, class: MsgClass, from: PartyId, to: PartyId, bytes: usize, bits: u64) {
        let mut m = self.inner.lock().unwrap();
        m.bytes[phase as usize][class as usize] += bytes as u64;
        if class == MsgClass::Value {
            m.value_bits[phase as usize] += bits;
        }
        m.pair_bytes[from.idx()][to.idx()] += bytes as u64;
        m.msgs[phase as usize] += 1;
    }
}

/// Aggregated measurements of one cluster run.
#[derive(Clone, Debug, Default)]
pub struct NetReport {
    /// Value-class bytes, offline/online.
    pub value_bytes: [u64; 2],
    /// Analytic value bits, offline/online.
    pub value_bits: [u64; 2],
    /// Hash+commit verification bytes, offline/online.
    pub verify_bytes: [u64; 2],
    /// Garbled-material bytes, offline/online.
    pub garbled_bytes: [u64; 2],
    /// Total bytes offline/online (all classes).
    pub total_bytes: [u64; 2],
    /// Measured communication rounds (depth), offline/online.
    pub rounds: [u64; 2],
    /// Per-party virtual completion time (s), offline/online.
    pub party_time: [[f64; 4]; 2],
    /// Messages, offline/online.
    pub msgs: [u64; 2],
    /// Real wall-clock duration of the whole cluster run.
    pub wall: Duration,
}

impl NetReport {
    /// Max party virtual time in a phase = protocol latency.
    pub fn latency(&self, phase: Phase) -> f64 {
        self.party_time[phase as usize].iter().cloned().fold(0.0, f64::max)
    }

    /// Latency over the online evaluators only (P0 excluded).
    pub fn online_latency(&self) -> f64 {
        self.party_time[Phase::Online as usize][1..].iter().cloned().fold(0.0, f64::max)
    }

    /// Sum of all parties' virtual time in a phase — the monetary-cost
    /// metric of Appendix E.
    pub fn total_party_time(&self, phase: Phase) -> f64 {
        self.party_time[phase as usize].iter().sum()
    }
}

/// Per-party handle to the cluster: channels + clock + round counter.
pub struct PartyCtx {
    pub id: PartyId,
    senders: [Option<Sender<Envelope>>; 4],
    receivers: [Option<Receiver<Envelope>>; 4],
    meter: Meter,
    profile: Arc<NetProfile>,
    /// Virtual clock per phase (seconds).
    clock: [f64; 2],
    /// Lamport-style round counter per phase.
    round: [u64; 2],
    phase: Phase,
    recv_timeout: Duration,
    aborted: bool,
    /// Local sent-traffic counters per phase (messages / payload bytes).
    /// Unlike the cluster-global [`Meter`], these move only when *this*
    /// party sends, so a party program can meter one of its own code
    /// windows (e.g. "this serving wave") without racing the other party
    /// threads — the offline-silence regression tests depend on that.
    sent_msgs: [u64; 2],
    sent_bytes: [u64; 2],
    /// `Value`-class payload bytes only (the class the communication
    /// lemmas count) — the serving engine's per-wave `value_bytes` column,
    /// kept apart from digests/commitments in [`PartyCtx::sent_bytes`].
    sent_value_bytes: [u64; 2],
    /// Local compute seconds charged via [`PartyCtx::charge_compute`] /
    /// [`PartyCtx::timed`], per phase (monotone — the virtual clock mixes
    /// compute with serialization and latency; this separates it so the
    /// serving engine can report a per-wave compute column).
    compute: [f64; 2],
    /// Structured trace sink ([`crate::obs`]). Disabled by default: every
    /// hook is one branch and records nothing. Every hook sits *after*
    /// the metering arithmetic of the site it instruments and never sends
    /// or samples — the observer-effect contract (see the module doc of
    /// [`crate::obs`]).
    trace: obs::Trace,
}

impl PartyCtx {
    pub fn phase(&self) -> Phase {
        self.phase
    }

    /// Switch to the online phase (clock and round counters are per-phase).
    pub fn set_phase(&mut self, phase: Phase) {
        if phase != self.phase {
            // per-party detail event (parties nest phases at different
            // depths around their own sends): lockstep = false
            self.trace.record("phase.switch", phase, false, obs::Payload::default());
        }
        self.phase = phase;
    }

    pub fn clock(&self, phase: Phase) -> f64 {
        self.clock[phase as usize]
    }

    pub fn rounds(&self, phase: Phase) -> u64 {
        self.round[phase as usize]
    }

    /// Reset clocks and round counters — benches call this after input
    /// sharing to measure a steady-state iteration in isolation.
    pub fn reset_clocks(&mut self) {
        self.clock = [0.0; 2];
        self.round = [0; 2];
    }

    /// Messages this party has sent in `phase` (all classes, monotone —
    /// window a code region by differencing two reads).
    pub fn sent_msgs(&self, phase: Phase) -> u64 {
        self.sent_msgs[phase as usize]
    }

    /// Payload bytes this party has sent in `phase` (all classes, monotone).
    pub fn sent_bytes(&self, phase: Phase) -> u64 {
        self.sent_bytes[phase as usize]
    }

    /// `Value`-class payload bytes this party has sent in `phase`
    /// (monotone; excludes hash/commit/garbled traffic).
    pub fn sent_value_bytes(&self, phase: Phase) -> u64 {
        self.sent_value_bytes[phase as usize]
    }

    /// Local compute seconds charged in `phase` (monotone — window a code
    /// region by differencing two reads, like [`PartyCtx::sent_bytes`]).
    pub fn compute_time(&self, phase: Phase) -> f64 {
        self.compute[phase as usize]
    }

    /// Snapshot of every monotone per-party meter, both phases — the
    /// opening value of an [`obs::Window`]. Replaces the hand-subtracted
    /// `sent_msgs`/`sent_bytes`/… snapshot pairs at the serving call
    /// sites.
    pub fn counters(&self) -> obs::Counters {
        obs::Counters {
            msgs: self.sent_msgs,
            bytes: self.sent_bytes,
            value_bytes: self.sent_value_bytes,
            rounds: self.round,
            clock: self.clock,
            compute: self.compute,
        }
    }

    /// The party's structured trace sink (cursor updates, enable/drain).
    pub fn trace(&mut self) -> &mut obs::Trace {
        &mut self.trace
    }

    #[inline]
    pub fn trace_on(&self) -> bool {
        self.trace.enabled()
    }

    /// Record a trace event stamped with the current phase and the
    /// ambient identity cursor.
    #[inline]
    pub fn trace_event(&mut self, op: &'static str, lockstep: bool, payload: obs::Payload) {
        let ph = self.phase;
        self.trace.record(op, ph, lockstep, payload);
    }

    /// Record a trace event with explicit identity fields (gauges whose
    /// tenant/gate are not the ambient wave's).
    #[inline]
    pub fn trace_event_at(
        &mut self,
        op: &'static str,
        lockstep: bool,
        tenant: Option<u32>,
        wave: Option<u64>,
        gate: Option<u32>,
        payload: obs::Payload,
    ) {
        let ph = self.phase;
        self.trace.record_at(op, ph, lockstep, tenant, wave, gate, payload);
    }

    /// Charge `dt` seconds of local compute to this party's virtual clock.
    pub fn charge_compute(&mut self, dt: f64) {
        self.clock[self.phase as usize] += dt;
        self.compute[self.phase as usize] += dt;
    }

    /// Run `f`, measure its real duration, charge it to the virtual clock.
    pub fn timed<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.charge_compute(t0.elapsed().as_secs_f64());
        out
    }

    /// Send `payload` to `to`. `bits` is the analytic size for the cost
    /// tables (pass `payload.len()*8` via [`PartyCtx::send`] when they
    /// coincide).
    pub fn send_with_bits(&mut self, to: PartyId, payload: &[u8], class: MsgClass, bits: u64) {
        assert_ne!(to, self.id, "self-send");
        let ph = self.phase as usize;
        // serialization occupies the sender link
        self.clock[ph] += payload.len() as f64 * 8.0 / self.profile.bandwidth_bps;
        self.sent_msgs[ph] += 1;
        self.sent_bytes[ph] += payload.len() as u64;
        if class == MsgClass::Value {
            self.sent_value_bytes[ph] += payload.len() as u64;
        }
        self.meter.record(self.phase, class, self.id, to, payload.len(), bits);
        // trace hook strictly AFTER the metering arithmetic: recording is
        // local-only, so metered counters are byte-for-byte unchanged by
        // tracing (the observer-effect contract, see `crate::obs`)
        self.trace.record(
            "net.send",
            self.phase,
            false,
            obs::Payload { msgs: 1, bytes: payload.len() as u64, ..obs::Payload::default() },
        );
        let env = Envelope {
            payload: payload.to_vec(),
            t_send: self.clock[ph],
            round: self.round[ph],
            class,
            abort: false,
        };
        // A closed channel means the peer is gone; the subsequent recv from
        // it will surface the abort, so ignore the send error here.
        let _ = self.senders[to.idx()].as_ref().expect("channel").send(env);
    }

    pub fn send(&mut self, to: PartyId, payload: &[u8], class: MsgClass) {
        self.send_with_bits(to, payload, class, payload.len() as u64 * 8)
    }

    /// Blocking receive from a specific peer; advances clock + round.
    pub fn recv(&mut self, from: PartyId) -> Result<Vec<u8>, Abort> {
        self.recv_tagged(from).map(|(p, _)| p)
    }

    /// [`PartyCtx::recv`] returning the sender's [`MsgClass`] tag — protocol
    /// code asserts the class to catch vouch/expect pairing bugs loudly
    /// instead of silently confusing a digest with a value message.
    pub fn recv_tagged(&mut self, from: PartyId) -> Result<(Vec<u8>, MsgClass), Abort> {
        assert_ne!(from, self.id, "self-recv");
        let rx = self.receivers[from.idx()].as_ref().expect("channel");
        let env = match rx.recv_timeout(self.recv_timeout) {
            Ok(e) => e,
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                return Err(Abort::Channel(from))
            }
        };
        if env.abort {
            return Err(Abort::Signalled(from));
        }
        let ph = self.phase as usize;
        let lat = self.profile.rtt[from.idx()][self.id.idx()] / 2.0;
        self.clock[ph] = self.clock[ph].max(env.t_send + lat);
        // Round depth counts protocol data (Value/Garbled) only: hash and
        // commitment traffic is the amortized verification the paper's round
        // lemmas exclude ("the cost gets amortized", Lemmas B.1–B.4).
        if matches!(env.class, MsgClass::Value | MsgClass::Garbled) {
            self.round[ph] = self.round[ph].max(env.round + 1);
        }
        Ok((env.payload, env.class))
    }

    /// Receive and require the payload to equal `expect` (consistency
    /// check pattern: "abort if the received values are inconsistent").
    pub fn recv_expect(&mut self, from: PartyId, expect: &[u8], what: &str) -> Result<(), Abort> {
        let got = self.recv(from)?;
        if got != expect {
            return Err(self.abort(format!("{what}: inconsistent value from {from}")));
        }
        Ok(())
    }

    /// Broadcast the abort signal to all peers (idempotent — the flag keeps
    /// a party from flooding twice). Split out of [`PartyCtx::abort`] so a
    /// containment wrapper can also unblock peers when the local error is
    /// *not* a fresh verification failure (e.g. the wave died on a
    /// [`Abort::Signalled`] from a third party, or a fail-closed pool pop).
    pub fn signal_abort(&mut self) {
        if self.aborted {
            return;
        }
        self.aborted = true;
        let ph = self.phase as usize;
        for p in ALL {
            if p != self.id {
                let env = Envelope {
                    payload: Vec::new(),
                    t_send: self.clock[ph],
                    round: self.round[ph],
                    class: MsgClass::Control,
                    abort: true,
                };
                if let Some(tx) = self.senders[p.idx()].as_ref() {
                    let _ = tx.send(env);
                }
            }
        }
    }

    /// Broadcast abort to all peers and construct the local abort error.
    pub fn abort(&mut self, why: String) -> Abort {
        self.signal_abort();
        Abort::Verify(why)
    }

    /// Four-party **wave-outcome barrier** — the containment layer's
    /// agreement step, run by every party after every serving wave when
    /// abort-blast-radius containment is enabled.
    ///
    /// Each party broadcasts one `Control`-class envelope carrying the
    /// public `(wave, status)` pair and then drains each peer channel up
    /// to that peer's matching barrier envelope, skipping whatever the
    /// aborted wave left in flight (stale value/digest payloads, abort
    /// signals — per-channel FIFO guarantees the peer's barrier envelope
    /// comes after all of its wave traffic). Returns all four statuses,
    /// indexed by party, identical at every party — any containment
    /// decision derived from them is therefore lockstep-deterministic.
    ///
    /// The barrier also re-arms the abort flood (`aborted = false`): a
    /// contained wave is over, and a *later* failure must broadcast again.
    /// A party that died before its barrier send surfaces here as
    /// [`Abort::Channel`] — a dead party always fails the run closed, the
    /// barrier never outvotes it.
    ///
    /// Barrier traffic is `Control` class: excluded from round counting
    /// and from `Value`-class byte accounting by the metering contract, so
    /// enabling containment does not perturb the paper-facing tables.
    pub fn wave_barrier(&mut self, wave: u64, status: u8) -> Result<[u8; 4], Abort> {
        let mut payload = [0u8; 9];
        payload[..8].copy_from_slice(&wave.to_le_bytes());
        payload[8] = status;
        for p in ALL {
            if p != self.id {
                self.send(p, &payload, MsgClass::Control);
            }
        }
        let mut statuses = [0u8; 4];
        statuses[self.id.idx()] = status;
        let ph = self.phase as usize;
        for p in ALL {
            if p == self.id {
                continue;
            }
            loop {
                let rx = self.receivers[p.idx()].as_ref().expect("channel");
                let env = match rx.recv_timeout(self.recv_timeout) {
                    Ok(e) => e,
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                        return Err(Abort::Channel(p))
                    }
                };
                // skip the aborted wave's leftovers: abort signals and any
                // stale value/digest traffic still queued ahead of the
                // peer's barrier envelope
                if env.abort || env.class != MsgClass::Control {
                    continue;
                }
                if env.payload.len() == 9 && env.payload[..8] == wave.to_le_bytes() {
                    statuses[p.idx()] = env.payload[8];
                    let lat = self.profile.rtt[p.idx()][self.id.idx()] / 2.0;
                    self.clock[ph] = self.clock[ph].max(env.t_send + lat);
                    break;
                }
                // a Control envelope for another wave index is stale
                // barrier debris from a skipped epoch — drain it too
            }
        }
        self.aborted = false;
        Ok(statuses)
    }

    /// Send a digest (verification traffic).
    pub fn send_digest(&mut self, to: PartyId, d: &Digest32) {
        self.send(to, d, MsgClass::Hash);
    }

    /// Receive a digest and compare.
    pub fn recv_digest_expect(&mut self, from: PartyId, expect: &Digest32, what: &str) -> Result<(), Abort> {
        let (got, class) = self.recv_tagged(from)?;
        if class != MsgClass::Hash {
            return Err(self.abort(format!("{what}: expected digest from {from}, got {class:?}")));
        }
        if got != expect.as_slice() {
            return Err(self.abort(format!("{what}: digest mismatch from {from}")));
        }
        Ok(())
    }
}

/// Outcome of one party program.
pub type PartyResult<T> = Result<T, Abort>;

/// Results of a full cluster run.
pub struct ClusterRun<T> {
    /// Per-party program outputs (indexed by party).
    pub outputs: [PartyResult<T>; 4],
    pub report: NetReport,
}

impl<T> ClusterRun<T> {
    /// Unwrap all four outputs, panicking on any abort (for tests/benches of
    /// honest executions).
    pub fn expect_ok(self) -> ([T; 4], NetReport) {
        let [a, b, c, d] = self.outputs;
        (
            [
                a.expect("P0 aborted"),
                b.expect("P1 aborted"),
                c.expect("P2 aborted"),
                d.expect("P3 aborted"),
            ],
            self.report,
        )
    }

    /// True if every party aborted-or-errored.
    pub fn all_aborted(&self) -> bool {
        self.outputs.iter().all(|o| o.is_err())
    }

    /// True if any honest party got a verification abort.
    pub fn any_verify_abort(&self) -> bool {
        self.outputs.iter().any(|o| {
            matches!(
                o,
                Err(Abort::Verify(_)) | Err(Abort::Signalled(_)) | Err(Abort::TenantScoped { .. })
            )
        })
    }
}

/// Build the 4-party cluster and run one party program per thread.
///
/// `program` receives the party's [`PartyCtx`]; it is cloned per thread via
/// `Arc`. Returns per-party outputs plus the merged [`NetReport`].
pub fn run_cluster<T, F>(profile: NetProfile, program: F) -> ClusterRun<T>
where
    T: Send + 'static,
    F: Fn(&mut PartyCtx) -> PartyResult<T> + Send + Sync + 'static,
{
    run_cluster_timeout(profile, Duration::from_secs(30), program)
}

/// [`run_cluster`] with a custom recv timeout (tests that expect deadlocked
/// aborts use a short one).
pub fn run_cluster_timeout<T, F>(profile: NetProfile, timeout: Duration, program: F) -> ClusterRun<T>
where
    T: Send + 'static,
    F: Fn(&mut PartyCtx) -> PartyResult<T> + Send + Sync + 'static,
{
    let meter = Meter::default();
    let profile = Arc::new(profile);
    // channels[from][to]
    let mut txs: Vec<Vec<Option<Sender<Envelope>>>> = (0..4).map(|_| (0..4).map(|_| None).collect()).collect();
    let mut rxs: Vec<Vec<Option<Receiver<Envelope>>>> = (0..4).map(|_| (0..4).map(|_| None).collect()).collect();
    for i in 0..4 {
        for j in 0..4 {
            if i != j {
                let (tx, rx) = std::sync::mpsc::channel();
                txs[i][j] = Some(tx);
                rxs[j][i] = Some(rx); // rxs[receiver][sender]
            }
        }
    }

    let program = Arc::new(program);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, (tx_row, rx_row)) in txs.into_iter().zip(rxs.into_iter()).enumerate() {
        let mut ctx = PartyCtx {
            id: PartyId(i as u8),
            senders: tx_row.try_into().map_err(|_| ()).unwrap(),
            receivers: rx_row.try_into().map_err(|_| ()).unwrap(),
            meter: meter.clone(),
            profile: profile.clone(),
            clock: [0.0; 2],
            round: [0; 2],
            phase: Phase::Offline,
            recv_timeout: timeout,
            aborted: false,
            sent_msgs: [0; 2],
            sent_bytes: [0; 2],
            sent_value_bytes: [0; 2],
            compute: [0.0; 2],
            trace: obs::Trace::default(),
        };
        let program = program.clone();
        handles.push(std::thread::spawn(move || {
            let out = program(&mut ctx);
            let out = match out {
                Ok(v) => Ok(v),
                Err(Abort::Verify(w)) => {
                    // make sure peers unblock
                    ctx.abort(w.clone());
                    Err(Abort::Verify(w))
                }
                e => e,
            };
            (out, ctx.clock, ctx.round)
        }));
    }

    let mut outputs: Vec<Option<PartyResult<T>>> = (0..4).map(|_| None).collect();
    let mut party_time = [[0.0f64; 4]; 2];
    let mut rounds = [0u64; 2];
    for (i, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok((out, clock, round)) => {
                outputs[i] = Some(out);
                party_time[0][i] = clock[0];
                party_time[1][i] = clock[1];
                rounds[0] = rounds[0].max(round[0]);
                rounds[1] = rounds[1].max(round[1]);
            }
            Err(_) => outputs[i] = Some(Err(Abort::Channel(PartyId(i as u8)))),
        }
    }
    let wall = t0.elapsed();

    let m = meter.inner.lock().unwrap().clone();
    let mut report = NetReport {
        value_bytes: [m.bytes[0][0], m.bytes[1][0]],
        value_bits: m.value_bits,
        verify_bytes: [m.bytes[0][1] + m.bytes[0][2], m.bytes[1][1] + m.bytes[1][2]],
        garbled_bytes: [m.bytes[0][3], m.bytes[1][3]],
        total_bytes: [0, 0],
        rounds,
        party_time,
        msgs: m.msgs,
        wall,
    };
    for ph in 0..2 {
        report.total_bytes[ph] = m.bytes[ph].iter().sum();
    }

    let mut it = outputs.into_iter().map(|o| o.unwrap());
    ClusterRun {
        outputs: [it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap()],
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong_counts_rounds_and_bytes() {
        let run = run_cluster(NetProfile::zero(), |ctx| {
            ctx.set_phase(Phase::Online);
            match ctx.id {
                P0 => {
                    ctx.send(P1, &[1u8; 8], MsgClass::Value);
                    let r = ctx.recv(P1)?;
                    assert_eq!(r, vec![2u8; 8]);
                }
                P1 => {
                    let r = ctx.recv(P0)?;
                    assert_eq!(r, vec![1u8; 8]);
                    ctx.send(P0, &[2u8; 8], MsgClass::Value);
                }
                _ => {}
            }
            Ok(())
        });
        let (_, report) = run.expect_ok();
        assert_eq!(report.rounds[Phase::Online as usize], 2);
        assert_eq!(report.value_bytes[Phase::Online as usize], 16);
        assert_eq!(report.value_bits[Phase::Online as usize], 128);
    }

    #[test]
    fn parallel_sends_are_one_round() {
        // all three evaluators exchange simultaneously: depth 1
        let run = run_cluster(NetProfile::zero(), |ctx| {
            ctx.set_phase(Phase::Online);
            if ctx.id.is_evaluator() {
                ctx.send(ctx.id.next_evaluator(), &[ctx.id.0], MsgClass::Value);
                let v = ctx.recv(ctx.id.prev_evaluator())?;
                assert_eq!(v, vec![ctx.id.prev_evaluator().0]);
            }
            Ok(())
        });
        let (_, report) = run.expect_ok();
        assert_eq!(report.rounds[Phase::Online as usize], 1);
    }

    #[test]
    fn wan_latency_charged() {
        let run = run_cluster(NetProfile::wan(), |ctx| {
            ctx.set_phase(Phase::Online);
            match ctx.id {
                P1 => ctx.send(P3, &[0u8; 100], MsgClass::Value),
                P3 => {
                    ctx.recv(P1)?;
                }
                _ => {}
            }
            Ok(())
        });
        let (_, report) = run.expect_ok();
        let t3 = report.party_time[Phase::Online as usize][3];
        // one-way P1-P3 = 60.19/2 ms plus 800 bits / 40 Mbps
        let expect = 60.19e-3 / 2.0 + 800.0 / 40e6;
        assert!((t3 - expect).abs() < 1e-9, "t3={t3}, expect={expect}");
        // P0 never active online
        assert_eq!(report.party_time[Phase::Online as usize][0], 0.0);
    }

    #[test]
    fn abort_propagates() {
        let run = run_cluster_timeout(NetProfile::zero(), Duration::from_millis(500), |ctx| {
            ctx.set_phase(Phase::Online);
            match ctx.id {
                P1 => Err(ctx.abort("cheater detected".into())),
                P2 => {
                    // P2 waits on P1 and sees the abort signal
                    let r = ctx.recv(P1);
                    assert!(matches!(r, Err(Abort::Signalled(P1))));
                    r.map(|_| ())
                }
                _ => Ok(()),
            }
        });
        assert!(run.outputs[1].is_err());
        assert!(run.outputs[2].is_err());
        assert!(run.outputs[0].is_ok());
    }

    #[test]
    fn wave_barrier_agrees_and_drains_stale_traffic() {
        let run = run_cluster_timeout(NetProfile::zero(), Duration::from_millis(500), |ctx| {
            ctx.set_phase(Phase::Online);
            // P1's wave "fails": it leaves a stale value message in P2's
            // channel and floods abort signals before entering the barrier
            if ctx.id == P1 {
                ctx.send(P2, &[7u8; 4], MsgClass::Value);
                ctx.signal_abort();
            }
            let statuses = ctx.wave_barrier(3, u8::from(ctx.id == P1))?;
            // the barrier re-arms the abort flood: a later failure at the
            // same party must broadcast fresh signals, observable at P2
            if ctx.id == P1 {
                ctx.signal_abort();
            }
            if ctx.id == P2 {
                let r = ctx.recv(P1);
                assert!(matches!(r, Err(Abort::Signalled(P1))), "re-armed flood: {r:?}");
            }
            Ok(statuses)
        });
        let (outs, _) = run.expect_ok();
        for s in &outs {
            assert_eq!(*s, [0, 1, 0, 0], "identical statuses at all four parties");
        }
    }

    #[test]
    fn phase_accounting_separates() {
        let run = run_cluster(NetProfile::zero(), |ctx| {
            if ctx.id == P0 {
                ctx.send(P1, &[9u8; 4], MsgClass::Value); // offline
            }
            if ctx.id == P1 {
                ctx.recv(P0)?;
            }
            ctx.set_phase(Phase::Online);
            if ctx.id == P1 {
                ctx.send(P2, &[9u8; 2], MsgClass::Value); // online
            }
            if ctx.id == P2 {
                ctx.recv(P1)?;
            }
            Ok(())
        });
        let (_, r) = run.expect_ok();
        assert_eq!(r.value_bytes, [4, 2]);
        assert_eq!(r.rounds[0], 1);
        assert_eq!(r.rounds[1], 1);
    }

    #[test]
    fn compute_charging() {
        let run = run_cluster(NetProfile::zero(), |ctx| {
            ctx.set_phase(Phase::Online);
            if ctx.id == P1 {
                ctx.charge_compute(0.125);
                assert_eq!(ctx.compute_time(Phase::Online), 0.125);
                assert_eq!(ctx.compute_time(Phase::Offline), 0.0);
            }
            Ok(())
        });
        let (_, r) = run.expect_ok();
        assert_eq!(r.party_time[1][1], 0.125);
        assert_eq!(r.online_latency(), 0.125);
    }

    #[test]
    fn bit_granular_metering() {
        let run = run_cluster(NetProfile::zero(), |ctx| {
            ctx.set_phase(Phase::Online);
            if ctx.id == P1 {
                // a boolean share travels as 1 byte but counts 1 analytic bit
                ctx.send_with_bits(P2, &[1u8], MsgClass::Value, 1);
            }
            if ctx.id == P2 {
                ctx.recv(P1)?;
            }
            Ok(())
        });
        let (_, r) = run.expect_ok();
        assert_eq!(r.value_bits[1], 1);
        assert_eq!(r.value_bytes[1], 1);
    }
}
