//! Garbled-world conversions: `Π_G2B` (Fig. 10), `Π_G2A` (Fig. 11),
//! `Π_B2G` (Fig. 12), `Π_A2G` (Fig. 13).
//!
//! The pattern: a random `r` bridges the worlds — shared verifiably in both
//! the garbled world and the target world by its two owners; P0 evaluates a
//! (possibly free-XOR-only) circuit on `v` and `r`, learns the masked
//! `v ⊕ r` / `v − r` in clear, and re-shares it towards the target world
//! with the garbling scheme's authenticity backing its honesty.

use crate::crypto::HashAcc;
use crate::gc::circuit::{adder, bits_u64, subtractor, u64_bits, Builder};
use crate::gc::{g_eval, g_reconstruct, g_vsh, offset, GShare};
use crate::net::{Abort, MsgClass, P0, P1, P2, P3};
use crate::proto::sharing::{vsh, vsh_many};
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::MShare;

/// `Π_G2B` for one bit: `[[v]]^G → [[v]]^B`. Online: 1 round, 3 bits.
pub fn g2b(ctx: &mut Ctx, v: &GShare) -> Result<MShare<Bit>, Abort> {
    let me = ctx.id();
    // offline: r by P1,P2 → [[r]]^G and [[r]]^B
    let r_clear: Option<Vec<Bit>> = (me == P1 || me == P2).then(|| {
        let peer = if me == P1 { P2 } else { P1 };
        vec![Bit(ctx.keys.sample_pair::<Z64>(peer).0 & 1 == 1)]
    });
    let (rg, rb) = ctx.offline(|ctx| -> Result<_, Abort> {
        let rg = g_vsh(ctx, (P1, P2), r_clear.as_deref(), 1)?;
        let rb = vsh_many::<Bit>(ctx, (P1, P2), r_clear.as_deref(), 1)?;
        Ok((rg, rb))
    })?;

    // online: P0 "evaluates Add(v, r)" — XOR is free, so the active label is
    // just the XOR of labels; P0 decodes v⊕r from the colour bits which the
    // garblers expose for this output wire (the "decoding information").
    let vr_label = crate::gc::g_xor(v, &rg[0]);
    let opened = g_reconstruct(ctx, &[vr_label], P0)?;

    // P0 sends v⊕r + H(actual key) to P3; P3 verifies via authenticity
    let vr_for_share: Option<Vec<Bit>> = ctx.online(|ctx| -> Result<_, Abort> {
        match me {
            P0 => {
                let bit = opened.as_ref().unwrap()[0];
                ctx.net.send_with_bits(P3, &[bit.as_u8()], MsgClass::Value, 1);
                let mut acc = HashAcc::new();
                acc.absorb(&vr_label.key());
                let d = acc.finalize();
                ctx.net.send_digest(P3, &d);
                Ok(Some(vec![bit]))
            }
            P3 => {
                let raw = ctx.net.recv(P0)?;
                let bit = Bit(raw[0] & 1 == 1);
                // authenticity: P0 must hold K^{v⊕r}
                let r_off = offset(ctx);
                let expect_key =
                    crate::gc::garble::active_label(vr_label.key(), r_off, bit);
                let mut acc = HashAcc::new();
                acc.absorb(&expect_key);
                let want = acc.finalize();
                ctx.net.recv_digest_expect(P0, &want, "Π_G2B key authenticity")?;
                Ok(Some(vec![bit]))
            }
            _ => Ok(None),
        }
    })?;

    // [[v⊕r]]^B by (P3, P0), then local XOR with [[r]]^B
    let vr_sh = vsh(ctx, (P3, P0), vr_for_share.map(|v| v[0]))?;
    Ok(vr_sh + rb[0])
}

/// `Π_G2A`: `[[v]]^G (ℓ bits) → [[v]]^A`. Online: 1 round, 3ℓ bits.
pub fn g2a(ctx: &mut Ctx, v_bits: &[GShare]) -> Result<MShare<Z64>, Abort> {
    assert_eq!(v_bits.len(), 64);
    let me = ctx.id();
    // offline: r ∈ Z_2^64 by P1,P2 → [[r]]^G and [[r]]^A
    let r_clear: Option<Z64> = (me == P1 || me == P2).then(|| {
        let peer = if me == P1 { P2 } else { P1 };
        ctx.keys.sample_pair::<Z64>(peer)
    });
    let r_bits: Option<Vec<Bit>> = r_clear.map(|r| u64_bits(r.0, 64));
    let (rg, ra, sub_out) = {
        let rg = ctx.offline(|ctx| g_vsh(ctx, (P1, P2), r_bits.as_deref(), 64))?;
        let ra = ctx.offline(|ctx| vsh(ctx, (P1, P2), r_clear))?;
        // garbled subtractor Sub(v, r): garble offline, evaluate online
        let circuit = subtractor(64);
        let mut inputs = v_bits.to_vec();
        inputs.extend(rg);
        let out = g_eval(ctx, &circuit, &inputs)?;
        (Vec::<GShare>::new(), ra, out)
    };
    let _ = rg;

    // P0 decodes v−r and forwards it (+ key hash) to P3
    let opened = g_reconstruct(ctx, &sub_out, P0)?;
    let vr: Option<Z64> = ctx.online(|ctx| -> Result<Option<Z64>, Abort> {
        match me {
            P0 => {
                let bits = opened.as_ref().unwrap();
                let val = Z64(bits_u64(bits));
                ctx.send_ring1(P3, val);
                let mut acc = HashAcc::new();
                for s in &sub_out {
                    acc.absorb(&s.key());
                }
                let d = acc.finalize();
                ctx.net.send_digest(P3, &d);
                Ok(Some(val))
            }
            P3 => {
                let val: Z64 = ctx.recv_ring1(P0)?;
                let r_off = offset(ctx);
                let bits = u64_bits(val.0, 64);
                let mut acc = HashAcc::new();
                for (s, b) in sub_out.iter().zip(bits) {
                    let k = crate::gc::garble::active_label(s.key(), r_off, b);
                    acc.absorb(&k);
                }
                let want = acc.finalize();
                ctx.net.recv_digest_expect(P0, &want, "Π_G2A key authenticity")?;
                Ok(Some(val))
            }
            _ => Ok(None),
        }
    })?;

    // [[v−r]]^A by (P3, P0) + [[r]]^A
    let vr_sh = vsh(ctx, (P3, P0), vr)?;
    Ok(vr_sh + ra)
}

/// `Π_B2G` for one bit: `[[v]]^B → [[v]]^G` — two verifiable garbled
/// sharings + free XOR. 1 round, κ bits online (Lemma C.6).
pub fn b2g(ctx: &mut Ctx, v: &MShare<Bit>) -> Result<GShare, Abort> {
    let me = ctx.id();
    // offline: [[y]]^G, y = λ_{v,2} ⊕ λ_{v,3} (owners P1, P0)
    let y_clear: Option<Vec<Bit>> = (me == P1 || me == P0).then(|| {
        vec![v.lam(me, 2).unwrap() + v.lam(me, 3).unwrap()]
    });
    let y_g = ctx.offline(|ctx| g_vsh(ctx, (P1, P0), y_clear.as_deref(), 1))?;
    // online: [[x]]^G, x = m_v ⊕ λ_{v,1} (owners P2, P3)
    let x_clear: Option<Vec<Bit>> =
        (me == P2 || me == P3).then(|| vec![v.m() + v.lam(me, 1).unwrap()]);
    let x_g = g_vsh(ctx, (P2, P3), x_clear.as_deref(), 1)?;
    Ok(crate::gc::g_xor(&x_g[0], &y_g[0]))
}

/// `Π_A2G`: `[[v]]^A → [[v]]^G` (64 bits) via a garbled subtractor on
/// `x = m_v − λ_{v,1}` (P2,P3) and `y = λ_{v,2} + λ_{v,3}` (P1,P0).
/// Online: 1 round, ℓκ bits (Lemma C.7).
pub fn a2g(ctx: &mut Ctx, v: &MShare<Z64>) -> Result<Vec<GShare>, Abort> {
    let me = ctx.id();
    let y_clear: Option<Vec<Bit>> = (me == P1 || me == P0).then(|| {
        let y = v.lam(me, 2).unwrap() + v.lam(me, 3).unwrap();
        u64_bits(y.0, 64)
    });
    let y_g = ctx.offline(|ctx| g_vsh(ctx, (P1, P0), y_clear.as_deref(), 64))?;
    let x_clear: Option<Vec<Bit>> = (me == P2 || me == P3).then(|| {
        let x = v.m() - v.lam(me, 1).unwrap();
        u64_bits(x.0, 64)
    });
    let x_g = g_vsh(ctx, (P2, P3), x_clear.as_deref(), 64)?;
    let circuit = subtractor(64);
    let mut inputs = x_g;
    inputs.extend(y_g);
    g_eval(ctx, &circuit, &inputs)
}

/// Garbled ℓ-bit division helper used by the MPC-friendly softmax (§VI-A.c:
/// "we switch from arithmetic to garbled world and then use a division
/// garbled circuit"). Non-restoring division is expensive; the NN layer
/// instead uses the public-denominator path (see `ml::softmax`), and this
/// adder is exposed for the mixed-world example.
pub fn garbled_add(ctx: &mut Ctx, x: &[GShare], y: &[GShare]) -> Result<Vec<GShare>, Abort> {
    assert_eq!(x.len(), y.len());
    let circuit = adder(x.len());
    let mut inputs = x.to_vec();
    inputs.extend_from_slice(y);
    g_eval(ctx, &circuit, &inputs)
}

/// A tiny garbled MUX (b ? x : y) used in tests of the garbled world.
pub fn garbled_mux_circuit(bits: usize) -> crate::gc::circuit::Circuit {
    let mut b = Builder::new(1 + 2 * bits);
    let sel = 0u32;
    let mut outs = Vec::with_capacity(bits);
    for i in 0..bits {
        let x = (1 + i) as u32;
        let y = (1 + bits + i) as u32;
        // out = y ⊕ b·(x⊕y)
        let d = b.xor(x, y);
        let t = b.and(sel, d);
        outs.push(b.xor(y, t));
    }
    b.finish(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::g_share;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, share};
    use crate::sharing::open;

    #[test]
    fn g2b_roundtrip() {
        for bit in [false, true] {
            let run = run_4pc(NetProfile::zero(), 140, move |ctx| {
                let g = g_share(ctx, P3, (ctx.id() == P3).then_some(&[Bit(bit)][..]), 1)?;
                let b = g2b(ctx, &g[0])?;
                ctx.flush_verify()?;
                Ok(b)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(bit), "g2b({bit})");
        }
    }

    #[test]
    fn g2a_roundtrip() {
        for v in [0u64, 1, 0xDEADBEEF, (-999i64) as u64] {
            let run = run_4pc(NetProfile::zero(), 141, move |ctx| {
                let bits = u64_bits(v, 64);
                let g = g_share(ctx, P1, (ctx.id() == P1).then_some(&bits[..]), 64)?;
                let a = g2a(ctx, &g)?;
                ctx.flush_verify()?;
                Ok(a)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Z64(v), "g2a({v})");
        }
    }

    #[test]
    fn b2g_roundtrip() {
        for bit in [false, true] {
            let run = run_4pc(NetProfile::zero(), 142, move |ctx| {
                let b = share(ctx, P2, (ctx.id() == P2).then_some(Bit(bit)))?;
                let g = b2g(ctx, &b)?;
                let out = g_reconstruct(ctx, &[g], P0)?;
                ctx.flush_verify()?;
                Ok(out)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(outs[0], Some(vec![Bit(bit)]), "b2g({bit})");
        }
    }

    #[test]
    fn a2g_roundtrip() {
        for v in [5u64, (-42i64) as u64, 1u64 << 62] {
            let run = run_4pc(NetProfile::zero(), 143, move |ctx| {
                let a = share(ctx, P1, (ctx.id() == P1).then_some(Z64(v)))?;
                let g = a2g(ctx, &a)?;
                let out = g_reconstruct(ctx, &g, P0)?;
                ctx.flush_verify()?;
                Ok(out)
            });
            let (outs, _) = run.expect_ok();
            let bits = outs[0].clone().unwrap();
            assert_eq!(bits_u64(&bits), v, "a2g({v})");
        }
    }

    #[test]
    fn a2g_then_g2a_identity() {
        let run = run_4pc(NetProfile::zero(), 144, |ctx| {
            let a = share(ctx, P2, (ctx.id() == P2).then_some(Z64(123_456_789_012)))?;
            let g = a2g(ctx, &a)?;
            let back = g2a(ctx, &g)?;
            ctx.flush_verify()?;
            Ok(back)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open(&outs), Z64(123_456_789_012));
    }

    #[test]
    fn garbled_mux_works() {
        let c = garbled_mux_circuit(8);
        use crate::gc::circuit::bits_u64 as b2u;
        for sel in [false, true] {
            let mut input = vec![Bit(sel)];
            input.extend(u64_bits(0xAA, 8));
            input.extend(u64_bits(0x55, 8));
            let out = c.eval(&input);
            assert_eq!(b2u(&out) as u8, if sel { 0xAA } else { 0x55 });
        }
    }

    #[test]
    fn g2b_online_cost_3_bits() {
        let run = run_4pc(NetProfile::zero(), 145, |ctx| {
            let g = g_share(ctx, P1, (ctx.id() == P1).then_some(&[Bit(true)][..]), 1)?;
            let b = g2b(ctx, &g[0])?;
            ctx.flush_verify()?;
            Ok(b)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Bit(true));
        // online: g_share key (κ=128) + colour bits (2) + v⊕r to P3 (1)
        // + vsh (1 bit) = κ + 4 — the G2B-specific part is 3 bits + the
        // colour-bit opening (Table I counts 3)
        assert!(report.value_bits[1] <= 128 + 8, "bits {}", report.value_bits[1]);
    }
}
