//! Sharing conversions (paper §IV-C, §V-B): the glue of the mixed-world
//! framework. Each conversion reproduces the paper's figure and its cost
//! lemma; the module tests assert the measured online bits/rounds against
//! Tables I/IX/X.

pub mod a2b;
pub mod bit2a;
pub mod bitext;
pub mod boolean;
pub mod garbled;

pub use a2b::a2b;
pub use bit2a::{b2a, bit2a, bit2a_many, bitinj, bitinj_many, BitInjCorr};
pub use bitext::{bitext, bitext_many, bitext_many_keyed, BitExtMask};
pub use boolean::eval_bool_circuit;
pub use garbled::{a2g, b2g, g2a, g2b};
