//! `Π_A2B` (Fig. 14): arithmetic → boolean sharing via a boolean-world
//! parallel-prefix subtractor over `v = x − y` with
//! `x = m_v − λ_{v,1}` (known to P2, P3) and `y = λ_{v,2} + λ_{v,3}`
//! (known to P0, P1).
//!
//! Online: `1 + log ℓ` rounds, `3ℓ log ℓ + ℓ` bits (Lemma C.8) — the `ℓ`
//! is the online `Π_vSh^B` of `x`, the rest the PPA AND gates.

use crate::gc::circuit::{ppa_subtractor, u64_bits};
use crate::net::{Abort, P0, P1, P2, P3};
use crate::proto::sharing::vsh_many;
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::MShare;

use super::boolean::eval_bool_circuit;

/// `Π_A2B`: `[[v]]^A → [[v]]^B` (64 boolean shares, little-endian).
pub fn a2b(ctx: &mut Ctx, v: &MShare<Z64>) -> Result<Vec<MShare<Bit>>, Abort> {
    let me = ctx.id();

    // offline: [[y]]^B by (P1, P0), y = λ_{v,2} + λ_{v,3}
    let y_clear: Option<Vec<Bit>> = (me == P1 || me == P0).then(|| {
        let l2 = v.lam(me, 2).expect("λ2");
        let l3 = v.lam(me, 3).expect("λ3");
        u64_bits((l2 + l3).0, 64)
    });
    let y_sh = ctx.offline(|ctx| vsh_many::<Bit>(ctx, (P1, P0), y_clear.as_deref(), 64))?;

    // online: [[x]]^B by (P2, P3), x = m_v − λ_{v,1}
    let x_clear: Option<Vec<Bit>> = (me == P2 || me == P3).then(|| {
        let l1 = v.lam(me, 1).expect("λ1");
        u64_bits((v.m() - l1).0, 64)
    });
    let x_sh = vsh_many::<Bit>(ctx, (P2, P3), x_clear.as_deref(), 64)?;

    // boolean subtractor (PPA): v = x − y
    let circuit = ppa_subtractor(64);
    let mut inputs = x_sh;
    inputs.extend(y_sh);
    eval_bool_circuit(ctx, &circuit, &inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::circuit::bits_u64;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, share};
    use crate::sharing::open;

    fn open_bits(outs: &[Vec<MShare<Bit>>; 4]) -> u64 {
        let bits: Vec<Bit> = (0..64)
            .map(|i| open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]))
            .collect();
        bits_u64(&bits)
    }

    #[test]
    fn a2b_roundtrip() {
        for v in [0u64, 1, 0xDEAD_BEEF, u64::MAX, 1u64 << 63, (-12345i64) as u64] {
            let run = run_4pc(NetProfile::zero(), 130, move |ctx| {
                let x = share(ctx, P3, (ctx.id() == P3).then_some(Z64(v)))?;
                let bits = a2b(ctx, &x)?;
                ctx.flush_verify()?;
                Ok(bits)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open_bits(&outs), v, "a2b({v})");
        }
    }

    #[test]
    fn a2b_log_rounds() {
        let run = run_4pc(NetProfile::zero(), 131, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(999)))?;
            let bits = a2b(ctx, &x)?;
            ctx.flush_verify()?;
            Ok(bits)
        });
        let (_, report) = run.expect_ok();
        // 1 input + 1 (vsh^B of x) + log ℓ PPA levels (Sklansky depth ≤ 7)
        assert!(report.rounds[1] <= 2 + 7, "rounds {}", report.rounds[1]);
        // offline: the y-side vsh costs 2ℓ bits (P0 is an owner)
        assert!(report.value_bits[0] >= 2 * 64);
    }

    #[test]
    fn a2b_then_b2a_identity() {
        let run = run_4pc(NetProfile::zero(), 132, |ctx| {
            let x = share(ctx, P2, (ctx.id() == P2).then_some(Z64(0xABCD_EF01_2345)))?;
            let bits = a2b(ctx, &x)?;
            let back = super::super::bit2a::b2a(ctx, &bits)?;
            ctx.flush_verify()?;
            Ok(back)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open(&outs), Z64(0xABCD_EF01_2345));
    }
}
