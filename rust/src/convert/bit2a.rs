//! Arithmetic-side conversions: `Π_Bit2A` (Fig. 15), `Π_B2A` (Fig. 16),
//! `Π_BitInj` (Fig. 17).
//!
//! All three share the same offline skeleton: P0 — who knows every boolean
//! mask bit — `Π_aSh`-shares its arithmetic lift, and the evaluators verify
//! the sharing with one masked linear identity. Online costs are the
//! constant-round 3ℓ of Tables I/IX.

use crate::net::{Abort, P0, P1, P2, P3};
use crate::proto::mult::lam_shares;
use crate::proto::sharing::ash_many;
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::{MShare, RShare};

/// Offline: P0 lifts the boolean masks `λ_b` of `bs` into `Z_{2^64}` and
/// ⟨·⟩-shares them; evaluators run the Fig. 15 check. Returns ⟨u⟩ per bit.
fn share_lifted_lambda(ctx: &mut Ctx, bs: &[MShare<Bit>]) -> Result<Vec<RShare<Z64>>, Abort> {
    let me = ctx.id();
    let n = bs.len();
    ctx.offline(|ctx| {
        // P0 computes u = λ_b (over the ring) for every bit
        let us: Option<Vec<Z64>> = (me == P0).then(|| {
            bs.iter()
                .map(|b| match b {
                    MShare::Helper { lam } => (lam[0] + lam[1] + lam[2]).to_z64(),
                    _ => unreachable!(),
                })
                .collect()
        });
        let u_shares = ash_many(ctx, us.as_deref(), n)?;

        // Fig. 15 verification: (λ_b ⊕ r_b)' == u + r_b' − 2·u·r_b',
        // blinded by r. Batched into one message + one digest.
        match me {
            P1 => {
                // packed payload: the n 64-bit y1 values followed by the n
                // x1 bits at 8/byte — (8n + ⌈n/8⌉) bytes, still metered as
                // the lemma-accurate 65n analytic bits (see the metering
                // contract at `Ctx::send_ring`)
                use crate::ring::Ring;
                let mut y1s: Vec<Z64> = Vec::with_capacity(n);
                let mut x1s: Vec<Bit> = Vec::with_capacity(n);
                for (i, b) in bs.iter().enumerate() {
                    let r: Z64 = ctx.keys.sample_pair(P2);
                    let rb = Bit(ctx.keys.sample_pair::<Z64>(P2).0 & 1 == 1);
                    let rbp = rb.to_z64();
                    let lam3 = b.lam(me, 3).expect("P1 holds λ_b,3");
                    let (u2, u3) = match u_shares[i] {
                        RShare::Eval { next, prev } => (next, prev),
                        _ => unreachable!(),
                    };
                    y1s.push((u2 + u3) * (Z64(1) - Z64(2) * rbp) + rbp + r);
                    x1s.push(lam3 + rb);
                }
                let mut payload = Vec::with_capacity(8 * n + n.div_ceil(8));
                Z64::to_wire_bulk(&y1s, &mut payload);
                Bit::to_wire_bulk(&x1s, &mut payload);
                ctx.net.send_with_bits(
                    P3,
                    &payload,
                    crate::net::MsgClass::Value,
                    (n * 65) as u64,
                );
            }
            P2 => {
                let mut acc = crate::crypto::HashAcc::new();
                for u in u_shares.iter().take(n) {
                    let r: Z64 = ctx.keys.sample_pair(P1);
                    let rb = Bit(ctx.keys.sample_pair::<Z64>(P1).0 & 1 == 1);
                    let rbp = rb.to_z64();
                    let u1 = match *u {
                        RShare::Eval { prev, .. } => prev, // P2 = (u3, u1)
                        _ => unreachable!(),
                    };
                    let y2 = u1 * (Z64(1) - Z64(2) * rbp) - r;
                    acc.absorb_ring(&y2);
                }
                let d = acc.finalize();
                ctx.net.send_digest(P3, &d);
            }
            P3 => {
                use crate::ring::Ring;
                let payload = ctx.net.recv(P1)?;
                let (y1s, used_y) = match Z64::from_wire_bulk(&payload, n) {
                    Some(v) => v,
                    None => return Err(ctx.net.abort("Π_Bit2A: short y1 payload".into())),
                };
                let x1s = match Bit::from_wire_bulk(&payload[used_y..], n) {
                    Some((bits, used_x)) if used_y + used_x == payload.len() => bits,
                    _ => return Err(ctx.net.abort("Π_Bit2A: malformed x1 payload".into())),
                };
                let mut acc = crate::crypto::HashAcc::new();
                for (i, b) in bs.iter().enumerate() {
                    let lam1 = b.lam(me, 1).expect("P3 holds λ_b,1");
                    let lam2 = b.lam(me, 2).expect("P3 holds λ_b,2");
                    let x = x1s[i] + lam1 + lam2; // λ_b ⊕ r_b
                    let xp = x.to_z64();
                    acc.absorb_ring(&(xp - y1s[i]));
                }
                let want = acc.finalize();
                ctx.net.recv_digest_expect(P2, &want, "Π_Bit2A λ_b lift check")?;
            }
            _ => {}
        }
        Ok(u_shares)
    })
}

/// Multiplication `[[u]]·[[v]]` where `λ_v = 0` (public-m `v`): no γ needed
/// (`γ_uv = λ_u·λ_v = 0`), so the offline phase is just a fresh λ_z — the
/// online exchange is the standard 3ℓ (Fig. 15's "γ_uv-sharing is not
/// needed").
fn mult_gamma_zero(
    ctx: &mut Ctx,
    us: &[MShare<Z64>],
    vs: &[Z64],
) -> Result<Vec<MShare<Z64>>, Abort> {
    let me = ctx.id();
    let n = us.len();
    // fresh λ_z per product — pool-aware ("bit2a material": the γ-free
    // multiplication randomness)
    let lam_zs: Vec<MShare<Z64>> = lam_shares(ctx, n);
    ctx.online(|ctx| {
        if me == P0 {
            return Ok(lam_zs);
        }
        let (jn, jp) = (me.next_evaluator().0, me.prev_evaluator().0);
        let mut mp_next = Vec::with_capacity(n);
        let mut mp_prev = Vec::with_capacity(n);
        for i in 0..n {
            // m_u = 0 ⇒ m'_j = −λ_u,j·m_v + λ_z,j  (λ_v = 0, γ = 0)
            let mv = vs[i];
            mp_next.push(-(us[i].lam(me, jn).unwrap() * mv) + lam_zs[i].lam(me, jn).unwrap());
            mp_prev.push(-(us[i].lam(me, jp).unwrap() * mv) + lam_zs[i].lam(me, jp).unwrap());
        }
        ctx.send_ring(me.prev_evaluator(), &mp_prev);
        ctx.vouch_ring(me.next_evaluator(), &mp_next);
        let missing: Vec<Z64> = ctx.recv_ring(me.next_evaluator(), n)?;
        ctx.expect_ring(me.prev_evaluator(), &missing);
        Ok((0..n)
            .map(|i| {
                let m_u = us[i].m(); // = 0 by construction, kept for clarity
                let m_z = mp_next[i] + mp_prev[i] + missing[i] + m_u * vs[i];
                match lam_zs[i] {
                    MShare::Eval { lam_next, lam_prev, .. } => {
                        MShare::Eval { m: m_z, lam_next, lam_prev }
                    }
                    _ => unreachable!(),
                }
            })
            .collect())
    })
}

/// `Π_Bit2A` (Fig. 15): `[[b]]^B → [[b]]^A`. Online: 1 round, 3ℓ bits.
pub fn bit2a(ctx: &mut Ctx, b: &MShare<Bit>) -> Result<MShare<Z64>, Abort> {
    bit2a_many(ctx, std::slice::from_ref(b)).map(|mut v| v.pop().unwrap())
}

/// Batched [`bit2a`].
pub fn bit2a_many(ctx: &mut Ctx, bs: &[MShare<Bit>]) -> Result<Vec<MShare<Z64>>, Abort> {
    let me = ctx.id();
    let n = bs.len();
    let u_shares = share_lifted_lambda(ctx, bs)?;
    // [[u]] with m_u = 0, λ_u = −u
    let us: Vec<MShare<Z64>> = u_shares.iter().map(|u| u.into_mshare()).collect();
    // v = m_b over the ring, public among evaluators
    let vs: Vec<Z64> = if me.is_evaluator() {
        bs.iter().map(|b| b.m().to_z64()).collect()
    } else {
        vec![Z64(0); n]
    };
    let uvs = mult_gamma_zero(ctx, &us, &vs)?;
    // [[b]] = [[v]] + [[u]] − 2[[uv]]
    Ok((0..n)
        .map(|i| {
            let v_pub = MShare::of_public(me, vs[i]);
            v_pub + us[i] - uvs[i].scale(Z64(2))
        })
        .collect())
}

/// `Π_B2A` (Fig. 16): `[[v]]^B (ℓ bits) → [[v]]^A` in **one** online round
/// and 3ℓ bits (vs ABY3's `1 + log ℓ` rounds / `9ℓ log ℓ` bits).
pub fn b2a(ctx: &mut Ctx, bits: &[MShare<Bit>]) -> Result<MShare<Z64>, Abort> {
    let me = ctx.id();
    let l = bits.len();
    assert!(l <= 64);
    // offline: lift every mask bit (ℓ × Bit2A offline)
    let p_shares = share_lifted_lambda(ctx, bits)?;

    ctx.online(|ctx| {
        // evaluator locals (Fig. 16): q_i = m_{v_i} over the ring
        let (x, y, z) = if me.is_evaluator() {
            let mut x = Z64(0);
            let mut y = Z64(0);
            let mut z = Z64(0);
            for (i, b) in bits.iter().enumerate() {
                let q = b.m().to_z64();
                let w = Z64::wrapping_pow2(i as u32);
                match me {
                    P1 => {
                        // x needs q_i + p_{i,2} − 2 q_i p_{i,2}; P1 holds p2
                        let p2 = p_shares[i].component(me, 2).unwrap();
                        x += w * (q + p2 - Z64(2) * q * p2);
                        // y needs p_{i,3} − 2 q_i p_{i,3}; P1 holds p3
                        let p3 = p_shares[i].component(me, 3).unwrap();
                        y += w * (p3 - Z64(2) * q * p3);
                    }
                    P2 => {
                        let p3 = p_shares[i].component(me, 3).unwrap();
                        y += w * (p3 - Z64(2) * q * p3);
                        let p1 = p_shares[i].component(me, 1).unwrap();
                        z += w * (p1 - Z64(2) * q * p1);
                    }
                    P3 => {
                        let p2 = p_shares[i].component(me, 2).unwrap();
                        x += w * (q + p2 - Z64(2) * q * p2);
                        let p1 = p_shares[i].component(me, 1).unwrap();
                        z += w * (p1 - Z64(2) * q * p1);
                    }
                    _ => unreachable!(),
                }
            }
            (Some(x), Some(y), Some(z))
        } else {
            (None, None, None)
        };

        // [[x]], [[y]], [[z]] by parallel Π_vSh (one round, 3ℓ bits)
        let xv = x.map(|v| vec![v]);
        let yv = y.map(|v| vec![v]);
        let zv = z.map(|v| vec![v]);
        let [sx, sy, sz] = crate::proto::sharing::vsh_cycle(
            ctx,
            [xv.as_deref(), yv.as_deref(), zv.as_deref()],
            1,
        )?;
        Ok(sx[0] + sy[0] + sz[0])
    })
}

/// `Π_BitInj` (Fig. 17): `[[b]]^B, [[v]]^A → [[b·v]]^A`. Online: 1 round,
/// 3ℓ bits (vs ABY3's 3 rounds / 27ℓ).
pub fn bitinj(ctx: &mut Ctx, b: &MShare<Bit>, v: &MShare<Z64>) -> Result<MShare<Z64>, Abort> {
    bitinj_many(ctx, std::slice::from_ref(b), std::slice::from_ref(v))
        .map(|mut o| o.pop().unwrap())
}

/// Pre-exchanged, pre-**checked** `Π_BitInj` offline material for a batch:
/// `⟨λ_b'⟩` (the Bit2A lift of the injected bits' masks) and `⟨λ_b·λ_v⟩`.
/// Depends only on the λ components of the bit and value wires, so a
/// circuit-keyed pool can generate it at fill time against pooled masks
/// ([`crate::pool::relu`]) and inject it into [`bitinj_online`] — the
/// verification messages of Figs. 15/17 then run at fill, not in the wave.
#[derive(Clone)]
pub struct BitInjCorr {
    pub(crate) y1: Vec<RShare<Z64>>,
    pub(crate) y2: Vec<RShare<Z64>>,
}

/// Batched [`bitinj`].
pub fn bitinj_many(
    ctx: &mut Ctx,
    bs: &[MShare<Bit>],
    vs: &[MShare<Z64>],
) -> Result<Vec<MShare<Z64>>, Abort> {
    let corr = bitinj_offline(ctx, bs, vs)?;
    bitinj_online(ctx, bs, vs, &corr)
}

/// The offline phase of `Π_BitInj` (Fig. 17): produce and check `⟨λ_b'⟩`
/// and `⟨λ_b·λ_v⟩`. Reads only the λ components of `bs`/`vs` — `m` may
/// still be zero skeletons, which is how the pool pre-generates this
/// material per circuit position.
pub(crate) fn bitinj_offline(
    ctx: &mut Ctx,
    bs: &[MShare<Bit>],
    vs: &[MShare<Z64>],
) -> Result<BitInjCorr, Abort> {
    assert_eq!(bs.len(), vs.len());
    let me = ctx.id();
    let n = bs.len();

    // ⟨y1⟩ = ⟨λ_b'⟩ with the Bit2A check
    let y1 = share_lifted_lambda(ctx, bs)?;
    // ⟨y2⟩ = ⟨λ_b·λ_v⟩ with the γ-style check
    let y2 = ctx.offline(|ctx| -> Result<Vec<RShare<Z64>>, Abort> {
        let vals: Option<Vec<Z64>> = (me == P0).then(|| {
            bs.iter()
                .zip(vs)
                .map(|(b, v)| match (b, v) {
                    (MShare::Helper { lam: lb }, MShare::Helper { lam: lv }) => {
                        (lb[0] + lb[1] + lb[2]).to_z64() * (lv[0] + lv[1] + lv[2])
                    }
                    _ => unreachable!(),
                })
                .collect()
        });
        let y2 = ash_many(ctx, vals.as_deref(), n)?;

        // check: Σ_i (u_i − y2_i) == 0 with u the γ-partition of λ_b'·λ_v
        let mut z_mine = Vec::with_capacity(n);
        if me.is_evaluator() {
            let j = me.next_evaluator().0;
            let jn = j;
            let jp = 1 + (jn % 3);
            for i in 0..n {
                let zsh = ctx.zero_share::<Z64>();
                let mask = match me {
                    P1 => zsh.a.unwrap(),
                    P2 => zsh.b.unwrap(),
                    P3 => zsh.gamma.unwrap(),
                    _ => unreachable!(),
                };
                let ly1_j = y1[i].component(me, jn).unwrap();
                let ly1_j1 = y1[i].component(me, jp).unwrap();
                let lv_j = vs[i].lam(me, jn).unwrap();
                let lv_j1 = vs[i].lam(me, jp).unwrap();
                let u = ly1_j * lv_j + ly1_j * lv_j1 + ly1_j1 * lv_j + mask;
                let y2_j = y2[i].component(me, jn).unwrap();
                z_mine.push(u - y2_j);
            }
        } else {
            for _ in 0..n {
                let _ = ctx.zero_share::<Z64>();
            }
        }
        match me {
            P1 => ctx.send_ring(P3, &z_mine),
            P2 => {
                let mut acc = crate::crypto::HashAcc::new();
                for z in &z_mine {
                    acc.absorb_ring(&(-*z));
                }
                let d = acc.finalize();
                ctx.net.send_digest(P3, &d);
            }
            P3 => {
                let z2: Vec<Z64> = ctx.recv_ring(P1, n)?;
                let mut acc = crate::crypto::HashAcc::new();
                for i in 0..n {
                    acc.absorb_ring(&(z_mine[i] + z2[i]));
                }
                let want = acc.finalize();
                ctx.net.recv_digest_expect(P2, &want, "Π_BitInj λ_bλ_v check")?;
            }
            _ => {}
        }
        Ok(y2)
    })?;
    Ok(BitInjCorr { y1, y2 })
}

/// The online phase of `Π_BitInj` (Fig. 17), given the offline material —
/// one round, 3ℓ bits, whether the correlation was generated inline or
/// popped from a circuit-keyed pool.
pub(crate) fn bitinj_online(
    ctx: &mut Ctx,
    bs: &[MShare<Bit>],
    vs: &[MShare<Z64>],
    corr: &BitInjCorr,
) -> Result<Vec<MShare<Z64>>, Abort> {
    assert_eq!(bs.len(), vs.len());
    let me = ctx.id();
    let n = bs.len();
    let (y1, y2) = (&corr.y1, &corr.y2);
    ctx.online(|ctx| {
        let cs: Option<Vec<(Z64, Z64, Z64)>> = me.is_evaluator().then(|| {
            (0..n)
                .map(|i| {
                    let mb = bs[i].m().to_z64();
                    let mv = vs[i].m();
                    let x0 = mb * mv;
                    let x1 = mb;
                    let x2 = mv - Z64(2) * mv * mb;
                    let x3 = Z64(2) * mb - Z64(1);
                    let c = |j: u8, with_x0: bool| {
                        let lv = vs[i].lam(me, j);
                        let y1j = y1[i].component(me, j);
                        let y2j = y2[i].component(me, j);
                        match (lv, y1j, y2j) {
                            (Some(lv), Some(y1j), Some(y2j)) => {
                                let base = -(x1 * lv) + x2 * y1j + x3 * y2j;
                                if with_x0 {
                                    x0 + base
                                } else {
                                    base
                                }
                            }
                            _ => Z64(0),
                        }
                    };
                    // c2 includes x0 (computed by P1, P3)
                    (c(1, false), c(2, true), c(3, false))
                })
                .collect()
        });
        // parallel vsh: c2 by (P1,P3), c3 by (P2,P1), c1 by (P3,P2)
        let pick = |sel: fn(&(Z64, Z64, Z64)) -> Z64| -> Option<Vec<Z64>> {
            cs.as_ref().map(|v| v.iter().map(sel).collect())
        };
        let c2_vals = if me == P1 || me == P3 { pick(|t| t.1) } else { None };
        let c3_vals = if me == P2 || me == P1 { pick(|t| t.2) } else { None };
        let c1_vals = if me == P3 || me == P2 { pick(|t| t.0) } else { None };
        let [s2, s3, s1] = crate::proto::sharing::vsh_cycle(
            ctx,
            [c2_vals.as_deref(), c3_vals.as_deref(), c1_vals.as_deref()],
            n,
        )?;
        Ok((0..n).map(|i| s1[i] + s2[i] + s3[i]).collect())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, share};
    use crate::sharing::open;

    #[test]
    fn bit2a_both_values() {
        for bit in [false, true] {
            let run = run_4pc(NetProfile::zero(), 110, move |ctx| {
                let b = share(ctx, P1, (ctx.id() == P1).then_some(Bit(bit)))?;
                let a = bit2a(ctx, &b)?;
                ctx.flush_verify()?;
                Ok(a)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Z64(bit as u64), "bit {bit}");
        }
    }

    #[test]
    fn bit2a_online_cost_3l() {
        let run = run_4pc(NetProfile::zero(), 111, |ctx| {
            let b = share(ctx, P2, (ctx.id() == P2).then_some(Bit(true)))?;
            let pre = 2; // input share bits (2 receivers × 1 bit)
            let a = bit2a(ctx, &b)?;
            ctx.flush_verify()?;
            let _ = pre;
            Ok(a)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(1));
        // online = input (2 bits) + mult exchange 3ℓ (Table IX)
        assert_eq!(report.value_bits[1], 2 + 3 * 64);
        // offline = aSh (2ℓ) + check (ℓ + 1 + a 64-bit blind... measured)
        assert!(report.value_bits[0] >= 3 * 64);
    }

    #[test]
    fn b2a_roundtrip_values() {
        for v in [0u64, 1, 42, 0xFFFF_FFFF_FFFF_FFFF, 1u64 << 63] {
            let run = run_4pc(NetProfile::zero(), 112, move |ctx| {
                let bits = crate::gc::circuit::u64_bits(v, 64);
                let bs = crate::proto::sharing::share_many_n(
                    ctx,
                    P3,
                    (ctx.id() == P3).then_some(&bits[..]),
                    64,
                )?;
                let a = b2a(ctx, &bs)?;
                ctx.flush_verify()?;
                Ok(a)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Z64(v), "value {v}");
        }
    }

    #[test]
    fn b2a_single_online_round_3l() {
        let run = run_4pc(NetProfile::zero(), 113, |ctx| {
            let bits = crate::gc::circuit::u64_bits(0xDEADBEEF, 64);
            let bs = crate::proto::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1).then_some(&bits[..]),
                64,
            )?;
            let pre_bits = 2 * 64; // input sharing online bits
            let a = b2a(ctx, &bs)?;
            ctx.flush_verify()?;
            let _ = pre_bits;
            Ok(a)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(0xDEADBEEF));
        // B2A online: exactly 3ℓ bits (Table I) and 1 round beyond inputs
        assert_eq!(report.value_bits[1] - 2 * 64, 3 * 64);
        assert_eq!(report.rounds[1], 2); // 1 input + 1 B2A
    }

    #[test]
    fn bitinj_all_cases() {
        for bit in [false, true] {
            for val in [0i64, 5, -17, 123456] {
                let run = run_4pc(NetProfile::zero(), 114, move |ctx| {
                    let b = share(ctx, P1, (ctx.id() == P1).then_some(Bit(bit)))?;
                    let v = share(ctx, P2, (ctx.id() == P2).then_some(Z64::from(val)))?;
                    let bv = bitinj(ctx, &b, &v)?;
                    ctx.flush_verify()?;
                    Ok(bv)
                });
                let (outs, _) = run.expect_ok();
                let want = if bit { Z64::from(val) } else { Z64(0) };
                assert_eq!(open(&outs), want, "b={bit} v={val}");
            }
        }
    }

    #[test]
    fn bitinj_online_cost_3l() {
        let run = run_4pc(NetProfile::zero(), 115, |ctx| {
            let b = share(ctx, P1, (ctx.id() == P1).then_some(Bit(true)))?;
            let v = share(ctx, P2, (ctx.id() == P2).then_some(Z64(77)))?;
            let bv = bitinj(ctx, &b, &v)?;
            ctx.flush_verify()?;
            Ok(bv)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Z64(77));
        // inputs: 2 bits + 2·64; BitInj online: 3ℓ (Table IX)
        assert_eq!(report.value_bits[1] - 2 - 2 * 64, 3 * 64);
    }
}
