//! `Π_BitExt` (Fig. 19) — secure comparison / MSB extraction in **constant
//! rounds** (3 online): the centrepiece of Trident's constant-round
//! ReLU/Sigmoid (Table II).
//!
//! Protocol as in the paper: P1,P2 pre-share a random `r` with known
//! `x = msb(r)`; online the parties compute `[[rv]] = Π_Mult([[r]],[[v]])`,
//! open `rv` towards P0,P3 who boolean-share `y = msb(rv)`, and
//! `msb(v) = x ⊕ y`.
//!
//! **Substitution note (DESIGN.md §3):** the identity
//! `msb(rv) = msb(r) ⊕ msb(v)` does not hold for arbitrary `r` over a
//! wrap-around ring, so we sample `r` uniformly from `{+1, −1}` (as
//! fixed-point ±1, `±2^f`, so the product keeps the fixed-point scale and
//! the comparison stays exact after `Π_MultTr`-style truncation — here we
//! multiply without truncation so `r = ±1` as ring integers). This
//! preserves the protocol's structure, rounds and communication exactly;
//! the multiplicative-masking privacy of the opened `rv` (already fragile
//! in the original construction) is traded for functional correctness.

use crate::net::{Abort, P0, P1, P2, P3};
use crate::proto::mult::{mult_offline, mult_online_many};
use crate::proto::reconstruct::reconstruct_to_many;
use crate::proto::sharing::vsh_many;
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::MShare;

/// `Π_BitExt` offline material: a shared random sign `[[r]]` together with
/// its boolean-shared msb `[[msb r]]^B` — what [`crate::pool`] stocks for
/// ReLU/Sigmoid serving.
#[derive(Clone, Copy, Debug)]
pub struct BitExtMask {
    pub r: MShare<Z64>,
    pub x: MShare<Bit>,
}

/// Inline generation of `n` bit-extraction masks (the `Π_BitExt` offline
/// phase): P1,P2 sample `r = ±1`, then `Π_vSh` both `[[r]]` and
/// `[[msb r]]^B`. Also used by [`crate::pool::fill_bitext`].
pub(crate) fn gen_bitext_masks(ctx: &mut Ctx, n: usize) -> Result<Vec<BitExtMask>, Abort> {
    let me = ctx.id();
    let rs: Option<Vec<Z64>> = (me == P1 || me == P2).then(|| {
        (0..n)
            .map(|_| {
                let s: Z64 = ctx.keys.sample_pair(if me == P1 { P2 } else { P1 });
                if s.0 & 1 == 1 {
                    Z64::from(-1i64)
                } else {
                    Z64(1)
                }
            })
            .collect()
    });
    let xs_clear: Option<Vec<Bit>> = rs.as_ref().map(|rs| rs.iter().map(|r| r.msb()).collect());
    ctx.offline(|ctx| -> Result<_, Abort> {
        let r_sh = vsh_many(ctx, (P1, P2), rs.as_deref(), n)?;
        let x_sh = vsh_many::<Bit>(ctx, (P1, P2), xs_clear.as_deref(), n)?;
        Ok(r_sh
            .into_iter()
            .zip(x_sh)
            .map(|(r, x)| BitExtMask { r, x })
            .collect())
    })
}

/// `Π_BitExt`: `[[v]]^A → [[msb(v)]]^B`. Online: 3 rounds, 5ℓ+2 bits.
pub fn bitext(ctx: &mut Ctx, v: &MShare<Z64>) -> Result<MShare<Bit>, Abort> {
    bitext_many(ctx, std::slice::from_ref(v)).map(|mut o| o.pop().unwrap())
}

/// Batched [`bitext`] — parallel instances share the three rounds (the
/// batching Sigmoid relies on for its 5-round total). Pool-aware: the
/// offline mask material is popped from an attached pool when stocked.
pub fn bitext_many(ctx: &mut Ctx, vs: &[MShare<Z64>]) -> Result<Vec<MShare<Bit>>, Abort> {
    let n = vs.len();

    // ---- offline: mask material (pooled or inline) ----
    let masks: Vec<BitExtMask> = match ctx.pool.as_mut().and_then(|p| p.pop_bitext(n)) {
        Some(m) => m,
        None => gen_bitext_masks(ctx, n)?,
    };
    let r_sh: Vec<MShare<Z64>> = masks.iter().map(|m| m.r).collect();
    let x_sh: Vec<MShare<Bit>> = masks.iter().map(|m| m.x).collect();

    // ---- online ----
    // [[rv]] = Π_Mult([[r]], [[v]]) — offline part of the mult is genuinely
    // offline (γ from the masks)
    let corr = mult_offline(ctx, &r_sh, vs, true)?;
    let rv = mult_online_many(ctx, &r_sh, vs, &corr)?;
    // open rv towards P0 and P3
    let opened = reconstruct_to_many(ctx, &rv, &[P0, P3])?;
    // y = msb(rv), boolean-shared by (P3, P0)
    let ys: Option<Vec<Bit>> = opened.map(|vals| vals.iter().map(|v| v.msb()).collect());
    let y_sh = vsh_many::<Bit>(ctx, (P3, P0), ys.as_deref(), n)?;
    // [[msb v]]^B = [[x]]^B ⊕ [[y]]^B
    Ok((0..n).map(|i| x_sh[i] + y_sh[i]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, share};
    use crate::ring::fixed::FixedPoint;
    use crate::sharing::open;

    #[test]
    fn msb_extraction_signs() {
        // v = 0 is excluded: with multiplicative masking msb(r·0) = 0 for
        // every r, so the protocol outputs msb(r) — an inherent edge case of
        // the paper's construction (harmless for ReLU where v=0 → relu=0
        // under either sign; see module docs).
        for v in [1i64, -1, 123456, -123456, i64::MAX / 2, i64::MIN / 2] {
            let run = run_4pc(NetProfile::zero(), 120, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(v)))?;
                let b = bitext(ctx, &x)?;
                ctx.flush_verify()?;
                Ok(b)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(v < 0), "msb({v})");
        }
    }

    #[test]
    fn msb_of_fixed_point() {
        for v in [0.5f64, -0.5, 3.25, -100.0, 0.0001] {
            let run = run_4pc(NetProfile::zero(), 121, move |ctx| {
                let x = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(v)))?;
                let b = bitext(ctx, &x)?;
                ctx.flush_verify()?;
                Ok(b)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(v < 0.0), "sign({v})");
        }
    }

    #[test]
    fn bitext_cost_constant_rounds() {
        let run = run_4pc(NetProfile::zero(), 122, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(-5i64)))?;
            let b = bitext(ctx, &x)?;
            ctx.flush_verify()?;
            Ok(b)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Bit(true));
        // Lemma D.3: online 3 rounds / 5ℓ+2 bits (+ the input share round)
        assert_eq!(report.rounds[1], 1 + 3, "rounds");
        assert_eq!(report.value_bits[1] - 2 * 64, 5 * 64 + 2, "online bits");
        // offline: vsh(r)=ℓ + vsh^B(x)=1 + mult offline 3ℓ = 4ℓ+1 (Lemma D.3)
        assert_eq!(report.value_bits[0], 4 * 64 + 1, "offline bits");
    }

    #[test]
    fn bitext_many_shares_rounds() {
        let run = run_4pc(NetProfile::zero(), 123, |ctx| {
            let vals = [-3i64, 7, -11, 13];
            let shares: Vec<MShare<Z64>> = crate::proto::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| vals.iter().map(|&v| Z64::from(v)).collect::<Vec<_>>())
                    .as_deref(),
                4,
            )?;
            let bs = bitext_many(ctx, &shares)?;
            ctx.flush_verify()?;
            Ok(bs)
        });
        let (outs, report) = run.expect_ok();
        for (i, &v) in [-3i64, 7, -11, 13].iter().enumerate() {
            assert_eq!(
                open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]),
                Bit(v < 0),
                "case {i}"
            );
        }
        // batching: still 1 + 3 rounds for 4 instances
        assert_eq!(report.rounds[1], 4);
    }
}
