//! `Π_BitExt` (Fig. 19) — secure comparison / MSB extraction in **constant
//! rounds** (3 online): the centrepiece of Trident's constant-round
//! ReLU/Sigmoid (Table II).
//!
//! Protocol as in the paper: P1,P2 pre-share a random `r` with known
//! `x = msb(r)`; online the parties compute `[[rv]] = Π_Mult([[r]],[[v]])`,
//! open `rv` towards P0,P3 who boolean-share `y = msb(rv)`, and
//! `msb(v) = x ⊕ y`.
//!
//! **Substitution note (DESIGN.md §3):** the identity
//! `msb(rv) = msb(r) ⊕ msb(v)` does not hold for arbitrary `r` over a
//! wrap-around ring, so we sample `r` uniformly from `{+1, −1}` (as
//! fixed-point ±1, `±2^f`, so the product keeps the fixed-point scale and
//! the comparison stays exact after `Π_MultTr`-style truncation — here we
//! multiply without truncation so `r = ±1` as ring integers). This
//! preserves the protocol's structure, rounds and communication exactly;
//! the multiplicative-masking privacy of the opened `rv` (already fragile
//! in the original construction) is traded for functional correctness.

use crate::convert::bit2a::BitInjCorr;
use crate::net::{Abort, P0, P1, P2, P3};
use crate::pool::{CircuitKey, OpKind, ReluCorr};
use crate::proto::mult::{mult_offline, mult_online_many, MultCorr};
use crate::proto::reconstruct::reconstruct_to_many;
use crate::proto::sharing::{sample_vsh_masks, vsh_deliver, vsh_many, VshMask};
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::MShare;

/// `Π_BitExt` offline material: a shared random sign `[[r]]` together with
/// its boolean-shared msb `[[msb r]]^B` — what [`crate::pool`] stocks for
/// ReLU/Sigmoid serving.
#[derive(Clone, Copy, Debug)]
pub struct BitExtMask {
    pub r: MShare<Z64>,
    pub x: MShare<Bit>,
}

/// Inline generation of `n` bit-extraction masks (the `Π_BitExt` offline
/// phase): P1,P2 sample `r = ±1`, then `Π_vSh` both `[[r]]` and
/// `[[msb r]]^B`. Also used by [`crate::pool::fill_bitext`].
pub(crate) fn gen_bitext_masks(ctx: &mut Ctx, n: usize) -> Result<Vec<BitExtMask>, Abort> {
    let me = ctx.id();
    let rs: Option<Vec<Z64>> = (me == P1 || me == P2).then(|| {
        (0..n)
            .map(|_| {
                let s: Z64 = ctx.keys.sample_pair(if me == P1 { P2 } else { P1 });
                if s.0 & 1 == 1 {
                    Z64::from(-1i64)
                } else {
                    Z64(1)
                }
            })
            .collect()
    });
    let xs_clear: Option<Vec<Bit>> = rs.as_ref().map(|rs| rs.iter().map(|r| r.msb()).collect());
    ctx.offline(|ctx| -> Result<_, Abort> {
        let r_sh = vsh_many(ctx, (P1, P2), rs.as_deref(), n)?;
        let x_sh = vsh_many::<Bit>(ctx, (P1, P2), xs_clear.as_deref(), n)?;
        Ok(r_sh
            .into_iter()
            .zip(x_sh)
            .map(|(r, x)| BitExtMask { r, x })
            .collect())
    })
}

/// `Π_BitExt`: `[[v]]^A → [[msb(v)]]^B`. Online: 3 rounds, 5ℓ+2 bits.
pub fn bitext(ctx: &mut Ctx, v: &MShare<Z64>) -> Result<MShare<Bit>, Abort> {
    bitext_many(ctx, std::slice::from_ref(v)).map(|mut o| o.pop().unwrap())
}

/// Batched [`bitext`] — parallel instances share the three rounds (the
/// batching Sigmoid relies on for its 5-round total). Pool-aware: the
/// offline mask material is popped from an attached pool when stocked
/// (the typed queue serves position-independent masks; the internal
/// `Π_Mult` γ still exchanges live — the **circuit-keyed** path
/// [`bitext_many_keyed`] pools that too).
pub fn bitext_many(ctx: &mut Ctx, vs: &[MShare<Z64>]) -> Result<Vec<MShare<Bit>>, Abort> {
    let n = vs.len();

    // ---- offline: mask material (pooled or inline) ----
    let masks: Vec<BitExtMask> = match ctx.pool.as_mut().and_then(|p| p.pop_bitext(n)) {
        Some(m) => m,
        None => gen_bitext_masks(ctx, n)?,
    };
    // SoA split once at this entry point — bitext_online takes the two
    // components as slices, so the circuit-keyed path can borrow its
    // bundle's pre-split vectors with no per-wave materialisation
    let r_sh: Vec<MShare<Z64>> = masks.iter().map(|m| m.r).collect();
    let x_sh: Vec<MShare<Bit>> = masks.iter().map(|m| m.x).collect();

    // [[rv]] = Π_Mult([[r]], [[v]]) — offline part of the mult is genuinely
    // offline (γ from the masks), but it γ-exchanges live inside the call
    let corr = mult_offline(ctx, &r_sh, vs, true)?;
    let y_masks = sample_vsh_masks::<Bit>(ctx, (P3, P0), n);
    bitext_online(ctx, vs, &r_sh, &x_sh, &corr, &y_masks)
}

/// Pool-aware **circuit-keyed** batched bit extraction — the nonlinear leg
/// of a keyed serving wave. Pops the whole [`ReluCorr`] bundle
/// pre-generated for `key` (bit-extraction masks, the pre-exchanged
/// `⟨γ_{r·v}⟩` of the internal `Π_Mult`, the pre-drawn `y` sharing mask
/// and the pre-checked `Π_BitInj` material): a hit runs **only** the
/// online phase — same 3 rounds, same `5ℓ+2` bits — and sends **zero
/// offline-phase messages**; the bundle's injection material is returned
/// for the follow-on `Π_BitInj` ([`crate::ml::relu_many_keyed`]). A miss
/// (exhausted or unattached pool, or an unregistered width) falls back to
/// the inline [`bitext_many`] and returns `None`; the pop decision is
/// lockstep at all four parties, so the fallback is deterministic.
/// Material filed under a different [`CircuitKey`] **fails closed**: the
/// popping party aborts rather than opening `r·v` under wrong-position
/// masks.
pub fn bitext_many_keyed(
    ctx: &mut Ctx,
    key: &CircuitKey,
    vs: &[MShare<Z64>],
) -> Result<(Vec<MShare<Bit>>, Option<BitInjCorr>), Abort> {
    let n = vs.len();
    match key.op {
        OpKind::Relu { n: width } => assert_eq!(width, n, "key width must match the batch"),
        _ => panic!("bitext_many_keyed requires an OpKind::Relu key"),
    }
    let popped = match ctx.pool.as_mut().map(|p| p.pop_relu(key)) {
        None => None,
        Some(Ok(item)) => item,
        Some(Err(why)) => return Err(ctx.net.abort(why)),
    };
    match popped {
        Some(bundle) => {
            // the bundle stores its mask material pre-split (SoA), so the
            // warm keyed path is allocation-free from here to the wire
            let ReluCorr { r_masks, x_masks, gamma, lam_z, y_masks, binj, .. } = bundle;
            let corr = MultCorr { gamma, lam_z };
            let bits = bitext_online(ctx, vs, &r_masks, &x_masks, &corr, &y_masks)?;
            Ok((bits, Some(binj)))
        }
        None => Ok((bitext_many(ctx, vs)?, None)),
    }
}

/// The online phase of `Π_BitExt`, shared by the inline and circuit-keyed
/// paths (which differ only in where the offline material comes from):
/// the `Π_Mult` online exchange for `[[rv]]`, the opening towards P0/P3,
/// and the `y = msb(rv)` delivery under the pre-drawn mask. Takes the
/// mask components as SoA slices so callers that already hold them split
/// (the keyed [`crate::pool::ReluCorr`] bundle) pay no per-wave collect.
fn bitext_online(
    ctx: &mut Ctx,
    vs: &[MShare<Z64>],
    r_sh: &[MShare<Z64>],
    x_sh: &[MShare<Bit>],
    corr: &MultCorr<Z64>,
    y_masks: &[VshMask<Bit>],
) -> Result<Vec<MShare<Bit>>, Abort> {
    let n = vs.len();
    let rv = mult_online_many(ctx, r_sh, vs, corr)?;
    // open rv towards P0 and P3
    let opened = reconstruct_to_many(ctx, &rv, &[P0, P3])?;
    // y = msb(rv), boolean-shared by (P3, P0)
    let ys: Option<Vec<Bit>> = opened.map(|vals| vals.iter().map(|v| v.msb()).collect());
    let y_sh = vsh_deliver::<Bit>(ctx, (P3, P0), ys.as_deref(), y_masks)?;
    // [[msb v]]^B = [[x]]^B ⊕ [[y]]^B
    Ok((0..n).map(|i| x_sh[i] + y_sh[i]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::proto::{run_4pc, share};
    use crate::ring::fixed::FixedPoint;
    use crate::sharing::open;

    #[test]
    fn msb_extraction_signs() {
        // v = 0 is excluded: with multiplicative masking msb(r·0) = 0 for
        // every r, so the protocol outputs msb(r) — an inherent edge case of
        // the paper's construction (harmless for ReLU where v=0 → relu=0
        // under either sign; see module docs).
        for v in [1i64, -1, 123456, -123456, i64::MAX / 2, i64::MIN / 2] {
            let run = run_4pc(NetProfile::zero(), 120, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(v)))?;
                let b = bitext(ctx, &x)?;
                ctx.flush_verify()?;
                Ok(b)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(v < 0), "msb({v})");
        }
    }

    #[test]
    fn msb_of_fixed_point() {
        for v in [0.5f64, -0.5, 3.25, -100.0, 0.0001] {
            let run = run_4pc(NetProfile::zero(), 121, move |ctx| {
                let x = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(v)))?;
                let b = bitext(ctx, &x)?;
                ctx.flush_verify()?;
                Ok(b)
            });
            let (outs, _) = run.expect_ok();
            assert_eq!(open(&outs), Bit(v < 0.0), "sign({v})");
        }
    }

    #[test]
    fn bitext_cost_constant_rounds() {
        let run = run_4pc(NetProfile::zero(), 122, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64::from(-5i64)))?;
            let b = bitext(ctx, &x)?;
            ctx.flush_verify()?;
            Ok(b)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open(&outs), Bit(true));
        // Lemma D.3: online 3 rounds / 5ℓ+2 bits (+ the input share round)
        assert_eq!(report.rounds[1], 1 + 3, "rounds");
        assert_eq!(report.value_bits[1] - 2 * 64, 5 * 64 + 2, "online bits");
        // offline: vsh(r)=ℓ + vsh^B(x)=1 + mult offline 3ℓ = 4ℓ+1 (Lemma D.3)
        assert_eq!(report.value_bits[0], 4 * 64 + 1, "offline bits");
    }

    #[test]
    fn bitext_keyed_matches_inline_and_is_offline_silent() {
        use crate::net::Phase;
        use crate::pool::Pool;
        let vals = [-9i64, 42];
        let run = run_4pc(NetProfile::zero(), 124, move |ctx| {
            let vs = crate::proto::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| vals.iter().map(|&v| Z64::from(v)).collect::<Vec<_>>())
                    .as_deref(),
                2,
            )?;
            let key = crate::pool::CircuitKey {
                model: 77,
                layer: 0,
                op: OpKind::Relu { n: 2 },
                rows: 2,
                inner: 1,
                cols: 1,
                dealer: P1,
            };
            // generate the bundle against the live wire's λ (what
            // fill_mat_relu does with the pooled pairs' λ = −rᵗ)
            ctx.attach_pool(Pool::new());
            let corr = crate::pool::relu::gen_relu_corr(ctx, key, &vs)?;
            ctx.pool_mut().unwrap().push_relu(corr);
            ctx.flush_verify()?; // settle the fill's deferred digests
            let w = crate::obs::Window::open(ctx.net);
            let (bits, binj) = bitext_many_keyed(ctx, &key, &vs)?;
            let off_sent = w.diff(ctx.net).msgs(Phase::Offline);
            ctx.flush_verify()?;
            Ok((bits, binj.is_some(), off_sent))
        });
        let (outs, _) = run.expect_ok();
        for (i, &v) in vals.iter().enumerate() {
            let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
            assert_eq!(b, Bit(v < 0), "keyed msb({v})");
        }
        for (p, o) in outs.iter().enumerate() {
            assert!(o.1, "P{p}: a stocked keyed pop must hit");
            assert_eq!(o.2, 0, "P{p} sent offline messages inside the keyed bitext");
        }
    }

    #[test]
    fn bitext_boolean_rounds_pack_on_wire() {
        // The packed-codec acceptance check: the boolean legs of a
        // bitext_many round shrink ~8× in payload bytes while the metered
        // analytic bits and the round count stay byte-for-byte unchanged.
        use crate::net::Phase;
        let n: usize = 64;
        let run = run_4pc(NetProfile::zero(), 125, move |ctx| {
            let vals: Option<Vec<Z64>> = (ctx.id() == P1)
                .then(|| (0..n as i64).map(|i| Z64::from(i - 32)).collect());
            let vs = crate::proto::sharing::share_many_n(ctx, P1, vals.as_deref(), n)?;
            ctx.flush_verify()?; // settle the input crosscheck digests
            let w = crate::obs::Window::open(ctx.net);
            let bits = bitext_many(ctx, &vs)?;
            let sent = w.diff(ctx.net).bytes(Phase::Online);
            ctx.flush_verify()?;
            Ok((bits, sent))
        });
        let (outs, report) = run.expect_ok();
        for i in 0..n {
            let b = open(&[outs[0].0[i], outs[1].0[i], outs[2].0[i], outs[3].0[i]]);
            assert_eq!(b, Bit((i as i64 - 32) < 0), "case {i}");
        }
        // P3's online sends inside the window: the Π_Mult exchange (8n B),
        // the two y-share deliveries — ⌈n/8⌉ B each, down from n B each
        // before the packed codec — plus batched 32-byte digests.
        let p3 = outs[3].1 as usize;
        assert!(p3 >= 8 * n + 2 * n.div_ceil(8), "P3 window too small: {p3}");
        assert!(
            p3 < 8 * n + 2 * n + 32,
            "P3 window {p3}: boolean y-deliveries must be packed 8 bits/byte"
        );
        // cluster totals: exact packed value payload; analytic bits and
        // rounds byte-for-byte unchanged (Lemma D.3 + the input round)
        assert_eq!(
            report.value_bytes[1] as usize,
            56 * n + 2 * n.div_ceil(8),
            "online value payload: 7 Z64 legs + 2 packed boolean legs + inputs"
        );
        assert_eq!(
            report.value_bits[1] as usize,
            2 * 64 * n + n * (5 * 64 + 2),
            "metered analytic bits unchanged"
        );
        assert_eq!(report.rounds[1], 1 + 3, "round count unchanged");
    }

    #[test]
    fn bitext_many_shares_rounds() {
        let run = run_4pc(NetProfile::zero(), 123, |ctx| {
            let vals = [-3i64, 7, -11, 13];
            let shares: Vec<MShare<Z64>> = crate::proto::sharing::share_many_n(
                ctx,
                P1,
                (ctx.id() == P1)
                    .then(|| vals.iter().map(|&v| Z64::from(v)).collect::<Vec<_>>())
                    .as_deref(),
                4,
            )?;
            let bs = bitext_many(ctx, &shares)?;
            ctx.flush_verify()?;
            Ok(bs)
        });
        let (outs, report) = run.expect_ok();
        for (i, &v) in [-3i64, 7, -11, 13].iter().enumerate() {
            assert_eq!(
                open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]),
                Bit(v < 0),
                "case {i}"
            );
        }
        // batching: still 1 + 3 rounds for 4 instances
        assert_eq!(report.rounds[1], 4);
    }
}
