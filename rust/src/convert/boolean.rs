//! Boolean-world circuit evaluation over `[[·]]^B` shares.
//!
//! XOR/NOT are local (linearity of the boolean sharing); AND gates are
//! `Π_Mult` instances over `Z_2`, batched **per AND-depth level** so the
//! online round count equals the circuit's multiplicative depth — this is
//! how `Π_A2B` achieves its `1 + log ℓ` online rounds with the PPA circuit
//! (Lemma C.8).

use crate::gc::circuit::{Circuit, Gate};
use crate::net::Abort;
use crate::proto::mult::mult_many;
use crate::proto::Ctx;
use crate::ring::Bit;
use crate::sharing::MShare;

/// Evaluate `circuit` on boolean shares, level-batched.
pub fn eval_bool_circuit(
    ctx: &mut Ctx,
    circuit: &Circuit,
    inputs: &[MShare<Bit>],
) -> Result<Vec<MShare<Bit>>, Abort> {
    assert_eq!(inputs.len(), circuit.n_inputs);
    let n_wires = circuit.n_wires();
    let mut wires: Vec<Option<MShare<Bit>>> = vec![None; n_wires];
    for (i, s) in inputs.iter().enumerate() {
        wires[i] = Some(*s);
    }

    // group gates into levels: a gate is ready when its inputs are resolved;
    // AND gates of the same level run in one mult_many batch.
    let mut remaining: Vec<(usize, Gate)> =
        circuit.gates.iter().cloned().enumerate().collect();
    while !remaining.is_empty() {
        let mut next_remaining = Vec::new();
        let mut and_batch: Vec<(usize, MShare<Bit>, MShare<Bit>)> = Vec::new();
        let mut progressed = false;
        for (g, gate) in remaining {
            let w = circuit.n_inputs + g;
            let ready = |a: u32| wires[a as usize].is_some();
            match gate {
                Gate::Xor(a, b) if ready(a) && ready(b) => {
                    wires[w] = Some(wires[a as usize].unwrap() + wires[b as usize].unwrap());
                    progressed = true;
                }
                Gate::Not(a) if ready(a) => {
                    wires[w] = Some(wires[a as usize].unwrap().add_const(Bit(true)));
                    progressed = true;
                }
                Gate::And(a, b) if ready(a) && ready(b) => {
                    and_batch.push((w, wires[a as usize].unwrap(), wires[b as usize].unwrap()));
                    progressed = true;
                }
                _ => next_remaining.push((g, gate)),
            }
        }
        if !and_batch.is_empty() {
            let xs: Vec<MShare<Bit>> = and_batch.iter().map(|t| t.1).collect();
            let ys: Vec<MShare<Bit>> = and_batch.iter().map(|t| t.2).collect();
            let zs = mult_many(ctx, &xs, &ys)?;
            for ((w, _, _), z) in and_batch.into_iter().zip(zs) {
                wires[w] = Some(z);
            }
        }
        assert!(progressed, "circuit has unresolvable wires");
        remaining = next_remaining;
    }

    Ok(circuit
        .outputs
        .iter()
        .map(|&o| wires[o as usize].expect("output resolved"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::circuit::{adder, bits_u64, ppa_subtractor, u64_bits};
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::sharing::share_many_n;
    use crate::proto::{run_4pc, Ctx};
    use crate::sharing::open;

    fn share_bits(
        ctx: &mut Ctx,
        dealer: crate::net::PartyId,
        v: u64,
        bits: usize,
    ) -> Result<Vec<MShare<Bit>>, crate::net::Abort> {
        let vs = (ctx.id() == dealer).then(|| u64_bits(v, bits));
        share_many_n(ctx, dealer, vs.as_deref(), bits)
    }

    fn open_bits(outs: &[Vec<MShare<Bit>>; 4]) -> u64 {
        let n = outs[0].len();
        let bits: Vec<Bit> = (0..n)
            .map(|i| open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]))
            .collect();
        bits_u64(&bits)
    }

    #[test]
    fn boolean_adder_over_shares() {
        let run = run_4pc(NetProfile::zero(), 100, |ctx| {
            let xs = share_bits(ctx, P1, 123456789, 64)?;
            let ys = share_bits(ctx, P2, 987654321, 64)?;
            let mut inputs = xs;
            inputs.extend(ys);
            let c = adder(64);
            let out = eval_bool_circuit(ctx, &c, &inputs)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(open_bits(&outs), 123456789 + 987654321);
    }

    #[test]
    fn boolean_ppa_subtractor_log_rounds() {
        let run = run_4pc(NetProfile::zero(), 101, |ctx| {
            let xs = share_bits(ctx, P1, 1000, 64)?;
            let ys = share_bits(ctx, P2, 2024, 64)?;
            let mut inputs = xs;
            inputs.extend(ys);
            let c = ppa_subtractor(64);
            let out = eval_bool_circuit(ctx, &c, &inputs)?;
            ctx.flush_verify()?;
            Ok(out)
        });
        let (outs, report) = run.expect_ok();
        assert_eq!(open_bits(&outs), 1000u64.wrapping_sub(2024));
        // online rounds: 2 input rounds + AND-depth (≤ 1 + log ℓ = 7)
        assert!(
            report.rounds[1] <= 2 + 7,
            "rounds {} too deep for a PPA",
            report.rounds[1]
        );
    }
}
