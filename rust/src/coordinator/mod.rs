//! Coordinator: user-facing orchestration of the four-party cluster —
//! the outsourced-MLaaS setting of §I where data owners secret-share their
//! inputs among four servers, the offline dealer phase runs ahead of time,
//! and the online phase answers training/prediction requests.
//!
//! The thread-per-party runtime lives in `net::run_cluster`; this module
//! packages complete workloads (training loops with loss curves, batched
//! prediction serving) behind simple entry points used by the CLI and the
//! examples.

use crate::crypto::Rng;
use crate::ml::data::{class_batch, linreg_batch, logreg_batch};
use crate::ml::{share_fixed_mat, F64Mat, LinReg, LogReg, Network, NetworkKind};
use crate::net::{NetProfile, Phase, P1, P2};
use crate::proto::{mult, reconstruct, run_4pc, share};
use crate::ring::{FixedPoint, Z64};

/// Quickstart demo: share → multiply → truncated multiply → reconstruct.
pub fn demo_quickstart() {
    let run = run_4pc(NetProfile::lan(), 42, |ctx| {
        // P1 contributes x = 6.5, P2 contributes y = -2.25
        let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(6.5)))?;
        let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(-2.25)))?;
        let xy = crate::proto::mult_tr(ctx, &x, &y)?;
        let raw = mult(ctx, &x, &x)?; // x² without truncation (ring product)
        let _ = raw;
        reconstruct(ctx, &xy)
    });
    let (outs, report) = run.expect_ok();
    println!("x·y = {}", FixedPoint::decode(outs[0]));
    println!(
        "online: {} rounds, {} value bits, simulated LAN latency {:.3} ms",
        report.rounds[Phase::Online as usize],
        report.value_bits[Phase::Online as usize],
        report.online_latency() * 1e3,
    );
}

/// Training driver used by `trident train` and the e2e example. Returns the
/// per-iteration loss curve (reconstructed from the shared residuals).
pub fn train_cli(model: &str, iters: usize, batch: usize, d: usize) -> Vec<f64> {
    println!("secure training: model={model} iters={iters} batch={batch} d={d}");
    let model = model.to_string();
    let run = run_4pc(NetProfile::lan(), 99, move |ctx| {
        let mut losses = Vec::new();
        let mut rng = Rng::seeded(2024);
        match model.as_str() {
            "linreg" | "logreg" => {
                let logistic = model == "logreg";
                let data = if logistic {
                    logreg_batch(&mut rng, batch, d)
                } else {
                    linreg_batch(&mut rng, batch, d)
                };
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
                let ys =
                    share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&data.y), batch, 1)?;
                let w0 = F64Mat::zeros(d, 1);
                let mut w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&w0), d, 1)?;
                // step size must shrink with the feature count for GD
                // stability: α = 2^-(log2 d + 1)
                let lr_pow = ((d as f64).log2().ceil() as u32 + 1).max(2);
                for _ in 0..iters {
                    if logistic {
                        let m = LogReg { d, batch, lr_pow };
                        w = m.train_iteration(ctx, &w, &xs, &ys)?;
                        let p = m.predict(ctx, &xs, &w)?;
                        losses.push(mse_against(ctx, &p, &ys)?);
                    } else {
                        let m = LinReg { d, batch, lr_pow };
                        w = m.train_iteration(ctx, &w, &xs, &ys)?;
                        let p = m.predict(ctx, &xs, &w)?;
                        losses.push(mse_against(ctx, &p, &ys)?);
                    }
                }
            }
            _ => {
                let kind = if model == "cnn" { NetworkKind::Cnn } else { NetworkKind::Nn };
                let mut net = Network::new(kind, batch);
                if d != net.layers[0] {
                    net.layers[0] = d;
                }
                let classes = *net.layers.last().unwrap();
                let data = class_batch(&mut rng, batch, net.layers[0], classes);
                let xs = share_fixed_mat(
                    ctx,
                    P1,
                    (ctx.id() == P1).then_some(&data.x),
                    batch,
                    net.layers[0],
                )?;
                let ts = share_fixed_mat(
                    ctx,
                    P2,
                    (ctx.id() == P2).then_some(&data.t),
                    batch,
                    classes,
                )?;
                let init = net.init_weights_clear(&mut Rng::seeded(7));
                let mut ws =
                    net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
                for _ in 0..iters {
                    ws = net.train_iteration(ctx, &ws, &xs, &ts)?;
                    let p = net.predict(ctx, &ws, &xs)?;
                    losses.push(mse_against(ctx, &p, &ts)?);
                }
            }
        }
        ctx.flush_verify()?;
        Ok(losses)
    });
    let (outs, report) = run.expect_ok();
    let losses = outs[1].clone();
    for (i, l) in losses.iter().enumerate() {
        println!("iter {i:>3}: loss {l:.6}");
    }
    println!(
        "online totals: {} rounds, {:.1} KiB values, simulated LAN time {:.1} ms ({:.2} it/s)",
        report.rounds[Phase::Online as usize],
        report.value_bytes[Phase::Online as usize] as f64 / 1024.0,
        report.online_latency() * 1e3,
        iters as f64 / report.online_latency(),
    );
    losses
}

/// Reconstruct the mean-squared error between two shared matrices
/// (output-stage reconstruction — the only values ever opened).
fn mse_against(
    ctx: &mut crate::proto::Ctx,
    p: &crate::sharing::MMat<Z64>,
    t: &crate::sharing::MMat<Z64>,
) -> Result<f64, crate::net::Abort> {
    let diff = p - t;
    let opened = crate::proto::reconstruct::reconstruct_many(ctx, &diff.to_shares())?;
    let n = opened.len() as f64;
    Ok(opened
        .iter()
        .map(|&v| {
            let f = FixedPoint::decode(v);
            f * f
        })
        .sum::<f64>()
        / n)
}

/// Prediction driver for `trident predict`.
pub fn predict_cli(model: &str, batch: usize) {
    let m = crate::bench::measure_predict(NetProfile::lan(), model, 784, batch);
    println!(
        "secure prediction: model={model} batch={batch} → {:.2} ms online (LAN), {} rounds, {} value bits",
        m.online_latency() * 1e3,
        m.online_rounds(),
        m.online_bits(),
    );
    let wan = crate::bench::measure_predict(NetProfile::wan(), model, 784, batch);
    println!("                   WAN latency {:.2} s", wan.online_latency());
}

/// Batched prediction serving demo: a stream of query batches answered by a
/// persistent trained model (the MLaaS loop).
pub fn serve_cli(queries: usize) {
    println!("serving {queries} query batches (linreg d=784, B=100 each) …");
    let run = run_4pc(NetProfile::lan(), 123, move |ctx| {
        let d = 784;
        let mut rng = Rng::seeded(5);
        let w0 = {
            let mut w = F64Mat::zeros(d, 1);
            for j in 0..d {
                w.set(j, 0, rng.normal() * 0.1);
            }
            w
        };
        let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&w0), d, 1)?;
        let model = LinReg::new(d, 100);
        let mut latencies = Vec::new();
        for _ in 0..queries {
            let q = linreg_batch(&mut rng, 100, d);
            let xs = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&q.x), 100, d)?;
            let t0 = ctx.net.clock(Phase::Online);
            let _p = model.predict(ctx, &xs, &w)?;
            latencies.push(ctx.net.clock(Phase::Online) - t0);
        }
        ctx.flush_verify()?;
        Ok(latencies)
    });
    let (outs, report) = run.expect_ok();
    let lat = &outs[1];
    let avg = lat.iter().sum::<f64>() / lat.len() as f64;
    println!(
        "served {} batches: avg {:.3} ms/batch (simulated LAN), throughput {:.0} queries/s",
        lat.len(),
        avg * 1e3,
        100.0 / avg,
    );
    println!(
        "total online bytes {:.1} KiB, wall {:?}",
        report.total_bytes[Phase::Online as usize] as f64 / 1024.0,
        report.wall
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_runs() {
        demo_quickstart();
    }

    #[test]
    fn train_cli_loss_decreases() {
        let losses = train_cli("linreg", 12, 16, 8);
        assert!(losses.last().unwrap() < &losses[0], "loss must drop: {losses:?}");
    }

    #[test]
    fn tiny_nn_cli() {
        let losses = train_cli("nn", 3, 8, 16);
        assert_eq!(losses.len(), 3);
    }
}
