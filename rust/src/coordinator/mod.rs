//! Coordinator: user-facing orchestration of the four-party cluster —
//! the outsourced-MLaaS setting of §I where data owners secret-share their
//! inputs among four servers, the offline dealer phase runs ahead of time,
//! and the online phase answers training/prediction requests.
//!
//! The thread-per-party runtime lives in `net::run_cluster`; this module
//! packages complete workloads (training loops with loss curves, batched
//! prediction serving) behind simple entry points used by the CLI and the
//! examples.

use crate::crypto::Rng;
use crate::ml::data::{class_batch, linreg_batch, logreg_batch};
use crate::ml::{share_fixed_mat, F64Mat, LinReg, LogReg, Network, NetworkKind};
use crate::net::{NetProfile, Phase, P1, P2};
use crate::proto::{mult, reconstruct, run_4pc, share};
use crate::ring::{FixedPoint, Z64};

/// Quickstart demo: share → multiply → truncated multiply → reconstruct.
pub fn demo_quickstart() {
    let run = run_4pc(NetProfile::lan(), 42, |ctx| {
        // P1 contributes x = 6.5, P2 contributes y = -2.25
        let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(6.5)))?;
        let y = share(ctx, P2, (ctx.id() == P2).then_some(FixedPoint::encode(-2.25)))?;
        let xy = crate::proto::mult_tr(ctx, &x, &y)?;
        let raw = mult(ctx, &x, &x)?; // x² without truncation (ring product)
        let _ = raw;
        reconstruct(ctx, &xy)
    });
    let (outs, report) = run.expect_ok();
    println!("x·y = {}", FixedPoint::decode(outs[0]));
    println!(
        "online: {} rounds, {} value bits, simulated LAN latency {:.3} ms",
        report.rounds[Phase::Online as usize],
        report.value_bits[Phase::Online as usize],
        report.online_latency() * 1e3,
    );
}

/// Training driver used by `trident train` and the e2e example. Returns the
/// per-iteration loss curve (reconstructed from the shared residuals).
pub fn train_cli(model: &str, iters: usize, batch: usize, d: usize) -> Vec<f64> {
    println!("secure training: model={model} iters={iters} batch={batch} d={d}");
    let model = model.to_string();
    let run = run_4pc(NetProfile::lan(), 99, move |ctx| {
        let mut losses = Vec::new();
        let mut rng = Rng::seeded(2024);
        match model.as_str() {
            "linreg" | "logreg" => {
                let logistic = model == "logreg";
                let data = if logistic {
                    logreg_batch(&mut rng, batch, d)
                } else {
                    linreg_batch(&mut rng, batch, d)
                };
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), batch, d)?;
                let ys =
                    share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&data.y), batch, 1)?;
                let w0 = F64Mat::zeros(d, 1);
                let mut w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&w0), d, 1)?;
                // step size must shrink with the feature count for GD
                // stability: α = 2^-(log2 d + 1)
                let lr_pow = ((d as f64).log2().ceil() as u32 + 1).max(2);
                for _ in 0..iters {
                    if logistic {
                        let m = LogReg { d, batch, lr_pow };
                        w = m.train_iteration(ctx, &w, &xs, &ys)?;
                        let p = m.predict(ctx, &xs, &w)?;
                        losses.push(mse_against(ctx, &p, &ys)?);
                    } else {
                        let m = LinReg { d, batch, lr_pow };
                        w = m.train_iteration(ctx, &w, &xs, &ys)?;
                        let p = m.predict(ctx, &xs, &w)?;
                        losses.push(mse_against(ctx, &p, &ys)?);
                    }
                }
            }
            _ => {
                let kind = if model == "cnn" { NetworkKind::Cnn } else { NetworkKind::Nn };
                let mut net = Network::new(kind, batch);
                if d != net.layers[0] {
                    net.layers[0] = d;
                }
                let classes = *net.layers.last().unwrap();
                let data = class_batch(&mut rng, batch, net.layers[0], classes);
                let xs = share_fixed_mat(
                    ctx,
                    P1,
                    (ctx.id() == P1).then_some(&data.x),
                    batch,
                    net.layers[0],
                )?;
                let ts = share_fixed_mat(
                    ctx,
                    P2,
                    (ctx.id() == P2).then_some(&data.t),
                    batch,
                    classes,
                )?;
                let init = net.init_weights_clear(&mut Rng::seeded(7));
                let mut ws =
                    net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
                for _ in 0..iters {
                    ws = net.train_iteration(ctx, &ws, &xs, &ts)?;
                    let p = net.predict(ctx, &ws, &xs)?;
                    losses.push(mse_against(ctx, &p, &ts)?);
                }
            }
        }
        ctx.flush_verify()?;
        Ok(losses)
    });
    let (outs, report) = run.expect_ok();
    let losses = outs[1].clone();
    for (i, l) in losses.iter().enumerate() {
        println!("iter {i:>3}: loss {l:.6}");
    }
    println!(
        "online totals: {} rounds, {:.1} KiB values, simulated LAN time {:.1} ms ({:.2} it/s)",
        report.rounds[Phase::Online as usize],
        report.value_bytes[Phase::Online as usize] as f64 / 1024.0,
        report.online_latency() * 1e3,
        iters as f64 / report.online_latency(),
    );
    losses
}

/// Reconstruct the mean-squared error between two shared matrices
/// (output-stage reconstruction — the only values ever opened).
fn mse_against(
    ctx: &mut crate::proto::Ctx,
    p: &crate::sharing::MMat<Z64>,
    t: &crate::sharing::MMat<Z64>,
) -> Result<f64, crate::net::Abort> {
    let diff = p - t;
    let opened = crate::proto::reconstruct::reconstruct_mat(ctx, &diff)?;
    let n = opened.data().len() as f64;
    Ok(opened
        .data()
        .iter()
        .map(|&v| {
            let f = FixedPoint::decode(v);
            f * f
        })
        .sum::<f64>()
        / n)
}

/// Prediction driver for `trident predict`.
pub fn predict_cli(model: &str, batch: usize) {
    let m = crate::bench::measure_predict(NetProfile::lan(), model, 784, batch);
    println!(
        "secure prediction: model={model} batch={batch} → {:.2} ms online (LAN), {} rounds, {} value bits",
        m.online_latency() * 1e3,
        m.online_rounds(),
        m.online_bits(),
    );
    let wan = crate::bench::measure_predict(NetProfile::wan(), model, 784, batch);
    println!("                   WAN latency {:.2} s", wan.online_latency());
}

/// Scheduled-training job options: the job rides the serving cluster as a
/// first-class [`crate::sched::Workload::Training`] tenant (class 1, one
/// preemptible wave per epoch, per-epoch keyed pools, checkpointed
/// shares). Built by `trident train --epochs …` and the mixed
/// `trident serve --train` path.
#[derive(Clone, Debug)]
pub struct TrainJobOpts {
    /// `"linreg"`, `"logreg"` or `"nn"`.
    pub model: String,
    /// Epochs to run (one scheduled wave each).
    pub epochs: usize,
    /// Mini-batch rows per epoch wave; rounded up to a power of two (the
    /// 1/B gradient scale is a ring shift).
    pub batch: usize,
    /// Feature count.
    pub features: usize,
    /// Checkpoint the per-party weight shares every N committed epochs
    /// (0 = never).
    pub checkpoint_every: usize,
    /// Learning rate α = 2^-lr_pow.
    pub lr_pow: u32,
}

impl Default for TrainJobOpts {
    fn default() -> TrainJobOpts {
        TrainJobOpts {
            model: "linreg".into(),
            epochs: 6,
            batch: 16,
            features: 8,
            checkpoint_every: 0,
            lr_pow: 4,
        }
    }
}

/// Unified serving/training configuration: ONE builder consumed by the
/// single-tenant engine sweep, the multi-tenant scheduler path and the
/// scheduled-training mode. Replaces the old `ServeCliOpts` /
/// `MultiServeCliOpts` pair — the CLI flags stay byte-compatible; only
/// the plumbing underneath them is shared now.
///
/// Routing: `models` empty and no training job → the single-tenant mode
/// sweep ([`serve_cli`] prints keyed/scalar/inline side by side);
/// otherwise the scheduler subsystem runs one tenant per model plus the
/// optional training job.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Queries per tenant.
    pub queries: usize,
    /// Coalescing factor; `None` = a mode-appropriate default.
    pub coalesce: Option<usize>,
    /// `"inline"`, `"scalar"` or `"keyed"` (single-tenant sweep only).
    pub mode: String,
    /// Background-refill low-water mark, in full-wave items.
    pub low_water: usize,
    /// Background-refill high-water mark, same units.
    pub high_water: usize,
    /// Apply a batched ReLU after the linear layer (single-tenant sweep).
    pub relu: bool,
    /// Tenant/model names, registry order (`--models m1,m2`); empty routes
    /// to the single-tenant path unless a training job is attached.
    pub models: Vec<String>,
    /// Weighted-round-robin shares (`--weights 2,1`); missing entries
    /// default to 1.
    pub weights: Vec<u64>,
    /// Priority classes, 0 = highest (`--priorities 0,1`); missing entries
    /// default to 0.
    pub priorities: Vec<u8>,
    /// Relative query deadline for every tenant (`--deadline-ms D`; one
    /// logical tick ≈ one serving wave ≈ 1 ms on the simulated LAN).
    pub deadline_ms: Option<u64>,
    /// Admission-control in-flight cap per tenant (`--cap N`).
    pub cap: Option<usize>,
    /// Abort blast-radius containment demo (`--containment`): enables the
    /// four-party wave-outcome barrier AND injects a deterministic
    /// mid-serve tamper fault (P1 corrupts tenant 0's second keyed wave),
    /// so the run shows a quarantine instead of failing closed.
    pub containment: bool,
    /// Failover policy past quarantine (`--failover god`): the
    /// quarantined tenant's re-queued queries are served on the
    /// Tetrad-style guaranteed-output-delivery backend and the tenant is
    /// rehabilitated back to keyed Trident serving after consecutive
    /// clean failover waves. `None`/`"none"` keeps quarantined tenants on
    /// the inline path forever. Only meaningful with `--containment`.
    pub failover: Option<String>,
    /// Also write the machine-readable benchmark (`BENCH_serving.json`).
    pub json: bool,
    /// Write the merged per-party trace as chrome-tracing-flavoured JSONL
    /// to this path (`--trace out.jsonl`). Tracing itself is always on for
    /// the CLI run — the observer-effect contract makes it free — so this
    /// only controls whether the event stream is persisted.
    pub trace: Option<String>,
    /// Scheduled training job sharing the cluster (`--train`).
    pub train: Option<TrainJobOpts>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            queries: 8,
            coalesce: None,
            mode: "keyed".into(),
            low_water: 1,
            high_water: 2,
            relu: false,
            models: Vec::new(),
            weights: Vec::new(),
            priorities: Vec::new(),
            deadline_ms: None,
            cap: None,
            containment: false,
            failover: None,
            json: false,
            trace: None,
            train: None,
        }
    }
}

impl ServeConfig {
    pub fn new() -> ServeConfig {
        ServeConfig::default()
    }

    /// Multi-tenant starting point (the old `MultiServeCliOpts` defaults):
    /// 12 queries per tenant; an empty `models` list falls back to the
    /// canonical `m1,m2` pair at lowering time.
    pub fn tenants(models: Vec<String>) -> ServeConfig {
        ServeConfig { queries: 12, models, ..ServeConfig::default() }
    }

    pub fn queries(mut self, n: usize) -> ServeConfig {
        self.queries = n;
        self
    }

    pub fn coalesce(mut self, c: usize) -> ServeConfig {
        self.coalesce = Some(c);
        self
    }

    pub fn mode(mut self, m: &str) -> ServeConfig {
        self.mode = m.into();
        self
    }

    pub fn water(mut self, low: usize, high: usize) -> ServeConfig {
        self.low_water = low;
        self.high_water = high;
        self
    }

    pub fn relu(mut self, on: bool) -> ServeConfig {
        self.relu = on;
        self
    }

    pub fn weights(mut self, w: Vec<u64>) -> ServeConfig {
        self.weights = w;
        self
    }

    pub fn priorities(mut self, p: Vec<u8>) -> ServeConfig {
        self.priorities = p;
        self
    }

    pub fn deadline_ms(mut self, d: Option<u64>) -> ServeConfig {
        self.deadline_ms = d;
        self
    }

    pub fn cap(mut self, c: Option<usize>) -> ServeConfig {
        self.cap = c;
        self
    }

    pub fn containment(mut self, on: bool) -> ServeConfig {
        self.containment = on;
        self
    }

    pub fn failover(mut self, policy: Option<String>) -> ServeConfig {
        self.failover = policy;
        self
    }

    pub fn json(mut self, on: bool) -> ServeConfig {
        self.json = on;
        self
    }

    pub fn trace(mut self, path: Option<String>) -> ServeConfig {
        self.trace = path;
        self
    }

    pub fn train(mut self, job: TrainJobOpts) -> ServeConfig {
        self.train = Some(job);
        self
    }

    /// Whether this config routes to the scheduler subsystem (any resident
    /// models named, or a training job attached).
    pub fn is_multi(&self) -> bool {
        !self.models.is_empty() || self.train.is_some()
    }
}

/// Lower a [`TrainJobOpts`] into the scheduler's tenant spec (model id
/// `model_id` in the registry). Non-power-of-two batches round up; an
/// unknown model kind falls back to linreg with a message.
fn train_tenant_spec(job: &TrainJobOpts, model_id: u64) -> crate::sched::TenantSpec {
    use crate::sched::{TenantSpec, TrainKind};
    let kind = TrainKind::parse(&job.model).unwrap_or_else(|| {
        println!("unknown training model {:?} (linreg|logreg|nn), using linreg", job.model);
        TrainKind::LinReg
    });
    let batch = job.batch.max(1).next_power_of_two();
    if batch != job.batch {
        println!("--batch {} rounded up to {batch} (the 1/B gradient scale is a ring shift)", job.batch);
    }
    // hidden 8 → 2 outputs for the NN job; the regressors are single-layer
    let layers = if kind == TrainKind::Nn { vec![8, 2] } else { Vec::new() };
    TenantSpec::training(
        "train",
        model_id,
        job.features.max(1),
        layers,
        kind,
        job.epochs.max(1),
        batch,
        job.checkpoint_every,
        job.lr_pow,
    )
}

/// Entry point behind `trident serve`: routes the unified config to the
/// single-tenant mode sweep or the multi-tenant scheduler.
pub fn serve_cli(cfg: ServeConfig) {
    if cfg.is_multi() {
        serve_tenants_cli(cfg)
    } else {
        serve_single_cli(cfg)
    }
}

/// Batched prediction serving (the MLaaS loop), backed by the real engine:
/// circuit-keyed pool pre-stocked and topped up by the background refill
/// producer, concurrent queries coalesced into cross-request batches,
/// every response verified before release. Prints the amortized per-query
/// cost next to the scalar-pool and seed-style inline paths.
pub fn serve_single_cli(opts: ServeConfig) {
    use crate::serve::{serve, PoolMode, ServeConfig as EngineConfig, ServeStats};
    let mode = match opts.mode.as_str() {
        "inline" => PoolMode::Inline,
        "scalar" => PoolMode::Scalar,
        "keyed" => PoolMode::Keyed,
        other => {
            println!("unknown --mode {other:?} (inline|scalar|keyed), using keyed");
            PoolMode::Keyed
        }
    };
    let queries = opts.queries;
    // sanitize the water marks up front: a low mark above high would trip
    // the in-protocol assertion in every party thread, and low = 0 never
    // triggers a refill — both deserve a CLI-level message instead
    let high_water = opts.high_water.max(1);
    let mut low_water = opts.low_water;
    if low_water > high_water {
        println!("--low-water {low_water} exceeds --high-water {high_water}; clamping low to {high_water}");
        low_water = high_water;
    }
    if low_water == 0 {
        println!("--low-water 0 disables background refill: pools will never be (re)stocked");
    }
    let cfg = EngineConfig {
        d: 784,
        rows_per_query: 1,
        queries,
        coalesce: opts.coalesce.unwrap_or_else(|| queries.clamp(1, 16)),
        mode,
        low_water,
        high_water,
        relu: opts.relu,
        seed: 123,
    };
    println!(
        "serving {queries} queries (linreg d={}, {} rows each, coalesce ≤{}, water marks {}/{}) …",
        cfg.d, cfg.rows_per_query, cfg.coalesce, cfg.low_water, cfg.high_water
    );
    let line = |name: &str, s: &ServeStats| {
        println!(
            "{name:<10}: {} batches | {:.3} ms/query | {:.0} B/query online | {} online rounds | {} offline msgs in waves",
            s.batches,
            s.per_query_latency() * 1e3,
            s.per_query_online_bytes(),
            s.online_rounds,
            s.offline_msgs_in_waves,
        );
    };
    let keyed = serve(NetProfile::lan(), EngineConfig { mode: PoolMode::Keyed, ..cfg.clone() });
    let scalar = serve(NetProfile::lan(), EngineConfig { mode: PoolMode::Scalar, ..cfg.clone() });
    let inline = serve(
        NetProfile::lan(),
        EngineConfig { coalesce: 1, mode: PoolMode::Inline, ..cfg.clone() },
    );
    line("keyed pool", &keyed);
    line("scalar    ", &scalar);
    line("inline    ", &inline);
    // detail lines follow the --mode selection
    let sel = match mode {
        PoolMode::Keyed => &keyed,
        PoolMode::Scalar => &scalar,
        PoolMode::Inline => &inline,
    };
    println!(
        "gain      : {:.1}× latency/query, {:.2}× bytes/query vs inline; refill {} bundles over {} ticks, offline {:.1} KiB metered separately",
        inline.per_query_latency() / sel.per_query_latency().max(1e-12),
        inline.per_query_online_bytes() / sel.per_query_online_bytes().max(1e-12),
        sel.refill_mat_items,
        sel.refill_ticks,
        sel.offline_value_bits as f64 / 8.0 / 1024.0,
    );
    if let Some(ps) = sel.pool_stats {
        println!(
            "pool      : {} hits / {} misses, {} keyed bundles left, per-wave offline silence: {}",
            ps.hits(),
            ps.misses(),
            sel.pool_left_mat,
            if sel.offline_msgs_in_waves == 0 { "yes" } else { "NO" },
        );
    }
}

/// Multi-tenant prediction serving: N resident models loaded into the
/// model registry (one keyed pool shard + refill targets per tenant), the
/// deadline/priority queue at the request edge, and the weighted
/// round-robin wave planner deciding whose coalesced wave runs next — plus
/// the optional scheduled training job riding the same cluster as a
/// class-1 workload (`--train`). Prints the per-tenant stats table.
pub fn serve_tenants_cli(opts: ServeConfig) {
    use crate::sched::TenantSpec;
    use crate::serve::{
        serve_multi, FailoverPolicy, FaultKind, FaultPlan, MultiServeConfig, PoolMode,
    };
    let queries = opts.queries.max(1);
    let coalesce = opts.coalesce.unwrap_or_else(|| queries.clamp(1, 8));
    let model_names: Vec<String> = if opts.models.is_empty() {
        vec!["m1".into(), "m2".into()]
    } else {
        opts.models.clone()
    };
    let mut tenants: Vec<TenantSpec> = model_names
        .iter()
        .enumerate()
        .map(|(t, name)| {
            let mut s = TenantSpec::new(name, t as u64 + 1, 128, queries, coalesce);
            s.weight = opts.weights.get(t).copied().unwrap_or(1).max(1);
            s.class = opts.priorities.get(t).copied().unwrap_or(0);
            s.deadline_ticks = opts.deadline_ms;
            s.inflight_cap = opts.cap;
            s
        })
        .collect();
    if let Some(job) = &opts.train {
        tenants.push(train_tenant_spec(job, tenants.len() as u64 + 1));
    }
    let failover = match opts.failover.as_deref() {
        None | Some("none") => FailoverPolicy::None,
        Some("god") => FailoverPolicy::God,
        Some(other) => {
            println!("unknown --failover {other:?} (expected god|none), using none");
            FailoverPolicy::None
        }
    };
    let cfg = MultiServeConfig {
        tenants,
        mode: PoolMode::Keyed,
        low_water: opts.low_water.max(1),
        high_water: opts.high_water.max(1),
        age_every: 2,
        seed: 333,
        containment: opts.containment,
        failover,
        fault: opts.containment.then_some(FaultPlan {
            party: crate::net::P1,
            tenant: 0,
            wave: 1,
            layer: 0,
            kind: FaultKind::TamperMatLamX,
            every: None,
        }),
        // always trace: every CLI run carries the skeleton-checked event
        // stream, and the observer-effect contract keeps the meters exact
        trace: true,
        ..MultiServeConfig::default()
    };
    println!(
        "multi-tenant serving: {} resident models × {queries} queries (d=128, coalesce ≤{coalesce}, keyed pools, LAN{}{}{}) …",
        model_names.len(),
        if opts.train.is_some() { ", + scheduled training job" } else { "" },
        if opts.containment { ", containment on + injected tamper fault" } else { "" },
        if failover == FailoverPolicy::God { ", GOD failover" } else { "" },
    );
    let stats = serve_multi(crate::net::NetProfile::lan(), cfg);
    print!("{}", crate::bench::tenant_table(&stats));
    print!("{}", crate::bench::flame_table(&stats));
    // the silence/quarantine/gauge summary is rendered from the same
    // trace-backed stats the exporters use (no hand-kept printf state)
    print!("{}", crate::obs::export::gauge_table(&stats));
    if opts.train.is_some() {
        print_train_summary(&stats.tenants[stats.tenants.len() - 1], stats.online_latency);
    }
    if let Some(path) = &opts.trace {
        match std::fs::write(path, crate::obs::export::trace_jsonl(&stats.party_traces)) {
            Ok(()) => println!(
                "wrote {path} ({} events across 4 parties)",
                stats.party_traces.iter().map(Vec::len).sum::<usize>(),
            ),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }
    if opts.json {
        match crate::bench::write_serving_bench_json("BENCH_serving.json") {
            Ok(_) => println!("wrote BENCH_serving.json"),
            Err(e) => println!("could not write BENCH_serving.json: {e}"),
        }
    }
}

/// Render the training-job trailer shared by the mixed-serve and
/// train-mode CLIs.
fn print_train_summary(ts: &crate::serve::TenantServeStats, online_latency: f64) {
    println!(
        "training : {} epochs committed over {} waves | {:.2} epochs/s online | {} offline msgs in wave windows | {} checkpoints",
        ts.epochs_committed,
        ts.waves,
        ts.epochs_committed as f64 / online_latency.max(1e-9),
        ts.offline_msgs_in_waves,
        ts.checkpoints.len(),
    );
    if let Some(model) = &ts.final_model {
        let norm: f64 = model
            .iter()
            .flat_map(|l| l.iter())
            .map(|v| v * v)
            .sum::<f64>()
            .sqrt();
        println!(
            "training : final model published ({} layer(s), ‖w‖₂ = {norm:.4})",
            model.len(),
        );
    }
}

/// Scheduled secure training (`trident train --epochs N`): the job is
/// admitted through the SAME registry/queue/planner as serving — one
/// preemptible wave per epoch, per-epoch circuit-keyed pools regenerated
/// between waves (fresh-weight bundles: reusing λ_W across epochs would
/// leak weight deltas), per-party checkpointed shares.
pub fn train_workload_cli(cfg: ServeConfig) {
    use crate::serve::{serve_multi, MultiServeConfig, PoolMode};
    let job = cfg.train.clone().unwrap_or_default();
    let spec = train_tenant_spec(&job, 1);
    println!(
        "scheduled training: model={} epochs={} batch={} d={} (α=2^-{}, checkpoint every {}) …",
        job.model, spec.queries, spec.rows_per_query, spec.d, job.lr_pow, job.checkpoint_every,
    );
    let mcfg = MultiServeConfig {
        tenants: vec![spec],
        mode: PoolMode::Keyed,
        low_water: cfg.low_water.max(1),
        high_water: cfg.high_water.max(1),
        age_every: 0,
        seed: 333,
        trace: true,
        ..MultiServeConfig::default()
    };
    let stats = serve_multi(crate::net::NetProfile::lan(), mcfg);
    print!("{}", crate::bench::tenant_table(&stats));
    print_train_summary(&stats.tenants[0], stats.online_latency);
}

/// `trident metrics`: run the canonical multi-tenant demo workload
/// (traced) and print a Prometheus-style text snapshot of every counter
/// and wave-boundary gauge the merged four-party trace carries.
pub fn metrics_cli() {
    let stats =
        crate::serve::serve_multi(crate::net::NetProfile::lan(), crate::bench::demo_tenants(12));
    print!("{}", crate::obs::export::prometheus(&stats));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_runs() {
        demo_quickstart();
    }

    #[test]
    fn train_cli_loss_decreases() {
        let losses = train_cli("linreg", 12, 16, 8);
        assert!(losses.last().unwrap() < &losses[0], "loss must drop: {losses:?}");
    }

    #[test]
    fn tiny_nn_cli() {
        let losses = train_cli("nn", 3, 8, 16);
        assert_eq!(losses.len(), 3);
    }

    #[test]
    fn serve_tenants_cli_writes_parseable_trace() {
        let path = std::env::temp_dir().join("trident_cli_trace_test.jsonl");
        let path_s = path.to_string_lossy().into_owned();
        let opts = ServeConfig::tenants(Vec::new()).queries(4).coalesce(2).trace(Some(path_s));
        serve_tenants_cli(opts);
        let body = std::fs::read_to_string(&path).unwrap();
        let first = body.lines().next().unwrap();
        assert!(first.contains("\"op\":\"run.open\""), "first line opens the run: {first}");
        assert!(body.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        assert!(body.contains("\"op\":\"gate.matmul\""), "per-gate spans present");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn serve_tenants_cli_containment_demo_runs() {
        // the --containment demo injects a tamper fault against tenant 0's
        // second wave; the run must quarantine and finish, not panic
        let opts = ServeConfig::tenants(Vec::new()).queries(6).coalesce(3).containment(true);
        serve_tenants_cli(opts);
    }

    #[test]
    fn serve_tenants_cli_failover_demo_runs() {
        // --containment --failover god: the tampered tenant quarantines,
        // degrades to the GOD backend, and rehabilitates — the run must
        // finish with every admitted query served
        let opts = ServeConfig::tenants(Vec::new())
            .queries(12)
            .coalesce(3)
            .containment(true)
            .failover(Some("god".into()));
        serve_tenants_cli(opts);
    }

    #[test]
    fn serve_config_routes_single_vs_multi() {
        assert!(!ServeConfig::new().is_multi(), "bare config is the single-tenant sweep");
        assert!(ServeConfig::tenants(vec!["m1".into()]).is_multi());
        assert!(
            ServeConfig::new().train(TrainJobOpts::default()).is_multi(),
            "a training job alone routes to the scheduler"
        );
    }

    #[test]
    fn mixed_serve_train_cli_runs() {
        // the mixed path: inference tenants + a scheduled LinReg job with
        // a non-power-of-two batch (rounded up) and mid-job checkpoints
        let opts = ServeConfig::tenants(vec!["m1".into()]).queries(4).coalesce(2).train(
            TrainJobOpts {
                model: "linreg".into(),
                epochs: 3,
                batch: 6,
                features: 6,
                checkpoint_every: 2,
                lr_pow: 4,
            },
        );
        serve_tenants_cli(opts);
    }

    #[test]
    fn train_workload_cli_runs_scheduled_nn_job() {
        train_workload_cli(ServeConfig::new().train(TrainJobOpts {
            model: "nn".into(),
            epochs: 2,
            batch: 8,
            features: 4,
            checkpoint_every: 0,
            lr_pow: 5,
        }));
    }
}
