//! Native (pure-rust) implementations of the hot-path kernels.
//!
//! The gemm is the ikj streaming loop from `ring::Matrix::matmul`; the
//! masked matmul fuses the two products and the two additive terms in a
//! single output pass to avoid materialising intermediates (see
//! EXPERIMENTS.md §Perf for the before/after).

use crate::ring::{Matrix, Ring};

/// `A∘B` over the ring.
pub fn gemm<R: Ring>(a: &Matrix<R>, b: &Matrix<R>) -> Matrix<R> {
    a.matmul(b)
}

/// `−Λx∘M_y − M_x∘Λy + Γ + Λz` in one fused pass.
pub fn masked_matmul<R: Ring>(
    lam_x: &Matrix<R>,
    m_y: &Matrix<R>,
    m_x: &Matrix<R>,
    lam_y: &Matrix<R>,
    gamma: &Matrix<R>,
    lam_z: &Matrix<R>,
) -> Matrix<R> {
    let (a, b) = (lam_x.rows(), lam_x.cols());
    let c = m_y.cols();
    assert_eq!(m_x.rows(), a);
    assert_eq!(m_x.cols(), b);
    assert_eq!(m_y.rows(), b);
    assert_eq!(lam_y.rows(), b);
    assert_eq!(lam_y.cols(), c);
    assert_eq!(gamma.rows(), a);
    assert_eq!(gamma.cols(), c);

    // out = Γ + Λz
    let mut out = gamma + lam_z;
    // out -= Λx∘M_y + M_x∘Λy, accumulated in one ikj sweep over both terms
    for i in 0..a {
        let orow_start = i * c;
        for k in 0..b {
            let alx = lam_x.row(i)[k];
            let amx = m_x.row(i)[k];
            let my_row = m_y.row(k);
            let ly_row = lam_y.row(k);
            let orow = &mut out.data_mut()[orow_start..orow_start + c];
            for ((o, &myv), &lyv) in orow.iter_mut().zip(my_row.iter()).zip(ly_row.iter()) {
                *o -= alx * myv + amx * lyv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ring::{Bit, Z64};

    #[test]
    fn fused_equals_composed_z64() {
        let mut rng = Rng::seeded(52);
        for (a, b, c) in [(1, 1, 1), (3, 4, 5), (8, 2, 8)] {
            let lx = Matrix::from_fn(a, b, |_, _| rng.gen::<Z64>());
            let mx = Matrix::from_fn(a, b, |_, _| rng.gen::<Z64>());
            let my = Matrix::from_fn(b, c, |_, _| rng.gen::<Z64>());
            let ly = Matrix::from_fn(b, c, |_, _| rng.gen::<Z64>());
            let g = Matrix::from_fn(a, c, |_, _| rng.gen::<Z64>());
            let lz = Matrix::from_fn(a, c, |_, _| rng.gen::<Z64>());
            let got = masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
            let want = &(&g + &lz) - &(&lx.matmul(&my) + &mx.matmul(&ly));
            assert_eq!(got, want);
        }
    }

    #[test]
    fn fused_boolean_world() {
        let mut rng = Rng::seeded(53);
        let n = 5;
        let mk = |rng: &mut Rng| Matrix::from_fn(n, n, |_, _| rng.gen::<Bit>());
        let (lx, my, mx, ly, g, lz) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let got = masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
        let want = &(&g + &lz) - &(&lx.matmul(&my) + &mx.matmul(&ly));
        assert_eq!(got, want);
    }
}
