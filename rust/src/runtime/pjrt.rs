//! PJRT-backed execution of the AOT artifacts — **offline-image stub**.
//!
//! The full engine loads `artifacts/*.hlo.txt` (lowered by
//! `python/compile/aot.py` from the L2 JAX graphs calling the L1 Pallas
//! kernels) through the `xla` crate's PJRT CPU client and serves
//! `gemm`/`masked_matmul` for the shapes that were lowered. The offline
//! build image has no crates.io mirror, so the `xla` dependency cannot be
//! vendored; this module keeps the engine's exact public surface
//! (`init`/`init_default`/`active`/`prefer_pjrt`/`try_*`) while reporting
//! the engine as unavailable, so every caller — CLI, benches, examples,
//! dispatchers in [`super`] — falls through to the fused native kernels
//! without noticing. The §Perf pass measured the interpret-mode CPU
//! artifacts at ~2.6× the fused native kernel anyway (see EXPERIMENTS.md);
//! on real accelerator hardware the Mosaic lowering flips that, at which
//! point this stub is replaced by the `xla`-backed engine again.

use std::path::Path;
use std::sync::OnceLock;

use crate::ring::{Matrix, Ring};

/// Recorded engine configuration: `Some(dir)` would hold the validated
/// artifact directory when a PJRT backend is linked in; the stub always
/// records `None`.
static CONFIG: OnceLock<Option<()>> = OnceLock::new();

/// Initialise the PJRT engine from an artifact directory. The stub records
/// the attempt and returns false: no PJRT backend is linked in this build.
pub fn init(dir: &Path) -> bool {
    let _ = dir;
    CONFIG.get_or_init(|| None);
    false
}

/// Initialise from `$TRIDENT_ARTIFACTS` or `./artifacts`.
pub fn init_default() -> bool {
    let dir = std::env::var("TRIDENT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    init(Path::new(&dir))
}

/// Is the engine live? (Always false in the stub build.)
pub fn active() -> bool {
    matches!(CONFIG.get(), Some(Some(_)))
}

/// Hot-path dispatch policy: prefer PJRT only when the engine is live and
/// `TRIDENT_PJRT` does not disable it.
pub fn prefer_pjrt() -> bool {
    active() && !matches!(std::env::var("TRIDENT_PJRT").as_deref(), Ok("off") | Ok("0"))
}

/// PJRT gemm if an artifact for the shape exists (stub: never).
pub fn try_gemm<R: Ring>(a: &Matrix<R>, b: &Matrix<R>) -> Option<Matrix<R>> {
    let _ = (a, b);
    None
}

/// PJRT fused masked matmul if an artifact for the shape exists (stub: never).
#[allow(clippy::too_many_arguments)]
pub fn try_masked_matmul<R: Ring>(
    lam_x: &Matrix<R>,
    m_y: &Matrix<R>,
    m_x: &Matrix<R>,
    lam_y: &Matrix<R>,
    gamma: &Matrix<R>,
    lam_z: &Matrix<R>,
) -> Option<Matrix<R>> {
    let _ = (lam_x, m_y, m_x, lam_y, gamma, lam_z);
    None
}

/// PJRT offline γ-component if an artifact exists (stub: never).
pub fn try_gamma<R: Ring>(
    lx_j: &Matrix<R>,
    lx_j1: &Matrix<R>,
    ly_j: &Matrix<R>,
    ly_j1: &Matrix<R>,
    mask: &Matrix<R>,
) -> Option<Matrix<R>> {
    let _ = (lx_j, lx_j1, ly_j, ly_j1, mask);
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ring::Z64;

    #[test]
    fn stub_reports_unavailable_and_dispatch_falls_back() {
        assert!(!init(Path::new("artifacts")));
        assert!(!active());
        assert!(!prefer_pjrt());
        let mut rng = Rng::seeded(302);
        let a = Matrix::from_fn(9, 7, |_, _| rng.gen::<Z64>());
        let b = Matrix::from_fn(7, 5, |_, _| rng.gen::<Z64>());
        assert!(try_gemm(&a, &b).is_none());
        // the dispatcher still answers through the native kernel
        assert_eq!(super::super::gemm(&a, &b), a.matmul(&b));
    }

    #[test]
    fn boolean_world_never_hits_pjrt() {
        use crate::ring::Bit;
        let a = Matrix::from_fn(8, 8, |_, _| Bit(true));
        assert!(try_gemm(&a, &a).is_none());
    }
}
