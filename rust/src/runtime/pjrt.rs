//! PJRT-backed execution of the AOT artifacts.
//!
//! `make artifacts` lowers the L2 JAX graphs (calling the L1 Pallas kernels)
//! to HLO text once; this module loads `artifacts/*.hlo.txt` through the
//! `xla` crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) and serves `gemm`/`masked_matmul` for the shapes
//! that were lowered. Anything else falls back to the native kernels — the
//! protocol layer never notices.
//!
//! The engine is opt-in (`init`/`init_default`): unit tests run native-only;
//! the CLI, benches and examples enable it when `artifacts/` exists.

use std::any::TypeId;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use once_cell::sync::OnceCell;

use crate::ring::{Matrix, Ring, Z64};

/// PJRT handles are not `Send`, so each party thread holds its own engine;
/// the global config only records the (validated) artifact directory.
static CONFIG: OnceCell<Option<PathBuf>> = OnceCell::new();

struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// compiled executables keyed by artifact name
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// artifact names known missing (avoid re-stat'ing)
    missing: HashMap<String, ()>,
}

thread_local! {
    static ENGINE: RefCell<Option<Engine>> = const { RefCell::new(None) };
}

/// Initialise the PJRT engine from an artifact directory. Returns false if
/// the directory does not exist.
pub fn init(dir: &Path) -> bool {
    CONFIG.get_or_init(|| dir.is_dir().then(|| dir.to_path_buf())).is_some()
        && CONFIG.get().unwrap().is_some()
}

/// Initialise from `$TRIDENT_ARTIFACTS` or `./artifacts`.
pub fn init_default() -> bool {
    let dir = std::env::var("TRIDENT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    init(Path::new(&dir))
}

/// Is the engine live?
pub fn active() -> bool {
    matches!(CONFIG.get(), Some(Some(_)))
}

/// Hot-path dispatch policy. `TRIDENT_PJRT=off` disables the PJRT path for
/// the protocol hot loop (the §Perf pass measured the interpret-mode CPU
/// artifacts at ~2.6× the fused native kernel; on a real TPU the Mosaic
/// lowering flips that — see EXPERIMENTS.md §Perf). Artifact-vs-native
/// parity tests call `try_*` directly and are unaffected.
pub fn prefer_pjrt() -> bool {
    active() && !matches!(std::env::var("TRIDENT_PJRT").as_deref(), Ok("off") | Ok("0"))
}

/// Execute artifact `name` on u64 input buffers with given dims; returns the
/// flat u64 output or None if the artifact is unavailable.
fn execute(name: &str, inputs: &[(&[u64], usize, usize)], out_len: usize) -> Option<Vec<u64>> {
    let dir = CONFIG.get()?.as_ref()?.clone();
    ENGINE.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            match xla::PjRtClient::cpu() {
                Ok(client) => {
                    *slot = Some(Engine {
                        client,
                        dir,
                        execs: HashMap::new(),
                        missing: HashMap::new(),
                    });
                }
                Err(e) => {
                    eprintln!("trident: PJRT client unavailable: {e}");
                    return None;
                }
            }
        }
        let eng = slot.as_mut().unwrap();
        if eng.missing.contains_key(name) {
            return None;
        }
        if !eng.execs.contains_key(name) {
            let path = eng.dir.join(format!("{name}.hlo.txt"));
            if !path.is_file() {
                eng.missing.insert(name.to_string(), ());
                return None;
            }
            let proto = xla::HloModuleProto::from_text_file(path.to_str()?).ok()?;
            let comp = xla::XlaComputation::from_proto(&proto);
            match eng.client.compile(&comp) {
                Ok(exe) => {
                    eng.execs.insert(name.to_string(), exe);
                }
                Err(e) => {
                    eprintln!("trident: compile {name} failed: {e}");
                    eng.missing.insert(name.to_string(), ());
                    return None;
                }
            }
        }
        let exe = eng.execs.get(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, r, c)| {
                xla::Literal::vec1(data).reshape(&[*r as i64, *c as i64]).expect("reshape")
            })
            .collect();
        let result = exe.execute::<xla::Literal>(&literals).ok()?;
        let lit = result[0][0].to_literal_sync().ok()?;
        let out = lit.to_tuple1().ok()?;
        let v = out.to_vec::<u64>().ok()?;
        (v.len() == out_len).then_some(v)
    })
}

#[inline]
fn as_u64_mat<R: Ring>(m: &Matrix<R>) -> Option<(&[u64], usize, usize)> {
    if TypeId::of::<R>() != TypeId::of::<Z64>() {
        return None;
    }
    // SAFETY: Z64 is repr(transparent) over u64; guarded by the TypeId check.
    let data: &[u64] =
        unsafe { std::slice::from_raw_parts(m.data().as_ptr() as *const u64, m.data().len()) };
    Some((data, m.rows(), m.cols()))
}

fn from_u64_mat<R: Ring>(rows: usize, cols: usize, v: Vec<u64>) -> Matrix<R> {
    debug_assert_eq!(TypeId::of::<R>(), TypeId::of::<Z64>());
    // SAFETY: guarded by caller's TypeId check; Z64 is repr(transparent).
    let data: Vec<R> = unsafe {
        let mut v = std::mem::ManuallyDrop::new(v);
        Vec::from_raw_parts(v.as_mut_ptr() as *mut R, v.len(), v.capacity())
    };
    Matrix::from_vec(rows, cols, data)
}

/// PJRT gemm if an artifact for the shape exists.
pub fn try_gemm<R: Ring>(a: &Matrix<R>, b: &Matrix<R>) -> Option<Matrix<R>> {
    let (ad, ar, ac) = as_u64_mat(a)?;
    let (bd, br, bc) = as_u64_mat(b)?;
    let name = format!("gemm_{ar}x{ac}x{bc}");
    let out = execute(&name, &[(ad, ar, ac), (bd, br, bc)], ar * bc)?;
    Some(from_u64_mat(ar, bc, out))
}

/// PJRT fused masked matmul if an artifact for the shape exists.
#[allow(clippy::too_many_arguments)]
pub fn try_masked_matmul<R: Ring>(
    lam_x: &Matrix<R>,
    m_y: &Matrix<R>,
    m_x: &Matrix<R>,
    lam_y: &Matrix<R>,
    gamma: &Matrix<R>,
    lam_z: &Matrix<R>,
) -> Option<Matrix<R>> {
    let (lx, a, b) = as_u64_mat(lam_x)?;
    let (my, _, c) = as_u64_mat(m_y)?;
    let (mx, _, _) = as_u64_mat(m_x)?;
    let (ly, _, _) = as_u64_mat(lam_y)?;
    let (g, _, _) = as_u64_mat(gamma)?;
    let (lz, _, _) = as_u64_mat(lam_z)?;
    let name = format!("masked_matmul_{a}x{b}x{c}");
    let out = execute(
        &name,
        &[(lx, a, b), (my, b, c), (mx, a, b), (ly, b, c), (g, a, c), (lz, a, c)],
        a * c,
    )?;
    Some(from_u64_mat(a, c, out))
}

/// PJRT offline γ-component if an artifact exists.
pub fn try_gamma<R: Ring>(
    lx_j: &Matrix<R>,
    lx_j1: &Matrix<R>,
    ly_j: &Matrix<R>,
    ly_j1: &Matrix<R>,
    mask: &Matrix<R>,
) -> Option<Matrix<R>> {
    let (a0, a, b) = as_u64_mat(lx_j)?;
    let (a1, _, _) = as_u64_mat(lx_j1)?;
    let (b0, _, c) = as_u64_mat(ly_j)?;
    let (b1, _, _) = as_u64_mat(ly_j1)?;
    let (m, _, _) = as_u64_mat(mask)?;
    let name = format!("gamma_{a}x{b}x{c}");
    let out = execute(
        &name,
        &[(a0, a, b), (a1, a, b), (b0, b, c), (b1, b, c), (m, a, c)],
        a * c,
    )?;
    Some(from_u64_mat(a, c, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;

    fn engine_up() -> bool {
        init(Path::new("artifacts")) && active()
    }

    #[test]
    fn pjrt_gemm_matches_native_when_available() {
        if !engine_up() {
            eprintln!("skipping: no artifacts/ (run `make artifacts`)");
            return;
        }
        let mut rng = Rng::seeded(300);
        let a = Matrix::from_fn(8, 8, |_, _| rng.gen::<Z64>());
        let b = Matrix::from_fn(8, 8, |_, _| rng.gen::<Z64>());
        let via_pjrt = try_gemm(&a, &b).expect("8x8x8 artifact present");
        assert_eq!(via_pjrt, a.matmul(&b));
    }

    #[test]
    fn pjrt_masked_matmul_matches_native() {
        if !engine_up() {
            eprintln!("skipping: no artifacts/");
            return;
        }
        let mut rng = Rng::seeded(301);
        let mk = |r: &mut Rng| Matrix::from_fn(8, 8, |_, _| r.gen::<Z64>());
        let (lx, my, mx, ly, g, lz) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let via_pjrt = try_masked_matmul(&lx, &my, &mx, &ly, &g, &lz).expect("artifact");
        let native = super::super::native::masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
        assert_eq!(via_pjrt, native);
    }

    #[test]
    fn unknown_shape_falls_back() {
        if !engine_up() {
            return;
        }
        let mut rng = Rng::seeded(302);
        let a = Matrix::from_fn(9, 7, |_, _| rng.gen::<Z64>());
        let b = Matrix::from_fn(7, 5, |_, _| rng.gen::<Z64>());
        assert!(try_gemm(&a, &b).is_none());
        // the dispatcher still answers
        assert_eq!(super::super::gemm(&a, &b), a.matmul(&b));
    }

    #[test]
    fn boolean_world_never_hits_pjrt() {
        use crate::ring::Bit;
        let a = Matrix::from_fn(8, 8, |_, _| Bit(true));
        assert!(try_gemm(&a, &a).is_none());
    }
}
