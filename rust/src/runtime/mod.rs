//! Execution engine for the party-local hot path.
//!
//! The heavy local work in `Π_DotP`/`Π_MultTr` (matrix form) is:
//!
//! * `masked_matmul`: `M' = Γ + Λz − Λx∘M_y − M_x∘Λy` (online), and
//! * `gemm`: plain `A∘B` over `Z_{2^64}` (offline γ terms, `M_x∘M_y`).
//!
//! Both exist in two implementations:
//! 1. **native** — fused wrapping-u64 loops in rust (always available, used
//!    for odd shapes and the boolean world), and
//! 2. **PJRT** — the AOT artifact compiled from the L2 JAX graph calling the
//!    L1 Pallas kernel (`python/compile/`), loaded via the `xla` crate and
//!    executed on the PJRT CPU client ([`pjrt`]).
//!
//! Dispatch ([`masked_matmul`], [`gemm`]) prefers the PJRT artifact when the
//! engine is initialised and the element type is `Z64`; protocol code is
//! oblivious to the choice.

pub mod native;
pub mod pjrt;

use crate::ring::{Matrix, Ring};

/// Plain ring matrix product (dispatching).
pub fn gemm<R: Ring>(a: &Matrix<R>, b: &Matrix<R>) -> Matrix<R> {
    if pjrt::prefer_pjrt() {
        if let Some(out) = pjrt::try_gemm(a, b) {
            return out;
        }
    }
    native::gemm(a, b)
}

/// Fused online share computation
/// `M' = −Λx∘M_y − M_x∘Λy + Γ + Λz` (dispatching).
pub fn masked_matmul<R: Ring>(
    lam_x: &Matrix<R>,
    m_y: &Matrix<R>,
    m_x: &Matrix<R>,
    lam_y: &Matrix<R>,
    gamma: &Matrix<R>,
    lam_z: &Matrix<R>,
) -> Matrix<R> {
    if pjrt::prefer_pjrt() {
        if let Some(out) = pjrt::try_masked_matmul(lam_x, m_y, m_x, lam_y, gamma, lam_z) {
            return out;
        }
    }
    native::masked_matmul(lam_x, m_y, m_x, lam_y, gamma, lam_z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ring::Z64;

    #[test]
    fn dispatch_matches_native() {
        let mut rng = Rng::seeded(50);
        let a = Matrix::from_fn(7, 5, |_, _| rng.gen::<Z64>());
        let b = Matrix::from_fn(5, 9, |_, _| rng.gen::<Z64>());
        assert_eq!(gemm(&a, &b), a.matmul(&b));
    }

    #[test]
    fn masked_matmul_formula() {
        let mut rng = Rng::seeded(51);
        let n = 6;
        let mk = |rng: &mut Rng| Matrix::from_fn(n, n, |_, _| rng.gen::<Z64>());
        let (lx, my, mx, ly, g, lz) =
            (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let got = masked_matmul(&lx, &my, &mx, &ly, &g, &lz);
        let want = &(&g + &lz) - &(&lx.matmul(&my) + &mx.matmul(&ly));
        assert_eq!(got, want);
    }
}
