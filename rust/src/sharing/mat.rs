//! Matrix-valued `[[·]]`-shares — the ML hot-path representation.
//!
//! A `[[X]]` for a matrix `X` is elementwise `[[·]]`-sharing; storing it as a
//! struct-of-matrices (`m`, `λ_next`, `λ_prev` / `λ_1..3`) keeps the party's
//! local work as dense `ring::Matrix` ops, which is exactly the shape the
//! L1/L2 artifacts consume (`runtime::MaskedMatmul`).
//!
//! The component matrices **are** the wire payloads: the serving hot path
//! (`share_mat_n`, `matmul_tr_online`, `reconstruct_mat_to`, the pooled
//! wire-mask fills) reads and builds them directly through the SoA views
//! ([`MMat::m`]/[`MMat::lam`] + `Matrix::data` slices, and the public
//! variant constructors) — [`MMat::to_shares`]/[`MMat::at`] are the
//! per-element compatibility path for share-level protocols, not the
//! wave pipeline.

use crate::net::PartyId;
use crate::ring::{Matrix, Ring};
use crate::sharing::MShare;

/// Matrix-valued `[[·]]`-share (see [`MShare`] for the scalar semantics).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MMat<R> {
    Helper { lam: [Matrix<R>; 3] },
    Eval { m: Matrix<R>, lam_next: Matrix<R>, lam_prev: Matrix<R> },
}

impl<R: Ring> MMat<R> {
    pub fn zero(me: PartyId, rows: usize, cols: usize) -> Self {
        if me.is_evaluator() {
            MMat::Eval {
                m: Matrix::zeros(rows, cols),
                lam_next: Matrix::zeros(rows, cols),
                lam_prev: Matrix::zeros(rows, cols),
            }
        } else {
            MMat::Helper {
                lam: [
                    Matrix::zeros(rows, cols),
                    Matrix::zeros(rows, cols),
                    Matrix::zeros(rows, cols),
                ],
            }
        }
    }

    /// Share of a public matrix: `λ = 0`, `m = c`.
    pub fn of_public(me: PartyId, c: Matrix<R>) -> Self {
        let (rows, cols) = (c.rows(), c.cols());
        if me.is_evaluator() {
            MMat::Eval {
                m: c,
                lam_next: Matrix::zeros(rows, cols),
                lam_prev: Matrix::zeros(rows, cols),
            }
        } else {
            MMat::Helper {
                lam: [
                    Matrix::zeros(rows, cols),
                    Matrix::zeros(rows, cols),
                    Matrix::zeros(rows, cols),
                ],
            }
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            MMat::Helper { lam } => lam[0].rows(),
            MMat::Eval { m, .. } => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MMat::Helper { lam } => lam[0].cols(),
            MMat::Eval { m, .. } => m.cols(),
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    /// The masked matrix `m_X` (evaluators only).
    pub fn m(&self) -> &Matrix<R> {
        match self {
            MMat::Eval { m, .. } => m,
            MMat::Helper { .. } => panic!("P0 holds no m"),
        }
    }

    /// Mask component matrix `Λ_j` if held.
    pub fn lam(&self, me: PartyId, j: u8) -> Option<&Matrix<R>> {
        match self {
            MMat::Helper { lam } => Some(&lam[(j - 1) as usize]),
            MMat::Eval { lam_next, lam_prev, .. } => {
                if me.next_evaluator().0 == j {
                    Some(lam_next)
                } else if me.prev_evaluator().0 == j {
                    Some(lam_prev)
                } else {
                    None
                }
            }
        }
    }

    /// Extract the scalar share at (r, c).
    pub fn at(&self, r: usize, c: usize) -> MShare<R> {
        match self {
            MMat::Helper { lam } => {
                MShare::Helper { lam: [lam[0][(r, c)], lam[1][(r, c)], lam[2][(r, c)]] }
            }
            MMat::Eval { m, lam_next, lam_prev } => MShare::Eval {
                m: m[(r, c)],
                lam_next: lam_next[(r, c)],
                lam_prev: lam_prev[(r, c)],
            },
        }
    }

    /// Build from per-element scalar shares (row-major).
    pub fn from_shares(rows: usize, cols: usize, shares: &[MShare<R>]) -> Self {
        assert_eq!(shares.len(), rows * cols);
        match shares[0] {
            MShare::Helper { .. } => {
                let comp = |k: usize| {
                    Matrix::from_vec(
                        rows,
                        cols,
                        shares
                            .iter()
                            .map(|s| match s {
                                MShare::Helper { lam } => lam[k],
                                _ => panic!("mixed shares"),
                            })
                            .collect(),
                    )
                };
                MMat::Helper { lam: [comp(0), comp(1), comp(2)] }
            }
            MShare::Eval { .. } => {
                let pick = |f: fn(&MShare<R>) -> R| {
                    Matrix::from_vec(rows, cols, shares.iter().map(f).collect())
                };
                MMat::Eval {
                    m: pick(|s| match s {
                        MShare::Eval { m, .. } => *m,
                        _ => panic!("mixed shares"),
                    }),
                    lam_next: pick(|s| match s {
                        MShare::Eval { lam_next, .. } => *lam_next,
                        _ => panic!("mixed shares"),
                    }),
                    lam_prev: pick(|s| match s {
                        MShare::Eval { lam_prev, .. } => *lam_prev,
                        _ => panic!("mixed shares"),
                    }),
                }
            }
        }
    }

    /// Row-major vector of scalar shares.
    pub fn to_shares(&self) -> Vec<MShare<R>> {
        let (rows, cols) = self.dims();
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                out.push(self.at(r, c));
            }
        }
        out
    }

    /// Transpose all components.
    pub fn transpose(&self) -> Self {
        match self {
            MMat::Helper { lam } => MMat::Helper {
                lam: [lam[0].transpose(), lam[1].transpose(), lam[2].transpose()],
            },
            MMat::Eval { m, lam_next, lam_prev } => MMat::Eval {
                m: m.transpose(),
                lam_next: lam_next.transpose(),
                lam_prev: lam_prev.transpose(),
            },
        }
    }

    /// Add a public matrix (only `m` moves).
    pub fn add_public(&self, c: &Matrix<R>) -> Self {
        match self {
            MMat::Eval { m, lam_next, lam_prev } => MMat::Eval {
                m: m + c,
                lam_next: lam_next.clone(),
                lam_prev: lam_prev.clone(),
            },
            h @ MMat::Helper { .. } => h.clone(),
        }
    }

    /// Multiply by a public ring scalar.
    pub fn scale(&self, c: R) -> Self {
        self.map(|x| x.scale(c))
    }

    fn map(&self, f: impl Fn(&Matrix<R>) -> Matrix<R>) -> Self {
        match self {
            MMat::Helper { lam } => MMat::Helper { lam: [f(&lam[0]), f(&lam[1]), f(&lam[2])] },
            MMat::Eval { m, lam_next, lam_prev } => {
                MMat::Eval { m: f(m), lam_next: f(lam_next), lam_prev: f(lam_prev) }
            }
        }
    }

    fn zip(&self, o: &Self, f: impl Fn(&Matrix<R>, &Matrix<R>) -> Matrix<R>) -> Self {
        match (self, o) {
            (MMat::Helper { lam: a }, MMat::Helper { lam: b }) => {
                MMat::Helper { lam: [f(&a[0], &b[0]), f(&a[1], &b[1]), f(&a[2], &b[2])] }
            }
            (
                MMat::Eval { m: ma, lam_next: na, lam_prev: pa },
                MMat::Eval { m: mb, lam_next: nb, lam_prev: pb },
            ) => MMat::Eval { m: f(ma, mb), lam_next: f(na, nb), lam_prev: f(pa, pb) },
            _ => panic!("mixing helper and evaluator shares"),
        }
    }
}

impl<R: Ring> std::ops::Add for &MMat<R> {
    type Output = MMat<R>;
    fn add(self, rhs: Self) -> MMat<R> {
        self.zip(rhs, |a, b| a + b)
    }
}

impl<R: Ring> std::ops::Sub for &MMat<R> {
    type Output = MMat<R>;
    fn sub(self, rhs: Self) -> MMat<R> {
        self.zip(rhs, |a, b| a - b)
    }
}

/// Test helper: open a matrix sharing from all four views.
pub fn open_mat<R: Ring>(shares: &[MMat<R>; 4]) -> Matrix<R> {
    let (rows, cols) = shares[0].dims();
    let mut out = Matrix::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            out[(r, c)] = super::open(&[
                shares[0].at(r, c),
                shares[1].at(r, c),
                shares[2].at(r, c),
                shares[3].at(r, c),
            ]);
        }
    }
    out
}

/// Test helper: deal a matrix sharing with PRG masks.
pub fn deal_mat<R: Ring>(x: &Matrix<R>, rng: &mut crate::crypto::Rng) -> [MMat<R>; 4] {
    let (rows, cols) = (x.rows(), x.cols());
    let n = rows * cols;
    let shares: Vec<[MShare<R>; 4]> = x
        .data()
        .iter()
        .map(|&v| super::deal(v, [rng.gen(), rng.gen(), rng.gen()]))
        .collect();
    let pick = |i: usize| {
        MMat::from_shares(rows, cols, &shares.iter().map(|s| s[i]).collect::<Vec<_>>()[..n])
    };
    [pick(0), pick(1), pick(2), pick(3)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ring::Z64;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix<Z64> {
        Matrix::from_fn(rows, cols, |_, _| rng.gen())
    }

    #[test]
    fn deal_open_mat_roundtrip() {
        let mut rng = Rng::seeded(5);
        let x = rand_mat(&mut rng, 3, 4);
        let shares = deal_mat(&x, &mut rng);
        assert_eq!(open_mat(&shares), x);
    }

    #[test]
    fn mat_linearity() {
        let mut rng = Rng::seeded(6);
        let x = rand_mat(&mut rng, 2, 3);
        let y = rand_mat(&mut rng, 2, 3);
        let sx = deal_mat(&x, &mut rng);
        let sy = deal_mat(&y, &mut rng);
        let sum: Vec<MMat<Z64>> = (0..4).map(|i| &sx[i] + &sy[i]).collect();
        assert_eq!(open_mat(&[sum[0].clone(), sum[1].clone(), sum[2].clone(), sum[3].clone()]), &x + &y);
        let sc: Vec<MMat<Z64>> = (0..4).map(|i| sx[i].scale(Z64(7))).collect();
        assert_eq!(
            open_mat(&[sc[0].clone(), sc[1].clone(), sc[2].clone(), sc[3].clone()]),
            x.scale(Z64(7))
        );
    }

    #[test]
    fn mat_transpose_and_scalar_access() {
        let mut rng = Rng::seeded(7);
        let x = rand_mat(&mut rng, 2, 5);
        let shares = deal_mat(&x, &mut rng);
        let t: Vec<MMat<Z64>> = shares.iter().map(|s| s.transpose()).collect();
        assert_eq!(
            open_mat(&[t[0].clone(), t[1].clone(), t[2].clone(), t[3].clone()]),
            x.transpose()
        );
    }

    #[test]
    fn shares_roundtrip_scalar_vector() {
        let mut rng = Rng::seeded(8);
        let x = rand_mat(&mut rng, 3, 3);
        let shares = deal_mat(&x, &mut rng);
        for s in &shares {
            let back = MMat::from_shares(3, 3, &s.to_shares());
            assert_eq!(&back, s);
        }
    }

    #[test]
    fn add_public_only_moves_m() {
        let mut rng = Rng::seeded(9);
        let x = rand_mat(&mut rng, 2, 2);
        let c = rand_mat(&mut rng, 2, 2);
        let shares = deal_mat(&x, &mut rng);
        let added: Vec<MMat<Z64>> = shares.iter().map(|s| s.add_public(&c)).collect();
        assert_eq!(
            open_mat(&[added[0].clone(), added[1].clone(), added[2].clone(), added[3].clone()]),
            &x + &c
        );
    }
}
