//! Trident's three sharing semantics (paper §III-A), over either ring.
//!
//! * `[·]`-sharing — plain 3-way additive sharing among the evaluators
//!   `P1,P2,P3` (a bare ring element per party; no type needed).
//! * [`RShare`] — `⟨·⟩`-sharing: replicated 3-way sharing where each
//!   evaluator holds **two** of the three additive components:
//!   `⟨v⟩_{P1} = (v2,v3)`, `⟨v⟩_{P2} = (v3,v1)`, `⟨v⟩_{P3} = (v1,v2)`.
//! * [`MShare`] — `[[·]]`-sharing, the protocol's workhorse: a public-ish
//!   masked value `m_v = v + λ_v` known to the evaluators, with the mask
//!   `λ_v` ⟨·⟩-shared among them, and `P0` holding all three mask components
//!   `λ_{v,1}, λ_{v,2}, λ_{v,3}` in clear.
//!
//! Component bookkeeping follows the cyclic convention: evaluator `P_i`
//! holds components indexed `next(i)` and `prev(i)` of `{1,2,3}`
//! (`P1 → (2,3)`, `P2 → (3,1)`, `P3 → (1,2)`).
//!
//! All sharings are linear (§III-A.d): addition, subtraction, negation and
//! multiplication by public constants are local, as is adding a public
//! constant to a `[[·]]`-share (only `m_v` moves).

pub mod mat;

pub use mat::MMat;

use crate::net::PartyId;
use crate::ring::Ring;

/// `⟨·⟩`-share: the party's view of a replicated additive sharing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RShare<R> {
    /// P0's view when it knows all components (e.g. after `Π_aSh`).
    Helper { v: [R; 3] },
    /// Evaluator view: components `v_{next(i)}` and `v_{prev(i)}`.
    Eval { next: R, prev: R },
}

impl<R: Ring> RShare<R> {
    /// The component `v_j` if this view holds it. `j ∈ {1,2,3}`.
    pub fn component(&self, me: PartyId, j: u8) -> Option<R> {
        debug_assert!((1..=3).contains(&j));
        match self {
            RShare::Helper { v } => Some(v[(j - 1) as usize]),
            RShare::Eval { next, prev } => {
                if me.next_evaluator().0 == j {
                    Some(*next)
                } else if me.prev_evaluator().0 == j {
                    Some(*prev)
                } else {
                    None
                }
            }
        }
    }

    /// Convert `⟨v⟩` into `[[v]]` locally by setting `m_v = 0` and
    /// `⟨λ_v⟩ = −⟨v⟩` (used by `Π_Bit2A`, `Π_MultTr`, `Π_BitInj`).
    pub fn into_mshare(self) -> MShare<R> {
        match self {
            RShare::Helper { v } => MShare::Helper { lam: [-v[0], -v[1], -v[2]] },
            RShare::Eval { next, prev } => {
                MShare::Eval { m: R::ZERO, lam_next: -next, lam_prev: -prev }
            }
        }
    }
}

/// `[[·]]`-share: the party's view of a masked sharing (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MShare<R> {
    /// P0: all three mask components `(λ_{v,1}, λ_{v,2}, λ_{v,3})`.
    Helper { lam: [R; 3] },
    /// Evaluator `P_i`: `m_v` plus `λ_{v,next(i)}`, `λ_{v,prev(i)}`.
    Eval { m: R, lam_next: R, lam_prev: R },
}

impl<R: Ring> MShare<R> {
    /// The all-zero share of the public constant 0.
    pub fn zero(me: PartyId) -> Self {
        Self::of_public(me, R::ZERO)
    }

    /// Non-interactive share of a public constant: `λ = 0`, `m = c`
    /// (the `Π_vSh(P1,P2,P3, v)` degenerate case of §IV-B.a).
    pub fn of_public(me: PartyId, c: R) -> Self {
        if me.is_evaluator() {
            MShare::Eval { m: c, lam_next: R::ZERO, lam_prev: R::ZERO }
        } else {
            MShare::Helper { lam: [R::ZERO; 3] }
        }
    }

    /// The masked value `m_v` (evaluators only).
    pub fn m(&self) -> R {
        match self {
            MShare::Eval { m, .. } => *m,
            MShare::Helper { .. } => panic!("P0 holds no m_v"),
        }
    }

    /// Mask component `λ_{v,j}` if held.
    pub fn lam(&self, me: PartyId, j: u8) -> Option<R> {
        debug_assert!((1..=3).contains(&j));
        match self {
            MShare::Helper { lam } => Some(lam[(j - 1) as usize]),
            MShare::Eval { lam_next, lam_prev, .. } => {
                if me.next_evaluator().0 == j {
                    Some(*lam_next)
                } else if me.prev_evaluator().0 == j {
                    Some(*lam_prev)
                } else {
                    None
                }
            }
        }
    }

    /// Add a public constant: `[[v + c]]` (only `m` moves; P0 unchanged).
    pub fn add_const(&self, c: R) -> Self {
        match *self {
            MShare::Eval { m, lam_next, lam_prev } => {
                MShare::Eval { m: m + c, lam_next, lam_prev }
            }
            h @ MShare::Helper { .. } => h,
        }
    }

    /// Multiply by a public constant (all components scale).
    pub fn scale(&self, c: R) -> Self {
        self.map(|v| c * v)
    }

    fn map(&self, f: impl Fn(R) -> R) -> Self {
        match *self {
            MShare::Helper { lam } => MShare::Helper { lam: [f(lam[0]), f(lam[1]), f(lam[2])] },
            MShare::Eval { m, lam_next, lam_prev } => {
                MShare::Eval { m: f(m), lam_next: f(lam_next), lam_prev: f(lam_prev) }
            }
        }
    }

    fn zip(&self, o: &Self, f: impl Fn(R, R) -> R) -> Self {
        match (*self, *o) {
            (MShare::Helper { lam: a }, MShare::Helper { lam: b }) => {
                MShare::Helper { lam: [f(a[0], b[0]), f(a[1], b[1]), f(a[2], b[2])] }
            }
            (
                MShare::Eval { m: ma, lam_next: na, lam_prev: pa },
                MShare::Eval { m: mb, lam_next: nb, lam_prev: pb },
            ) => MShare::Eval { m: f(ma, mb), lam_next: f(na, nb), lam_prev: f(pa, pb) },
            _ => panic!("mixing helper and evaluator shares"),
        }
    }
}

impl<R: Ring> std::ops::Add for MShare<R> {
    type Output = MShare<R>;
    fn add(self, rhs: Self) -> Self {
        self.zip(&rhs, |a, b| a + b)
    }
}

impl<R: Ring> std::ops::Sub for MShare<R> {
    type Output = MShare<R>;
    fn sub(self, rhs: Self) -> Self {
        self.zip(&rhs, |a, b| a - b)
    }
}

impl<R: Ring> std::ops::Neg for MShare<R> {
    type Output = MShare<R>;
    fn neg(self) -> Self {
        self.map(|v| -v)
    }
}

/// Test/debug helper: open a `[[·]]`-sharing given all four views.
/// `v = m_v − λ_{v,1} − λ_{v,2} − λ_{v,3}`.
pub fn open<R: Ring>(shares: &[MShare<R>; 4]) -> R {
    let lam = match shares[0] {
        MShare::Helper { lam } => lam,
        _ => panic!("shares[0] must be P0's"),
    };
    // cross-check evaluator mask components against P0's
    for (i, s) in shares.iter().enumerate().skip(1) {
        let me = PartyId(i as u8);
        for j in 1..=3u8 {
            if let Some(l) = s.lam(me, j) {
                assert_eq!(l, lam[(j - 1) as usize], "λ_{j} mismatch at P{i}");
            }
        }
    }
    let m = shares[1].m();
    assert_eq!(m, shares[2].m(), "m mismatch P1/P2");
    assert_eq!(m, shares[3].m(), "m mismatch P1/P3");
    m - lam[0] - lam[1] - lam[2]
}

/// Test/debug helper: deal a `[[·]]`-sharing of `v` from explicit masks.
pub fn deal<R: Ring>(v: R, lam: [R; 3]) -> [MShare<R>; 4] {
    let m = v + lam[0] + lam[1] + lam[2];
    [
        MShare::Helper { lam },
        MShare::Eval { m, lam_next: lam[1], lam_prev: lam[2] }, // P1: λ2, λ3
        MShare::Eval { m, lam_next: lam[2], lam_prev: lam[0] }, // P2: λ3, λ1
        MShare::Eval { m, lam_next: lam[0], lam_prev: lam[1] }, // P3: λ1, λ2
    ]
}

/// Test/debug helper: open a `⟨·⟩`-sharing from the three evaluator views.
pub fn open_rss<R: Ring>(shares: &[RShare<R>; 3]) -> R {
    // P1 = (v2,v3), P2 = (v3,v1), P3 = (v1,v2); cross-check replicas.
    let (v2, v3a) = match shares[0] {
        RShare::Eval { next, prev } => (next, prev),
        _ => panic!("evaluator share expected"),
    };
    let (v3b, v1a) = match shares[1] {
        RShare::Eval { next, prev } => (next, prev),
        _ => panic!("evaluator share expected"),
    };
    let (v1b, v2b) = match shares[2] {
        RShare::Eval { next, prev } => (next, prev),
        _ => panic!("evaluator share expected"),
    };
    assert_eq!(v3a, v3b, "v3 replica mismatch");
    assert_eq!(v1a, v1b, "v1 replica mismatch");
    assert_eq!(v2, v2b, "v2 replica mismatch");
    v1a + v2 + v3a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::net::{P1, P2, P3};
    use crate::ring::{Bit, Z64};

    #[test]
    fn deal_open_roundtrip() {
        let mut rng = Rng::seeded(1);
        for _ in 0..20 {
            let v: Z64 = rng.gen();
            let lam = [rng.gen(), rng.gen(), rng.gen()];
            assert_eq!(open(&deal(v, lam)), v);
        }
    }

    #[test]
    fn linearity_add_sub_scale() {
        let mut rng = Rng::seeded(2);
        let x: Z64 = rng.gen();
        let y: Z64 = rng.gen();
        let c: Z64 = rng.gen();
        let lx = [rng.gen(), rng.gen(), rng.gen()];
        let ly = [rng.gen(), rng.gen(), rng.gen()];
        let sx = deal(x, lx);
        let sy = deal(y, ly);
        let sum: Vec<_> = (0..4).map(|i| sx[i] + sy[i]).collect();
        assert_eq!(open(&[sum[0], sum[1], sum[2], sum[3]]), x + y);
        let dif: Vec<_> = (0..4).map(|i| sx[i] - sy[i]).collect();
        assert_eq!(open(&[dif[0], dif[1], dif[2], dif[3]]), x - y);
        let sc: Vec<_> = (0..4).map(|i| sx[i].scale(c)).collect();
        assert_eq!(open(&[sc[0], sc[1], sc[2], sc[3]]), c * x);
        let ac: Vec<_> = (0..4).map(|i| sx[i].add_const(c)).collect();
        assert_eq!(open(&[ac[0], ac[1], ac[2], ac[3]]), x + c);
        let neg: Vec<_> = (0..4).map(|i| -sx[i]).collect();
        assert_eq!(open(&[neg[0], neg[1], neg[2], neg[3]]), -x);
    }

    #[test]
    fn boolean_world_linearity() {
        // in Z_2 the same algebra is XOR
        let lam = [Bit(true), Bit(false), Bit(true)];
        let s = deal(Bit(true), lam);
        assert_eq!(open(&s), Bit(true));
        let flipped: Vec<_> = (0..4).map(|i| s[i].add_const(Bit(true))).collect();
        assert_eq!(open(&[flipped[0], flipped[1], flipped[2], flipped[3]]), Bit(false));
    }

    #[test]
    fn lam_component_visibility() {
        let s = deal(Z64(5), [Z64(10), Z64(20), Z64(30)]);
        // P1 holds λ2, λ3 but not λ1
        assert_eq!(s[1].lam(P1, 2), Some(Z64(20)));
        assert_eq!(s[1].lam(P1, 3), Some(Z64(30)));
        assert_eq!(s[1].lam(P1, 1), None);
        // P2 holds λ3, λ1
        assert_eq!(s[2].lam(P2, 3), Some(Z64(30)));
        assert_eq!(s[2].lam(P2, 1), Some(Z64(10)));
        assert_eq!(s[2].lam(P2, 2), None);
        // P3 holds λ1, λ2
        assert_eq!(s[3].lam(P3, 1), Some(Z64(10)));
        assert_eq!(s[3].lam(P3, 2), Some(Z64(20)));
        assert_eq!(s[3].lam(P3, 3), None);
        // P0 holds all
        for j in 1..=3 {
            assert!(s[0].lam(crate::net::P0, j).is_some());
        }
    }

    #[test]
    fn rss_open_and_convert() {
        let v = [Z64(100), Z64(200), Z64(300)];
        let shares = [
            RShare::Eval { next: v[1], prev: v[2] }, // P1: (v2, v3)
            RShare::Eval { next: v[2], prev: v[0] }, // P2: (v3, v1)
            RShare::Eval { next: v[0], prev: v[1] }, // P3: (v1, v2)
        ];
        assert_eq!(open_rss(&shares), Z64(600));
        // ⟨v⟩ → [[v]] with m=0, λ=−v opens back to v
        let m0 = RShare::Helper { v }.into_mshare();
        let m1 = shares[0].into_mshare();
        let m2 = shares[1].into_mshare();
        let m3 = shares[2].into_mshare();
        assert_eq!(open(&[m0, m1, m2, m3]), Z64(600));
    }

    #[test]
    fn rss_component_access() {
        let sh = RShare::Eval { next: Z64(7), prev: Z64(9) };
        assert_eq!(sh.component(P2, 3), Some(Z64(7)));
        assert_eq!(sh.component(P2, 1), Some(Z64(9)));
        assert_eq!(sh.component(P2, 2), None);
    }

    #[test]
    #[should_panic(expected = "m mismatch")]
    fn open_detects_inconsistent_m() {
        let mut s = deal(Z64(5), [Z64(1), Z64(2), Z64(3)]);
        if let MShare::Eval { ref mut m, .. } = s[2] {
            *m += Z64(1);
        }
        let _ = open(&s);
    }
}
