//! Secure linear regression (paper §VI-A.a): mini-batch gradient descent
//! entirely in the arithmetic world —
//! `w ← w − (α/B)·Xᵀ∘(X∘w − y)` — two `Π_MatMulTr` per iteration, so the
//! online cost is `3(B + d)` ring elements and 2 rounds regardless of the
//! feature count (the dot-product property).

use crate::net::Abort;
use crate::proto::{matmul_tr, matmul_tr_shift, Ctx};
use crate::ring::fixed::FRAC_BITS;
use crate::ring::Z64;
use crate::sharing::MMat;

use super::nn::{train_step, HeadActivation, TrainLayerKeys, TrainStepOut};

/// Linear-regression trainer configuration.
#[derive(Copy, Clone, Debug)]
pub struct LinReg {
    pub d: usize,
    pub batch: usize,
    /// learning rate = 2^{−lr_pow} (α/B folded into the truncation:
    /// effective shift = FRAC_BITS + lr_pow + log2(batch)).
    pub lr_pow: u32,
}

impl LinReg {
    pub fn new(d: usize, batch: usize) -> LinReg {
        LinReg { d, batch, lr_pow: 7 }
    }

    /// Shift for the gradient matmul: divides by `2^{lr_pow}·B`. Public so
    /// the scheduler can mint this trainer's gradient gate key.
    pub fn grad_shift(&self) -> u32 {
        FRAC_BITS + self.lr_pow + (self.batch as f64).log2().round() as u32
    }

    /// Forward pass: `[[u]] = [[X ∘ w]]` (truncated).
    pub fn forward(
        &self,
        ctx: &mut Ctx,
        x: &MMat<Z64>,
        w: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        matmul_tr(ctx, x, w)
    }

    /// One GD iteration; returns the updated weight share.
    pub fn train_iteration(
        &self,
        ctx: &mut Ctx,
        w: &MMat<Z64>,
        x: &MMat<Z64>,
        y: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        let u = self.forward(ctx, x, w)?;
        let e = &u - y;
        let xt = x.transpose();
        let grad = matmul_tr_shift(ctx, &xt, &e, self.grad_shift())?;
        Ok(w - &grad)
    }

    /// One **scheduled** GD iteration through the circuit-keyed pool: the
    /// one-layer case of [`train_step`] (linear head), so a warm epoch's
    /// forward and gradient gates are both offline-silent.
    pub fn train_step_keyed(
        &self,
        ctx: &mut Ctx,
        w: &MMat<Z64>,
        keys: &[TrainLayerKeys],
        x: &MMat<Z64>,
        y: &MMat<Z64>,
    ) -> Result<TrainStepOut, Abort> {
        train_step(
            ctx,
            std::slice::from_ref(w),
            HeadActivation::Linear,
            self.grad_shift(),
            Some(keys),
            x,
            y,
        )
    }

    /// Prediction = forward pass.
    pub fn predict(
        &self,
        ctx: &mut Ctx,
        x: &MMat<Z64>,
        w: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        self.forward(ctx, x, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ml::data::linreg_batch;
    use crate::ml::share_fixed_mat;
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::run_4pc;
    use crate::ring::FixedPoint;
    use crate::sharing::mat::open_mat;

    #[test]
    fn secure_linreg_converges() {
        // train on a fixed batch; the residual must drop substantially
        let run = run_4pc(NetProfile::zero(), 210, |ctx| {
            let mut rng = Rng::seeded(77);
            let batch = linreg_batch(&mut rng, 32, 8);
            let model = LinReg { d: 8, batch: 32, lr_pow: 2 };
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.x), 32, 8)?;
            let ys = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&batch.y), 32, 1)?;
            let zeros = crate::ml::F64Mat::zeros(8, 1);
            let mut w =
                share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&zeros), 8, 1)?;
            for _ in 0..60 {
                w = model.train_iteration(ctx, &w, &xs, &ys)?;
            }
            let u = model.predict(ctx, &xs, &w)?;
            ctx.flush_verify()?;
            Ok((w, u, batch))
        });
        let (outs, _) = run.expect_ok();
        let (w0, u0, batch) = (&outs[0].0, &outs[0].1, &outs[1].2);
        let w_open = open_mat(&[
            w0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        let u_open = open_mat(&[
            u0.clone(),
            outs[1].1.clone(),
            outs[2].1.clone(),
            outs[3].1.clone(),
        ]);
        // residual ‖u − y‖ should be small after training
        let mut mse = 0.0;
        for i in 0..32 {
            let pred = FixedPoint::decode(u_open[(i, 0)]);
            let diff = pred - batch.y.at(i, 0);
            mse += diff * diff;
        }
        mse /= 32.0;
        assert!(mse < 0.05, "mse after training = {mse}");
        // learned weights approach the teacher
        let mut werr = 0.0;
        for j in 0..8 {
            werr += (FixedPoint::decode(w_open[(j, 0)]) - batch.w_true[j]).abs();
        }
        assert!(werr / 8.0 < 0.2, "avg weight error {werr}");
    }

    #[test]
    fn secure_matches_plaintext_fixed_point() {
        // one iteration secure vs the same iteration in cleartext fixed point
        let run = run_4pc(NetProfile::zero(), 211, |ctx| {
            let mut rng = Rng::seeded(78);
            let batch = linreg_batch(&mut rng, 16, 4);
            let model = LinReg { d: 4, batch: 16, lr_pow: 3 };
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.x), 16, 4)?;
            let ys = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.y), 16, 1)?;
            let mut init = crate::ml::F64Mat::zeros(4, 1);
            for j in 0..4 {
                init.set(j, 0, 0.1 * j as f64);
            }
            let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&init), 4, 1)?;
            let w1 = model.train_iteration(ctx, &w, &xs, &ys)?;
            ctx.flush_verify()?;
            Ok((w1, batch, init))
        });
        let (outs, _) = run.expect_ok();
        let (batch, init) = (&outs[1].1, &outs[1].2);
        let w_open = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        // plaintext float reference
        let mut w_ref = init.clone();
        let lr = 1.0 / (8.0 * 16.0); // 2^-3 / B
        let mut u = vec![0.0; 16];
        for i in 0..16 {
            for j in 0..4 {
                u[i] += batch.x.at(i, j) * w_ref.at(j, 0);
            }
        }
        for j in 0..4 {
            let mut g = 0.0;
            for i in 0..16 {
                g += batch.x.at(i, j) * (u[i] - batch.y.at(i, 0));
            }
            w_ref.set(j, 0, w_ref.at(j, 0) - lr * g);
        }
        for j in 0..4 {
            let secure = FixedPoint::decode(w_open[(j, 0)]);
            assert!(
                (secure - w_ref.at(j, 0)).abs() < 0.01,
                "w[{j}]: secure {secure} vs plain {}",
                w_ref.at(j, 0)
            );
        }
    }

    #[test]
    fn per_iteration_online_cost_flat_in_d() {
        // online bits per iteration = 3(B + d)·64 — the Table IV driver
        let mut costs = Vec::new();
        for d in [4usize, 32] {
            let run = run_4pc(NetProfile::zero(), 212, move |ctx| {
                let mut rng = Rng::seeded(79);
                let batch = linreg_batch(&mut rng, 8, d);
                let model = LinReg { d, batch: 8, lr_pow: 3 };
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.x), 8, d)?;
                let ys =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.y), 8, 1)?;
                let zeros = crate::ml::F64Mat::zeros(d, 1);
                let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&zeros), d, 1)?;
                let report_before = ();
                let w2 = model.train_iteration(ctx, &w, &xs, &ys)?;
                ctx.flush_verify()?;
                let _ = (report_before, w2);
                Ok(())
            });
            let (_, report) = run.expect_ok();
            // subtract input-sharing cost (2 copies of X, y, w)
            let inputs = 2 * (8 * d + 8 + d) as u64 * 64;
            costs.push((d, report.value_bits[1] - inputs));
        }
        // cost(d) = 3(B + d)·64 → difference between d=32 and d=4 is 3·28·64
        let delta = costs[1].1 - costs[0].1;
        assert_eq!(delta, 3 * 28 * 64, "costs: {costs:?}");
    }
}
