//! Synthetic datasets with the shapes of the paper's benchmarks
//! (DESIGN.md §3: the Kaggle/MNIST data is not available offline; the
//! throughput/latency tables depend only on `(d, B)` and the accuracy claim
//! is replaced by secure-vs-plaintext equivalence tests).

use crate::crypto::Rng;

use super::F64Mat;

/// Dataset shapes from §VI ("Datasets" table).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Candy Power Ranking — 13 features, 85 samples (logistic)
    Candy,
    /// Boston Housing — 14 features, 506 samples (linear)
    Boston,
    /// Weather WW2 — 31 features, ~119k samples (linear)
    Weather,
    /// CalCOFI — 74 features, ~876k samples (linear)
    CalCofi,
    /// Epileptic Seizures — 179 features, ~11.5k samples (logistic)
    Epileptic,
    /// Food Recipes — 680 features, ~20k samples (logistic)
    Recipes,
    /// MNIST — 784 features, 70k samples (NN/CNN + regressions)
    Mnist,
}

impl Shape {
    pub fn features(self) -> usize {
        match self {
            Shape::Candy => 13,
            Shape::Boston => 14,
            Shape::Weather => 31,
            Shape::CalCofi => 74,
            Shape::Epileptic => 179,
            Shape::Recipes => 680,
            Shape::Mnist => 784,
        }
    }

    pub fn samples(self) -> usize {
        match self {
            Shape::Candy => 85,
            Shape::Boston => 506,
            Shape::Weather => 119_000,
            Shape::CalCofi => 876_000,
            Shape::Epileptic => 11_500,
            Shape::Recipes => 20_000,
            Shape::Mnist => 70_000,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Shape::Candy => "CD",
            Shape::Boston => "BT",
            Shape::Weather => "WR",
            Shape::CalCofi => "CI",
            Shape::Epileptic => "EP",
            Shape::Recipes => "RE",
            Shape::Mnist => "MNIST",
        }
    }
}

/// A regression batch: features `x` (B×d, values in [0,1)-ish) and targets
/// `y` (B×1).
pub struct Batch {
    pub x: F64Mat,
    pub y: F64Mat,
    /// The ground-truth weights the generator used (for convergence tests).
    pub w_true: Vec<f64>,
}

/// Linear-regression batch: `y = X·w* + ε`, `ε ~ N(0, 0.01)`.
pub fn linreg_batch(rng: &mut Rng, batch: usize, d: usize) -> Batch {
    let w_true: Vec<f64> = (0..d).map(|_| rng.normal() * 0.5).collect();
    let mut x = F64Mat::zeros(batch, d);
    let mut y = F64Mat::zeros(batch, 1);
    for i in 0..batch {
        let mut acc = 0.0;
        for j in 0..d {
            let v = rng.uniform();
            x.set(i, j, v);
            acc += v * w_true[j];
        }
        y.set(i, 0, acc + rng.normal() * 0.01);
    }
    Batch { x, y, w_true }
}

/// Logistic-regression batch: `y = 1[X·w* + ε > 0]`.
pub fn logreg_batch(rng: &mut Rng, batch: usize, d: usize) -> Batch {
    let w_true: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut x = F64Mat::zeros(batch, d);
    let mut y = F64Mat::zeros(batch, 1);
    for i in 0..batch {
        let mut acc = 0.0;
        for j in 0..d {
            let v = rng.uniform() - 0.5;
            x.set(i, j, v);
            acc += v * w_true[j];
        }
        y.set(i, 0, if acc + rng.normal() * 0.05 > 0.0 { 1.0 } else { 0.0 });
    }
    Batch { x, y, w_true }
}

/// MNIST-shaped classification batch: `d` pixel features in [0,1), one-hot
/// labels over `classes` derived from a random linear teacher.
pub struct ClassBatch {
    pub x: F64Mat,
    /// one-hot targets, B×classes
    pub t: F64Mat,
}

pub fn class_batch(rng: &mut Rng, batch: usize, d: usize, classes: usize) -> ClassBatch {
    let teacher: Vec<f64> = (0..d * classes).map(|_| rng.normal() * 0.1).collect();
    let mut x = F64Mat::zeros(batch, d);
    let mut t = F64Mat::zeros(batch, classes);
    for i in 0..batch {
        for j in 0..d {
            x.set(i, j, rng.uniform());
        }
        // argmax of teacher logits
        let mut best = 0usize;
        let mut best_v = f64::MIN;
        for c in 0..classes {
            let mut acc = 0.0;
            for j in 0..d {
                acc += x.at(i, j) * teacher[c * d + j];
            }
            if acc > best_v {
                best_v = acc;
                best = c;
            }
        }
        t.set(i, best, 1.0);
    }
    ClassBatch { x, t }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_table() {
        assert_eq!(Shape::Mnist.features(), 784);
        assert_eq!(Shape::Boston.features(), 14);
        assert_eq!(Shape::Recipes.features(), 680);
        assert_eq!(Shape::CalCofi.samples(), 876_000);
    }

    #[test]
    fn linreg_batch_is_consistent() {
        let mut rng = Rng::seeded(200);
        let b = linreg_batch(&mut rng, 32, 10);
        assert_eq!(b.x.rows, 32);
        assert_eq!(b.x.cols, 10);
        // y ≈ Xw*
        for i in 0..32 {
            let mut acc = 0.0;
            for j in 0..10 {
                acc += b.x.at(i, j) * b.w_true[j];
            }
            assert!((b.y.at(i, 0) - acc).abs() < 0.1);
        }
    }

    #[test]
    fn logreg_labels_binary() {
        let mut rng = Rng::seeded(201);
        let b = logreg_batch(&mut rng, 64, 13);
        assert!(b.y.data.iter().all(|&v| v == 0.0 || v == 1.0));
        // not degenerate
        let ones: f64 = b.y.data.iter().sum();
        assert!(ones > 5.0 && ones < 59.0, "ones = {ones}");
    }

    #[test]
    fn class_batch_one_hot() {
        let mut rng = Rng::seeded(202);
        let b = class_batch(&mut rng, 16, 20, 10);
        for i in 0..16 {
            let row: f64 = (0..10).map(|c| b.t.at(i, c)).sum();
            assert_eq!(row, 1.0);
        }
    }
}
