//! MPC-friendly softmax (paper §VI-A.c):
//! `smx(u_i) = relu(u_i) / Σ_j relu(u_j)`, with the division performed in
//! the **garbled world** — arithmetic shares are converted with `Π_A2G`,
//! P0 evaluates a restoring-divider circuit, and `Π_G2A` brings the
//! fixed-point quotient back.

use crate::convert::garbled::{a2g, g2a};
use crate::gc::circuit::safe_divider;
use crate::gc::g_eval;
use crate::net::Abort;
use crate::proto::Ctx;
use crate::ring::fixed::FRAC_BITS;
use crate::ring::{FixedPoint, Z64};
use crate::sharing::MShare;

use super::activation::relu_many;

/// Softmax over one score vector. Returns fixed-point probabilities
/// (summing to ≈1). Heavy: one garbled 64-bit divider per class
/// (~16k AND gates each) — the paper pays the same (§VI-A.c).
///
/// **Zero-denominator contract.** When every score is non-positive, each
/// `relu(u_i)` — and with it `Σ relu(u_j)` — is zero, and a bare restoring
/// divider would emit garbage on `0/0`. The divider here is
/// [`safe_divider`]: a garbled comparator tests the shared denominator for
/// zero *inside the circuit* and muxes in the constant `1/n`, so an
/// all-negative score vector decodes to the **uniform distribution** and
/// the zero-denominator test is never revealed to any party.
pub fn softmax_garbled(
    ctx: &mut Ctx,
    scores: &[MShare<Z64>],
) -> Result<Vec<MShare<Z64>>, Abort> {
    let n = scores.len();
    // numerators: relu(u_i), denominator: Σ relu(u_j) (local addition)
    let (relu, _) = relu_many(ctx, scores)?;
    let mut denom = MShare::zero(ctx.id());
    for r in &relu {
        denom = denom + *r;
    }
    // fixed-point quotient: (relu_i · 2^f) / denom, with the in-circuit
    // D = 0 fallback fixed to the uniform probability 1/n
    let div = safe_divider(64, FixedPoint::encode(1.0 / n as f64).0);
    let denom_g = a2g(ctx, &denom)?;
    let mut out = Vec::with_capacity(n);
    for r in &relu {
        let num = r.scale(Z64(1u64 << FRAC_BITS));
        let num_g = a2g(ctx, &num)?;
        let mut inputs = num_g;
        inputs.extend(denom_g.iter().cloned());
        let q_g = g_eval(ctx, &div, &inputs)?;
        out.push(g2a(ctx, &q_g)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1};
    use crate::proto::{run_4pc, share};
    use crate::ring::FixedPoint;
    use crate::sharing::open;

    #[test]
    fn softmax_normalizes_and_orders() {
        let run = run_4pc(NetProfile::zero(), 600, |ctx| {
            let vals = [2.0f64, -1.0, 1.0];
            let mut shares = Vec::new();
            for v in vals {
                shares.push(share(
                    ctx,
                    P1,
                    (ctx.id() == P1).then_some(FixedPoint::encode(v)),
                )?);
            }
            let p = softmax_garbled(ctx, &shares)?;
            ctx.flush_verify()?;
            Ok(p)
        });
        let (outs, _) = run.expect_ok();
        let probs: Vec<f64> = (0..3)
            .map(|i| {
                FixedPoint::decode(open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]))
            })
            .collect();
        // relu(-1) = 0 → p1 = 0; p0 = 2/3; p2 = 1/3
        assert!((probs[0] - 2.0 / 3.0).abs() < 0.01, "{probs:?}");
        assert!(probs[1].abs() < 0.01, "{probs:?}");
        assert!((probs[2] - 1.0 / 3.0).abs() < 0.01, "{probs:?}");
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 0.02, "sum {total}");
    }

    #[test]
    fn softmax_all_negative_scores_is_uniform() {
        // regression: every relu(u_i) = 0 → Σ relu = 0, and the old bare
        // restoring divider fed 0/0 through undefined behavior; the safe
        // divider's in-circuit comparator must yield the uniform 1/n
        let run = run_4pc(NetProfile::zero(), 601, |ctx| {
            let vals = [-2.0f64, -0.5, -1.0];
            let mut shares = Vec::new();
            for v in vals {
                shares.push(share(
                    ctx,
                    P1,
                    (ctx.id() == P1).then_some(FixedPoint::encode(v)),
                )?);
            }
            let p = softmax_garbled(ctx, &shares)?;
            ctx.flush_verify()?;
            Ok(p)
        });
        let (outs, _) = run.expect_ok();
        for i in 0..3 {
            let p =
                FixedPoint::decode(open(&[outs[0][i], outs[1][i], outs[2][i], outs[3][i]]));
            assert!((p - 1.0 / 3.0).abs() < 0.01, "class {i}: {p} (want uniform 1/3)");
        }
    }
}
