//! Secure neural networks (paper §VI-A.c): fully-connected layers with ReLU
//! activations, trained by mini-batch gradient descent over shares.
//!
//! * **NN** — the paper's 784-128-128-10 network.
//! * **CNN** — per §VI-A.c the running time is *overestimated* "by replacing
//!   the convolutional kernel with a fully connected layer": we model the
//!   conv stage as its FC-equivalent expansion (784 → 5·24·24 = 2880
//!   neurons), then the paper's 100 and 10-node layers.
//!
//! Output layer: the paper's MPC-friendly softmax divides by `Σ relu(u)`
//! through a garbled division circuit. For training we use the standard
//! identity that the gradient only needs `E_m = A_m − T`; we take
//! `A_m = U_m` (linear output + squared loss), which trains to the same
//! argmax-accuracy. The faithful garbled-division softmax is implemented in
//! `ml::softmax::softmax_garbled` (A2G → restoring divider → G2A) and
//! exercised by its tests and `examples/mixed_world.rs` (DESIGN.md §3).

use crate::convert::bit2a::{bitinj_many, bitinj_online};
use crate::net::{Abort, Phase};
use crate::obs::Window;
use crate::pool::CircuitKey;
use crate::proto::dotp::pop_keyed;
use crate::proto::sharing::remask_mat;
use crate::proto::trunc::matmul_tr_online;
use crate::proto::{matmul_tr, matmul_tr_keyed, matmul_tr_keyed_shared, matmul_tr_shift, Ctx};
use crate::ring::fixed::FRAC_BITS;
use crate::ring::{Bit, Matrix, Z64};
use crate::sharing::{MMat, MShare};

use super::activation::{relu_mat, relu_mat_keyed, sigmoid_many};
use super::F64Mat;

/// Which benchmark network (Table VI).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum NetworkKind {
    /// 784-128-128-10 (two ReLU hidden layers)
    Nn,
    /// conv-as-FC overestimate: 784-2880-100-10
    Cnn,
}

/// A fully-connected network configuration.
#[derive(Clone, Debug)]
pub struct Network {
    /// Layer widths, input first.
    pub layers: Vec<usize>,
    pub batch: usize,
    pub lr_pow: u32,
}

impl Network {
    pub fn new(kind: NetworkKind, batch: usize) -> Network {
        let layers = match kind {
            NetworkKind::Nn => vec![784, 128, 128, 10],
            NetworkKind::Cnn => vec![784, 2880, 100, 10],
        };
        Network::custom(layers, batch, 7)
    }

    /// Small custom network (tests). The batch must be a power of two: the
    /// `α/B` gradient scaling is implemented as a probabilistic ring
    /// truncation by `lr_pow + log2(B)` bits ([`Network::grad_shift`]),
    /// which only divides exactly by powers of two — any other batch would
    /// silently train at a mis-scaled learning rate.
    pub fn custom(layers: Vec<usize>, batch: usize, lr_pow: u32) -> Network {
        assert!(
            batch.is_power_of_two(),
            "batch {batch} is not a power of two: the 1/B gradient scale is a ring shift"
        );
        Network { layers, batch, lr_pow }
    }

    /// Shift of the gradient matmuls: the `α/B` scaling folded into the
    /// free truncation. Public because the scheduler needs it to mint the
    /// training gate keys ([`TrainLayerKeys`]) for a resident tenant.
    pub fn grad_shift(&self) -> u32 {
        // exact by the power-of-two batch invariant enforced at construction
        FRAC_BITS + self.lr_pow + self.batch.trailing_zeros()
    }

    /// Xavier-ish random init (cleartext, to be shared by a data owner).
    pub fn init_weights_clear(&self, rng: &mut crate::crypto::Rng) -> Vec<F64Mat> {
        self.layers
            .windows(2)
            .map(|w| {
                let (fan_in, fan_out) = (w[0], w[1]);
                let scale = (2.0 / fan_in as f64).sqrt();
                let mut m = F64Mat::zeros(fan_in, fan_out);
                for v in m.data.iter_mut() {
                    *v = rng.normal() * scale;
                }
                m
            })
            .collect()
    }

    /// Share the initial weights from `dealer`.
    pub fn share_weights(
        &self,
        ctx: &mut Ctx,
        dealer: crate::net::PartyId,
        clear: Option<&[F64Mat]>,
    ) -> Result<Vec<MMat<Z64>>, Abort> {
        let mut out = Vec::new();
        for (i, w) in self.layers.windows(2).enumerate() {
            let m = clear.map(|c| &c[i]);
            out.push(super::share_fixed_mat(ctx, dealer, m, w[0], w[1])?);
        }
        Ok(out)
    }

    /// Forward pass. Returns per-layer activations `A_i` (A_0 = X) and the
    /// drelu bits of every hidden layer.
    #[allow(clippy::type_complexity)]
    pub fn forward(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
    ) -> Result<(Vec<MMat<Z64>>, Vec<Vec<MShare<Bit>>>), Abort> {
        let mut acts = vec![x.clone()];
        let mut drelus = Vec::new();
        for (i, w) in weights.iter().enumerate() {
            let u = matmul_tr(ctx, acts.last().unwrap(), w)?;
            if i + 1 < weights.len() {
                let (a, d) = relu_mat(ctx, &u)?;
                acts.push(a);
                drelus.push(d);
            } else {
                // output layer: linear scores (see module docs on softmax)
                acts.push(u);
            }
        }
        Ok((acts, drelus))
    }

    /// One training iteration (forward + backward + update). Returns the
    /// updated weights.
    pub fn train_iteration(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
        t: &MMat<Z64>,
    ) -> Result<Vec<MMat<Z64>>, Abort> {
        let (acts, drelus) = self.forward(ctx, weights, x)?;
        let m = weights.len();
        // E_m = A_m − T
        let mut e = &acts[m] - t;
        let mut new_weights = weights.to_vec();
        for i in (0..m).rev() {
            // W_i ← W_i − (α/B)·A_i^T ∘ E
            let at = acts[i].transpose();
            let grad = matmul_tr_shift(ctx, &at, &e, self.grad_shift())?;
            new_weights[i] = &weights[i] - &grad;
            if i > 0 {
                // E_{i-1} = (E ∘ W_i^T) ⊗ drelu(U_{i-1})
                let wt = weights[i].transpose();
                let back = matmul_tr(ctx, &e, &wt)?;
                let (rows, cols) = back.dims();
                let gated = crate::convert::bit2a::bitinj_many(
                    ctx,
                    &drelus[i - 1],
                    &back.to_shares(),
                )?;
                e = MMat::from_shares(rows, cols, &gated);
            }
        }
        Ok(new_weights)
    }

    /// One **scheduled** training iteration through the circuit-keyed pool
    /// (see [`train_step`]): every forward and backward gate pops its
    /// bundle, so a warm epoch is offline-silent end to end.
    pub fn train_step_keyed(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        keys: &[TrainLayerKeys],
        x: &MMat<Z64>,
        t: &MMat<Z64>,
    ) -> Result<TrainStepOut, Abort> {
        train_step(
            ctx,
            weights,
            HeadActivation::Linear,
            self.grad_shift(),
            Some(keys),
            x,
            t,
        )
    }

    /// Prediction: forward pass, returns the output scores.
    pub fn predict(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        let (acts, _) = self.forward(ctx, weights, x)?;
        Ok(acts.into_iter().next_back().unwrap())
    }
}

/// Result of a circuit-keyed forward pass: the output scores plus the
/// **per-layer** offline-message meters (messages sent in `Phase::Offline`
/// during each layer's matmul and ReLU respectively — all-zero on a warm
/// wave, the deep-circuit serving invariant) and the matching per-layer
/// online compute-ns spans (this party's [`Window`] diffs — the serving
/// engine records them as `gate.matmul`/`gate.relu` trace events).
pub struct KeyedForwardOut {
    pub out: MMat<Z64>,
    pub om_mat: Vec<u64>,
    pub om_relu: Vec<u64>,
    pub cn_mat: Vec<u64>,
    pub cn_relu: Vec<u64>,
}

/// Forward pass of a resident network through the **circuit-keyed pool**:
/// layer 0 shares the dealer-held input under the popped bundle's wire mask
/// ([`matmul_tr_keyed`]); every deeper layer re-masks the previous layer's
/// shared activation under its own popped bundle
/// ([`matmul_tr_keyed_shared`]) so a warm wave runs share →
/// L×(matmul → relu) → done with **zero offline-phase messages** end to
/// end. `keys[l]` is the layer's `(matrix, relu?)` circuit-key pair, gate
/// order, as produced by `TenantSpec::layer_keys` — a `None` relu key makes
/// the layer linear (the network head). Per-layer pops are lockstep, and a
/// caller that wants all-or-nothing semantics gates on
/// [`crate::pool::Pool::check_layer_vec`] first; a cold pop inside still
/// falls back inline per layer, deterministically at all four parties.
pub fn forward_keyed(
    ctx: &mut Ctx,
    weights: &[MMat<Z64>],
    keys: &[(CircuitKey, Option<CircuitKey>)],
    x_clear: Option<&Matrix<Z64>>,
) -> Result<KeyedForwardOut, Abort> {
    assert_eq!(weights.len(), keys.len(), "one key pair per layer");
    assert!(!keys.is_empty(), "forward pass needs at least one layer");
    let depth = keys.len();
    let mut om_mat = Vec::with_capacity(depth);
    let mut om_relu = Vec::with_capacity(depth);
    let mut cn_mat = Vec::with_capacity(depth);
    let mut cn_relu = Vec::with_capacity(depth);
    let mut a: Option<MMat<Z64>> = None;
    for ((mk, rk), w) in keys.iter().zip(weights) {
        let wm = Window::open(ctx.net);
        let u = match &a {
            None => {
                let (_, u) = matmul_tr_keyed(ctx, mk, x_clear, w)?;
                u
            }
            Some(prev) => matmul_tr_keyed_shared(ctx, mk, prev, w)?,
        };
        let dm = wm.diff(ctx.net);
        om_mat.push(dm.msgs(Phase::Offline));
        cn_mat.push(dm.compute_ns(Phase::Online));
        let wr = Window::open(ctx.net);
        let act = match rk {
            Some(rk) => relu_mat_keyed(ctx, rk, &u)?.0,
            None => u,
        };
        let dr = wr.diff(ctx.net);
        om_relu.push(dr.msgs(Phase::Offline));
        cn_relu.push(dr.compute_ns(Phase::Online));
        a = Some(act);
    }
    Ok(KeyedForwardOut {
        out: a.expect("at least one layer"),
        om_mat,
        om_relu,
        cn_mat,
        cn_relu,
    })
}

/// Which activation closes the network head during a training step.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum HeadActivation {
    /// Linear scores + squared loss (NN, linear regression): `E = U − T`.
    Linear,
    /// 3-segment sigmoid (logistic regression): `E = sig(U) − T`.
    Sigmoid,
}

/// One layer's training gate keys, gate order, as minted by the scheduler
/// registry: the forward position (+ paired ReLU on hidden layers), the
/// gradient position (`A_lᵀ ∘ E_l`, double-masked, shift = the trainer's
/// `grad_shift`), and the back-propagation position (`E_l ∘ W_lᵀ`, absent
/// on layer 0). Key numbering per [`crate::sched::workload`]: layer bases
/// keep the three families disjoint so square layers can never alias.
#[derive(Copy, Clone, Debug)]
pub struct TrainLayerKeys {
    pub fwd: CircuitKey,
    pub relu: Option<CircuitKey>,
    pub grad: CircuitKey,
    pub back: Option<CircuitKey>,
}

/// Flatten per-layer training keys into the `(mat, relu?)` gate list the
/// pool's all-or-nothing stock checks
/// ([`crate::pool::Pool::check_layer_vec_gates`]) and
/// [`crate::pool::Pool::layer_vec_stock`] consume — forward (+relu), grad,
/// back, per layer in order, mirroring the pop order of [`train_step`].
pub fn train_gate_keys(keys: &[TrainLayerKeys]) -> Vec<(CircuitKey, Option<CircuitKey>)> {
    let mut out = Vec::with_capacity(keys.len() * 3);
    for k in keys {
        out.push((k.fwd, k.relu));
        out.push((k.grad, None));
        if let Some(bk) = k.back {
            out.push((bk, None));
        }
    }
    out
}

/// Result of one training step: updated weight shares plus per-**gate
/// window** offline-message and online compute meters, mirroring
/// [`KeyedForwardOut`]. Window order: forward layer 0..L (matmul meter in
/// `om_mat[l]`, activation meter in `om_relu[l]`), then backward in
/// reverse layer order — for each layer the gradient window (matmul slot;
/// its relu slot is always 0), then for layers ≥ 1 the back-propagation
/// window (matmul slot = the `E∘Wᵀ` matmul, relu slot = the drelu-gating
/// bit injection). Total `3L − 1` windows — the serving engine sizes its
/// per-tenant trace vectors with `TenantSpec::gate_windows`.
pub struct TrainStepOut {
    pub weights: Vec<MMat<Z64>>,
    pub om_mat: Vec<u64>,
    pub om_relu: Vec<u64>,
    pub cn_mat: Vec<u64>,
    pub cn_relu: Vec<u64>,
}

/// One mini-batch gradient-descent step — forward, backward, update — over
/// an already-shared batch `(x, t)`, generic over the trainer (linreg and
/// logreg are the 1-layer cases, the NN the deep case; pick via `head`).
///
/// With `keys = Some(..)` every gate draws from the **circuit-keyed pool**:
/// forward matmuls re-mask onto the popped bundle's wire mask
/// ([`matmul_tr_keyed_shared`]), hidden activations run keyed ReLU, the
/// gradient matmul re-masks **both** live operands onto the double-masked
/// gradient bundle, and the back-propagation matmul runs against the
/// resident `Wᵀ` bundle whose attached `Π_BitInj` material gates the error
/// through the drelus popped by this same step's forward ReLU — so a warm
/// step sends **zero offline-phase messages at every gate, forward and
/// backward**. Any cold pop falls back inline for that gate,
/// deterministically at all four parties (pool state is lockstep). With
/// `keys = None` every gate generates inline (the pre-scheduler path —
/// [`Network::train_iteration`] and the linreg/logreg `train_iteration`s
/// remain thin wrappers over the same protocols).
///
/// The caller supplies `grad_shift` (= `FRAC_BITS + lr_pow + log2(B)`)
/// because the learning rate is the trainer's, not the network shape's.
pub fn train_step(
    ctx: &mut Ctx,
    weights: &[MMat<Z64>],
    head: HeadActivation,
    grad_shift: u32,
    keys: Option<&[TrainLayerKeys]>,
    x: &MMat<Z64>,
    t: &MMat<Z64>,
) -> Result<TrainStepOut, Abort> {
    let depth = weights.len();
    assert!(depth > 0, "training needs at least one layer");
    if let Some(k) = keys {
        assert_eq!(k.len(), depth, "one key set per layer");
    }
    let windows = 3 * depth - 1;
    let mut om_mat = Vec::with_capacity(windows);
    let mut om_relu = Vec::with_capacity(windows);
    let mut cn_mat = Vec::with_capacity(windows);
    let mut cn_relu = Vec::with_capacity(windows);

    // ---- forward: A_0 = X, U_i = A_i ∘ W_i, A_{i+1} = act(U_i) ----------
    let mut acts = vec![x.clone()];
    let mut drelus: Vec<Option<Vec<MShare<Bit>>>> = Vec::with_capacity(depth);
    for i in 0..depth {
        let wm = Window::open(ctx.net);
        let u = match keys.map(|k| &k[i]) {
            Some(k) => matmul_tr_keyed_shared(ctx, &k.fwd, acts.last().unwrap(), &weights[i])?,
            None => matmul_tr(ctx, acts.last().unwrap(), &weights[i])?,
        };
        let dm = wm.diff(ctx.net);
        om_mat.push(dm.msgs(Phase::Offline));
        cn_mat.push(dm.compute_ns(Phase::Online));
        let wr = Window::open(ctx.net);
        if i + 1 < depth {
            let (a, d) = match keys.map(|k| &k[i]) {
                Some(k) => {
                    let rk = k.relu.as_ref().expect("hidden layer carries a relu key");
                    relu_mat_keyed(ctx, rk, &u)?
                }
                None => relu_mat(ctx, &u)?,
            };
            acts.push(a);
            drelus.push(Some(d));
        } else {
            let out = match head {
                HeadActivation::Linear => u,
                HeadActivation::Sigmoid => {
                    let (r, c) = u.dims();
                    let s = sigmoid_many(ctx, &u.to_shares())?;
                    MMat::from_shares(r, c, &s)
                }
            };
            acts.push(out);
            drelus.push(None);
        }
        let dr = wr.diff(ctx.net);
        om_relu.push(dr.msgs(Phase::Offline));
        cn_relu.push(dr.compute_ns(Phase::Online));
    }

    // ---- backward: E_m = A_m − T, then per layer (rev) grad + back ------
    let mut e = &acts[depth] - t;
    let mut new_weights = weights.to_vec();
    for i in (0..depth).rev() {
        // gradient gate: W_i ← W_i − (α/B)·A_iᵀ ∘ E
        let wg = Window::open(ctx.net);
        let at = acts[i].transpose();
        let grad = match keys.map(|k| &k[i]) {
            Some(k) => match pop_keyed(ctx, &k.grad)? {
                Some(c) => {
                    let lam_y = c
                        .lam_y
                        .clone()
                        .expect("gradient bundle carries the second wire mask");
                    let xa = remask_mat(ctx, &at, c.lam_x.clone())?;
                    let ya = remask_mat(ctx, &e, lam_y)?;
                    matmul_tr_online(ctx, &xa, &ya, &c.gamma, &c.pairs, grad_shift)?
                }
                None => matmul_tr_shift(ctx, &at, &e, grad_shift)?,
            },
            None => matmul_tr_shift(ctx, &at, &e, grad_shift)?,
        };
        new_weights[i] = &weights[i] - &grad;
        let dg = wg.diff(ctx.net);
        om_mat.push(dg.msgs(Phase::Offline));
        cn_mat.push(dg.compute_ns(Phase::Online));
        om_relu.push(0);
        cn_relu.push(0);
        // back-propagation gate: E ← (E ∘ W_iᵀ) ⊗ drelu(U_{i-1})
        if i > 0 {
            let wb = Window::open(ctx.net);
            let wt = weights[i].transpose();
            let bits = drelus[i - 1]
                .as_ref()
                .expect("hidden layer left drelu bits behind");
            let back = match keys.map(|k| &k[i]) {
                Some(k) => {
                    let bk = k.back.as_ref().expect("layer ≥ 1 carries a back key");
                    match pop_keyed(ctx, bk)? {
                        Some(c) => {
                            let binj = c
                                .binj
                                .clone()
                                .expect("back bundle carries Π_BitInj material");
                            let ea = remask_mat(ctx, &e, c.lam_x.clone())?;
                            let b =
                                matmul_tr_online(ctx, &ea, &wt, &c.gamma, &c.pairs, FRAC_BITS)?;
                            let wbj = Window::open(ctx.net);
                            let gated = bitinj_online(ctx, bits, &b.to_shares(), &binj)?;
                            (b.dims(), gated, wbj.diff(ctx.net))
                        }
                        None => {
                            let b = matmul_tr(ctx, &e, &wt)?;
                            let wbj = Window::open(ctx.net);
                            let gated = bitinj_many(ctx, bits, &b.to_shares())?;
                            (b.dims(), gated, wbj.diff(ctx.net))
                        }
                    }
                }
                None => {
                    let b = matmul_tr(ctx, &e, &wt)?;
                    let wbj = Window::open(ctx.net);
                    let gated = bitinj_many(ctx, bits, &b.to_shares())?;
                    (b.dims(), gated, wbj.diff(ctx.net))
                }
            };
            let ((rows, cols), gated, dbj) = back;
            e = MMat::from_shares(rows, cols, &gated);
            let db = wb.diff(ctx.net);
            om_mat.push(db.msgs(Phase::Offline) - dbj.msgs(Phase::Offline));
            cn_mat.push(db.compute_ns(Phase::Online) - dbj.compute_ns(Phase::Online));
            om_relu.push(dbj.msgs(Phase::Offline));
            cn_relu.push(dbj.compute_ns(Phase::Online));
        }
    }
    Ok(TrainStepOut {
        weights: new_weights,
        om_mat,
        om_relu,
        cn_mat,
        cn_relu,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ml::data::class_batch;
    use crate::ml::share_fixed_mat;
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::run_4pc;
    use crate::ring::FixedPoint;
    use crate::sharing::mat::open_mat;

    #[test]
    fn tiny_nn_trains_to_fit_batch() {
        // 6-8-3 network on an 8-sample batch (power of two, so the 1/B
        // gradient shift is exact): loss must drop
        let run = run_4pc(NetProfile::zero(), 230, |ctx| {
            let mut rng = Rng::seeded(99);
            let net = Network::custom(vec![6, 8, 3], 8, 3);
            let data = class_batch(&mut rng, 8, 6, 3);
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 8, 6)?;
            let ts = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&data.t), 8, 3)?;
            let init = net.init_weights_clear(&mut Rng::seeded(7));
            let mut ws = net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
            // initial loss
            let out0 = net.predict(ctx, &ws, &xs)?;
            for _ in 0..25 {
                ws = net.train_iteration(ctx, &ws, &xs, &ts)?;
            }
            let out1 = net.predict(ctx, &ws, &xs)?;
            ctx.flush_verify()?;
            Ok((out0, out1, data))
        });
        let (outs, _) = run.expect_ok();
        let data = &outs[1].2;
        let before = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        let after = open_mat(&[
            outs[0].1.clone(),
            outs[1].1.clone(),
            outs[2].1.clone(),
            outs[3].1.clone(),
        ]);
        let loss = |m: &crate::ring::Matrix<Z64>| -> f64 {
            let mut acc = 0.0;
            for i in 0..8 {
                for c in 0..3 {
                    let d = FixedPoint::decode(m[(i, c)]) - data.t.at(i, c);
                    acc += d * d;
                }
            }
            acc / 24.0
        };
        let (l0, l1) = (loss(&before), loss(&after));
        assert!(l1 < l0 * 0.5, "loss {l0} → {l1}: insufficient progress");
    }

    #[test]
    fn nn_iteration_communication_flat_in_feature_dim() {
        // Table VI's observation: "#it/sec has not decreased with increase
        // in features due to our dot product protocol" — online bits depend
        // on layer widths and batch, not on the inner dims of the matmuls.
        let mut per_d = Vec::new();
        for d in [16usize, 64] {
            let run = run_4pc(NetProfile::zero(), 231, move |ctx| {
                let mut rng = Rng::seeded(101);
                let net = Network::custom(vec![d, 4, 2], 4, 3);
                let data = class_batch(&mut rng, 4, d, 2);
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 4, d)?;
                let ts =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.t), 4, 2)?;
                let init = net.init_weights_clear(&mut Rng::seeded(8));
                let ws =
                    net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
                let _ = net.train_iteration(ctx, &ws, &xs, &ts)?;
                ctx.flush_verify()?;
                Ok(())
            });
            let (_, report) = run.expect_ok();
            let inputs = 2 * (4 * d + 4 * 2 + d * 4 + 4 * 2) as u64 * 64;
            // the only d-dependent remainder is the W1 gradient (d×4 output)
            per_d.push((d, report.value_bits[1] - inputs));
        }
        // W1-grad matmul output is d×4 → slope 3·4·64 per feature
        let slope = (per_d[1].1 - per_d[0].1) / (64 - 16);
        // per extra feature: 4 more W1-gradient outputs × 3ℓ each
        assert_eq!(slope, 3 * 4 * 64, "slope {slope} bits/feature");
    }

    #[test]
    #[should_panic(expected = "is not a power of two")]
    fn network_rejects_non_power_of_two_batch() {
        // batch 3 would round log2 to 2 and silently halve the effective
        // learning rate — construction must refuse instead
        let _ = Network::custom(vec![4, 2], 3, 3);
    }

    #[test]
    fn train_step_keyed_matches_inline_and_is_offline_silent_when_warm() {
        use crate::pool::{
            fill_train_vec, relu_key_for, CircuitKey, OpKind, Pool, TrainLayerTarget,
        };
        use crate::sched::{BACK_GATE_BASE, GRAD_GATE_BASE};
        let run = run_4pc(NetProfile::zero(), 233, |ctx| {
            let mut rng = Rng::seeded(21);
            let net = Network::custom(vec![4, 6, 2], 4, 3);
            let data = class_batch(&mut rng, 4, 4, 2);
            let init = net.init_weights_clear(&mut Rng::seeded(22));
            let ws = net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 4, 4)?;
            let ts = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&data.t), 4, 2)?;
            ctx.flush_verify()?;
            let dims = [4usize, 6, 2];
            let keys: Vec<TrainLayerKeys> = (0..2)
                .map(|l| {
                    let fwd = CircuitKey {
                        model: 6,
                        layer: l as u32,
                        op: OpKind::MatMulTr { shift: FRAC_BITS },
                        rows: 4,
                        inner: dims[l],
                        cols: dims[l + 1],
                        dealer: P1,
                    };
                    let grad = CircuitKey {
                        model: 6,
                        layer: GRAD_GATE_BASE + l as u32,
                        op: OpKind::MatMulTr { shift: net.grad_shift() },
                        rows: dims[l],
                        inner: 4,
                        cols: dims[l + 1],
                        dealer: P1,
                    };
                    let back = (l > 0).then(|| CircuitKey {
                        model: 6,
                        layer: BACK_GATE_BASE + l as u32,
                        op: OpKind::MatMulTr { shift: FRAC_BITS },
                        rows: 4,
                        inner: dims[l + 1],
                        cols: dims[l],
                        dealer: P1,
                    });
                    TrainLayerKeys {
                        fwd,
                        relu: (l == 0).then(|| relu_key_for(&fwd)),
                        grad,
                        back,
                    }
                })
                .collect();
            ctx.attach_pool(Pool::new());
            let targets: Vec<TrainLayerTarget> = keys
                .iter()
                .zip(&ws)
                .map(|(k, w)| TrainLayerTarget {
                    fwd: k.fwd,
                    relu: k.relu,
                    grad: k.grad,
                    back: k.back,
                    w: w.clone(),
                })
                .collect();
            fill_train_vec(ctx, &targets)?;
            let gates = train_gate_keys(&keys);
            assert!(
                ctx.pool_mut().unwrap().check_layer_vec_gates(&gates),
                "whole training vector stocked after fill"
            );
            let m0 = ctx.net.sent_msgs(Phase::Offline);
            let out = net.train_step_keyed(ctx, &ws, &keys, &xs, &ts)?;
            let om = ctx.net.sent_msgs(Phase::Offline) - m0;
            // inline reference iteration on the same shares
            let inline = net.train_iteration(ctx, &ws, &xs, &ts)?;
            ctx.flush_verify()?;
            assert_eq!(om, 0, "warm keyed training step is offline-silent");
            assert!(
                out.om_mat.iter().chain(&out.om_relu).all(|&m| m == 0),
                "per-gate offline meters all zero on a warm step"
            );
            assert_eq!(out.om_mat.len(), 5, "3L−1 gate windows for L = 2");
            assert_eq!(out.om_relu.len(), 5);
            Ok((out.weights, inline))
        });
        let (outs, _) = run.expect_ok();
        for l in 0..2 {
            let keyed = open_mat(&[
                outs[0].0[l].clone(),
                outs[1].0[l].clone(),
                outs[2].0[l].clone(),
                outs[3].0[l].clone(),
            ]);
            let inline = open_mat(&[
                outs[0].1[l].clone(),
                outs[1].1[l].clone(),
                outs[2].1[l].clone(),
                outs[3].1[l].clone(),
            ]);
            for (a, b) in keyed.data().iter().zip(inline.data()) {
                let d = FixedPoint::decode(*a) - FixedPoint::decode(*b);
                assert!(d.abs() < 0.01, "layer {l}: keyed {a:?} vs inline {b:?} drift {d}");
            }
        }
    }

    #[test]
    fn forward_keyed_matches_inline_and_is_offline_silent_when_warm() {
        use crate::pool::{fill_layer_vec, relu_key_for, CircuitKey, LayerTarget, OpKind, Pool};
        let run = run_4pc(NetProfile::zero(), 232, |ctx| {
            let mut rng = Rng::seeded(11);
            let net = Network::custom(vec![4, 6, 2], 4, 3);
            let data = class_batch(&mut rng, 4, 4, 2);
            let init = net.init_weights_clear(&mut Rng::seeded(12));
            let ws = net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
            ctx.flush_verify()?;
            // per-layer keys in gate order; the head layer is linear
            let dims = [4usize, 6, 2];
            let keys: Vec<(CircuitKey, Option<CircuitKey>)> = (0..2)
                .map(|l| {
                    let mk = CircuitKey {
                        model: 5,
                        layer: l as u32,
                        op: OpKind::MatMulTr { shift: crate::ring::fixed::FRAC_BITS },
                        rows: 4,
                        inner: dims[l],
                        cols: dims[l + 1],
                        dealer: P1,
                    };
                    (mk, (l == 0).then(|| relu_key_for(&mk)))
                })
                .collect();
            ctx.attach_pool(Pool::new());
            let targets: Vec<LayerTarget> = keys
                .iter()
                .zip(&ws)
                .map(|((mk, rk), w)| LayerTarget { key: *mk, relu: *rk, w: w.clone() })
                .collect();
            fill_layer_vec(ctx, &targets, 1)?;
            let enc = data.x.encode();
            let m0 = ctx.net.sent_msgs(Phase::Offline);
            let out = forward_keyed(ctx, &ws, &keys, (ctx.id() == P1).then_some(&enc))?;
            let om = ctx.net.sent_msgs(Phase::Offline) - m0;
            // inline reference forward on the same cleartext input
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 4, 4)?;
            let (acts, _) = net.forward(ctx, &ws, &xs)?;
            ctx.flush_verify()?;
            assert_eq!(om, 0, "warm keyed forward is offline-silent");
            assert!(
                out.om_mat.iter().chain(&out.om_relu).all(|&m| m == 0),
                "per-layer meters all zero on a warm wave"
            );
            assert_eq!((out.om_mat.len(), out.om_relu.len()), (2, 2));
            Ok((out.out, acts.into_iter().next_back().unwrap()))
        });
        let (outs, _) = run.expect_ok();
        let keyed = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        let inline = open_mat(&[
            outs[0].1.clone(),
            outs[1].1.clone(),
            outs[2].1.clone(),
            outs[3].1.clone(),
        ]);
        for (a, b) in keyed.data().iter().zip(inline.data()) {
            let d = FixedPoint::decode(*a) - FixedPoint::decode(*b);
            assert!(d.abs() < 0.01, "keyed {a:?} vs inline {b:?} drifted by {d}");
        }
    }
}
