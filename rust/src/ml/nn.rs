//! Secure neural networks (paper §VI-A.c): fully-connected layers with ReLU
//! activations, trained by mini-batch gradient descent over shares.
//!
//! * **NN** — the paper's 784-128-128-10 network.
//! * **CNN** — per §VI-A.c the running time is *overestimated* "by replacing
//!   the convolutional kernel with a fully connected layer": we model the
//!   conv stage as its FC-equivalent expansion (784 → 5·24·24 = 2880
//!   neurons), then the paper's 100 and 10-node layers.
//!
//! Output layer: the paper's MPC-friendly softmax divides by `Σ relu(u)`
//! through a garbled division circuit. For training we use the standard
//! identity that the gradient only needs `E_m = A_m − T`; we take
//! `A_m = U_m` (linear output + squared loss), which trains to the same
//! argmax-accuracy. The faithful garbled-division softmax is implemented in
//! `ml::softmax::softmax_garbled` (A2G → restoring divider → G2A) and
//! exercised by its tests and `examples/mixed_world.rs` (DESIGN.md §3).

use crate::net::Abort;
use crate::proto::{matmul_tr, matmul_tr_shift, Ctx};
use crate::ring::fixed::FRAC_BITS;
use crate::ring::{Bit, Z64};
use crate::sharing::{MMat, MShare};

use super::activation::relu_mat;
use super::F64Mat;

/// Which benchmark network (Table VI).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum NetworkKind {
    /// 784-128-128-10 (two ReLU hidden layers)
    Nn,
    /// conv-as-FC overestimate: 784-2880-100-10
    Cnn,
}

/// A fully-connected network configuration.
#[derive(Clone, Debug)]
pub struct Network {
    /// Layer widths, input first.
    pub layers: Vec<usize>,
    pub batch: usize,
    pub lr_pow: u32,
}

impl Network {
    pub fn new(kind: NetworkKind, batch: usize) -> Network {
        let layers = match kind {
            NetworkKind::Nn => vec![784, 128, 128, 10],
            NetworkKind::Cnn => vec![784, 2880, 100, 10],
        };
        Network { layers, batch, lr_pow: 7 }
    }

    /// Small custom network (tests).
    pub fn custom(layers: Vec<usize>, batch: usize, lr_pow: u32) -> Network {
        Network { layers, batch, lr_pow }
    }

    fn grad_shift(&self) -> u32 {
        FRAC_BITS + self.lr_pow + (self.batch as f64).log2().round() as u32
    }

    /// Xavier-ish random init (cleartext, to be shared by a data owner).
    pub fn init_weights_clear(&self, rng: &mut crate::crypto::Rng) -> Vec<F64Mat> {
        self.layers
            .windows(2)
            .map(|w| {
                let (fan_in, fan_out) = (w[0], w[1]);
                let scale = (2.0 / fan_in as f64).sqrt();
                let mut m = F64Mat::zeros(fan_in, fan_out);
                for v in m.data.iter_mut() {
                    *v = rng.normal() * scale;
                }
                m
            })
            .collect()
    }

    /// Share the initial weights from `dealer`.
    pub fn share_weights(
        &self,
        ctx: &mut Ctx,
        dealer: crate::net::PartyId,
        clear: Option<&[F64Mat]>,
    ) -> Result<Vec<MMat<Z64>>, Abort> {
        let mut out = Vec::new();
        for (i, w) in self.layers.windows(2).enumerate() {
            let m = clear.map(|c| &c[i]);
            out.push(super::share_fixed_mat(ctx, dealer, m, w[0], w[1])?);
        }
        Ok(out)
    }

    /// Forward pass. Returns per-layer activations `A_i` (A_0 = X) and the
    /// drelu bits of every hidden layer.
    #[allow(clippy::type_complexity)]
    pub fn forward(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
    ) -> Result<(Vec<MMat<Z64>>, Vec<Vec<MShare<Bit>>>), Abort> {
        let mut acts = vec![x.clone()];
        let mut drelus = Vec::new();
        for (i, w) in weights.iter().enumerate() {
            let u = matmul_tr(ctx, acts.last().unwrap(), w)?;
            if i + 1 < weights.len() {
                let (a, d) = relu_mat(ctx, &u)?;
                acts.push(a);
                drelus.push(d);
            } else {
                // output layer: linear scores (see module docs on softmax)
                acts.push(u);
            }
        }
        Ok((acts, drelus))
    }

    /// One training iteration (forward + backward + update). Returns the
    /// updated weights.
    pub fn train_iteration(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
        t: &MMat<Z64>,
    ) -> Result<Vec<MMat<Z64>>, Abort> {
        let (acts, drelus) = self.forward(ctx, weights, x)?;
        let m = weights.len();
        // E_m = A_m − T
        let mut e = &acts[m] - t;
        let mut new_weights = weights.to_vec();
        for i in (0..m).rev() {
            // W_i ← W_i − (α/B)·A_i^T ∘ E
            let at = acts[i].transpose();
            let grad = matmul_tr_shift(ctx, &at, &e, self.grad_shift())?;
            new_weights[i] = &weights[i] - &grad;
            if i > 0 {
                // E_{i-1} = (E ∘ W_i^T) ⊗ drelu(U_{i-1})
                let wt = weights[i].transpose();
                let back = matmul_tr(ctx, &e, &wt)?;
                let (rows, cols) = back.dims();
                let gated = crate::convert::bit2a::bitinj_many(
                    ctx,
                    &drelus[i - 1],
                    &back.to_shares(),
                )?;
                e = MMat::from_shares(rows, cols, &gated);
            }
        }
        Ok(new_weights)
    }

    /// Prediction: forward pass, returns the output scores.
    pub fn predict(
        &self,
        ctx: &mut Ctx,
        weights: &[MMat<Z64>],
        x: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        let (acts, _) = self.forward(ctx, weights, x)?;
        Ok(acts.into_iter().next_back().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ml::data::class_batch;
    use crate::ml::share_fixed_mat;
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::run_4pc;
    use crate::ring::FixedPoint;
    use crate::sharing::mat::open_mat;

    #[test]
    fn tiny_nn_trains_to_fit_batch() {
        // 6-8-3 network on a 12-sample batch: loss must drop
        let run = run_4pc(NetProfile::zero(), 230, |ctx| {
            let mut rng = Rng::seeded(99);
            let net = Network::custom(vec![6, 8, 3], 12, 3);
            let data = class_batch(&mut rng, 12, 6, 3);
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 12, 6)?;
            let ts = share_fixed_mat(ctx, P2, (ctx.id() == P2).then_some(&data.t), 12, 3)?;
            let init = net.init_weights_clear(&mut Rng::seeded(7));
            let mut ws = net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
            // initial loss
            let out0 = net.predict(ctx, &ws, &xs)?;
            for _ in 0..25 {
                ws = net.train_iteration(ctx, &ws, &xs, &ts)?;
            }
            let out1 = net.predict(ctx, &ws, &xs)?;
            ctx.flush_verify()?;
            Ok((out0, out1, data))
        });
        let (outs, _) = run.expect_ok();
        let data = &outs[1].2;
        let before = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        let after = open_mat(&[
            outs[0].1.clone(),
            outs[1].1.clone(),
            outs[2].1.clone(),
            outs[3].1.clone(),
        ]);
        let loss = |m: &crate::ring::Matrix<Z64>| -> f64 {
            let mut acc = 0.0;
            for i in 0..12 {
                for c in 0..3 {
                    let d = FixedPoint::decode(m[(i, c)]) - data.t.at(i, c);
                    acc += d * d;
                }
            }
            acc / 36.0
        };
        let (l0, l1) = (loss(&before), loss(&after));
        assert!(l1 < l0 * 0.5, "loss {l0} → {l1}: insufficient progress");
    }

    #[test]
    fn nn_iteration_communication_flat_in_feature_dim() {
        // Table VI's observation: "#it/sec has not decreased with increase
        // in features due to our dot product protocol" — online bits depend
        // on layer widths and batch, not on the inner dims of the matmuls.
        let mut per_d = Vec::new();
        for d in [16usize, 64] {
            let run = run_4pc(NetProfile::zero(), 231, move |ctx| {
                let mut rng = Rng::seeded(101);
                let net = Network::custom(vec![d, 4, 2], 4, 3);
                let data = class_batch(&mut rng, 4, d, 2);
                let xs =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.x), 4, d)?;
                let ts =
                    share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&data.t), 4, 2)?;
                let init = net.init_weights_clear(&mut Rng::seeded(8));
                let ws =
                    net.share_weights(ctx, P1, (ctx.id() == P1).then_some(&init[..]))?;
                let _ = net.train_iteration(ctx, &ws, &xs, &ts)?;
                ctx.flush_verify()?;
                Ok(())
            });
            let (_, report) = run.expect_ok();
            let inputs = 2 * (4 * d + 4 * 2 + d * 4 + 4 * 2) as u64 * 64;
            // the only d-dependent remainder is the W1 gradient (d×4 output)
            per_d.push((d, report.value_bits[1] - inputs));
        }
        // W1-grad matmul output is d×4 → slope 3·4·64 per feature
        let slope = (per_d[1].1 - per_d[0].1) / (64 - 16);
        // per extra feature: 4 more W1-gradient outputs × 3ℓ each
        assert_eq!(slope, 3 * 4 * 64, "slope {slope} bits/feature");
    }
}
