//! Activation functions (paper §V-C).
//!
//! * ReLU: `relu(v) = (1 ⊕ b)·v` with `b = msb(v)` — `Π_BitExt` then
//!   `Π_BitInj`: 4 online rounds, `8ℓ+2` bits per element (Table II).
//! * Sigmoid: the 3-segment approximation of SecureML/ABY3/Trident —
//!   `sig(v) = (1⊕b1)·b2·(v+½) + (1⊕b2)` with `b1 = msb(v+½)`,
//!   `b2 = msb(v−½)`: two batched `Π_BitExt`, one boolean AND, then the two
//!   injections **batched into one `Π_BitInj` round** (the second term is
//!   `BitInj(1⊕b2, [[1]])`), for 5 online rounds total.

use crate::convert::bit2a::{bitinj_many, bitinj_online};
use crate::convert::bitext::{bitext_many, bitext_many_keyed};
use crate::net::Abort;
use crate::pool::CircuitKey;
use crate::proto::mult::mult_many;
use crate::proto::Ctx;
use crate::ring::{fixed::FixedPoint, Bit, Z64};
use crate::sharing::{MMat, MShare};

/// Batched ReLU; also returns the `drelu` bits (`1 ⊕ msb(v)`), which the NN
/// backward pass reuses for free.
pub fn relu_many(
    ctx: &mut Ctx,
    vs: &[MShare<Z64>],
) -> Result<(Vec<MShare<Z64>>, Vec<MShare<Bit>>), Abort> {
    let bs = bitext_many(ctx, vs)?;
    let nbs: Vec<MShare<Bit>> = bs.iter().map(|b| b.add_const(Bit(true))).collect();
    let relu = bitinj_many(ctx, &nbs, vs)?;
    Ok((relu, nbs))
}

/// Batched ReLU through the **circuit-keyed nonlinear pool**: pops the
/// position's whole [`crate::pool::ReluCorr`] bundle (bit-extraction masks,
/// pre-exchanged `⟨γ_{r·v}⟩`, pre-checked `Π_BitInj` material) so a warm
/// keyed wave's ReLU sends **zero offline-phase messages** — same online
/// rounds and bits as [`relu_many`]. A miss falls back to the inline path
/// deterministically (the pop decision is lockstep at all four parties);
/// wrong-key material fails closed.
pub fn relu_many_keyed(
    ctx: &mut Ctx,
    key: &CircuitKey,
    vs: &[MShare<Z64>],
) -> Result<(Vec<MShare<Z64>>, Vec<MShare<Bit>>), Abort> {
    let (bs, binj) = bitext_many_keyed(ctx, key, vs)?;
    let nbs: Vec<MShare<Bit>> = bs.iter().map(|b| b.add_const(Bit(true))).collect();
    let relu = match &binj {
        // the pooled material was generated for λ_b (= λ_{1⊕b}) — inject
        // with the online phase only
        Some(corr) => bitinj_online(ctx, &nbs, vs, corr)?,
        None => bitinj_many(ctx, &nbs, vs)?,
    };
    Ok((relu, nbs))
}

/// Derivative of ReLU as boolean shares (`drelu(v) = 1 ⊕ msb(v)`).
pub fn drelu_many(ctx: &mut Ctx, vs: &[MShare<Z64>]) -> Result<Vec<MShare<Bit>>, Abort> {
    let bs = bitext_many(ctx, vs)?;
    Ok(bs.iter().map(|b| b.add_const(Bit(true))).collect())
}

/// Batched sigmoid approximation. 5 online rounds for the whole batch.
pub fn sigmoid_many(ctx: &mut Ctx, vs: &[MShare<Z64>]) -> Result<Vec<MShare<Z64>>, Abort> {
    let n = vs.len();
    let half = FixedPoint::encode(0.5);
    let one = FixedPoint::encode(1.0);

    // v ± ½ locally; both msb batches in ONE bitext_many (3 rounds)
    let mut probes: Vec<MShare<Z64>> = Vec::with_capacity(2 * n);
    probes.extend(vs.iter().map(|v| v.add_const(half)));
    probes.extend(vs.iter().map(|v| v.add_const(-half)));
    let bs = bitext_many(ctx, &probes)?;
    let (b1, b2) = bs.split_at(n);

    // c = (1⊕b1)·b2 — one boolean multiplication round
    let nb1: Vec<MShare<Bit>> = b1.iter().map(|b| b.add_const(Bit(true))).collect();
    let cs = mult_many(ctx, &nb1, b2)?;

    // sig = BitInj(c, v+½) + BitInj(1⊕b2, [[1]]) — one batched Π_BitInj
    let me = ctx.id();
    let mut inj_bits: Vec<MShare<Bit>> = Vec::with_capacity(2 * n);
    inj_bits.extend(cs.iter().cloned());
    inj_bits.extend(b2.iter().map(|b| b.add_const(Bit(true))));
    let mut inj_vals: Vec<MShare<Z64>> = Vec::with_capacity(2 * n);
    inj_vals.extend(vs.iter().map(|v| v.add_const(half)));
    inj_vals.extend((0..n).map(|_| MShare::of_public(me, one)));
    let injected = bitinj_many(ctx, &inj_bits, &inj_vals)?;
    let (t1, t2) = injected.split_at(n);
    Ok((0..n).map(|i| t1[i] + t2[i]).collect())
}

/// ReLU over a shared matrix (elementwise), returning drelu bits alongside.
pub fn relu_mat(
    ctx: &mut Ctx,
    m: &MMat<Z64>,
) -> Result<(MMat<Z64>, Vec<MShare<Bit>>), Abort> {
    let (rows, cols) = m.dims();
    let shares = m.to_shares();
    let (relu, drelu) = relu_many(ctx, &shares)?;
    Ok((MMat::from_shares(rows, cols, &relu), drelu))
}

/// [`relu_mat`] through the circuit-keyed nonlinear pool — the serving
/// wave's matrix-level entry point ([`relu_many_keyed`] semantics: whole
/// [`crate::pool::ReluCorr`] bundle pop, deterministic inline fallback,
/// wrong-key pops fail closed). Keeps the share-vector conversion in one
/// place so the wave pipeline itself stays on SoA matrices end to end.
pub fn relu_mat_keyed(
    ctx: &mut Ctx,
    key: &CircuitKey,
    m: &MMat<Z64>,
) -> Result<(MMat<Z64>, Vec<MShare<Bit>>), Abort> {
    let (rows, cols) = m.dims();
    let shares = m.to_shares();
    let (relu, drelu) = relu_many_keyed(ctx, key, &shares)?;
    Ok((MMat::from_shares(rows, cols, &relu), drelu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1};
    use crate::proto::{run_4pc, share};
    use crate::ring::fixed::SCALE;
    use crate::sharing::open;

    #[test]
    fn relu_positive_negative() {
        for v in [3.5f64, -2.25, 0.125, -0.001, 100.0] {
            let run = run_4pc(NetProfile::zero(), 150, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(v)))?;
                let (r, d) = relu_many(ctx, &[x])?;
                ctx.flush_verify()?;
                Ok((r[0], d[0]))
            });
            let (outs, _) = run.expect_ok();
            let relu = FixedPoint::decode(open(&[outs[0].0, outs[1].0, outs[2].0, outs[3].0]));
            let want = if v > 0.0 { v } else { 0.0 };
            assert!((relu - want).abs() < 1.0 / SCALE, "relu({v}) = {relu}");
            let drelu = open(&[outs[0].1, outs[1].1, outs[2].1, outs[3].1]);
            assert_eq!(drelu, Bit(v > 0.0), "drelu({v})");
        }
    }

    #[test]
    fn relu_cost_table2() {
        let run = run_4pc(NetProfile::zero(), 151, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(-7.0)))?;
            let (r, _) = relu_many(ctx, &[x])?;
            ctx.flush_verify()?;
            Ok(r[0])
        });
        let (_, report) = run.expect_ok();
        // Table II: ReLU online 4 rounds, 8ℓ+2 bits (+1 input round / 2ℓ)
        assert_eq!(report.rounds[1], 1 + 4, "rounds");
        assert_eq!(report.value_bits[1] - 2 * 64, 8 * 64 + 2, "online bits");
    }

    #[test]
    fn sigmoid_three_segments() {
        let cases = [
            (-5.0, 0.0),
            (-0.6, 0.0),
            (-0.25, 0.25),
            (0.0, 0.5),
            (0.3, 0.8),
            (0.5, 1.0),
            (4.0, 1.0),
        ];
        for (v, want) in cases {
            let run = run_4pc(NetProfile::zero(), 152, move |ctx| {
                let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(v)))?;
                let s = sigmoid_many(ctx, &[x])?;
                ctx.flush_verify()?;
                Ok(s[0])
            });
            let (outs, _) = run.expect_ok();
            let sig = FixedPoint::decode(open(&outs));
            assert!((sig - want).abs() < 2.0 / SCALE, "sig({v}) = {sig}, want {want}");
        }
    }

    #[test]
    fn sigmoid_cost_5_rounds() {
        let run = run_4pc(NetProfile::zero(), 153, |ctx| {
            let x = share(ctx, P1, (ctx.id() == P1).then_some(FixedPoint::encode(0.1)))?;
            let s = sigmoid_many(ctx, &[x])?;
            ctx.flush_verify()?;
            Ok(s[0])
        });
        let (_, report) = run.expect_ok();
        // Table II: Sigmoid online 5 rounds (+ 1 input round)
        assert_eq!(report.rounds[1], 1 + 5, "rounds");
        // 16ℓ+7 online bits: 2 bitext (10ℓ+4) + AND (3) + 2-elt bitinj (6ℓ)
        assert_eq!(report.value_bits[1] - 2 * 64, 16 * 64 + 7, "online bits");
    }
}
