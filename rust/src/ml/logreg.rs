//! Secure logistic regression (paper §VI-A.b): linear regression plus the
//! 3-segment sigmoid on the forward activations —
//! `w ← w − (α/B)·Xᵀ∘(sig(X∘w) − y)`.

use crate::net::Abort;
use crate::proto::{matmul_tr, matmul_tr_shift, Ctx};
use crate::ring::fixed::FRAC_BITS;
use crate::ring::Z64;
use crate::sharing::MMat;

use super::activation::sigmoid_many;
use super::nn::{train_step, HeadActivation, TrainLayerKeys, TrainStepOut};

/// Logistic-regression trainer configuration.
#[derive(Copy, Clone, Debug)]
pub struct LogReg {
    pub d: usize,
    pub batch: usize,
    pub lr_pow: u32,
}

impl LogReg {
    pub fn new(d: usize, batch: usize) -> LogReg {
        LogReg { d, batch, lr_pow: 4 }
    }

    /// Public so the scheduler can mint this trainer's gradient gate key.
    pub fn grad_shift(&self) -> u32 {
        FRAC_BITS + self.lr_pow + (self.batch as f64).log2().round() as u32
    }

    /// Forward pass with activation: `sig(X ∘ w)`.
    pub fn forward(
        &self,
        ctx: &mut Ctx,
        x: &MMat<Z64>,
        w: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        let u = matmul_tr(ctx, x, w)?;
        let (rows, cols) = u.dims();
        let act = sigmoid_many(ctx, &u.to_shares())?;
        Ok(MMat::from_shares(rows, cols, &act))
    }

    /// One GD iteration.
    pub fn train_iteration(
        &self,
        ctx: &mut Ctx,
        w: &MMat<Z64>,
        x: &MMat<Z64>,
        y: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        let a = self.forward(ctx, x, w)?;
        let e = &a - y;
        let xt = x.transpose();
        let grad = matmul_tr_shift(ctx, &xt, &e, self.grad_shift())?;
        Ok(w - &grad)
    }

    /// One **scheduled** GD iteration through the circuit-keyed pool: the
    /// one-layer case of [`train_step`] with the sigmoid head (the sigmoid
    /// itself runs the generic `msb`/`bit2a` machinery, drawing from the
    /// generic pools when stocked).
    pub fn train_step_keyed(
        &self,
        ctx: &mut Ctx,
        w: &MMat<Z64>,
        keys: &[TrainLayerKeys],
        x: &MMat<Z64>,
        y: &MMat<Z64>,
    ) -> Result<TrainStepOut, Abort> {
        train_step(
            ctx,
            std::slice::from_ref(w),
            HeadActivation::Sigmoid,
            self.grad_shift(),
            Some(keys),
            x,
            y,
        )
    }

    /// Prediction (probability estimates).
    pub fn predict(
        &self,
        ctx: &mut Ctx,
        x: &MMat<Z64>,
        w: &MMat<Z64>,
    ) -> Result<MMat<Z64>, Abort> {
        self.forward(ctx, x, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Rng;
    use crate::ml::data::logreg_batch;
    use crate::ml::share_fixed_mat;
    use crate::net::{NetProfile, P1, P3};
    use crate::proto::run_4pc;
    use crate::ring::FixedPoint;
    use crate::sharing::mat::open_mat;

    #[test]
    fn secure_logreg_learns_separation() {
        let run = run_4pc(NetProfile::zero(), 220, |ctx| {
            let mut rng = Rng::seeded(88);
            let batch = logreg_batch(&mut rng, 32, 6);
            let model = LogReg { d: 6, batch: 32, lr_pow: 1 };
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.x), 32, 6)?;
            let ys = share_fixed_mat(ctx, P3, (ctx.id() == P3).then_some(&batch.y), 32, 1)?;
            let zeros = crate::ml::F64Mat::zeros(6, 1);
            let mut w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&zeros), 6, 1)?;
            for _ in 0..40 {
                w = model.train_iteration(ctx, &w, &xs, &ys)?;
            }
            let p = model.predict(ctx, &xs, &w)?;
            ctx.flush_verify()?;
            Ok((p, batch))
        });
        let (outs, _) = run.expect_ok();
        let batch = &outs[1].1;
        let p = open_mat(&[
            outs[0].0.clone(),
            outs[1].0.clone(),
            outs[2].0.clone(),
            outs[3].0.clone(),
        ]);
        // training accuracy
        let mut correct = 0;
        for i in 0..32 {
            let pred = FixedPoint::decode(p[(i, 0)]);
            let label = if pred > 0.5 { 1.0 } else { 0.0 };
            if label == batch.y.at(i, 0) {
                correct += 1;
            }
        }
        assert!(correct >= 26, "train accuracy {correct}/32");
    }

    #[test]
    fn logreg_iteration_cost() {
        // one iteration = linreg cost + one batched sigmoid (B elements)
        let run = run_4pc(NetProfile::zero(), 221, |ctx| {
            let mut rng = Rng::seeded(89);
            let b = 8usize;
            let d = 4usize;
            let batch = logreg_batch(&mut rng, b, d);
            let model = LogReg { d, batch: b, lr_pow: 2 };
            let xs = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.x), b, d)?;
            let ys = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&batch.y), b, 1)?;
            let zeros = crate::ml::F64Mat::zeros(d, 1);
            let w = share_fixed_mat(ctx, P1, (ctx.id() == P1).then_some(&zeros), d, 1)?;
            let w2 = model.train_iteration(ctx, &w, &xs, &ys)?;
            ctx.flush_verify()?;
            let _ = w2;
            Ok(())
        });
        let (_, report) = run.expect_ok();
        let b = 8u64;
        let d = 4u64;
        let inputs = 2 * (b * d + b + d) * 64;
        let online = report.value_bits[1] - inputs;
        // linreg part 3(B+d)ℓ + sigmoid 16ℓ+7 per element over B elements
        let want = 3 * (b + d) * 64 + b * (16 * 64 + 7);
        assert_eq!(online, want, "online bits");
        // rounds: 1 input + 2 matmul + 5 sigmoid = 8
        assert_eq!(report.rounds[1], 8);
    }
}
