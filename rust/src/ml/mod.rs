//! Privacy-preserving machine learning on top of the 4PC framework
//! (paper §V–§VI): activation functions, the four benchmark algorithms
//! (linear regression, logistic regression, NN, CNN-as-FC), and synthetic
//! dataset generators standing in for the Kaggle/MNIST data (DESIGN.md §3).

pub mod activation;
pub mod data;
pub mod linreg;
pub mod logreg;
pub mod nn;
pub mod softmax;

pub use activation::{
    drelu_many, relu_many, relu_many_keyed, relu_mat, relu_mat_keyed, sigmoid_many,
};
pub use linreg::LinReg;
pub use logreg::LogReg;
pub use nn::{
    forward_keyed, train_gate_keys, train_step, HeadActivation, KeyedForwardOut, Network,
    NetworkKind, TrainLayerKeys, TrainStepOut,
};

use crate::net::{Abort, PartyId};
use crate::proto::Ctx;
use crate::ring::{Matrix, Z64};
use crate::sharing::MMat;

/// Share a matrix of fixed-point values from `dealer` (input-sharing stage
/// of the outsourced setting: data owners hand their rows to the servers).
pub fn share_fixed_mat(
    ctx: &mut Ctx,
    dealer: PartyId,
    m: Option<&F64Mat>,
    rows: usize,
    cols: usize,
) -> Result<MMat<Z64>, Abort> {
    // flat path: encode once, share as a matrix — the SoA share_mat_n
    // builds the component matrices directly (no per-element round-trip)
    let enc: Option<Matrix<Z64>> = m.map(F64Mat::encode);
    crate::proto::sharing::share_mat_n(ctx, dealer, enc.as_ref(), rows, cols)
}

/// Plain `f64` matrix helper (row-major) used by the data generators.
#[derive(Clone, Debug)]
pub struct F64Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl F64Mat {
    pub fn zeros(rows: usize, cols: usize) -> F64Mat {
        F64Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    pub fn matmul(&self, o: &F64Mat) -> F64Mat {
        assert_eq!(self.cols, o.rows);
        let mut out = F64Mat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                for j in 0..o.cols {
                    out.data[i * o.cols + j] += a * o.at(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> F64Mat {
        let mut out = F64Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.at(r, c));
            }
        }
        out
    }

    /// Encode into a fixed-point ring matrix.
    pub fn encode(&self) -> Matrix<Z64> {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&v| crate::ring::FixedPoint::encode(v)).collect(),
        )
    }
}
