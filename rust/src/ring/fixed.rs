//! Fixed-point embedding of decimals into `Z_{2^64}` (paper §V).
//!
//! "To represent decimal values, we use signed two's complement over Z_{2^ℓ},
//! where the most significant bit represents the sign and the last d bits
//! represent the fractional part." We follow SecureML/ABY3 and use
//! `FRAC_BITS = 13` fractional bits.

use super::Z64;

/// Number of fractional bits in the embedding (SecureML's choice, kept by
/// ABY3 and Trident).
pub const FRAC_BITS: u32 = 13;

/// Scale factor 2^FRAC_BITS.
pub const SCALE: f64 = (1u64 << FRAC_BITS) as f64;

/// Helpers for moving between `f64` and the ring embedding.
///
/// The embedding is exact for values representable in `Q50.13`; everything
/// in the ML workloads (inputs normalised to [0,1], weights, activations)
/// stays far inside that range.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct FixedPoint;

impl FixedPoint {
    /// Encode a decimal into the ring.
    #[inline]
    pub fn encode(v: f64) -> Z64 {
        Z64(((v * SCALE).round() as i64) as u64)
    }

    /// Decode a ring element back into a decimal.
    #[inline]
    pub fn decode(v: Z64) -> f64 {
        (v.0 as i64) as f64 / SCALE
    }

    /// Encode a slice.
    pub fn encode_vec(vs: &[f64]) -> Vec<Z64> {
        vs.iter().map(|&v| Self::encode(v)).collect()
    }

    /// Decode a slice.
    pub fn decode_vec(vs: &[Z64]) -> Vec<f64> {
        vs.iter().map(|&v| Self::decode(v)).collect()
    }

    /// The product of two encoded values carries 2·f fractional bits; this is
    /// the local truncation that `Π_MultTr` applies to bring it back to f.
    #[inline]
    pub fn post_mul_truncate(v: Z64) -> Z64 {
        v.truncate(FRAC_BITS)
    }

    /// Largest decimal magnitude the `Q50.13` embedding can hold: `2^50`
    /// (the sign bit plus 50 integer bits plus 13 fractional bits fill the
    /// 64-bit ring). `−2^50` is exactly representable by two's-complement
    /// asymmetry; `+2^50` encodes one ulp below the sign boundary. Anything
    /// larger wraps.
    pub fn max_magnitude() -> f64 {
        ((1u64 << 62) as f64) * 2.0 / SCALE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_on_grid() {
        for v in [-100.0, -1.5, -0.0001220703125, 0.0, 0.5, 1.0, 3.25, 1e6] {
            let enc = FixedPoint::encode(v);
            let dec = FixedPoint::decode(enc);
            assert!((dec - v).abs() <= 0.5 / SCALE, "roundtrip {v} -> {dec}");
        }
    }

    #[test]
    fn negative_encoding_is_twos_complement() {
        let enc = FixedPoint::encode(-1.0);
        assert_eq!(enc.0, (-(1i64 << FRAC_BITS)) as u64);
        assert_eq!(enc.msb().0, true);
    }

    #[test]
    fn mul_then_truncate_approximates_product() {
        let cases = [(1.5, 2.25), (-3.0, 0.5), (0.125, -0.25), (100.0, -0.01)];
        for (a, b) in cases {
            let prod = FixedPoint::encode(a) * FixedPoint::encode(b);
            let dec = FixedPoint::decode(FixedPoint::post_mul_truncate(prod));
            // error = operand-encoding error (≤0.5 ulp each, scaled by the
            // other operand) + 1 ulp truncation
            let tol = (a.abs() + b.abs() + 2.0) * 0.5 / SCALE + 1.0 / SCALE;
            assert!((dec - a * b).abs() < tol, "{a}*{b}: got {dec}, want {}", a * b);
        }
    }

    #[test]
    fn max_magnitude_is_the_full_q50_13_envelope() {
        // regression: this used to report 2^49 — half the documented range
        let m = FixedPoint::max_magnitude();
        assert_eq!(m, (1u64 << 50) as f64);
        // encode(+max) stays out of the sign bit and round-trips to within
        // one ulp (the positive side tops out one ulp below 2^50)
        let enc = FixedPoint::encode(m);
        assert!(!enc.msb().0, "encode(max_magnitude) must not wrap into the sign bit");
        assert!((FixedPoint::decode(enc) - m).abs() <= 1.0 / SCALE);
        // −max is exactly representable (two's-complement asymmetry)
        assert_eq!(FixedPoint::decode(FixedPoint::encode(-m)), -m);
        // 2·max does NOT fit: the embedding cannot represent it
        let over = FixedPoint::decode(FixedPoint::encode(2.0 * m));
        assert!((over - 2.0 * m).abs() > m / 2.0, "2·max_magnitude must not round-trip");
    }

    #[test]
    fn addition_is_exact() {
        let a = FixedPoint::encode(1.25);
        let b = FixedPoint::encode(-0.75);
        assert_eq!(FixedPoint::decode(a + b), 0.5);
    }

    #[test]
    fn truncation_error_at_most_one_ulp() {
        // §VI-B: "Our truncation protocol causes a bit-error at the least
        // significant bit position" — check the local op's error bound.
        for i in 0..1000i64 {
            let v = (i - 500) as f64 * 0.37;
            let w = 0.77;
            let prod = FixedPoint::encode(v) * FixedPoint::encode(w);
            let got = FixedPoint::decode(FixedPoint::post_mul_truncate(prod));
            let tol = (v.abs() + w.abs() + 2.0) * 0.5 / SCALE + 1.0 / SCALE;
            assert!((got - v * w).abs() <= tol, "{v}*{w}: {got}");
        }
    }
}
