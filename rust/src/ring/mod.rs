//! Ring arithmetic substrate.
//!
//! Trident operates over the arithmetic ring `Z_{2^64}` and the boolean ring
//! `Z_2` (paper §II). Both are exposed through the [`Ring`] trait so that the
//! sharing semantics and most protocols (`Π_Sh`, `Π_Rec`, `Π_Mult`, …) can be
//! written once and instantiated in either world — exactly the structure the
//! paper uses ("The sharings work over both arithmetic (Z_{2^ℓ}) and boolean
//! (Z_{2^1}) rings", §III-A).
//!
//! `Z64` is a transparent wrapper over `u64` with **wrapping** semantics: ring
//! addition/multiplication are mod 2^64, which is what makes 64-bit CPUs (and
//! the XLA u64 ops used by the L1/L2 artifacts) evaluate the ring natively —
//! the "rings vs fields" argument of §I.

pub mod fixed;
pub mod matrix;

pub use fixed::FixedPoint;
pub use matrix::Matrix;

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Neg, Sub, SubAssign};

/// A finite commutative ring with enough structure for Trident's sharings.
///
/// For `Z64` this is ordinary wrapping integer arithmetic; for [`Bit`] the
/// addition is XOR and multiplication is AND (the paper's boolean world).
pub trait Ring:
    Copy
    + Clone
    + PartialEq
    + Eq
    + fmt::Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + Default
    + Send
    + Sync
    + 'static
{
    /// Additive identity.
    const ZERO: Self;
    /// Multiplicative identity.
    const ONE: Self;
    /// Size of one element on the wire, in bytes (ℓ/8 for Z_{2^ℓ}; bits are
    /// metered as one byte on the wire but counted as 1 bit analytically).
    const WIRE_BYTES: usize;
    /// Number of bits of the ring (ℓ).
    const BITS: usize;

    /// Canonical little-endian wire encoding.
    fn to_wire(&self, out: &mut Vec<u8>);
    /// Inverse of [`Ring::to_wire`]. Returns the element and bytes consumed.
    fn from_wire(buf: &[u8]) -> Option<(Self, usize)>;
    /// Sample an element from a uniformly random 16-byte block (PRF output).
    fn from_block(block: &[u8; 16]) -> Self;

    /// Fixed-size encode into a caller-provided buffer (the scalar
    /// fast path of `Ctx::send_ring1`: no per-message `Vec`). Writes at
    /// most [`Ring::WIRE_BYTES`] bytes and returns the count.
    fn to_wire_into(&self, out: &mut [u8]) -> usize;

    /// Payload bytes of `n` elements under the **bulk** wire codec
    /// ([`Ring::to_wire_bulk`]): `n·WIRE_BYTES` for byte-granular rings;
    /// the boolean ring overrides this to `⌈n/8⌉` — bits pack 8 per byte
    /// on the wire while the analytic meters keep counting `n` bits.
    fn wire_len(n: usize) -> usize {
        n * Self::WIRE_BYTES
    }

    /// Bulk wire encoding of a slice. Default: element-wise
    /// [`Ring::to_wire`]; [`Bit`] overrides it to pack 8 bits per byte
    /// (LSB-first), zero-padding the trailing byte.
    fn to_wire_bulk(vals: &[Self], out: &mut Vec<u8>) {
        out.reserve(Self::wire_len(vals.len()));
        for v in vals {
            v.to_wire(out);
        }
    }

    /// Inverse of [`Ring::to_wire_bulk`]: decode exactly `n` elements,
    /// returning them and the bytes consumed. `None` on short or malformed
    /// input — for the packed boolean codec that includes non-zero padding
    /// bits, so a sender cannot smuggle payload past the metered count.
    fn from_wire_bulk(buf: &[u8], n: usize) -> Option<(Vec<Self>, usize)> {
        let mut out = Vec::with_capacity(n);
        let mut off = 0;
        for _ in 0..n {
            let (v, used) = Self::from_wire(&buf[off..])?;
            out.push(v);
            off += used;
        }
        Some((out, off))
    }
}

/// An element of the arithmetic ring `Z_{2^64}`.
///
/// All arithmetic wraps mod 2^64. Decimal values are embedded via
/// [`FixedPoint`] (§V: signed two's complement, low `f` bits fractional).
#[derive(Copy, Clone, PartialEq, Eq, Default, Hash, PartialOrd, Ord)]
#[repr(transparent)]
pub struct Z64(pub u64);

impl Z64 {
    /// The most significant bit — the sign under the two's-complement
    /// embedding; this is what `Π_BitExt` (secure comparison, §V-B) extracts.
    #[inline]
    pub fn msb(self) -> Bit {
        Bit(((self.0 >> 63) & 1) == 1)
    }

    /// Arithmetic shift right by `d` preserving the embedded sign: the local
    /// truncation operation of `Π_MultTr` (§V-A), identical to ABY3/SecureML.
    #[inline]
    pub fn truncate(self, d: u32) -> Z64 {
        Z64(((self.0 as i64) >> d) as u64)
    }

    /// The low `d` bits (the `r_d` of the Π_MultTr correctness check).
    #[inline]
    pub fn low_bits(self, d: u32) -> Z64 {
        if d >= 64 {
            self
        } else {
            Z64(self.0 & ((1u64 << d) - 1))
        }
    }

    /// Bit `i` of the canonical representative, as a boolean-ring element.
    #[inline]
    pub fn bit(self, i: usize) -> Bit {
        Bit(((self.0 >> i) & 1) == 1)
    }

    /// Interpret as signed (the two's-complement embedding of §V).
    #[inline]
    pub fn as_i64(self) -> i64 {
        self.0 as i64
    }

    #[inline]
    pub fn wrapping_pow2(shift: u32) -> Z64 {
        if shift >= 64 {
            Z64(0)
        } else {
            Z64(1u64 << shift)
        }
    }
}

impl fmt::Debug for Z64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Z64({})", self.0)
    }
}

impl fmt::Display for Z64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl Add for Z64 {
    type Output = Z64;
    #[inline]
    fn add(self, rhs: Z64) -> Z64 {
        Z64(self.0.wrapping_add(rhs.0))
    }
}

impl Sub for Z64 {
    type Output = Z64;
    #[inline]
    fn sub(self, rhs: Z64) -> Z64 {
        Z64(self.0.wrapping_sub(rhs.0))
    }
}

impl Mul for Z64 {
    type Output = Z64;
    #[inline]
    fn mul(self, rhs: Z64) -> Z64 {
        Z64(self.0.wrapping_mul(rhs.0))
    }
}

impl Neg for Z64 {
    type Output = Z64;
    #[inline]
    fn neg(self) -> Z64 {
        Z64(self.0.wrapping_neg())
    }
}

impl AddAssign for Z64 {
    #[inline]
    fn add_assign(&mut self, rhs: Z64) {
        self.0 = self.0.wrapping_add(rhs.0);
    }
}

impl SubAssign for Z64 {
    #[inline]
    fn sub_assign(&mut self, rhs: Z64) {
        self.0 = self.0.wrapping_sub(rhs.0);
    }
}

impl From<u64> for Z64 {
    #[inline]
    fn from(v: u64) -> Z64 {
        Z64(v)
    }
}

impl From<i64> for Z64 {
    #[inline]
    fn from(v: i64) -> Z64 {
        Z64(v as u64)
    }
}

impl Ring for Z64 {
    const ZERO: Z64 = Z64(0);
    const ONE: Z64 = Z64(1);
    const WIRE_BYTES: usize = 8;
    const BITS: usize = 64;

    #[inline]
    fn to_wire(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.0.to_le_bytes());
    }

    #[inline]
    fn from_wire(buf: &[u8]) -> Option<(Z64, usize)> {
        if buf.len() < 8 {
            return None;
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[..8]);
        Some((Z64(u64::from_le_bytes(b)), 8))
    }

    #[inline]
    fn from_block(block: &[u8; 16]) -> Z64 {
        let mut b = [0u8; 8];
        b.copy_from_slice(&block[..8]);
        Z64(u64::from_le_bytes(b))
    }

    #[inline]
    fn to_wire_into(&self, out: &mut [u8]) -> usize {
        out[..8].copy_from_slice(&self.0.to_le_bytes());
        8
    }
}

/// An element of the boolean ring `Z_2`: addition is XOR, multiplication AND.
///
/// Negation is the identity (−b ≡ b mod 2), which is why the generic
/// subtraction-shaped protocol algebra specialises to XOR in the boolean
/// world, matching e.g. `v = (m_v ⊕ λ_v,1) ⊕ (λ_v,2 ⊕ λ_v,3)` in `Π_B2G`.
#[derive(Copy, Clone, PartialEq, Eq, Default, Hash, PartialOrd, Ord)]
#[repr(transparent)]
pub struct Bit(pub bool);

impl Bit {
    pub const FALSE: Bit = Bit(false);
    pub const TRUE: Bit = Bit(true);

    /// Lift into the arithmetic ring ("b over Z_{2^ℓ}" in Π_Bit2A).
    #[inline]
    pub fn to_z64(self) -> Z64 {
        Z64(self.0 as u64)
    }

    #[inline]
    pub fn not(self) -> Bit {
        Bit(!self.0)
    }

    #[inline]
    pub fn as_u8(self) -> u8 {
        self.0 as u8
    }
}

impl fmt::Debug for Bit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Bit({})", self.0 as u8)
    }
}

impl Add for Bit {
    type Output = Bit;
    #[inline]
    fn add(self, rhs: Bit) -> Bit {
        Bit(self.0 ^ rhs.0)
    }
}

impl Sub for Bit {
    type Output = Bit;
    #[inline]
    fn sub(self, rhs: Bit) -> Bit {
        Bit(self.0 ^ rhs.0)
    }
}

impl Mul for Bit {
    type Output = Bit;
    #[inline]
    fn mul(self, rhs: Bit) -> Bit {
        Bit(self.0 & rhs.0)
    }
}

impl Neg for Bit {
    type Output = Bit;
    #[inline]
    fn neg(self) -> Bit {
        self
    }
}

impl AddAssign for Bit {
    #[inline]
    fn add_assign(&mut self, rhs: Bit) {
        self.0 ^= rhs.0;
    }
}

impl SubAssign for Bit {
    #[inline]
    fn sub_assign(&mut self, rhs: Bit) {
        self.0 ^= rhs.0;
    }
}

impl Ring for Bit {
    const ZERO: Bit = Bit(false);
    const ONE: Bit = Bit(true);
    // A *lone* bit travels as one byte; slices go through the packed bulk
    // codec below (8 bits/byte). The analytic cost tables count 1 bit
    // either way — net::Meter records both (see net::Meter::bits).
    const WIRE_BYTES: usize = 1;
    const BITS: usize = 1;

    #[inline]
    fn to_wire(&self, out: &mut Vec<u8>) {
        out.push(self.0 as u8);
    }

    #[inline]
    fn from_wire(buf: &[u8]) -> Option<(Bit, usize)> {
        buf.first().map(|&b| (Bit(b != 0), 1))
    }

    #[inline]
    fn from_block(block: &[u8; 16]) -> Bit {
        Bit(block[0] & 1 == 1)
    }

    #[inline]
    fn to_wire_into(&self, out: &mut [u8]) -> usize {
        out[0] = self.0 as u8;
        1
    }

    fn wire_len(n: usize) -> usize {
        n.div_ceil(8)
    }

    /// Packed boolean codec: 8 bits per byte, LSB-first, zero-padded
    /// trailing byte — the byte-optimal encoding the boolean-world
    /// communication lemmas count.
    fn to_wire_bulk(vals: &[Self], out: &mut Vec<u8>) {
        out.reserve(vals.len().div_ceil(8));
        let mut acc = 0u8;
        for (i, b) in vals.iter().enumerate() {
            acc |= (b.0 as u8) << (i % 8);
            if i % 8 == 7 {
                out.push(acc);
                acc = 0;
            }
        }
        if vals.len() % 8 != 0 {
            out.push(acc);
        }
    }

    fn from_wire_bulk(buf: &[u8], n: usize) -> Option<(Vec<Bit>, usize)> {
        let nb = n.div_ceil(8);
        if buf.len() < nb {
            return None;
        }
        // reject non-zero padding: the unused high bits of the trailing
        // byte carry no metered payload and must not carry covert one
        if n % 8 != 0 && (buf[nb - 1] >> (n % 8)) != 0 {
            return None;
        }
        let out = (0..n).map(|i| Bit((buf[i / 8] >> (i % 8)) & 1 == 1)).collect();
        Some((out, nb))
    }
}

/// Dot product over any ring (the cleartext reference for `Π_DotP`).
pub fn dot<R: Ring>(x: &[R], y: &[R]) -> R {
    assert_eq!(x.len(), y.len(), "dot: length mismatch");
    let mut acc = R::ZERO;
    for (a, b) in x.iter().zip(y.iter()) {
        acc += *a * *b;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn z64_wraps() {
        assert_eq!(Z64(u64::MAX) + Z64(1), Z64(0));
        assert_eq!(Z64(0) - Z64(1), Z64(u64::MAX));
        assert_eq!(Z64(1u64 << 63) * Z64(2), Z64(0));
        assert_eq!(-Z64(5), Z64(0) - Z64(5));
    }

    #[test]
    fn z64_msb_is_sign() {
        assert_eq!(Z64::from(-1i64).msb(), Bit(true));
        assert_eq!(Z64::from(1i64).msb(), Bit(false));
        assert_eq!(Z64(0).msb(), Bit(false));
        assert_eq!(Z64(1u64 << 63).msb(), Bit(true));
    }

    #[test]
    fn z64_truncate_signed() {
        // truncation is an arithmetic shift: sign-preserving
        let v = Z64::from(-(1i64 << 20));
        assert_eq!(v.truncate(13).as_i64(), -(1i64 << 7));
        let w = Z64::from(1i64 << 20);
        assert_eq!(w.truncate(13).as_i64(), 1i64 << 7);
    }

    #[test]
    fn z64_split_recombine() {
        // r = 2^d * r^t + r_d  (the Π_MultTr correctness identity, Lemma D.1)
        // holds exactly for non-negative representatives.
        for raw in [0u64, 1, 8191, 8192, 123456789, (1u64 << 62) + 12345] {
            let r = Z64(raw);
            let d = 13u32;
            let lhs = Z64::wrapping_pow2(d) * Z64(((r.0 as i64) >> d) as u64) + r.low_bits(d);
            assert_eq!(lhs, r, "split identity failed for {raw}");
        }
    }

    #[test]
    fn bit_ring_axioms() {
        for a in [Bit(false), Bit(true)] {
            for b in [Bit(false), Bit(true)] {
                assert_eq!(a + b, b + a);
                assert_eq!(a * b, b * a);
                assert_eq!(a + b, a - b); // characteristic 2
                assert_eq!(-a, a);
            }
        }
        assert_eq!(Bit(true) + Bit(true), Bit(false));
        assert_eq!(Bit(true) * Bit(true), Bit(true));
    }

    #[test]
    fn wire_roundtrip() {
        let mut buf = Vec::new();
        Z64(0xDEADBEEF12345678).to_wire(&mut buf);
        Bit(true).to_wire(&mut buf);
        let (z, n) = Z64::from_wire(&buf).unwrap();
        assert_eq!(z, Z64(0xDEADBEEF12345678));
        let (b, _) = Bit::from_wire(&buf[n..]).unwrap();
        assert_eq!(b, Bit(true));
    }

    #[test]
    fn packed_bit_codec_roundtrip_and_padding() {
        // all lengths around byte boundaries round-trip
        for n in [0usize, 1, 7, 8, 9, 15, 16, 63, 64, 65] {
            let bits: Vec<Bit> = (0..n).map(|i| Bit(i % 3 == 0)).collect();
            let mut buf = Vec::new();
            Bit::to_wire_bulk(&bits, &mut buf);
            assert_eq!(buf.len(), n.div_ceil(8), "n={n}: 8 bits per byte");
            assert_eq!(buf.len(), Bit::wire_len(n));
            let (back, used) = Bit::from_wire_bulk(&buf, n).expect("roundtrip");
            assert_eq!(back, bits, "n={n}");
            assert_eq!(used, buf.len());
        }
        // non-zero padding bits are rejected (no covert payload)
        let mut buf = Vec::new();
        Bit::to_wire_bulk(&[Bit(true), Bit(false), Bit(true)], &mut buf);
        buf[0] |= 0x80;
        assert!(Bit::from_wire_bulk(&buf, 3).is_none(), "padding must be zero");
        // short input is rejected
        assert!(Bit::from_wire_bulk(&[], 1).is_none());
    }

    #[test]
    fn bulk_codec_default_matches_elementwise() {
        let vals = [Z64(1), Z64(u64::MAX), Z64(0xDEADBEEF)];
        let mut bulk = Vec::new();
        Z64::to_wire_bulk(&vals, &mut bulk);
        let mut each = Vec::new();
        for v in &vals {
            v.to_wire(&mut each);
        }
        assert_eq!(bulk, each);
        assert_eq!(bulk.len(), Z64::wire_len(3));
        let (back, used) = Z64::from_wire_bulk(&bulk, 3).unwrap();
        assert_eq!(back, vals.to_vec());
        assert_eq!(used, 24);
    }

    #[test]
    fn to_wire_into_matches_to_wire() {
        let mut stack = [0u8; 16];
        let used = Z64(0x0102030405060708).to_wire_into(&mut stack);
        let mut heap = Vec::new();
        Z64(0x0102030405060708).to_wire(&mut heap);
        assert_eq!(&stack[..used], &heap[..]);
        let used = Bit(true).to_wire_into(&mut stack);
        assert_eq!(&stack[..used], &[1u8]);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<Z64> = (1..=10u64).map(Z64).collect();
        let y: Vec<Z64> = (11..=20u64).map(Z64).collect();
        let expect: u64 = (1..=10u64).zip(11..=20u64).map(|(a, b)| a * b).sum();
        assert_eq!(dot(&x, &y), Z64(expect));
    }

    #[test]
    fn bit_extraction_from_z64() {
        let v = Z64(0b1011);
        assert_eq!(v.bit(0), Bit(true));
        assert_eq!(v.bit(1), Bit(true));
        assert_eq!(v.bit(2), Bit(false));
        assert_eq!(v.bit(3), Bit(true));
        // recompose
        let mut acc = 0u64;
        for i in 0..64 {
            acc |= (v.bit(i).0 as u64) << i;
        }
        assert_eq!(acc, v.0);
    }
}
