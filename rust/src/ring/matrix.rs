//! Dense row-major matrices over a [`Ring`].
//!
//! This is the *local* linear algebra each party performs on its shares —
//! `X_i ∘ w`, `X_i^T ∘ e`, the γ-products of `Π_DotP`'s offline phase, etc.
//! The matmul here is the native fallback for the hot path; when an AOT HLO
//! artifact for the shape exists, `runtime::Engine` executes the same
//! computation through PJRT instead (see `runtime/`).

use super::Ring;
use std::ops::{Add, Index, IndexMut, Mul, Neg, Sub};

/// Dense row-major matrix.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Matrix<R> {
    rows: usize,
    cols: usize,
    data: Vec<R>,
}

impl<R: Ring> Matrix<R> {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![R::ZERO; rows * cols] }
    }

    /// Build from a row-major vec (must have `rows*cols` elements).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<R>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix dims mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> R) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[R] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [R] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<R> {
        self.data
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[R] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose (allocates).
    pub fn transpose(&self) -> Matrix<R> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Matrix product `self ∘ rhs` over the ring (wrapping).
    ///
    /// ikj loop order so the inner loop streams both the row of `self` and
    /// the row of `rhs` — this is the perf-relevant native path (see
    /// EXPERIMENTS.md §Perf).
    pub fn matmul(&self, rhs: &Matrix<R>) -> Matrix<R> {
        assert_eq!(self.cols, rhs.rows, "matmul inner dims");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                let brow = &rhs.data[k * rhs.cols..(k + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Elementwise (Hadamard) product — the `⊗` of the NN backward pass.
    pub fn hadamard(&self, rhs: &Matrix<R>) -> Matrix<R> {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(&a, &b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Map every element.
    pub fn map(&self, f: impl Fn(R) -> R) -> Matrix<R> {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Scale by a public ring constant (local op — linearity, §III-A.d).
    pub fn scale(&self, c: R) -> Matrix<R> {
        self.map(|v| c * v)
    }
}

impl<R: Ring> Index<(usize, usize)> for Matrix<R> {
    type Output = R;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &R {
        &self.data[r * self.cols + c]
    }
}

impl<R: Ring> IndexMut<(usize, usize)> for Matrix<R> {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut R {
        &mut self.data[r * self.cols + c]
    }
}

impl<R: Ring> Add for &Matrix<R> {
    type Output = Matrix<R>;
    fn add(self, rhs: &Matrix<R>) -> Matrix<R> {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(&a, &b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl<R: Ring> Sub for &Matrix<R> {
    type Output = Matrix<R>;
    fn sub(self, rhs: &Matrix<R>) -> Matrix<R> {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self.data.iter().zip(&rhs.data).map(|(&a, &b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }
}

impl<R: Ring> Neg for &Matrix<R> {
    type Output = Matrix<R>;
    fn neg(self) -> Matrix<R> {
        self.map(|v| -v)
    }
}

impl<R: Ring> Mul for &Matrix<R> {
    type Output = Matrix<R>;
    fn mul(self, rhs: &Matrix<R>) -> Matrix<R> {
        self.matmul(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::Z64;

    fn m(rows: usize, cols: usize, vs: &[u64]) -> Matrix<Z64> {
        Matrix::from_vec(rows, cols, vs.iter().map(|&v| Z64(v)).collect())
    }

    #[test]
    fn matmul_small() {
        let a = m(2, 2, &[1, 2, 3, 4]);
        let b = m(2, 2, &[5, 6, 7, 8]);
        assert_eq!(a.matmul(&b), m(2, 2, &[19, 22, 43, 50]));
    }

    #[test]
    fn matmul_rectangular() {
        let a = m(2, 3, &[1, 2, 3, 4, 5, 6]);
        let b = m(3, 1, &[7, 8, 9]);
        assert_eq!(a.matmul(&b), m(2, 1, &[50, 122]));
    }

    #[test]
    fn matmul_wraps() {
        let a = m(1, 1, &[u64::MAX]);
        let b = m(1, 1, &[2]);
        assert_eq!(a.matmul(&b), m(1, 1, &[u64::MAX - 1]));
    }

    #[test]
    fn transpose_involution() {
        let a = m(2, 3, &[1, 2, 3, 4, 5, 6]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], Z64(6));
    }

    #[test]
    fn matmul_transpose_identity() {
        // (A∘B)^T == B^T ∘ A^T
        let a = m(2, 3, &[1, 2, 3, 4, 5, 6]);
        let b = m(3, 2, &[9, 8, 7, 6, 5, 4]);
        assert_eq!(a.matmul(&b).transpose(), b.transpose().matmul(&a.transpose()));
    }

    #[test]
    fn add_sub_hadamard() {
        let a = m(2, 2, &[1, 2, 3, 4]);
        let b = m(2, 2, &[10, 20, 30, 40]);
        assert_eq!(&(&a + &b) - &b, a);
        assert_eq!(a.hadamard(&b), m(2, 2, &[10, 40, 90, 160]));
        assert_eq!(a.scale(Z64(3)), m(2, 2, &[3, 6, 9, 12]));
    }

    #[test]
    fn distributivity_over_shares() {
        // (A1+A2) ∘ B == A1∘B + A2∘B — the property that lets parties matmul
        // additive shares locally.
        let a1 = m(2, 2, &[1, 2, 3, 4]);
        let a2 = m(2, 2, &[5, 6, 7, 8]);
        let b = m(2, 2, &[2, 0, 1, 2]);
        assert_eq!((&a1 + &a2).matmul(&b), &a1.matmul(&b) + &a2.matmul(&b));
    }
}
