//! Background pool refill — a **producer that tops queues up between
//! serving waves** against low-water marks, replacing the one-shot
//! workload-sized fill of PR 1.
//!
//! ## Refill state machine
//!
//! A [`Refill`] holds a set of registered targets, each pairing a pool
//! resource with [`WaterMarks`] `{low, high}`. Every call to
//! [`Refill::tick`] runs the same deterministic loop per target:
//!
//! ```text
//!   CHECK  stock = pool.len(target)
//!   ──────  stock ≥ low  → SKIP (no traffic at all)
//!   ──────  stock < low  → FILL high − stock items (the real offline
//!                          generation protocols, metered Phase::Offline),
//!                          then settle the fill's verification digests
//! ```
//!
//! **Lockstep determinism.** Stock levels are identical at all four
//! parties (fills and pops run in lockstep, like the PRF streams the pool
//! caches), so every party takes the same SKIP/FILL branch with the same
//! count — a tick can never desynchronise the cluster. In a deployment the
//! producer runs on its own connection whenever the serving loop is idle;
//! the in-process cluster calls `tick` cooperatively at wave boundaries,
//! which is the deterministic equivalent.
//!
//! **No interleaving.** A fill appends to the end of FIFO queues and keyed
//! pops are whole-bundle atomic, so a refill between (or conceptually
//! during) waves can never interleave material *within* one pop — asserted
//! by the pool's sequence-number tests.
//!
//! **Offline-only traffic.** Everything a tick does is offline-phase:
//! generation messages, verification, digests. The serving-wave windows
//! around ticks stay offline-silent (the meter regression tests assert
//! both directions).

use crate::convert::bit2a::bitinj_offline;
use crate::net::Abort;
use crate::proto::sharing::vsh_mask_skeleton;
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::{MMat, MShare};

use super::mat::{fill_mat, gen_grad_corr, gen_mat_corr, CircuitKey};
use super::relu::{fill_mat_relu, gen_relu_corr};
use super::{fill_bitext, fill_lam, fill_trunc};

/// Refill thresholds for one pooled resource, in items of that resource
/// (keyed matrix bundles, truncation pairs, λ skeletons, bitext masks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaterMarks {
    /// A tick refills only when stock has fallen **below** this.
    pub low: usize,
    /// A triggered refill tops the queue up to this.
    pub high: usize,
}

impl WaterMarks {
    pub fn new(low: usize, high: usize) -> WaterMarks {
        assert!(low <= high, "low-water mark must not exceed high-water mark");
        WaterMarks { low, high }
    }
}

struct MatTarget {
    key: CircuitKey,
    /// The paired nonlinear position, when the gate feeds a ReLU: the tick
    /// then fills **paired** bundles ([`fill_mat_relu`]) so the matrix and
    /// ReLU queues advance in lockstep.
    relu: Option<CircuitKey>,
    /// Resident model share the γ correlations are generated against.
    w: MMat<Z64>,
    marks: WaterMarks,
}

struct TruncTarget {
    shift: u32,
    marks: WaterMarks,
}

/// One layer of a resident network's per-layer key vector, as the fill
/// side sees it: the matrix position, the paired nonlinear position when
/// the layer ends in a ReLU (hidden layers; the final layer is
/// matmul-only), and the resident weight share the `⟨Γ⟩` correlations are
/// generated against.
#[derive(Clone)]
pub struct LayerTarget {
    pub key: CircuitKey,
    pub relu: Option<CircuitKey>,
    pub w: MMat<Z64>,
}

/// Restock a whole **per-layer key vector** as an atomic unit: every
/// layer's `(mat, relu?)` queue pair is topped up to `target` stocked
/// items, layer-major in gate order (layer 0's bundles first, then layer
/// 1's, …) within one lockstep tick — nothing pops between the per-layer
/// fills, so after the call [`crate::pool::Pool::layer_vec_stock`] over
/// these keys reads ≥ `target` whole poppable vectors at all four parties.
/// Paired layers fill through [`fill_mat_relu`] (mat and relu queues
/// advance together); each underlying fill settles its own verification
/// digests, so the tick leaves no offline digest for the next wave's
/// flush. Returns what was generated.
pub fn fill_layer_vec(
    ctx: &mut Ctx,
    layers: &[LayerTarget],
    target: usize,
) -> Result<RefillOutcome, Abort> {
    assert!(ctx.has_pool(), "fill_layer_vec requires an attached pool");
    let mut out = RefillOutcome::default();
    for t in layers {
        let stock = ctx.pool.as_ref().map_or(0, |p| match &t.relu {
            Some(rk) => p.len_mat(&t.key).min(p.len_relu(rk)),
            None => p.len_mat(&t.key),
        });
        if stock >= target {
            continue;
        }
        let need = target - stock;
        match &t.relu {
            Some(rk) => {
                fill_mat_relu(ctx, t.key, *rk, &t.w, need)?;
                out.relu_items += need;
            }
            None => fill_mat(ctx, t.key, &t.w, need)?,
        }
        out.mat_items += need;
    }
    Ok(out)
}

/// One layer of a **training** tenant's gate vector, as the fill side sees
/// it: the forward position (with its paired ReLU on hidden layers), the
/// gradient position (`A_lᵀ ∘ E_l` — both operands live, double-masked
/// bundle), the back-propagation position (`E_l ∘ W_lᵀ`, layers ≥ 1, whose
/// bundle also carries the `Π_BitInj` material for the drelu gating), and
/// the **current** weight share the resident-operand `⟨Γ⟩`s are generated
/// against. See [`crate::sched::workload`] for the gate numbering and why
/// the vector is regenerated per epoch (fresh post-commit λ — reusing a
/// mask across epochs would leak weight deltas).
#[derive(Clone)]
pub struct TrainLayerTarget {
    pub fwd: CircuitKey,
    pub relu: Option<CircuitKey>,
    pub grad: CircuitKey,
    /// `None` for layer 0 (no error to propagate past the input).
    pub back: Option<CircuitKey>,
    pub w: MMat<Z64>,
}

/// Restock one whole **training gate vector** (stock depth 1 — bundles are
/// valid only against the current epoch's weight λ, so deeper stock would
/// be dead weight): for each layer in order, the forward bundle (+ paired
/// ReLU on hidden layers), the double-masked gradient bundle, and the
/// back-propagation bundle generated against `Wᵀ` with its drelu-gating
/// `Π_BitInj` material pre-exchanged against the *previous* layer's ReLU
/// masks from this same pass (the bit wire of the gating is
/// `b = msb ⊕ y`, whose λ is exactly the relu bundle's `λ_x ⊕ λ_y` —
/// `Π_BitInj`'s offline phase reads only λ components, and
/// `1⊕b` has the same λ, so the material serves the `drelu = 1⊕msb`
/// gating unchanged). No-op when a whole vector is already stocked.
/// Settles its own verification digests; everything is `Phase::Offline`.
pub fn fill_train_vec(ctx: &mut Ctx, layers: &[TrainLayerTarget]) -> Result<RefillOutcome, Abort> {
    assert!(ctx.has_pool(), "fill_train_vec requires an attached pool");
    let mut out = RefillOutcome::default();
    let mut keys: Vec<(CircuitKey, Option<CircuitKey>)> = Vec::new();
    for t in layers {
        keys.push((t.fwd, t.relu));
        keys.push((t.grad, None));
        if let Some(bk) = t.back {
            keys.push((bk, None));
        }
    }
    if ctx.pool.as_ref().map_or(0, |p| p.layer_vec_stock(&keys)) >= 1 {
        return Ok(out);
    }
    let me = ctx.id();
    // the back gate of layer l gates through the drelus of layer l−1, so
    // its injection material is exchanged against the ReLU masks generated
    // earlier in this same layer-major pass
    let mut prev_b_skel: Option<Vec<MShare<Bit>>> = None;
    for t in layers {
        let fwd = gen_mat_corr(ctx, t.fwd, &t.w)?;
        let relu = match &t.relu {
            Some(rk) => {
                let vs_skel: Vec<MShare<Z64>> = fwd.pairs.iter().map(|p| p.rt).collect();
                Some(gen_relu_corr(ctx, *rk, &vs_skel)?)
            }
            None => None,
        };
        let b_skel: Option<Vec<MShare<Bit>>> = relu.as_ref().map(|r| {
            r.x_masks
                .iter()
                .zip(&r.y_masks)
                .map(|(x, ym)| *x + vsh_mask_skeleton(me, ym))
                .collect()
        });
        let grad = gen_grad_corr(ctx, t.grad)?;
        let back = match &t.back {
            Some(bk) => {
                let wt = t.w.transpose();
                let mut b = gen_mat_corr(ctx, *bk, &wt)?;
                let gate_bits = prev_b_skel
                    .as_ref()
                    .expect("a back gate requires the previous layer's ReLU position");
                let vs_skel: Vec<MShare<Z64>> = b.pairs.iter().map(|p| p.rt).collect();
                b.binj = Some(bitinj_offline(ctx, gate_bits, &vs_skel)?);
                Some(b)
            }
            None => None,
        };
        prev_b_skel = b_skel;
        let pool = ctx.pool.as_mut().expect("pool attached");
        pool.push_mat(fwd);
        out.mat_items += 1;
        if let Some(r) = relu {
            pool.push_relu(r);
            out.relu_items += 1;
        }
        pool.push_mat(grad);
        out.mat_items += 1;
        if let Some(b) = back {
            pool.push_mat(b);
            out.mat_items += 1;
        }
    }
    ctx.flush_verify()?;
    Ok(out)
}

/// The background refill producer: registered targets + cooperative
/// [`Refill::tick`]. See the module docs for the state machine.
#[derive(Default)]
pub struct Refill {
    mat: Vec<MatTarget>,
    /// Per-layer key vectors (deep resident networks), measured and
    /// refilled in whole-vector units.
    mat_vec: Vec<MatVecTarget>,
    trunc: Vec<TruncTarget>,
    lam_z64: Option<WaterMarks>,
    bitext: Option<WaterMarks>,
}

struct MatVecTarget {
    layers: Vec<LayerTarget>,
    marks: WaterMarks,
}

/// What one tick generated, per resource (all zero ⇒ every stock was at or
/// above its low-water mark and the tick was traffic-free).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RefillOutcome {
    /// Keyed matrix correlation bundles filled.
    pub mat_items: usize,
    /// Keyed nonlinear (ReLU) bundles filled — always paired one-for-one
    /// with `mat_items` for a ReLU-registered gate.
    pub relu_items: usize,
    /// Truncation pairs filled.
    pub trunc_pairs: usize,
    /// λ_Z skeletons filled.
    pub lam: usize,
    /// Bit-extraction masks filled.
    pub bitext: usize,
}

impl RefillOutcome {
    pub fn total(&self) -> usize {
        self.mat_items + self.relu_items + self.trunc_pairs + self.lam + self.bitext
    }
}

impl Refill {
    pub fn new() -> Refill {
        Refill::default()
    }

    /// Register a circuit position: the serving engine calls this once per
    /// resident-model matrix gate at model-load time.
    pub fn register_mat(&mut self, key: CircuitKey, w: MMat<Z64>, marks: WaterMarks) {
        self.mat.push(MatTarget { key, relu: None, w, marks });
    }

    /// Register a matrix gate **together with its trailing ReLU**: the tick
    /// fills paired `MatCorr`+`ReluCorr` bundles ([`fill_mat_relu`]) so the
    /// nonlinear leg of a keyed wave is offline-silent too.
    pub fn register_mat_relu(
        &mut self,
        key: CircuitKey,
        relu: CircuitKey,
        w: MMat<Z64>,
        marks: WaterMarks,
    ) {
        self.mat.push(MatTarget { key, relu: Some(relu), w, marks });
    }

    /// Register a whole **per-layer key vector** (deep resident network):
    /// the tick measures its stock in whole vectors (the min paired stock
    /// across layers) and restocks atomically through [`fill_layer_vec`].
    pub fn register_mat_vec(&mut self, layers: Vec<LayerTarget>, marks: WaterMarks) {
        assert!(!layers.is_empty(), "a layer vector needs at least one layer");
        self.mat_vec.push(MatVecTarget { layers, marks });
    }

    /// Remove every registered matrix/ReLU target belonging to `model` —
    /// the refill leg of quarantine: a contained tenant's positions stop
    /// being topped up (and the pool's push guard would drop the items
    /// anyway). Returns how many targets were deregistered (a layer vector
    /// counts as one). Lockstep-safe: all four parties deregister from the
    /// same public wave metadata.
    pub fn deregister_model(&mut self, model: u64) -> usize {
        let before = self.mat.len() + self.mat_vec.len();
        self.mat.retain(|t| t.key.model != model);
        self.mat_vec.retain(|t| t.layers[0].key.model != model);
        before - self.mat.len() - self.mat_vec.len()
    }

    pub fn register_trunc(&mut self, shift: u32, marks: WaterMarks) {
        self.trunc.push(TruncTarget { shift, marks });
    }

    pub fn register_lam(&mut self, marks: WaterMarks) {
        self.lam_z64 = Some(marks);
    }

    pub fn register_bitext(&mut self, marks: WaterMarks) {
        self.bitext = Some(marks);
    }

    /// One cooperative refill step (all four parties call in lockstep
    /// between serving waves). Checks every registered target against its
    /// low-water mark and tops depleted queues back up to high; targets at
    /// or above low generate **no traffic at all**.
    pub fn tick(&self, ctx: &mut Ctx) -> Result<RefillOutcome, Abort> {
        assert!(ctx.has_pool(), "refill tick requires an attached pool");
        let mut out = RefillOutcome::default();
        for t in &self.mat {
            // a ReLU-paired gate refills on the paired stock (the min of the
            // two queues — always equal under paired fills/pops, but the min
            // keeps the state machine safe under any skew)
            let stock = ctx.pool.as_ref().map_or(0, |p| match &t.relu {
                Some(rk) => p.len_mat(&t.key).min(p.len_relu(rk)),
                None => p.len_mat(&t.key),
            });
            if stock < t.marks.low {
                let need = t.marks.high - stock;
                match &t.relu {
                    Some(rk) => {
                        fill_mat_relu(ctx, t.key, *rk, &t.w, need)?;
                        out.relu_items += need;
                    }
                    None => fill_mat(ctx, t.key, &t.w, need)?,
                }
                out.mat_items += need;
            }
        }
        for t in &self.mat_vec {
            // a layer vector's stock is whole poppable vectors: the min
            // paired stock across its layers
            let keys: Vec<_> = t.layers.iter().map(|l| (l.key, l.relu)).collect();
            let stock = ctx.pool.as_ref().map_or(0, |p| p.layer_vec_stock(&keys));
            if stock < t.marks.low {
                let o = fill_layer_vec(ctx, &t.layers, t.marks.high)?;
                out.mat_items += o.mat_items;
                out.relu_items += o.relu_items;
            }
        }
        for t in &self.trunc {
            let stock = ctx.pool.as_ref().map_or(0, |p| p.len_trunc(t.shift));
            if stock < t.marks.low {
                let need = t.marks.high - stock;
                fill_trunc(ctx, need, t.shift)?;
                out.trunc_pairs += need;
            }
        }
        if let Some(marks) = self.lam_z64 {
            let stock = ctx.pool.as_ref().map_or(0, |p| p.len_lam::<Z64>());
            if stock < marks.low {
                let need = marks.high - stock;
                fill_lam::<Z64>(ctx, need);
                out.lam += need;
            }
        }
        if let Some(marks) = self.bitext {
            let stock = ctx.pool.as_ref().map_or(0, |p| p.len_bitext());
            if stock < marks.low {
                let need = marks.high - stock;
                fill_bitext(ctx, need)?;
                out.bitext += need;
            }
        }
        // Settle every fill's deferred verification digests at the tick
        // boundary (fill_mat flushes its own; fill_trunc/fill_bitext defer
        // theirs) so no offline-phase digest leaks into the next serving
        // wave's flush window.
        if out.total() > 0 {
            ctx.flush_verify()?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2};
    use crate::pool::{CircuitKey, OpKind, Pool};
    use crate::proto::run_4pc;
    use crate::ring::fixed::FRAC_BITS;
    use crate::ring::Matrix;

    #[test]
    fn refill_triggers_exactly_at_low_water() {
        let key = CircuitKey {
            model: 9,
            layer: 0,
            op: OpKind::MatMulTr { shift: FRAC_BITS },
            rows: 1,
            inner: 2,
            cols: 1,
            dealer: P2,
        };
        let run = run_4pc(NetProfile::zero(), 810, move |ctx| {
            let w0 = Matrix::from_fn(2, 1, |r, _| crate::ring::Z64(3 + r as u64));
            let w = crate::testutil::share_mat(ctx, P1, &w0)?;
            ctx.attach_pool(Pool::new());
            let mut refill = Refill::new();
            refill.register_mat(key, w, WaterMarks::new(2, 3));
            // empty pool: first tick fills to high
            let t1 = refill.tick(ctx)?;
            // stock 3 ≥ low 2: no-op
            let t2 = refill.tick(ctx)?;
            // pop one (stock 2, still ≥ low): no-op
            let _ = ctx.pool_mut().unwrap().pop_mat(&key).unwrap().expect("stocked");
            let t3 = refill.tick(ctx)?;
            // pop one more (stock 1 < low): top back up to 3
            let _ = ctx.pool_mut().unwrap().pop_mat(&key).unwrap().expect("stocked");
            let t4 = refill.tick(ctx)?;
            let left = ctx.pool.as_ref().unwrap().len_mat(&key);
            ctx.flush_verify()?;
            Ok((t1.mat_items, t2.mat_items, t3.mat_items, t4.mat_items, left))
        });
        let (outs, _) = run.expect_ok();
        for (t1, t2, t3, t4, left) in &outs {
            assert_eq!(*t1, 3, "cold pool fills to high");
            assert_eq!(*t2, 0, "at high: no refill");
            assert_eq!(*t3, 0, "at low mark exactly: no refill");
            assert_eq!(*t4, 2, "below low: top back up to high");
            assert_eq!(*left, 3);
        }
    }

    #[test]
    fn layer_vector_refills_atomically_in_whole_vector_units() {
        use crate::pool::relu_key_for;
        // 2-layer resident net, hidden layer ReLU-paired, output matmul-only
        fn key(layer: u32) -> CircuitKey {
            CircuitKey {
                model: 11,
                layer,
                op: OpKind::MatMulTr { shift: FRAC_BITS },
                rows: 1,
                inner: 2,
                cols: if layer == 0 { 2 } else { 1 },
                dealer: P2,
            }
        }
        let run = run_4pc(NetProfile::zero(), 812, move |ctx| {
            let w0a = Matrix::from_fn(2, 2, |r, c| crate::ring::Z64(1 + (r + 2 * c) as u64));
            let w0b = Matrix::from_fn(2, 1, |r, _| crate::ring::Z64(3 + r as u64));
            let wa = crate::testutil::share_mat(ctx, P1, &w0a)?;
            let wb = crate::testutil::share_mat(ctx, P1, &w0b)?;
            ctx.attach_pool(Pool::new());
            let rk = relu_key_for(&key(0));
            let mut refill = Refill::new();
            refill.register_mat_vec(
                vec![
                    LayerTarget { key: key(0), relu: Some(rk), w: wa },
                    LayerTarget { key: key(1), relu: None, w: wb },
                ],
                WaterMarks::new(1, 2),
            );
            // cold pool: fill every layer to high (2 vectors)
            let t1 = refill.tick(ctx)?;
            let keys = vec![(key(0), Some(rk)), (key(1), None)];
            let s1 = ctx.pool.as_ref().unwrap().layer_vec_stock(&keys);
            // drain one whole vector in gate order (stock 1 = low: no-op)
            {
                let pool = ctx.pool_mut().unwrap();
                pool.pop_mat(&key(0)).unwrap().expect("stocked");
                pool.pop_relu(&rk).unwrap().expect("stocked");
                pool.pop_mat(&key(1)).unwrap().expect("stocked");
            }
            let t2 = refill.tick(ctx)?;
            // drain one MID-vector gate only: the vector count drops to 0
            // (< low) and the tick must restore WHOLE vectors, not just the
            // drained gate
            ctx.pool_mut().unwrap().pop_mat(&key(1)).unwrap().expect("stocked");
            let t3 = refill.tick(ctx)?;
            let s3 = ctx.pool.as_ref().unwrap().layer_vec_stock(&keys);
            ctx.flush_verify()?;
            Ok((t1, t2, t3, s1, s3))
        });
        let (outs, _) = run.expect_ok();
        for (t1, t2, t3, s1, s3) in &outs {
            assert_eq!((t1.mat_items, t1.relu_items), (4, 2), "cold fill: 2 vectors × 2 layers");
            assert_eq!(*s1, 2, "stock counts whole vectors");
            assert_eq!(t2.total(), 0, "at the low mark exactly: no refill");
            // layer 1 was drained to 0 (needs 2) while layer 0 still held 1
            // (needs 1 paired bundle): the tick levels BOTH back to 2
            assert_eq!(
                (t3.mat_items, t3.relu_items),
                (3, 1),
                "mid-vector drain refills back to whole vectors: {t3:?}"
            );
            assert_eq!(*s3, 2);
        }
    }

    #[test]
    fn deregister_model_stops_refilling_only_that_model() {
        fn key(model: u64) -> CircuitKey {
            CircuitKey {
                model,
                layer: 0,
                op: OpKind::MatMulTr { shift: FRAC_BITS },
                rows: 1,
                inner: 2,
                cols: 1,
                dealer: P2,
            }
        }
        let run = run_4pc(NetProfile::zero(), 811, move |ctx| {
            let w0 = Matrix::from_fn(2, 1, |r, _| crate::ring::Z64(3 + r as u64));
            let w = crate::testutil::share_mat(ctx, P1, &w0)?;
            ctx.attach_pool(Pool::new());
            let mut refill = Refill::new();
            refill.register_mat(key(5), w.clone(), WaterMarks::new(1, 2));
            refill.register_mat(key(6), w, WaterMarks::new(1, 2));
            assert_eq!(refill.deregister_model(5), 1, "one target removed");
            assert_eq!(refill.deregister_model(5), 0, "idempotent");
            let t = refill.tick(ctx)?;
            let pool = ctx.pool.as_ref().unwrap();
            let lens = (pool.len_mat(&key(5)), pool.len_mat(&key(6)));
            ctx.flush_verify()?;
            Ok((t.mat_items, lens))
        });
        let (outs, _) = run.expect_ok();
        for (items, (m5, m6)) in &outs {
            assert_eq!(*items, 2, "only the surviving model refills");
            assert_eq!((*m5, *m6), (0, 2), "deregistered model gets no stock");
        }
    }
}
