//! Circuit-position-keyed **matrix wire-mask pooling** — the material that
//! makes a pool-backed serving wave's per-request offline phase truly
//! message-free (the one-time-setup direction Tetrad pushes for 4PC
//! serving).
//!
//! The scalar pool (PR 1) stocks truncation pairs, λ skeletons and bitext
//! masks, but every matrix product still ran `matmul_offline`'s γ-exchange
//! live, so a "pool-backed" wave was not offline-silent. The missing piece
//! is that the γ correlation depends on the **wire masks** of the two
//! operands: to pre-exchange it, the input wire's mask must itself be
//! pooled and later *used* by the input sharing. This module pools exactly
//! that bundle, keyed by circuit position:
//!
//! ## `CircuitKey` layout
//!
//! A key names one matrix-product gate of a resident model's circuit:
//!
//! * `model` — resident-model id (multi-model residency shards pools by it);
//! * `layer` — gate index inside the model's circuit;
//! * `op` — [`OpKind::MatMul`] (ring product, pooled `λ_Z`) or
//!   [`OpKind::MatMulTr`] (truncated product, pooled truncation pairs in
//!   place of `λ_Z`, Fig. 18);
//! * `rows × inner × cols` — the public gate shape (`X: rows×inner`,
//!   resident `Y: inner×cols`);
//! * `dealer` — who deals the live `X` online; the pooled wire mask is
//!   drawn through `Π_Sh`'s own batched mask sampler
//!   ([`crate::proto::sharing::sample_mask_vecs`] — per-scope bulk
//!   keystream draws, value-identical to the per-element path), so the
//!   dealer knows the full mask and can later send `m = X + Λ_X` without
//!   any offline step.
//!
//! ## Pooled item ([`MatCorr`])
//!
//! One item serves one whole gate evaluation: the pre-drawn `Λ_X` skeleton
//! (plus the full mask at the dealer), the pre-exchanged `⟨Γ⟩` against the
//! resident `Λ_Y`, and — per `op` — a pooled `λ_Z` skeleton or
//! `rows·cols` verified truncation pairs. Pops are **all-or-nothing and
//! atomic**: a wave either gets the entire bundle or falls back inline, so
//! lockstep parties can never interleave material within one pop. Items
//! carry a per-key fill sequence number; [`crate::pool::Pool::push_mat`]
//! assigns it and pops are FIFO, so a background refill *appends* — it can
//! never reorder material under a consumer.
//!
//! Items also embed their own key: popping under a different key fails
//! closed ([`crate::pool::Pool::pop_mat`] errors and the popping party
//! aborts) rather than silently running the online phase on wrong-position
//! correlations.

use crate::convert::bit2a::BitInjCorr;
use crate::net::{Abort, PartyId};
use crate::proto::dotp::{matmul_offline, MatGamma};
use crate::proto::sharing::{assemble_mmat, full_masks, sample_mask_vecs};
use crate::proto::trunc::{gen_trunc_pairs, TruncPair};
use crate::proto::Ctx;
use crate::ring::{Matrix, Z64};
use crate::sharing::MMat;

/// Which gate a [`CircuitKey`] names.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Plain `Π_MatMul` — the pooled item carries a `λ_Z` skeleton.
    MatMul,
    /// `Π_MatMulTr` with this arithmetic shift — the pooled item carries
    /// verified truncation pairs (`λ_{Zᵗ} = −rᵗ`) instead of `λ_Z`.
    MatMulTr { shift: u32 },
    /// Batched ReLU over the `n`-element output of this position's matrix
    /// gate (`n` is the underlying `Π_BitExt` width). The pooled item is a
    /// [`crate::pool::relu::ReluCorr`] bundle, generated **against** the
    /// position's matrix bundle so the `γ_{r·v}` correlation matches the
    /// wave's actual output masks (see [`crate::pool::relu`]).
    Relu { n: usize },
}

/// A circuit position of a resident model: the index of one keyed queue of
/// pre-generated matrix correlations (see the module docs for the layout).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CircuitKey {
    /// Resident-model id.
    pub model: u64,
    /// Gate index inside the model's circuit.
    pub layer: u32,
    pub op: OpKind,
    /// Rows of the live input `X` (a serving wave's stacked row count).
    pub rows: usize,
    /// Inner dimension (`X` cols == resident `Y` rows).
    pub inner: usize,
    /// Cols of the resident `Y`.
    pub cols: usize,
    /// Dealer of the live `X`.
    pub dealer: PartyId,
}

/// One pooled correlation bundle for a circuit position — everything the
/// gate's offline phase would otherwise produce live.
#[derive(Clone)]
pub struct MatCorr {
    pub(crate) key: CircuitKey,
    /// Pre-drawn input wire mask skeleton (`m` still zero).
    pub(crate) lam_x: MMat<Z64>,
    /// Full mask `Λ_X = Λ_1+Λ_2+Λ_3`, held where the dealer scope pattern
    /// yields all components (the dealer, and P0).
    pub(crate) lam_x_full: Option<Matrix<Z64>>,
    /// Pre-exchanged `⟨Γ⟩` for `(Λ_X, Λ_Y)`.
    pub(crate) gamma: MatGamma<Z64>,
    /// `λ_Z` skeleton (`OpKind::MatMul`; all-zero otherwise).
    pub(crate) lam_z: MMat<Z64>,
    /// `rows·cols` verified truncation pairs (`OpKind::MatMulTr`).
    pub(crate) pairs: Vec<TruncPair>,
    /// Second pooled wire-mask skeleton — training **gradient** gates
    /// (`A_lᵀ ∘ E_l`) have *both* operands live, so the bundle carries a
    /// mask per operand and the wave re-masks each under its own
    /// ([`gen_grad_corr`]). `None` for resident-operand gates.
    pub(crate) lam_y: Option<MMat<Z64>>,
    /// Pre-exchanged + pre-checked `Π_BitInj` material for the drelu
    /// gating that rides a training **back-propagation** gate
    /// (`E_l ∘ W_lᵀ` followed by `drelu·(·)` — see
    /// [`crate::pool::refill::fill_train_vec`]). `None` elsewhere.
    pub(crate) binj: Option<BitInjCorr>,
    /// Per-key fill sequence number, assigned by `Pool::push_mat` — lets
    /// tests pin down FIFO/no-interleave behaviour under refill.
    pub(crate) seq: u64,
}

impl MatCorr {
    /// The circuit position this material was generated for.
    pub fn key(&self) -> CircuitKey {
        self.key
    }

    /// The resident model (= tenant) this material belongs to — the shard
    /// axis [`crate::pool::Pool::quarantine_model`] drains and poisons when
    /// a tenant-scoped abort quarantines its owner.
    pub fn model(&self) -> u64 {
        self.key.model
    }

    /// Fill sequence number within this item's keyed queue.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    // ---- failure-injection hooks (a locally corrupted pool models a
    // malicious party; the online checks must abort) ----

    /// Corrupt one held component of the pooled wire-mask skeleton.
    pub fn tamper_lam_x(&mut self) {
        match &mut self.lam_x {
            MMat::Eval { lam_prev, .. } => lam_prev.data_mut()[0] += Z64(1),
            MMat::Helper { lam } => lam[0].data_mut()[0] += Z64(1),
        }
    }

    /// Corrupt a held `r` component of the first pooled truncation pair.
    /// Returns false when the item carries no pairs (`OpKind::MatMul`).
    pub fn tamper_pair_r(&mut self) -> bool {
        if let Some(p) = self.pairs.first_mut() {
            for c in p.r.iter_mut() {
                if let Some(v) = c {
                    *v += Z64(1);
                    return true;
                }
            }
        }
        false
    }
}

/// Pre-draw one input wire mask (PRF-only; no messages) through `Π_Sh`'s
/// own batched mask sampler ([`sample_mask_vecs`]) — same scope pattern,
/// same per-stream order as an inline sharing, so a pooled mask is
/// draw-for-draw what the inline path would have produced, while the
/// keystream fills in one bulk pass per scope and the SoA component
/// matrices are built directly (no per-element `MShare` materialisation).
/// Returns the party's skeleton and — where all three components are held
/// (dealer, P0) — the full mask.
pub(crate) fn sample_wire_mask(
    ctx: &mut Ctx,
    dealer: PartyId,
    rows: usize,
    cols: usize,
) -> (MMat<Z64>, Option<Matrix<Z64>>) {
    ctx.offline(|ctx| {
        let me = ctx.id();
        let n = rows * cols;
        let lam = sample_mask_vecs::<Z64>(ctx, dealer, n);
        let full = full_masks(&lam, n).map(|v| Matrix::from_vec(rows, cols, v));
        // same assembly helper as share_mat_n — the pooled==inline mask
        // layout invariant lives in proto::sharing, not here
        let m_skel = me.is_evaluator().then(|| Matrix::zeros(rows, cols));
        (assemble_mmat(me, lam, m_skel, rows, cols), full)
    })
}

/// Pre-generate `n` circuit-keyed matrix correlations for `key` against the
/// resident model share `w` into the attached pool. Runs the real offline
/// protocols — wire-mask PRF draws, the `matmul_offline` γ-exchange,
/// truncation-pair generation + verification — all metered under
/// `Phase::Offline`, and flushes its own deferred verification digests so a
/// later serving wave's flush carries no offline traffic.
pub fn fill_mat(ctx: &mut Ctx, key: CircuitKey, w: &MMat<Z64>, n: usize) -> Result<(), Abort> {
    assert!(ctx.has_pool(), "fill_mat requires an attached pool");
    for _ in 0..n {
        let item = gen_mat_corr(ctx, key, w)?;
        ctx.pool.as_mut().expect("pool attached").push_mat(item);
    }
    // Fill is a natural barrier: settle the deferred offline digests here so
    // the serving window between waves stays offline-silent.
    ctx.flush_verify()
}

/// Generate one [`MatCorr`] bundle for `key` against the resident share
/// `w` — the loop body of [`fill_mat`], split out so
/// [`crate::pool::relu::fill_mat_relu`] can pair each matrix bundle with
/// the ReLU bundle generated **against its truncation pairs**. Deferred
/// verification digests are the caller's to flush.
pub(crate) fn gen_mat_corr(
    ctx: &mut Ctx,
    key: CircuitKey,
    w: &MMat<Z64>,
) -> Result<MatCorr, Abort> {
    assert_eq!(
        (key.inner, key.cols),
        w.dims(),
        "resident model share must match the key shape"
    );
    let (lam_x, lam_x_full) = sample_wire_mask(ctx, key.dealer, key.rows, key.inner);
    let with_lam_z = matches!(key.op, OpKind::MatMul);
    let corr = matmul_offline(ctx, &lam_x, w, with_lam_z)?;
    let pairs = match key.op {
        OpKind::MatMulTr { shift } => gen_trunc_pairs(ctx, key.rows * key.cols, shift)?,
        OpKind::MatMul => Vec::new(),
        OpKind::Relu { .. } => panic!("Relu positions pool ReluCorr bundles, not MatCorr"),
    };
    Ok(MatCorr {
        key,
        lam_x,
        lam_x_full,
        gamma: corr.gamma,
        lam_z: corr.lam_z,
        pairs,
        lam_y: None,
        binj: None,
        seq: 0, // assigned by push_mat
    })
}

/// Generate one [`MatCorr`] bundle for a training **gradient** gate
/// (`A_lᵀ ∘ E_l`), where — unlike the serving gates — *both* operands are
/// live shares of the wave: the bundle pools a wire mask per operand
/// (`Λ_X` for the transposed activation, `Λ_Y` for the error), the
/// `⟨Γ⟩` exchanged against the two skeletons, and one verified truncation
/// pair per output element at the key's shift (which folds `α/B` into the
/// free truncation). The wave re-masks each operand under its own pooled
/// mask ([`crate::proto::sharing::remask_mat`]) and runs only the online
/// exchange — zero offline-phase messages, same as the resident-operand
/// gates. Deferred digests are the caller's to flush.
pub(crate) fn gen_grad_corr(ctx: &mut Ctx, key: CircuitKey) -> Result<MatCorr, Abort> {
    let shift = match key.op {
        OpKind::MatMulTr { shift } => shift,
        _ => panic!("gen_grad_corr requires an OpKind::MatMulTr key"),
    };
    let (lam_x, lam_x_full) = sample_wire_mask(ctx, key.dealer, key.rows, key.inner);
    let (lam_y, _) = sample_wire_mask(ctx, key.dealer, key.inner, key.cols);
    let corr = matmul_offline(ctx, &lam_x, &lam_y, false)?;
    let pairs = gen_trunc_pairs(ctx, key.rows * key.cols, shift)?;
    Ok(MatCorr {
        key,
        lam_x,
        lam_x_full,
        gamma: corr.gamma,
        lam_z: corr.lam_z,
        pairs,
        lam_y: Some(lam_y),
        binj: None,
        seq: 0, // assigned by push_mat
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{P0, P2};
    use crate::pool::Pool;
    use crate::ring::fixed::FRAC_BITS;

    fn key(layer: u32) -> CircuitKey {
        CircuitKey {
            model: 1,
            layer,
            op: OpKind::MatMulTr { shift: FRAC_BITS },
            rows: 2,
            inner: 3,
            cols: 1,
            dealer: P2,
        }
    }

    fn dummy(k: CircuitKey) -> MatCorr {
        MatCorr {
            key: k,
            lam_x: MMat::zero(P0, k.rows, k.inner),
            lam_x_full: None,
            gamma: MatGamma::Helper([
                Matrix::zeros(k.rows, k.cols),
                Matrix::zeros(k.rows, k.cols),
                Matrix::zeros(k.rows, k.cols),
            ]),
            lam_z: MMat::zero(P0, k.rows, k.cols),
            pairs: Vec::new(),
            lam_y: None,
            binj: None,
            seq: 0,
        }
    }

    #[test]
    fn pop_is_fifo_and_refill_appends() {
        let mut pool = Pool::new();
        let k = key(0);
        pool.push_mat(dummy(k));
        pool.push_mat(dummy(k));
        let a = pool.pop_mat(&k).unwrap().expect("stocked");
        assert_eq!(a.seq(), 0);
        // a background refill between pops appends — never interleaves
        pool.push_mat(dummy(k));
        let b = pool.pop_mat(&k).unwrap().expect("stocked");
        assert_eq!(b.seq(), 1, "refill must append behind in-flight material");
        let c = pool.pop_mat(&k).unwrap().expect("stocked");
        assert_eq!(c.seq(), 2);
        assert!(pool.pop_mat(&k).unwrap().is_none(), "drained");
        assert_eq!(pool.stats().mat_hits, 3);
        assert_eq!(pool.stats().mat_misses, 1);
    }

    #[test]
    fn cross_key_pop_fails_closed() {
        let mut pool = Pool::new();
        let (ka, kb) = (key(0), key(1));
        pool.push_mat(dummy(ka));
        pool.push_mat(dummy(kb));
        assert!(pool.cross_file_front_mat(&ka, &kb), "hook moves the item");
        // the queue under kb now fronts material generated for ka
        assert!(pool.pop_mat(&kb).is_err(), "wrong-key material must fail closed");
        // the honest queue under ka is simply empty → miss, not an error
        assert!(pool.pop_mat(&ka).unwrap().is_none());
    }
}
