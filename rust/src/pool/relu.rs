//! Circuit-keyed **nonlinear correlation pooling** — extends the keyed
//! matrix pool ([`super::mat`]) to ReLU so a warm keyed wave's *entire*
//! pipeline (share → `Π_MatMulTr` → ReLU → reconstruct) sends **zero
//! offline-phase messages**.
//!
//! ## Why the matrix pool alone was not enough
//!
//! PR 2 made the linear layer offline-silent, but ReLU still leaked
//! offline work into the wave: `Π_BitExt`'s internal `Π_Mult` γ-exchanged
//! live (only its *mask* material was poolable from the shared typed
//! queue), and `Π_BitInj`'s offline sharings + checks (Figs. 15/17) ran
//! live too. Both depend only on **wire masks** that are themselves
//! poolable per circuit position:
//!
//! * the multiplication is `r·v` where `r` comes from the pooled
//!   [`crate::convert::BitExtMask`] and `v` is the `Π_MatMulTr` output, whose mask is
//!   `λ_v = −rᵗ` — embedded in the *matrix* bundle's truncation pairs;
//! * the injected bit's mask is `λ_b = λ_x ⊕ λ_y`, where `λ_x` comes from
//!   the pooled mask and `λ_y` is the `(P3, P0)` `Π_vSh` mask of
//!   `y = msb(rv)` — pre-drawable with `Π_vSh`'s own scope pattern.
//!
//! ## `ReluCorr` bundle
//!
//! One bundle serves one whole keyed ReLU evaluation of width `n`
//! ([`super::mat::OpKind::Relu`]): the `n` bit-extraction masks, the
//! pre-exchanged `⟨γ_{r·v}⟩` + `λ_z` of the internal `Π_Mult`, the
//! pre-drawn `y`-sharing masks, and the pre-exchanged + pre-**checked**
//! `Π_BitInj` material. Because `γ_{r·v}` and the injection material are
//! functions of the *matrix* bundle's truncation pairs, a ReLU bundle is
//! generated **paired** with its matrix bundle ([`fill_mat_relu`]) and the
//! two queues drain in lockstep — bundle `k` of the ReLU queue matches
//! bundle `k` of the matrix queue by FIFO construction.
//!
//! Pops carry the same semantics as the matrix pool: atomic whole-bundle,
//! per-key FIFO sequence numbers, wrong-key pops **fail closed** (abort,
//! never an online phase run on wrong-position correlations), and the
//! failure-injection hooks model a malicious party corrupting or
//! replaying its local copy — the online vouch/expect digests catch every
//! case (`tests/equivalence.rs` locks this down).

use crate::convert::bit2a::{bitinj_offline, BitInjCorr};
use crate::convert::bitext::gen_bitext_masks;
use crate::net::{Abort, P0, P3};
use crate::proto::mult::{mult_gamma_offline, sample_lam_share, GammaView};
use crate::proto::sharing::{sample_vsh_masks, vsh_mask_skeleton, VshMask};
use crate::proto::Ctx;
use crate::ring::{Bit, Z64};
use crate::sharing::{MMat, MShare};

use super::mat::{gen_mat_corr, CircuitKey, OpKind};

/// The ReLU position riding a matrix gate: same model/layer/shape/dealer,
/// `op` replaced by [`OpKind::Relu`] over the gate's `rows·cols` outputs.
pub fn relu_key_for(mat_key: &CircuitKey) -> CircuitKey {
    CircuitKey {
        op: OpKind::Relu { n: mat_key.rows * mat_key.cols },
        ..*mat_key
    }
}

/// One pooled nonlinear correlation bundle — everything the keyed ReLU's
/// offline phase would otherwise produce live (see the module docs).
#[derive(Clone)]
pub struct ReluCorr {
    pub(crate) key: CircuitKey,
    /// `Π_BitExt` mask material, stored SoA (`[[r]]` and `[[msb r]]^B` as
    /// separate vectors): the online phase consumes the two components in
    /// separate passes — `r` feeds the `Π_Mult` exchange, `x` the final
    /// xor — so splitting once at fill time lets a warm keyed wave borrow
    /// both as slices with **zero** per-wave share-vector materialisation.
    pub(crate) r_masks: Vec<MShare<Z64>>,
    pub(crate) x_masks: Vec<MShare<Bit>>,
    /// Pre-exchanged `⟨γ_{r·v}⟩` against the paired matrix bundle's
    /// output masks (`λ_v = −rᵗ`).
    pub(crate) gamma: GammaView<Z64>,
    /// λ_z skeleton of the internal `Π_Mult` (shared across the batch,
    /// exactly like the inline path).
    pub(crate) lam_z: MShare<Z64>,
    /// Pre-drawn `(P3, P0)` `Π_vSh` masks for `y = msb(rv)`.
    pub(crate) y_masks: Vec<VshMask<Bit>>,
    /// Pre-exchanged + pre-checked `Π_BitInj` material for `(1⊕b)·v`.
    pub(crate) binj: BitInjCorr,
    /// Per-key fill sequence number, assigned by
    /// [`crate::pool::Pool::push_relu`].
    pub(crate) seq: u64,
}

impl ReluCorr {
    /// The circuit position this material was generated for.
    pub fn key(&self) -> CircuitKey {
        self.key
    }

    /// Fill sequence number within this item's keyed queue.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The resident model (= tenant) this material belongs to — the shard
    /// axis [`crate::pool::Pool::quarantine_model`] drains and poisons when
    /// a tenant-scoped abort quarantines its owner.
    pub fn model(&self) -> u64 {
        self.key.model
    }

    // ---- failure-injection hooks (a locally corrupted pool models a
    // malicious party; the online checks must abort) ----

    /// Corrupt one held element of the pre-exchanged `⟨γ_{r·v}⟩`.
    pub fn tamper_gamma(&mut self) {
        match &mut self.gamma {
            GammaView::Eval { next, .. } => next[0] += Z64(1),
            GammaView::Helper(all) => all[0][0] += Z64(1),
        }
    }

    /// Corrupt a held λ component of the first mask's `[[r]]` share.
    pub fn tamper_mask_r(&mut self) {
        match &mut self.r_masks[0] {
            MShare::Eval { lam_next, .. } => *lam_next += Z64(1),
            MShare::Helper { lam } => lam[0] += Z64(1),
        }
    }
}

/// Generate one [`ReluCorr`] bundle for `key` against the output-wire
/// skeletons `vs_skel` of the paired matrix bundle (`m = 0`, `λ_v = −rᵗ`).
/// Runs the real offline protocols — mask generation, the `Π_Mult`
/// γ-exchange, the `Π_BitInj` sharings and checks — all metered under
/// `Phase::Offline`. Deferred digests are the caller's to flush.
pub(crate) fn gen_relu_corr(
    ctx: &mut Ctx,
    key: CircuitKey,
    vs_skel: &[MShare<Z64>],
) -> Result<ReluCorr, Abort> {
    let n = match key.op {
        OpKind::Relu { n } => n,
        _ => panic!("gen_relu_corr requires an OpKind::Relu key"),
    };
    assert_eq!(vs_skel.len(), n, "one output-wire skeleton per ReLU element");
    let me = ctx.id();

    // SoA split at fill time: the bundle stores r and x as separate
    // vectors, so the keyed wave borrows them directly (no per-wave
    // collect on the hot path)
    let masks = gen_bitext_masks(ctx, n)?;
    let r_masks: Vec<MShare<Z64>> = masks.iter().map(|m| m.r).collect();
    let x_masks: Vec<MShare<Bit>> = masks.iter().map(|m| m.x).collect();
    // the internal Π_Mult's correlation: λ_z (PRF-only) + the γ-exchange,
    // computed against λ_r (pooled) and λ_v (the pairs' −rᵗ)
    let lam_z = ctx.offline(|ctx| sample_lam_share::<Z64>(ctx));
    let gamma = mult_gamma_offline(ctx, &r_masks, vs_skel)?;
    // the y = msb(rv) sharing mask, with Π_vSh's own (P3, P0) scope pattern
    let y_masks = sample_vsh_masks::<Bit>(ctx, (P3, P0), n);
    // the injected bit's wire is b = x ⊕ y: λ_b = λ_x ⊕ λ_y, m still 0 —
    // Π_BitInj's offline phase reads only the λ components
    let b_skel: Vec<MShare<Bit>> = x_masks
        .iter()
        .zip(&y_masks)
        .map(|(x, ym)| *x + vsh_mask_skeleton(me, ym))
        .collect();
    let binj = bitinj_offline(ctx, &b_skel, vs_skel)?;

    Ok(ReluCorr {
        key,
        r_masks,
        x_masks,
        gamma,
        lam_z,
        y_masks,
        binj,
        seq: 0, // assigned by push_relu
    })
}

/// Pre-generate `n` **paired** matrix + ReLU correlation bundles into the
/// attached pool: each [`super::MatCorr`] for `mat_key` is immediately
/// followed by the [`ReluCorr`] for `relu_key` generated against its
/// truncation pairs, so the two keyed queues advance in lockstep and
/// bundle `k` of one always matches bundle `k` of the other. Runs the real
/// offline protocols (metered `Phase::Offline`) and flushes its own
/// deferred verification digests, so a later serving wave's flush carries
/// no offline traffic.
pub fn fill_mat_relu(
    ctx: &mut Ctx,
    mat_key: CircuitKey,
    relu_key: CircuitKey,
    w: &MMat<Z64>,
    n: usize,
) -> Result<(), Abort> {
    assert!(
        matches!(mat_key.op, OpKind::MatMulTr { .. }),
        "a pooled ReLU rides a truncated matrix gate"
    );
    assert_eq!(
        relu_key,
        relu_key_for(&mat_key),
        "the ReLU key must be the mat key's paired position"
    );
    assert!(ctx.has_pool(), "fill_mat_relu requires an attached pool");
    for _ in 0..n {
        let mat = gen_mat_corr(ctx, mat_key, w)?;
        // the wave's ReLU input is the Π_MatMulTr output, whose share is
        // pairs[i].rt.add_const(·): λ_v = λ(rt), m online-only — so the
        // pairs' rt shares ARE the output-wire skeletons
        let vs_skel: Vec<MShare<Z64>> = mat.pairs.iter().map(|p| p.rt).collect();
        let relu = gen_relu_corr(ctx, relu_key, &vs_skel)?;
        let pool = ctx.pool.as_mut().expect("pool attached");
        pool.push_mat(mat);
        pool.push_relu(relu);
    }
    ctx.flush_verify()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2};
    use crate::pool::Pool;
    use crate::proto::run_4pc;
    use crate::ring::fixed::FRAC_BITS;
    use crate::ring::Matrix;

    fn mat_key(layer: u32) -> CircuitKey {
        CircuitKey {
            model: 4,
            layer,
            op: OpKind::MatMulTr { shift: FRAC_BITS },
            rows: 2,
            inner: 2,
            cols: 1,
            dealer: P2,
        }
    }

    #[test]
    fn relu_key_mirrors_the_mat_position() {
        let mk = mat_key(3);
        let rk = relu_key_for(&mk);
        assert_eq!(rk.op, OpKind::Relu { n: 2 });
        assert_eq!((rk.model, rk.layer, rk.dealer), (mk.model, mk.layer, mk.dealer));
        // different layers → different relu keys (position-keyed)
        assert_ne!(relu_key_for(&mat_key(4)), rk);
    }

    #[test]
    fn fill_pairs_mat_and_relu_queues_in_lockstep() {
        let mk = mat_key(0);
        let rk = relu_key_for(&mk);
        let run = run_4pc(NetProfile::zero(), 870, move |ctx| {
            let w0 = Matrix::from_fn(2, 1, |r, _| Z64(5 + r as u64));
            let w = crate::testutil::share_mat(ctx, P1, &w0)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mk, rk, &w, 2)?;
            let pool = ctx.pool.as_ref().unwrap();
            let lens = (pool.len_mat(&mk), pool.len_relu(&rk));
            // FIFO seq numbers advance together
            let a = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let b = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            Ok((lens, a.seq(), b.seq()))
        });
        let (outs, report) = run.expect_ok();
        for ((m, r), s0, s1) in &outs {
            assert_eq!((*m, *r), (2, 2), "paired fill stocks both queues");
            assert_eq!((*s0, *s1), (0, 1), "FIFO seq order");
        }
        // generation is offline traffic (online carries only the one-time
        // resident-weight sharing, 2·d·ℓ bits)
        assert!(report.value_bits[0] > 0);
        assert_eq!(report.value_bits[1], 2 * 2 * 64, "fill itself must be online-silent");
    }

    #[test]
    fn cross_key_relu_pop_fails_closed() {
        let (ka, kb) = (relu_key_for(&mat_key(0)), relu_key_for(&mat_key(1)));
        let run = run_4pc(NetProfile::zero(), 871, move |ctx| {
            let w0 = Matrix::from_fn(2, 1, |r, _| Z64(9 + r as u64));
            let w = crate::testutil::share_mat(ctx, P1, &w0)?;
            ctx.attach_pool(Pool::new());
            fill_mat_relu(ctx, mat_key(0), ka, &w, 1)?;
            fill_mat_relu(ctx, mat_key(1), kb, &w, 1)?;
            let pool = ctx.pool_mut().unwrap();
            assert!(pool.cross_file_front_relu(&ka, &kb), "hook moves the item");
            // kb's queue now fronts ka-keyed material → fail closed
            let err = pool.pop_relu(&kb).is_err();
            // ka's queue is simply empty → miss, not an error
            let miss = pool.pop_relu(&ka).unwrap().is_none();
            Ok((err, miss))
        });
        let (outs, _) = run.expect_ok();
        for (err, miss) in &outs {
            assert!(*err, "wrong-key relu material must fail closed");
            assert!(*miss);
        }
    }
}
