//! Offline precomputation pool — keyed, typed correlated randomness
//! generated ahead of time (§VI-A.a's offline/online decoupling as a
//! serving-system component).
//!
//! The paper's efficiency story assumes all input-independent work is done
//! *before* queries arrive: the online phase then costs only
//! `compute + rounds×latency + bytes/bandwidth`. The seed executed both
//! phases inline per protocol call, so a serving deployment paid offline
//! cost on every request. This module closes that gap:
//!
//! * [`Pool`] holds typed queues of pre-generated material:
//!   - **truncation pairs** (`(r, [[rᵗ]])`, keyed by shift) for
//!     `Π_MultTr`/`Π_MatMulTr`,
//!   - **λ-skeletons** (fresh `[[0]]`-masks, arithmetic and boolean) — the
//!     multiplication/dot-product output randomness of `Π_Mult`/`Π_DotP`
//!     and the γ-free multiplication inside `Π_Bit2A`,
//!   - **bit-extraction masks** (`[[r]], [[msb r]]^B` pairs) for
//!     `Π_BitExt` and therefore ReLU/Sigmoid,
//!   - **circuit-keyed matrix correlations** ([`mat`]): per
//!     [`CircuitKey`] (model · layer · op · shape · dealer), the pre-drawn
//!     input **wire mask**, the pre-exchanged `⟨Γ⟩` of `matmul_offline`
//!     against the resident model, and the gate's `λ_Z`/truncation pairs —
//!     the bundle that makes a pool-backed serving wave's linear layer
//!     **message-free** per request,
//!   - **circuit-keyed nonlinear bundles** ([`relu`]): per
//!     `OpKind::Relu` position, the bit-extraction masks **plus** the
//!     pre-exchanged `⟨γ_{r·v}⟩` of `Π_BitExt`'s internal `Π_Mult` and the
//!     pre-checked `Π_BitInj` material, generated paired with the matrix
//!     bundle — completing the invariant that **every** per-request
//!     message in a warm keyed wave is online-phase.
//! * `fill_*` run the real generation protocols (messages, verification,
//!   metering all land under [`Phase::Offline`](crate::net::Phase)) and
//!   stock the party's pool.
//! * A **background refill producer** ([`refill`]) registers per-resource
//!   water marks and tops queues back up *between* serving waves instead of
//!   one workload-sized up-front fill.
//! * Pool-aware entry points (`proto::trunc::trunc_pairs`,
//!   `proto::mult::lam_shares`, `convert::bitext::bitext_many`,
//!   `proto::dotp::matmul_keyed`, `proto::trunc::matmul_tr_keyed`) pop from
//!   an attached pool and fall back to inline generation when it cannot
//!   serve the full request.
//!
//! **Determinism contract.** Consumption is all-or-nothing per request: a
//! pool either serves the entire batch or none of it, so all four parties —
//! which fill and pop in lockstep, like the PRF streams the pool caches —
//! agree on every fallback decision. Exhaustion therefore degrades to the
//! seed's inline path, never to a desync.
//!
//! **Per-layer key vectors (deep circuits).** An N-layer resident network
//! registers one `(MatCorr, ReluCorr?)` key pair **per layer** (same
//! `model`, `layer = 0..N−1`; the final layer is matmul-only), and a warm
//! wave consumes one whole **bundle vector** in gate order: layer 0's mat
//! (+relu) bundle, then layer 1's, … The atomicity contract is two-sided:
//! - *fill side* ([`refill::fill_layer_vec`]): vectors are restocked as a
//!   unit, layer-major in gate order within one lockstep tick, so stock
//!   counted by [`Pool::layer_vec_stock`] (the min paired stock across
//!   layers) is always a whole number of poppable vectors;
//! - *pop side* ([`Pool::check_layer_vec`]): a wave first checks that
//!   **every** layer fronts a bundle; any gap sends the *entire* wave down
//!   the inline path (one recorded miss), never a partially keyed circuit.
//!   With the gate passed, the per-layer keyed entry points pop in gate
//!   order; a wrong-keyed front at any layer still fails closed.
//!
//! Layer ≥ 1 inputs are already-shared (the previous layer's output), so
//! their keyed matmul re-masks the input under the bundle's pooled wire
//! mask by opening the uniform mask delta online
//! ([`crate::proto::sharing::remask_mat`]) — the offline phase stays
//! message-free across the whole vector.
//!
//! **Tamper safety.** Pool items are shares of *verified* correlations; a
//! party that tampers with (or replays) its local copy is exactly a
//! malicious party mis-executing the online phase, and the existing
//! vouch/expect digests and reconstruction cross-checks catch it (the
//! failure-injection suite in `tests/equivalence.rs` exercises both).

pub mod mat;
pub mod refill;
pub mod relu;

pub use mat::{fill_mat, CircuitKey, MatCorr, OpKind};
pub use refill::{
    fill_layer_vec, fill_train_vec, LayerTarget, Refill, RefillOutcome, TrainLayerTarget,
    WaterMarks,
};
pub use relu::{fill_mat_relu, relu_key_for, ReluCorr};

use std::collections::{HashMap, HashSet, VecDeque};

use crate::convert::bitext::{gen_bitext_masks, BitExtMask};
use crate::net::Abort;
use crate::proto::mult::sample_lam_share;
use crate::proto::trunc::{gen_trunc_pairs, TruncPair};
use crate::proto::Ctx;
use crate::ring::{Bit, Ring, Z64};
use crate::sharing::MShare;

/// Pool hit/miss counters, per material kind. A *miss* is recorded when a
/// pool was attached but could not serve the full request (exhaustion →
/// inline fallback); requests against an unattached pool are not counted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub trunc_hits: u64,
    pub trunc_misses: u64,
    pub lam_hits: u64,
    pub lam_misses: u64,
    pub bitext_hits: u64,
    pub bitext_misses: u64,
    /// Circuit-keyed matrix correlation pops ([`mat`]).
    pub mat_hits: u64,
    pub mat_misses: u64,
    /// Circuit-keyed nonlinear (ReLU) bundle pops ([`relu`]).
    pub relu_hits: u64,
    pub relu_misses: u64,
}

impl PoolStats {
    pub fn hits(&self) -> u64 {
        self.trunc_hits + self.lam_hits + self.bitext_hits + self.mat_hits + self.relu_hits
    }

    pub fn misses(&self) -> u64 {
        self.trunc_misses
            + self.lam_misses
            + self.bitext_misses
            + self.mat_misses
            + self.relu_misses
    }
}

/// One party's pool of pre-generated correlated randomness.
#[derive(Default)]
pub struct Pool {
    /// Truncation pairs, keyed by the arithmetic shift they were built for.
    trunc: HashMap<u32, VecDeque<TruncPair>>,
    /// Fresh λ_z skeletons over `Z_{2^64}`.
    lam_z64: VecDeque<MShare<Z64>>,
    /// Fresh λ_z skeletons over `Z_2`.
    lam_bit: VecDeque<MShare<Bit>>,
    /// `Π_BitExt` offline material.
    bitext: VecDeque<BitExtMask>,
    /// Circuit-keyed matrix correlations (wire masks + `⟨Γ⟩` + pairs/λ_Z).
    mat: HashMap<CircuitKey, VecDeque<MatCorr>>,
    /// Per-key fill sequence counters (FIFO/no-interleave accounting).
    mat_seq: HashMap<CircuitKey, u64>,
    /// Circuit-keyed nonlinear bundles ([`relu`]: bitext masks +
    /// pre-exchanged `⟨γ_{r·v}⟩` + pre-checked `Π_BitInj` material).
    relu: HashMap<CircuitKey, VecDeque<ReluCorr>>,
    relu_seq: HashMap<CircuitKey, u64>,
    /// Models whose keyed shards are quarantined: their stock is drained
    /// and future pushes for them are dropped, so every pop under their
    /// keys deterministically **misses** (→ the secure inline fallback).
    quarantined: HashSet<u64>,
    stats: PoolStats,
}

impl Pool {
    pub fn new() -> Pool {
        Pool::default()
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    // ---- stock levels ---------------------------------------------------

    pub fn len_trunc(&self, shift: u32) -> usize {
        self.trunc.get(&shift).map_or(0, VecDeque::len)
    }

    pub fn len_lam<R: Ring>(&self) -> usize {
        self.lam_queue::<R>().map_or(0, VecDeque::len)
    }

    pub fn len_bitext(&self) -> usize {
        self.bitext.len()
    }

    pub fn len_mat(&self, key: &CircuitKey) -> usize {
        self.mat.get(key).map_or(0, VecDeque::len)
    }

    pub fn len_relu(&self, key: &CircuitKey) -> usize {
        self.relu.get(key).map_or(0, VecDeque::len)
    }

    pub fn is_empty(&self) -> bool {
        self.trunc.values().all(VecDeque::is_empty)
            && self.lam_z64.is_empty()
            && self.lam_bit.is_empty()
            && self.bitext.is_empty()
            && self.mat.values().all(VecDeque::is_empty)
            && self.relu.values().all(VecDeque::is_empty)
    }

    // ---- typed λ queue dispatch -----------------------------------------

    fn lam_queue<R: Ring>(&self) -> Option<&VecDeque<MShare<R>>> {
        use std::any::Any;
        if let Some(q) = (&self.lam_z64 as &dyn Any).downcast_ref::<VecDeque<MShare<R>>>() {
            return Some(q);
        }
        (&self.lam_bit as &dyn Any).downcast_ref::<VecDeque<MShare<R>>>()
    }

    fn lam_queue_mut<R: Ring>(&mut self) -> Option<&mut VecDeque<MShare<R>>> {
        use std::any::Any;
        if (&self.lam_z64 as &dyn Any).is::<VecDeque<MShare<R>>>() {
            return (&mut self.lam_z64 as &mut dyn Any).downcast_mut::<VecDeque<MShare<R>>>();
        }
        (&mut self.lam_bit as &mut dyn Any).downcast_mut::<VecDeque<MShare<R>>>()
    }

    // ---- push (fill side) -----------------------------------------------

    pub fn push_trunc(&mut self, shift: u32, pairs: Vec<TruncPair>) {
        self.trunc.entry(shift).or_default().extend(pairs);
    }

    pub fn push_lam<R: Ring>(&mut self, items: Vec<MShare<R>>) {
        let q = self
            .lam_queue_mut::<R>()
            .expect("pool stocks Z64 and Bit λ-skeletons only");
        q.extend(items);
    }

    pub fn push_bitext(&mut self, masks: Vec<BitExtMask>) {
        self.bitext.extend(masks);
    }

    /// Stock one circuit-keyed matrix correlation under its embedded key,
    /// stamping the per-key FIFO sequence number. Pushes for a
    /// [quarantined](Pool::quarantine_model) model are dropped.
    pub fn push_mat(&mut self, mut item: MatCorr) {
        let key = item.key();
        if self.quarantined.contains(&key.model) {
            return;
        }
        let seq = self.mat_seq.entry(key).or_insert(0);
        item.seq = *seq;
        *seq += 1;
        self.mat.entry(key).or_default().push_back(item);
    }

    /// Stock one circuit-keyed nonlinear bundle under its embedded key,
    /// stamping the per-key FIFO sequence number. Pushes for a
    /// [quarantined](Pool::quarantine_model) model are dropped.
    pub fn push_relu(&mut self, mut item: ReluCorr) {
        let key = item.key();
        if self.quarantined.contains(&key.model) {
            return;
        }
        let seq = self.relu_seq.entry(key).or_insert(0);
        item.seq = *seq;
        *seq += 1;
        self.relu.entry(key).or_default().push_back(item);
    }

    // ---- pop (consumption side; all-or-nothing) -------------------------

    /// Pop `n` truncation pairs for `shift`, or None (recording a miss) if
    /// fewer are stocked.
    pub fn pop_trunc(&mut self, shift: u32, n: usize) -> Option<Vec<TruncPair>> {
        let q = self.trunc.entry(shift).or_default();
        if q.len() < n {
            self.stats.trunc_misses += 1;
            return None;
        }
        self.stats.trunc_hits += 1;
        Some(q.drain(..n).collect())
    }

    /// Pop `n` λ-skeletons of ring `R`, or None (recording a miss).
    pub fn pop_lam<R: Ring>(&mut self, n: usize) -> Option<Vec<MShare<R>>> {
        let available = self.lam_queue::<R>().map(VecDeque::len);
        match available {
            Some(len) if len >= n => {
                let out = self
                    .lam_queue_mut::<R>()
                    .expect("queue just observed")
                    .drain(..n)
                    .collect();
                self.stats.lam_hits += 1;
                Some(out)
            }
            Some(_) => {
                self.stats.lam_misses += 1;
                None
            }
            None => None,
        }
    }

    /// Pop `n` bit-extraction masks, or None (recording a miss).
    pub fn pop_bitext(&mut self, n: usize) -> Option<Vec<BitExtMask>> {
        if self.bitext.len() < n {
            self.stats.bitext_misses += 1;
            return None;
        }
        self.stats.bitext_hits += 1;
        Some(self.bitext.drain(..n).collect())
    }

    /// Pop one circuit-keyed matrix correlation. `Ok(None)` records a miss
    /// (→ the caller's deterministic inline fallback); an `Err` means the
    /// queue fronts material generated for a **different** key — the
    /// caller must **fail closed** (abort), never run the online phase on
    /// wrong-position correlations. The pop is atomic: the whole bundle
    /// (wire mask + `⟨Γ⟩` + pairs) or nothing.
    pub fn pop_mat(&mut self, key: &CircuitKey) -> Result<Option<MatCorr>, String> {
        let q = match self.mat.get_mut(key) {
            Some(q) => q,
            None => {
                self.stats.mat_misses += 1;
                return Ok(None);
            }
        };
        match q.pop_front() {
            None => {
                self.stats.mat_misses += 1;
                Ok(None)
            }
            Some(item) if item.key() == *key => {
                self.stats.mat_hits += 1;
                Ok(Some(item))
            }
            Some(item) => Err(format!(
                "pool material generated for {:?} popped under {:?} — failing closed",
                item.key(),
                key
            )),
        }
    }

    /// Pop one circuit-keyed nonlinear bundle — the [`pop_mat`](Pool::pop_mat)
    /// semantics, verbatim: `Ok(None)` records a miss (→ the caller's
    /// deterministic inline fallback); `Err` means the queue fronts
    /// material generated for a **different** key and the caller must fail
    /// closed. The pop is atomic: the whole bundle (masks + `⟨γ⟩` +
    /// `Π_BitInj` material) or nothing.
    pub fn pop_relu(&mut self, key: &CircuitKey) -> Result<Option<ReluCorr>, String> {
        let q = match self.relu.get_mut(key) {
            Some(q) => q,
            None => {
                self.stats.relu_misses += 1;
                return Ok(None);
            }
        };
        match q.pop_front() {
            None => {
                self.stats.relu_misses += 1;
                Ok(None)
            }
            Some(item) if item.key() == *key => {
                self.stats.relu_hits += 1;
                Ok(Some(item))
            }
            Some(item) => Err(format!(
                "relu pool material generated for {:?} popped under {:?} — failing closed",
                item.key(),
                key
            )),
        }
    }

    // ---- per-layer key vectors (deep-circuit serving) --------------------

    /// Stock level of a **per-layer key vector** — the number of complete
    /// bundle vectors poppable for an N-layer resident network, i.e. the
    /// minimum paired stock across every layer's `(mat, relu?)` pair.
    /// Watermark refill and `most_depleted` steering measure deep tenants
    /// in this unit: one vector = one warm wave.
    pub fn layer_vec_stock(&self, keys: &[(CircuitKey, Option<CircuitKey>)]) -> usize {
        keys.iter()
            .map(|(mk, rk)| {
                let m = self.len_mat(mk);
                match rk {
                    Some(rk) => m.min(self.len_relu(rk)),
                    None => m,
                }
            })
            .min()
            .unwrap_or(0)
    }

    /// The **all-or-nothing gate** of a deep keyed wave: true iff every
    /// layer's mat queue (and paired relu queue, where the layer has one)
    /// fronts at least one bundle, so the whole vector can be popped in
    /// gate order with no mid-circuit exhaustion. On false, records **one**
    /// mat miss (mirroring the single-gate miss accounting the containment
    /// status classifier reads) and the caller must run the *entire* wave
    /// over the inline path — never a partially keyed circuit. Note this
    /// checks *presence*, not key correctness: a wrong-keyed front still
    /// fails closed inside the per-layer pop, exactly as for single gates.
    pub fn check_layer_vec(&mut self, keys: &[(CircuitKey, Option<CircuitKey>)]) -> bool {
        let ok = self.layer_vec_stock(keys) >= 1;
        if !ok {
            self.stats.mat_misses += 1;
        }
        ok
    }

    /// [`check_layer_vec`](Pool::check_layer_vec) with **per-gate miss
    /// accounting** — the training-wave gate. A training epoch evaluates
    /// `3L−1` matrix gates (forward + grad + back), so when a training
    /// tenant was registered but never warmed, folding the whole cold
    /// vector into one wave-level miss would hide how much material the
    /// refill owes; this variant records one mat miss per missing mat
    /// bundle and one relu miss per missing paired relu bundle instead.
    /// The wave decision is unchanged: all-or-nothing, and a cold vector
    /// sends the entire epoch down the inline path.
    pub fn check_layer_vec_gates(&mut self, keys: &[(CircuitKey, Option<CircuitKey>)]) -> bool {
        let ok = self.layer_vec_stock(keys) >= 1;
        if !ok {
            for (mk, rk) in keys {
                if self.len_mat(mk) == 0 {
                    self.stats.mat_misses += 1;
                }
                if let Some(rk) = rk {
                    if self.len_relu(rk) == 0 {
                        self.stats.relu_misses += 1;
                    }
                }
            }
        }
        ok
    }

    // ---- quarantine (abort blast-radius containment) --------------------

    /// Drain-and-poison every keyed shard belonging to `model`: all stocked
    /// [`MatCorr`]/[`ReluCorr`] bundles whose embedded key names the model
    /// are discarded **now**, and future [`push_mat`](Pool::push_mat)/
    /// [`push_relu`](Pool::push_relu) for the model are dropped, so every
    /// later pop under its keys deterministically misses and the tenant is
    /// served by the secure inline path. Returns `(mat, relu)` drained
    /// counts. All four parties quarantine in lockstep (the decision is a
    /// function of public wave metadata), so stock levels stay agreed.
    pub fn quarantine_model(&mut self, model: u64) -> (usize, usize) {
        self.quarantined.insert(model);
        let mut drained = (0usize, 0usize);
        for (key, q) in self.mat.iter_mut() {
            if key.model == model {
                drained.0 += q.len();
                q.clear();
            }
        }
        for (key, q) in self.relu.iter_mut() {
            if key.model == model {
                drained.1 += q.len();
                q.clear();
            }
        }
        drained
    }

    /// Whether `model`'s keyed shards are quarantined.
    pub fn is_model_quarantined(&self, model: u64) -> bool {
        self.quarantined.contains(&model)
    }

    /// Lift the quarantine on `model`'s keyed shards: future pushes stock
    /// again (the drained queues stay empty until a refill tick restocks
    /// them — rehabilitation never resurrects discarded material). The
    /// registry-side companion is [`crate::sched::ModelRegistry::rehabilitate`];
    /// like the quarantine itself, all four parties lift it in lockstep off
    /// the agreed failover-wave count. Idempotent.
    pub fn unquarantine_model(&mut self, model: u64) {
        self.quarantined.remove(&model);
    }

    // ---- failure-injection hooks ----------------------------------------

    /// Mutable access to the next-to-be-served truncation pair — the
    /// tamper hook of the failure-injection suite (a locally corrupted pool
    /// models a malicious party; the online checks must abort).
    pub fn trunc_front_mut(&mut self, shift: u32) -> Option<&mut TruncPair> {
        self.trunc.get_mut(&shift).and_then(VecDeque::front_mut)
    }

    /// Duplicate the front truncation pair (a replay: this party will serve
    /// the same pair twice while its peers advance). Returns false when
    /// nothing is stocked.
    pub fn replay_front_trunc(&mut self, shift: u32) -> bool {
        let q = match self.trunc.get_mut(&shift) {
            Some(q) => q,
            None => return false,
        };
        match q.front().cloned() {
            Some(front) => {
                q.push_front(front);
                true
            }
            None => false,
        }
    }

    /// Mutable access to the next-to-be-served keyed matrix correlation —
    /// the tamper hook for wire masks and pooled truncation pairs.
    pub fn mat_front_mut(&mut self, key: &CircuitKey) -> Option<&mut MatCorr> {
        self.mat.get_mut(key).and_then(VecDeque::front_mut)
    }

    /// Duplicate the front keyed matrix correlation (a replay of the
    /// pre-exchanged `MatGamma` and its wire mask: this party will serve
    /// the same bundle twice while its peers advance). Returns false when
    /// nothing is stocked.
    pub fn replay_front_mat(&mut self, key: &CircuitKey) -> bool {
        let q = match self.mat.get_mut(key) {
            Some(q) => q,
            None => return false,
        };
        match q.front().cloned() {
            Some(front) => {
                q.push_front(front);
                true
            }
            None => false,
        }
    }

    /// Move the front item of `from`'s queue to the front of `to`'s queue
    /// *without* rewriting its embedded key — a malicious party serving
    /// material at the wrong circuit position. The next honest `pop_mat`
    /// under `to` fails closed. Returns false when `from` is unstocked.
    pub fn cross_file_front_mat(&mut self, from: &CircuitKey, to: &CircuitKey) -> bool {
        let item = match self.mat.get_mut(from).and_then(VecDeque::pop_front) {
            Some(i) => i,
            None => return false,
        };
        self.mat.entry(*to).or_default().push_front(item);
        true
    }

    /// Mutable access to the next-to-be-served nonlinear bundle — the
    /// tamper hook for `⟨γ_{r·v}⟩` and the bit-extraction masks.
    pub fn relu_front_mut(&mut self, key: &CircuitKey) -> Option<&mut ReluCorr> {
        self.relu.get_mut(key).and_then(VecDeque::front_mut)
    }

    /// Duplicate the front nonlinear bundle (a replay: this party will
    /// serve the same masks/γ/injection material twice while its peers
    /// advance). Returns false when nothing is stocked.
    pub fn replay_front_relu(&mut self, key: &CircuitKey) -> bool {
        let q = match self.relu.get_mut(key) {
            Some(q) => q,
            None => return false,
        };
        match q.front().cloned() {
            Some(front) => {
                q.push_front(front);
                true
            }
            None => false,
        }
    }

    /// [`cross_file_front_mat`](Pool::cross_file_front_mat) for nonlinear
    /// bundles: file `from`'s front item at `to`'s position without
    /// rewriting its embedded key. The next honest `pop_relu` under `to`
    /// fails closed. Returns false when `from` is unstocked.
    pub fn cross_file_front_relu(&mut self, from: &CircuitKey, to: &CircuitKey) -> bool {
        let item = match self.relu.get_mut(from).and_then(VecDeque::pop_front) {
            Some(i) => i,
            None => return false,
        };
        self.relu.entry(*to).or_default().push_front(item);
        true
    }
}

// ---- fill protocols (4-party; run under Phase::Offline) ------------------

/// Pre-generate `n` verified truncation pairs for `shift` into the attached
/// pool. Runs the full Fig. 18 offline protocol (generation + the P1/P2
/// linear check), metered under `Phase::Offline`.
pub fn fill_trunc(ctx: &mut Ctx, n: usize, shift: u32) -> Result<(), Abort> {
    let pairs = gen_trunc_pairs(ctx, n, shift)?;
    ctx.pool
        .as_mut()
        .expect("fill_trunc requires an attached pool")
        .push_trunc(shift, pairs);
    Ok(())
}

/// Pre-draw `n` fresh λ_z skeletons of ring `R` into the attached pool
/// (non-interactive: correlated PRF draws only).
pub fn fill_lam<R: Ring>(ctx: &mut Ctx, n: usize) {
    let items: Vec<MShare<R>> =
        ctx.offline(|ctx| (0..n).map(|_| sample_lam_share(ctx)).collect());
    ctx.pool
        .as_mut()
        .expect("fill_lam requires an attached pool")
        .push_lam(items);
}

/// Pre-generate `n` bit-extraction masks (`[[r]]`, `[[msb r]]^B`) into the
/// attached pool — the `Π_BitExt` offline material ReLU/Sigmoid consume.
pub fn fill_bitext(ctx: &mut Ctx, n: usize) -> Result<(), Abort> {
    let masks = gen_bitext_masks(ctx, n)?;
    ctx.pool
        .as_mut()
        .expect("fill_bitext requires an attached pool")
        .push_bitext(masks);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetProfile, P1, P2};
    use crate::proto::{run_4pc, share};
    use crate::ring::fixed::FRAC_BITS;
    use crate::sharing::open;

    #[test]
    fn pop_is_all_or_nothing() {
        let mut pool = Pool::new();
        pool.push_lam::<Z64>(vec![MShare::Helper { lam: [Z64(1), Z64(2), Z64(3)] }; 4]);
        assert_eq!(pool.len_lam::<Z64>(), 4);
        // request more than stocked: nothing drained, miss recorded
        assert!(pool.pop_lam::<Z64>(5).is_none());
        assert_eq!(pool.len_lam::<Z64>(), 4);
        assert_eq!(pool.stats().lam_misses, 1);
        // exact request drains
        assert!(pool.pop_lam::<Z64>(4).is_some());
        assert_eq!(pool.len_lam::<Z64>(), 0);
        assert_eq!(pool.stats().lam_hits, 1);
    }

    #[test]
    fn lam_queues_are_typed() {
        let mut pool = Pool::new();
        pool.push_lam::<Bit>(vec![MShare::Helper { lam: [Bit(true); 3] }; 2]);
        assert_eq!(pool.len_lam::<Bit>(), 2);
        assert_eq!(pool.len_lam::<Z64>(), 0);
        assert!(pool.pop_lam::<Z64>(1).is_none());
        assert!(pool.pop_lam::<Bit>(2).is_some());
    }

    #[test]
    fn fill_trunc_stocks_all_parties_in_sync() {
        let run = run_4pc(NetProfile::zero(), 700, |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 8, FRAC_BITS)?;
            let pool = ctx.detach_pool().unwrap();
            Ok((pool.len_trunc(FRAC_BITS), pool.stats()))
        });
        let (outs, report) = run.expect_ok();
        for (len, _) in &outs {
            assert_eq!(*len, 8);
        }
        // generation traffic is offline-only
        assert!(report.value_bits[0] > 0);
        assert_eq!(report.value_bits[1], 0);
    }

    #[test]
    fn pooled_trunc_pairs_open_consistently() {
        // pairs served from the pool satisfy the r/rᵗ relation, same as
        // inline generation
        let run = run_4pc(NetProfile::zero(), 701, |ctx| {
            ctx.attach_pool(Pool::new());
            fill_trunc(ctx, 4, FRAC_BITS)?;
            crate::proto::trunc::trunc_pairs(ctx, 4, FRAC_BITS)
        });
        let (outs, _) = run.expect_ok();
        for i in 0..4 {
            let r = outs[0][i].r[0].unwrap() + outs[0][i].r[1].unwrap() + outs[0][i].r[2].unwrap();
            let rt = open(&[outs[0][i].rt, outs[1][i].rt, outs[2][i].rt, outs[3][i].rt]);
            let diff = (r.truncate(FRAC_BITS) - rt).as_i64();
            assert!((0..=2).contains(&diff), "pair {i}: rᵗ off by {diff}");
        }
    }

    #[test]
    fn quarantine_drains_and_poisons_only_the_named_model() {
        use crate::net::P0;
        use crate::proto::dotp::MatGamma;
        use crate::ring::Matrix;
        use crate::sharing::MMat;

        fn key(model: u64) -> CircuitKey {
            CircuitKey {
                model,
                layer: 0,
                op: OpKind::MatMulTr { shift: FRAC_BITS },
                rows: 2,
                inner: 3,
                cols: 1,
                dealer: P2,
            }
        }
        fn dummy(k: CircuitKey) -> MatCorr {
            MatCorr {
                key: k,
                lam_x: MMat::zero(P0, k.rows, k.inner),
                lam_x_full: None,
                gamma: MatGamma::Helper([
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                ]),
                lam_z: MMat::zero(P0, k.rows, k.cols),
                pairs: Vec::new(),
                lam_y: None,
                binj: None,
                seq: 0,
            }
        }

        let mut pool = Pool::new();
        let (ka, kb) = (key(7), key(8));
        pool.push_mat(dummy(ka));
        pool.push_mat(dummy(ka));
        pool.push_mat(dummy(kb));

        let (mat, relu) = pool.quarantine_model(7);
        assert_eq!((mat, relu), (2, 0), "only model 7's stock is drained");
        assert!(pool.is_model_quarantined(7));
        assert!(!pool.is_model_quarantined(8));

        // poisoned: restocking is dropped, pops deterministically miss
        pool.push_mat(dummy(ka));
        assert_eq!(pool.len_mat(&ka), 0, "restock of a quarantined model is dropped");
        assert!(pool.pop_mat(&ka).unwrap().is_none(), "quarantined pop is a miss");

        // the innocent model's shard is untouched
        assert!(pool.pop_mat(&kb).unwrap().is_some());

        // lifting the quarantine re-opens the push path, but never
        // resurrects drained material: stock starts from zero
        pool.unquarantine_model(7);
        assert!(!pool.is_model_quarantined(7));
        assert_eq!(pool.len_mat(&ka), 0, "rehabilitation starts from a drained shard");
        pool.push_mat(dummy(ka));
        assert_eq!(pool.len_mat(&ka), 1, "restock flows after unquarantine");
        assert!(pool.pop_mat(&ka).unwrap().is_some());
    }

    #[test]
    fn layer_vec_stock_is_min_over_layers_and_check_is_all_or_nothing() {
        use crate::net::{P0, P2};
        use crate::proto::dotp::MatGamma;
        use crate::ring::Matrix;
        use crate::sharing::MMat;

        fn key(layer: u32) -> CircuitKey {
            CircuitKey {
                model: 9,
                layer,
                op: OpKind::MatMulTr { shift: FRAC_BITS },
                rows: 2,
                inner: 3,
                cols: 1,
                dealer: P2,
            }
        }
        fn dummy(k: CircuitKey) -> MatCorr {
            MatCorr {
                key: k,
                lam_x: MMat::zero(P0, k.rows, k.inner),
                lam_x_full: None,
                gamma: MatGamma::Helper([
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                ]),
                lam_z: MMat::zero(P0, k.rows, k.cols),
                pairs: Vec::new(),
                lam_y: None,
                binj: None,
                seq: 0,
            }
        }

        let mut pool = Pool::new();
        // 3-layer vector, final layer matmul-only; layer 1 requires relu
        let keys = vec![
            (key(0), None),
            (key(1), Some(relu_key_for(&key(1)))),
            (key(2), None),
        ];
        assert_eq!(pool.layer_vec_stock(&keys), 0, "empty pool fronts no vector");

        pool.push_mat(dummy(key(0)));
        pool.push_mat(dummy(key(0)));
        pool.push_mat(dummy(key(2)));
        // layer 1's mat AND relu queues are empty → still no whole vector
        assert_eq!(pool.layer_vec_stock(&keys), 0);
        pool.push_mat(dummy(key(1)));
        // mat stocked everywhere, but layer 1's PAIRED relu queue is empty:
        // the vector is incomplete — a partially keyed circuit is never run
        assert_eq!(pool.layer_vec_stock(&keys), 0, "paired min includes relu stock");
        let misses0 = pool.stats().mat_misses;
        assert!(!pool.check_layer_vec(&keys));
        assert_eq!(pool.stats().mat_misses, misses0 + 1, "one miss per failed gate");

        // a mat-only vector over the same mat stock IS poppable (min = 1)
        let keys_linear = vec![(key(0), None), (key(1), None), (key(2), None)];
        assert_eq!(pool.layer_vec_stock(&keys_linear), 1);
        assert!(pool.check_layer_vec(&keys_linear));
        assert_eq!(pool.stats().mat_misses, misses0 + 1, "a passing gate records no miss");
    }

    #[test]
    fn cold_training_vector_counts_misses_per_gate() {
        use crate::net::{P0, P2};
        use crate::proto::dotp::MatGamma;
        use crate::ring::Matrix;
        use crate::sharing::MMat;

        fn key(layer: u32) -> CircuitKey {
            CircuitKey {
                model: 11,
                layer,
                op: OpKind::MatMulTr { shift: FRAC_BITS },
                rows: 4,
                inner: 3,
                cols: 2,
                dealer: P2,
            }
        }
        fn dummy(k: CircuitKey) -> MatCorr {
            MatCorr {
                key: k,
                lam_x: MMat::zero(P0, k.rows, k.inner),
                lam_x_full: None,
                gamma: MatGamma::Helper([
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                    Matrix::zeros(k.rows, k.cols),
                ]),
                lam_z: MMat::zero(P0, k.rows, k.cols),
                pairs: Vec::new(),
                lam_y: None,
                binj: None,
                seq: 0,
            }
        }

        let mut pool = Pool::new();
        // a 2-layer training tenant's gate vector: 2 forward (first with a
        // paired relu), 2 grad, 1 back — registered but NEVER warmed
        let keys = vec![
            (key(0), Some(relu_key_for(&key(0)))),
            (key(1), None),
            (key(0x1000), None),
            (key(0x1001), None),
            (key(0x2001), None),
        ];
        assert!(!pool.check_layer_vec_gates(&keys), "cold vector fails the gate");
        // the fix under test: one miss PER missing gate, not one per wave
        assert_eq!(pool.stats().mat_misses, 5, "five cold mat gates");
        assert_eq!(pool.stats().relu_misses, 1, "one cold paired relu gate");

        // partially warmed: only the still-missing gates count
        pool.push_mat(dummy(key(0)));
        pool.push_mat(dummy(key(1)));
        assert!(!pool.check_layer_vec_gates(&keys));
        assert_eq!(pool.stats().mat_misses, 5 + 3, "three mat gates still cold");
        assert_eq!(pool.stats().relu_misses, 2, "paired relu still cold");
    }

    #[test]
    fn pool_backed_mult_opens_to_product() {
        let run = run_4pc(NetProfile::zero(), 702, |ctx| {
            ctx.attach_pool(Pool::new());
            fill_lam::<Z64>(ctx, 2);
            let x = share(ctx, P1, (ctx.id() == P1).then_some(Z64(41)))?;
            let y = share(ctx, P2, (ctx.id() == P2).then_some(Z64(1009)))?;
            let z = crate::proto::mult(ctx, &x, &y)?;
            ctx.flush_verify()?;
            let stats = ctx.detach_pool().unwrap().stats();
            Ok((z, stats))
        });
        let (outs, _) = run.expect_ok();
        assert_eq!(
            open(&[outs[0].0, outs[1].0, outs[2].0, outs[3].0]),
            Z64(41 * 1009)
        );
        assert!(outs[1].1.lam_hits >= 1, "mult must draw λ_z from the pool");
    }
}
