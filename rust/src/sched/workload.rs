//! The **workload** abstraction of the admission surface: what a tenant
//! asks the cluster to do.
//!
//! PR 9 makes secure *training* (the paper's 187× headline, §VI-A) a
//! first-class scheduled workload sharing the cluster with
//! latency-sensitive inference. Both kinds are admitted through the same
//! [`crate::sched::SchedQueue`] / [`crate::sched::WavePlanner`]:
//!
//! * [`Workload::Inference`] — today's queries: each admitted query is one
//!   prediction row, waves coalesce many queries into one circuit
//!   evaluation.
//! * [`Workload::Training`] — a long-lived batch job: each admitted
//!   "query" is one **epoch** (query id = epoch index), a wave runs the
//!   whole forward/backward pass over the job's fixed batch, and the wave
//!   boundary is the **preemption point** — between epochs the planner is
//!   free to grant inference waves, so a saturating training job can never
//!   hold the cluster across a tick.
//!
//! ## Training gate numbering
//!
//! A training epoch evaluates three families of matrix gates per layer
//! `l` (dims `d_l × d_{l+1}`, batch `B`):
//!
//! | family  | product               | shape               | `CircuitKey::layer` |
//! |---------|-----------------------|---------------------|---------------------|
//! | forward | `A_l ∘ W_l`           | `B×d_l ∘ d_l×d_{l+1}`  | `l`              |
//! | grad    | `A_lᵀ ∘ E_l`          | `d_l×B ∘ B×d_{l+1}` | [`GRAD_GATE_BASE`]` + l` |
//! | back    | `E_l ∘ W_lᵀ` (`l>0`)  | `B×d_{l+1} ∘ d_{l+1}×d_l` | [`BACK_GATE_BASE`]` + l` |
//!
//! The bases keep the three families in **disjoint key ranges**: for a
//! square hidden layer (`d_l == d_{l+1}`) the forward and back gates have
//! identical `op`/shape/dealer, and without distinct gate numbers their
//! pooled bundles would alias in the circuit-keyed pool and a pop could
//! serve backward material to a forward gate (which fails closed, but
//! deterministically — the wave would abort, not misbehave).
//!
//! Training bundles are generated **per epoch** against the current
//! weight shares: an epoch commit replaces `[[W]]` with `[[W − ∇]]`,
//! whose λ components are fresh (the gradient's mask comes from the
//! epoch's truncation pairs), so next epoch's Γ correlations must be
//! re-exchanged. Re-using one fixed `λ_W` across epochs would let the
//! evaluators difference `m_W` between commits and learn the cleartext
//! weight deltas — a gradient leak — so the regeneration is a security
//! requirement, not a convenience. It runs *post-commit between waves*
//! (offline phase), which is what keeps the epoch wave itself
//! offline-silent.
//!
//! ## Checkpointed shares
//!
//! [`Checkpoint`] serializes one party's view of a training job — the
//! epoch counter and the replicated weight shares (for plain SGD the
//! optimizer state *is* the epoch counter plus the static
//! learning-rate schedule, both in the header) — to a deterministic byte
//! format. Restoring the four per-party blobs into a fresh run resumes
//! the job mid-stream: the remaining epochs are re-admitted, fresh
//! training bundles are generated against the restored λ, and the final
//! model reconstructs identically at all four parties (locked by the
//! equivalence suite).

use crate::ring::{Matrix, Z64};
use crate::sharing::MMat;

/// `CircuitKey::layer` base for gradient gates (`A_lᵀ ∘ E_l`).
pub const GRAD_GATE_BASE: u32 = 0x1000;
/// `CircuitKey::layer` base for back-propagation gates (`E_l ∘ W_lᵀ`).
pub const BACK_GATE_BASE: u32 = 0x2000;

/// Which training loop a [`Workload::Training`] job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainKind {
    /// Linear regression: single `d → 1` layer, linear head.
    LinReg,
    /// Logistic regression: single `d → 1` layer, sigmoid head (the
    /// sigmoid itself runs inline — keyed sigmoid is ROADMAP direction 1).
    LogReg,
    /// Feed-forward network with hidden ReLU layers (dims from the
    /// tenant's `layers` vector).
    Nn,
}

impl TrainKind {
    /// Parse the CLI spelling (`--model linreg|logreg|nn`).
    pub fn parse(s: &str) -> Option<TrainKind> {
        match s {
            "linreg" => Some(TrainKind::LinReg),
            "logreg" => Some(TrainKind::LogReg),
            "nn" => Some(TrainKind::Nn),
            _ => None,
        }
    }
}

/// What a tenant asks the cluster to do — the admission-surface axis both
/// the queue and the planner understand (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Latency-sensitive prediction queries (the default).
    Inference,
    /// A long-lived training job, scheduled as epoch-granular waves.
    Training {
        kind: TrainKind,
        /// Total epochs; each is one admitted query (query id = epoch).
        epochs: usize,
        /// Fixed training batch (power of two — the `1/B` factor folds
        /// into the gradient truncation shift).
        batch: usize,
        /// Serialize a [`Checkpoint`] every this many committed epochs
        /// (0 = never).
        checkpoint_every: usize,
        /// Learning rate `2^{−lr_pow}` (folded into the same shift).
        lr_pow: u32,
    },
}

impl Workload {
    /// Whether this is a training job.
    pub fn is_training(&self) -> bool {
        matches!(self, Workload::Training { .. })
    }

    /// The training parameters, if any.
    pub fn training(&self) -> Option<(TrainKind, usize, usize, usize, u32)> {
        match *self {
            Workload::Training { kind, epochs, batch, checkpoint_every, lr_pow } => {
                Some((kind, epochs, batch, checkpoint_every, lr_pow))
            }
            Workload::Inference => None,
        }
    }
}

// ---- checkpointed shares -------------------------------------------------

const CKPT_MAGIC: &[u8; 4] = b"TCK1";

/// One party's serialized view of a training job at an epoch boundary:
/// the job identity, the epoch counter (the next epoch to run), and the
/// replicated weight shares. Byte lengths are equal across parties (both
/// the helper and an evaluator hold exactly three component matrices per
/// weight), so blobs can be stored/rotated symmetrically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Checkpoint {
    /// Resident-model id of the training tenant.
    pub model: u64,
    /// Next epoch to run on restore (= committed epochs so far).
    pub epoch: u64,
    /// Per-layer replicated weight shares.
    pub weights: Vec<MMat<Z64>>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_matrix(out: &mut Vec<u8>, m: &Matrix<Z64>) {
    for v in m.data() {
        put_u64(out, v.0);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.at + n > self.buf.len() {
            return Err("checkpoint truncated".into());
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn matrix(&mut self, rows: usize, cols: usize) -> Result<Matrix<Z64>, String> {
        let n = rows.checked_mul(cols).ok_or("checkpoint matrix overflow")?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(Z64(self.u64()?));
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }
}

impl Checkpoint {
    /// Serialize to the deterministic byte format (see the module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(CKPT_MAGIC);
        put_u64(&mut out, self.model);
        put_u64(&mut out, self.epoch);
        put_u32(&mut out, self.weights.len() as u32);
        for w in &self.weights {
            let (rows, cols) = w.dims();
            match w {
                MMat::Helper { lam } => {
                    out.push(0);
                    put_u32(&mut out, rows as u32);
                    put_u32(&mut out, cols as u32);
                    for l in lam {
                        put_matrix(&mut out, l);
                    }
                }
                MMat::Eval { m, lam_next, lam_prev } => {
                    out.push(1);
                    put_u32(&mut out, rows as u32);
                    put_u32(&mut out, cols as u32);
                    put_matrix(&mut out, m);
                    put_matrix(&mut out, lam_next);
                    put_matrix(&mut out, lam_prev);
                }
            }
        }
        out
    }

    /// Parse a blob produced by [`Checkpoint::encode`]; errors on any
    /// malformed framing rather than restoring garbage shares.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, String> {
        let mut r = Reader { buf: bytes, at: 0 };
        if r.take(4)? != CKPT_MAGIC {
            return Err("not a trident checkpoint (bad magic)".into());
        }
        let model = r.u64()?;
        let epoch = r.u64()?;
        let count = r.u32()? as usize;
        let mut weights = Vec::with_capacity(count);
        for _ in 0..count {
            let tag = r.take(1)?[0];
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let w = match tag {
                0 => {
                    let l1 = r.matrix(rows, cols)?;
                    let l2 = r.matrix(rows, cols)?;
                    let l3 = r.matrix(rows, cols)?;
                    MMat::Helper { lam: [l1, l2, l3] }
                }
                1 => {
                    let m = r.matrix(rows, cols)?;
                    let lam_next = r.matrix(rows, cols)?;
                    let lam_prev = r.matrix(rows, cols)?;
                    MMat::Eval { m, lam_next, lam_prev }
                }
                t => return Err(format!("unknown checkpoint share tag {t}")),
            };
            weights.push(w);
        }
        if r.at != bytes.len() {
            return Err("trailing bytes after checkpoint".into());
        }
        Ok(Checkpoint { model, epoch, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(seed: u64, rows: usize, cols: usize) -> Matrix<Z64> {
        Matrix::from_fn(rows, cols, |r, c| Z64(seed + (r * cols + c) as u64))
    }

    #[test]
    fn checkpoint_roundtrips_both_share_kinds() {
        let ck = Checkpoint {
            model: 7,
            epoch: 3,
            weights: vec![
                MMat::Helper { lam: [mat(1, 2, 3), mat(100, 2, 3), mat(200, 2, 3)] },
                MMat::Eval {
                    m: mat(300, 3, 1),
                    lam_next: mat(400, 3, 1),
                    lam_prev: mat(500, 3, 1),
                },
            ],
        };
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).expect("roundtrip");
        assert_eq!(back, ck);
        // helper and evaluator blobs of equal shapes have equal lengths —
        // both hold exactly three component matrices per weight
        let helper_only = Checkpoint {
            model: 7,
            epoch: 3,
            weights: vec![MMat::Helper { lam: [mat(0, 2, 3), mat(0, 2, 3), mat(0, 2, 3)] }],
        };
        let eval_only = Checkpoint {
            model: 7,
            epoch: 3,
            weights: vec![MMat::Eval {
                m: mat(0, 2, 3),
                lam_next: mat(0, 2, 3),
                lam_prev: mat(0, 2, 3),
            }],
        };
        assert_eq!(helper_only.encode().len(), eval_only.encode().len());
    }

    #[test]
    fn checkpoint_decode_rejects_malformed_blobs() {
        let ck = Checkpoint { model: 1, epoch: 0, weights: vec![] };
        let mut bytes = ck.encode();
        assert!(Checkpoint::decode(&bytes[..3]).is_err(), "truncated");
        bytes[0] = b'X';
        assert!(Checkpoint::decode(&bytes).is_err(), "bad magic");
        let mut ok = ck.encode();
        ok.push(0);
        assert!(Checkpoint::decode(&ok).is_err(), "trailing bytes");
    }

    #[test]
    fn gate_bases_keep_families_disjoint() {
        // deepest realistic network ≪ 0x1000 layers, so forward / grad /
        // back gate numbers can never collide
        assert!(GRAD_GATE_BASE > 0x100);
        assert!(BACK_GATE_BASE > GRAD_GATE_BASE + 0x100);
    }

    #[test]
    fn workload_training_accessor() {
        let w = Workload::Training {
            kind: TrainKind::Nn,
            epochs: 4,
            batch: 8,
            checkpoint_every: 2,
            lr_pow: 5,
        };
        assert!(w.is_training());
        assert_eq!(w.training(), Some((TrainKind::Nn, 4, 8, 2, 5)));
        assert!(!Workload::Inference.is_training());
        assert_eq!(Workload::Inference.training(), None);
        assert_eq!(TrainKind::parse("logreg"), Some(TrainKind::LogReg));
        assert_eq!(TrainKind::parse("cnn"), None);
    }
}
