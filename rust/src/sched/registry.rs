//! Model registry — multi-model residency over the circuit-keyed pool.
//!
//! The registry owns the platform's resident models. For each tenant it
//! holds the shared weights, the tenant's [`CircuitKey`] (whose `model`
//! field **is** the tenant id — the keyed pool of `pool/mat.rs` shards by
//! it, so tenant A's pre-generated correlations are unreachable under
//! tenant B's key and a cross-tenant pop fails closed), and a private
//! background-[`Refill`] producer with that tenant's water marks. The
//! serving engine interleaves refill ticks **per tenant** between waves,
//! steered to the most-depleted pool ([`ModelRegistry::most_depleted`]).
//!
//! Loading is a lockstep protocol step: every party calls
//! [`ModelRegistry::load`] in the same tenant order, the model owner (P1)
//! contributing the weight values, and the sharing is verified before any
//! pool material is generated against it. All registry state that steers
//! scheduling (keys, marks, stock levels) is public and identical at the
//! four parties.

use crate::crypto::Rng;
use crate::ml::{share_fixed_mat, F64Mat};
use crate::net::{Abort, P1, P2};
use crate::pool::{
    fill_mat, fill_mat_relu, relu_key_for, CircuitKey, OpKind, Refill, RefillOutcome, WaterMarks,
};
use crate::proto::Ctx;
use crate::ring::fixed::FRAC_BITS;
use crate::ring::Z64;
use crate::sharing::MMat;

/// Domain separator for per-tenant resident weights.
const TW_SEED: u64 = 0x7363_6864_5f77_3174;

/// One tenant of the serving platform: a resident model plus its traffic
/// contract. Everything here is public schedule metadata, identical at all
/// four parties.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Human-readable tenant/model name (CLI `--models m1,m2`).
    pub name: String,
    /// Resident-model id — becomes `CircuitKey::model`, sharding the
    /// pooled offline material per tenant.
    pub model: u64,
    /// Feature count of the tenant's linear model.
    pub d: usize,
    /// Rows per query (client-side mini-batch).
    pub rows_per_query: usize,
    /// Queries this tenant submits in the workload.
    pub queries: usize,
    /// Max queries coalesced into one of this tenant's waves.
    pub coalesce: usize,
    /// Weighted-round-robin share.
    pub weight: u64,
    /// Priority class of this tenant's queries (0 = highest).
    pub class: u8,
    /// Relative deadline in logical ticks (`None` = no deadline).
    pub deadline_ticks: Option<u64>,
    /// Admission-control cap on admitted-but-unanswered queries
    /// (`None` = uncapped).
    pub inflight_cap: Option<usize>,
    /// Arrivals per logical tick (0 = the whole workload arrives at tick 0).
    pub arrive_per_tick: usize,
    /// Apply a batched ReLU after the linear layer.
    pub relu: bool,
    /// Seed for this tenant's deterministic weights/queries.
    pub seed: u64,
}

impl TenantSpec {
    /// A small default contract: weight 1, class 0, no deadline, no cap.
    pub fn new(name: &str, model: u64, d: usize, queries: usize, coalesce: usize) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            model,
            d,
            rows_per_query: 1,
            queries,
            coalesce,
            weight: 1,
            class: 0,
            deadline_ticks: None,
            inflight_cap: None,
            arrive_per_tick: 0,
            relu: false,
            seed: 0x7465_6e61 ^ model,
        }
    }

    /// The coalescing factor real waves can reach (`coalesce` capped by the
    /// workload, 0 guarded as 1) — the registered key must match a wave the
    /// tenant can actually produce.
    pub fn effective_coalesce(&self) -> usize {
        self.coalesce.max(1).min(self.queries.max(1))
    }

    /// Stacked rows of one full coalesced wave.
    pub fn wave_rows(&self) -> usize {
        self.effective_coalesce() * self.rows_per_query
    }

    /// The circuit key of this tenant's resident linear layer for a full
    /// coalesced wave (the key the registry registers and refills).
    pub fn key(&self) -> CircuitKey {
        tenant_wave_key(self, self.wave_rows())
    }

    /// The paired nonlinear circuit key of a `relu: true` tenant's full
    /// coalesced wave (`None` for linear tenants). Keyed by the tenant's
    /// model id like the matrix key, so the formerly-shared bit-extraction
    /// material is **sharded per tenant** and a cross-tenant pop fails
    /// closed — per-tenant offline budgets are exact.
    pub fn relu_key(&self) -> Option<CircuitKey> {
        self.relu.then(|| tenant_relu_key(self, self.wave_rows()))
    }

    /// Arrival tick of query `id` under this tenant's arrival plan.
    pub fn arrival_tick(&self, id: usize) -> u64 {
        if self.arrive_per_tick == 0 {
            0
        } else {
            (id / self.arrive_per_tick) as u64
        }
    }
}

/// The circuit key of tenant `spec`'s linear layer for a wave of `rows`
/// stacked feature rows (a trailing partial wave keys differently from
/// [`TenantSpec::key`] and falls back inline).
pub fn tenant_wave_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    CircuitKey {
        model: spec.model,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows,
        inner: spec.d,
        cols: 1,
        dealer: P2,
    }
}

/// The nonlinear circuit key of tenant `spec`'s wave of `rows` stacked
/// rows — the [`tenant_wave_key`] position with `op` replaced by
/// `OpKind::Relu` over the wave's outputs.
pub fn tenant_relu_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    relu_key_for(&tenant_wave_key(spec, rows))
}

/// Deterministic resident weights for a tenant (at the model owner).
pub fn tenant_weights(d: usize, seed: u64) -> F64Mat {
    let mut rng = Rng::seeded(seed ^ TW_SEED);
    let mut w = F64Mat::zeros(d, 1);
    for j in 0..d {
        w.set(j, 0, rng.normal() * 0.1);
    }
    w
}

/// One loaded resident model: spec + shared weights + registered key +
/// private refill producer.
pub struct ResidentModel {
    pub spec: TenantSpec,
    /// The tenant's shared resident weights (`d × 1`).
    pub w: MMat<Z64>,
    /// The registered full-wave circuit key.
    pub key: CircuitKey,
    /// The paired full-wave nonlinear key (`relu: true` tenants): the
    /// tick fills `MatCorr`+`ReluCorr` bundles in lockstep pairs.
    pub relu_key: Option<CircuitKey>,
    marks: WaterMarks,
    refill: Refill,
}

impl ResidentModel {
    /// The refill water marks this tenant was registered with (high is
    /// clamped to the tenant's total full-wave demand at load).
    pub fn marks(&self) -> WaterMarks {
        self.marks
    }
}

/// Registry of resident models (see the module docs).
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<ResidentModel>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn model(&self, t: usize) -> &ResidentModel {
        &self.models[t]
    }

    /// Tenant weights for the wave planner, registry order.
    pub fn planner_weights(&self) -> Vec<u64> {
        self.models.iter().map(|m| m.spec.weight).collect()
    }

    /// Load one resident model (lockstep at all four parties, same tenant
    /// order everywhere): P1 contributes the deterministic weights, and the
    /// tenant's full-wave circuit key is registered with a private refill
    /// producer at `{low, high}` water marks (keyed-matrix bundles; plus
    /// scaled bit-extraction material when the tenant's pipeline ends in a
    /// ReLU). Returns the tenant index. The caller must flush verification
    /// after the last `load`, before any pool fill runs against the
    /// weights.
    pub fn load(
        &mut self,
        ctx: &mut Ctx,
        spec: TenantSpec,
        low_water: usize,
        high_water: usize,
    ) -> Result<usize, Abort> {
        // the model id IS the pool shard: two tenants sharing one id would
        // file correlations generated against different resident weights
        // into one keyed queue, and the embedded-key fail-closed check
        // could no longer tell them apart — reject at load, loudly
        assert!(
            self.models.iter().all(|m| m.spec.model != spec.model),
            "duplicate tenant model id {}: per-tenant pool sharding requires a unique CircuitKey::model per resident model",
            spec.model
        );
        let w0 = (ctx.id() == P1).then(|| tenant_weights(spec.d, spec.seed));
        let w = share_fixed_mat(ctx, P1, w0.as_ref(), spec.d, 1)?;
        let key = spec.key();
        let relu_key = spec.relu_key();
        // clamp the high-water mark to the tenant's total full-wave demand
        // so neither the warm-up fill nor a steady-state top-up can stock
        // more bundles than real waves will ever pop (a partial trailing
        // wave keys differently and consumes nothing)
        let total_full_waves = spec.queries.max(1) / spec.effective_coalesce();
        let high = high_water.max(1).min(total_full_waves.max(1));
        let marks = WaterMarks::new(low_water.min(high), high);
        // keyed bundles — matrix AND (for `relu: true` tenants) the paired
        // nonlinear bundles — are filled by [`ModelRegistry::tick`] itself,
        // so the top-up can be capped by remaining demand. Nothing is
        // registered on the formerly-shared typed bitext/λ queues any more:
        // a tenant's nonlinear material lives under its own circuit key,
        // which is what makes per-tenant offline budgets exact. The private
        // producer stays for shapeless per-tenant targets a future pipeline
        // may add.
        let refill = Refill::new();
        self.models.push(ResidentModel { spec, w, key, relu_key, marks, refill });
        Ok(self.models.len() - 1)
    }

    /// One cooperative refill step for tenant `t`'s pool targets (lockstep;
    /// offline-phase traffic only — see [`crate::pool::refill`]). The keyed
    /// top-up follows the refill state machine (`stock < low` → fill
    /// towards `high`) but never stocks more than `max_mat` bundles — the
    /// caller passes the tenant's remaining full-wave demand, so a
    /// late-run tick cannot strand material a trailing partial wave would
    /// never pop. `max_mat` is public schedule state, identical at all
    /// four parties.
    pub fn tick(
        &self,
        ctx: &mut Ctx,
        t: usize,
        max_mat: usize,
    ) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        let mut out = RefillOutcome::default();
        let stock = ctx.pool.as_ref().map_or(0, |p| Self::paired_stock(p, m));
        if stock < m.marks.low {
            let need = (m.marks.high - stock).min(max_mat.saturating_sub(stock));
            if need > 0 {
                match &m.relu_key {
                    Some(rk) => {
                        fill_mat_relu(ctx, m.key, *rk, &m.w, need)?;
                        out.relu_items = need;
                    }
                    None => fill_mat(ctx, m.key, &m.w, need)?,
                }
                out.mat_items = need;
            }
        }
        let rest = m.refill.tick(ctx)?;
        out.trunc_pairs = rest.trunc_pairs;
        out.lam = rest.lam;
        out.bitext = rest.bitext;
        Ok(out)
    }

    /// The tenant's poppable keyed stock: matrix bundles, paired with the
    /// nonlinear bundles for a ReLU tenant (the min keeps the refill state
    /// machine safe under any skew, though paired fills/pops keep the two
    /// queues equal by construction).
    fn paired_stock(pool: &crate::pool::Pool, m: &ResidentModel) -> usize {
        match &m.relu_key {
            Some(rk) => pool.len_mat(&m.key).min(pool.len_relu(rk)),
            None => pool.len_mat(&m.key),
        }
    }

    /// The most-depleted tenant pool among `eligible` tenants: largest
    /// keyed-bundle deficit **below the tenant's low-water mark** — i.e.
    /// the tenant whose next refill tick will actually fill (a tick on a
    /// pool at or above low is a no-op by the refill state machine, so
    /// picking one would waste the between-waves slot). Ties go to the
    /// lowest tenant index; `None` when no eligible pool is below low.
    /// Deterministic — stock levels are lockstep state.
    pub fn most_depleted(&self, ctx: &Ctx, eligible: &[bool]) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (deficit, tenant)
        for (t, m) in self.models.iter().enumerate() {
            if !eligible.get(t).copied().unwrap_or(false) {
                continue;
            }
            let stock = ctx.pool.as_ref().map_or(0, |p| Self::paired_stock(p, m));
            let deficit = m.marks.low.saturating_sub(stock);
            if deficit == 0 {
                continue;
            }
            match best {
                Some((d, _)) if d >= deficit => {}
                _ => best = Some((deficit, t)),
            }
        }
        best.map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::pool::Pool;
    use crate::proto::run_4pc;

    fn spec(name: &str, model: u64, d: usize) -> TenantSpec {
        TenantSpec::new(name, model, d, 4, 2)
    }

    #[test]
    fn keys_are_sharded_by_tenant_model_id() {
        let a = spec("m1", 11, 4);
        let b = spec("m2", 22, 4);
        assert_ne!(a.key(), b.key(), "same shape, different tenant → different key");
        assert_eq!(a.key().model, 11);
        assert_eq!(b.key().model, 22);
    }

    #[test]
    fn effective_coalesce_guards_zero_and_oversize() {
        let mut s = spec("m", 1, 4);
        s.coalesce = 0;
        assert_eq!(s.effective_coalesce(), 1, "coalesce 0 treated as 1");
        s.coalesce = 99;
        assert_eq!(s.effective_coalesce(), s.queries, "capped by the workload");
    }

    #[test]
    fn arrival_plan_is_deterministic() {
        let mut s = spec("m", 1, 4);
        assert_eq!(s.arrival_tick(3), 0, "burst plan: everything at tick 0");
        s.arrive_per_tick = 2;
        assert_eq!(
            (0..6).map(|i| s.arrival_tick(i)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1, 2, 2]
        );
    }

    #[test]
    fn registry_rejects_duplicate_model_ids() {
        // the assert fires inside every party thread (same public spec at
        // all four), so each thread dies before any protocol message and
        // the cluster reports four dead parties
        let run = run_4pc(NetProfile::zero(), 911, |ctx| {
            let mut reg = ModelRegistry::new();
            reg.load(ctx, spec("m1", 7, 3), 1, 2)?;
            // same model id with different weights/seed: must fail fast at
            // load instead of silently sharing one pool shard
            reg.load(ctx, TenantSpec::new("m1-again", 7, 3, 4, 2), 1, 2)?;
            Ok(())
        });
        assert!(run.all_aborted(), "duplicate model id must refuse to load");
    }

    #[test]
    fn high_water_is_clamped_to_total_full_wave_demand() {
        let run = run_4pc(NetProfile::zero(), 912, |ctx| {
            let mut reg = ModelRegistry::new();
            // 4 queries at coalesce 2 = 2 full waves, but high-water 5:
            // stocking 5 bundles would strand 3 — the registry clamps
            let t = reg.load(ctx, spec("m1", 11, 3), 1, 5)?;
            ctx.flush_verify()?;
            Ok(reg.model(t).marks())
        });
        let (outs, _) = run.expect_ok();
        for m in &outs {
            assert_eq!(m.high, 2, "high clamped to the 2 poppable full waves");
            assert_eq!(m.low, 1);
        }
    }

    #[test]
    fn relu_tenant_refills_paired_bundles_per_tenant() {
        // a `relu: true` tenant's nonlinear material is keyed by ITS model
        // id (no shared typed queue): the tick fills MatCorr+ReluCorr in
        // pairs, the watermark state machine runs on the paired stock, and
        // another tenant's key never sees the material
        let run = run_4pc(NetProfile::zero(), 913, |ctx| {
            let mut reg = ModelRegistry::new();
            let mut sa = spec("m1", 31, 3);
            sa.relu = true;
            let ta = reg.load(ctx, sa, 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 32, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired cold fill");
            let (mk, rk) = (reg.model(ta).key, reg.model(ta).relu_key.expect("relu key"));
            assert_eq!(rk.model, 31, "nonlinear material is sharded by tenant id");
            // tenant B's position (same shape, different model id) sees
            // none of tenant A's nonlinear material
            let rk_b = relu_key_for(&reg.model(tb).key);
            assert_eq!(ctx.pool.as_ref().unwrap().len_relu(&rk_b), 0);
            // pop one pair → stock 1, at low: no refill
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.relu_items, 0, "stock 1 is at low water: no refill");
            // pop the second pair → stock 0 < low: paired top-up to high
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired top-up to high");
            let pool = ctx.detach_pool().unwrap();
            Ok((pool.len_mat(&mk), pool.len_relu(&rk)))
        });
        let (outs, _) = run.expect_ok();
        for (m, r) in &outs {
            assert_eq!((*m, *r), (2, 2), "mat and relu queues stay paired");
        }
    }

    #[test]
    fn registry_loads_tenants_and_steers_refill_to_the_most_depleted_pool() {
        let run = run_4pc(NetProfile::zero(), 910, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 11, 3), 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 22, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            // both pools empty, both eligible: deficit ties → lowest index
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(ta));
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 2, "cold pool fills to high");
            // tenant A full: B is now the most depleted
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(tb));
            // … unless B is ineligible
            assert_eq!(reg.most_depleted(ctx, &[true, false]), None);
            // a demand cap below the water marks bounds the top-up
            let o = reg.tick(ctx, tb, 1)?;
            assert_eq!(o.mat_items, 1, "top-up capped by remaining demand");
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 0, "stock 1 is at low water: no refill");
            let _ = ctx.pool_mut().unwrap().pop_mat(&reg.model(tb).key).unwrap();
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 2, "uncapped refill tops back up to high");
            assert_eq!(reg.most_depleted(ctx, &[true, true]), None, "both full");
            let pool = ctx.detach_pool().unwrap();
            Ok((
                pool.len_mat(&reg.model(ta).key),
                pool.len_mat(&reg.model(tb).key),
            ))
        });
        let (outs, report) = run.expect_ok();
        for (a, b) in &outs {
            assert_eq!(*a, 2);
            assert_eq!(*b, 2);
        }
        // registry loading + refill generation is offline-silent online
        assert!(report.value_bits[0] > 0, "fills are offline traffic");
    }
}
