//! Model registry — multi-model residency over the circuit-keyed pool.
//!
//! The registry owns the platform's resident models. For each tenant it
//! holds the shared weights, the tenant's [`CircuitKey`] (whose `model`
//! field **is** the tenant id — the keyed pool of `pool/mat.rs` shards by
//! it, so tenant A's pre-generated correlations are unreachable under
//! tenant B's key and a cross-tenant pop fails closed), and a private
//! background-[`Refill`] producer with that tenant's water marks. The
//! serving engine interleaves refill ticks **per tenant** between waves,
//! steered to the most-depleted pool ([`ModelRegistry::most_depleted`]).
//!
//! Loading is a lockstep protocol step: every party calls
//! [`ModelRegistry::load`] in the same tenant order, the model owner (P1)
//! contributing the weight values, and the sharing is verified before any
//! pool material is generated against it. All registry state that steers
//! scheduling (keys, marks, stock levels) is public and identical at the
//! four parties.

use crate::crypto::Rng;
use crate::ml::{share_fixed_mat, F64Mat};
use crate::net::{Abort, P1, P2};
use crate::pool::{
    fill_mat, fill_mat_relu, relu_key_for, CircuitKey, OpKind, Refill, RefillOutcome, WaterMarks,
};
use crate::proto::Ctx;
use crate::ring::fixed::FRAC_BITS;
use crate::ring::Z64;
use crate::sharing::MMat;

/// Domain separator for per-tenant resident weights.
const TW_SEED: u64 = 0x7363_6864_5f77_3174;

/// One tenant of the serving platform: a resident model plus its traffic
/// contract. Everything here is public schedule metadata, identical at all
/// four parties.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Human-readable tenant/model name (CLI `--models m1,m2`).
    pub name: String,
    /// Resident-model id — becomes `CircuitKey::model`, sharding the
    /// pooled offline material per tenant.
    pub model: u64,
    /// Feature count of the tenant's linear model.
    pub d: usize,
    /// Rows per query (client-side mini-batch).
    pub rows_per_query: usize,
    /// Queries this tenant submits in the workload.
    pub queries: usize,
    /// Max queries coalesced into one of this tenant's waves.
    pub coalesce: usize,
    /// Weighted-round-robin share.
    pub weight: u64,
    /// Priority class of this tenant's queries (0 = highest).
    pub class: u8,
    /// Relative deadline in logical ticks (`None` = no deadline).
    pub deadline_ticks: Option<u64>,
    /// Admission-control cap on admitted-but-unanswered queries
    /// (`None` = uncapped).
    pub inflight_cap: Option<usize>,
    /// Arrivals per logical tick (0 = the whole workload arrives at tick 0).
    pub arrive_per_tick: usize,
    /// Apply a batched ReLU after the linear layer.
    pub relu: bool,
    /// Seed for this tenant's deterministic weights/queries.
    pub seed: u64,
}

impl TenantSpec {
    /// A small default contract: weight 1, class 0, no deadline, no cap.
    pub fn new(name: &str, model: u64, d: usize, queries: usize, coalesce: usize) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            model,
            d,
            rows_per_query: 1,
            queries,
            coalesce,
            weight: 1,
            class: 0,
            deadline_ticks: None,
            inflight_cap: None,
            arrive_per_tick: 0,
            relu: false,
            seed: 0x7465_6e61 ^ model,
        }
    }

    /// The coalescing factor real waves can reach (`coalesce` capped by the
    /// workload, 0 guarded as 1) — the registered key must match a wave the
    /// tenant can actually produce.
    pub fn effective_coalesce(&self) -> usize {
        self.coalesce.max(1).min(self.queries.max(1))
    }

    /// Stacked rows of one full coalesced wave.
    pub fn wave_rows(&self) -> usize {
        self.effective_coalesce() * self.rows_per_query
    }

    /// The circuit key of this tenant's resident linear layer for a full
    /// coalesced wave (the key the registry registers and refills).
    pub fn key(&self) -> CircuitKey {
        tenant_wave_key(self, self.wave_rows())
    }

    /// The paired nonlinear circuit key of a `relu: true` tenant's full
    /// coalesced wave (`None` for linear tenants). Keyed by the tenant's
    /// model id like the matrix key, so the formerly-shared bit-extraction
    /// material is **sharded per tenant** and a cross-tenant pop fails
    /// closed — per-tenant offline budgets are exact.
    pub fn relu_key(&self) -> Option<CircuitKey> {
        self.relu.then(|| tenant_relu_key(self, self.wave_rows()))
    }

    /// Stacked rows of the trailing **partial** wave, when the workload
    /// does not divide evenly (`queries % coalesce ≠ 0`); `None` when every
    /// wave is full. The partial wave is a real wave the tenant always
    /// produces exactly once per workload — its key must be registered at
    /// load like the full-wave key, or the last wave silently misses the
    /// pool and serves inline.
    pub fn partial_rows(&self) -> Option<usize> {
        let rem = self.queries % self.effective_coalesce();
        (rem != 0).then(|| rem * self.rows_per_query)
    }

    /// The circuit key of the trailing partial wave (`None` when the
    /// workload divides evenly).
    pub fn partial_key(&self) -> Option<CircuitKey> {
        self.partial_rows().map(|rows| tenant_wave_key(self, rows))
    }

    /// The paired nonlinear key of the trailing partial wave (`relu: true`
    /// tenants with a partial wave only).
    pub fn partial_relu_key(&self) -> Option<CircuitKey> {
        if !self.relu {
            return None;
        }
        self.partial_rows().map(|rows| tenant_relu_key(self, rows))
    }

    /// Arrival tick of query `id` under this tenant's arrival plan.
    pub fn arrival_tick(&self, id: usize) -> u64 {
        if self.arrive_per_tick == 0 {
            0
        } else {
            (id / self.arrive_per_tick) as u64
        }
    }
}

/// The circuit key of tenant `spec`'s linear layer for a wave of `rows`
/// stacked feature rows. A trailing partial wave keys differently from
/// [`TenantSpec::key`] — its key is registered separately at load
/// ([`TenantSpec::partial_key`]) so it hits the pool like any full wave.
pub fn tenant_wave_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    CircuitKey {
        model: spec.model,
        layer: 0,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows,
        inner: spec.d,
        cols: 1,
        dealer: P2,
    }
}

/// The nonlinear circuit key of tenant `spec`'s wave of `rows` stacked
/// rows — the [`tenant_wave_key`] position with `op` replaced by
/// `OpKind::Relu` over the wave's outputs.
pub fn tenant_relu_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    relu_key_for(&tenant_wave_key(spec, rows))
}

/// Deterministic resident weights for a tenant (at the model owner).
pub fn tenant_weights(d: usize, seed: u64) -> F64Mat {
    let mut rng = Rng::seeded(seed ^ TW_SEED);
    let mut w = F64Mat::zeros(d, 1);
    for j in 0..d {
        w.set(j, 0, rng.normal() * 0.1);
    }
    w
}

/// One loaded resident model: spec + shared weights + registered key +
/// private refill producer.
pub struct ResidentModel {
    pub spec: TenantSpec,
    /// The tenant's shared resident weights (`d × 1`).
    pub w: MMat<Z64>,
    /// The registered full-wave circuit key.
    pub key: CircuitKey,
    /// The paired full-wave nonlinear key (`relu: true` tenants): the
    /// tick fills `MatCorr`+`ReluCorr` bundles in lockstep pairs.
    pub relu_key: Option<CircuitKey>,
    /// The trailing partial wave's circuit key, when the workload does not
    /// divide evenly — stocked exactly once at warm-up
    /// ([`ModelRegistry::warm_partial`]), never refilled between waves.
    pub partial_key: Option<CircuitKey>,
    /// The partial wave's paired nonlinear key (`relu: true` tenants).
    pub partial_relu_key: Option<CircuitKey>,
    /// Quarantined after a tenant-scoped abort: refill ticks become no-ops
    /// and the depletion steering skips the tenant.
    quarantined: bool,
    marks: WaterMarks,
    refill: Refill,
}

impl ResidentModel {
    /// The refill water marks this tenant was registered with (high is
    /// clamped to the tenant's total full-wave demand at load).
    pub fn marks(&self) -> WaterMarks {
        self.marks
    }
}

/// Registry of resident models (see the module docs).
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<ResidentModel>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn model(&self, t: usize) -> &ResidentModel {
        &self.models[t]
    }

    /// Tenant weights for the wave planner, registry order.
    pub fn planner_weights(&self) -> Vec<u64> {
        self.models.iter().map(|m| m.spec.weight).collect()
    }

    /// Load one resident model (lockstep at all four parties, same tenant
    /// order everywhere): P1 contributes the deterministic weights, and the
    /// tenant's full-wave circuit key is registered with a private refill
    /// producer at `{low, high}` water marks (keyed-matrix bundles; plus
    /// scaled bit-extraction material when the tenant's pipeline ends in a
    /// ReLU). Returns the tenant index. The caller must flush verification
    /// after the last `load`, before any pool fill runs against the
    /// weights.
    pub fn load(
        &mut self,
        ctx: &mut Ctx,
        spec: TenantSpec,
        low_water: usize,
        high_water: usize,
    ) -> Result<usize, Abort> {
        // the model id IS the pool shard: two tenants sharing one id would
        // file correlations generated against different resident weights
        // into one keyed queue, and the embedded-key fail-closed check
        // could no longer tell them apart — reject at load, loudly
        assert!(
            self.models.iter().all(|m| m.spec.model != spec.model),
            "duplicate tenant model id {}: per-tenant pool sharding requires a unique CircuitKey::model per resident model",
            spec.model
        );
        let w0 = (ctx.id() == P1).then(|| tenant_weights(spec.d, spec.seed));
        let w = share_fixed_mat(ctx, P1, w0.as_ref(), spec.d, 1)?;
        let key = spec.key();
        let relu_key = spec.relu_key();
        let partial_key = spec.partial_key();
        let partial_relu_key = spec.partial_relu_key();
        // clamp the high-water mark to the tenant's total full-wave demand
        // so neither the warm-up fill nor a steady-state top-up can stock
        // more bundles than real waves will ever pop (the trailing partial
        // wave keys differently and is stocked exactly once at warm-up by
        // `warm_partial`, outside this state machine)
        let total_full_waves = spec.queries.max(1) / spec.effective_coalesce();
        let high = high_water.max(1).min(total_full_waves.max(1));
        let marks = WaterMarks::new(low_water.min(high), high);
        // keyed bundles — matrix AND (for `relu: true` tenants) the paired
        // nonlinear bundles — are filled by [`ModelRegistry::tick`] itself,
        // so the top-up can be capped by remaining demand. Nothing is
        // registered on the formerly-shared typed bitext/λ queues any more:
        // a tenant's nonlinear material lives under its own circuit key,
        // which is what makes per-tenant offline budgets exact. The private
        // producer stays for shapeless per-tenant targets a future pipeline
        // may add.
        let refill = Refill::new();
        self.models.push(ResidentModel {
            spec,
            w,
            key,
            relu_key,
            partial_key,
            partial_relu_key,
            quarantined: false,
            marks,
            refill,
        });
        Ok(self.models.len() - 1)
    }

    /// Stock tenant `t`'s trailing-partial-wave position with exactly one
    /// bundle (paired with its ReLU for `relu: true` tenants). Called once
    /// during warm-up; a no-op for tenants whose workload divides evenly,
    /// whose partial position is already stocked, or who are quarantined.
    /// Lockstep-deterministic like every fill.
    pub fn warm_partial(&self, ctx: &mut Ctx, t: usize) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        let mut out = RefillOutcome::default();
        let pk = match (&m.partial_key, m.quarantined) {
            (Some(pk), false) => *pk,
            _ => return Ok(out),
        };
        if ctx.pool.as_ref().map_or(0, |p| p.len_mat(&pk)) > 0 {
            return Ok(out);
        }
        match &m.partial_relu_key {
            Some(rk) => {
                fill_mat_relu(ctx, pk, *rk, &m.w, 1)?;
                out.relu_items = 1;
            }
            None => fill_mat(ctx, pk, &m.w, 1)?,
        }
        out.mat_items = 1;
        Ok(out)
    }

    /// Quarantine tenant `t` after a tenant-scoped abort: its refill ticks
    /// become no-ops, the between-waves depletion steering skips it, and
    /// its private producer's keyed targets are deregistered. The pool-side
    /// drain-and-poison ([`crate::pool::Pool::quarantine_model`]) is the
    /// caller's companion step. Idempotent; lockstep-deterministic (driven
    /// by public wave metadata).
    pub fn quarantine(&mut self, t: usize) {
        let m = &mut self.models[t];
        m.quarantined = true;
        let model = m.spec.model;
        m.refill.deregister_model(model);
    }

    /// Whether tenant `t` has been quarantined.
    pub fn is_quarantined(&self, t: usize) -> bool {
        self.models[t].quarantined
    }

    /// One cooperative refill step for tenant `t`'s pool targets (lockstep;
    /// offline-phase traffic only — see [`crate::pool::refill`]). The keyed
    /// top-up follows the refill state machine (`stock < low` → fill
    /// towards `high`) but never stocks more than `max_mat` bundles — the
    /// caller passes the tenant's remaining full-wave demand, so a
    /// late-run tick cannot strand material a trailing partial wave would
    /// never pop. `max_mat` is public schedule state, identical at all
    /// four parties.
    pub fn tick(
        &self,
        ctx: &mut Ctx,
        t: usize,
        max_mat: usize,
    ) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        let mut out = RefillOutcome::default();
        if m.quarantined {
            // the pool-side push guard would drop the items anyway; skip
            // the generation traffic entirely
            return Ok(out);
        }
        let stock = ctx.pool.as_ref().map_or(0, |p| Self::paired_stock(p, m));
        if stock < m.marks.low {
            let need = (m.marks.high - stock).min(max_mat.saturating_sub(stock));
            if need > 0 {
                match &m.relu_key {
                    Some(rk) => {
                        fill_mat_relu(ctx, m.key, *rk, &m.w, need)?;
                        out.relu_items = need;
                    }
                    None => fill_mat(ctx, m.key, &m.w, need)?,
                }
                out.mat_items = need;
            }
        }
        let rest = m.refill.tick(ctx)?;
        out.trunc_pairs = rest.trunc_pairs;
        out.lam = rest.lam;
        out.bitext = rest.bitext;
        Ok(out)
    }

    /// The tenant's poppable keyed stock: matrix bundles, paired with the
    /// nonlinear bundles for a ReLU tenant (the min keeps the refill state
    /// machine safe under any skew, though paired fills/pops keep the two
    /// queues equal by construction).
    fn paired_stock(pool: &crate::pool::Pool, m: &ResidentModel) -> usize {
        match &m.relu_key {
            Some(rk) => pool.len_mat(&m.key).min(pool.len_relu(rk)),
            None => pool.len_mat(&m.key),
        }
    }

    /// The most-depleted tenant pool among `eligible` tenants: largest
    /// keyed-bundle deficit **below the tenant's low-water mark** — i.e.
    /// the tenant whose next refill tick will actually fill (a tick on a
    /// pool at or above low is a no-op by the refill state machine, so
    /// picking one would waste the between-waves slot). Ties go to the
    /// lowest tenant index; `None` when no eligible pool is below low.
    /// Deterministic — stock levels are lockstep state.
    pub fn most_depleted(&self, ctx: &Ctx, eligible: &[bool]) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (deficit, tenant)
        for (t, m) in self.models.iter().enumerate() {
            if !eligible.get(t).copied().unwrap_or(false) || m.quarantined {
                continue;
            }
            let stock = ctx.pool.as_ref().map_or(0, |p| Self::paired_stock(p, m));
            let deficit = m.marks.low.saturating_sub(stock);
            if deficit == 0 {
                continue;
            }
            match best {
                Some((d, _)) if d >= deficit => {}
                _ => best = Some((deficit, t)),
            }
        }
        best.map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::pool::Pool;
    use crate::proto::run_4pc;

    fn spec(name: &str, model: u64, d: usize) -> TenantSpec {
        TenantSpec::new(name, model, d, 4, 2)
    }

    #[test]
    fn keys_are_sharded_by_tenant_model_id() {
        let a = spec("m1", 11, 4);
        let b = spec("m2", 22, 4);
        assert_ne!(a.key(), b.key(), "same shape, different tenant → different key");
        assert_eq!(a.key().model, 11);
        assert_eq!(b.key().model, 22);
    }

    #[test]
    fn effective_coalesce_guards_zero_and_oversize() {
        let mut s = spec("m", 1, 4);
        s.coalesce = 0;
        assert_eq!(s.effective_coalesce(), 1, "coalesce 0 treated as 1");
        s.coalesce = 99;
        assert_eq!(s.effective_coalesce(), s.queries, "capped by the workload");
    }

    #[test]
    fn arrival_plan_is_deterministic() {
        let mut s = spec("m", 1, 4);
        assert_eq!(s.arrival_tick(3), 0, "burst plan: everything at tick 0");
        s.arrive_per_tick = 2;
        assert_eq!(
            (0..6).map(|i| s.arrival_tick(i)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1, 2, 2]
        );
    }

    #[test]
    fn registry_rejects_duplicate_model_ids() {
        // the assert fires inside every party thread (same public spec at
        // all four), so each thread dies before any protocol message and
        // the cluster reports four dead parties
        let run = run_4pc(NetProfile::zero(), 911, |ctx| {
            let mut reg = ModelRegistry::new();
            reg.load(ctx, spec("m1", 7, 3), 1, 2)?;
            // same model id with different weights/seed: must fail fast at
            // load instead of silently sharing one pool shard
            reg.load(ctx, TenantSpec::new("m1-again", 7, 3, 4, 2), 1, 2)?;
            Ok(())
        });
        assert!(run.all_aborted(), "duplicate model id must refuse to load");
    }

    #[test]
    fn high_water_is_clamped_to_total_full_wave_demand() {
        let run = run_4pc(NetProfile::zero(), 912, |ctx| {
            let mut reg = ModelRegistry::new();
            // 4 queries at coalesce 2 = 2 full waves, but high-water 5:
            // stocking 5 bundles would strand 3 — the registry clamps
            let t = reg.load(ctx, spec("m1", 11, 3), 1, 5)?;
            ctx.flush_verify()?;
            Ok(reg.model(t).marks())
        });
        let (outs, _) = run.expect_ok();
        for m in &outs {
            assert_eq!(m.high, 2, "high clamped to the 2 poppable full waves");
            assert_eq!(m.low, 1);
        }
    }

    #[test]
    fn relu_tenant_refills_paired_bundles_per_tenant() {
        // a `relu: true` tenant's nonlinear material is keyed by ITS model
        // id (no shared typed queue): the tick fills MatCorr+ReluCorr in
        // pairs, the watermark state machine runs on the paired stock, and
        // another tenant's key never sees the material
        let run = run_4pc(NetProfile::zero(), 913, |ctx| {
            let mut reg = ModelRegistry::new();
            let mut sa = spec("m1", 31, 3);
            sa.relu = true;
            let ta = reg.load(ctx, sa, 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 32, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired cold fill");
            let (mk, rk) = (reg.model(ta).key, reg.model(ta).relu_key.expect("relu key"));
            assert_eq!(rk.model, 31, "nonlinear material is sharded by tenant id");
            // tenant B's position (same shape, different model id) sees
            // none of tenant A's nonlinear material
            let rk_b = relu_key_for(&reg.model(tb).key);
            assert_eq!(ctx.pool.as_ref().unwrap().len_relu(&rk_b), 0);
            // pop one pair → stock 1, at low: no refill
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.relu_items, 0, "stock 1 is at low water: no refill");
            // pop the second pair → stock 0 < low: paired top-up to high
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired top-up to high");
            let pool = ctx.detach_pool().unwrap();
            Ok((pool.len_mat(&mk), pool.len_relu(&rk)))
        });
        let (outs, _) = run.expect_ok();
        for (m, r) in &outs {
            assert_eq!((*m, *r), (2, 2), "mat and relu queues stay paired");
        }
    }

    #[test]
    fn partial_wave_key_is_registered_and_warmed_once() {
        // 5 queries at coalesce 2 → two full waves + one partial wave of 1
        let mut s = spec("m1", 41, 3);
        s.queries = 5;
        s.relu = true;
        assert_eq!(s.partial_rows(), Some(1));
        let pk = s.partial_key().expect("uneven workload has a partial key");
        assert_eq!(pk.rows, 1);
        assert_ne!(pk, s.key(), "partial wave is its own circuit position");
        // even workload: no partial position at all
        let mut even = spec("m2", 42, 3);
        even.queries = 4;
        assert_eq!(even.partial_key(), None);

        let run = run_4pc(NetProfile::zero(), 914, move |ctx| {
            let mut reg = ModelRegistry::new();
            let s = {
                let mut s = spec("m1", 41, 3);
                s.queries = 5;
                s.relu = true;
                s
            };
            let t = reg.load(ctx, s, 1, 4)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let m = reg.model(t);
            let (pk, prk) = (m.partial_key.unwrap(), m.partial_relu_key.unwrap());
            let o1 = reg.warm_partial(ctx, t)?;
            // idempotent: the position is stocked, a second warm is a no-op
            let o2 = reg.warm_partial(ctx, t)?;
            let pool = ctx.pool.as_ref().unwrap();
            Ok((o1.mat_items, o1.relu_items, o2.mat_items, pool.len_mat(&pk), pool.len_relu(&prk)))
        });
        let (outs, _) = run.expect_ok();
        for (m1, r1, m2, pm, pr) in &outs {
            assert_eq!((*m1, *r1), (1, 1), "one paired partial bundle");
            assert_eq!(*m2, 0, "second warm-up is a no-op");
            assert_eq!((*pm, *pr), (1, 1), "partial position stocked exactly once");
        }
    }

    #[test]
    fn quarantined_tenant_stops_refilling_and_steering() {
        let run = run_4pc(NetProfile::zero(), 915, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 51, 3), 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 52, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            reg.quarantine(ta);
            assert!(reg.is_quarantined(ta));
            // a tick on the quarantined tenant is a silent no-op
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 0, "quarantined tick fills nothing");
            // steering skips the quarantined tenant even though it is the
            // most depleted
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(tb));
            let o = reg.tick(ctx, tb, 8)?;
            Ok(o.mat_items)
        });
        let (outs, _) = run.expect_ok();
        for items in &outs {
            assert_eq!(*items, 2, "the innocent tenant keeps refilling");
        }
    }

    #[test]
    fn registry_loads_tenants_and_steers_refill_to_the_most_depleted_pool() {
        let run = run_4pc(NetProfile::zero(), 910, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 11, 3), 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 22, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            // both pools empty, both eligible: deficit ties → lowest index
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(ta));
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 2, "cold pool fills to high");
            // tenant A full: B is now the most depleted
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(tb));
            // … unless B is ineligible
            assert_eq!(reg.most_depleted(ctx, &[true, false]), None);
            // a demand cap below the water marks bounds the top-up
            let o = reg.tick(ctx, tb, 1)?;
            assert_eq!(o.mat_items, 1, "top-up capped by remaining demand");
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 0, "stock 1 is at low water: no refill");
            let _ = ctx.pool_mut().unwrap().pop_mat(&reg.model(tb).key).unwrap();
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 2, "uncapped refill tops back up to high");
            assert_eq!(reg.most_depleted(ctx, &[true, true]), None, "both full");
            let pool = ctx.detach_pool().unwrap();
            Ok((
                pool.len_mat(&reg.model(ta).key),
                pool.len_mat(&reg.model(tb).key),
            ))
        });
        let (outs, report) = run.expect_ok();
        for (a, b) in &outs {
            assert_eq!(*a, 2);
            assert_eq!(*b, 2);
        }
        // registry loading + refill generation is offline-silent online
        assert!(report.value_bits[0] > 0, "fills are offline traffic");
    }
}
