//! Model registry — multi-model residency over the circuit-keyed pool.
//!
//! The registry owns the platform's resident models. For each tenant it
//! holds the shared weights, the tenant's [`CircuitKey`] (whose `model`
//! field **is** the tenant id — the keyed pool of `pool/mat.rs` shards by
//! it, so tenant A's pre-generated correlations are unreachable under
//! tenant B's key and a cross-tenant pop fails closed), and a private
//! background-[`Refill`] producer with that tenant's water marks. The
//! serving engine interleaves refill ticks **per tenant** between waves,
//! steered to the most-depleted pool ([`ModelRegistry::most_depleted`]).
//!
//! Loading is a lockstep protocol step: every party calls
//! [`ModelRegistry::load`] in the same tenant order, the model owner (P1)
//! contributing the weight values, and the sharing is verified before any
//! pool material is generated against it. All registry state that steers
//! scheduling (keys, marks, stock levels) is public and identical at the
//! four parties.

use crate::crypto::Rng;
use crate::ml::{share_fixed_mat, F64Mat, TrainLayerKeys};
use crate::net::{Abort, P1, P2};
use crate::pool::{
    fill_layer_vec, fill_train_vec, relu_key_for, CircuitKey, LayerTarget, OpKind, Refill,
    RefillOutcome, TrainLayerTarget, WaterMarks,
};
use crate::proto::Ctx;
use crate::ring::fixed::FRAC_BITS;
use crate::ring::Z64;
use crate::sharing::MMat;

use super::workload::{TrainKind, Workload, BACK_GATE_BASE, GRAD_GATE_BASE};

/// Domain separator for per-tenant resident weights.
const TW_SEED: u64 = 0x7363_6864_5f77_3174;

/// One tenant of the serving platform: a resident model plus its traffic
/// contract. Everything here is public schedule metadata, identical at all
/// four parties.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Human-readable tenant/model name (CLI `--models m1,m2`).
    pub name: String,
    /// Resident-model id — becomes `CircuitKey::model`, sharding the
    /// pooled offline material per tenant.
    pub model: u64,
    /// Feature count of the tenant's linear model.
    pub d: usize,
    /// Rows per query (client-side mini-batch).
    pub rows_per_query: usize,
    /// Queries this tenant submits in the workload.
    pub queries: usize,
    /// Max queries coalesced into one of this tenant's waves.
    pub coalesce: usize,
    /// Weighted-round-robin share.
    pub weight: u64,
    /// Priority class of this tenant's queries (0 = highest).
    pub class: u8,
    /// Relative deadline in logical ticks (`None` = no deadline).
    pub deadline_ticks: Option<u64>,
    /// Admission-control cap on admitted-but-unanswered queries
    /// (`None` = uncapped).
    pub inflight_cap: Option<usize>,
    /// Arrivals per logical tick (0 = the whole workload arrives at tick 0).
    pub arrive_per_tick: usize,
    /// Apply a batched ReLU after the linear layer.
    pub relu: bool,
    /// Hidden/output widths of a **deep resident network**: a tenant with
    /// `layers = [h1, …, out]` serves the N-layer forward pass
    /// `d → h1 → … → out` with ReLU on every hidden layer (the final layer
    /// is linear). Empty = the legacy single linear layer `d → 1` (with
    /// `relu` optionally gating its output). Each layer gets its own
    /// circuit key (`CircuitKey::layer` = position), and a warm wave pops
    /// one whole per-layer bundle vector.
    pub layers: Vec<usize>,
    /// What this tenant runs through the shared queue/planner: a
    /// latency-sensitive inference stream (the default) or a **scheduled
    /// training job** — epochs admitted as queries (query id = epoch), one
    /// epoch per wave, drawing from the same per-tenant circuit-keyed pool
    /// plus the gradient/back-prop gate families of
    /// [`crate::sched::workload`].
    pub workload: Workload,
    /// Seed for this tenant's deterministic weights/queries.
    pub seed: u64,
    /// Which 4PC protocol family serves this tenant's waves
    /// ([`crate::proto::Backend`]): Trident secure-with-abort (default),
    /// Tetrad-style fair, or Tetrad-style GOD. The serving engine also
    /// overrides this at runtime for a quarantined tenant under
    /// `--failover god` — see the failover state machine in `serve/multi.rs`.
    pub backend: crate::proto::Backend,
}

impl TenantSpec {
    /// A small default contract: weight 1, class 0, no deadline, no cap.
    pub fn new(name: &str, model: u64, d: usize, queries: usize, coalesce: usize) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            model,
            d,
            rows_per_query: 1,
            queries,
            coalesce,
            weight: 1,
            class: 0,
            deadline_ticks: None,
            inflight_cap: None,
            arrive_per_tick: 0,
            relu: false,
            layers: Vec::new(),
            workload: Workload::Inference,
            seed: 0x7465_6e61 ^ model,
            backend: crate::proto::Backend::Trident,
        }
    }

    /// A **training tenant**: `epochs` mini-batch GD epochs over a fixed
    /// `batch`-row dataset, admitted through the same queue/planner as
    /// inference traffic — one epoch per wave, query id = epoch. Training
    /// rides at priority class 1 (inference defaults to class 0) so a
    /// saturating job can never displace latency-sensitive waves, and at
    /// `coalesce = 1` because an epoch is inherently sequential. `layers`
    /// empty = the 1-layer linreg/logreg shape `d → 1`; non-empty = a deep
    /// ReLU network (`kind` must be [`TrainKind::Nn`]).
    #[allow(clippy::too_many_arguments)]
    pub fn training(
        name: &str,
        model: u64,
        d: usize,
        layers: Vec<usize>,
        kind: TrainKind,
        epochs: usize,
        batch: usize,
        checkpoint_every: usize,
        lr_pow: u32,
    ) -> TenantSpec {
        assert!(
            batch.is_power_of_two(),
            "training batch {batch} is not a power of two: the 1/B gradient scale is a ring shift"
        );
        assert_eq!(
            kind == TrainKind::Nn,
            !layers.is_empty(),
            "deep layers iff the tenant trains a neural network"
        );
        let mut s = TenantSpec::new(name, model, d, epochs, 1);
        s.rows_per_query = batch;
        s.class = 1;
        s.layers = layers;
        s.workload = Workload::Training { kind, epochs, batch, checkpoint_every, lr_pow };
        s
    }

    /// Whether this tenant is a scheduled training job.
    pub fn is_training(&self) -> bool {
        self.workload.is_training()
    }

    /// Gradient-matmul shift of a training tenant: `α/B` folded into the
    /// free truncation (`FRAC_BITS + lr_pow + log2(batch)`; exact by the
    /// power-of-two batch invariant of [`TenantSpec::training`]).
    pub fn grad_shift(&self) -> u32 {
        let (_, _, batch, _, lr_pow) = self.workload.training().expect("training tenant");
        FRAC_BITS + lr_pow + batch.trailing_zeros()
    }

    /// Gate **windows** of one of this tenant's waves — what the serving
    /// engine sizes its per-tenant trace vectors with: `depth` for an
    /// inference wave (one matmul+activation window per layer), `3·depth−1`
    /// for a training epoch (forward windows, then per layer in reverse a
    /// gradient window and — layers ≥ 1 — a back-propagation window).
    pub fn gate_windows(&self) -> usize {
        if self.is_training() {
            3 * self.depth() - 1
        } else {
            self.depth()
        }
    }

    /// The whole per-layer **training** key set, gate order: forward keys
    /// shared with the inference path (`layer_keys` at the wave's stacked
    /// rows), plus the gradient family at `GRAD_GATE_BASE` (double-masked,
    /// shift = [`TenantSpec::grad_shift`]) and the back-propagation family
    /// at `BACK_GATE_BASE` (shift = `FRAC_BITS`, layers ≥ 1). The disjoint
    /// layer bases keep the three families from aliasing in the pool even
    /// on square layers.
    pub fn train_keys(&self) -> Vec<TrainLayerKeys> {
        assert!(self.is_training(), "train_keys on an inference tenant");
        let dims = self.layer_dims();
        let batch = self.wave_rows();
        (0..self.depth())
            .map(|l| {
                let fwd = tenant_layer_key(self, batch, l);
                let grad = CircuitKey {
                    model: self.model,
                    layer: GRAD_GATE_BASE + l as u32,
                    op: OpKind::MatMulTr { shift: self.grad_shift() },
                    rows: dims[l],
                    inner: batch,
                    cols: dims[l + 1],
                    dealer: P2,
                };
                let back = (l > 0).then(|| CircuitKey {
                    model: self.model,
                    layer: BACK_GATE_BASE + l as u32,
                    op: OpKind::MatMulTr { shift: FRAC_BITS },
                    rows: batch,
                    inner: dims[l + 1],
                    cols: dims[l],
                    dealer: P2,
                });
                TrainLayerKeys {
                    fwd,
                    relu: self.layer_relu(l).then(|| relu_key_for(&fwd)),
                    grad,
                    back,
                }
            })
            .collect()
    }

    /// Whether this tenant is a deep resident network (≥ 1 hidden layer)
    /// rather than the legacy single linear layer.
    pub fn is_deep(&self) -> bool {
        !self.layers.is_empty()
    }

    /// Wire widths of the resident network, input first: `[d, h1, …, out]`
    /// for a deep tenant, `[d, 1]` for the legacy single layer.
    pub fn layer_dims(&self) -> Vec<usize> {
        let mut dims = vec![self.d];
        if self.layers.is_empty() {
            dims.push(1);
        } else {
            dims.extend_from_slice(&self.layers);
        }
        dims
    }

    /// Number of matrix gates in the forward pass.
    pub fn depth(&self) -> usize {
        self.layer_dims().len() - 1
    }

    /// Output width of the network (1 for the legacy single layer).
    pub fn out_cols(&self) -> usize {
        *self.layer_dims().last().expect("at least two dims")
    }

    /// Whether layer `l`'s matmul feeds a ReLU: every hidden layer of a
    /// deep network does (the final layer is linear); the legacy single
    /// layer follows the tenant's `relu` flag.
    pub fn layer_relu(&self, l: usize) -> bool {
        if self.is_deep() {
            l + 1 < self.depth()
        } else {
            self.relu
        }
    }

    /// The whole **per-layer key vector** of a wave of `rows` stacked
    /// rows: one `(mat, relu?)` circuit-key pair per layer, gate order.
    /// This is the unit the pool pops ([`crate::pool::Pool::check_layer_vec`])
    /// and the refill restocks atomically.
    pub fn layer_keys(&self, rows: usize) -> Vec<(CircuitKey, Option<CircuitKey>)> {
        (0..self.depth())
            .map(|l| {
                let mk = tenant_layer_key(self, rows, l);
                (mk, self.layer_relu(l).then(|| relu_key_for(&mk)))
            })
            .collect()
    }

    /// The coalescing factor real waves can reach (`coalesce` capped by the
    /// workload, 0 guarded as 1) — the registered key must match a wave the
    /// tenant can actually produce.
    pub fn effective_coalesce(&self) -> usize {
        self.coalesce.max(1).min(self.queries.max(1))
    }

    /// Stacked rows of one full coalesced wave.
    pub fn wave_rows(&self) -> usize {
        self.effective_coalesce() * self.rows_per_query
    }

    /// The circuit key of this tenant's resident linear layer for a full
    /// coalesced wave (the key the registry registers and refills).
    pub fn key(&self) -> CircuitKey {
        tenant_wave_key(self, self.wave_rows())
    }

    /// The paired nonlinear circuit key of a `relu: true` tenant's full
    /// coalesced wave (`None` for linear tenants). Keyed by the tenant's
    /// model id like the matrix key, so the formerly-shared bit-extraction
    /// material is **sharded per tenant** and a cross-tenant pop fails
    /// closed — per-tenant offline budgets are exact.
    pub fn relu_key(&self) -> Option<CircuitKey> {
        self.relu.then(|| tenant_relu_key(self, self.wave_rows()))
    }

    /// Stacked rows of the trailing **partial** wave, when the workload
    /// does not divide evenly (`queries % coalesce ≠ 0`); `None` when every
    /// wave is full. The partial wave is a real wave the tenant always
    /// produces exactly once per workload — its key must be registered at
    /// load like the full-wave key, or the last wave silently misses the
    /// pool and serves inline.
    pub fn partial_rows(&self) -> Option<usize> {
        let rem = self.queries % self.effective_coalesce();
        (rem != 0).then(|| rem * self.rows_per_query)
    }

    /// The circuit key of the trailing partial wave (`None` when the
    /// workload divides evenly).
    pub fn partial_key(&self) -> Option<CircuitKey> {
        self.partial_rows().map(|rows| tenant_wave_key(self, rows))
    }

    /// The paired nonlinear key of the trailing partial wave (`relu: true`
    /// tenants with a partial wave only).
    pub fn partial_relu_key(&self) -> Option<CircuitKey> {
        if !self.relu {
            return None;
        }
        self.partial_rows().map(|rows| tenant_relu_key(self, rows))
    }

    /// Arrival tick of query `id` under this tenant's arrival plan.
    pub fn arrival_tick(&self, id: usize) -> u64 {
        if self.arrive_per_tick == 0 {
            0
        } else {
            (id / self.arrive_per_tick) as u64
        }
    }
}

/// The circuit key of layer `layer` of tenant `spec`'s resident network
/// for a wave of `rows` stacked rows: `rows × dims[layer]` input against
/// the resident `dims[layer] × dims[layer+1]` weight. The `layer` field of
/// the key IS the gate position, so two layers of one model (or one layer
/// of two models) can never alias in the pool.
pub fn tenant_layer_key(spec: &TenantSpec, rows: usize, layer: usize) -> CircuitKey {
    let dims = spec.layer_dims();
    assert!(layer + 1 < dims.len(), "layer {layer} out of range");
    CircuitKey {
        model: spec.model,
        layer: layer as u32,
        op: OpKind::MatMulTr { shift: FRAC_BITS },
        rows,
        inner: dims[layer],
        cols: dims[layer + 1],
        dealer: P2,
    }
}

/// The circuit key of tenant `spec`'s **first** linear layer for a wave of
/// `rows` stacked feature rows (= the whole pipeline for a legacy
/// single-layer tenant). A trailing partial wave keys differently from
/// [`TenantSpec::key`] — its key is registered separately at load
/// ([`TenantSpec::partial_key`]) so it hits the pool like any full wave.
pub fn tenant_wave_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    tenant_layer_key(spec, rows, 0)
}

/// The nonlinear circuit key of tenant `spec`'s wave of `rows` stacked
/// rows — the [`tenant_wave_key`] position with `op` replaced by
/// `OpKind::Relu` over the wave's outputs.
pub fn tenant_relu_key(spec: &TenantSpec, rows: usize) -> CircuitKey {
    relu_key_for(&tenant_wave_key(spec, rows))
}

/// Deterministic resident weights for a tenant (at the model owner).
pub fn tenant_weights(d: usize, seed: u64) -> F64Mat {
    let mut rng = Rng::seeded(seed ^ TW_SEED);
    let mut w = F64Mat::zeros(d, 1);
    for j in 0..d {
        w.set(j, 0, rng.normal() * 0.1);
    }
    w
}

/// Deterministic per-layer resident weights for a tenant (at the model
/// owner), gate order. A legacy tenant gets exactly its historical
/// [`tenant_weights`] matrix as the single layer; deep layers draw from a
/// per-layer domain-separated stream, scaled by `1/√fan_in` so Q·.13
/// activations stay in range through the stack.
pub fn tenant_layer_weights(spec: &TenantSpec) -> Vec<F64Mat> {
    if !spec.is_deep() {
        return vec![tenant_weights(spec.d, spec.seed)];
    }
    let dims = spec.layer_dims();
    (0..spec.depth())
        .map(|l| {
            let mut rng = Rng::seeded(spec.seed ^ TW_SEED ^ (((l + 1) as u64) << 32));
            let (inn, out) = (dims[l], dims[l + 1]);
            let scale = 0.5 / (inn as f64).sqrt();
            let mut w = F64Mat::zeros(inn, out);
            for i in 0..inn {
                for j in 0..out {
                    w.set(i, j, rng.normal() * scale);
                }
            }
            w
        })
        .collect()
}

/// One layer of a loaded resident model: the shared weight block plus the
/// registered circuit keys of its full-wave and (for an uneven workload)
/// trailing-partial-wave positions.
pub struct TenantLayer {
    /// The layer's shared resident weights (`dims[l] × dims[l+1]`).
    pub w: MMat<Z64>,
    /// The full-wave matrix key at this gate position.
    pub key: CircuitKey,
    /// The paired nonlinear key when this layer feeds a ReLU.
    pub relu_key: Option<CircuitKey>,
    /// The trailing partial wave's matrix key (uneven workloads only).
    pub partial_key: Option<CircuitKey>,
    /// The partial wave's paired nonlinear key.
    pub partial_relu_key: Option<CircuitKey>,
    /// The gradient gate key (`A_lᵀ ∘ E_l`, training tenants only).
    pub grad_key: Option<CircuitKey>,
    /// The back-propagation gate key (`E_l ∘ W_lᵀ`, training tenants,
    /// layers ≥ 1 only).
    pub back_key: Option<CircuitKey>,
}

/// One loaded resident model: spec + per-layer shared weights/keys +
/// private refill producer. The per-gate `layers` vector is the one and
/// only key/weight API — read `layers[0]` for the historical single-layer
/// position.
pub struct ResidentModel {
    pub spec: TenantSpec,
    /// The whole resident network, gate order: shared weights plus
    /// registered keys per layer. `layers.len() == spec.depth()`; a legacy
    /// tenant has exactly one entry.
    pub layers: Vec<TenantLayer>,
    /// Quarantined after a tenant-scoped abort: refill ticks become no-ops
    /// and the depletion steering skips the tenant.
    quarantined: bool,
    marks: WaterMarks,
    refill: Refill,
}

impl ResidentModel {
    /// The refill water marks this tenant was registered with (high is
    /// clamped to the tenant's total full-wave demand at load).
    pub fn marks(&self) -> WaterMarks {
        self.marks
    }

    /// The full-wave per-layer key vector, gate order — the unit the pool
    /// pops ([`crate::pool::Pool::check_layer_vec`]) and restocks.
    pub fn layer_keys(&self) -> Vec<(CircuitKey, Option<CircuitKey>)> {
        self.layers.iter().map(|l| (l.key, l.relu_key)).collect()
    }

    /// The full-wave refill targets, gate order.
    pub fn layer_targets(&self) -> Vec<LayerTarget> {
        self.layers
            .iter()
            .map(|l| LayerTarget { key: l.key, relu: l.relu_key, w: l.w.clone() })
            .collect()
    }

    /// The trailing-partial-wave per-layer key vector (empty when the
    /// workload divides evenly).
    pub fn partial_layer_keys(&self) -> Vec<(CircuitKey, Option<CircuitKey>)> {
        self.layers
            .iter()
            .filter_map(|l| l.partial_key.map(|pk| (pk, l.partial_relu_key)))
            .collect()
    }

    /// The trailing-partial-wave refill targets (empty when the workload
    /// divides evenly).
    pub fn partial_layer_targets(&self) -> Vec<LayerTarget> {
        self.layers
            .iter()
            .filter_map(|l| {
                l.partial_key
                    .map(|pk| LayerTarget { key: pk, relu: l.partial_relu_key, w: l.w.clone() })
            })
            .collect()
    }

    /// The per-layer training key sets, gate order (training tenants only).
    pub fn train_keys(&self) -> Vec<TrainLayerKeys> {
        self.layers
            .iter()
            .map(|l| TrainLayerKeys {
                fwd: l.key,
                relu: l.relu_key,
                grad: l.grad_key.expect("training tenant layer has a grad key"),
                back: l.back_key,
            })
            .collect()
    }

    /// The whole-epoch training fill targets against the **current** weight
    /// shares (training tenants only) — regenerated per epoch, post-commit,
    /// because each epoch's bundles embed the epoch's weight λ.
    pub fn train_targets(&self) -> Vec<TrainLayerTarget> {
        self.layers
            .iter()
            .map(|l| TrainLayerTarget {
                fwd: l.key,
                relu: l.relu_key,
                grad: l.grad_key.expect("training tenant layer has a grad key"),
                back: l.back_key,
                w: l.w.clone(),
            })
            .collect()
    }

    /// The current per-layer weight shares, gate order.
    pub fn layer_weights(&self) -> Vec<MMat<Z64>> {
        self.layers.iter().map(|l| l.w.clone()).collect()
    }
}

/// Registry of resident models (see the module docs).
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<ResidentModel>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn model(&self, t: usize) -> &ResidentModel {
        &self.models[t]
    }

    /// Tenant weights for the wave planner, registry order.
    pub fn planner_weights(&self) -> Vec<u64> {
        self.models.iter().map(|m| m.spec.weight).collect()
    }

    /// Load one resident model (lockstep at all four parties, same tenant
    /// order everywhere): P1 contributes the deterministic per-layer
    /// weights, every layer is shared and registered under its own circuit
    /// key (`CircuitKey::layer` = gate position), and the tenant's refill
    /// runs on whole layer vectors at `{low, high}` water marks
    /// (keyed-matrix bundles; plus scaled bit-extraction material for every
    /// layer that feeds a ReLU). Returns the tenant index. The caller must
    /// flush verification after the last `load`, before any pool fill runs
    /// against the weights.
    pub fn load(
        &mut self,
        ctx: &mut Ctx,
        spec: TenantSpec,
        low_water: usize,
        high_water: usize,
    ) -> Result<usize, Abort> {
        // the model id IS the pool shard: two tenants sharing one id would
        // file correlations generated against different resident weights
        // into one keyed queue, and the embedded-key fail-closed check
        // could no longer tell them apart — reject at load, loudly
        assert!(
            self.models.iter().all(|m| m.spec.model != spec.model),
            "duplicate tenant model id {}: per-tenant pool sharding requires a unique CircuitKey::model per resident model",
            spec.model
        );
        let dims = spec.layer_dims();
        let rows = spec.wave_rows();
        let prows = spec.partial_rows();
        let train_keys = spec.is_training().then(|| spec.train_keys());
        let weights0 = (ctx.id() == P1).then(|| tenant_layer_weights(&spec));
        let mut layers = Vec::with_capacity(spec.depth());
        for l in 0..spec.depth() {
            let w0_l = weights0.as_ref().map(|ws| &ws[l]);
            let w = share_fixed_mat(ctx, P1, w0_l, dims[l], dims[l + 1])?;
            let key = tenant_layer_key(&spec, rows, l);
            let relu_key = spec.layer_relu(l).then(|| relu_key_for(&key));
            let partial_key = prows.map(|pr| tenant_layer_key(&spec, pr, l));
            let partial_relu_key = partial_key
                .filter(|_| spec.layer_relu(l))
                .map(|pk| relu_key_for(&pk));
            let grad_key = train_keys.as_ref().map(|tk| tk[l].grad);
            let back_key = train_keys.as_ref().and_then(|tk| tk[l].back);
            layers.push(TenantLayer {
                w,
                key,
                relu_key,
                partial_key,
                partial_relu_key,
                grad_key,
                back_key,
            });
        }
        // clamp the high-water mark to the tenant's total full-wave demand
        // so neither the warm-up fill nor a steady-state top-up can stock
        // more bundles than real waves will ever pop (the trailing partial
        // wave keys differently and is stocked exactly once at warm-up by
        // `warm_partial`, outside this state machine)
        let total_full_waves = spec.queries.max(1) / spec.effective_coalesce();
        let high = high_water.max(1).min(total_full_waves.max(1));
        let marks = WaterMarks::new(low_water.min(high), high);
        // keyed bundles — matrix AND (for `relu: true` tenants) the paired
        // nonlinear bundles — are filled by [`ModelRegistry::tick`] itself,
        // so the top-up can be capped by remaining demand. Nothing is
        // registered on the formerly-shared typed bitext/λ queues any more:
        // a tenant's nonlinear material lives under its own circuit key,
        // which is what makes per-tenant offline budgets exact. The private
        // producer stays for shapeless per-tenant targets a future pipeline
        // may add.
        let refill = Refill::new();
        self.models.push(ResidentModel { spec, layers, quarantined: false, marks, refill });
        Ok(self.models.len() - 1)
    }

    /// Stock tenant `t`'s trailing-partial-wave positions with exactly one
    /// whole layer-vector bundle (every layer's matrix bundle, paired with
    /// its ReLU where the layer feeds one). Called once during warm-up; a
    /// no-op for tenants whose workload divides evenly, whose partial
    /// vector is already stocked, or who are quarantined.
    /// Lockstep-deterministic like every fill.
    pub fn warm_partial(&self, ctx: &mut Ctx, t: usize) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        if m.quarantined || m.layers[0].partial_key.is_none() {
            return Ok(RefillOutcome::default());
        }
        let targets = m.partial_layer_targets();
        let keys = m.partial_layer_keys();
        if ctx.pool.as_ref().map_or(0, |p| p.layer_vec_stock(&keys)) > 0 {
            return Ok(RefillOutcome::default());
        }
        fill_layer_vec(ctx, &targets, 1)
    }

    /// Quarantine tenant `t` after a tenant-scoped abort: its refill ticks
    /// become no-ops, the between-waves depletion steering skips it, and
    /// its private producer's keyed targets are deregistered. The pool-side
    /// drain-and-poison ([`crate::pool::Pool::quarantine_model`]) is the
    /// caller's companion step. Idempotent; lockstep-deterministic (driven
    /// by public wave metadata).
    pub fn quarantine(&mut self, t: usize) {
        let m = &mut self.models[t];
        m.quarantined = true;
        let model = m.spec.model;
        m.refill.deregister_model(model);
    }

    /// Whether tenant `t` has been quarantined.
    pub fn is_quarantined(&self, t: usize) -> bool {
        self.models[t].quarantined
    }

    /// Rehabilitate tenant `t` after a clean failover streak: the exact
    /// inverse of [`ModelRegistry::quarantine`] — refill ticks, depletion
    /// steering and training fills resume (the keyed layer-key vector is
    /// re-registered implicitly, because [`ModelRegistry::tick`] derives
    /// its fill targets from the resident spec, not from retained refill
    /// state). The caller pairs this with
    /// [`crate::pool::Pool::unquarantine_model`] so restocked pushes stop
    /// being dropped by the pool-side guard. Idempotent;
    /// lockstep-deterministic (driven by the agreed failover-wave count).
    pub fn rehabilitate(&mut self, t: usize) {
        self.models[t].quarantined = false;
    }

    /// One cooperative refill step for tenant `t`'s pool targets (lockstep;
    /// offline-phase traffic only — see [`crate::pool::refill`]). The keyed
    /// top-up follows the refill state machine (`stock < low` → fill
    /// towards `high`) but never stocks more than `max_mat` bundles — the
    /// caller passes the tenant's remaining full-wave demand, so a
    /// late-run tick cannot strand material a trailing partial wave would
    /// never pop. `max_mat` is public schedule state, identical at all
    /// four parties.
    pub fn tick(
        &self,
        ctx: &mut Ctx,
        t: usize,
        max_mat: usize,
    ) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        let mut out = RefillOutcome::default();
        if m.quarantined || m.spec.is_training() {
            // quarantined: the pool-side push guard would drop the items
            // anyway. Training: its bundles embed the current epoch's
            // weight λ, so the wave path regenerates them post-commit
            // ([`ModelRegistry::fill_train`]) — a between-waves tick would
            // stock stale-λ material.
            return Ok(out);
        }
        let stock = ctx.pool.as_ref().map_or(0, |p| Self::vec_stock(p, m));
        if stock < m.marks.low {
            let need = (m.marks.high - stock).min(max_mat.saturating_sub(stock));
            if need > 0 {
                // layer-major atomic top-up: every layer position reaches
                // `stock + need` whole vectors before the tick returns
                let o = fill_layer_vec(ctx, &m.layer_targets(), stock + need)?;
                out.mat_items = o.mat_items;
                out.relu_items = o.relu_items;
            }
        }
        let rest = m.refill.tick(ctx)?;
        out.trunc_pairs = rest.trunc_pairs;
        out.lam = rest.lam;
        out.bitext = rest.bitext;
        Ok(out)
    }

    /// Regenerate training tenant `t`'s whole-epoch gate vector against its
    /// **current** weight shares (forward + gradient + back-prop bundles,
    /// drelu-gating material attached — see
    /// [`crate::pool::fill_train_vec`]). Called at warm-up and after every
    /// epoch commit; a no-op when a vector is already stocked or the tenant
    /// is quarantined. Lockstep-deterministic, offline-phase traffic only.
    pub fn fill_train(&self, ctx: &mut Ctx, t: usize) -> Result<RefillOutcome, Abort> {
        let m = &self.models[t];
        assert!(m.spec.is_training(), "fill_train on an inference tenant");
        if m.quarantined {
            return Ok(RefillOutcome::default());
        }
        fill_train_vec(ctx, &m.train_targets())
    }

    /// Commit training tenant `t`'s post-epoch weight shares. The caller
    /// regenerates the tenant's pool material afterwards
    /// ([`ModelRegistry::fill_train`]) — any bundle generated against the
    /// old weights is now mask-stale by construction.
    pub fn update_weights(&mut self, t: usize, ws: Vec<MMat<Z64>>) {
        let m = &mut self.models[t];
        assert!(m.spec.is_training(), "update_weights on an inference tenant");
        assert_eq!(ws.len(), m.layers.len(), "one weight block per layer");
        for (l, w) in m.layers.iter_mut().zip(ws) {
            assert_eq!(l.w.dims(), w.dims(), "weight shape is fixed for a job");
            l.w = w;
        }
    }

    /// The tenant's poppable keyed stock in whole layer-vector units: the
    /// min across every layer position of the paired matrix/nonlinear
    /// stock (the min keeps the refill state machine safe under any skew,
    /// though vector fills/pops keep the queues equal by construction).
    fn vec_stock(pool: &crate::pool::Pool, m: &ResidentModel) -> usize {
        pool.layer_vec_stock(&m.layer_keys())
    }

    /// The most-depleted tenant pool among `eligible` tenants: largest
    /// keyed-bundle deficit **below the tenant's low-water mark** — i.e.
    /// the tenant whose next refill tick will actually fill (a tick on a
    /// pool at or above low is a no-op by the refill state machine, so
    /// picking one would waste the between-waves slot). Ties go to the
    /// lowest tenant index; `None` when no eligible pool is below low.
    /// Deterministic — stock levels are lockstep state.
    pub fn most_depleted(&self, ctx: &Ctx, eligible: &[bool]) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (deficit, tenant)
        for (t, m) in self.models.iter().enumerate() {
            if !eligible.get(t).copied().unwrap_or(false)
                || m.quarantined
                || m.spec.is_training()
            {
                // training pools refill on the wave path (post-commit, per
                // epoch), never by between-waves steering
                continue;
            }
            let stock = ctx.pool.as_ref().map_or(0, |p| Self::vec_stock(p, m));
            let deficit = m.marks.low.saturating_sub(stock);
            if deficit == 0 {
                continue;
            }
            match best {
                Some((d, _)) if d >= deficit => {}
                _ => best = Some((deficit, t)),
            }
        }
        best.map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetProfile;
    use crate::pool::Pool;
    use crate::proto::run_4pc;

    fn spec(name: &str, model: u64, d: usize) -> TenantSpec {
        TenantSpec::new(name, model, d, 4, 2)
    }

    #[test]
    fn keys_are_sharded_by_tenant_model_id() {
        let a = spec("m1", 11, 4);
        let b = spec("m2", 22, 4);
        assert_ne!(a.key(), b.key(), "same shape, different tenant → different key");
        assert_eq!(a.key().model, 11);
        assert_eq!(b.key().model, 22);
    }

    #[test]
    fn effective_coalesce_guards_zero_and_oversize() {
        let mut s = spec("m", 1, 4);
        s.coalesce = 0;
        assert_eq!(s.effective_coalesce(), 1, "coalesce 0 treated as 1");
        s.coalesce = 99;
        assert_eq!(s.effective_coalesce(), s.queries, "capped by the workload");
    }

    #[test]
    fn arrival_plan_is_deterministic() {
        let mut s = spec("m", 1, 4);
        assert_eq!(s.arrival_tick(3), 0, "burst plan: everything at tick 0");
        s.arrive_per_tick = 2;
        assert_eq!(
            (0..6).map(|i| s.arrival_tick(i)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1, 2, 2]
        );
    }

    #[test]
    fn registry_rejects_duplicate_model_ids() {
        // the assert fires inside every party thread (same public spec at
        // all four), so each thread dies before any protocol message and
        // the cluster reports four dead parties
        let run = run_4pc(NetProfile::zero(), 911, |ctx| {
            let mut reg = ModelRegistry::new();
            reg.load(ctx, spec("m1", 7, 3), 1, 2)?;
            // same model id with different weights/seed: must fail fast at
            // load instead of silently sharing one pool shard
            reg.load(ctx, TenantSpec::new("m1-again", 7, 3, 4, 2), 1, 2)?;
            Ok(())
        });
        assert!(run.all_aborted(), "duplicate model id must refuse to load");
    }

    #[test]
    fn high_water_is_clamped_to_total_full_wave_demand() {
        let run = run_4pc(NetProfile::zero(), 912, |ctx| {
            let mut reg = ModelRegistry::new();
            // 4 queries at coalesce 2 = 2 full waves, but high-water 5:
            // stocking 5 bundles would strand 3 — the registry clamps
            let t = reg.load(ctx, spec("m1", 11, 3), 1, 5)?;
            ctx.flush_verify()?;
            Ok(reg.model(t).marks())
        });
        let (outs, _) = run.expect_ok();
        for m in &outs {
            assert_eq!(m.high, 2, "high clamped to the 2 poppable full waves");
            assert_eq!(m.low, 1);
        }
    }

    #[test]
    fn relu_tenant_refills_paired_bundles_per_tenant() {
        // a `relu: true` tenant's nonlinear material is keyed by ITS model
        // id (no shared typed queue): the tick fills MatCorr+ReluCorr in
        // pairs, the watermark state machine runs on the paired stock, and
        // another tenant's key never sees the material
        let run = run_4pc(NetProfile::zero(), 913, |ctx| {
            let mut reg = ModelRegistry::new();
            let mut sa = spec("m1", 31, 3);
            sa.relu = true;
            let ta = reg.load(ctx, sa, 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 32, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired cold fill");
            let (mk, rk) = (
                reg.model(ta).layers[0].key,
                reg.model(ta).layers[0].relu_key.expect("relu key"),
            );
            assert_eq!(rk.model, 31, "nonlinear material is sharded by tenant id");
            // tenant B's position (same shape, different model id) sees
            // none of tenant A's nonlinear material
            let rk_b = relu_key_for(&reg.model(tb).layers[0].key);
            assert_eq!(ctx.pool.as_ref().unwrap().len_relu(&rk_b), 0);
            // pop one pair → stock 1, at low: no refill
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.relu_items, 0, "stock 1 is at low water: no refill");
            // pop the second pair → stock 0 < low: paired top-up to high
            let _ = ctx.pool_mut().unwrap().pop_mat(&mk).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_relu(&rk).unwrap().expect("stocked");
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 2), "paired top-up to high");
            let pool = ctx.detach_pool().unwrap();
            Ok((pool.len_mat(&mk), pool.len_relu(&rk)))
        });
        let (outs, _) = run.expect_ok();
        for (m, r) in &outs {
            assert_eq!((*m, *r), (2, 2), "mat and relu queues stay paired");
        }
    }

    #[test]
    fn partial_wave_key_is_registered_and_warmed_once() {
        // 5 queries at coalesce 2 → two full waves + one partial wave of 1
        let mut s = spec("m1", 41, 3);
        s.queries = 5;
        s.relu = true;
        assert_eq!(s.partial_rows(), Some(1));
        let pk = s.partial_key().expect("uneven workload has a partial key");
        assert_eq!(pk.rows, 1);
        assert_ne!(pk, s.key(), "partial wave is its own circuit position");
        // even workload: no partial position at all
        let mut even = spec("m2", 42, 3);
        even.queries = 4;
        assert_eq!(even.partial_key(), None);

        let run = run_4pc(NetProfile::zero(), 914, move |ctx| {
            let mut reg = ModelRegistry::new();
            let s = {
                let mut s = spec("m1", 41, 3);
                s.queries = 5;
                s.relu = true;
                s
            };
            let t = reg.load(ctx, s, 1, 4)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let m = reg.model(t);
            let (pk, prk) = (
                m.layers[0].partial_key.unwrap(),
                m.layers[0].partial_relu_key.unwrap(),
            );
            let o1 = reg.warm_partial(ctx, t)?;
            // idempotent: the position is stocked, a second warm is a no-op
            let o2 = reg.warm_partial(ctx, t)?;
            let pool = ctx.pool.as_ref().unwrap();
            Ok((o1.mat_items, o1.relu_items, o2.mat_items, pool.len_mat(&pk), pool.len_relu(&prk)))
        });
        let (outs, _) = run.expect_ok();
        for (m1, r1, m2, pm, pr) in &outs {
            assert_eq!((*m1, *r1), (1, 1), "one paired partial bundle");
            assert_eq!(*m2, 0, "second warm-up is a no-op");
            assert_eq!((*pm, *pr), (1, 1), "partial position stocked exactly once");
        }
    }

    #[test]
    fn quarantined_tenant_stops_refilling_and_steering() {
        let run = run_4pc(NetProfile::zero(), 915, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 51, 3), 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 52, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            reg.quarantine(ta);
            assert!(reg.is_quarantined(ta));
            // a tick on the quarantined tenant is a silent no-op
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 0, "quarantined tick fills nothing");
            // steering skips the quarantined tenant even though it is the
            // most depleted
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(tb));
            let o = reg.tick(ctx, tb, 8)?;
            Ok(o.mat_items)
        });
        let (outs, _) = run.expect_ok();
        for items in &outs {
            assert_eq!(*items, 2, "the innocent tenant keeps refilling");
        }
    }

    #[test]
    fn rehabilitated_tenant_steers_and_restocks_again() {
        // the satellite fix: quarantine deregisters the tenant's keyed
        // steering, rehabilitation restores it — `most_depleted` must point
        // back at the rehabilitated (drained) pool and the next tick must
        // actually restock it through the no-longer-poisoned push guard
        let run = run_4pc(NetProfile::zero(), 919, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 61, 3), 1, 2)?;
            let _tb = reg.load(ctx, spec("m2", 62, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            // stock both, then quarantine A: pool drained + steering off
            reg.tick(ctx, ta, 8)?;
            reg.tick(ctx, _tb, 8)?;
            let model = reg.model(ta).spec.model;
            let drained = ctx.pool_mut().unwrap().quarantine_model(model);
            assert!(drained.0 > 0, "quarantine drains the stocked shards");
            reg.quarantine(ta);
            assert_eq!(
                reg.most_depleted(ctx, &[true, true]),
                None,
                "quarantined tenant never steers, even fully drained"
            );
            // a push at a quarantined key is dropped by the pool guard, so
            // a (buggy) premature tick would leave the stock at zero
            reg.rehabilitate(ta);
            ctx.pool_mut().unwrap().unquarantine_model(model);
            assert!(!reg.is_quarantined(ta));
            assert_eq!(
                reg.most_depleted(ctx, &[true, true]),
                Some(ta),
                "rehabilitated drained pool is the most depleted again"
            );
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 2, "restock flows again after unquarantine");
            let pool = ctx.detach_pool().unwrap();
            Ok(pool.len_mat(&reg.model(ta).layers[0].key))
        });
        let (outs, _) = run.expect_ok();
        for stock in &outs {
            assert_eq!(*stock, 2, "rehabilitated pool is warm again");
        }
    }

    #[test]
    fn registry_loads_tenants_and_steers_refill_to_the_most_depleted_pool() {
        let run = run_4pc(NetProfile::zero(), 910, |ctx| {
            let mut reg = ModelRegistry::new();
            let ta = reg.load(ctx, spec("m1", 11, 3), 1, 2)?;
            let tb = reg.load(ctx, spec("m2", 22, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            // both pools empty, both eligible: deficit ties → lowest index
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(ta));
            let o = reg.tick(ctx, ta, 8)?;
            assert_eq!(o.mat_items, 2, "cold pool fills to high");
            // tenant A full: B is now the most depleted
            assert_eq!(reg.most_depleted(ctx, &[true, true]), Some(tb));
            // … unless B is ineligible
            assert_eq!(reg.most_depleted(ctx, &[true, false]), None);
            // a demand cap below the water marks bounds the top-up
            let o = reg.tick(ctx, tb, 1)?;
            assert_eq!(o.mat_items, 1, "top-up capped by remaining demand");
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 0, "stock 1 is at low water: no refill");
            let _ = ctx.pool_mut().unwrap().pop_mat(&reg.model(tb).layers[0].key).unwrap();
            let o = reg.tick(ctx, tb, 8)?;
            assert_eq!(o.mat_items, 2, "uncapped refill tops back up to high");
            assert_eq!(reg.most_depleted(ctx, &[true, true]), None, "both full");
            let pool = ctx.detach_pool().unwrap();
            Ok((
                pool.len_mat(&reg.model(ta).layers[0].key),
                pool.len_mat(&reg.model(tb).layers[0].key),
            ))
        });
        let (outs, report) = run.expect_ok();
        for (a, b) in &outs {
            assert_eq!(*a, 2);
            assert_eq!(*b, 2);
        }
        // registry loading + refill generation is offline-silent online
        assert!(report.value_bits[0] > 0, "fills are offline traffic");
    }

    fn deep_spec(name: &str, model: u64) -> TenantSpec {
        let mut s = TenantSpec::new(name, model, 4, 4, 2);
        s.layers = vec![8, 8, 2];
        s
    }

    #[test]
    fn deep_spec_keys_cover_every_layer_in_gate_order() {
        let s = deep_spec("nn3", 61);
        assert!(s.is_deep());
        assert_eq!(s.layer_dims(), vec![4, 8, 8, 2]);
        assert_eq!(s.depth(), 3);
        assert_eq!(s.out_cols(), 2);
        let dims = s.layer_dims();
        let keys = s.layer_keys(2);
        assert_eq!(keys.len(), 3);
        for (l, (mk, rk)) in keys.iter().enumerate() {
            assert_eq!(mk.layer, l as u32, "the key layer IS the gate position");
            assert_eq!(mk.rows, 2);
            assert_eq!((mk.inner, mk.cols), (dims[l], dims[l + 1]));
            assert_eq!(rk.is_some(), l + 1 < 3, "hidden layers pair a ReLU; the head is linear");
        }
        let ws = tenant_layer_weights(&s);
        assert_eq!(ws.len(), 3);
        assert_eq!((ws[1].rows, ws[1].cols), (8, 8));
        // legacy spec: one layer, identical to the historical wave key
        let leg = spec("m1", 62, 5);
        assert_eq!(leg.layer_keys(leg.wave_rows()), vec![(leg.key(), None)]);
        assert_eq!(tenant_layer_weights(&leg)[0].data, tenant_weights(5, leg.seed).data);
    }

    #[test]
    fn deep_tenant_refills_and_steers_in_whole_layer_vector_units() {
        let run = run_4pc(NetProfile::zero(), 916, |ctx| {
            let mut reg = ModelRegistry::new();
            let s = {
                let mut s = TenantSpec::new("nn", 71, 3, 4, 2);
                s.layers = vec![4, 2];
                s
            };
            let t = reg.load(ctx, s, 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let o = reg.tick(ctx, t, 8)?;
            // 2 vectors × 2 matrix layers; only the hidden layer pairs ReLU
            assert_eq!((o.mat_items, o.relu_items), (4, 2), "cold fill in vector units");
            let keys = reg.model(t).layer_keys();
            assert_eq!(ctx.pool.as_ref().unwrap().layer_vec_stock(&keys), 2);
            // drain ONLY the head layer's matrix queue → vector stock 0
            let head = keys[1].0;
            let _ = ctx.pool_mut().unwrap().pop_mat(&head).unwrap().expect("stocked");
            let _ = ctx.pool_mut().unwrap().pop_mat(&head).unwrap().expect("stocked");
            assert_eq!(ctx.pool.as_ref().unwrap().layer_vec_stock(&keys), 0);
            assert_eq!(reg.most_depleted(ctx, &[true]), Some(t), "vector stock steers depletion");
            let o = reg.tick(ctx, t, 8)?;
            assert_eq!((o.mat_items, o.relu_items), (2, 0), "top-up fills the short layer only");
            let pool = ctx.detach_pool().unwrap();
            Ok(pool.layer_vec_stock(&keys))
        });
        let (outs, _) = run.expect_ok();
        for s in &outs {
            assert_eq!(*s, 2, "whole vectors restored");
        }
    }

    #[test]
    fn deep_partial_wave_warms_the_whole_layer_vector_once() {
        let run = run_4pc(NetProfile::zero(), 917, |ctx| {
            let mut reg = ModelRegistry::new();
            let s = {
                let mut s = TenantSpec::new("nn", 81, 3, 5, 2);
                s.layers = vec![4, 2];
                s
            };
            let t = reg.load(ctx, s, 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            let o1 = reg.warm_partial(ctx, t)?;
            let o2 = reg.warm_partial(ctx, t)?;
            let pkeys = reg.model(t).partial_layer_keys();
            assert_eq!(pkeys.len(), 2);
            assert_eq!(pkeys[0].0.rows, 1, "partial wave stacks the 1 leftover query");
            let pool = ctx.pool.as_ref().unwrap();
            Ok((o1.mat_items, o1.relu_items, o2.total(), pool.layer_vec_stock(&pkeys)))
        });
        let (outs, _) = run.expect_ok();
        for (m1, r1, t2, st) in &outs {
            assert_eq!((*m1, *r1), (2, 1), "every partial position stocked, hidden ReLU paired");
            assert_eq!(*t2, 0, "second warm-up is a no-op");
            assert_eq!(*st, 1);
        }
    }

    #[test]
    fn training_tenant_mints_gate_families_and_fills_on_the_wave_path() {
        // spec level: contract, windows, key families and shapes
        let s = TenantSpec::training("job", 91, 4, vec![6, 2], TrainKind::Nn, 3, 4, 2, 3);
        assert!(s.is_training());
        assert_eq!(
            (s.queries, s.rows_per_query, s.effective_coalesce(), s.class),
            (3, 4, 1, 1),
            "epochs as queries, batch rows, no coalescing, background class"
        );
        assert_eq!(s.gate_windows(), 5, "3L−1 gate windows for L = 2");
        let tk = s.train_keys();
        assert_eq!(tk.len(), 2);
        assert_eq!(tk[0].fwd, s.layer_keys(s.wave_rows())[0].0, "forward keys shared with inference");
        assert_eq!(tk[0].grad.layer, GRAD_GATE_BASE);
        assert!(tk[0].back.is_none(), "layer 0 has no back gate");
        assert_eq!(tk[1].back.unwrap().layer, BACK_GATE_BASE + 1);
        assert_eq!((tk[1].grad.rows, tk[1].grad.inner, tk[1].grad.cols), (6, 4, 2));
        let bk = tk[1].back.unwrap();
        assert_eq!((bk.rows, bk.inner, bk.cols), (4, 2, 6));

        let run = run_4pc(NetProfile::zero(), 918, |ctx| {
            let mut reg = ModelRegistry::new();
            let s = TenantSpec::training("job", 91, 4, vec![6, 2], TrainKind::Nn, 3, 4, 2, 3);
            let t = reg.load(ctx, s, 1, 2)?;
            let ti = reg.load(ctx, spec("m1", 92, 3), 1, 2)?;
            ctx.flush_verify()?;
            ctx.attach_pool(Pool::new());
            // between-waves machinery never touches the training pool
            assert_eq!(reg.tick(ctx, t, 8)?.total(), 0, "tick skips training tenants");
            assert_eq!(
                reg.most_depleted(ctx, &[true, true]),
                Some(ti),
                "depletion steering skips training tenants"
            );
            // the wave path stocks one whole epoch vector…
            let o = reg.fill_train(ctx, t)?;
            assert_eq!(
                (o.mat_items, o.relu_items),
                (5, 1),
                "2 forward + 2 grad + 1 back bundles, hidden ReLU paired"
            );
            let gates = crate::ml::train_gate_keys(&reg.model(t).train_keys());
            assert!(ctx.pool_mut().unwrap().check_layer_vec_gates(&gates));
            // …and refuses to deepen the stock while it is poppable
            assert_eq!(reg.fill_train(ctx, t)?.total(), 0, "stock depth is 1");
            // weight commit keeps shapes fixed
            let ws = reg.model(t).layer_weights();
            reg.update_weights(t, ws);
            Ok(())
        });
        run.expect_ok();
    }
}
