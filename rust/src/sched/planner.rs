//! Fair wave planner — smooth **weighted round-robin** tenant selection.
//!
//! Each planning step the engine hands the planner an eligibility mask
//! (tenants with a pending query at the queue's best priority class, see
//! [`crate::sched::queue`]); the planner grants the wave to one of them so
//! that, under saturation, the number of waves granted to each tenant
//! tracks its weight share to within one wave over **any** window — the
//! classic smooth-WRR bound, which is what the meter test asserts.
//!
//! The algorithm (per step, over the eligible set only):
//!
//! ```text
//!   credit[i] += weight[i]        for every eligible i
//!   winner     = argmax credit    (tie → lowest tenant index)
//!   credit[winner] -= Σ weight[i] over eligible i
//! ```
//!
//! Ineligible tenants accumulate **no** credit: a tenant returning from an
//! empty backlog re-enters at its steady-state share instead of bursting
//! on saved-up debt (work conservation without bank-account starvation of
//! the others). All state is integers updated from public metadata, so the
//! planner is lockstep-deterministic across the four party threads.

/// Smooth weighted-round-robin wave planner (see the module docs).
pub struct WavePlanner {
    weights: Vec<u64>,
    credit: Vec<i128>,
    /// Waves granted per tenant.
    waves: Vec<usize>,
    /// Grant sequence, in order (tenant index per wave).
    order: Vec<usize>,
}

impl WavePlanner {
    /// `weights[i]` is tenant `i`'s share; every weight must be ≥ 1.
    pub fn new(weights: &[u64]) -> WavePlanner {
        assert!(!weights.is_empty(), "planner needs at least one tenant");
        assert!(weights.iter().all(|&w| w >= 1), "tenant weights must be >= 1");
        WavePlanner {
            weights: weights.to_vec(),
            credit: vec![0; weights.len()],
            waves: vec![0; weights.len()],
            order: Vec::new(),
        }
    }

    pub fn tenants(&self) -> usize {
        self.weights.len()
    }

    /// Waves granted per tenant so far.
    pub fn waves(&self) -> &[usize] {
        &self.waves
    }

    /// Grant sequence so far (tenant index per wave).
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// Grant the next wave to one eligible tenant, or `None` when no
    /// tenant is eligible.
    pub fn next(&mut self, eligible: &[bool]) -> Option<usize> {
        assert_eq!(eligible.len(), self.weights.len());
        let total: i128 = eligible
            .iter()
            .zip(&self.weights)
            .filter(|(&e, _)| e)
            .map(|(_, &w)| w as i128)
            .sum();
        if total == 0 {
            return None;
        }
        let mut winner: Option<usize> = None;
        for i in 0..self.weights.len() {
            if !eligible[i] {
                continue;
            }
            self.credit[i] += self.weights[i] as i128;
            match winner {
                Some(w) if self.credit[w] >= self.credit[i] => {}
                _ => winner = Some(i),
            }
        }
        let w = winner.expect("total > 0 implies an eligible tenant");
        self.credit[w] -= total;
        self.waves[w] += 1;
        self.order.push(w);
        Some(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturated_two_tenant_split_tracks_weights_within_one_wave() {
        let mut p = WavePlanner::new(&[2, 1]);
        let both = [true, true];
        for n in 1..=30usize {
            p.next(&both).unwrap();
            let a = p.waves()[0] as f64;
            let want = n as f64 * 2.0 / 3.0;
            assert!(
                (a - want).abs() <= 1.0,
                "after {n} waves tenant A has {a}, want {want} ± 1"
            );
        }
        assert_eq!(p.waves(), &[20, 10], "exact 2:1 split over a full window");
    }

    #[test]
    fn equal_weights_alternate() {
        let mut p = WavePlanner::new(&[1, 1]);
        let grants: Vec<usize> = (0..6).map(|_| p.next(&[true, true]).unwrap()).collect();
        assert_eq!(grants, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn ineligible_tenants_are_skipped_without_accruing_debt() {
        let mut p = WavePlanner::new(&[1, 1]);
        // tenant 1 idle for three waves: tenant 0 gets all of them
        for _ in 0..3 {
            assert_eq!(p.next(&[true, false]), Some(0));
        }
        // tenant 1 returns: it does NOT get a compensating burst — the
        // steady 1:1 alternation resumes immediately
        let grants: Vec<usize> = (0..4).map(|_| p.next(&[true, true]).unwrap()).collect();
        assert_eq!(grants.iter().filter(|&&t| t == 0).count(), 2);
        assert_eq!(grants.iter().filter(|&&t| t == 1).count(), 2);
    }

    #[test]
    fn no_eligible_tenant_grants_nothing() {
        let mut p = WavePlanner::new(&[3, 2]);
        assert_eq!(p.next(&[false, false]), None);
        assert_eq!(p.waves(), &[0, 0]);
        assert!(p.order().is_empty());
    }

    #[test]
    fn three_way_weighted_split_is_proportional() {
        let mut p = WavePlanner::new(&[3, 2, 1]);
        let all = [true, true, true];
        for _ in 0..60 {
            p.next(&all).unwrap();
        }
        assert_eq!(p.waves(), &[30, 20, 10]);
    }
}
