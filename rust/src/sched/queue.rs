//! Deadline/priority request queue with admission control — the
//! multi-tenant replacement for the FIFO-only
//! [`RequestQueue`](crate::serve::RequestQueue) path.
//!
//! ## Ordering
//!
//! Each query carries a **priority class** (0 = highest) and an optional
//! **absolute deadline** in logical ticks (see the [`crate::sched`] module
//! docs for why the scheduler runs on ticks, never wall-clock). Within one
//! tenant's backlog, service order is:
//!
//! 1. effective priority class (ascending — see *Aging* below),
//! 2. earliest deadline first (queries without a deadline sort last),
//! 3. arrival tick, then query id — a total order, so every party pops the
//!    same batch.
//!
//! ## Aging (starvation freedom)
//!
//! A saturating stream of class-0 queries would otherwise starve class-1
//! forever. With `age_every = A > 0`, a query's *effective* class drops by
//! one for every `A` ticks it has waited: any query reaches class 0 after
//! at most `A · class` ticks and then competes on (deadline, arrival),
//! where its older arrival wins. `age_every = 0` disables aging.
//!
//! ## Expiry
//!
//! A query whose deadline has passed (`deadline < now`) is **counted and
//! dropped** at the tick boundary — it is never served late, and it stops
//! occupying its tenant's in-flight budget. A deadline equal to the
//! current tick is still serviceable: the deadline bounds the last tick at
//! which service may *start*.
//!
//! ## Admission control
//!
//! Per-tenant in-flight caps bound how much backlog one tenant can park in
//! the platform: a query is rejected at [`SchedQueue::admit`] when its
//! tenant already has `cap` queries admitted-but-unanswered (queued or in
//! service). Rejection is load shedding, not queueing — the caller sees it
//! immediately and the query is counted per tenant.

use crate::ml::F64Mat;

/// One tenant-tagged inference query. The clear feature rows exist only at
/// the data owner; everything else is public schedule metadata, identical
/// at all four parties.
#[derive(Clone, Debug)]
pub struct SchedQuery {
    /// Tenant (resident-model) index in the registry.
    pub tenant: usize,
    /// Query id, unique within its tenant.
    pub id: usize,
    /// Feature rows in this query.
    pub rows: usize,
    /// Priority class, 0 = highest.
    pub class: u8,
    /// Arrival logical tick.
    pub arrival: u64,
    /// Absolute deadline tick (last tick service may start); `None` = no
    /// deadline.
    pub deadline: Option<u64>,
    /// Feature rows, present at the data owner only.
    pub x: Option<F64Mat>,
}

/// Per-tenant accounting of everything the queue decided.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SchedQueueStats {
    /// Queries offered to `admit` per tenant.
    pub submitted: Vec<usize>,
    /// Queries accepted per tenant.
    pub admitted: Vec<usize>,
    /// Queries shed by the in-flight cap per tenant.
    pub rejected: Vec<usize>,
    /// Queries dropped past their deadline per tenant (never served).
    pub expired: Vec<usize>,
    /// Queries completed per tenant.
    pub served: Vec<usize>,
    /// Pops in which aging lifted at least one query above a younger,
    /// nominally-higher-priority one.
    pub aged_promotions: u64,
}

/// Deadline/priority-aware multi-tenant queue (see the module docs).
pub struct SchedQueue {
    pending: Vec<SchedQuery>,
    /// Promote a waiting query one class per this many ticks (0 = off).
    age_every: u64,
    /// Per-tenant in-flight caps (`usize::MAX` = uncapped).
    caps: Vec<usize>,
    /// Admitted-but-unanswered count per tenant (queued + in service).
    inflight: Vec<usize>,
    /// Tenants whose queries never age ([`SchedQueue::set_unaged`]):
    /// scheduled training jobs ride here so a saturating job can never be
    /// promoted into the latency-sensitive class — the priority-isolation
    /// invariant the serving tests lock.
    unaged: Vec<bool>,
    stats: SchedQueueStats,
}

impl SchedQueue {
    pub fn new(tenants: usize, age_every: u64) -> SchedQueue {
        SchedQueue {
            pending: Vec::new(),
            age_every,
            caps: vec![usize::MAX; tenants],
            inflight: vec![0; tenants],
            unaged: vec![false; tenants],
            stats: SchedQueueStats {
                submitted: vec![0; tenants],
                admitted: vec![0; tenants],
                rejected: vec![0; tenants],
                expired: vec![0; tenants],
                served: vec![0; tenants],
                aged_promotions: 0,
            },
        }
    }

    /// Cap tenant `t`'s admitted-but-unanswered queries.
    pub fn set_cap(&mut self, t: usize, cap: usize) {
        self.caps[t] = cap.max(1);
    }

    /// Exempt tenant `t` from aging: its queries keep their nominal class
    /// forever. Scheduled **training** tenants are registered unaged — a
    /// background epoch must wait for an idle slot no matter how long it
    /// has queued, so inference p99 under a saturating training job is
    /// *identical* to the idle-cluster p99 (the isolation test pins
    /// equality, not a bound). Starvation-freedom for training comes from
    /// waves being epoch-granular: any tick with no class-0 work runs the
    /// next epoch.
    pub fn set_unaged(&mut self, t: usize) {
        self.unaged[t] = true;
    }

    pub fn stats(&self) -> &SchedQueueStats {
        &self.stats
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pending (not yet popped) queries of tenant `t`.
    pub fn pending_tenant(&self, t: usize) -> usize {
        self.pending.iter().filter(|q| q.tenant == t).count()
    }

    /// Admitted-but-unanswered queries of tenant `t` (queued + in
    /// service) — the per-tenant in-flight gauge sampled at wave
    /// boundaries into the trace ([`crate::obs`]).
    pub fn inflight(&self, t: usize) -> usize {
        self.inflight[t]
    }

    /// Pending queries whose *effective* class at tick `now` is `class` —
    /// the per-class queue-depth gauge sampled at wave boundaries into
    /// the trace. Deterministic: effective classes are functions of
    /// public metadata and the tick.
    pub fn depth_class(&self, class: u8, now: u64) -> usize {
        self.pending.iter().filter(|q| self.effective_class(q, now) == class).count()
    }

    /// Admit or shed one query (admission control). Returns whether the
    /// query was accepted.
    pub fn admit(&mut self, q: SchedQuery) -> bool {
        let t = q.tenant;
        self.stats.submitted[t] += 1;
        if self.inflight[t] >= self.caps[t] {
            self.stats.rejected[t] += 1;
            return false;
        }
        self.inflight[t] += 1;
        self.stats.admitted[t] += 1;
        self.pending.push(q);
        true
    }

    /// Effective priority class of `q` at tick `now`: the nominal class
    /// minus one per `age_every` ticks waited (saturating at 0).
    fn effective_class(&self, q: &SchedQuery, now: u64) -> u8 {
        if self.age_every == 0 || self.unaged.get(q.tenant).copied().unwrap_or(false) {
            return q.class;
        }
        let waited = now.saturating_sub(q.arrival) / self.age_every;
        q.class.saturating_sub(waited.min(u8::MAX as u64) as u8)
    }

    /// Drop every pending query whose deadline has passed, counting it per
    /// tenant. Call once per tick, before planning. Returns how many were
    /// dropped.
    pub fn expire(&mut self, now: u64) -> usize {
        let mut dropped = 0;
        let mut i = 0;
        while i < self.pending.len() {
            let past = matches!(self.pending[i].deadline, Some(d) if d < now);
            if past {
                let q = self.pending.remove(i);
                self.stats.expired[q.tenant] += 1;
                debug_assert!(
                    self.inflight[q.tenant] > 0,
                    "expire underflows tenant {}'s in-flight count",
                    q.tenant
                );
                self.inflight[q.tenant] = self.inflight[q.tenant].saturating_sub(1);
                dropped += 1;
            } else {
                i += 1;
            }
        }
        dropped
    }

    /// The best (lowest) effective class over all pending queries.
    pub fn best_class(&self, now: u64) -> Option<u8> {
        self.pending.iter().map(|q| self.effective_class(q, now)).min()
    }

    /// Eligibility mask for the planner: tenant `t` is eligible when it has
    /// a pending query at the queue-wide best effective class.
    pub fn eligible_mask(&self, tenants: usize, now: u64) -> Vec<bool> {
        let mut mask = vec![false; tenants];
        if let Some(best) = self.best_class(now) {
            for q in &self.pending {
                if self.effective_class(q, now) == best {
                    mask[q.tenant] = true;
                }
            }
        }
        mask
    }

    /// Total order for one tenant's backlog: effective class, then EDF
    /// (no deadline sorts last), then arrival, then id.
    fn order_key(&self, q: &SchedQuery, now: u64) -> (u8, u64, u64, usize) {
        (
            self.effective_class(q, now),
            q.deadline.unwrap_or(u64::MAX),
            q.arrival,
            q.id,
        )
    }

    /// Pop tenant `t`'s next coalesced batch: up to `coalesce` queries
    /// (0 is guarded — treated as 1), best-first in the order above. Once
    /// a tenant is picked the batch fills with its best remaining queries
    /// regardless of class, to maximize coalescing. Deterministic: all
    /// parties hold identical metadata and pop identical batches — in
    /// particular the trailing partial batch (fewer than `coalesce`
    /// pending) is the same at every party.
    pub fn pop_batch(&mut self, t: usize, coalesce: usize, now: u64) -> Vec<SchedQuery> {
        let coalesce = coalesce.max(1);
        let mut idxs: Vec<usize> = (0..self.pending.len())
            .filter(|&i| self.pending[i].tenant == t)
            .collect();
        idxs.sort_by_key(|&i| self.order_key(&self.pending[i], now));
        idxs.truncate(coalesce);
        // detect an aging promotion: a nominally worse class scheduled
        // ahead of a better one still pending for this tenant
        if let Some(&first) = idxs.first() {
            let first_class = self.pending[first].class;
            let jumped = self
                .pending
                .iter()
                .any(|q| q.tenant == t && q.class < first_class);
            if jumped {
                self.stats.aged_promotions += 1;
            }
        }
        // remove back-to-front so earlier indices stay valid, then restore
        // the service order (the batch row order is the schedule order at
        // every party)
        idxs.sort_unstable();
        let mut keyed = Vec::with_capacity(idxs.len());
        for i in idxs.into_iter().rev() {
            let key = self.order_key(&self.pending[i], now);
            keyed.push((key, self.pending.remove(i)));
        }
        keyed.sort_by(|a, b| a.0.cmp(&b.0));
        keyed.into_iter().map(|(_, q)| q).collect()
    }

    /// Mark `n` of tenant `t`'s in-service queries answered. The in-flight
    /// decrement saturates (with a `debug_assert!`): a miscounting caller
    /// is a bug, but wrapping would permanently jam the tenant's admission
    /// cap in release builds.
    pub fn complete(&mut self, t: usize, n: usize) {
        debug_assert!(
            self.inflight[t] >= n,
            "complete({t}, {n}) underflows the in-flight count {}",
            self.inflight[t]
        );
        self.inflight[t] = self.inflight[t].saturating_sub(n);
        self.stats.served[t] += n;
    }

    /// Re-admit a popped-but-unanswered query after a contained abort: the
    /// query returns to the backlog with its **original** arrival tick (and
    /// class/deadline), so aging and EDF treat it exactly as if its wave
    /// had never run. No admission control and no stat changes — the query
    /// was already admitted and is still counted in-flight (its wave never
    /// called [`complete`](SchedQueue::complete)).
    pub fn readmit(&mut self, q: SchedQuery) {
        self.pending.push(q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(tenant: usize, id: usize, class: u8, arrival: u64, deadline: Option<u64>) -> SchedQuery {
        SchedQuery { tenant, id, rows: 1, class, arrival, deadline, x: None }
    }

    #[test]
    fn edf_orders_within_a_priority_class() {
        let mut sq = SchedQueue::new(1, 0);
        assert!(sq.admit(q(0, 0, 1, 0, Some(9))));
        assert!(sq.admit(q(0, 1, 1, 0, Some(3))));
        assert!(sq.admit(q(0, 2, 1, 0, None)));
        assert!(sq.admit(q(0, 3, 1, 0, Some(5))));
        let batch = sq.pop_batch(0, 4, 0);
        let ids: Vec<usize> = batch.iter().map(|q| q.id).collect();
        // earliest deadline first; no-deadline sorts last
        assert_eq!(ids, vec![1, 3, 0, 2]);
    }

    #[test]
    fn priority_class_beats_deadline_across_classes() {
        let mut sq = SchedQueue::new(1, 0);
        assert!(sq.admit(q(0, 0, 1, 0, Some(1)))); // urgent but class 1
        assert!(sq.admit(q(0, 1, 0, 0, Some(50)))); // relaxed but class 0
        let batch = sq.pop_batch(0, 2, 0);
        assert_eq!(batch[0].id, 1, "class 0 schedules before class 1");
        assert_eq!(batch[1].id, 0);
    }

    #[test]
    fn aging_prevents_starvation_under_saturating_high_priority_stream() {
        // class-1 query at tick 0; one fresh class-0 query arrives every
        // tick and one query is served per tick. Without aging the class-1
        // query would wait forever; with age_every = 3 it must be served by
        // tick 3 (it reaches effective class 0 and wins on arrival).
        let mut sq = SchedQueue::new(1, 3);
        assert!(sq.admit(q(0, 100, 1, 0, None)));
        let mut served_low_at = None;
        for now in 0..10u64 {
            sq.expire(now);
            assert!(sq.admit(q(0, now as usize, 0, now, None)));
            let batch = sq.pop_batch(0, 1, now);
            assert_eq!(batch.len(), 1);
            sq.complete(0, 1);
            if batch[0].id == 100 {
                served_low_at = Some(now);
                break;
            }
        }
        let at = served_low_at.expect("aged query must eventually be served");
        assert_eq!(at, 3, "effective class reaches 0 after age_every ticks");
        assert!(sq.stats().aged_promotions >= 1, "promotion must be accounted");
        // control: with aging disabled the class-1 query is still waiting
        // after the same workload
        let mut no_age = SchedQueue::new(1, 0);
        assert!(no_age.admit(q(0, 100, 1, 0, None)));
        for now in 0..10u64 {
            assert!(no_age.admit(q(0, now as usize, 0, now, None)));
            let batch = no_age.pop_batch(0, 1, now);
            assert_ne!(batch[0].id, 100, "without aging class 0 always wins");
            no_age.complete(0, 1);
        }
    }

    #[test]
    fn unaged_tenant_never_promotes_past_the_latency_class() {
        let mut sq = SchedQueue::new(2, 2);
        sq.set_unaged(1);
        // a training epoch queued at tick 0 …
        assert!(sq.admit(q(1, 0, 1, 0, None)));
        // … and a fresh class-0 inference query arriving much later
        assert!(sq.admit(q(0, 0, 0, 50, None)));
        // without the exemption the epoch would have aged to class 0 long
        // ago and won on arrival tick; unaged it keeps its nominal class
        assert_eq!(sq.best_class(50), Some(0));
        assert_eq!(sq.eligible_mask(2, 50), vec![true, false], "inference keeps the slot");
        assert_eq!(sq.depth_class(1, 50), 1, "the epoch still sits at class 1");
    }

    #[test]
    fn expired_queries_are_counted_and_never_served() {
        let mut sq = SchedQueue::new(1, 0);
        assert!(sq.admit(q(0, 0, 0, 0, Some(1))));
        assert!(sq.admit(q(0, 1, 0, 0, Some(4))));
        // a deadline equal to `now` is still serviceable …
        assert_eq!(sq.expire(1), 0);
        // … but one tick later the id-0 query is past due
        assert_eq!(sq.expire(2), 1);
        assert_eq!(sq.stats().expired[0], 1);
        let batch = sq.pop_batch(0, 4, 2);
        assert_eq!(batch.len(), 1, "expired query must never be served");
        assert_eq!(batch[0].id, 1);
        sq.complete(0, 1);
        assert_eq!(sq.stats().served[0], 1);
    }

    #[test]
    fn admission_cap_sheds_load_per_tenant() {
        let mut sq = SchedQueue::new(2, 0);
        sq.set_cap(0, 2);
        for id in 0..5 {
            sq.admit(q(0, id, 0, 0, None));
            assert!(sq.admit(q(1, id, 0, 0, None)), "uncapped tenant takes all");
        }
        assert_eq!(sq.stats().admitted[0], 2);
        assert_eq!(sq.stats().rejected[0], 3);
        assert_eq!(sq.stats().rejected[1], 0);
        // completing frees budget for later arrivals
        let batch = sq.pop_batch(0, 2, 0);
        assert_eq!(batch.len(), 2);
        sq.complete(0, 2);
        assert!(sq.admit(q(0, 9, 0, 1, None)), "freed in-flight budget re-admits");
    }

    #[test]
    fn coalesce_zero_is_guarded_and_trailing_partial_batch_is_deterministic() {
        let mut sq = SchedQueue::new(1, 0);
        for id in 0..5 {
            assert!(sq.admit(q(0, id, 0, 0, None)));
        }
        // coalesce == 0 must behave as 1, not panic or drain nothing
        let b0 = sq.pop_batch(0, 0, 0);
        assert_eq!(b0.len(), 1);
        assert_eq!(b0[0].id, 0);
        // waves of 2 then the trailing partial wave of 1, same every run
        let b1 = sq.pop_batch(0, 2, 0);
        assert_eq!(b1.iter().map(|q| q.id).collect::<Vec<_>>(), vec![1, 2]);
        let b2 = sq.pop_batch(0, 2, 0);
        assert_eq!(b2.iter().map(|q| q.id).collect::<Vec<_>>(), vec![3, 4]);
        let b3 = sq.pop_batch(0, 2, 0);
        assert!(b3.is_empty(), "drained queue pops an empty batch");
    }

    #[test]
    fn readmit_restores_original_order_without_touching_accounting() {
        let mut sq = SchedQueue::new(1, 0);
        assert!(sq.admit(q(0, 0, 0, 0, Some(9))));
        assert!(sq.admit(q(0, 1, 0, 1, Some(9))));
        assert!(sq.admit(q(0, 2, 0, 2, Some(9))));
        let batch = sq.pop_batch(0, 2, 3);
        assert_eq!(batch.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1]);
        // the wave aborted: both queries go back with their original ticks
        for q in batch {
            sq.readmit(q);
        }
        assert_eq!(sq.pending_tenant(0), 3);
        // accounting unchanged: still 3 admitted, 0 served, 0 expired
        assert_eq!(sq.stats().admitted[0], 3);
        assert_eq!(sq.stats().served[0], 0);
        // original arrival restored → the re-queued ids still sort first
        let again = sq.pop_batch(0, 3, 4);
        assert_eq!(again.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        sq.complete(0, 3);
        // all in-flight budget released: a full cap is available again
        sq.set_cap(0, 1);
        assert!(sq.admit(q(0, 9, 0, 5, None)), "in-flight budget fully freed");
    }

    #[test]
    fn readmitted_query_ages_from_its_original_arrival() {
        // pin the post-quarantine contract: a readmitted query's effective
        // class is computed from the tick it FIRST arrived, not from when
        // it was readmitted — the aborted wave must not reset its aging
        // clock. Tick-deterministic: fixed ticks, no randomness.
        let mut sq = SchedQueue::new(1, 2);
        assert!(sq.admit(q(0, 7, 1, 0, None)));
        // its wave runs at tick 0 and is quarantined → readmit
        let popped = sq.pop_batch(0, 1, 0);
        assert_eq!(popped[0].id, 7);
        for p in popped {
            sq.readmit(p);
        }
        // a fresh class-1 rival arrives at tick 4; by then the survivor has
        // waited 4 ticks = 2 aging steps → effective class 0, rival still 1
        assert!(sq.admit(q(0, 8, 1, 4, None)));
        assert_eq!(sq.best_class(4), Some(0), "aged from the original arrival");
        let batch = sq.pop_batch(0, 1, 4);
        assert_eq!(batch[0].id, 7, "the readmitted survivor outranks the newcomer");
        assert!(sq.stats().aged_promotions >= 1, "the jump is accounted as a promotion");
        // control: had aging restarted at readmission (arrival 0 → 4), both
        // would sit at class 1 and the older arrival would still win — so
        // also pin the effective class directly via best_class at tick 5:
        // 7 waited 5 ticks (class 0), 8 waited 1 tick (class 1)
        sq.readmit(batch.into_iter().next().unwrap());
        assert_eq!(sq.best_class(5), Some(0));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "underflows")]
    fn complete_underflow_panics_in_debug() {
        let mut sq = SchedQueue::new(1, 0);
        sq.complete(0, 1);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn complete_underflow_saturates_in_release() {
        let mut sq = SchedQueue::new(1, 0);
        sq.complete(0, 1);
        // saturated, not wrapped: admission stays unjammed
        sq.set_cap(0, 1);
        assert!(sq.admit(q(0, 0, 0, 0, None)));
    }

    #[test]
    fn gauge_accessors_track_inflight_and_effective_class_depth() {
        let mut sq = SchedQueue::new(2, 2);
        assert!(sq.admit(q(0, 0, 1, 0, None)));
        assert!(sq.admit(q(1, 0, 0, 0, None)));
        assert_eq!(sq.inflight(0), 1);
        assert_eq!(sq.inflight(1), 1);
        assert_eq!(sq.depth_class(0, 0), 1);
        assert_eq!(sq.depth_class(1, 0), 1);
        // aging moves the class-1 query's *effective* depth bucket
        assert_eq!(sq.depth_class(0, 2), 2);
        assert_eq!(sq.depth_class(1, 2), 0);
        // popping empties depth but keeps in-flight until completion
        let b = sq.pop_batch(1, 1, 0);
        assert_eq!(b.len(), 1);
        assert_eq!(sq.depth_class(0, 0), 0);
        assert_eq!(sq.inflight(1), 1);
        sq.complete(1, 1);
        assert_eq!(sq.inflight(1), 0);
    }

    #[test]
    fn eligibility_mask_tracks_best_effective_class() {
        let mut sq = SchedQueue::new(3, 0);
        assert!(sq.admit(q(0, 0, 1, 0, None)));
        assert!(sq.admit(q(1, 0, 0, 0, None)));
        assert!(sq.admit(q(2, 0, 1, 0, None)));
        assert_eq!(sq.best_class(0), Some(0));
        assert_eq!(sq.eligible_mask(3, 0), vec![false, true, false]);
        let b = sq.pop_batch(1, 1, 0);
        assert_eq!(b.len(), 1);
        sq.complete(1, 1);
        assert_eq!(sq.best_class(0), Some(1));
        assert_eq!(sq.eligible_mask(3, 0), vec![true, false, true]);
    }
}
