//! Multi-tenant scheduler — the framework layer between the request edge
//! and the serving engine (`crate::serve`), turning the single-resident
//! pool+wave machinery of PRs 1–2 into a *serving platform*: N resident
//! models sharing the four parties, each model's offline material staged
//! ahead of its own traffic, and a deterministic planner deciding whose
//! wave runs next.
//!
//! ## Components
//!
//! * [`ModelRegistry`] ([`registry`]) — loads N resident models, registers
//!   each model's [`CircuitKey`](crate::pool::CircuitKey)s (the `model`
//!   field the keyed pool already carries is the tenant id), and pairs each
//!   tenant with its own background-refill targets. Pooled offline
//!   material is thereby **sharded per tenant**: a pop under tenant A's
//!   key can never serve tenant B's correlation — wrong-tenant material
//!   fails closed exactly like wrong-layer material
//!   ([`crate::pool::Pool::pop_mat`]).
//! * [`SchedQueue`] ([`queue`]) — replaces the FIFO-only
//!   [`RequestQueue`](crate::serve::RequestQueue) path: priority classes
//!   (0 = highest), **earliest-deadline-first within a class**, per-query
//!   expiry accounting (an expired query is counted and dropped, never
//!   served past its deadline), a starvation-freedom **aging** rule, and
//!   admission control with per-tenant in-flight caps.
//! * [`WavePlanner`] ([`planner`]) — picks the next tenant to serve by
//!   **smooth weighted round-robin** over the tenants eligible at the
//!   queue's best priority class (weights = tenant shares), so the wave
//!   split tracks the share split to within one wave over any window.
//!   Between waves the engine interleaves one refill tick for the
//!   **most-depleted** tenant pool ([`ModelRegistry::most_depleted`]).
//!
//! ## Lockstep determinism: logical ticks, no wall-clock
//!
//! Every scheduling decision must be taken identically by all four party
//! threads — a desynchronised pop or refill is a protocol break, not a
//! performance bug. The scheduler therefore never reads a wall clock (and
//! never reads the per-party *virtual* clocks, which legitimately differ
//! across parties): time is a **logical tick counter** advanced once per
//! planner iteration, shared by construction. Arrivals, deadlines, expiry
//! and aging are all expressed in ticks; query metadata (tenant, id, rows,
//! class, arrival, deadline) is public schedule state, identical at every
//! party, while the query *payload* exists only at the data owner. Tests
//! stay deterministic for the same reason the protocols do: same inputs,
//! same tick sequence, same decisions.
//!
//! The CLI maps `--deadline-ms N` to N logical ticks (one tick ≈ one
//! serving wave ≈ 1 ms on the simulated LAN profile); a deployment with
//! real clocks would instead stamp ticks from a leader-sequenced arrival
//! log — the tick abstraction is the point, not the unit.

pub mod planner;
pub mod queue;
pub mod registry;
pub mod workload;

pub use planner::WavePlanner;
pub use queue::{SchedQueue, SchedQueueStats, SchedQuery};
pub use registry::{
    tenant_layer_key, tenant_layer_weights, tenant_relu_key, tenant_wave_key, tenant_weights,
    ModelRegistry, ResidentModel, TenantLayer, TenantSpec,
};
pub use crate::proto::Backend;
pub use workload::{Checkpoint, TrainKind, Workload, BACK_GATE_BASE, GRAD_GATE_BASE};
